"""The paper's App, end to end: train -> export FAIR artifact -> "ship to the
client" -> load in a model-code-free runtime -> interactive generation.

This is the reproduction of Figures 2-3: the artifact (our ONNX analogue)
fully decouples inference from the training framework, and all health data
stays on the "client" side of the boundary.  With artifact spec v2 the
client generates via the exported prefill + KV-cached decode graphs
(``repro.api.Client``) instead of re-running the full graph per token; the
legacy ``InferenceSession`` shim keeps the v1 loop for comparison.

Run:  PYTHONPATH=src python examples/export_and_serve.py
"""
import json
import tempfile

import jax

from repro.api import Client
from repro.configs import get_config
from repro.core import init_delphi
from repro.data import (SimulatorConfig, batches, generate_dataset,
                        pack_trajectories)
from repro.data import vocab as V
from repro.sdk import export_model, verify_checksums
from repro.train import OptimizerConfig, train_loop


def main():
    cfg = get_config("delphi-2m").replace(dtype="float32", max_seq_len=96)
    params = init_delphi(cfg, jax.random.PRNGKey(0))

    print("== server side: train briefly on synthetic data ==")
    train, _ = generate_dataset(SimulatorConfig(n_train=512, n_val=8))
    ti = batches(pack_trajectories(train, 96), 32, seed=0)
    params, _ = train_loop(params, cfg,
                           OptimizerConfig(lr=6e-4, warmup_steps=5,
                                           total_steps=60),
                           ti, objective="delphi", steps=60, log_every=20)

    print("== export: the ONNX-conversion step (full + prefill + decode "
          "graphs, params, FAIR manifest) ==")
    d = tempfile.mkdtemp(prefix="delphi_artifact_")
    export_model(params, cfg, d)                 # spec v2 by default
    print("   artifact:", d)
    report = verify_checksums(d)                 # per-file integrity report
    print(f"   checksums: {report} "
          f"({', '.join(sorted(report.files))})")
    with open(f"{d}/manifest.json") as f:
        m = json.load(f)
    print("   FAIR manifest:", json.dumps(
        {k: m[k] for k in ("identifier", "spec_version",
                           "interchange_format", "license", "privacy")},
        indent=4))

    print("== client side: load the artifact (no model code, no network) ==")
    # migration note: InferenceSession(d) still works (it is now a shim over
    # this Client, pinned to the v1 full-graph loop); Client.from_artifact
    # uses the v2 prefill+decode graphs — O(1) model work per token.
    client = Client.from_artifact(d)
    tok, age = train[1]
    half = max(len(tok) // 2, 2)
    print(f"   input trajectory ({half} events, like the App's left panel):")
    for t, a in list(zip(tok[:half], age[:half]))[-5:]:
        print(f"     age {a:5.1f}  {V.code_name(int(t))}")

    print("   predicted continuation (right panel), streamed as sampled:")
    n = 0
    for ev in client.stream(tokens=tok[:half].tolist(),
                            ages=age[:half].tolist(), max_new=20):
        print(f"     age {ev.age:5.1f}  {V.code_name(ev.token)}")
        n += 1
    print(f"   {n} events (termination: Death token or age 85, "
          f"paper defaults)")

    print("   5-year morbidity risks (the App's displayed output):")
    for item in client.risk(tok[:half].tolist(), age[:half].tolist(),
                            horizon=5.0, top=5).items:
        print(f"     {item.risk:6.1%}  {V.code_name(item.token)}")


if __name__ == "__main__":
    main()
