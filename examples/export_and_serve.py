"""The paper's App, end to end: train -> export FAIR artifact -> "ship to the
client" -> load in a model-code-free runtime -> interactive generation.

This is the reproduction of Figures 2-3: the artifact (our ONNX analogue)
fully decouples inference from the training framework, and all health data
stays on the "client" side of the boundary.

Run:  PYTHONPATH=src python examples/export_and_serve.py
"""
import json
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import init_delphi
from repro.data import (SimulatorConfig, batches, generate_dataset,
                        pack_trajectories)
from repro.data import vocab as V
from repro.sdk import InferenceSession, export_model, verify_checksums
from repro.train import OptimizerConfig, train_loop


def main():
    cfg = get_config("delphi-2m").replace(dtype="float32", max_seq_len=96)
    params = init_delphi(cfg, jax.random.PRNGKey(0))

    print("== server side: train briefly on synthetic data ==")
    train, _ = generate_dataset(SimulatorConfig(n_train=512, n_val=8))
    ti = batches(pack_trajectories(train, 96), 32, seed=0)
    params, _ = train_loop(params, cfg,
                           OptimizerConfig(lr=6e-4, warmup_steps=5,
                                           total_steps=60),
                           ti, objective="delphi", steps=60, log_every=20)

    print("== export: the ONNX-conversion step (model.bin + params + "
          "FAIR manifest) ==")
    d = tempfile.mkdtemp(prefix="delphi_artifact_")
    export_model(params, cfg, d)
    print("   artifact:", d)
    print("   checksums verified:", verify_checksums(d))
    with open(f"{d}/manifest.json") as f:
        m = json.load(f)
    print("   FAIR manifest:", json.dumps(
        {k: m[k] for k in ("identifier", "interchange_format", "license",
                           "privacy")}, indent=4))

    print("== client side: load the artifact (no model code, no network) ==")
    sess = InferenceSession(d)   # <- imports nothing from repro.models/core
    tok, age = train[1]
    half = max(len(tok) // 2, 2)
    print(f"   input trajectory ({half} events, like the App's left panel):")
    for t, a in list(zip(tok[:half], age[:half]))[-5:]:
        print(f"     age {a:5.1f}  {V.code_name(int(t))}")

    out = sess.generateTrajectory(tok[:half].tolist(), age[:half].tolist(),
                                  max_new=20)
    print(f"   predicted continuation (right panel), {len(out['tokens'])} "
          f"events:")
    for t, a in zip(out["tokens"], out["ages"]):
        print(f"     age {a:5.1f}  {V.code_name(int(t))}")
    print("   (termination: Death token or age 85, paper defaults)")


if __name__ == "__main__":
    main()
