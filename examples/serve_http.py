"""End-to-end wire-protocol serving: the App's client/server split, live.

Boots the HTTP/SSE front-end over an engine backend (async admission: the
engine ticks on a background thread while handler threads enqueue), then
talks to it exactly the way the paper's thin JS SDK would — generate,
per-event SSE streaming, and the closed-form risk panel — through
``Client.connect(url)``, the fourth pluggable backend.

Run:  PYTHONPATH=src python examples/serve_http.py [--port 8478]
(--port 0 picks an ephemeral port; the server is torn down at the end.
 To keep one running instead, use the `repro-serve` CLI.)
"""
import argparse

import jax
import numpy as np

from repro.api import Client
from repro.api.client import EngineBackend
from repro.configs import get_config
from repro.core import init_delphi
from repro.data import vocab as V
from repro.serve.server import InferenceServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config("delphi-2m", reduced=True).replace(dtype="float32")
    params = init_delphi(cfg, jax.random.PRNGKey(0))
    backend = EngineBackend.create(params, cfg, slots=args.slots,
                                   max_context=128)
    server = InferenceServer(backend, port=args.port).start()
    print(f"== serving {backend.name} backend at {server.address} ==")

    client = Client.connect(server.address)
    m = client.backend.server_manifest
    print(f"manifest: wire v{m['protocol_version']}, "
          f"vocab={m['model']['vocab_size']}, "
          f"max_age={m['model']['max_age']}")

    toks = [V.SEX_MALE, V.LIFESTYLE0 + 2, V.DISEASE0 + 40]
    ages = [0.0, 30.0, 45.2]

    print("\n== POST /v1/generate ==")
    res = client.generate(tokens=toks, ages=ages, max_new=12)
    for t, a in zip(res.tokens, res.ages):
        print(f"  age {a:5.1f}  {V.code_name(t)}")

    print("\n== POST /v1/stream (SSE, event per engine tick) ==")
    for ev in client.stream(tokens=toks, ages=ages, max_new=8):
        print(f"  [{ev.index}] age {ev.age:5.1f}  {V.code_name(ev.token)}")

    print("\n== POST /v1/risk (the App's left-hand panel) ==")
    rep = client.risk(toks, ages, horizon=5.0, top=5)
    for it in rep.items:
        print(f"  {it.risk:6.4f}  {V.code_name(it.token)}")

    print(f"\nhealthz: {client.backend.healthz()}")
    server.stop()
    print("done.")


if __name__ == "__main__":
    main()
