"""Cohort-scale scenario analysis: population risk + counterfactuals.

Train a small Delphi, then drive a synthetic cohort through the paged +
prefix-cached batching engine with the ``ScenarioEngine`` — bounded
concurrency, per-patient injected uniforms (bit-reproducible regardless
of worker count), per-chapter population risk histograms — and finish
with a paired counterfactual: "how do this patient's 10-year chapter
risks change if one diagnosis had (not) happened?", re-forked from the
shared history prefix under common random numbers.

Run:  PYTHONPATH=src python examples/cohort_sweep.py [--patients 24]
"""
import argparse
import string

import jax
import numpy as np

from repro.api.client import EngineBackend
from repro.cohort import CounterfactualEdit, ScenarioEngine
from repro.configs import get_config
from repro.core import init_delphi
from repro.data import (SimulatorConfig, batches, generate_dataset,
                        pack_trajectories)
from repro.data import vocab as V
from repro.data.synthetic import patient
from repro.train import OptimizerConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--patients", type=int, default=24)
    ap.add_argument("--futures", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--horizon", type=float, default=10.0)
    ap.add_argument("--workers", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config("delphi-2m").replace(dtype="float32", max_seq_len=160)
    params = init_delphi(cfg, jax.random.PRNGKey(0))

    print(f"== train {args.steps} steps ==")
    train, _ = generate_dataset(SimulatorConfig(n_train=512, n_val=8))
    ti = batches(pack_trajectories(train, 96), 32, seed=0)
    params, _ = train_loop(params, cfg,
                           OptimizerConfig(lr=6e-4, total_steps=args.steps),
                           ti, objective="delphi", steps=args.steps,
                           log_every=20)

    # O(1) per-patient access — no need to materialize the whole split
    S = 12
    pats = []
    for i in range(args.patients):
        tok, age = patient(i, SimulatorConfig(seed=7))
        k = min(S, max(len(tok) - 1, 2))
        pats.append((tok[:k], age[:k]))

    print(f"== sweep {len(pats)} patients x {args.futures} futures "
          f"({args.workers} workers) ==")
    backend = EngineBackend.create(params, cfg, slots=8, max_context=160,
                                   cache="paged", block_size=16, blocks=512,
                                   prefix_cache=True)
    engine = ScenarioEngine(backend, max_in_flight=args.workers, seed=1)
    res = engine.sweep(pats, n_futures=args.futures, max_new=args.max_new,
                       horizon=args.horizon)
    print(f"   {res.n_ok}/{res.n_patients} patients, {res.events_total} "
          f"events in {res.wall_s:.1f}s ({res.patients_per_s:.1f} "
          f"patients/s, {res.events_per_s:.1f} events/s, prefix hit rate "
          f"{res.prefix_hit_rate:.2f})")

    print(f"   population {args.horizon:.0f}y chapter risk (top 6):")
    order = np.argsort(-res.chapter_mean)[:6]
    for c in order:
        label = ("non-disease" if c == 0
                 else f"chapter {string.ascii_uppercase[c - 1]}")
        bar = "#" * int(40 * res.chapter_mean[c])
        print(f"     {label:12s} {res.chapter_mean[c]:6.3f} {bar}")

    # paired counterfactual on the longest history in the cohort
    idx = max(range(len(pats)), key=lambda i: len(pats[i][0]))
    toks, ages = pats[idx]
    code = int(toks[len(toks) // 2])
    edits = [CounterfactualEdit("remove", code)]
    print(f"== counterfactual: patient {idx}, remove "
          f"{V.code_name(code)} at age "
          f"{float(ages[list(toks).index(code)]):.0f} ==")
    rep = engine.counterfactual(toks, ages, edits, n_futures=8,
                                max_new=args.max_new,
                                horizon=args.horizon)[0]
    print(f"   shared prefix {rep.shared_prefix_len}/{len(toks)} events; "
          f"top code-risk deltas:")
    for tok, base, edited, delta in rep.top_deltas[:6]:
        print(f"     {V.code_name(int(tok)):12s} "
              f"{base:.3f} -> {edited:.3f} ({delta:+.3f})")


if __name__ == "__main__":
    main()
