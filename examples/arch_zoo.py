"""Architecture zoo: every assigned architecture as a selectable config.

Runs a reduced variant of each family through forward + prefill + decode on
CPU and prints a table (the full configs are exercised by the dry-run:
``python -m repro.launch.dryrun --all``).

Run:  PYTHONPATH=src python examples/arch_zoo.py [--arch tinyllama-1.1b]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import decode_step, forward, init_params, param_count


def run_one(arch: str):
    cfg = get_config(arch, reduced=True).replace(dtype="float32")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, S = 2, 32
    batch = {"tokens": jax.random.randint(key, (B, S), 3, cfg.vocab_size)}
    if cfg.age_encoding:
        batch["ages"] = jnp.cumsum(
            jax.random.uniform(key, (B, S), maxval=3.0), axis=1)
    if cfg.frontend == "vision_patches":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.n_frontend_tokens, cfg.d_model))
    if cfg.frontend == "audio_frames":
        batch["frames"] = jax.random.normal(key, (B, 8, cfg.d_model))

    t0 = time.time()
    out = forward(params, cfg, batch, mode="train")
    pre = forward(params, cfg, batch, mode="prefill", cache_width=64)
    db = {"tokens": batch["tokens"][:, :1]}
    if cfg.age_encoding:
        db["ages"] = batch["ages"][:, :1]
    step_pos = S + (cfg.n_frontend_tokens
                    if cfg.frontend == "vision_patches" else 0)
    d = decode_step(params, cfg, pre["cache"], db, jnp.int32(step_pos))
    jax.block_until_ready(d["logits"])
    dt = time.time() - t0
    ok = bool(jnp.isfinite(out["logits"]).all()
              & jnp.isfinite(d["logits"]).all())
    full = get_config(arch)
    print(f"{arch:24s} {full.arch_type:7s} L{full.n_layers:<3d} "
          f"d{full.d_model:<5d} V{full.vocab_size:<7d} "
          f"| reduced {param_count(params)/1e6:5.2f}M params "
          f"fwd+prefill+decode {dt:5.2f}s finite={ok}")
    assert ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="run one architecture (default: all 10)")
    args = ap.parse_args()
    archs = [args.arch] if args.arch else ASSIGNED_ARCHS
    print(f"{'architecture':24s} {'type':7s} production-spec | reduced smoke")
    for a in archs:
        run_one(a)


if __name__ == "__main__":
    main()
