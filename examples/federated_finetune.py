"""Client-side federated fine-tuning — the paper's §Discussion future work.

Scenario: a model pretrained on the released synthetic cohort is fine-tuned
on K hospitals' *private* patients (here: a cohort simulated with shifted
hazards — a domain shift).  Patient data never leaves its client; only
clipped parameter deltas are averaged.

Run:  PYTHONPATH=src python examples/federated_finetune.py [--clients 6]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import init_delphi
from repro.core.delphi import loss_fn
from repro.data import (SimulatorConfig, batches, generate_dataset,
                        pack_trajectories)
from repro.federated import FedConfig, federated_finetune
from repro.train import OptimizerConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=6)
    ap.add_argument("--pretrain-steps", type=int, default=60)
    ap.add_argument("--rounds", type=int, default=5)
    args = ap.parse_args()

    cfg = get_config("delphi-2m").replace(dtype="float32", max_seq_len=96)
    params = init_delphi(cfg, jax.random.PRNGKey(0))

    print("== pretrain on the public synthetic cohort ==")
    public, _ = generate_dataset(SimulatorConfig(n_train=512, n_val=8))
    it = batches(pack_trajectories(public, 96), 32, seed=0)
    params, _ = train_loop(
        params, cfg, OptimizerConfig(lr=6e-4, total_steps=args.pretrain_steps),
        it, objective="delphi", steps=args.pretrain_steps, log_every=20)

    print("== private cohort (shifted hazards = domain shift) ==")
    shifted = SimulatorConfig(n_train=64 * args.clients, n_val=128, seed=123,
                              mean_age_slope=0.5, death_age_slope=1.1,
                              mean_log_hazard=-10.0)
    private, private_val = generate_dataset(shifted)
    pv = pack_trajectories(private_val, 96)
    vb = {k: jnp.asarray(v[:64]) for k, v in pv.items()}

    @jax.jit
    def val_loss(p):
        return loss_fn(p, cfg, vb)["loss"]

    print(f"   pretrain model on private-domain val: {val_loss(params):.4f}")

    shards = [private[i::args.clients] for i in range(args.clients)]
    iters = [batches(pack_trajectories(s, 96), 16, seed=i)
             for i, s in enumerate(shards)]
    fed = FedConfig(n_rounds=args.rounds, local_steps=5, local_lr=5e-4,
                    clip_delta_norm=10.0)
    print(f"== federated fine-tune: {args.clients} clients x "
          f"{len(shards[0])} patients, deltas clipped, data stays local ==")
    params, hist = federated_finetune(params, cfg, iters, fed,
                                      eval_fn=val_loss)
    print(f"   private-domain val: {hist['val'][0]:.4f} -> "
          f"{min(hist['val']):.4f} (no patient record ever centralized)")


if __name__ == "__main__":
    main()
