"""Quickstart: the paper's pipeline end to end, in one file, on CPU.

1. simulate synthetic disease histories (the released-data stand-in),
2. train Delphi-2M (dual loss: next event + time-to-event),
3. generate future-trajectory predictions with the eq.-1 sampler.

Run:  PYTHONPATH=src python examples/quickstart.py [--steps 120]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import generate_trajectories, init_delphi
from repro.data import (SimulatorConfig, batches, dataset_stats,
                        generate_dataset, pack_trajectories)
from repro.data import vocab as V
from repro.train import OptimizerConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--patients", type=int, default=1024)
    ap.add_argument("--seq-len", type=int, default=96)
    args = ap.parse_args()

    cfg = get_config("delphi-2m").replace(dtype="float32",
                                          max_seq_len=args.seq_len)
    params = init_delphi(cfg, jax.random.PRNGKey(0))

    print("== 1. synthetic data (competing-risk simulator) ==")
    train, val = generate_dataset(SimulatorConfig(
        n_train=args.patients, n_val=max(args.patients // 8, 32)))
    print("   stats:", dataset_stats(train))

    print("== 2. train (event CE + exponential time NLL) ==")
    ti = batches(pack_trajectories(train, args.seq_len), 32, seed=0)
    vi = batches(pack_trajectories(val, args.seq_len), 32, seed=1)
    ocfg = OptimizerConfig(lr=6e-4, warmup_steps=max(args.steps // 20, 5),
                           total_steps=args.steps)
    params, hist = train_loop(params, cfg, ocfg, ti, objective="delphi",
                              steps=args.steps, eval_iter=vi,
                              eval_every=max(args.steps // 3, 20))
    print(f"   loss {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f}; "
          f"val {hist['val_loss']}")

    print("== 3. predict future trajectories (paper eq. 1) ==")
    tok, age = train[0]
    half = max(len(tok) // 2, 2)
    out = generate_trajectories(
        params, cfg, jnp.asarray(tok[:half][None]),
        jnp.asarray(age[:half][None]), jax.random.PRNGKey(7), max_new=24)
    n = int(out["n_generated"][0])
    print(f"   patient history ({half} events, age "
          f"{age[half-1]:.1f}y) -> {n} predicted events:")
    for i in range(n):
        t = int(out["tokens"][0, half + i])
        a = float(out["ages"][0, half + i])
        print(f"     age {a:5.1f}  {V.code_name(t)}")


if __name__ == "__main__":
    main()
