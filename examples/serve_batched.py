"""End-to-end serving driver (the mandated e2e example for a serving paper):
train a small Delphi, then serve a stream of batched trajectory requests
through the device-resident continuous-batching engine — one jitted
decode_and_sample step per tick, eq. 1 sampling in-graph, a single packed
host transfer per tick, and bucketed-padding batched prefill on admission.

Run:  PYTHONPATH=src python examples/serve_batched.py [--requests 24]
"""
import argparse
import time

import jax

from repro.configs import get_config
from repro.core import init_delphi
from repro.data import (SimulatorConfig, batches, generate_dataset,
                        pack_trajectories)
from repro.data import vocab as V
from repro.serve import BatchedEngine, Request
from repro.train import OptimizerConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config("delphi-2m").replace(dtype="float32", max_seq_len=160)
    params = init_delphi(cfg, jax.random.PRNGKey(0))

    print(f"== train {args.steps} steps ==")
    train, _ = generate_dataset(SimulatorConfig(n_train=512, n_val=8))
    ti = batches(pack_trajectories(train, 96), 32, seed=0)
    params, _ = train_loop(params, cfg,
                           OptimizerConfig(lr=6e-4, total_steps=args.steps),
                           ti, objective="delphi", steps=args.steps,
                           log_every=20)

    print(f"== serve {args.requests} requests on {args.slots} slots ==")
    eng = BatchedEngine(params, cfg, slots=args.slots, max_context=160)
    reqs, _ = generate_dataset(SimulatorConfig(n_train=args.requests, n_val=1,
                                               seed=99))
    t0 = time.time()
    for tok, age in reqs:
        h = max(len(tok) // 2, 2)
        eng.submit(Request(tokens=tok[:h], ages=age[:h],
                           max_new=args.max_new))
    done = eng.run()
    dt = time.time() - t0
    ev = sum(len(r.out_tokens) for r in done)
    print(f"   {len(done)} requests, {ev} events in {dt:.1f}s "
          f"({ev/dt:.1f} events/s, {eng.ticks/dt:.1f} ticks/s, "
          f"{eng.host_syncs} host syncs over {eng.ticks} ticks + "
          f"{eng.admit_batches} admissions, "
          f"prefill shapes {sorted(eng.prefill_shapes)})")

    r = done[0]
    print("   sample continuation:")
    for t, a in list(zip(r.out_tokens, r.out_ages))[:8]:
        print(f"     age {a:5.1f}  {V.code_name(int(t))}")
    deaths = sum(r.out_tokens[-1] == V.DEATH for r in done if r.out_tokens)
    print(f"   {deaths}/{len(done)} trajectories terminated at Death; "
          f"rest censored at max age / max_new")

    # the same engine behind the unified client API: per-event streaming
    from repro.api import Client
    client = Client.from_engine(eng)
    tok, age = reqs[0]
    h = max(len(tok) // 2, 2)
    print("   streamed via repro.api.Client.from_engine(engine):")
    for ev in client.stream(tokens=tok[:h].tolist(), ages=age[:h].tolist(),
                            max_new=6):
        print(f"     age {ev.age:5.1f}  {V.code_name(ev.token)}")


if __name__ == "__main__":
    main()
