"""Ablation: the paper's dual loss vs an event-only objective.

Delphi's defining training choice is the *dual* objective — next-event CE
plus the exponential time-to-event NLL over the same logit head (rates
lambda_i = e^{logit_i}).  This ablation trains the same model with
time_weight in {0, 1} and evaluates both terms on held-out patients:

  * time_weight=1 must achieve much lower val time-NLL (it actually models
    waiting times) at little-to-no cost in event CE;
  * with time_weight=0 the logit scale is unconstrained, so the implied
    total rate (and hence sampled waiting times) is arbitrary — the reason
    the paper's eq.-1 sampler needs the dual loss to be meaningful.

Run:  PYTHONPATH=src python examples/ablation_dual_loss.py [--steps 80]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import init_delphi
from repro.core.delphi import loss_fn
from repro.data import (SimulatorConfig, batches, generate_dataset,
                        pack_trajectories)
from repro.train import OptimizerConfig, init_opt_state
from repro.train.optimizer import adamw_update


def train_one(cfg, data_iter, steps, time_weight, seed=0):
    params = init_delphi(cfg, jax.random.PRNGKey(seed))
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=max(steps // 10, 3),
                           total_steps=steps)

    @jax.jit
    def step(params, opt, batch):
        def scalar(p):
            m = loss_fn(p, cfg, batch, time_weight=time_weight)
            return m["loss"], m
        g, m = jax.grad(scalar, has_aux=True)(params)
        params, opt, _ = adamw_update(g, opt, params, ocfg)
        return params, opt, m

    opt = init_opt_state(params)
    for _ in range(steps):
        b = {k: jnp.asarray(v) for k, v in next(data_iter).items()}
        params, opt, m = step(params, opt, b)
    return params


def evaluate(cfg, params, val_iter, n_batches=4):
    ce = tn = 0.0
    lam = 0.0
    for _ in range(n_batches):
        b = {k: jnp.asarray(v) for k, v in next(val_iter).items()}
        m = loss_fn(params, cfg, b, time_weight=1.0)
        ce += float(m["event_ce"]) / n_batches
        tn += float(m["time_nll"]) / n_batches
        # implied total event rate at supervised positions
        from repro.core.delphi import get_logits
        lg = get_logits(params, cfg, b["tokens"], b["ages"])
        rate = jnp.exp(jax.nn.logsumexp(lg, axis=-1))
        mask = b["loss_mask"]
        lam += float(jnp.sum(rate * mask) / jnp.maximum(jnp.sum(mask), 1)) \
            / n_batches
    return ce, tn, lam


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--patients", type=int, default=512)
    args = ap.parse_args()

    cfg = get_config("delphi-2m").replace(dtype="float32", max_seq_len=96)
    train, val = generate_dataset(SimulatorConfig(
        n_train=args.patients, n_val=128))
    pt, pv = pack_trajectories(train, 96), pack_trajectories(val, 96)

    # empirical event rate of the data (events per patient-year)
    import numpy as np
    dt = pt["target_dt"][pt["loss_mask"] > 0]
    print(f"data: mean waiting time {dt.mean():.3f}y "
          f"-> empirical rate ~{1 / dt.mean():.2f}/y")

    print(f"{'time_weight':>12s} {'val event CE':>14s} {'val time NLL':>14s} "
          f"{'implied rate/y':>15s}")
    for tw in (0.0, 1.0):
        params = train_one(cfg, batches(pt, 32, seed=0), args.steps, tw)
        ce, tn, lam = evaluate(cfg, params, batches(pv, 32, seed=1))
        print(f"{tw:12.1f} {ce:14.4f} {tn:14.4f} {lam:15.3f}")
    print("(dual loss calibrates the total rate toward the empirical rate; "
          "event-only leaves it arbitrary)")


if __name__ == "__main__":
    main()
