"""Benchmark harness — one benchmark per paper claim/figure.

Prints ``name,us_per_call,derived`` CSV rows:

  logits_native / logits_artifact   Fig. 3: same graph under the in-framework
                                    runtime vs the exported FAIR artifact
                                    (the paper's ONNX portability claim)
  trajectory_sdk_host               Fig. 2 App loop: host-side NumPy SDK
                                    generation (paper-faithful client path)
  trajectory_batched_graph          beyond-paper: in-graph batched sampler
                                    (lax.fori_loop + KV cache), events/s
  sdk_v1_fullgraph / sdk_v2_decode  artifact spec v2: full-graph-per-token
                                    client loop vs exported prefill + KV-
                                    cached decode graphs (tokens/s, same
                                    injected uniforms -> same events)
  tte_fused_kernel / tte_ref        eq. 1 sampler: fused Pallas kernel
                                    (interpret-mode CPU proxy) vs jnp oracle
  train_step_delphi                 dual-loss training throughput, tokens/s
  serving_engine_batched            slot continuous batching end-to-end
  serving_ring/paged_fixed_mem      paged KV cache vs dense ring at EQUAL
                                    resident KV bytes: tokens/s, ticks/s,
                                    peak concurrent requests, pool
                                    utilization, preemptions
  futures_shared / futures_naive    N Monte-Carlo futures per patient:
                                    prefix-shared engine fork (1 prefill,
                                    COW tails) vs N independent requests —
                                    events/s + peak resident KV bytes
  http_generate_p50/p95             wire-protocol serving: concurrent
                                    RemoteBackend clients vs the threaded
                                    HTTP front-end (req/s + latency tails)
  http_keepalive_*                  HTTP/1.1 keep-alive connection reuse vs
                                    a fresh socket per call (req/s delta)
  router_Nx_p50 / router_2x_speedup horizontal serving: the prefix-affinity
                                    router over 1/2/4 engine replicas under
                                    mixed generate/futures load (req/s +
                                    latency tails; 2x row asserts >= 1.5x
                                    the 1-replica req/s)
  cohort_sweep / cohort_*           cohort-scale scenario engine: 1000
                                    patients x 4 futures through the paged +
                                    prefix-cached engine, bit-identical to
                                    the straight-line foreground oracle;
                                    counterfactual re-fork amortization and
                                    shared-vs-naive resident KV (rows append
                                    to BENCH_cohort.json)
  roofline_*                        derived = dominant roofline term (reads
                                    experiments/dryrun; skipped when absent)

CPU numbers are proxies for relative comparisons, not TPU projections — the
TPU story lives in EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import os
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, n: int = 10, warmup: int = 2) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def _row(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")


def bench_runtime_portability():
    from repro.configs import get_config
    from repro.core import get_logits, init_delphi
    from repro.sdk import Runtime, export_model

    cfg = get_config("delphi-2m").replace(dtype="float32", max_seq_len=64)
    params = init_delphi(cfg, jax.random.PRNGKey(0))
    tokens = jnp.zeros((1, 64), jnp.int32)
    ages = jnp.zeros((1, 64), jnp.float32)

    native = jax.jit(lambda p, t, a: get_logits(p, cfg, t, a))
    us_native = _time(native, params, tokens, ages)
    _row("logits_native", us_native, f"{1e6 / us_native:.1f} calls/s")

    d = tempfile.mkdtemp()
    export_model(params, cfg, d)
    rt = Runtime(d)
    t_np, a_np = np.asarray(tokens), np.asarray(ages)
    us_art = _time(lambda: rt.run(t_np, a_np), n=10)
    _row("logits_artifact", us_art,
         f"overhead {us_art / us_native:.2f}x vs native")


def bench_trajectory_generation():
    from repro.configs import get_config
    from repro.core import generate_trajectories_jit, init_delphi
    from repro.sdk import InferenceSession, export_model

    cfg = get_config("delphi-2m").replace(dtype="float32", max_seq_len=96)
    params = init_delphi(cfg, jax.random.PRNGKey(0))
    d = tempfile.mkdtemp()
    export_model(params, cfg, d)
    sess = InferenceSession(d)

    toks, ags = [3, 500, 700], [0.0, 30.0, 40.0]
    n_events = 16

    def sdk_loop():
        return sess.generate_trajectory(toks, ags, max_new=n_events,
                                        max_age=1e9)
    t0 = time.perf_counter()
    out = sdk_loop()
    us = (time.perf_counter() - t0) * 1e6
    ev = max(len(out["tokens"]), 1)
    _row("trajectory_sdk_host", us / ev, f"{ev * 1e6 / us:.1f} events/s")

    B = 16
    tokens = jnp.tile(jnp.asarray(toks, jnp.int32)[None], (B, 1))
    ages = jnp.tile(jnp.asarray(ags, jnp.float32)[None], (B, 1))
    fn = lambda: generate_trajectories_jit(  # noqa: E731
        params, cfg, tokens, ages, jax.random.PRNGKey(1), max_new=n_events)
    us_g = _time(fn, n=3, warmup=1)
    ev_g = B * n_events
    _row("trajectory_batched_graph", us_g / ev_g,
         f"{ev_g * 1e6 / us_g:.1f} events/s (beyond-paper batched path)")


def bench_sdk():
    """Before/after for the artifact spec-v2 redesign: the v1 client path
    (re-running the O(S·V) full graph per generated token) vs the v2 path
    (one prefill, then one KV-cached decode_step per token), same artifact,
    same injected uniforms.  Early events are bit-identical; over a long
    horizon fp fusion noise compounds through the age feedback (the caveat
    tests/test_serve_device.py documents), so parity is asserted on the
    leading prefix and the agreement length is reported."""
    from repro.api import Client
    from repro.configs import get_config
    from repro.core import init_delphi
    from repro.sdk import export_model

    # the artifact keeps the config's native fixed axis (S=256): that is the
    # graph the paper's App ships, and exactly what the v1 client re-runs
    # once per generated token
    cfg = get_config("delphi-2m").replace(dtype="float32", max_age=1e9)
    params = init_delphi(cfg, jax.random.PRNGKey(0))
    d = tempfile.mkdtemp()
    export_model(params, cfg, d)

    toks, ags = [3, 500, 700], [0.0, 30.0, 40.0]
    max_new = 48
    rng = np.random.default_rng(7)
    u = rng.uniform(size=(max_new, cfg.vocab_size)).astype(np.float32)

    v1 = Client.from_artifact(d, use_decode_graph=False)
    v2 = Client.from_artifact(d)

    def measure(client):
        def gen():
            return client.generate(tokens=toks, ages=ags, max_new=max_new,
                                   uniforms=u)
        gen()                                    # warm the graph jits
        ts, ev = [], None
        for _ in range(3):
            t0 = time.perf_counter()
            out = gen()
            ts.append(time.perf_counter() - t0)
            ev = len(out.tokens)
        return ev, float(np.median(ts)), out

    ev1, dt1, out1 = measure(v1)
    _row("sdk_v1_fullgraph", dt1 * 1e6 / max(ev1, 1),
         f"{ev1 / dt1:.1f} tokens/s (full graph per token)")
    ev2, dt2, out2 = measure(v2)
    _row("sdk_v2_decode", dt2 * 1e6 / max(ev2, 1),
         f"{ev2 / dt2:.1f} tokens/s (prefill + KV-cached decode)")
    agree = 0
    for a, b in zip(out1.tokens, out2.tokens):
        if a != b:
            break
        agree += 1
    assert agree >= min(8, ev1), \
        f"v1/v2 diverged after {agree} events — expected >= 8"
    _row("sdk_v2_speedup", 0.0,
         f"{(ev2 / dt2) / max(ev1 / dt1, 1e-9):.2f}x tokens/s v2 vs v1 "
         f"({ev1} events, first {agree} bit-identical)")


def bench_tte_kernel():
    from repro.kernels import tte_sample
    from repro.kernels.ref import tte_sample_ref

    for V in (1289, 256206):
        logits = jax.random.normal(jax.random.PRNGKey(0), (8, V))
        u = jax.random.uniform(jax.random.PRNGKey(1), (8, V))
        us_ref = _time(jax.jit(tte_sample_ref), logits, u)
        _row(f"tte_ref_V{V}", us_ref, f"{8e6 / us_ref:.0f} samples/s")
        us_k = _time(lambda l, uu: tte_sample(l, uu), logits, u, n=3,
                     warmup=1)
        _row(f"tte_fused_kernel_V{V}", us_k,
             "interpret-mode proxy; HBM-fusion win is a TPU property")


def bench_train_step():
    from repro.configs import get_config
    from repro.core import init_delphi
    from repro.data import SimulatorConfig, generate_dataset, pack_trajectories
    from repro.train import OptimizerConfig, init_opt_state, make_train_step

    cfg = get_config("delphi-2m").replace(dtype="float32", max_seq_len=96)
    params = init_delphi(cfg, jax.random.PRNGKey(0))
    train, _ = generate_dataset(SimulatorConfig(n_train=64, n_val=1))
    packed = pack_trajectories(train, 96)
    batch = {k: jnp.asarray(v[:32]) for k, v in packed.items()}
    step = jax.jit(make_train_step(cfg, OptimizerConfig(), "delphi"))
    opt = init_opt_state(params)

    def run(p, o, b):
        p2, o2, m = step(p, o, b)
        return m["loss"]
    us = _time(run, params, opt, batch, n=5, warmup=1)
    toks = 32 * 96
    _row("train_step_delphi", us, f"{toks * 1e6 / us:.0f} tokens/s")


def bench_serving_engine():
    """Before/after for the device-resident engine rework: the seed host-loop
    engine (vmap-of-single-slot decode + per-slot host sampling) vs the
    jitted decode_and_sample tick with ONE host sync per tick."""
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serve import BatchedEngine, ReferenceEngine, Request

    cfg = get_config("delphi-2m").replace(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))

    def _requests(n):
        return [Request(tokens=np.arange(3, 9, dtype=np.int32),
                        ages=np.linspace(0, 30, 6).astype(np.float32),
                        max_new=12) for _ in range(n)]

    def _measure(make_engine):
        # warm then measure the SAME instance: compiles of the (slots,
        # bucket) prefill, the tick, and the insert/commit shapes all land
        # in the warmup (the device engine additionally shares compiles
        # across instances via its module-level jits)
        eng = make_engine()
        for r in _requests(8):
            eng.submit(r)
        eng.run()
        n_done = len(eng.completed)
        ticks0 = getattr(eng, "ticks", 0)
        for r in _requests(16):
            eng.submit(r)
        t0 = time.perf_counter()
        done = eng.run()
        dt = time.perf_counter() - t0
        new = done[n_done:]
        ev = sum(len(r.out_tokens) for r in new)
        ticks = getattr(eng, "ticks", None)
        ticks = ticks - ticks0 if ticks is not None else None
        return ev, dt, ticks, len(new)

    ev_r, dt_r, _, n_r = _measure(
        lambda: ReferenceEngine(params, cfg, slots=8, max_context=128))
    _row("serving_engine_seed", dt_r * 1e6 / max(ev_r, 1),
         f"{ev_r / dt_r:.1f} events/s across {n_r} requests (host-loop)")

    ev_d, dt_d, ticks, n_d = _measure(
        lambda: BatchedEngine(params, cfg, slots=8, max_context=128))
    _row("serving_engine_device", dt_d * 1e6 / max(ev_d, 1),
         f"{ev_d / dt_d:.1f} events/s, {ticks / dt_d:.1f} ticks/s "
         f"across {n_d} requests (device-resident)")
    _row("serving_engine_speedup", 0.0,
         f"{(ev_d / dt_d) / max(ev_r / dt_r, 1e-9):.2f}x tokens/s "
         f"device-resident vs seed")
    bench_paged_vs_ring(params, cfg)
    bench_chunked_prefill()


def bench_paged_vs_ring(params, cfg):
    """Fixed-memory concurrency: a dense ring burns slots x max_context
    whether a trajectory is 5 events or 500; the paged pool admits by
    free-block budget, so at the SAME resident KV bytes it sustains far
    more concurrent short requests (Delphi trajectories are short-median/
    long-tail).  Reports KV-cache bytes, block-pool utilization and peak
    concurrent requests alongside tokens/s + ticks/s."""
    from repro.serve import BatchedEngine, Request

    W, bs, dense_slots = 128, 16, 4
    n_req, max_new = 24, 12

    def _requests():
        return [Request(tokens=np.arange(3, 9, dtype=np.int32),
                        ages=np.linspace(0, 30, 6).astype(np.float32),
                        max_new=max_new) for _ in range(n_req)]

    def _measure(eng):
        for r in _requests():
            eng.submit(r)
        eng.run()                        # warm ALL jit shapes (same load)
        eng.peak_active, t0 = 0, time.perf_counter()
        ticks0 = eng.ticks
        for r in _requests():
            eng.submit(r)
        done = eng.run()
        dt = time.perf_counter() - t0
        ev = sum(len(r.out_tokens) for r in done[-n_req:])
        return ev, dt, eng.ticks - ticks0

    ring = BatchedEngine(params, cfg, slots=dense_slots, max_context=W)
    ev, dt, ticks = _measure(ring)
    _row("serving_ring_fixed_mem", dt * 1e6 / max(ev, 1),
         f"{ev / dt:.1f} events/s, {ticks / dt:.1f} ticks/s, "
         f"kv_bytes={ring.cache_bytes} peak_concurrent={ring.peak_active} "
         f"({dense_slots} dense slots)")

    # same resident KV bytes: pool holds dense_slots * (W/bs) real blocks
    paged = BatchedEngine(params, cfg, slots=4 * dense_slots, max_context=W,
                          cache="paged", block_size=bs,
                          blocks=dense_slots * (W // bs) + 1)
    ev_p, dt_p, ticks_p = _measure(paged)
    st = paged.pool_stats()
    _row("serving_paged_fixed_mem", dt_p * 1e6 / max(ev_p, 1),
         f"{ev_p / dt_p:.1f} events/s, {ticks_p / dt_p:.1f} ticks/s, "
         f"kv_bytes={paged.cache_bytes} peak_concurrent={paged.peak_active} "
         f"peak_pool_util={st['blocks_peak_used'] / max(paged.allocator.capacity, 1):.2f} "
         f"preemptions={st['preemptions']} "
         f"shared_peak={st['shared_blocks_peak']} cow={st['cow_copies']}")
    assert paged.allocator.used == 0, "paged benchmark leaked blocks"
    assert paged.peak_active > ring.peak_active, \
        (paged.peak_active, ring.peak_active)
    _row("serving_paged_concurrency_gain", 0.0,
         f"{paged.peak_active / max(ring.peak_active, 1):.1f}x peak "
         f"concurrent requests at equal KV bytes "
         f"({paged.cache_bytes / max(ring.cache_bytes, 1):.2f}x bytes)")


def _git_rev() -> str:
    import subprocess
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=os.path.dirname(
                os.path.dirname(os.path.abspath(__file__))),
            capture_output=True, text=True, timeout=10).stdout.strip() \
            or "unknown"
    except Exception:               # noqa: BLE001 — bench must not die on VCS
        return "unknown"


def _bench_serve_record(mode: str, config: dict, metrics: dict) -> None:
    """Append one machine-readable record to BENCH_serve.json (JSON lines:
    each run appends, nothing is rewritten — diffable across commits)."""
    import json
    path = os.path.join(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), "BENCH_serve.json")
    rec = {"schema": 1, "bench": "serve", "mode": mode,
           "git_rev": _git_rev(), "timestamp": round(time.time(), 1),
           "config": config, "metrics": metrics}
    with open(path, "a") as f:
        f.write(json.dumps(rec) + "\n")


def bench_chunked_prefill():
    """Mixed long/short workload: in-flight short decodes tick while long
    prompts keep arriving.  Unchunked, every long admission's monolithic
    prefill stalls ALL in-flight decode slots for the whole prompt — the
    stall lands in the short requests' per-event latency tail.  Chunked
    (``prefill_chunk_tokens``), prefill is metered through the per-tick
    budget between decode ticks, so the tail collapses while throughput
    holds (bit-identical outputs either way — the parity invariant
    scripts/paged_parity.py and tests/test_prefix.py pin down).  A third
    run shows partial-prefix suffix prefill: a long prompt extending an
    already-cached prefix admits by reference and prefills only the
    suffix.  Every row is appended to BENCH_serve.json."""
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serve import BatchedEngine, Request

    cfg = get_config("delphi-2m", reduced=True).replace(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    W, bs, chunk = 512, 16, 64
    S_long, n_long, n_short, max_new = 448, 6, 6, 48

    def shorts():
        return [Request(
            tokens=((np.arange(3, 9) + 7 * i) % 90).astype(np.int32),
            ages=np.linspace(0.0, 30.0, 6).astype(np.float32),
            max_new=max_new) for i in range(n_short)]

    def longs():
        return [Request(
            tokens=((np.arange(3, 3 + S_long) + 11 * i) % 90).astype(
                np.int32),
            ages=np.linspace(0.0, 60.0, S_long).astype(np.float32),
            max_new=4) for i in range(n_long)]

    def run(chunk_tokens):
        eng = BatchedEngine(params, cfg, slots=12, max_context=W,
                            cache="paged", block_size=bs, blocks=256,
                            prefill_chunk_tokens=chunk_tokens)

        def drive():
            ss, ls = shorts(), longs()
            for r in ss:
                eng.submit(r)
            pending = list(ls)
            lat: list = []
            seen = [0] * n_short
            now = time.perf_counter()
            last = [now] * n_short
            tick, t0 = 0, now
            while not all(r.done for r in ss + ls):
                if pending and tick % 8 == 3:   # longs arrive mid-decode
                    eng.submit(pending.pop(0))
                eng.step()
                tick += 1
                now = time.perf_counter()
                for i, r in enumerate(ss):
                    k = len(r.out_tokens)
                    if k > seen[i]:
                        dt = (now - last[i]) / (k - seen[i])
                        lat.extend([dt] * (k - seen[i]))
                        seen[i], last[i] = k, now
            wall = now - t0
            ev = sum(len(r.out_tokens) for r in ss + ls)
            return np.asarray(lat), ev, wall
        drive()                                 # warm every jit shape
        lat, ev, wall = drive()
        assert eng.allocator.used == 0, "mixed-workload bench leaked blocks"
        return lat, ev, wall, eng.pool_stats()

    config = {"slots": 12, "max_context": W, "block_size": bs,
              "blocks": 256, "S_long": S_long, "n_long": n_long,
              "n_short": n_short, "max_new_short": max_new}
    results = {}
    for mode, ct in (("monolithic", None), ("chunked", chunk)):
        lat, ev, wall, st = run(ct)
        p50, p95 = np.percentile(lat, 50), np.percentile(lat, 95)
        results[mode] = (p50, p95, ev / wall)
        derived = (f"{ev / wall:.1f} events/s, p50 {p50 * 1e3:.1f} ms "
                   f"per short-request event")
        if ct is not None:
            derived += (f" (chunk={ct}, {st['prefill_chunks']} chunks / "
                        f"{st['chunked_prefills']} prefills)")
        _row(f"serving_mixed_{mode}_p95", p95 * 1e6, derived)
        _bench_serve_record(
            mode, dict(config, prefill_chunk_tokens=ct),
            {"p50_event_latency_us": round(p50 * 1e6, 1),
             "p95_event_latency_us": round(p95 * 1e6, 1),
             "events_per_s": round(ev / wall, 2),
             "chunked_prefills": st["chunked_prefills"],
             "prefill_chunks": st["prefill_chunks"],
             "suffix_tokens_saved": st["suffix_tokens_saved"],
             "preemptions": st["preemptions"]})
    gain = results["monolithic"][1] / max(results["chunked"][1], 1e-12)
    thru = results["chunked"][2] / max(results["monolithic"][2], 1e-12)
    _row("serving_chunked_p95_gain", 0.0,
         f"{gain:.1f}x lower p95 per-event latency at {thru:.2f}x "
         f"throughput, chunked vs monolithic prefill")
    assert gain >= 2.0, \
        f"chunked prefill p95 gain {gain:.2f}x < 2x over monolithic"

    # partial-prefix suffix prefill: the second long prompt extends the
    # first's prefix, so only the unmatched suffix runs through prefill
    eng = BatchedEngine(params, cfg, slots=4, max_context=W, cache="paged",
                        block_size=bs, blocks=128, prefix_cache=True,
                        prefill_chunk_tokens=chunk)
    base = longs()[0]
    eng.submit(base)
    eng.run()
    matched = (S_long // bs) * bs
    ext = Request(
        tokens=np.concatenate([np.asarray(base.tokens),
                               (np.arange(10, 26) % 90)]).astype(np.int32),
        ages=np.concatenate([np.asarray(base.ages),
                             np.linspace(61.0, 70.0, 16)]).astype(
                                 np.float32),
        max_new=4)
    t0 = time.perf_counter()
    eng.submit(ext)
    eng.run()
    dt_suffix = time.perf_counter() - t0
    st = eng.pool_stats()
    assert st["suffix_tokens_saved"] >= matched, \
        f"suffix admission saved {st['suffix_tokens_saved']} < {matched}"
    _row("serving_suffix_prefill", dt_suffix * 1e6,
         f"suffix_tokens_saved={st['suffix_tokens_saved']} of "
         f"S={S_long + 16} prompt, partial_hits="
         f"{st['prefix_cache']['partial_hits']} (prefix-cache reuse)")
    _bench_serve_record(
        "suffix", {"slots": 4, "max_context": W, "block_size": bs,
                   "blocks": 128, "prefill_chunk_tokens": chunk,
                   "S_base": S_long, "S_ext": S_long + 16},
        {"suffix_tokens_saved": st["suffix_tokens_saved"],
         "partial_hits": st["prefix_cache"]["partial_hits"],
         "prefill_chunks": st["prefill_chunks"],
         "ext_request_wall_us": round(dt_suffix * 1e6, 1)})
    eng.drop_prefix_cache()
    assert eng.allocator.used == 0


def bench_futures():
    """The paper's headline workload at serving scale: N Monte-Carlo
    futures per patient.  `futures_shared` forks N decode slots off ONE
    prefilled history (prefix blocks shared by reference, tails copy-on-
    write); `futures_naive` runs the same N continuations as independent
    requests, each re-prefilling and holding its own KV.  Reports events/s
    and the PEAK RESIDENT KV bytes actually backing the N futures — the
    shared path should sit well under 2x a single request's bytes where
    naive pays ~Nx."""
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serve import BatchedEngine, Request

    cfg = get_config("delphi-2m").replace(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    N, max_new, W, bs = 8, 8, 256, 16
    # long history, one token past a block boundary: the shared prefix is
    # 16 blocks and each future's 8 decode writes fit inside its single
    # copy-on-written tail block
    S = 241
    toks = (np.arange(3, 3 + S) % 1200).astype(np.int32)
    ages = np.linspace(0.0, 60.0, S).astype(np.float32)

    def block_bytes(eng):
        pc = eng.cache["self"]
        per = (pc.k.size + pc.v.size) // pc.k.shape[1]
        return per * pc.k.dtype.itemsize

    def run_shared():
        eng = BatchedEngine(params, cfg, slots=N, max_context=W,
                            cache="paged", block_size=bs,
                            blocks=4 * (W // bs) + 1)
        eng.sample_futures(toks, ages, n=N, max_new=max_new)   # warm jits
        eng.allocator.peak_used = 0
        t0 = time.perf_counter()
        kids = eng.sample_futures(toks, ages, n=N, max_new=max_new)
        dt = time.perf_counter() - t0
        ev = sum(len(k.out_tokens) for k in kids)
        return ev, dt, eng.allocator.peak_used * block_bytes(eng), eng

    def run_naive():
        # same pool geometry, no sharing: N independent identical requests
        eng = BatchedEngine(params, cfg, slots=N, max_context=W,
                            cache="paged", block_size=bs,
                            blocks=N * (W // bs) + 1)
        def submit_all():
            rs = [Request(tokens=toks.copy(), ages=ages.copy(),
                          max_new=max_new) for _ in range(N)]
            for r in rs:
                eng.submit(r)
            return rs
        submit_all(); eng.run()                                # warm jits
        eng.allocator.peak_used = 0
        t0 = time.perf_counter()
        rs = submit_all()
        eng.run()
        dt = time.perf_counter() - t0
        ev = sum(len(r.out_tokens) for r in rs)
        return ev, dt, eng.allocator.peak_used * block_bytes(eng), eng

    ev_n, dt_n, bytes_n, eng_n = run_naive()
    ev_s, dt_s, bytes_s, eng_s = run_shared()
    # one request's resident blocks (plus its growth block when the prompt
    # lands exactly on a block boundary)
    single = -(-S // bs) + (1 if S % bs == 0 else 0)
    single_bytes = single * block_bytes(eng_s)
    st = eng_s.pool_stats()
    _row("futures_naive", dt_n * 1e6 / max(ev_n, 1),
         f"{ev_n / dt_n:.1f} events/s, resident_kv={bytes_n} "
         f"({bytes_n / single_bytes:.1f}x one request) N={N} S={S}")
    _row("futures_shared", dt_s * 1e6 / max(ev_s, 1),
         f"{ev_s / dt_s:.1f} events/s, resident_kv={bytes_s} "
         f"({bytes_s / single_bytes:.1f}x one request) "
         f"shared_peak={st['shared_blocks_peak']} cow={st['cow_copies']} "
         f"forks={st['forks']}")
    _row("futures_sharing_gain", 0.0,
         f"{(ev_s / dt_s) / max(ev_n / dt_n, 1e-9):.2f}x events/s and "
         f"{bytes_n / max(bytes_s, 1):.1f}x less resident KV, "
         f"fork-shared vs naive-N-requests")
    assert eng_s.allocator.used == 0 and eng_n.allocator.used == 0, \
        "futures benchmark leaked blocks"
    assert bytes_s < 2 * single_bytes, \
        (f"shared futures resident KV {bytes_s} not < 2x a single "
         f"request's {single_bytes}")


def bench_http():
    """End-to-end wire-protocol serving: N concurrent RemoteBackend clients
    against the threaded HTTP front-end over a background-ticking engine —
    requests/s plus p50/p95 request latency, the numbers that sit alongside
    the in-process `serve`/`sdk` rows to show what the network hop and
    admission queueing cost."""
    import threading

    from repro.api import Client
    from repro.api.client import EngineBackend
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serve.server import InferenceServer

    cfg = get_config("delphi-2m", reduced=True).replace(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    backend = EngineBackend.create(params, cfg, slots=8, max_context=128)
    server = InferenceServer(backend, port=0).start()
    try:
        n_clients, per_client, max_new = 4, 6, 12
        toks = list(range(3, 9))
        ages = np.linspace(0, 30, 6).tolist()
        # warm: compiles (tick + the batch-bucketed prefill shapes a
        # concurrent burst admits under) land outside the clock
        from repro.api import GenerateRequest
        warm = Client.connect(server.address)
        warm.generate(tokens=toks, ages=ages, max_new=max_new)
        for nb in (2, n_clients):       # power-of-two admission batch buckets
            warm.generate_batch([GenerateRequest(tokens=toks, ages=ages,
                                                 max_new=2)
                                 for _ in range(nb)])

        lat: list = []
        failures: list = []
        lock = threading.Lock()

        def worker(i):
            try:
                client = Client.connect(server.address)
                for j in range(per_client):
                    t0 = time.perf_counter()
                    out = client.generate(tokens=toks, ages=ages,
                                          max_new=max_new)
                    dt = time.perf_counter() - t0
                    with lock:
                        lat.append((dt, len(out.tokens)))
            except Exception as e:          # noqa: BLE001 — surface after join
                with lock:
                    failures.append(e)

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(n_clients)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
    finally:
        server.stop()
    if failures:
        raise RuntimeError(
            f"http benchmark: {len(failures)} worker(s) failed "
            f"({len(lat)} requests completed): {failures[0]}")

    n = len(lat)
    times = np.asarray([d for d, _ in lat])
    ev = sum(k for _, k in lat)
    p50, p95 = np.percentile(times, 50), np.percentile(times, 95)
    _row("http_generate_p50", p50 * 1e6,
         f"{n / wall:.1f} req/s, {ev / wall:.1f} events/s "
         f"({n_clients} concurrent clients)")
    _row("http_generate_p95", p95 * 1e6,
         f"{n} requests end-to-end over HTTP (engine async admission)")


def bench_http_keepalive():
    """HTTP/1.1 keep-alive vs socket-per-call: the same sequential risk()
    round-trips through one persistent RemoteBackend connection and through
    a fresh TCP connection each call — the wire-overhead delta the
    keep-alive rework buys (model work is identical, so the gap is pure
    connection setup)."""
    from repro.api import RemoteBackend
    from repro.api.client import EngineBackend
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serve.server import InferenceServer

    cfg = get_config("delphi-2m", reduced=True).replace(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    backend = EngineBackend.create(params, cfg, slots=2, max_context=64)
    server = InferenceServer(backend, port=0).start()
    try:
        n_calls = 40
        toks = list(range(3, 9))
        ages = np.linspace(0, 30, 6).tolist()

        def measure(keep_alive):
            rb = RemoteBackend(server.address, keep_alive=keep_alive)
            rb.risk(toks, ages, top=4)          # warm the logits jit
            t0 = time.perf_counter()
            for _ in range(n_calls):
                rb.risk(toks, ages, top=4)
            dt = time.perf_counter() - t0
            opened = rb.connections_opened
            rb.close()
            return dt, opened

        dt_ka, conns_ka = measure(True)
        dt_na, conns_na = measure(False)
    finally:
        server.stop()
    _row("http_keepalive_req", dt_ka * 1e6 / n_calls,
         f"{n_calls / dt_ka:.1f} req/s over {conns_ka} connection(s)")
    _row("http_per_call_conn_req", dt_na * 1e6 / n_calls,
         f"{n_calls / dt_na:.1f} req/s over {conns_na} connections")
    _row("http_keepalive_speedup", 0.0,
         f"{(n_calls / dt_ka) / max(n_calls / dt_na, 1e-9):.2f}x req/s "
         f"keep-alive vs socket-per-call")


def bench_router():
    """Horizontal serving: mixed generate/futures load through the
    prefix-affinity router at 1/2/4 in-process engine replicas, equal
    per-replica settings — req/s and p50/p95 end-to-end latency next to the
    single-server `http` row.  The 2-replica row must clear 1.5x the
    1-replica req/s: with small per-replica admission width the single
    replica is queue-bound, and a second replica doubles the slot budget
    while ticks stay overhead-dominated for the reduced model (jitted
    compute also releases the GIL, so replicas overlap on multicore)."""
    import threading

    from repro.api import Client, FuturesRequest
    from repro.api.client import EngineBackend
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serve.router import ReplicaSupervisor, RouterServer

    cfg = get_config("delphi-2m", reduced=True).replace(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    n_clients, per_client, max_new = 8, 3, 12

    def make_backend(i):
        # params shared across replicas: N replicas cost N KV pools, and
        # the module-level jit cache means replica 2..n compile nothing
        return EngineBackend.create(params, cfg, slots=2, max_context=64,
                                    cache="paged", prefix_cache=True,
                                    seed=i + 1)

    def measure(n_replicas):
        sup = ReplicaSupervisor.in_process(make_backend, n_replicas,
                                           probe_interval=0.5)
        router = RouterServer(sup, port=0).start()
        try:
            warm = Client.connect(router.address)     # compiles off-clock
            warm.generate(tokens=[3, 4, 5], ages=[0., 1., 2.],
                          max_new=max_new)
            warm.backend.sample_futures(FuturesRequest(
                tokens=[3, 4, 5], ages=[0., 1., 2.], n_futures=2,
                max_new=6))
            lat: list = []
            failures: list = []
            lock = threading.Lock()

            def worker(i):
                try:
                    client = Client.connect(router.address)
                    # per-worker histories: load spreads by free blocks,
                    # repeats within a worker ride prefix affinity
                    toks = [3 + i] * 20     # >= one full 16-token block:
                    ages = [float(j)        # repeats ride prefix affinity
                            for j in range(20)]
                    for j in range(per_client):
                        t0 = time.perf_counter()
                        if j % 3 == 2:      # mixed load: 1/3 futures
                            client.backend.sample_futures(FuturesRequest(
                                tokens=toks, ages=ages, n_futures=2,
                                max_new=6))
                        else:
                            client.generate(tokens=toks, ages=ages,
                                            max_new=max_new)
                        with lock:
                            lat.append(time.perf_counter() - t0)
                except Exception as e:      # noqa: BLE001 — after join
                    with lock:
                        failures.append(e)

            threads = [threading.Thread(target=worker, args=(i,))
                       for i in range(n_clients)]
            t0 = time.perf_counter()
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            wall = time.perf_counter() - t0
            sched = router.scheduler.stats()
        finally:
            router.stop()
        if failures:
            raise RuntimeError(
                f"router benchmark ({n_replicas} replicas): "
                f"{len(failures)} worker(s) failed: {failures[0]}")
        times = np.asarray(lat)
        return (len(lat) / wall, np.percentile(times, 50),
                np.percentile(times, 95), sched["affinity_rate"])

    rps = {}
    for n in (1, 2, 4):
        req_s, p50, p95, aff = measure(n)
        rps[n] = req_s
        _row(f"router_{n}x_p50", p50 * 1e6,
             f"{req_s:.1f} req/s, p95 {p95 * 1e3:.0f} ms "
             f"({n} replica(s) x 2 slots, {n_clients} clients, "
             f"affinity {aff:.2f})")
    speedup = rps[2] / max(rps[1], 1e-9)
    _row("router_2x_speedup", 0.0,
         f"{speedup:.2f}x req/s 2 replicas vs 1 (equal per-replica "
         f"settings)")
    assert speedup >= 1.5, \
        f"2-replica router speedup {speedup:.2f}x < 1.5x over 1 replica"


def bench_calibration():
    """Delphi-style evaluation: generated cohort vs held-out cohort stats."""
    from repro.configs import get_config
    from repro.core import calibration_report, init_delphi
    from repro.data import (SimulatorConfig, batches, generate_dataset,
                            pack_trajectories)
    from repro.train import OptimizerConfig, init_opt_state, make_train_step

    cfg = get_config("delphi-2m").replace(dtype="float32", max_seq_len=96)
    params = init_delphi(cfg, jax.random.PRNGKey(0))
    train, val = generate_dataset(SimulatorConfig(n_train=256, n_val=64))
    it = batches(pack_trajectories(train, 96), 32, seed=0)
    step = jax.jit(make_train_step(
        cfg, OptimizerConfig(lr=1e-3, total_steps=50), "delphi"))
    opt = init_opt_state(params)
    for _ in range(50):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt, _ = step(params, opt, b)
    t0 = time.perf_counter()
    rep = calibration_report(params, cfg, val, n_batches=1)
    us = (time.perf_counter() - t0) * 1e6
    _row("calibration_chapter_l1", us,
         f"L1={rep['chapter_l1']:.3f} data_rate={rep['data']['events_per_year']:.2f}/y "
         f"model_rate={rep['model']['events_per_year']:.2f}/y (50-step model)")


def bench_roofline():
    from repro.launch.roofline import analyse, load_records
    for dirpath in ("experiments/dryrun", "experiments/dryrun_multipod"):
        if not os.path.isdir(dirpath):
            continue
        for rec in load_records(dirpath):
            a = analyse(rec)
            dom_s = a[f"{a['dominant']}_s"]
            _row(f"roofline_{a['arch']}_{a['shape']}_{a['mesh']}",
                 dom_s * 1e6,
                 f"dominant={a['dominant']} useful={a['useful_ratio']:.3f}"
                 if a["useful_ratio"] else f"dominant={a['dominant']}")


def _bench_cohort_record(mode: str, config: dict, metrics: dict) -> None:
    """Append one machine-readable record to BENCH_cohort.json (JSON
    lines, schema 1 — same append-only discipline as BENCH_serve.json)."""
    import json
    path = os.path.join(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), "BENCH_cohort.json")
    rec = {"schema": 1, "bench": "cohort", "mode": mode,
           "git_rev": _git_rev(), "timestamp": round(time.time(), 1),
           "config": config, "metrics": metrics}
    with open(path, "a") as f:
        f.write(json.dumps(rec) + "\n")


def bench_cohort():
    """Cohort-scale scenario analysis: a 1000-patient x 4-futures sweep
    through the paged + prefix-cached engine via the ``ScenarioEngine``
    scheduler, verified **bit-identical** to the straight-line per-patient
    foreground oracle (which doubles as the naive no-scheduler baseline
    timing).  Then the counterfactual workload: K edited arms re-forked
    off one long history's cached prefix vs the same arms with the prefix
    cache off (every arm re-prefills) — the amortization factor the
    counterfactual API exists for.  Rows append to BENCH_cohort.json."""
    from repro.api.client import EngineBackend
    from repro.cohort import (CounterfactualEdit, ScenarioEngine,
                              apply_edit, assert_sweep_parity)
    from repro.configs import get_config
    from repro.data.synthetic import SimulatorConfig, patient
    from repro.models import init_params

    cfg = get_config("delphi-2m", reduced=True).replace(
        dtype="float32", vocab_size=1289, max_age=1e9)
    params = init_params(cfg, jax.random.PRNGKey(0))
    n_patients, n_fut, max_new, S = 1000, 4, 8, 8
    W, bs, slots = 64, 8, 8
    sim = SimulatorConfig(seed=7)
    pats, i = [], 0
    while len(pats) < n_patients:       # O(1) access: no split materialized
        tok, age = patient(i, sim)
        i += 1
        if len(tok) > S:                # uniform prompt shape: one prefill
            pats.append((tok[:S], age[:S]))     # bucket, one oracle shape

    def make_backend():
        return EngineBackend.create(params, cfg, slots=slots,
                                    max_context=W, cache="paged",
                                    block_size=bs, blocks=256,
                                    prefix_cache=True)

    se = ScenarioEngine(make_backend(), max_in_flight=4, seed=13)
    se.sweep(pats[:2], n_futures=n_fut, max_new=max_new)   # warm the jits
    se = ScenarioEngine(make_backend(), max_in_flight=4, seed=13)
    res = se.sweep(pats, n_futures=n_fut, max_new=max_new, horizon=10.0)
    assert res.n_failed == 0, f"{res.n_failed} patients failed"

    t0 = time.perf_counter()
    stats = assert_sweep_parity(res, params, cfg, pats, seed=13,
                                n_futures=n_fut, max_new=max_new,
                                horizon=10.0, slots=slots, max_context=W)
    dt_oracle = time.perf_counter() - t0
    assert stats["patients_checked"] == n_patients
    naive_ps = n_patients / dt_oracle   # straight-line foreground baseline
    _row("cohort_sweep", res.wall_s * 1e6 / n_patients,
         f"{res.patients_per_s:.1f} patients/s ({res.events_per_s:.1f} "
         f"events/s, prefix hit rate {res.prefix_hit_rate:.2f}), "
         f"{stats['events_checked']} events bit-identical to oracle at "
         f"{naive_ps:.1f} patients/s foreground")

    # shared-vs-naive resident KV for one patient's N futures (the same
    # invariant bench_futures pins down, here at cohort geometry)
    from repro.serve import BatchedEngine, Request
    S_kv = 9 * bs + 1
    toks = (np.arange(3, 3 + S_kv) % 1200).astype(np.int32)
    ages = np.linspace(0.0, 60.0, S_kv).astype(np.float32)
    Wkv = 128

    def block_bytes(eng):
        pc = eng.cache["self"]
        per = (pc.k.size + pc.v.size) // pc.k.shape[1]
        return per * pc.k.dtype.itemsize

    eng = BatchedEngine(params, cfg, slots=n_fut, max_context=Wkv,
                        cache="paged", block_size=bs, blocks=128)
    eng.sample_futures(toks, ages, n=n_fut, max_new=max_new)
    eng.allocator.peak_used = 0
    eng.sample_futures(toks, ages, n=n_fut, max_new=max_new)
    bytes_shared = eng.allocator.peak_used * block_bytes(eng)
    eng2 = BatchedEngine(params, cfg, slots=n_fut, max_context=Wkv,
                         cache="paged", block_size=bs, blocks=128)
    for _ in range(2):                  # second pass is the measured one
        eng2.allocator.peak_used = 0
        for _ in range(n_fut):
            eng2.submit(Request(tokens=toks.copy(), ages=ages.copy(),
                                max_new=max_new))
        eng2.run()
    bytes_naive = eng2.allocator.peak_used * block_bytes(eng2)
    kv_ratio = bytes_naive / max(bytes_shared, 1)

    # counterfactual amortization: K edited arms off one long history.
    # Shared = the counterfactual API (forked futures + prefix-cache
    # re-fork of the baseline's blocks).  Naive = what a user without the
    # API pays: every arm's N futures as N independent requests, each
    # re-prefilling the full history and holding its own KV.
    S_cf = 120
    cf_fut, cf_new = 8, 4
    rng = np.random.default_rng(5)
    ctoks = np.concatenate([[3], rng.choice(
        np.arange(13, 1289), S_cf - 1, replace=False)]).astype(np.int32)
    cages = np.concatenate([[0.0], np.sort(
        rng.uniform(1.0, 60.0, S_cf - 1))]).astype(np.float32)
    edits = [CounterfactualEdit("substitute", int(ctoks[-1 - k]),
                                new_code=int(1288 - k)) for k in range(6)]
    arms = [(ctoks, cages)]
    for e in edits:
        t2, a2, _ = apply_edit(ctoks, cages, e)
        arms.append((t2, a2))

    def run_cf_shared():
        be = EngineBackend.create(params, cfg, slots=cf_fut,
                                  max_context=256, cache="paged",
                                  block_size=bs, blocks=512,
                                  prefix_cache=True)
        eng_cf = ScenarioEngine(be, seed=4)
        eng_cf.counterfactual(ctoks, cages, edits[:1], n_futures=cf_fut,
                              max_new=cf_new)            # warm the jits
        be.engine.drop_prefix_cache()
        t0 = time.perf_counter()
        reps = eng_cf.counterfactual(ctoks, cages, edits,
                                     n_futures=cf_fut, max_new=cf_new)
        dt = time.perf_counter() - t0
        ev = sum(len(t.tokens) for t in reps[0].baseline.trajectories)
        ev += sum(len(t.tokens) for r in reps
                  for t in r.edited.trajectories)
        return ev / dt, reps

    def run_cf_naive():
        eng_cf = BatchedEngine(params, cfg, slots=cf_fut, max_context=256,
                               cache="paged", block_size=bs, blocks=512)

        def drive():
            ev = 0
            for at, aa in arms:
                rs = [Request(tokens=np.asarray(at).copy(),
                              ages=np.asarray(aa).copy(), max_new=cf_new)
                      for _ in range(cf_fut)]
                for r in rs:
                    eng_cf.submit(r)
                eng_cf.run()
                ev += sum(len(r.out_tokens) for r in rs)
            return ev
        drive()                                          # warm the jits
        t0 = time.perf_counter()
        ev = drive()
        return ev / (time.perf_counter() - t0)

    eps_shared, reps = run_cf_shared()
    eps_naive = run_cf_naive()
    amort = eps_shared / max(eps_naive, 1e-9)
    assert all(r.shared_prefix_len >= S_cf - 7 for r in reps)
    assert amort >= 2.0, \
        f"counterfactual amortization {amort:.2f}x < 2x over naive"
    _row("cohort_counterfactual", 0.0,
         f"{amort:.2f}x events/s re-forking {len(edits)} arms off the "
         f"cached prefix vs unshared per-future requests "
         f"({eps_shared:.1f} vs {eps_naive:.1f} events/s, S={S_cf}, "
         f"N={cf_fut})")
    _row("cohort_resident_kv", 0.0,
         f"{kv_ratio:.1f}x less resident KV, fork-shared futures vs "
         f"naive N requests (N={n_fut}, S={S_kv})")
    _bench_cohort_record(
        "sweep",
        {"n_patients": n_patients, "n_futures": n_fut, "max_new": max_new,
         "prompt_events": S, "slots": slots, "max_context": W,
         "block_size": bs, "blocks": 256, "max_in_flight": 4,
         "vocab_size": cfg.vocab_size},
        {"patients_per_s": round(res.patients_per_s, 2),
         "events_per_s": round(res.events_per_s, 2),
         "prefix_hit_rate": round(res.prefix_hit_rate, 4),
         "events_total": res.events_total,
         "oracle_patients_per_s": round(naive_ps, 2),
         "oracle_events_checked": stats["events_checked"],
         "resident_kv_shared_bytes": int(bytes_shared),
         "resident_kv_naive_bytes": int(bytes_naive),
         "resident_kv_ratio": round(kv_ratio, 2),
         "counterfactual_amortization": round(amort, 2),
         "counterfactual_events_per_s": round(eps_shared, 2),
         "counterfactual_naive_events_per_s": round(eps_naive, 2)})


BENCHES = {
    "portability": bench_runtime_portability,
    "trajectory": bench_trajectory_generation,
    "sdk": bench_sdk,
    "tte": bench_tte_kernel,
    "train": bench_train_step,
    "serve": bench_serving_engine,
    "futures": bench_futures,
    "http": bench_http,
    "http_keepalive": bench_http_keepalive,
    "router": bench_router,
    "calibration": bench_calibration,
    "cohort": bench_cohort,
    "roofline": bench_roofline,
}


def main() -> None:
    which = sys.argv[1:] or list(BENCHES)
    print("name,us_per_call,derived")
    for name in which:
        BENCHES[name]()


if __name__ == "__main__":
    main()
