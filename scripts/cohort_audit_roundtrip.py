"""CI guard: cohort sweep bit-parity + privacy audit over the HTTP wire.

Two acceptance checks of the cohort/privacy subsystem, end to end:

1. **Cohort parity** — a concurrent ``ScenarioEngine`` sweep through the
   paged + prefix-cached batching engine is *bit-identical*, patient for
   patient and event for event, to the straight-line per-patient
   foreground oracle (``monte_carlo_risk`` over
   ``ring_reference_futures``) under the same injected uniforms; a
   paired counterfactual re-forks from the shared history prefix and
   actually hits the engine's prefix index.

2. **Privacy audit round-trip** — train a tiny Delphi with member
   canaries planted (``inject_canaries``), serve it over HTTP, and run
   the ``repro-audit`` CLI against the URL: the report must come back
   machine-readable with a sane membership-inference AUC + CI and
   extraction rates.  This is the paper's privacy axis made measurable
   in CI: the exact pipeline a deployment would run against its own
   serving endpoint.

Run:  PYTHONPATH=src python scripts/cohort_audit_roundtrip.py
"""
import argparse
import json
import sys
import tempfile

import jax
import numpy as np

from repro.api.client import EngineBackend, LocalBackend
from repro.cohort import (CounterfactualEdit, ScenarioEngine,
                          assert_sweep_parity)
from repro.configs import get_config
from repro.core import init_delphi
from repro.data import (SimulatorConfig, batches, generate_dataset,
                        pack_trajectories)
from repro.privacy import inject_canaries, make_canaries
from repro.privacy.audit import main as audit_main
from repro.serve.server import InferenceServer
from repro.train import OptimizerConfig, train_loop

W, BS, K = 64, 16, 4      # the paged-parity engine geometry


def check_cohort_parity() -> None:
    cfg = get_config("delphi-2m", reduced=True).replace(
        dtype="float32", vocab_size=96, max_seq_len=48, max_age=1e9)
    params = init_delphi(cfg, jax.random.PRNGKey(7))
    pats = []
    for i in range(6):
        rng = np.random.default_rng(500 + i)
        S = 6
        toks = np.concatenate([[3], rng.integers(13, 90, S - 1)])
        ages = np.concatenate([[0.0], np.sort(rng.uniform(1.0, 40.0,
                                                          S - 1))])
        pats.append((toks.astype(np.int32), ages.astype(np.float32)))

    be = EngineBackend.create(params, cfg, slots=K, max_context=W,
                              cache="paged", block_size=BS, blocks=64,
                              prefix_cache=True)
    se = ScenarioEngine(be, max_in_flight=3, seed=21)
    res = se.sweep(pats, n_futures=3, max_new=8, horizon=20.0)
    assert res.n_failed == 0, f"sweep failures: {res.n_failed}"
    stats = assert_sweep_parity(res, params, cfg, pats, seed=21,
                                n_futures=3, max_new=8, horizon=20.0,
                                slots=K, max_context=W)
    print(f"[1/2] cohort parity: {stats['patients_checked']} patients, "
          f"{stats['events_checked']} events bit-identical to the "
          f"foreground oracle (prefix hit rate "
          f"{res.prefix_hit_rate:.2f})")

    # counterfactual arms must re-fork from the baseline's cached prefix
    rng = np.random.default_rng(999)
    S = 20
    toks = np.concatenate([[3], rng.choice(np.arange(13, 90), S - 1,
                                           replace=False)]).astype(np.int32)
    ages = np.concatenate([[0.0], np.sort(
        rng.uniform(1.0, 40.0, S - 1))]).astype(np.float32)
    be2 = EngineBackend.create(params, cfg, slots=K, max_context=W,
                               cache="paged", block_size=4, blocks=128,
                               prefix_cache=True)
    se2 = ScenarioEngine(be2, seed=3)
    edits = [CounterfactualEdit("remove", int(toks[-1])),
             CounterfactualEdit("insert", 44, age=float(ages[-2]))]
    reps = se2.counterfactual(toks, ages, edits, n_futures=3, max_new=6,
                              horizon=30.0)
    pc = be2.engine.pool_stats()["prefix_cache"]
    hits = pc["hits"] + pc["partial_hits"]
    assert hits >= len(edits), \
        f"counterfactual arms missed the prefix cache ({pc})"
    assert all(r.shared_prefix_len >= S - 2 for r in reps)
    print(f"      counterfactual: {len(reps)} paired arms, shared prefix "
          f">= {S - 2}/{S}, prefix-cache hits {hits}")


def check_privacy_audit(steps: int) -> None:
    cfg = get_config("delphi-2m", reduced=True).replace(
        dtype="float32", vocab_size=1289)
    params = init_delphi(cfg, jax.random.PRNGKey(2))
    sim = SimulatorConfig(n_train=96, n_val=4, seed=0)
    train, _ = generate_dataset(sim)
    canaries = make_canaries(4, sim, seed=0, secret_len=3, prefix_events=6)
    train = inject_canaries(train, canaries, repeats=8)
    ti = batches(pack_trajectories(train, 32), 16, seed=0)
    params, _ = train_loop(params, cfg,
                           OptimizerConfig(lr=6e-4, total_steps=steps),
                           ti, objective="delphi", steps=steps,
                           log_every=max(steps // 2, 1))

    server = InferenceServer(LocalBackend(params, cfg, seq_len=16),
                             port=0).start()
    try:
        with tempfile.NamedTemporaryFile(suffix=".json", mode="r") as f:
            rc = audit_main(["--url", server.address,
                             "--canaries", "4", "--secret-len", "3",
                             "--prefix-events", "6", "--sim-seed", "0",
                             "--seed", "0", "--n-futures", "2",
                             "--max-new", "4", "--n-boot", "50",
                             "--out", f.name])
            assert rc == 0
            report = json.load(open(f.name))
    finally:
        server.stop()

    assert report["backend"] == "remote"
    assert report["n_members"] == 2 and report["n_nonmembers"] == 2
    assert 0.0 <= report["mi_auc"] <= 1.0
    lo, hi = report["mi_auc_ci"]
    assert 0.0 <= lo <= hi <= 1.0
    for k in ("member_extraction_rate", "nonmember_extraction_rate"):
        assert 0.0 <= report[k] <= 1.0
    assert len(report["member_scores"]) == 2
    assert all(s <= 0.0 for s in report["member_scores"])
    print(f"[2/2] privacy audit over the wire: MI AUC "
          f"{report['mi_auc']:.2f} [{lo:.2f}, {hi:.2f}], extraction gap "
          f"{report['extraction_gap']:+.2f} "
          f"(trained {steps} steps with planted canaries)")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=80,
                    help="training steps for the audited model")
    args = ap.parse_args()
    check_cohort_parity()
    check_privacy_audit(args.steps)
    print("cohort_audit_roundtrip: all checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
