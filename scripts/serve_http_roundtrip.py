"""CI guard: boot the HTTP front-end and round-trip the wire protocol.

The cross-process counterpart of ``scripts/artifact_roundtrip.py``: export a
tiny delphi-2m artifact, serve it through ``repro.serve.server`` on an
ephemeral port, and drive generate / stream / risk through
``Client(RemoteBackend(url))``, asserting

* trajectories over the wire are **bit-identical** to ``LocalBackend`` under
  injected uniforms (tokens exact; uniforms cross as base64 raw bytes),
* SSE streaming yields exactly the same events as non-streaming generate,
* every ``_validate`` failure surfaces as a structured JSON error with its
  stable code over HTTP.

Run:  PYTHONPATH=src python scripts/serve_http_roundtrip.py
"""
import json
import sys
import tempfile
import urllib.error
import urllib.request

import jax
import numpy as np

from repro.api import ApiError, Client, GenerateRequest
from repro.configs import get_config
from repro.core import init_delphi
from repro.sdk import export_model
from repro.serve.server import InferenceServer


def _post_raw(url, path, payload):
    req = urllib.request.Request(
        url + path, data=json.dumps(payload).encode(),
        headers={"Content-Type": "application/json"}, method="POST")
    try:
        with urllib.request.urlopen(req, timeout=60) as resp:
            return resp.status, json.loads(resp.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def main() -> int:
    # same known-stable constants as the test_api parity fixture
    cfg = get_config("delphi-2m", reduced=True).replace(
        dtype="float32", vocab_size=96, max_seq_len=48, max_age=1e9)
    params = init_delphi(cfg, jax.random.PRNGKey(7))
    d = tempfile.mkdtemp(prefix="ci_http_artifact_")
    export_model(params, cfg, d)

    toks, ages = [3, 10, 20], [0.0, 15.0, 28.0]
    max_new = 6
    u = np.random.default_rng(42).uniform(
        size=(max_new, cfg.vocab_size)).astype(np.float32)

    local = Client.from_params(params, cfg)
    ref = local.generate(tokens=toks, ages=ages, max_new=max_new, uniforms=u)
    assert len(ref.tokens) > 0

    server = InferenceServer(Client.from_artifact(d).backend, port=0).start()
    try:
        remote = Client.connect(server.address)

        # 1) bit-identical generation across the wire
        res = remote.generate(tokens=toks, ages=ages, max_new=max_new,
                              uniforms=u)
        assert res.tokens == ref.tokens, \
            f"remote tokens {res.tokens} != local {ref.tokens}"
        assert res.backend == "remote[artifact]"

        # 2) SSE stream == generate, event for event
        evs = list(remote.stream(tokens=toks, ages=ages, max_new=max_new,
                                 uniforms=u))
        assert [e.token for e in evs] == res.tokens, \
            f"SSE {[e.token for e in evs]} != generate {res.tokens}"
        assert [e.index for e in evs] == list(range(len(res.tokens)))

        # 3) risk over the wire matches the local closed form
        rl = local.risk(toks, ages, horizon=5.0, top=8)
        rr = remote.risk(toks, ages, horizon=5.0, top=8)
        assert [i.token for i in rr.items] == [i.token for i in rl.items]
        np.testing.assert_allclose([i.risk for i in rr.items],
                                   [i.risk for i in rl.items], rtol=1e-5)

        # 4) every validation failure -> stable JSON error code over HTTP
        cases = [
            ({"tokens": [], "ages": []}, 400, "empty_trajectory"),
            ({"tokens": list(range(100)), "ages": [0.0] * 100}, 400,
             "too_long"),
            ({"tokens": toks}, 400, "ages_required"),
            ({"tokens": toks, "ages": [0.0]}, 400, "ages_length_mismatch"),
            ({"protocol_version": "999", "tokens": toks, "ages": ages}, 409,
             "protocol_version_mismatch"),
        ]
        for payload, want_status, want_code in cases:
            status, body = _post_raw(server.address, "/v1/generate", payload)
            assert (status, body["error"]["code"]) == \
                (want_status, want_code), (payload, status, body)
            try:
                remote.generate(GenerateRequest.from_json(dict(payload)))
                raise AssertionError(f"no error for {payload}")
            except ApiError as e:
                assert e.code == want_code, (payload, e.code)

        print(f"OK http round-trip: {len(res.tokens)} events bit-identical "
              f"local vs RemoteBackend (generate + SSE), risk parity, "
              f"{len(cases)} error codes mapped")
    finally:
        server.stop()
    return 0


if __name__ == "__main__":
    sys.exit(main())
