"""Smoke-run every example headlessly with fast arguments.

Each example is a documented entry point; this script is the guard that
keeps them all runnable (imports, CLI flags, end-to-end wiring) without
paying their demo-scale training budgets.  Every example runs in its own
interpreter via subprocess — import-order isolation, and exactly how a
user invokes them.

Run:  PYTHONPATH=src python scripts/examples_smoke.py [--only quickstart]
"""
import argparse
import os
import subprocess
import sys
import time

#: example file -> fast-args override (keys mirror examples/*.py)
EXAMPLES = {
    "quickstart.py": ["--steps", "2", "--patients", "64"],
    "ablation_dual_loss.py": ["--steps", "2", "--patients", "64"],
    "serve_batched.py": ["--requests", "4", "--slots", "4",
                         "--steps", "2", "--max-new", "6"],
    "export_and_serve.py": [],
    "federated_finetune.py": ["--clients", "2", "--pretrain-steps", "2",
                              "--rounds", "1"],
    "arch_zoo.py": ["--arch", "delphi-2m"],
    "serve_http.py": ["--port", "0", "--slots", "4"],
    "cohort_sweep.py": ["--patients", "4", "--futures", "2",
                        "--max-new", "6", "--steps", "2"],
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", help="run a single example (stem or file)")
    ap.add_argument("--timeout", type=float, default=600.0)
    args = ap.parse_args()

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src")] +
        ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))

    todo = {k: v for k, v in EXAMPLES.items()
            if not args.only or args.only in (k, k[:-3])}
    if not todo:
        print(f"examples_smoke: no example matches --only {args.only!r}",
              file=sys.stderr)
        return 2

    missing = [k for k in todo
               if not os.path.exists(os.path.join(root, "examples", k))]
    if missing:
        print(f"examples_smoke: missing examples: {missing}",
              file=sys.stderr)
        return 2

    failures = []
    for name, extra in todo.items():
        t0 = time.time()
        proc = subprocess.run(
            [sys.executable, os.path.join(root, "examples", name)] + extra,
            env=env, cwd=root, capture_output=True, text=True,
            timeout=args.timeout)
        dt = time.time() - t0
        status = "ok" if proc.returncode == 0 else f"FAIL({proc.returncode})"
        print(f"  {name:24s} {status:8s} {dt:5.1f}s")
        if proc.returncode != 0:
            failures.append(name)
            sys.stderr.write(proc.stdout[-2000:] + proc.stderr[-2000:])
    if failures:
        print(f"examples_smoke: {len(failures)} failed: {failures}",
              file=sys.stderr)
        return 1
    print(f"examples_smoke: all {len(todo)} examples ran clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
