"""CI guard: 2-replica router bit-parity vs a direct engine + failover storm.

The wire-level acceptance check of the multi-replica serving tier
(``repro.serve.router``): boot TWO in-process engine replicas behind a
``RouterServer`` and one direct single-engine ``InferenceServer`` over the
same parameters, and assert

* generate / SSE stream / futures through the router are **bit-identical**
  to the direct server under injected uniforms (the router adds a network
  hop and a scheduling decision — never a numeric one),
* repeated shared-history prompts are affinity-routed (scheduler counters),
* a failover storm — kill replica 0 mid-traffic — loses no fresh request
  (each retries onto the survivor), surfaces the structured
  ``replica_unavailable`` on the pinned stream, and leaves the survivor's
  pool leak-free; with BOTH replicas dead the router answers 503
  ``replica_unavailable``.

Run:  PYTHONPATH=src python scripts/router_roundtrip.py
"""
import json
import sys
import urllib.error
import urllib.request

import jax
import numpy as np

from repro.api import Client, GenerateRequest, ReplicaUnavailableError
from repro.api.client import EngineBackend
from repro.configs import get_config
from repro.core import init_delphi
from repro.serve.router import ReplicaSupervisor, RouterServer
from repro.serve.server import InferenceServer


def _post_raw(url, path, payload):
    req = urllib.request.Request(
        url + path, data=json.dumps(payload).encode(),
        headers={"Content-Type": "application/json"}, method="POST")
    try:
        with urllib.request.urlopen(req, timeout=60) as resp:
            return resp.status, json.loads(resp.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def main() -> int:
    # same known-stable constants as the test_api parity fixture
    cfg = get_config("delphi-2m", reduced=True).replace(
        dtype="float32", vocab_size=96, max_seq_len=48, max_age=1e9)
    params = init_delphi(cfg, jax.random.PRNGKey(7))

    toks, ages = [3, 10, 20], [0.0, 15.0, 28.0]
    max_new = 6
    u = np.random.default_rng(42).uniform(
        size=(max_new, cfg.vocab_size)).astype(np.float32)
    u_long = np.random.default_rng(43).uniform(
        size=(40, cfg.vocab_size)).astype(np.float32)
    u_long[:, cfg.death_token] = 1e-12      # streams run their full max_new

    def make_backend(i):
        return EngineBackend.create(params, cfg, slots=4, max_context=64,
                                    cache="paged", prefix_cache=True)

    direct = InferenceServer(make_backend(-1), port=0).start()
    sup = ReplicaSupervisor.in_process(make_backend, 2, probe_interval=0.2)
    router = RouterServer(sup, port=0).start()
    try:
        via_router = Client.connect(router.address)
        via_direct = Client.connect(direct.address)

        # 1) bit-identical generation through the router
        res_r = via_router.generate(tokens=toks, ages=ages, max_new=max_new,
                                    uniforms=u)
        res_d = via_direct.generate(tokens=toks, ages=ages, max_new=max_new,
                                    uniforms=u)
        assert res_r.tokens == res_d.tokens, \
            f"router tokens {res_r.tokens} != direct {res_d.tokens}"
        assert res_r.ages == res_d.ages
        assert res_r.backend.startswith("remote[router[r"), res_r.backend
        assert res_r.request_id, "router must echo a routed request id"

        # 2) SSE through the router == direct SSE, frame for frame
        req = GenerateRequest(tokens=toks, ages=ages, max_new=max_new,
                              uniforms=u)
        ev_r = list(via_router.backend.stream(req))
        ev_d = list(via_direct.backend.stream(req))
        assert [(e.token, e.age) for e in ev_r] == \
               [(e.token, e.age) for e in ev_d], "SSE divergence"

        # 3) futures parity (pinned by router-assigned id, engine forks)
        from repro.api import FuturesRequest
        uf = np.stack([np.random.default_rng(100 + i).uniform(
            size=(max_new, cfg.vocab_size)).astype(np.float32)
            for i in range(3)])
        freq = FuturesRequest(tokens=toks, ages=ages, n_futures=3,
                              max_new=max_new, uniforms=uf, horizon=5.0)
        fr = via_router.backend.sample_futures(freq)
        fd = via_direct.backend.sample_futures(freq)
        assert [t.tokens for t in fr.trajectories] == \
               [t.tokens for t in fd.trajectories], "futures divergence"

        # 4) shared histories are affinity-routed
        shared_t, shared_a = [5] * 20, [float(i) for i in range(20)]
        for i in range(6):
            via_router.generate(tokens=shared_t + [10 + i],
                                ages=shared_a + [21.0],
                                max_new=2, uniforms=u[:2])
        sched = via_router.backend.healthz()["router"]["scheduler"]
        assert sched["affinity_routed"] >= 5, sched
        n_parity = len(res_r.tokens) + len(ev_r)

        # 5) failover storm: pin a stream to r0... then kill r0 mid-flight
        sit = via_router.backend.stream(GenerateRequest(
            tokens=toks, ages=ages, max_new=40, uniforms=u_long,
            request_id="storm-pinned"))
        next(sit)                           # committed: stream is pinned
        victim = router.pinned_replica("storm-pinned")
        survivor = [r.name for r in sup.replicas if r.name != victim][0]
        sup.replica(victim).kill()
        try:
            list(sit)
            raise AssertionError("pinned stream must fail on replica death")
        except ReplicaUnavailableError:
            pass                            # structured failover signal
        # ...and hammer fresh generates: every one must land on the survivor
        for i in range(8):
            out = via_router.generate(tokens=toks, ages=ages, max_new=2,
                                      uniforms=u[:2])
            assert f"router[{survivor}:" in out.backend, out.backend
        h = via_router.backend.healthz()
        assert h["ok"] and not h["router"]["replicas"][victim]["healthy"]

        # 6) survivor pool is leak-free after the storm
        eng = sup.replica(survivor).server.backend.engine
        eng.stop()
        eng.drop_prefix_cache()
        st = eng.pool_stats()
        assert st["blocks_used"] == 0 and st["shared_blocks"] == 0, st
        eng.start()

        # 7) both replicas dead -> structured 503 replica_unavailable
        sup.replica(survivor).kill()
        status, body = _post_raw(router.address, "/v1/generate",
                                 {"tokens": toks, "ages": ages,
                                  "max_new": 2, "seed": 0})
        assert status == 503, (status, body)
        assert body["error"]["code"] == "replica_unavailable", body

        print(f"OK router round-trip: {n_parity} events bit-identical "
              f"2-replica router vs direct engine (generate + SSE + "
              f"futures), affinity rate {sched['affinity_rate']:.2f}, "
              f"failover storm survived ({victim} killed mid-stream, 8/8 "
              f"retries on {survivor}, zero-leak pool, all-down -> 503 "
              f"replica_unavailable)")
    finally:
        router.stop()
        direct.stop()
    return 0


if __name__ == "__main__":
    sys.exit(main())
