"""CI guard: export a tiny spec-v2 artifact and round-trip it end to end.

Catches export/runtime drift that unit tests mock away: the *serialized*
prefill + decode graphs must load in the model-code-free runtime and drive
``repro.api.ArtifactBackend`` to the same event sequence as the legacy
full-graph client loop under injected uniforms.

Run:  PYTHONPATH=src python scripts/artifact_roundtrip.py
"""
import sys
import tempfile

import jax
import numpy as np

from repro.api import Client
from repro.configs import get_config
from repro.core import init_delphi
from repro.sdk import InferenceSession, export_model, verify_checksums


def main() -> int:
    # same constants as the tests/test_api.py parity fixture: on untrained
    # models the high-frequency age encoding amplifies fp fusion noise once
    # ages drift, so a known-stable seed keeps the 6-event horizon bit-exact
    cfg = get_config("delphi-2m", reduced=True).replace(
        dtype="float32", vocab_size=96, max_seq_len=48)
    params = init_delphi(cfg, jax.random.PRNGKey(7))
    d = tempfile.mkdtemp(prefix="ci_artifact_")
    export_model(params, cfg, d)
    verify_checksums(d, strict=True)

    toks, ages = [3, 10, 20], [0.0, 15.0, 28.0]
    max_new = 6
    u = np.random.default_rng(42).uniform(
        size=(max_new, cfg.vocab_size)).astype(np.float32)

    client = Client.from_artifact(d)
    assert client.backend.use_decode_graph, "v2 artifact must ship decode"
    res = client.generate(tokens=toks, ages=ages, max_new=max_new,
                          uniforms=u, max_age=1e9)
    legacy = InferenceSession(d).generate_trajectory(
        toks, ages, max_new=max_new, uniforms=u, max_age=1e9)
    assert res.tokens == legacy["tokens"], \
        f"decode-path tokens {res.tokens} != full-graph {legacy['tokens']}"
    assert len(res.tokens) > 0
    streamed = [e.token for e in client.stream(
        tokens=toks, ages=ages, max_new=max_new, uniforms=u, max_age=1e9)]
    assert streamed == res.tokens
    print(f"OK artifact round-trip: {len(res.tokens)} events bit-identical "
          f"across decode-graph generate/stream and the full-graph loop")
    return 0


if __name__ == "__main__":
    sys.exit(main())
