"""CI guard: paged KV-cache engine == ring engine, and no block leaks.

Two phases:

1. **Parity** — same config, same injected uniforms, same slot count: the
   paged engine's trajectories must be bit-identical to the ring engine's
   (tokens AND fp32 ages) across the generate, stream and batch paths,
   including an over-width (S > max_context) wrapped-ring prompt.  The
   paged read path reconstructs the exact dense ring view through the
   block table, so any divergence is a real bug, not fp noise.

2. **Cancel/preempt/timeout storm** — a deliberately undersized pool plus
   mid-flight cancellations and a zero-second deadline batch must leave
   the allocator with ZERO leaked blocks and every block table empty.

Run:  PYTHONPATH=src python scripts/paged_parity.py
"""
import sys
import time

import jax
import numpy as np

from repro.api import GenerateRequest, RequestCancelledError
from repro.api.client import EngineBackend
from repro.configs import get_config
from repro.core import init_delphi
from repro.serve import BatchedEngine, Request


def _uniforms(max_new, V, seed):
    return np.random.default_rng(seed).uniform(
        size=(max_new, V)).astype(np.float32)


def _reqs(cfg, n, max_new, seed0=0):
    out = []
    for s in range(n):
        S = 3 + (s % 4)
        out.append(Request(
            tokens=(np.arange(3, 3 + S, dtype=np.int32) + s) % 90,
            ages=np.linspace(0.0, 30.0, S).astype(np.float32),
            max_new=max_new,
            uniforms=_uniforms(max_new, cfg.vocab_size, seed0 + s)))
    return out


def parity(params, cfg) -> None:
    def run(kind):
        eng = BatchedEngine(params, cfg, slots=2, max_context=64,
                            cache=kind, block_size=16)
        for r in _reqs(cfg, 5, 8):
            eng.submit(r)
        done = eng.run()
        assert len(done) == 5
        return eng, [(r.out_tokens, r.out_ages) for r in done]

    _, ring = run("ring")
    eng, paged = run("paged")
    assert ring == paged, "paged generate diverged from ring"
    assert eng.allocator.used == 0

    # over-width prompt: wrapped ring pack through the block copy
    S, W = 33, 16
    for kind in ("ring", "paged"):
        e = BatchedEngine(params, cfg, slots=1, max_context=W, cache=kind,
                          block_size=8)
        e.submit(Request(tokens=(np.arange(3, 3 + S) % 90).astype(np.int32),
                         ages=np.linspace(0.0, 30.0, S).astype(np.float32),
                         max_new=4,
                         uniforms=_uniforms(4, cfg.vocab_size, 99)))
        d = e.run()[0]
        if kind == "ring":
            wrap_ref = (d.out_tokens, d.out_ages)
        else:
            assert (d.out_tokens, d.out_ages) == wrap_ref, \
                "paged over-width prompt diverged"
            assert e.allocator.used == 0

    # stream + batch through the client backend surface
    u = _uniforms(6, cfg.vocab_size, 42)
    req = GenerateRequest(tokens=[3, 10, 20], ages=[0.0, 15.0, 28.0],
                          max_new=6, uniforms=u)
    ring_b = EngineBackend.create(params, cfg, slots=2, max_context=64)
    paged_b = EngineBackend.create(params, cfg, slots=2, max_context=64,
                                   cache="paged", block_size=16)
    ev_r = [(e.token, e.age) for e in ring_b.stream(req)]
    ev_p = [(e.token, e.age) for e in paged_b.stream(req)]
    assert ev_r == ev_p, "paged stream diverged from ring"
    batch = [GenerateRequest(tokens=[3, 10, 20], ages=[0.0, 15.0, 28.0],
                             max_new=6, uniforms=u) for _ in range(3)]
    b_r = [(r.tokens, r.ages) for r in ring_b.generate_batch(batch)]
    b_p = [(r.tokens, r.ages) for r in paged_b.generate_batch(batch)]
    assert b_r == b_p, "paged batch diverged from ring"
    assert paged_b.engine.allocator.used == 0
    print(f"parity OK: generate/stream/batch bit-identical "
          f"({len(ring)} + 1 wrapped + stream + batch)")


def storm(params, cfg) -> None:
    # undersized pool: capacity 5 blocks, a full slot needs 4 -> constant
    # growth pressure and preemptions while cancels land mid-flight
    eng = BatchedEngine(params, cfg, slots=4, max_context=32, cache="paged",
                        block_size=8, blocks=6).start()
    try:
        reqs = []
        for s in range(24):
            S = 3 + (s % 5)
            r = Request(tokens=(np.arange(3, 3 + S, dtype=np.int32)) % 90,
                        ages=np.linspace(0.0, 30.0, S).astype(np.float32),
                        max_new=12, request_id=f"storm-{s}")
            reqs.append(r)
            eng.submit(r)
        time.sleep(0.3)
        cancelled = [r.request_id for i, r in enumerate(reqs) if i % 3 == 0]
        for rid in cancelled:
            eng.cancel(rid)
        deadline = time.monotonic() + 120
        while (not all(r.done for r in reqs)) and time.monotonic() < deadline:
            time.sleep(0.05)
        assert all(r.done for r in reqs), "storm requests did not drain"
    finally:
        eng.stop()
    n_cancelled = sum(isinstance(r.error, RequestCancelledError)
                      for r in reqs)
    n_ok = sum(r.error is None for r in reqs)
    assert n_ok + n_cancelled == len(reqs), \
        [type(r.error).__name__ for r in reqs if r.error is not None]
    assert eng.allocator.used == 0, \
        f"LEAK: {eng.allocator.used} blocks still allocated"
    assert (eng._table == -1).all(), "LEAK: block table still references pool"
    # timeout path also reclaims
    eng2 = BatchedEngine(params, cfg, slots=2, max_context=32, cache="paged",
                         block_size=8, request_timeout=0.0)
    for s in range(3):
        eng2.submit(Request(tokens=np.arange(3, 8, dtype=np.int32),
                            ages=np.linspace(0.0, 30.0, 5).astype(np.float32),
                            max_new=12))
    time.sleep(0.01)
    eng2.run(max_ticks=200)
    assert eng2.allocator.used == 0
    print(f"storm OK: {len(reqs)} requests ({n_cancelled} cancelled, "
          f"{eng.preemptions} preemptions, {eng.pool_stats()['blocks_peak_used']}"
          f"/{eng.allocator.capacity} peak blocks), zero leaked blocks")


def main() -> int:
    cfg = get_config("delphi-2m", reduced=True).replace(
        dtype="float32", vocab_size=96, max_seq_len=48, max_age=1e9)
    params = init_delphi(cfg, jax.random.PRNGKey(7))
    parity(params, cfg)
    storm(params, cfg)
    print("paged_parity: all checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
