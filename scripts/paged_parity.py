"""CI guard: paged KV-cache engine == ring engine, and no block leaks.

Five phases:

1. **Parity** — same config, same injected uniforms, same slot count: the
   paged engine's trajectories must be bit-identical to the ring engine's
   (tokens AND fp32 ages) across the generate, stream and batch paths,
   including an over-width (S > max_context) wrapped-ring prompt.  The
   paged read path reconstructs the exact dense ring view through the
   block table, so any divergence is a real bug, not fp noise.

2. **Cancel/preempt/timeout storm** — a deliberately undersized pool plus
   mid-flight cancellations and a zero-second deadline batch must leave
   the allocator with ZERO leaked blocks and every block table empty.

3. **Fork parity** — ``sample_futures`` (hold + fork + COW + prefix index)
   on both cache layouts must be bit-identical to the scheduler-free
   ``ring_reference_futures`` oracle under injected uniforms.

4. **Fork/cancel/timeout storm** — concurrent futures fan-outs on an
   undersized prefix-cached pool with mid-flight child cancellations and
   an expiring-deadline batch: every refcount must drain to zero and the
   prefix index must be empty (and the pool fully free) after eviction.

5. **Chunked-prefill storm** — mixed long/short prompts on an undersized
   chunked (``prefill_chunk_tokens``) prefix-cached pool, with cancels
   landing while long prompts are still mid-prefill and pool pressure
   preempting mid-prefill slots: partially-written prompt blocks (and
   shared prefix refs) must all release — zero leaked blocks, refcounts
   drained, empty block table.

Run:  PYTHONPATH=src python scripts/paged_parity.py
"""
import sys
import time

import jax
import numpy as np

from repro.api import GenerateRequest, RequestCancelledError
from repro.api.client import EngineBackend
from repro.configs import get_config
from repro.core import init_delphi
from repro.serve import BatchedEngine, Request, ring_reference_futures


def _uniforms(max_new, V, seed):
    return np.random.default_rng(seed).uniform(
        size=(max_new, V)).astype(np.float32)


def _reqs(cfg, n, max_new, seed0=0):
    out = []
    for s in range(n):
        S = 3 + (s % 4)
        out.append(Request(
            tokens=(np.arange(3, 3 + S, dtype=np.int32) + s) % 90,
            ages=np.linspace(0.0, 30.0, S).astype(np.float32),
            max_new=max_new,
            uniforms=_uniforms(max_new, cfg.vocab_size, seed0 + s)))
    return out


def parity(params, cfg) -> None:
    def run(kind):
        eng = BatchedEngine(params, cfg, slots=2, max_context=64,
                            cache=kind, block_size=16)
        for r in _reqs(cfg, 5, 8):
            eng.submit(r)
        done = eng.run()
        assert len(done) == 5
        return eng, [(r.out_tokens, r.out_ages) for r in done]

    _, ring = run("ring")
    eng, paged = run("paged")
    assert ring == paged, "paged generate diverged from ring"
    assert eng.allocator.used == 0

    # over-width prompt: wrapped ring pack through the block copy
    S, W = 33, 16
    for kind in ("ring", "paged"):
        e = BatchedEngine(params, cfg, slots=1, max_context=W, cache=kind,
                          block_size=8)
        e.submit(Request(tokens=(np.arange(3, 3 + S) % 90).astype(np.int32),
                         ages=np.linspace(0.0, 30.0, S).astype(np.float32),
                         max_new=4,
                         uniforms=_uniforms(4, cfg.vocab_size, 99)))
        d = e.run()[0]
        if kind == "ring":
            wrap_ref = (d.out_tokens, d.out_ages)
        else:
            assert (d.out_tokens, d.out_ages) == wrap_ref, \
                "paged over-width prompt diverged"
            assert e.allocator.used == 0

    # stream + batch through the client backend surface
    u = _uniforms(6, cfg.vocab_size, 42)
    req = GenerateRequest(tokens=[3, 10, 20], ages=[0.0, 15.0, 28.0],
                          max_new=6, uniforms=u)
    ring_b = EngineBackend.create(params, cfg, slots=2, max_context=64)
    paged_b = EngineBackend.create(params, cfg, slots=2, max_context=64,
                                   cache="paged", block_size=16)
    ev_r = [(e.token, e.age) for e in ring_b.stream(req)]
    ev_p = [(e.token, e.age) for e in paged_b.stream(req)]
    assert ev_r == ev_p, "paged stream diverged from ring"
    batch = [GenerateRequest(tokens=[3, 10, 20], ages=[0.0, 15.0, 28.0],
                             max_new=6, uniforms=u) for _ in range(3)]
    b_r = [(r.tokens, r.ages) for r in ring_b.generate_batch(batch)]
    b_p = [(r.tokens, r.ages) for r in paged_b.generate_batch(batch)]
    assert b_r == b_p, "paged batch diverged from ring"
    assert paged_b.engine.allocator.used == 0
    print(f"parity OK: generate/stream/batch bit-identical "
          f"({len(ring)} + 1 wrapped + stream + batch)")


def storm(params, cfg) -> None:
    # undersized pool: capacity 5 blocks, a full slot needs 4 -> constant
    # growth pressure and preemptions while cancels land mid-flight
    eng = BatchedEngine(params, cfg, slots=4, max_context=32, cache="paged",
                        block_size=8, blocks=6).start()
    try:
        reqs = []
        for s in range(24):
            S = 3 + (s % 5)
            r = Request(tokens=(np.arange(3, 3 + S, dtype=np.int32)) % 90,
                        ages=np.linspace(0.0, 30.0, S).astype(np.float32),
                        max_new=12, request_id=f"storm-{s}")
            reqs.append(r)
            eng.submit(r)
        time.sleep(0.3)
        cancelled = [r.request_id for i, r in enumerate(reqs) if i % 3 == 0]
        for rid in cancelled:
            eng.cancel(rid)
        deadline = time.monotonic() + 120
        while (not all(r.done for r in reqs)) and time.monotonic() < deadline:
            time.sleep(0.05)
        assert all(r.done for r in reqs), "storm requests did not drain"
    finally:
        eng.stop()
    n_cancelled = sum(isinstance(r.error, RequestCancelledError)
                      for r in reqs)
    n_ok = sum(r.error is None for r in reqs)
    assert n_ok + n_cancelled == len(reqs), \
        [type(r.error).__name__ for r in reqs if r.error is not None]
    assert eng.allocator.used == 0, \
        f"LEAK: {eng.allocator.used} blocks still allocated"
    assert (eng._table == -1).all(), "LEAK: block table still references pool"
    # timeout path also reclaims
    eng2 = BatchedEngine(params, cfg, slots=2, max_context=32, cache="paged",
                         block_size=8, request_timeout=0.0)
    for s in range(3):
        eng2.submit(Request(tokens=np.arange(3, 8, dtype=np.int32),
                            ages=np.linspace(0.0, 30.0, 5).astype(np.float32),
                            max_new=12))
    time.sleep(0.01)
    eng2.run(max_ticks=200)
    assert eng2.allocator.used == 0
    print(f"storm OK: {len(reqs)} requests ({n_cancelled} cancelled, "
          f"{eng.preemptions} preemptions, {eng.pool_stats()['blocks_peak_used']}"
          f"/{eng.allocator.capacity} peak blocks), zero leaked blocks")


def fork_parity(params, cfg) -> None:
    toks = np.asarray([3, 10, 20, 30, 41], np.int32)
    ages = np.linspace(0.0, 30.0, 5).astype(np.float32)
    n, max_new, W, K = 4, 6, 64, 4
    u = _uniforms(n * max_new, cfg.vocab_size, seed=23).reshape(
        n, max_new, cfg.vocab_size)
    oracle = ring_reference_futures(params, cfg, toks, ages, n=n,
                                    max_new=max_new, uniforms=u, slots=K,
                                    max_context=W)
    ora = [(list(t), [np.float32(a) for a in a_]) for t, a_ in oracle]
    for kind, kw in (("ring", {}),
                     ("paged", {"block_size": 16}),
                     ("paged", {"block_size": 16, "prefix_cache": True})):
        eng = BatchedEngine(params, cfg, slots=K, max_context=W, cache=kind,
                            **kw)
        for round_ in range(2):          # round 2 hits the prefix index
            kids = eng.sample_futures(toks, ages, n=n, max_new=max_new,
                                      uniforms=u)
            got = [(list(k.out_tokens),
                    [np.float32(a) for a in k.out_ages]) for k in kids]
            assert got == ora, \
                f"forked futures diverged from oracle ({kind} {kw} " \
                f"round {round_})"
        if eng.paged:
            eng.drop_prefix_cache()
            assert eng.allocator.used == 0
            assert not eng.pool._refs, "refcounts left after drain"
    print("fork parity OK: ring/paged/prefix-cached sample_futures "
          "bit-identical to the oracle (2 rounds each)")


def fork_storm(params, cfg) -> None:
    # undersized prefix-cached pool under concurrent futures fan-outs,
    # mid-flight child cancellations, then an expiring-deadline batch
    eng = BatchedEngine(params, cfg, slots=4, max_context=32, cache="paged",
                        block_size=8, blocks=8, prefix_cache=True).start()
    all_kids = []
    try:
        import threading
        waves = []
        for w in range(6):
            S = 3 + (w % 3)
            t = threading.Thread(
                target=lambda w=w, S=S: all_kids.append(eng.sample_futures(
                    (np.arange(3, 3 + S, dtype=np.int32) + w) % 90,
                    np.linspace(0.0, 30.0, S).astype(np.float32),
                    n=3, max_new=10, request_id=f"fut-{w}",
                    wait_timeout=120.0)))
            t.start()
            waves.append(t)
        time.sleep(0.2)
        for w in range(0, 6, 2):         # cancel one child of every other
            eng.cancel(f"fut-{w}/fork-1")
        for t in waves:
            t.join(timeout=180)
        assert not any(t.is_alive() for t in waves), "futures storm hung"
    finally:
        eng.stop()
    kids = [k for wave in all_kids for k in wave]
    bad = [k for k in kids
           if k.error is not None
           and not isinstance(k.error, RequestCancelledError)]
    assert not bad, [type(k.error).__name__ for k in bad]
    assert all(k.done for k in kids)
    # zero-leak with refcounts: drained engine + dropped index -> all free
    eng.drop_prefix_cache()
    assert eng.prefix.entries == 0, "prefix index not empty after eviction"
    assert not eng.pool._refs, f"refcounts not drained: {eng.pool._refs}"
    assert eng.allocator.used == 0, \
        f"LEAK: {eng.allocator.used} blocks still allocated"
    assert (eng._table == -1).all(), "LEAK: block table still references pool"

    # expiring deadlines mid-fork also drain
    eng2 = BatchedEngine(params, cfg, slots=2, max_context=32, cache="paged",
                         block_size=8, request_timeout=0.0,
                         prefix_cache=True)
    parent = Request(tokens=np.arange(3, 8, dtype=np.int32),
                     ages=np.linspace(0.0, 30.0, 5).astype(np.float32),
                     max_new=10, hold=True)
    eng2.submit(parent)
    kids2 = eng2.fork(parent.request_id, 3)
    time.sleep(0.01)
    eng2.run(max_ticks=200)
    assert all(k.done for k in kids2)
    eng2.drop_prefix_cache()
    assert eng2.allocator.used == 0 and not eng2.pool._refs
    st = eng.pool_stats()
    print(f"fork storm OK: {len(kids)} forked futures "
          f"({st['forks']} forks, {st['cow_copies']} COW copies, "
          f"{st['preemptions']} preemptions, peak shared "
          f"{st['shared_blocks_peak']}), refcounts drained, index empty")


def chunked_storm(params, cfg) -> None:
    # mixed long/short prompts on an undersized chunked pool: long prompts
    # span several one-block chunks, so cancels and preemptions land while
    # slots are still mid-prefill — their partially-written blocks (and the
    # shared prefix refs acquired at admission) must all release
    eng = BatchedEngine(params, cfg, slots=4, max_context=32, cache="paged",
                        block_size=8, blocks=8, prefix_cache=True,
                        prefill_chunk_tokens=8).start()
    base = (np.arange(3, 23, dtype=np.int32)) % 90      # shared long prefix
    base_ages = np.linspace(0.0, 30.0, 20).astype(np.float32)
    try:
        # warm registrant: its 2 full blocks seed the index so later long
        # admissions take the partial-hit suffix path
        warm = Request(tokens=base[:16], ages=base_ages[:16], max_new=2,
                       request_id="chunk-warm")
        eng.submit(warm)
        deadline = time.monotonic() + 60
        while not warm.done and time.monotonic() < deadline:
            time.sleep(0.02)
        assert warm.done and warm.error is None
        reqs = []
        for s in range(24):
            if s % 2 == 0:               # long: 16-token prefix + tail
                S = 17 + (s % 4)
                toks, ages = base[:S], base_ages[:S]
            else:                        # short: single partial block
                S = 3 + (s % 5)
                toks = (np.arange(3, 3 + S, dtype=np.int32) + s) % 90
                ages = np.linspace(0.0, 30.0, S).astype(np.float32)
            r = Request(tokens=toks, ages=ages, max_new=12,
                        request_id=f"chunk-storm-{s}")
            reqs.append(r)
            eng.submit(r)
        time.sleep(0.15)                 # some longs are mid-prefill now
        for i, r in enumerate(reqs):
            if i % 3 == 0:
                eng.cancel(r.request_id)
        deadline = time.monotonic() + 120
        while (not all(r.done for r in reqs)) and time.monotonic() < deadline:
            time.sleep(0.05)
        assert all(r.done for r in reqs), "chunked storm did not drain"
    finally:
        eng.stop()
    bad = [r for r in reqs if r.error is not None
           and not isinstance(r.error, RequestCancelledError)]
    assert not bad, [type(r.error).__name__ for r in bad]
    st = eng.pool_stats()
    assert st["chunked_prefills"] > 0, "no admission took the chunked path"
    assert st["prefill_chunks"] > st["chunked_prefills"], \
        "no prompt actually spanned multiple chunks"
    assert st["prefill_in_progress"] == 0
    eng.drop_prefix_cache()
    assert not eng.pool._refs, f"refcounts not drained: {eng.pool._refs}"
    assert eng.allocator.used == 0, \
        f"LEAK: {eng.allocator.used} blocks still allocated"
    assert (eng._table == -1).all(), "LEAK: block table still references pool"
    print(f"chunked storm OK: {len(reqs)} requests "
          f"({st['chunked_prefills']} chunked prefills, "
          f"{st['prefill_chunks']} chunks, {st['suffix_tokens_saved']} "
          f"suffix tokens saved, {st['preemptions']} preemptions), "
          f"zero leaked blocks")


def main() -> int:
    cfg = get_config("delphi-2m", reduced=True).replace(
        dtype="float32", vocab_size=96, max_seq_len=48, max_age=1e9)
    params = init_delphi(cfg, jax.random.PRNGKey(7))
    parity(params, cfg)
    storm(params, cfg)
    fork_parity(params, cfg)
    fork_storm(params, cfg)
    chunked_storm(params, cfg)
    print("paged_parity: all checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
