"""Repo-local developer tooling (not shipped as part of the model stack)."""
