"""RL002: trace purity inside module-level jitted functions.

Entry points are module-level ``def``s decorated with ``jax.jit`` /
``functools.partial(jax.jit, ...)`` (plus any function opted in with a
``# repro-lint: traced`` marker).  The traced set is closed over direct
calls to same-module top-level helpers; static args declared via
``static_argnames`` are propagated call-site by call-site, so an ``if``
on ``cfg.age_encoding`` is recognized as trace-time control flow while an
``if`` on a tracer value is flagged.

Flagged inside traced code:

* ``.item()`` / ``.tolist()`` / ``.to_py()`` / ``.block_until_ready()``
  and ``jax.device_get`` -- explicit device->host syncs;
* ``float()/int()/bool()/complex()`` applied to a non-static value --
  implicit host sync on a tracer;
* ``np.*`` calls with a non-static argument -- silent host
  materialization (``np.inf`` and numpy math on static python values are
  fine);
* ``if``/``while``/``assert``/ternary/comprehension conditions that are
  not provably trace-static (static = literals, static params and
  attribute chains on them, ``.shape``/``.ndim``/``.dtype``, ``is
  None`` tests, and arithmetic over those);
* mutating a container that outlives the trace body (``append`` etc. on
  a closure/global name, or through an attribute chain);
* ``global``/``nonlocal`` declarations.

This mechanically enforces the "exactly one device->host sync per tick"
property: the only sanctioned sync is the engine's ``_fetch``, which
lives outside the jitted functions.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .core import Finding, Project, SourceFile, attr_root, dotted_name

RULE_ID = "RL002"

_SYNC_METHODS = {"item", "tolist", "to_py", "block_until_ready"}
_CAST_BUILTINS = {"float", "int", "bool", "complex"}
_MUTATORS = {"append", "extend", "insert", "remove", "clear", "pop",
             "popitem", "update", "setdefault", "add", "discard"}
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size"}
_STATIC_CALLS = {"min", "max", "len", "abs", "round", "sorted", "tuple",
                 "list", "sum", "range", "isinstance", "getattr", "hasattr",
                 "divmod", "zip", "enumerate"}


def _jit_decoration(dec: ast.AST) -> Optional[Tuple[bool, Set[str]]]:
    """(is_jit, static_argnames) if this decorator applies jax.jit."""
    target = dec.func if isinstance(dec, ast.Call) else dec
    name = dotted_name(target)
    if name in ("jax.jit", "jit"):
        return True, set()
    # functools.partial(jax.jit, static_argnames=(...), ...)
    if isinstance(dec, ast.Call) and name in ("functools.partial", "partial"):
        if dec.args and dotted_name(dec.args[0]) in ("jax.jit", "jit"):
            static: Set[str] = set()
            for kw in dec.keywords:
                if kw.arg == "static_argnames":
                    static |= _str_elts(kw.value)
            return True, static
    return None


def _str_elts(node: ast.AST) -> Set[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return {node.value}
    if isinstance(node, (ast.Tuple, ast.List)):
        out: Set[str] = set()
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                out.add(e.value)
        return out
    return set()


def _param_names(fn: ast.FunctionDef) -> List[str]:
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


def _assigned_names(fn: ast.FunctionDef) -> Set[str]:
    """Names bound in ``fn``'s own scope (excluding nested function bodies)."""
    out: Set[str] = set(_param_names(fn))

    def walk(node: ast.AST) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    out.add(child.name)
                continue
            if isinstance(child, ast.Name) and \
                    isinstance(child.ctx, (ast.Store, ast.Del)):
                out.add(child.id)
            if isinstance(child, ast.comprehension):
                for t in ast.walk(child.target):
                    if isinstance(t, ast.Name):
                        out.add(t.id)
            walk(child)

    walk(fn)
    return out


class _Scope:
    def __init__(self, fn: ast.FunctionDef, static: Set[str],
                 parent: Optional["_Scope"]):
        self.fn = fn
        self.locals = _assigned_names(fn)
        self.static = set(static)
        self.parent = parent

    def is_local(self, name: str) -> bool:
        return name in self.locals

    def lookup_static(self, name: str) -> bool:
        """True if ``name`` resolves to a trace-static value."""
        if name in self.locals:
            return name in self.static
        if self.parent is not None:
            return self.parent.lookup_static(name)
        # Module globals (imports, constants, other functions) are fixed at
        # trace time.
        return True


class _FnAnalyzer:
    """Analyze one traced function; record violations and outgoing calls."""

    def __init__(self, module: "_ModuleCtx", fn: ast.FunctionDef,
                 static_params: Set[str], entry: str):
        self.m = module
        self.fn = fn
        self.entry = entry
        self.scope = _Scope(fn, static_params & set(_param_names(fn)), None)
        # calls into same-module top-level functions: name -> static params
        self.calls: Dict[str, Set[str]] = {}

    # -- static-expression classification -----------------------------------
    def is_static(self, node: ast.AST, scope: _Scope) -> bool:
        if node is None:
            return True
        if isinstance(node, ast.Constant):
            return True
        if isinstance(node, ast.Name):
            return scope.lookup_static(node.id)
        if isinstance(node, ast.Attribute):
            if node.attr in _STATIC_ATTRS:
                return True     # .shape/.ndim/.dtype are trace-time values
            return self.is_static(node.value, scope)
        if isinstance(node, ast.Subscript):
            return (self.is_static(node.value, scope)
                    and self.is_static(node.slice, scope))
        if isinstance(node, ast.Slice):
            return all(self.is_static(p, scope)
                       for p in (node.lower, node.upper, node.step))
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return all(self.is_static(e, scope) for e in node.elts)
        if isinstance(node, ast.Compare):
            # `x is None` / `x is not None` is resolved at trace time even
            # when x is a tracer (tracers are never None).
            if len(node.ops) == 1 and \
                    isinstance(node.ops[0], (ast.Is, ast.IsNot)) and \
                    isinstance(node.comparators[0], ast.Constant) and \
                    node.comparators[0].value is None:
                return True
            return (self.is_static(node.left, scope)
                    and all(self.is_static(c, scope)
                            for c in node.comparators))
        if isinstance(node, ast.BoolOp):
            return all(self.is_static(v, scope) for v in node.values)
        if isinstance(node, ast.UnaryOp):
            return self.is_static(node.operand, scope)
        if isinstance(node, ast.BinOp):
            return (self.is_static(node.left, scope)
                    and self.is_static(node.right, scope))
        if isinstance(node, ast.IfExp):
            return all(self.is_static(p, scope)
                       for p in (node.test, node.body, node.orelse))
        if isinstance(node, ast.Call):
            args_static = (all(self.is_static(a, scope) for a in node.args)
                           and all(self.is_static(kw.value, scope)
                                   for kw in node.keywords))
            if isinstance(node.func, ast.Name):
                if node.func.id in _STATIC_CALLS | _CAST_BUILTINS:
                    return args_static
                return False
            if isinstance(node.func, ast.Attribute):
                # method on a static receiver, e.g. (V - 1).bit_length()
                return args_static and self.is_static(node.func.value, scope)
            return False
        if isinstance(node, ast.JoinedStr):
            return True
        return False

    # -- driver --------------------------------------------------------------
    def run(self) -> None:
        self._visit_body(self.fn.body, self.scope)

    def _flag(self, node: ast.AST, what: str) -> None:
        self.m.findings.append(Finding(
            rule=RULE_ID, path=self.m.file.path,
            line=node.lineno, col=node.col_offset,
            message=(f"{what} inside traced function "
                     f"`{self.fn.name}` (jit entry `{self.entry}`)"),
            symbol=f"{self.fn.name}.{what}"))

    def _visit_body(self, body: Sequence[ast.stmt], scope: _Scope) -> None:
        for stmt in body:
            self._visit_stmt(stmt, scope)

    def _visit_stmt(self, stmt: ast.stmt, scope: _Scope) -> None:
        if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            value = stmt.value
            if value is not None:
                self._scan_expr(value, scope)
                static = self.is_static(value, scope)
                if isinstance(stmt, ast.AugAssign):
                    static = static and self.is_static(stmt.target, scope)
                targets = (stmt.targets if isinstance(stmt, ast.Assign)
                           else [stmt.target])
                for t in targets:
                    self._bind(t, static, scope)
        elif isinstance(stmt, ast.If):
            self._scan_expr(stmt.test, scope)
            if not self.is_static(stmt.test, scope):
                self._flag(stmt.test, "`if` on a traced value")
            self._visit_body(stmt.body, scope)
            self._visit_body(stmt.orelse, scope)
        elif isinstance(stmt, ast.While):
            self._scan_expr(stmt.test, scope)
            if not self.is_static(stmt.test, scope):
                self._flag(stmt.test, "`while` on a traced value")
            self._visit_body(stmt.body, scope)
            self._visit_body(stmt.orelse, scope)
        elif isinstance(stmt, ast.For):
            self._scan_expr(stmt.iter, scope)
            if not self.is_static(stmt.iter, scope) and \
                    not _dict_style_iter(stmt.iter, stmt.target, stmt):
                self._flag(stmt.iter, "python `for` over a traced value")
            self._bind(stmt.target, True, scope)
            self._visit_body(stmt.body, scope)
            self._visit_body(stmt.orelse, scope)
        elif isinstance(stmt, ast.Assert):
            self._scan_expr(stmt.test, scope)
            if not self.is_static(stmt.test, scope):
                self._flag(stmt.test, "`assert` on a traced value")
        elif isinstance(stmt, (ast.Global, ast.Nonlocal)):
            self._flag(stmt, f"`{'global' if isinstance(stmt, ast.Global) else 'nonlocal'}` rebinding")
        elif isinstance(stmt, ast.With):
            for item in stmt.items:
                self._scan_expr(item.context_expr, scope)
            self._visit_body(stmt.body, scope)
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            inner = _Scope(stmt, set(), scope)
            self._visit_body(stmt.body, inner)
        elif isinstance(stmt, ast.Return):
            if stmt.value is not None:
                self._scan_expr(stmt.value, scope)
        elif isinstance(stmt, ast.Expr):
            self._scan_expr(stmt.value, scope)
        elif isinstance(stmt, (ast.Try,)):
            self._visit_body(stmt.body, scope)
            for h in stmt.handlers:
                self._visit_body(h.body, scope)
            self._visit_body(stmt.orelse, scope)
            self._visit_body(stmt.finalbody, scope)
        elif isinstance(stmt, ast.Raise):
            if stmt.exc is not None:
                self._scan_expr(stmt.exc, scope)
        elif isinstance(stmt, (ast.Import, ast.ImportFrom, ast.Pass,
                               ast.Break, ast.Continue, ast.Delete)):
            pass
        else:
            for child in ast.iter_child_nodes(stmt):
                if isinstance(child, ast.expr):
                    self._scan_expr(child, scope)

    def _bind(self, target: ast.AST, static: bool, scope: _Scope) -> None:
        if isinstance(target, ast.Name):
            if static:
                scope.static.add(target.id)
            else:
                scope.static.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._bind(e, static, scope)
        elif isinstance(target, ast.Starred):
            self._bind(target.value, static, scope)
        # attribute/subscript stores don't bind names

    # -- expression scan -----------------------------------------------------
    def _scan_expr(self, node: ast.AST, scope: _Scope) -> None:
        for sub in ast.walk(node):
            if isinstance(sub, ast.IfExp):
                if not self.is_static(sub.test, scope):
                    self._flag(sub.test, "ternary on a traced value")
            elif isinstance(sub, ast.comprehension):
                if not self.is_static(sub.iter, scope) and \
                        not _dict_style_iter(sub.iter, sub.target, node):
                    self._flag(sub.iter, "comprehension over a traced value")
                for cond in sub.ifs:
                    if not self.is_static(cond, scope):
                        self._flag(cond, "comprehension `if` on a traced value")
            elif isinstance(sub, ast.Call):
                self._scan_call(sub, scope)

    def _scan_call(self, call: ast.Call, scope: _Scope) -> None:
        func = call.func
        args_static = (all(self.is_static(a, scope) for a in call.args)
                       and all(self.is_static(kw.value, scope)
                               for kw in call.keywords))
        if isinstance(func, ast.Attribute):
            if func.attr in _SYNC_METHODS:
                self._flag(call, f"host sync `.{func.attr}()`")
                return
            root = attr_root(func)
            if root in self.m.np_aliases and not args_static:
                self._flag(call, f"`{dotted_name(func)}` call on a traced value")
                return
            if dotted_name(func) in ("jax.device_get",):
                self._flag(call, "host sync `jax.device_get`")
                return
            if func.attr in _MUTATORS:
                self._scan_mutation(call, func, scope)
        elif isinstance(func, ast.Name):
            if func.id in _CAST_BUILTINS and not args_static:
                self._flag(call, f"host cast `{func.id}()` on a traced value")
            elif func.id == "print":
                self._flag(call, "`print` side effect")
            elif func.id in self.m.functions:
                # same-module helper: propagate static params transitively
                callee = self.m.functions[func.id]
                bound = _bind_call_static(self, callee, call, scope)
                prev = self.calls.get(func.id)
                self.calls[func.id] = (bound if prev is None
                                       else prev & bound)

    def _scan_mutation(self, call: ast.Call, func: ast.Attribute,
                       scope: _Scope) -> None:
        recv = func.value
        if isinstance(recv, ast.Name):
            # mutating a local container is trace-time metaprogramming;
            # mutating a closure/global container escapes the trace body
            if not scope.is_local(recv.id):
                self._flag(call, f"mutation `.{func.attr}()` of "
                                 f"non-local container `{recv.id}`")
        elif isinstance(recv, ast.Attribute):
            self._flag(call, f"mutation `.{func.attr}()` through attribute "
                             f"`{dotted_name(recv) or recv.attr}`")


def _dict_style_iter(iter_node: ast.AST, target: ast.AST,
                     context: ast.AST) -> bool:
    """Iterating a pytree dict by key (``{... for k in state}`` with
    ``state[k]`` in the body) is trace-static structure iteration, not a
    host sync -- the keys are python strings even when the values are
    tracers."""
    if isinstance(iter_node, ast.Call) and \
            isinstance(iter_node.func, ast.Attribute) and \
            iter_node.func.attr in ("keys", "items") and not iter_node.args:
        container = iter_node.func.value
    else:
        container = iter_node
    if not isinstance(container, ast.Name):
        return False
    targets = {t.id for t in ast.walk(target) if isinstance(t, ast.Name)}
    for sub in ast.walk(context):
        if isinstance(sub, ast.Subscript) and \
                isinstance(sub.value, ast.Name) and \
                sub.value.id == container.id:
            for n in ast.walk(sub.slice):
                if isinstance(n, ast.Name) and n.id in targets:
                    return True
    return False


def _bind_call_static(an: _FnAnalyzer, callee: ast.FunctionDef,
                      call: ast.Call, scope: _Scope) -> Set[str]:
    """Callee params that receive trace-static expressions at this site."""
    a = callee.args
    pos = [p.arg for p in a.posonlyargs + a.args]
    static: Set[str] = set()
    for i, arg in enumerate(call.args):
        if i < len(pos) and an.is_static(arg, scope):
            static.add(pos[i])
    kwonly = {p.arg for p in a.kwonlyargs}
    for kw in call.keywords:
        if kw.arg and (kw.arg in kwonly or kw.arg in pos) \
                and an.is_static(kw.value, scope):
            static.add(kw.arg)
    return static


class _ModuleCtx:
    def __init__(self, file: SourceFile):
        self.file = file
        self.findings: List[Finding] = []
        self.functions: Dict[str, ast.FunctionDef] = {}
        self.np_aliases: Set[str] = set()
        assert file.tree is not None
        for node in file.tree.body:
            if isinstance(node, ast.FunctionDef):
                self.functions[node.name] = node
            elif isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name == "numpy":
                        self.np_aliases.add(alias.asname or "numpy")
            elif isinstance(node, ast.ImportFrom):
                if node.module == "numpy":
                    for alias in node.names:
                        self.np_aliases.add(alias.asname or alias.name)


def check(project: Project, graph=None) -> List[Finding]:
    findings: List[Finding] = []
    traced_fns: List[Tuple[SourceFile, ast.FunctionDef, str]] = []
    for f in project.files:
        if f.tree is None:
            continue
        m = _ModuleCtx(f)
        # seed: decorated jit entries + explicitly marked traced functions
        worklist: List[str] = []
        static_of: Dict[str, Set[str]] = {}
        entry_of: Dict[str, str] = {}
        for name, fn in m.functions.items():
            jit_static: Optional[Set[str]] = None
            for dec in fn.decorator_list:
                hit = _jit_decoration(dec)
                if hit:
                    jit_static = (jit_static or set()) | hit[1]
            if jit_static is None and "traced" in f.markers_for_def(fn):
                jit_static = set()
            if jit_static is not None:
                static_of[name] = jit_static
                entry_of[name] = name
                worklist.append(name)
        if not worklist:
            continue
        # transitive closure over same-module helpers, propagating which
        # params are static; re-analyze if a static set shrinks
        analyzed: Dict[str, Set[str]] = {}
        guard = 0
        while worklist and guard < 1000:
            guard += 1
            name = worklist.pop(0)
            fn = m.functions[name]
            static = static_of.get(name, set())
            if analyzed.get(name) == static:
                continue
            analyzed[name] = set(static)
            an = _FnAnalyzer(m, fn, static, entry_of.get(name, name))
            an.run()
            for callee, bound in an.calls.items():
                prev = static_of.get(callee)
                merged = bound if prev is None else prev & bound
                if callee not in entry_of:
                    entry_of[callee] = entry_of.get(name, name)
                if prev is None or merged != prev or callee not in analyzed:
                    static_of[callee] = merged
                    worklist.append(callee)
        # keep only findings from the final fixpoint pass of each function:
        # re-run once cleanly to avoid duplicates from re-analysis
        m_final = _ModuleCtx(f)
        m_final.np_aliases = m.np_aliases
        for name, static in analyzed.items():
            final_static = static_of.get(name, static)
            an = _FnAnalyzer(m_final, m.functions[name], final_static,
                             entry_of.get(name, name))
            an.run()
            traced_fns.append((f, m.functions[name],
                               entry_of.get(name, name)))
        findings.extend(m_final.findings)
    if graph is not None:
        findings.extend(_cross_module_syncs(graph, traced_fns))
    return findings


def _cross_module_syncs(graph, traced_fns) -> List[Finding]:
    """Chase traced functions' call edges into *other* files.

    Same-file helpers are already in the worklist closure above; a
    traced function calling a plain (non-jit) top-level helper in
    another module drags that helper into the trace too.  Full static
    propagation across modules is out of scope, so the transitive pass
    is sync-only: explicit device->host syncs, ``print``, and
    ``global``/``nonlocal`` are flagged wherever they appear.
    """
    findings: List[Finding] = []
    analyzed = {(sf.path, fn.name): entry for sf, fn, entry in traced_fns}
    visited: Set[Tuple[str, str]] = set()
    work = []
    for sf, fn, entry in traced_fns:
        fi = graph.func_for(fn)
        if fi is not None:
            work.append((fi, entry))
    while work:
        fi, entry = work.pop()
        for site in fi.calls:
            callee = site.callee
            key = (callee.path, callee.name)
            if callee.path == fi.path or callee.is_jit or \
                    callee.cls is not None or key in analyzed or \
                    key in visited:
                continue
            visited.add(key)
            findings.extend(_sync_only_scan(callee, entry))
            work.append((callee, entry))
    return findings


def _sync_only_scan(fi, entry: str) -> List[Finding]:
    out: List[Finding] = []

    def flag(node: ast.AST, what: str) -> None:
        out.append(Finding(
            rule=RULE_ID, path=fi.path,
            line=node.lineno, col=node.col_offset,
            message=(f"{what} inside `{fi.name}`, traced transitively "
                     f"from jit entry `{entry}` in another module"),
            symbol=f"{fi.name}.transitive.{what}"))

    for sub in ast.walk(fi.node):
        if isinstance(sub, ast.Call):
            func = sub.func
            if isinstance(func, ast.Attribute):
                if func.attr in _SYNC_METHODS:
                    flag(sub, f"host sync `.{func.attr}()`")
                elif dotted_name(func) == "jax.device_get":
                    flag(sub, "host sync `jax.device_get`")
            elif isinstance(func, ast.Name) and func.id == "print":
                flag(sub, "`print` side effect")
        elif isinstance(sub, (ast.Global, ast.Nonlocal)):
            kind = "global" if isinstance(sub, ast.Global) else "nonlocal"
            flag(sub, f"`{kind}` rebinding")
    return out
