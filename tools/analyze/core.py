"""Shared infrastructure for repro-lint: parsed files, findings, markers.

Everything here is stdlib-only.  Comments are extracted with ``tokenize``
(not regexes over raw lines) so ``#`` inside string literals can never be
mistaken for an annotation.
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import io
import re
import tokenize
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

RULE_IDS = ("RL001", "RL002", "RL003", "RL004", "RL005", "RL006", "RL007")

# --- annotation grammar -----------------------------------------------------
# field declaration:   self.pending = []          # guarded-by: _lock
#                      self.slot_req = [...]      # guarded-by: engine-thread
# method markers:      def step(self):            # repro-lint: engine-thread-only
#                      def _sel(self):            # repro-lint: holds=_lock
#                      def helper(...):           # repro-lint: traced
# suppression:         <stmt>  # repro-lint: disable=RL001,RL004 <reason>
_GUARDED_BY_RE = re.compile(r"#\s*guarded-by:\s*([A-Za-z_][\w-]*)")
_LINT_RE = re.compile(r"#\s*repro-lint:\s*(.*)$")
_DISABLE_RE = re.compile(r"disable=((?:RL\d{3})(?:\s*,\s*RL\d{3})*)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation, anchored to a source location.

    ``symbol`` is a stable dotted anchor (``Class.method.field`` or similar)
    used for baseline fingerprints so that line-number churn does not
    invalidate a committed baseline.
    """

    rule: str
    path: str            # repo-relative posix path
    line: int
    col: int
    message: str
    symbol: str = ""

    @property
    def fingerprint(self) -> str:
        key = "|".join((self.rule, self.path, self.symbol, self.message))
        return hashlib.sha1(key.encode("utf-8")).hexdigest()[:16]

    def format_text(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"

    def format_github(self) -> str:
        # GitHub annotation command; message must not contain newlines.
        msg = f"{self.rule} {self.message}".replace("\n", " ")
        return f"::error file={self.path},line={self.line}::{msg}"

    def to_dict(self) -> Dict[str, object]:
        return {
            "rule": self.rule, "path": self.path, "line": self.line,
            "col": self.col, "message": self.message, "symbol": self.symbol,
            "fingerprint": self.fingerprint,
        }


class SourceFile:
    """A parsed python file plus its comment-derived annotations."""

    def __init__(self, path: str, text: str):
        self.path = path                      # repo-relative posix path
        self.text = text
        self.tree: Optional[ast.Module] = None
        self.parse_error: Optional[SyntaxError] = None
        try:
            self.tree = ast.parse(text, filename=path)
        except SyntaxError as e:              # surfaced as an RL000 finding
            self.parse_error = e
        self.comments: Dict[int, str] = {}    # line -> comment text (with '#')
        self._standalone: Set[int] = set()    # lines that are comment-only
        self._scan_comments()

    def _scan_comments(self) -> None:
        lines = self.text.splitlines()
        try:
            toks = tokenize.generate_tokens(io.StringIO(self.text).readline)
            for tok in toks:
                if tok.type == tokenize.COMMENT:
                    line = tok.start[0]
                    self.comments[line] = tok.string
                    src = lines[line - 1] if line <= len(lines) else ""
                    if src.lstrip().startswith("#"):
                        self._standalone.add(line)
        except (tokenize.TokenError, IndentationError, SyntaxError):
            pass  # parse_error already recorded; comments best-effort

    # -- annotations --------------------------------------------------------
    def guard_for_line(self, line: int) -> Optional[str]:
        """``# guarded-by: X`` trailing comment on this line, if any."""
        c = self.comments.get(line)
        if not c:
            return None
        m = _GUARDED_BY_RE.search(c)
        return m.group(1) if m else None

    def markers_for_def(self, node: ast.AST) -> Set[str]:
        """repro-lint markers on a ``def`` line or the line just above it.

        Recognized markers: ``engine-thread-only``, ``holds=_lock``,
        ``traced``, ``hot-path``, ``transfers-ownership`` (space-separated
        on one comment).
        """
        out: Set[str] = set()
        lineno = getattr(node, "lineno", None)
        if lineno is None:
            return out
        candidates = [lineno]
        above = lineno - 1
        if above in self._standalone:
            candidates.append(above)
        for ln in candidates:
            c = self.comments.get(ln)
            if not c:
                continue
            m = _LINT_RE.search(c)
            if not m:
                continue
            for tok in m.group(1).split():
                if tok in ("engine-thread-only", "holds=_lock", "traced",
                           "hot-path", "transfers-ownership"):
                    out.add(tok)
        return out

    def suppressions(self) -> Dict[int, Set[str]]:
        """Map of source line -> rule IDs suppressed on that line.

        A trailing ``# repro-lint: disable=RLxxx <reason>`` suppresses
        findings on its own line; a standalone comment suppresses the
        next non-comment line (so a multi-line reason still anchors to
        the statement below it).
        """
        out: Dict[int, Set[str]] = {}
        n_lines = self.text.count("\n") + 1
        for line, c in self.comments.items():
            m = _LINT_RE.search(c)
            if not m:
                continue
            d = _DISABLE_RE.search(m.group(1))
            if not d:
                continue
            rules = {r.strip() for r in d.group(1).split(",")}
            out.setdefault(line, set()).update(rules)
            if line in self._standalone:
                nxt = line + 1
                while nxt in self._standalone and nxt <= n_lines:
                    nxt += 1
                out.setdefault(nxt, set()).update(rules)
        return out


class Project:
    """The analyzed tree: ``src/repro`` sources plus the test corpus.

    ``root`` is the repository root.  Fixture projects in tests may use any
    directory that mimics the ``src/repro`` + ``tests`` layout (both
    subtrees are optional; rules degrade gracefully when one is absent).
    """

    def __init__(self, root: Path, src_rel: str = "src/repro",
                 tests_rel: str = "tests"):
        self.root = Path(root)
        self.src_rel = src_rel
        self.tests_rel = tests_rel
        self.files: List[SourceFile] = []
        src_dir = self.root / src_rel
        if src_dir.is_dir():
            for p in sorted(src_dir.rglob("*.py")):
                rel = p.relative_to(self.root).as_posix()
                self.files.append(SourceFile(rel, p.read_text()))
        self.tests: List[Tuple[str, str]] = []   # (rel path, text)
        tests_dir = self.root / tests_rel
        if tests_dir.is_dir():
            for p in sorted(tests_dir.rglob("*.py")):
                rel = p.relative_to(self.root).as_posix()
                self.tests.append((rel, p.read_text()))
        self._by_path = {f.path: f for f in self.files}

    def file(self, path: str) -> Optional[SourceFile]:
        return self._by_path.get(path)

    def find_suffix(self, suffix: str) -> Optional[SourceFile]:
        """First source file whose path ends with ``suffix`` (posix)."""
        for f in self.files:
            if f.path.endswith(suffix):
                return f
        return None

    def parse_errors(self) -> List[Finding]:
        out = []
        for f in self.files:
            if f.parse_error is not None:
                out.append(Finding(
                    rule="RL000", path=f.path,
                    line=f.parse_error.lineno or 1,
                    col=(f.parse_error.offset or 1) - 1,
                    message=f"syntax error: {f.parse_error.msg}",
                    symbol="<parse>"))
        return out


def apply_suppressions(project: Project,
                       findings: List[Finding]) -> Tuple[List[Finding], int]:
    """Drop findings covered by inline ``disable=`` comments.

    Returns (kept, suppressed_count).
    """
    cache: Dict[str, Dict[int, Set[str]]] = {}
    kept: List[Finding] = []
    dropped = 0
    for f in findings:
        sf = project.file(f.path)
        if sf is None:
            kept.append(f)
            continue
        if f.path not in cache:
            cache[f.path] = sf.suppressions()
        rules = cache[f.path].get(f.line, set())
        if f.rule in rules:
            dropped += 1
        else:
            kept.append(f)
    return kept, dropped


def attr_root(node: ast.AST) -> Optional[str]:
    """Root ``Name`` of a dotted attribute chain (``np.linalg.norm`` -> np)."""
    while isinstance(node, ast.Attribute):
        node = node.value
    if isinstance(node, ast.Name):
        return node.id
    return None


def dotted_name(node: ast.AST) -> Optional[str]:
    """Render ``a.b.c`` attribute/name chains; None for anything else."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None
