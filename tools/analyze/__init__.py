"""repro-lint: interprocedural AST invariant analyzer for this repository.

Seven repo-specific rules, all built on the stdlib ``ast`` module (no
third-party dependencies).  Since v2 the analyzer is interprocedural: a
project-wide call graph (``callgraph.py``) resolves ``self.method()`` /
``self.field.method()`` / bare-name / module-attribute calls and
propagates markers transitively, and a per-function control-flow
interpreter (``cfg.py``) walks branches, loops, ``try/except/finally``,
``with``, and early returns path-sensitively.

* **RL001 lock discipline** -- fields annotated ``# guarded-by: _lock`` or
  ``# guarded-by: engine-thread`` may only be touched under ``with
  self._lock`` / in methods marked ``# repro-lint: engine-thread-only``
  (or ``holds=_lock``); both markers are also *derived* through the call
  graph when every caller has them.  Turns the prose contract in
  ``serve/engine.py`` into a race detector.
* **RL002 trace purity** -- module-level ``jax.jit`` functions (and the
  same-module helpers they trace into) must not host-sync: no
  ``.item()``/``.tolist()``, no ``float()/int()/bool()`` on tracers, no
  ``np.*`` calls on traced values, no ``if``/``while`` on tracer values,
  no mutation of containers that outlive the trace.
* **RL003 kernel<->oracle pairing** -- every public kernel in
  ``src/repro/kernels/`` needs a ``<name>_ref`` oracle in
  ``kernels/ref.py`` and at least one test referencing both names; the
  wrapper and oracle must agree on positional parameter names and order.
* **RL004 wire stability** -- the ``ApiError`` code->HTTP-status table is
  frozen, every wire dataclass field must round-trip through
  ``to_json``/``from_json``, and every POST ``/v1/*`` handler must check
  ``protocol_version``.
* **RL005 resource discipline** -- block handles from
  ``BlockAllocator.alloc`` / ``SharedBlockPool.alloc``/``.share`` must be
  released, stored into ``self.*`` state, or handed to a
  ``# repro-lint: transfers-ownership`` callee on every path out of the
  function, including raise edges of intervening calls (``resources.py``).
* **RL006 host-sync purity** -- methods marked ``# repro-lint: hot-path``
  and everything reachable from them through the call graph (stopping at
  jit boundaries) must not implicitly sync device->host; the engine's one
  budgeted packed sync carries a reviewed suppression (``hostsync.py``).
* **RL007 Pallas kernel geometry** -- for each ``pl.pallas_call``:
  index-map arity == ``len(grid) + num_scalar_prefetch``, kernel
  positional signature matches refs+inputs+outputs+scratch, ``pltpu.VMEM``
  scratch dtypes are explicit, and prefetched-table indexing sits under a
  ``pl.when`` guard (``pallas.py``).

Run ``python -m tools.analyze --help`` (or the ``repro-lint`` console
script) for usage; see the README "Static analysis" section for the full
annotation grammar and triage runbook.
"""
from .core import Finding, Project, SourceFile  # noqa: F401

__all__ = ["Finding", "Project", "SourceFile"]
