"""repro-lint: AST-based invariant analyzer for this repository.

Four repo-specific rules, all built on the stdlib ``ast`` module (no
third-party dependencies):

* **RL001 lock discipline** -- fields annotated ``# guarded-by: _lock`` or
  ``# guarded-by: engine-thread`` may only be touched under ``with
  self._lock`` / in methods marked ``# repro-lint: engine-thread-only``
  (or ``holds=_lock``).  Turns the prose contract in
  ``serve/engine.py`` into a race detector.
* **RL002 trace purity** -- module-level ``jax.jit`` functions (and the
  same-module helpers they trace into) must not host-sync: no
  ``.item()``/``.tolist()``, no ``float()/int()/bool()`` on tracers, no
  ``np.*`` calls on traced values, no ``if``/``while`` on tracer values,
  no mutation of containers that outlive the trace.
* **RL003 kernel<->oracle pairing** -- every public kernel in
  ``src/repro/kernels/`` needs a ``<name>_ref`` oracle in
  ``kernels/ref.py`` and at least one test referencing both names.
* **RL004 wire stability** -- the ``ApiError`` code->HTTP-status table is
  frozen, every wire dataclass field must round-trip through
  ``to_json``/``from_json``, and every POST ``/v1/*`` handler must check
  ``protocol_version``.

Run ``python -m tools.analyze --help`` (or the ``repro-lint`` console
script) for usage; see the README "Static analysis" section for the
annotation conventions.
"""
from .core import Finding, Project, SourceFile  # noqa: F401

__all__ = ["Finding", "Project", "SourceFile"]
