"""RL007: Pallas kernel geometry cross-checks.

For every ``pl.pallas_call`` in ``src/repro/kernels/*.py`` (either with
direct ``grid=``/``in_specs=``/``out_specs=``/``scratch_shapes=``
keywords, or through a local ``pltpu.PrefetchScalarGridSpec`` bound to
``grid_spec=``):

* **index-map arity** -- every resolvable BlockSpec index map (lambda or
  local ``def``) must take ``len(grid) + num_scalar_prefetch`` args;
* **kernel signature** -- the kernel body's positional parameter count
  must equal ``num_scalar_prefetch + len(in_specs) + len(out_specs) +
  len(scratch_shapes)`` (keyword-only params bound via
  ``functools.partial`` don't count);
* **scratch dtypes** -- every ``pltpu.VMEM(shape, dtype)`` scratch entry
  must carry an explicit dotted dtype (``jnp.float32``), not a bare
  name or a positional omission;
* **prefetch guards** -- if an index map subscripts a scalar-prefetch
  operand (a block table lookup), the kernel body must contain a
  ``pl.when(...)`` guard (call or decorator form) over a value read from
  the corresponding prefetch ref -- the sentinel-block (-1) discipline.

Anything unresolvable (dynamic grids, kernels built outside the module)
is skipped silently: this rule only reports what it can prove.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .core import Finding, Project, SourceFile, attr_root, dotted_name

RULE_ID = "RL007"

_SKIP_BASES = {"ref.py", "__init__.py"}


class _KernelsModule:
    def __init__(self, file: SourceFile):
        self.file = file
        self.pl: Set[str] = set()      # pallas aliases
        self.pltpu: Set[str] = set()   # pallas tpu aliases
        self.defs: Dict[str, ast.FunctionDef] = {}
        assert file.tree is not None
        for node in file.tree.body:
            if isinstance(node, ast.FunctionDef):
                self.defs[node.name] = node
            elif isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                for alias in node.names:
                    bound = alias.asname or alias.name
                    if mod == "jax.experimental" and alias.name == "pallas":
                        self.pl.add(bound)
                    elif mod == "jax.experimental.pallas" and \
                            alias.name == "tpu":
                        self.pltpu.add(bound)
            elif isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name == "jax.experimental.pallas":
                        self.pl.add(alias.asname or "jax")
                    elif alias.name == "jax.experimental.pallas.tpu":
                        self.pltpu.add(alias.asname or "jax")


class _Geometry:
    """One resolved pallas_call site."""

    def __init__(self) -> None:
        self.kernel: Optional[ast.FunctionDef] = None
        self.kernel_name: str = "<kernel>"
        self.num_prefetch: int = 0
        self.grid_len: Optional[int] = None
        self.in_specs: List[ast.Call] = []
        self.out_specs: List[ast.Call] = []
        self.scratch: List[ast.AST] = []
        self.has_scratch_kw = False
        self.call: Optional[ast.Call] = None


def _local_assigns(fn: ast.FunctionDef) -> Dict[str, ast.AST]:
    out: Dict[str, ast.AST] = {}
    for sub in ast.walk(fn):
        if isinstance(sub, ast.Assign) and len(sub.targets) == 1 and \
                isinstance(sub.targets[0], ast.Name):
            out.setdefault(sub.targets[0].id, sub.value)
    return out


def _local_defs(fn: ast.FunctionDef) -> Dict[str, ast.FunctionDef]:
    return {sub.name: sub for sub in ast.walk(fn)
            if isinstance(sub, ast.FunctionDef) and sub is not fn}


def _deref(expr: ast.AST, assigns: Dict[str, ast.AST]) -> ast.AST:
    seen = 0
    while isinstance(expr, ast.Name) and expr.id in assigns and seen < 4:
        expr = assigns[expr.id]
        seen += 1
    return expr


def _spec_list(expr: ast.AST, assigns: Dict[str, ast.AST],
               pl: Set[str]) -> Optional[List[ast.Call]]:
    """BlockSpec calls in an in_specs/out_specs expression; None if opaque."""
    expr = _deref(expr, assigns)
    if isinstance(expr, (ast.List, ast.Tuple)):
        elts = expr.elts
    else:
        elts = [expr]
    out = []
    for e in elts:
        e = _deref(e, assigns)
        if isinstance(e, ast.Call) and isinstance(e.func, ast.Attribute) \
                and e.func.attr == "BlockSpec" and attr_root(e.func) in pl:
            out.append(e)
        else:
            return None
    return out


def _index_map_arity(spec: ast.Call, assigns: Dict[str, ast.AST],
                     defs: Dict[str, ast.FunctionDef],
                     ) -> Optional[Tuple[ast.AST, int, List[str]]]:
    """(node, arity, param names) of a BlockSpec's index map, if present."""
    im: Optional[ast.AST] = None
    if len(spec.args) >= 2:
        im = spec.args[1]
    for kw in spec.keywords:
        if kw.arg == "index_map":
            im = kw.value
    if im is None:
        return None
    if isinstance(im, ast.Name) and im.id in defs:
        fn = defs[im.id]
        params = [p.arg for p in fn.args.posonlyargs + fn.args.args]
        return fn, len(params), params
    im = _deref(im, assigns)
    if isinstance(im, ast.Lambda):
        params = [p.arg for p in im.args.posonlyargs + im.args.args]
        return im, len(params), params
    if isinstance(im, ast.Name) and im.id in defs:
        fn = defs[im.id]
        params = [p.arg for p in fn.args.posonlyargs + fn.args.args]
        return fn, len(params), params
    return None


def _resolve_kernel(expr: ast.AST, assigns: Dict[str, ast.AST],
                    module: _KernelsModule,
                    ) -> Tuple[Optional[ast.FunctionDef], str]:
    expr = _deref(expr, assigns)
    if isinstance(expr, ast.Call):
        name = dotted_name(expr.func)
        if name in ("functools.partial", "partial") and expr.args:
            expr = _deref(expr.args[0], assigns)
    if isinstance(expr, ast.Name):
        fn = module.defs.get(expr.id)
        return fn, expr.id
    return None, "<kernel>"


def _const_int(expr: ast.AST) -> Optional[int]:
    if isinstance(expr, ast.Constant) and isinstance(expr.value, int):
        return expr.value
    return None


def _grid_len(expr: ast.AST, assigns: Dict[str, ast.AST]) -> Optional[int]:
    expr = _deref(expr, assigns)
    if isinstance(expr, (ast.Tuple, ast.List)):
        return len(expr.elts)
    return None


def _geometry(call: ast.Call, wrapper: ast.FunctionDef,
              module: _KernelsModule) -> Optional[_Geometry]:
    assigns = _local_assigns(wrapper)
    g = _Geometry()
    g.call = call
    if call.args:
        g.kernel, g.kernel_name = _resolve_kernel(call.args[0], assigns,
                                                  module)
    kwargs = {kw.arg: kw.value for kw in call.keywords if kw.arg}
    spec_src = kwargs
    if "grid_spec" in kwargs:
        gs = _deref(kwargs["grid_spec"], assigns)
        if not (isinstance(gs, ast.Call)
                and isinstance(gs.func, ast.Attribute)
                and gs.func.attr == "PrefetchScalarGridSpec"
                and attr_root(gs.func) in module.pltpu):
            return None
        spec_src = {kw.arg: kw.value for kw in gs.keywords if kw.arg}
        npf = spec_src.get("num_scalar_prefetch")
        g.num_prefetch = _const_int(npf) if npf is not None else 0
        if g.num_prefetch is None:
            return None
    if "grid" in spec_src:
        g.grid_len = _grid_len(spec_src["grid"], assigns)
    for key, dest in (("in_specs", "in_specs"), ("out_specs", "out_specs")):
        if key in spec_src:
            specs = _spec_list(spec_src[key], assigns, module.pl)
            if specs is None:
                return None
            setattr(g, dest, specs)
    if "scratch_shapes" in spec_src:
        g.has_scratch_kw = True
        sc = _deref(spec_src["scratch_shapes"], assigns)
        if isinstance(sc, (ast.List, ast.Tuple)):
            g.scratch = list(sc.elts)
        else:
            return None
    return g


def _prefetch_guard_ok(g: _Geometry, assigns: Dict[str, ast.AST],
                       defs: Dict[str, ast.FunctionDef],
                       pl: Set[str]) -> Optional[bool]:
    """None = check not applicable; True/False = guard present/missing."""
    if g.num_prefetch <= 0 or g.kernel is None or g.grid_len is None:
        return None
    # does any index map subscript a prefetch operand?
    uses_prefetch = False
    for spec in g.in_specs + g.out_specs:
        im = _index_map_arity(spec, assigns, defs)
        if im is None:
            continue
        node, _arity, params = im
        pf_params = set(params[g.grid_len:])
        if not pf_params:
            continue
        body = node.body if isinstance(node, ast.Lambda) else node
        for sub in ast.walk(body):
            if isinstance(sub, ast.Subscript) and \
                    isinstance(sub.value, ast.Name) and \
                    sub.value.id in pf_params:
                uses_prefetch = True
    if not uses_prefetch:
        return None
    # prefetch refs are the kernel's first num_prefetch positional params
    ka = g.kernel.args
    kparams = [p.arg for p in ka.posonlyargs + ka.args]
    pf_refs = set(kparams[:g.num_prefetch])
    if not pf_refs:
        return False
    # names read from a prefetch ref inside the kernel body
    derived: Set[str] = set(pf_refs)
    for sub in ast.walk(g.kernel):
        if isinstance(sub, ast.Assign) and len(sub.targets) == 1 and \
                isinstance(sub.targets[0], ast.Name):
            for inner in ast.walk(sub.value):
                if isinstance(inner, ast.Subscript) and \
                        isinstance(inner.value, ast.Name) and \
                        inner.value.id in derived:
                    derived.add(sub.targets[0].id)
    # a pl.when(...) whose test mentions a derived name
    whens: List[ast.Call] = []
    for sub in ast.walk(g.kernel):
        if isinstance(sub, ast.Call) and \
                isinstance(sub.func, ast.Attribute) and \
                sub.func.attr == "when" and attr_root(sub.func) in pl:
            whens.append(sub)
        elif isinstance(sub, ast.FunctionDef):
            for dec in sub.decorator_list:
                if isinstance(dec, ast.Call) and \
                        isinstance(dec.func, ast.Attribute) and \
                        dec.func.attr == "when" and \
                        attr_root(dec.func) in pl:
                    whens.append(dec)
    for w in whens:
        for arg in w.args:
            for inner in ast.walk(arg):
                if isinstance(inner, ast.Name) and inner.id in derived:
                    return True
    return False


def check(project: Project, graph=None) -> List[Finding]:
    findings: List[Finding] = []
    seen: Set[str] = set()

    def emit(f: Finding) -> None:
        if f.fingerprint not in seen:
            seen.add(f.fingerprint)
            findings.append(f)

    for f in project.files:
        if f.tree is None or "/kernels/" not in f.path:
            continue
        if f.path.rsplit("/", 1)[-1] in _SKIP_BASES:
            continue
        module = _KernelsModule(f)
        if not module.pl:
            continue
        for wrapper in module.defs.values():
            assigns = _local_assigns(wrapper)
            defs = {**module.defs, **_local_defs(wrapper)}
            for sub in ast.walk(wrapper):
                if not (isinstance(sub, ast.Call)
                        and isinstance(sub.func, ast.Attribute)
                        and sub.func.attr == "pallas_call"
                        and attr_root(sub.func) in module.pl):
                    continue
                g = _geometry(sub, wrapper, module)
                if g is None:
                    continue
                _check_site(f, g, assigns, defs, module, emit)
    return findings


def _check_site(f: SourceFile, g: _Geometry, assigns, defs,
                module: _KernelsModule, emit) -> None:
    kname = g.kernel_name
    # (a) index-map arity vs grid + prefetch
    if g.grid_len is not None:
        expected = g.grid_len + g.num_prefetch
        for spec in g.in_specs + g.out_specs:
            im = _index_map_arity(spec, assigns, defs)
            if im is None:
                continue
            node, arity, _params = im
            if arity != expected:
                emit(Finding(
                    rule=RULE_ID, path=f.path, line=node.lineno,
                    col=node.col_offset,
                    message=(f"BlockSpec index map for `{kname}` takes "
                             f"{arity} args, expected {expected} (grid "
                             f"{g.grid_len} + {g.num_prefetch} "
                             f"scalar-prefetch)"),
                    symbol=f"kernels.{kname}.index-map-arity.{arity}"))
    # (b) kernel positional signature
    if g.kernel is not None and g.in_specs and g.out_specs:
        ka = g.kernel.args
        actual = len(ka.posonlyargs + ka.args)
        expected = (g.num_prefetch + len(g.in_specs) + len(g.out_specs)
                    + len(g.scratch))
        if actual != expected:
            emit(Finding(
                rule=RULE_ID, path=f.path, line=g.kernel.lineno, col=0,
                message=(f"kernel `{kname}` takes {actual} positional "
                         f"refs, expected {expected} "
                         f"({g.num_prefetch} prefetch + "
                         f"{len(g.in_specs)} in + {len(g.out_specs)} out "
                         f"+ {len(g.scratch)} scratch)"),
                symbol=f"kernels.{kname}.signature"))
    # (c) scratch dtype explicitness
    for entry in g.scratch:
        if isinstance(entry, ast.Call) and \
                isinstance(entry.func, ast.Attribute) and \
                entry.func.attr == "VMEM" and \
                attr_root(entry.func) in module.pltpu:
            dt: Optional[ast.AST] = entry.args[1] if len(entry.args) >= 2 \
                else None
            for kw in entry.keywords:
                if kw.arg == "dtype":
                    dt = kw.value
            if not isinstance(dt, ast.Attribute):
                emit(Finding(
                    rule=RULE_ID, path=f.path, line=entry.lineno,
                    col=entry.col_offset,
                    message=(f"scratch buffer of `{kname}` lacks an "
                             f"explicit dotted dtype (e.g. `jnp.float32`)"),
                    symbol=f"kernels.{kname}.scratch-dtype"))
    # (d) pl.when guard over prefetched-table loads
    ok = _prefetch_guard_ok(g, assigns, defs, module.pl)
    if ok is False:
        emit(Finding(
            rule=RULE_ID, path=f.path,
            line=g.kernel.lineno if g.kernel else
            (g.call.lineno if g.call else 1),
            col=0,
            message=(f"kernel `{kname}` indexes a scalar-prefetch table in "
                     f"an index map but has no `pl.when` guard on the "
                     f"prefetched value (sentinel blocks would be read)"),
            symbol=f"kernels.{kname}.prefetch-guard"))
