"""Project-wide call graph with class/method resolution (stdlib-only).

Built once per lint run and shared by every rule.  Nodes are top-level
functions and class methods; edges are resolved call sites.  Resolution
is deliberately conservative -- only calls we can pin to a project
definition become edges:

* ``self.m(...)``            -- method of the enclosing class or a base;
* ``self.field.m(...)``      -- via field-type inference (``self.field =
  ClassName(...)`` anywhere in the class, or an annotated ``__init__``
  parameter stored into the field);
* ``name(...)``              -- same-module def, imported symbol (one
  re-export chase through ``__init__`` modules, depth-limited), or a
  class constructor (edge lands on ``__init__``);
* ``alias.name(...)``        -- through a module import alias.

Calls written inside nested ``def``/``lambda`` bodies are attributed to
the enclosing top-level function (an over-approximation: the closure
*may* run there), but with ``locked=False`` -- the closure may also run
after the ``with self._lock`` block exits.

On top of the graph, three marker fixpoints (all monotone -- they only
ever add):

* :func:`propagate_all_callers` -- a function inherits a marker
  (``engine-thread-only``) when every known caller carries it;
* :func:`propagate_holds` -- a function holds ``_lock`` when every
  inbound edge is either lexically under ``with self._lock`` or comes
  from a holder;
* :func:`propagate_reachable` -- forward closure (``hot-path``) from
  explicitly marked seeds, not descending into jitted callees (those
  run on device and are RL002's problem).
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, Iterable, List, Optional, Set, Tuple

from .core import Project, SourceFile, dotted_name
from .purity import _jit_decoration

_REEXPORT_DEPTH = 4


@dataclasses.dataclass
class CallSite:
    caller: "FuncInfo"
    callee: "FuncInfo"
    node: ast.Call
    locked: bool          # lexically under ``with self._lock`` in the caller


class FuncInfo:
    def __init__(self, path: str, module: str, cls: Optional[str],
                 node: ast.FunctionDef, file: SourceFile):
        self.path = path
        self.module = module
        self.cls = cls                      # class name or None
        self.name = node.name
        self.qualname = f"{cls}.{node.name}" if cls else node.name
        self.fid = f"{path}::{self.qualname}"
        self.node = node
        self.file = file
        self.markers: Set[str] = file.markers_for_def(node)
        self.is_jit = any(_jit_decoration(d) for d in node.decorator_list)
        self.calls: List[CallSite] = []     # outgoing
        self.callers: List[CallSite] = []   # incoming


class _Class:
    def __init__(self, module: "_Module", node: ast.ClassDef):
        self.module = module
        self.node = node
        self.name = node.name
        self.methods: Dict[str, FuncInfo] = {}
        self.base_names: List[str] = [
            n for n in (dotted_name(b) for b in node.bases) if n]
        self.field_types: Dict[str, str] = {}   # self.X -> class name (unresolved)


class _Module:
    def __init__(self, name: str, file: SourceFile, is_pkg: bool):
        self.name = name
        self.file = file
        self.is_pkg = is_pkg
        self.defs: Dict[str, FuncInfo] = {}
        self.classes: Dict[str, _Class] = {}
        # alias -> (module dotted name, symbol or None for module imports)
        self.imports: Dict[str, Tuple[str, Optional[str]]] = {}


def _module_name(path: str, src_rel: str) -> Tuple[str, bool]:
    """(dotted module name, is_package) for a repo-relative source path."""
    first = src_rel.split("/", 1)[0]
    p = path
    if p.startswith(first + "/"):
        p = p[len(first) + 1:]
    if p.endswith(".py"):
        p = p[:-3]
    is_pkg = p.endswith("/__init__") or p == "__init__"
    if is_pkg:
        p = p[:-len("/__init__")] if "/" in p else ""
    return p.replace("/", "."), is_pkg


class CallGraph:
    def __init__(self) -> None:
        self.functions: List[FuncInfo] = []
        self.by_fid: Dict[str, FuncInfo] = {}
        self.modules: Dict[str, _Module] = {}
        self._by_node: Dict[int, FuncInfo] = {}      # id(def node) -> info
        self.call_by_node: Dict[int, CallSite] = {}  # id(call node) -> site

    def func_for(self, node: ast.AST) -> Optional[FuncInfo]:
        return self._by_node.get(id(node))

    # -- symbol resolution ---------------------------------------------------
    def _resolve_symbol(self, module: _Module, name: str,
                        depth: int = 0) -> Optional[object]:
        """FuncInfo or _Class that ``name`` denotes inside ``module``."""
        if name in module.defs:
            return module.defs[name]
        if name in module.classes:
            return module.classes[name]
        imp = module.imports.get(name)
        if imp is None or depth >= _REEXPORT_DEPTH:
            return None
        mod_name, sym = imp
        target = self.modules.get(mod_name)
        if target is None:
            return None
        if sym is None:
            return target                    # a module alias
        return self._resolve_symbol(target, sym, depth + 1)

    def _resolve_class(self, module: _Module, name: str) -> Optional[_Class]:
        hit = self._resolve_symbol(module, name)
        return hit if isinstance(hit, _Class) else None

    def _method_of(self, cls: _Class, name: str,
                   depth: int = 0) -> Optional[FuncInfo]:
        if name in cls.methods:
            return cls.methods[name]
        if depth >= _REEXPORT_DEPTH:
            return None
        for base in cls.base_names:
            b = self._resolve_class(cls.module, base)
            if b is not None:
                hit = self._method_of(b, name, depth + 1)
                if hit is not None:
                    return hit
        return None

    def _constructor(self, cls: _Class) -> Optional[FuncInfo]:
        return self._method_of(cls, "__init__")


def build(project: Project) -> CallGraph:
    g = CallGraph()
    # pass 1: modules, defs, classes, imports
    for f in project.files:
        if f.tree is None:
            continue
        mod_name, is_pkg = _module_name(f.path, project.src_rel)
        m = _Module(mod_name, f, is_pkg)
        g.modules[mod_name] = m
        for node in f.tree.body:
            if isinstance(node, ast.FunctionDef):
                fi = FuncInfo(f.path, mod_name, None, node, f)
                m.defs[node.name] = fi
                g.functions.append(fi)
                g.by_fid[fi.fid] = fi
                g._by_node[id(node)] = fi
            elif isinstance(node, ast.ClassDef):
                ci = _Class(m, node)
                m.classes[node.name] = ci
                for item in node.body:
                    if isinstance(item, ast.FunctionDef):
                        fi = FuncInfo(f.path, mod_name, node.name, item, f)
                        ci.methods[item.name] = fi
                        g.functions.append(fi)
                        g.by_fid[fi.fid] = fi
                        g._by_node[id(item)] = fi
            elif isinstance(node, ast.Import):
                for alias in node.names:
                    bound = alias.asname or alias.name.split(".", 1)[0]
                    target = alias.name if alias.asname else \
                        alias.name.split(".", 1)[0]
                    m.imports[bound] = (target, None)
            elif isinstance(node, ast.ImportFrom):
                base = _import_base(m, node)
                if base is None:
                    continue
                for alias in node.names:
                    if alias.name == "*":
                        continue
                    m.imports[alias.asname or alias.name] = (base, alias.name)
    # pass 2: field types (needs the class tables)
    for m in g.modules.values():
        for ci in m.classes.values():
            _collect_field_types(g, ci)
    # pass 3: call sites
    for m in g.modules.values():
        for fi in list(m.defs.values()):
            _collect_calls(g, m, None, fi)
        for ci in m.classes.values():
            for fi in ci.methods.values():
                _collect_calls(g, m, ci, fi)
    return g


def _import_base(m: _Module, node: ast.ImportFrom) -> Optional[str]:
    """Absolute dotted module a ``from X import ...`` refers to."""
    if node.level == 0:
        return node.module
    parts = m.name.split(".") if m.name else []
    if not m.is_pkg:
        parts = parts[:-1]
    hops = node.level - 1
    if hops:
        if hops > len(parts):
            return None
        parts = parts[:-hops] if hops else parts
    if node.module:
        parts = parts + node.module.split(".")
    return ".".join(parts) if parts else None


def _collect_field_types(g: CallGraph, ci: _Class) -> None:
    ann_params: Dict[str, str] = {}
    init = ci.methods.get("__init__")
    if init is not None:
        a = init.node.args
        for p in a.posonlyargs + a.args + a.kwonlyargs:
            if p.annotation is not None:
                nm = dotted_name(p.annotation)
                if nm:
                    ann_params[p.arg] = nm.rsplit(".", 1)[-1]
    for fi in ci.methods.values():
        for sub in ast.walk(fi.node):
            if not isinstance(sub, ast.Assign):
                continue
            for t in sub.targets:
                if not (isinstance(t, ast.Attribute)
                        and isinstance(t.value, ast.Name)
                        and t.value.id == "self"):
                    continue
                v = sub.value
                if isinstance(v, ast.Call):
                    nm = dotted_name(v.func)
                    if nm:
                        cand = nm.rsplit(".", 1)[-1]
                        if g._resolve_class(ci.module, cand) is not None \
                                or cand in ci.module.classes:
                            ci.field_types.setdefault(t.attr, cand)
                elif isinstance(v, ast.Name) and fi.name == "__init__" \
                        and v.id in ann_params:
                    ci.field_types.setdefault(t.attr, ann_params[v.id])


class _CallCollector(ast.NodeVisitor):
    def __init__(self, g: CallGraph, m: _Module, cls: Optional[_Class],
                 fi: FuncInfo):
        self.g = g
        self.m = m
        self.cls = cls
        self.fi = fi
        self.lock_depth = 0
        self.fn_depth = 0

    def visit_With(self, node: ast.With) -> None:
        takes = any(isinstance(i.context_expr, ast.Attribute)
                    and i.context_expr.attr == "_lock"
                    and isinstance(i.context_expr.value, ast.Name)
                    and i.context_expr.value.id == "self"
                    for i in node.items)
        if takes:
            self.lock_depth += 1
            self.generic_visit(node)
            self.lock_depth -= 1
        else:
            self.generic_visit(node)

    def _enter_fn(self, node: ast.AST) -> None:
        # nested def/lambda: calls attributed here, but the closure may run
        # without the lock
        self.fn_depth += 1
        saved, self.lock_depth = self.lock_depth, 0
        self.generic_visit(node)
        self.lock_depth = saved
        self.fn_depth -= 1

    visit_FunctionDef = _enter_fn
    visit_AsyncFunctionDef = _enter_fn
    visit_Lambda = _enter_fn

    def visit_Call(self, node: ast.Call) -> None:
        callee = self._resolve(node.func)
        if callee is not None and callee is not self.fi:
            site = CallSite(caller=self.fi, callee=callee, node=node,
                            locked=self.lock_depth > 0)
            self.fi.calls.append(site)
            callee.callers.append(site)
            self.g.call_by_node[id(node)] = site
        self.generic_visit(node)

    def _resolve(self, func: ast.AST) -> Optional[FuncInfo]:
        g, m = self.g, self.m
        if isinstance(func, ast.Name):
            hit = g._resolve_symbol(m, func.id)
            if isinstance(hit, FuncInfo):
                return hit
            if isinstance(hit, _Class):
                return g._constructor(hit)
            return None
        if not isinstance(func, ast.Attribute):
            return None
        recv = func.value
        # self.m(...)
        if isinstance(recv, ast.Name) and recv.id == "self":
            if self.cls is not None:
                return g._method_of(self.cls, func.attr)
            return None
        # self.field.m(...)
        if isinstance(recv, ast.Attribute) and \
                isinstance(recv.value, ast.Name) and recv.value.id == "self":
            if self.cls is not None:
                tname = self.cls.field_types.get(recv.attr)
                if tname:
                    tcls = g._resolve_class(m, tname) or \
                        m.classes.get(tname)
                    if tcls is not None:
                        return g._method_of(tcls, func.attr)
            return None
        # alias.name(...)
        if isinstance(recv, ast.Name):
            imp = m.imports.get(recv.id)
            if imp is not None and imp[1] is None:
                target = g.modules.get(imp[0])
                if target is not None:
                    hit = g._resolve_symbol(target, func.attr)
                    if isinstance(hit, FuncInfo):
                        return hit
                    if isinstance(hit, _Class):
                        return g._constructor(hit)
        return None


def _collect_calls(g: CallGraph, m: _Module, cls: Optional[_Class],
                   fi: FuncInfo) -> None:
    col = _CallCollector(g, m, cls, fi)
    for stmt in fi.node.body:
        col.visit(stmt)


# --------------------------------------------------------------------------
# marker fixpoints
# --------------------------------------------------------------------------
def propagate_all_callers(graph: CallGraph, marker: str) -> Set[str]:
    """Fids carrying ``marker`` explicitly or because *every* caller does."""
    marked = {f.fid for f in graph.functions if marker in f.markers}
    changed = True
    while changed:
        changed = False
        for f in graph.functions:
            if f.fid in marked or not f.callers:
                continue
            if all(s.caller.fid in marked for s in f.callers):
                marked.add(f.fid)
                changed = True
    return marked


def propagate_holds(graph: CallGraph) -> Set[str]:
    """Fids that hold ``_lock``: explicit ``holds=_lock`` markers, plus
    functions whose every inbound edge is lexically locked or comes from
    a holder."""
    holders = {f.fid for f in graph.functions if "holds=_lock" in f.markers}
    changed = True
    while changed:
        changed = False
        for f in graph.functions:
            if f.fid in holders or not f.callers:
                continue
            if all(s.locked or s.caller.fid in holders for s in f.callers):
                holders.add(f.fid)
                changed = True
    return holders


def propagate_reachable(graph: CallGraph, marker: str) -> Set[str]:
    """Forward closure from ``marker`` seeds, skipping jitted callees."""
    seeds = [f for f in graph.functions if marker in f.markers]
    reach = {f.fid for f in seeds}
    work = list(seeds)
    while work:
        f = work.pop()
        for s in f.calls:
            if s.callee.is_jit:
                continue
            if s.callee.fid not in reach:
                reach.add(s.callee.fid)
                work.append(s.callee)
    return reach
