"""Committed-baseline support: grandfather findings without hiding new ones.

The baseline maps finding fingerprints (stable across line-number churn;
see :class:`tools.analyze.core.Finding`) to a context record so humans
can audit what was grandfathered.  ``repro-lint --write-baseline``
regenerates it; findings absent from the baseline fail the run.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Tuple

from .core import Finding

BASELINE_VERSION = 1


def load(path: Path) -> Dict[str, dict]:
    if not path.is_file():
        return {}
    data = json.loads(path.read_text())
    if data.get("version") != BASELINE_VERSION:
        raise ValueError(f"unsupported baseline version in {path}")
    return dict(data.get("findings", {}))


def save(path: Path, findings: List[Finding]) -> None:
    body = {
        "version": BASELINE_VERSION,
        "findings": {
            f.fingerprint: {
                "rule": f.rule, "path": f.path,
                "symbol": f.symbol, "message": f.message,
            }
            for f in sorted(findings, key=lambda x: (x.path, x.line, x.rule))
        },
    }
    path.write_text(json.dumps(body, indent=2, sort_keys=True) + "\n")


def split(findings: List[Finding], baseline: Dict[str, dict],
          ) -> Tuple[List[Finding], List[Finding], List[str]]:
    """(new, grandfathered, stale_baseline_fingerprints)."""
    new: List[Finding] = []
    old: List[Finding] = []
    live = set()
    for f in findings:
        if f.fingerprint in baseline:
            old.append(f)
            live.add(f.fingerprint)
        else:
            new.append(f)
    stale = [fp for fp in baseline if fp not in live]
    return new, old, stale
