"""RL004: wire stability -- error taxonomy, schema round-trips, handshakes.

Three sub-checks over the versioned JSON protocol:

(a) **Frozen error table** -- every ``ApiError`` subclass in
    ``api/errors.py`` must carry a literal ``code`` that is unique and
    maps onto exactly the HTTP status recorded in :data:`FROZEN_WIRE_V1`.
    Adding a wire code is a deliberate protocol change: extend the table
    here in the same commit (that's the point -- the analyzer makes the
    diff reviewable instead of silent).

(b) **Schema round-trips** -- every field of a wire dataclass in
    ``api/schemas.py`` (a ``@dataclass`` that defines ``to_json`` /
    ``from_json``) must appear in both methods, so nothing silently
    drops on one side of the wire.

(c) **Protocol handshake** -- every ``path == "/v1/..."`` branch in
    ``serve/server.py``'s ``do_POST`` must (transitively) call
    ``check_protocol`` or parse the body through a schema whose
    ``from_json`` does.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from .core import Finding, Project, SourceFile, dotted_name

RULE_ID = "RL004"

# The protocol-v1 error table. Frozen: drift between this and
# api/errors.py is an RL004 finding in either direction.
FROZEN_WIRE_V1: Dict[str, int] = {
    "empty_trajectory": 400,
    "too_long": 400,
    "ages_required": 400,
    "ages_length_mismatch": 400,
    "rng_not_serializable": 400,
    "unsupported_override": 400,
    "invalid_request": 400,
    "protocol_version_mismatch": 409,
    "unknown_endpoint": 404,
    "timeout": 504,
    "request_cancelled": 409,
    "replica_unavailable": 503,
    "internal": 500,
}

_ERRORS_SUFFIX = "api/errors.py"
_SCHEMAS_SUFFIX = "api/schemas.py"
_SERVER_SUFFIX = "serve/server.py"


# --------------------------------------------------------------------------
# (a) error taxonomy
# --------------------------------------------------------------------------
def _class_attr(node: ast.ClassDef, name: str):
    """(value_node, lineno) of a class-level ``name = ...`` / AnnAssign."""
    for item in node.body:
        if isinstance(item, ast.Assign):
            for t in item.targets:
                if isinstance(t, ast.Name) and t.id == name:
                    return item.value, item.lineno
        elif isinstance(item, ast.AnnAssign) and \
                isinstance(item.target, ast.Name) and \
                item.target.id == name and item.value is not None:
            return item.value, item.lineno
    return None, None


def _check_errors(f: SourceFile, findings: List[Finding]) -> None:
    classes = {n.name: n for n in f.tree.body if isinstance(n, ast.ClassDef)}

    def reaches_api_error(cls: ast.ClassDef, seen: Set[str]) -> bool:
        for b in cls.bases:
            bname = dotted_name(b)
            if bname == "ApiError":
                return True
            if bname in classes and bname not in seen:
                seen.add(bname)
                if reaches_api_error(classes[bname], seen):
                    return True
        return False

    def resolved(cls: ast.ClassDef, attr: str):
        """Walk the in-file MRO for a literal class attribute."""
        cur: Optional[ast.ClassDef] = cls
        while cur is not None:
            val, line = _class_attr(cur, attr)
            if val is not None:
                return val, line, cur.name
            nxt = None
            for b in cur.bases:
                bname = dotted_name(b)
                if bname in classes:
                    nxt = classes[bname]
                    break
            cur = nxt
        return None, None, None

    seen_codes: Dict[str, str] = {}   # code -> class name
    live: Dict[str, Tuple[int, str, int]] = {}  # code -> (status, cls, line)
    for name, cls in classes.items():
        if name == "ApiError" or not reaches_api_error(cls, set()):
            continue
        code_val, code_line, _ = resolved(cls, "code")
        status_val, _, _ = resolved(cls, "http_status")
        anchor = code_line or cls.lineno
        if not (isinstance(code_val, ast.Constant)
                and isinstance(code_val.value, str)):
            findings.append(Finding(
                rule=RULE_ID, path=f.path, line=anchor, col=0,
                message=f"`{name}.code` is not a string literal; wire codes "
                        f"must be statically auditable",
                symbol=f"errors.{name}.code"))
            continue
        code = code_val.value
        if not (isinstance(status_val, ast.Constant)
                and isinstance(status_val.value, int)):
            findings.append(Finding(
                rule=RULE_ID, path=f.path, line=anchor, col=0,
                message=f"`{name}.http_status` is not an int literal",
                symbol=f"errors.{name}.http_status"))
            continue
        status = status_val.value
        if code in seen_codes:
            findings.append(Finding(
                rule=RULE_ID, path=f.path, line=anchor, col=0,
                message=(f"wire code `{code}` registered by both "
                         f"`{seen_codes[code]}` and `{name}`; the registry "
                         f"must be 1:1"),
                symbol=f"errors.{name}.duplicate"))
            continue
        seen_codes[code] = name
        live[code] = (status, name, anchor)

    for code, (status, name, anchor) in sorted(live.items()):
        if code not in FROZEN_WIRE_V1:
            findings.append(Finding(
                rule=RULE_ID, path=f.path, line=anchor, col=0,
                message=(f"new wire code `{code}` ({name}) not in the frozen "
                         f"v1 table; extend FROZEN_WIRE_V1 in "
                         f"tools/analyze/wire.py deliberately"),
                symbol=f"errors.{name}.unfrozen"))
        elif FROZEN_WIRE_V1[code] != status:
            findings.append(Finding(
                rule=RULE_ID, path=f.path, line=anchor, col=0,
                message=(f"wire code `{code}` maps to HTTP {status} but the "
                         f"frozen v1 table says {FROZEN_WIRE_V1[code]}"),
                symbol=f"errors.{name}.status-drift"))
    for code in sorted(set(FROZEN_WIRE_V1) - set(live)):
        findings.append(Finding(
            rule=RULE_ID, path=f.path, line=1, col=0,
            message=(f"frozen wire code `{code}` has no ApiError subclass; "
                     f"removing a v1 code breaks deployed clients"),
            symbol=f"errors.{code}.removed"))


# --------------------------------------------------------------------------
# (b) schema round-trips
# --------------------------------------------------------------------------
def _is_dataclass_decorated(cls: ast.ClassDef) -> bool:
    for dec in cls.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        if dotted_name(target) in ("dataclass", "dataclasses.dataclass"):
            return True
    return False


def _method(cls: ast.ClassDef, name: str) -> Optional[ast.FunctionDef]:
    for item in cls.body:
        if isinstance(item, ast.FunctionDef) and item.name == name:
            return item
    return None


def _mentions_field(fn: ast.FunctionDef, field: str, *,
                    as_self_attr: bool) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Constant) and node.value == field:
            return True
        if as_self_attr and isinstance(node, ast.Attribute) \
                and node.attr == field \
                and isinstance(node.value, ast.Name) \
                and node.value.id == "self":
            return True
        if isinstance(node, ast.keyword) and node.arg == field:
            return True
    return False


def _check_schemas(f: SourceFile, findings: List[Finding]) -> Set[str]:
    """Returns the set of schema classes whose from_json checks protocol."""
    checking: Set[str] = set()
    for cls in f.tree.body:
        if not isinstance(cls, ast.ClassDef) or not _is_dataclass_decorated(cls):
            continue
        to_json = _method(cls, "to_json")
        from_json = _method(cls, "from_json")
        if to_json is None and from_json is None:
            continue   # not a wire type
        if to_json is None or from_json is None:
            missing = "to_json" if to_json is None else "from_json"
            findings.append(Finding(
                rule=RULE_ID, path=f.path, line=cls.lineno, col=0,
                message=f"wire dataclass `{cls.name}` lacks `{missing}`",
                symbol=f"schemas.{cls.name}.{missing}"))
            continue
        for node in ast.walk(from_json):
            if isinstance(node, ast.Call):
                nm = dotted_name(node.func)
                if nm and nm.split(".")[-1] == "check_protocol":
                    checking.add(cls.name)
        for item in cls.body:
            if not isinstance(item, ast.AnnAssign) or \
                    not isinstance(item.target, ast.Name):
                continue
            ann = ast.dump(item.annotation)
            if "ClassVar" in ann:
                continue
            field = item.target.id
            for fn, side, self_attr in ((to_json, "to_json", True),
                                        (from_json, "from_json", False)):
                if not _mentions_field(fn, field, as_self_attr=self_attr):
                    findings.append(Finding(
                        rule=RULE_ID, path=f.path, line=item.lineno, col=0,
                        message=(f"field `{cls.name}.{field}` does not appear "
                                 f"in `{side}`; wire fields must round-trip "
                                 f"on both sides"),
                        symbol=f"schemas.{cls.name}.{field}.{side}"))
    return checking


# --------------------------------------------------------------------------
# (c) protocol handshake in /v1/* POST handlers
# --------------------------------------------------------------------------
def _call_is_checking(call: ast.Call, checking_fns: Set[str],
                      checking_schemas: Set[str]) -> bool:
    func = call.func
    if isinstance(func, ast.Attribute) and func.attr == "from_json":
        recv = dotted_name(func.value)
        if recv and recv.split(".")[-1] in checking_schemas:
            return True
    nm = dotted_name(func)
    terminal = nm.split(".")[-1] if nm else None
    return terminal in checking_fns if terminal else False


def _check_server(f: SourceFile, checking_schemas: Set[str],
                  findings: List[Finding]) -> None:
    # fixpoint: a function in server.py "checks protocol" if its body calls
    # check_protocol, a checking schema's from_json, or another checking fn
    fns: Dict[str, List[ast.FunctionDef]] = {}
    for node in ast.walk(f.tree):
        if isinstance(node, ast.FunctionDef):
            fns.setdefault(node.name, []).append(node)
    checking_fns: Set[str] = {"check_protocol"}
    changed = True
    while changed:
        changed = False
        for name, defs in fns.items():
            if name in checking_fns:
                continue
            for d in defs:
                for node in ast.walk(d):
                    if isinstance(node, ast.Call) and _call_is_checking(
                            node, checking_fns, checking_schemas):
                        checking_fns.add(name)
                        changed = True
                        break
                if name in checking_fns:
                    break

    for post in fns.get("do_POST", []):
        for node in ast.walk(post):
            if not isinstance(node, ast.If):
                continue
            route = _v1_route(node.test)
            if route is None:
                continue
            ok = False
            for stmt in node.body:
                for sub in ast.walk(stmt):
                    if isinstance(sub, ast.Call) and _call_is_checking(
                            sub, checking_fns, checking_schemas):
                        ok = True
                        break
                if ok:
                    break
            if not ok:
                findings.append(Finding(
                    rule=RULE_ID, path=f.path, line=node.lineno, col=0,
                    message=(f"handler branch for `{route}` never checks "
                             f"`protocol_version` (no check_protocol / "
                             f"checking from_json on any call path)"),
                    symbol=f"server.do_POST.{route}"))


def _v1_route(test: ast.AST) -> Optional[str]:
    """`path == \"/v1/x\"` (either operand order) -> the route string."""
    if not isinstance(test, ast.Compare) or \
            not any(isinstance(op, ast.Eq) for op in test.ops):
        return None
    for node in [test.left] + list(test.comparators):
        if isinstance(node, ast.Constant) and isinstance(node.value, str) \
                and node.value.startswith("/v1/"):
            return node.value
    return None


def check(project: Project, graph=None) -> List[Finding]:
    findings: List[Finding] = []
    errors_f = project.find_suffix(_ERRORS_SUFFIX)
    if errors_f is not None and errors_f.tree is not None:
        _check_errors(errors_f, findings)
    checking_schemas: Set[str] = set()
    schemas_f = project.find_suffix(_SCHEMAS_SUFFIX)
    if schemas_f is not None and schemas_f.tree is not None:
        checking_schemas = _check_schemas(schemas_f, findings)
    server_f = project.find_suffix(_SERVER_SUFFIX)
    if server_f is not None and server_f.tree is not None:
        _check_server(server_f, checking_schemas, findings)
    return findings
