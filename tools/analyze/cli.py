"""Command-line front end for repro-lint (``python -m tools.analyze``)."""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional, Sequence

from . import baseline as baseline_mod
from . import callgraph as callgraph_mod
from . import hostsync, locks, pairing, purity, resources, wire
from . import pallas as pallas_mod
from .core import Finding, Project, apply_suppressions

RULES = {
    locks.RULE_ID: (locks.check, "lock discipline for guarded-by fields"),
    purity.RULE_ID: (purity.check, "trace purity in module-level jit fns"),
    pairing.RULE_ID: (pairing.check,
                      "kernel <-> ref.py oracle pairing + signature parity"),
    wire.RULE_ID: (wire.check, "wire protocol stability (errors/schemas/handlers)"),
    resources.RULE_ID: (resources.check,
                        "alloc/release discipline on all paths (block pool)"),
    hostsync.RULE_ID: (hostsync.check,
                       "no device->host syncs on the engine hot path"),
    pallas_mod.RULE_ID: (pallas_mod.check,
                         "Pallas grid/BlockSpec/scratch/guard geometry"),
}

DEFAULT_BASELINE = "tools/analyze/baseline.json"


@dataclasses.dataclass
class LintResult:
    new: List[Finding]
    grandfathered: List[Finding]
    stale_baseline: List[str]
    suppressed: int

    @property
    def exit_code(self) -> int:
        return 1 if self.new else 0


def run_lint(root: Path, *, select: Optional[Sequence[str]] = None,
             baseline_path: Optional[Path] = None,
             src_rel: str = "src/repro",
             tests_rel: str = "tests") -> LintResult:
    """Programmatic entry point (used by tests and the CLI)."""
    project = Project(root, src_rel=src_rel, tests_rel=tests_rel)
    graph = callgraph_mod.build(project)
    findings: List[Finding] = list(project.parse_errors())
    wanted = set(select) if select else set(RULES)
    for rule_id, (check, _) in RULES.items():
        if rule_id in wanted:
            findings.extend(check(project, graph))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    findings, suppressed = apply_suppressions(project, findings)
    base: Dict[str, dict] = {}
    if baseline_path is not None:
        base = baseline_mod.load(baseline_path)
    new, old, stale = baseline_mod.split(findings, base)
    return LintResult(new=new, grandfathered=old, stale_baseline=stale,
                      suppressed=suppressed)


def _emit(findings: List[Finding], fmt: str, out) -> None:
    if fmt == "json":
        print(json.dumps([f.to_dict() for f in findings], indent=2),
              file=out)
        return
    for f in findings:
        print(f.format_github() if fmt == "github" else f.format_text(),
              file=out)


def _fix_baseline(root: Path, res: LintResult,
                  target: Path) -> int:
    """Rewrite the baseline; print the fingerprint diff for PR review."""
    old = baseline_mod.load(target) if target.is_file() else {}
    current = res.new + res.grandfathered
    new_fps = {f.fingerprint: f for f in current}
    added = [fp for fp in new_fps if fp not in old]
    removed = [fp for fp in old if fp not in new_fps]
    for fp in sorted(added):
        f = new_fps[fp]
        print(f"+ {fp} {f.rule} {f.path} {f.symbol}")
    for fp in sorted(removed):
        rec = old[fp]
        print(f"- {fp} {rec.get('rule', '?')} {rec.get('path', '?')} "
              f"{rec.get('symbol', '?')}")
    baseline_mod.save(target, current)
    print(f"repro-lint: baseline rewritten at {target}: "
          f"{len(added)} added, {len(removed)} removed, "
          f"{len(new_fps)} total", file=sys.stderr)
    return 0


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-lint",
        description="AST-based invariant analyzer for this repo "
                    "(RL001 locks, RL002 trace purity, RL003 kernel/oracle "
                    "pairing, RL004 wire stability, RL005 resource "
                    "discipline, RL006 hot-path syncs, RL007 Pallas "
                    "geometry).")
    ap.add_argument("--root", type=Path, default=Path("."),
                    help="repository root (default: cwd)")
    ap.add_argument("--src", default="src/repro",
                    help="source subtree to analyze, relative to --root")
    ap.add_argument("--tests", default="tests",
                    help="test subtree (RL003 parity cross-check)")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule IDs (default: all)")
    ap.add_argument("--format", choices=("text", "json", "github"),
                    default="text")
    ap.add_argument("--baseline", type=Path, default=None,
                    help=f"baseline file (default: {DEFAULT_BASELINE} "
                         f"under --root, if present)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore any baseline file")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write all current findings to the baseline and exit 0")
    ap.add_argument("--fix-baseline", action="store_true",
                    help="rewrite the baseline and print the fingerprint "
                         "diff (+added/-removed) for PR review")
    ap.add_argument("--show-baselined", action="store_true",
                    help="also print grandfathered findings")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule_id, (_, desc) in sorted(RULES.items()):
            print(f"{rule_id}  {desc}")
        return 0

    select = args.select.split(",") if args.select else None
    root = args.root.resolve()
    baseline_path = args.baseline
    if baseline_path is None and not args.no_baseline:
        cand = root / DEFAULT_BASELINE
        if cand.is_file():
            baseline_path = cand
    elif args.no_baseline:
        baseline_path = None

    try:
        res = run_lint(root, select=select, baseline_path=baseline_path,
                       src_rel=args.src, tests_rel=args.tests)
    except (OSError, ValueError) as e:
        print(f"repro-lint: error: {e}", file=sys.stderr)
        return 2

    if args.fix_baseline:
        target = args.baseline or (root / DEFAULT_BASELINE)
        return _fix_baseline(root, res, target)

    if args.write_baseline:
        target = args.baseline or (root / DEFAULT_BASELINE)
        baseline_mod.save(target, res.new + res.grandfathered)
        print(f"repro-lint: wrote {len(res.new) + len(res.grandfathered)} "
              f"finding(s) to {target}")
        return 0

    _emit(res.new, args.format, sys.stdout)
    if args.show_baselined and res.grandfathered:
        print(f"-- {len(res.grandfathered)} baselined finding(s):")
        _emit(res.grandfathered, args.format, sys.stdout)
    if res.stale_baseline:
        print(f"repro-lint: note: {len(res.stale_baseline)} stale baseline "
              f"entr{'y' if len(res.stale_baseline) == 1 else 'ies'} no "
              f"longer fire(s); run --write-baseline to prune",
              file=sys.stderr)
    n_old = len(res.grandfathered)
    summary = (f"repro-lint: {len(res.new)} new finding(s), "
               f"{n_old} baselined, {res.suppressed} suppressed inline")
    print(summary, file=sys.stderr)
    return res.exit_code


if __name__ == "__main__":
    sys.exit(main())
