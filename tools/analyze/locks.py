"""RL001: lock discipline for annotated engine/pool/index fields.

A field declared in ``__init__`` with a trailing ``# guarded-by: _lock``
comment may only be read or written:

* lexically inside a ``with self._lock:`` block, or
* in a method carrying ``# repro-lint: holds=_lock`` (the caller owns the
  lock), or
* in a method carrying ``# repro-lint: engine-thread-only`` (only the
  single thread driving ``step()`` ever runs it).

A field declared ``# guarded-by: engine-thread`` is single-thread state:
it may only be touched in ``engine-thread-only`` methods.  ``__init__``
is always exempt (the object is not yet shared).

Both markers propagate through the call graph: a method whose *every*
known caller is ``engine-thread-only`` inherits the marker, and a
method reached only through ``with self._lock`` blocks (or from
``holds=_lock`` holders) counts as a holder -- so internal helpers no
longer need one annotation each.

Accesses to a guarded field name through anything other than ``self`` in
its declaring class ("foreign" accesses, e.g. ``eng.pending`` from an
HTTP handler) are flagged everywhere in the scanned tree, unless the
enclosing class declares a field of the same name itself.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from .core import Finding, Project, SourceFile

RULE_ID = "RL001"

GUARD_LOCK = "_lock"
GUARD_THREAD = "engine-thread"


class _ClassInfo:
    def __init__(self, file: SourceFile, node: ast.ClassDef):
        self.file = file
        self.node = node
        self.name = node.name
        self.guarded: Dict[str, str] = {}      # field -> guard kind
        self.own_fields: Set[str] = set()      # every self.X ever assigned
        self._collect()

    def _collect(self) -> None:
        for item in self.node.body:
            if not isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for sub in ast.walk(item):
                if isinstance(sub, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                    targets = (sub.targets if isinstance(sub, ast.Assign)
                               else [sub.target])
                    for t in targets:
                        for field in _self_fields(t):
                            self.own_fields.add(field)
                            if item.name != "__init__":
                                continue
                            # the annotation may sit on any line of a
                            # multi-line declaration
                            end = getattr(sub, "end_lineno", sub.lineno)
                            for ln in range(sub.lineno, (end or sub.lineno) + 1):
                                guard = self.file.guard_for_line(ln)
                                if guard:
                                    self.guarded[field] = guard
                                    break


def _self_fields(target: ast.AST) -> List[str]:
    """Field names from an assignment target rooted at ``self``."""
    out: List[str] = []
    if isinstance(target, ast.Attribute) and \
            isinstance(target.value, ast.Name) and target.value.id == "self":
        out.append(target.attr)
    elif isinstance(target, (ast.Tuple, ast.List)):
        for elt in target.elts:
            out.extend(_self_fields(elt))
    return out


def _is_self_lock(expr: ast.AST) -> bool:
    """``self._lock`` (the guard object) as a with-item context."""
    return (isinstance(expr, ast.Attribute) and expr.attr == "_lock"
            and isinstance(expr.value, ast.Name) and expr.value.id == "self")


class _MethodChecker(ast.NodeVisitor):
    """Walk one method; report guarded self.X accesses outside the lock."""

    def __init__(self, cls: _ClassInfo, method: ast.FunctionDef,
                 markers: Set[str], findings: List[Finding]):
        self.cls = cls
        self.method = method
        self.markers = markers
        self.findings = findings
        self.lock_depth = 0
        # A nested def/lambda runs later, possibly without the lock: being
        # lexically inside the with-block proves nothing, so the guard
        # context resets at function boundaries.
        self.fn_depth = 0

    def visit_With(self, node: ast.With) -> None:
        takes_lock = any(_is_self_lock(item.context_expr)
                         for item in node.items)
        if takes_lock:
            self.lock_depth += 1
            self.generic_visit(node)
            self.lock_depth -= 1
        else:
            self.generic_visit(node)

    def _enter_fn(self, node: ast.AST) -> None:
        self.fn_depth += 1
        saved = self.lock_depth
        self.lock_depth = 0
        self.generic_visit(node)
        self.lock_depth = saved
        self.fn_depth -= 1

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._enter_fn(node)

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self._enter_fn(node)

    def visit_Lambda(self, node: ast.Lambda) -> None:
        self._enter_fn(node)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        if isinstance(node.value, ast.Name) and node.value.id == "self" \
                and node.attr in self.cls.guarded:
            guard = self.cls.guarded[node.attr]
            if not self._access_ok(guard):
                kind = ("outside `with self._lock`" if guard == GUARD_LOCK
                        else "outside the engine thread")
                self.findings.append(Finding(
                    rule=RULE_ID, path=self.cls.file.path,
                    line=node.lineno, col=node.col_offset,
                    message=(f"`self.{node.attr}` (guarded-by: {guard}) "
                             f"accessed {kind} in "
                             f"`{self.cls.name}.{self.method.name}`"),
                    symbol=f"{self.cls.name}.{self.method.name}.{node.attr}"))
        self.generic_visit(node)

    def _access_ok(self, guard: str) -> bool:
        if self.method.name == "__init__":
            return True
        if guard == GUARD_LOCK:
            return (self.lock_depth > 0
                    or "holds=_lock" in self.markers
                    or "engine-thread-only" in self.markers)
        if guard == GUARD_THREAD:
            return "engine-thread-only" in self.markers
        return True  # unknown guard kinds are declarations-only


class _ForeignChecker(ast.NodeVisitor):
    """Flag ``anything_but_self.<guarded-field>`` across the whole tree."""

    def __init__(self, file: SourceFile, registry: Dict[str, List[str]],
                 findings: List[Finding]):
        self.file = file
        self.registry = registry
        self.findings = findings
        self.class_stack: List[_ClassInfo] = []
        self.fn_stack: List[str] = []

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self.class_stack.append(_ClassInfo(self.file, node))
        self.generic_visit(node)
        self.class_stack.pop()

    def _visit_fn(self, node) -> None:
        self.fn_stack.append(node.name)
        self.generic_visit(node)
        self.fn_stack.pop()

    visit_FunctionDef = _visit_fn
    visit_AsyncFunctionDef = _visit_fn

    def visit_Attribute(self, node: ast.Attribute) -> None:
        field = node.attr
        owners = self.registry.get(field)
        if owners:
            base_is_self = (isinstance(node.value, ast.Name)
                            and node.value.id == "self")
            cls = self.class_stack[-1] if self.class_stack else None
            if base_is_self:
                pass  # declaring/owning classes handled by _MethodChecker
            elif cls is not None and field in cls.own_fields \
                    and cls.name not in owners:
                pass  # same-named private field of an unrelated class
            else:
                where = ".".join(self.fn_stack) or "<module>"
                scope = f"{cls.name}.{where}" if cls else where
                self.findings.append(Finding(
                    rule=RULE_ID, path=self.file.path,
                    line=node.lineno, col=node.col_offset,
                    message=(f"foreign access to `{field}` (guarded field of "
                             f"{'/'.join(owners)}) from `{scope}`; go through "
                             f"a locked accessor instead"),
                    symbol=f"{scope}.{field}"))
        self.generic_visit(node)


def check(project: Project, graph=None) -> List[Finding]:
    findings: List[Finding] = []
    classes: List[_ClassInfo] = []
    for f in project.files:
        if f.tree is None:
            continue
        for node in ast.walk(f.tree):
            if isinstance(node, ast.ClassDef):
                info = _ClassInfo(f, node)
                if info.guarded:
                    classes.append(info)

    # call-graph-derived markers (transitive callees of annotated methods)
    derived_eng: Set[str] = set()
    derived_holds: Set[str] = set()
    if graph is not None:
        from .callgraph import propagate_all_callers, propagate_holds
        derived_eng = propagate_all_callers(graph, "engine-thread-only")
        derived_holds = propagate_holds(graph)

    # pass 1: in-class discipline
    for cls in classes:
        for item in cls.node.body:
            if not isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            markers = set(cls.file.markers_for_def(item))
            if graph is not None:
                fi = graph.func_for(item)
                if fi is not None:
                    if fi.fid in derived_eng:
                        markers.add("engine-thread-only")
                    if fi.fid in derived_holds:
                        markers.add("holds=_lock")
            _MethodChecker(cls, item, markers, findings).visit(item)

    # pass 2: foreign accesses anywhere in the scanned tree
    registry: Dict[str, List[str]] = {}
    for cls in classes:
        for field in cls.guarded:
            registry.setdefault(field, []).append(cls.name)
    if registry:
        for f in project.files:
            if f.tree is not None:
                _ForeignChecker(f, registry, findings).visit(f.tree)
    return findings
