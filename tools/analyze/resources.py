"""RL005: path-sensitive alloc/release discipline for block resources.

Acquisitions tracked:

* ``x = <pool>.alloc(n)``      -- fresh blocks (handle keyed ``x``);
* ``<pool>.share(blocks)``     -- a refcount increment (handle keyed by
  the argument's root names);
* ``x = self._entries.pop(k)`` -- removing a ref-holding ``PrefixIndex``
  entry (its blocks are now owned by the popped value).

``<pool>`` matches by resolution first -- a call-graph edge landing in
``SharedBlockPool`` / ``BlockAllocator`` -- with a receiver-name
fallback (``pool``/``allocator``/``_pool``/``_allocator``) for fields
the type inference cannot pin.

A handle dies when it is *released* (``.release(x)`` / ``.free(x)``),
*transferred* (stored into ``self.*`` state, appended to a self-rooted
container, returned, passed to a callee marked ``# repro-lint:
transfers-ownership``, or covered by a statement-level marker), or
*refined away* (the ``x is None`` branch of a failed allocation).
Aliasing (``blocks_j = hits + alloc``, ``e = _Entry(..., blocks, ...)``)
is handled by flow-insensitive *carrier sets*: releasing or
transferring a value kills every handle whose root names it carries.

A finding is one handle that can escape on a raising path (or a normal
exit) while still live.  Exception edges carry the state *before* the
raising statement -- except for release calls, which count as released
on their own raise edge.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, FrozenSet, List, Optional, Set, Tuple

from . import callgraph as callgraph_mod
from .cfg import Flow
from .core import _LINT_RE, Finding, Project, attr_root, dotted_name

RULE_ID = "RL005"

POOL_CLASSES = {"SharedBlockPool", "BlockAllocator"}
ACQUIRE_METHODS = {"alloc", "share"}
RELEASE_METHODS = {"release", "free"}
RECV_NAME_FALLBACK = {"pool", "allocator", "_pool", "_allocator"}
REF_CONTAINERS = {"_entries"}
TRANSFER_MARK = "transfers-ownership"

# calls that cannot raise in practice (so a live handle across them is
# not an escape path) -- deliberately excludes `.pop` and `.index`
SAFE_FUNCS = {"len", "list", "tuple", "dict", "set", "min", "max", "sum",
              "sorted", "zip", "enumerate", "range", "isinstance", "id",
              "str", "repr", "bool", "abs", "int", "float", "frozenset"}
SAFE_METHODS = {"get", "append", "extend", "copy", "items", "keys",
                "values", "add", "update", "discard", "clear",
                "setdefault", "insert"}
# safe self-rooted container mutators that adopt their argument
ADOPT_METHODS = {"append", "extend", "add", "insert", "setdefault",
                 "update"}


@dataclasses.dataclass(frozen=True)
class Handle:
    names: FrozenSet[str]
    desc: str          # e.g. "self.pool.share"
    line: int
    col: int


@dataclasses.dataclass(frozen=True)
class State:
    """Live handles plus path predicates over stable ``self.X`` tests.

    ``facts`` remembers which branch of an attribute-truthiness test
    (``if self.paged:``) this path took, so a later test of the same
    attribute prunes the contradictory branch -- the pattern behind
    "acquire under ``if self.paged``, release in a ``finally`` under the
    same test"."""
    handles: FrozenSet[Handle] = frozenset()
    facts: FrozenSet[Tuple[str, bool]] = frozenset()


def _roots(node: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(node)
            if isinstance(n, ast.Name)} - {"self"}


def _self_rooted(node: ast.AST) -> bool:
    """``self.x``, ``self.x[i]``, ``self.x[i].y`` ... rooted at ``self``."""
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    return isinstance(node, ast.Name) and node.id == "self"


def _call_unsafe(call: ast.Call) -> bool:
    f = call.func
    if isinstance(f, ast.Name):
        return f.id not in SAFE_FUNCS
    if isinstance(f, ast.Attribute):
        return f.attr not in SAFE_METHODS
    return True


def _recv_tail(func: ast.Attribute) -> Optional[str]:
    recv = func.value
    if isinstance(recv, ast.Attribute):
        return recv.attr
    if isinstance(recv, ast.Name):
        return recv.id
    return None


def _carriers(fn: ast.FunctionDef) -> Dict[str, Set[str]]:
    """Flow-insensitive alias map: name -> root names it may carry."""
    out: Dict[str, Set[str]] = {}

    def feed(target: ast.AST, value: ast.AST) -> None:
        vroots = _roots(value)
        for t in ast.walk(target):
            if isinstance(t, ast.Name):
                out.setdefault(t.id, set()).update(vroots - {t.id})

    for sub in ast.walk(fn):
        if isinstance(sub, ast.Assign):
            for t in sub.targets:
                feed(t, sub.value)
        elif isinstance(sub, (ast.AnnAssign, ast.AugAssign)):
            if sub.value is not None:
                feed(sub.target, sub.value)
        elif isinstance(sub, ast.For):
            feed(sub.target, sub.iter)
        elif isinstance(sub, ast.withitem) and sub.optional_vars is not None:
            feed(sub.optional_vars, sub.context_expr)
        elif isinstance(sub, ast.comprehension):
            feed(sub.target, sub.iter)
    return out


class _Domain:
    """cfg.Flow domain over :class:`State` (live handles + path facts)."""

    def __init__(self, fi: "callgraph_mod.FuncInfo",
                 graph: "callgraph_mod.CallGraph"):
        self.fi = fi
        self.graph = graph
        self.file = fi.file
        self.carriers = _carriers(fi.node)

    # -- cfg protocol --------------------------------------------------------
    def initial(self) -> State:
        return State()

    def key(self, state: State):
        return state

    def collapse(self, states: List[State]):
        return [State(handles=frozenset().union(
            *(s.handles for s in states)))]

    def may_raise_expr(self, expr: Optional[ast.AST]) -> bool:
        if expr is None:
            return False
        return any(isinstance(n, ast.Call) and _call_unsafe(n)
                   for n in ast.walk(expr))

    def refine(self, test: ast.AST, state: State,
               branch: bool) -> Optional[State]:
        fact = self._attr_test(test)
        if fact is not None:
            fact_key, positive = fact
            want = branch == positive
            if (fact_key, not want) in state.facts:
                return None                    # contradictory path: prune
            return State(handles=state.handles,
                         facts=state.facts | {(fact_key, want)})
        name, none_branch = self._none_test(test)
        if name is not None and branch == none_branch:
            return State(handles=frozenset(
                h for h in state.handles if name not in h.names),
                facts=state.facts)
        return state

    def at_return(self, stmt: ast.Return, state: State) -> State:
        if stmt.value is None:
            return state
        return self._kill(state, _roots(stmt.value))

    def transfer(self, stmt: ast.stmt, state: State,
                 ) -> Tuple[State, Optional[State]]:
        calls = [n for n in ast.walk(stmt) if isinstance(n, ast.Call)]
        s = state
        # 1. releases and *explicit* transfers count even on the statement's
        #    own raise edge: a release frees first, and a marked transfer is
        #    a human assertion that the callee owns the handle from the call
        for c in calls:
            if self._is_pool_method(c, RELEASE_METHODS):
                roots: Set[str] = set()
                for a in c.args:
                    roots |= _roots(a)
                s = self._kill(s, roots)
        if self._stmt_marked_transfer(stmt):
            s = self._kill(s, _roots(stmt))
        for c in calls:
            s = self._call_transfers(c, s)
        raise_state = s if any(_call_unsafe(c) for c in calls) else None
        # 2. a store into self.* only lands if its RHS succeeded, so it
        #    transfers on the fallthrough edge only
        s = self._assign_transfers(stmt, s)
        # 3. acquisitions
        for c in calls:
            h = self._acquire(stmt, c)
            if h is not None:
                s = State(handles=s.handles | {h}, facts=s.facts)
        return s, raise_state

    # -- semantics -----------------------------------------------------------
    def _closure(self, roots: Set[str]) -> Set[str]:
        out, work = set(roots), list(roots)
        while work:
            n = work.pop()
            for carried in self.carriers.get(n, ()):
                if carried not in out:
                    out.add(carried)
                    work.append(carried)
        return out

    def _kill(self, state: State, roots: Set[str]) -> State:
        if not roots:
            return state
        cl = self._closure(roots)
        return State(handles=frozenset(
            h for h in state.handles if not (h.names & cl)),
            facts=state.facts)

    @staticmethod
    def _attr_test(test: ast.AST) -> Optional[Tuple[str, bool]]:
        """(dotted self-attribute, polarity) for ``self.X`` truthiness."""
        positive = True
        if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
            test = test.operand
            positive = False
        if isinstance(test, ast.Attribute) and _self_rooted(test) \
                and not any(isinstance(n, ast.Subscript)
                            for n in ast.walk(test)):
            name = dotted_name(test)
            if name is not None:
                return name, positive
        return None

    def _is_pool_method(self, call: ast.Call, methods: Set[str]) -> bool:
        f = call.func
        if not isinstance(f, ast.Attribute) or f.attr not in methods:
            return False
        site = self.graph.call_by_node.get(id(call))
        if site is not None and site.callee.cls is not None:
            return site.callee.cls in POOL_CLASSES
        return _recv_tail(f) in RECV_NAME_FALLBACK

    def _is_entry_pop(self, call: ast.Call) -> bool:
        f = call.func
        return (isinstance(f, ast.Attribute) and f.attr == "pop"
                and isinstance(f.value, ast.Attribute)
                and f.value.attr in REF_CONTAINERS
                and attr_root(f.value) == "self")

    def _acquire(self, stmt: ast.stmt, call: ast.Call) -> Optional[Handle]:
        f = call.func
        if not isinstance(f, ast.Attribute):
            return None
        desc = dotted_name(f) or f.attr
        if f.attr == "share" and self._is_pool_method(call, {"share"}):
            roots: Set[str] = set()
            for a in call.args:
                roots |= _roots(a)
            if not roots:
                return None          # self-rooted: ref already held by state
            return Handle(names=frozenset(roots), desc=desc,
                          line=call.lineno, col=call.col_offset)
        target = self._single_name_target(stmt, call)
        if target is None:
            return None
        if f.attr == "alloc" and self._is_pool_method(call, {"alloc"}):
            return Handle(names=frozenset({target}), desc=desc,
                          line=call.lineno, col=call.col_offset)
        if self._is_entry_pop(call):
            return Handle(names=frozenset({target}), desc=desc,
                          line=call.lineno, col=call.col_offset)
        return None

    @staticmethod
    def _single_name_target(stmt: ast.stmt,
                            call: ast.Call) -> Optional[str]:
        """``x = <call>`` -> "x"; stores into self.* are direct transfers."""
        if isinstance(stmt, ast.Assign) and stmt.value is call \
                and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name):
            return stmt.targets[0].id
        if isinstance(stmt, ast.AnnAssign) and stmt.value is call \
                and isinstance(stmt.target, ast.Name):
            return stmt.target.id
        return None

    def _assign_transfers(self, stmt: ast.stmt, state: State) -> State:
        """Storing into self-rooted state hands the blocks to the object."""
        if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)) \
                and stmt.value is not None:
            targets = (stmt.targets if isinstance(stmt, ast.Assign)
                       else [stmt.target])
            for t in targets:
                if isinstance(t, (ast.Attribute, ast.Subscript)) \
                        and _self_rooted(t):
                    state = self._invalidate_fact(t, state)
                    state = self._kill(state, _roots(stmt.value))
        return state

    @staticmethod
    def _invalidate_fact(target: ast.AST, state: State) -> State:
        """Reassigning ``self.X`` voids path facts recorded about it."""
        if isinstance(target, ast.Attribute):
            name = dotted_name(target)
            if name is not None and any(k == name for k, _ in state.facts):
                return State(handles=state.handles,
                             facts=frozenset((k, v) for k, v in state.facts
                                             if k != name))
        return state

    def _call_transfers(self, call: ast.Call, state: State) -> State:
        f = call.func
        if not isinstance(f, ast.Attribute):
            site = self.graph.call_by_node.get(id(call))
            if site is not None and TRANSFER_MARK in site.callee.markers:
                roots: Set[str] = set()
                for a in call.args:
                    roots |= _roots(a)
                return self._kill(state, roots)
            return state
        # adopting mutation of self-rooted containers: self.x[s].append(b)
        if f.attr in ADOPT_METHODS and _self_rooted(f.value):
            roots = set()
            for a in call.args:
                roots |= _roots(a)
            return self._kill(state, roots)
        site = self.graph.call_by_node.get(id(call))
        if site is not None and TRANSFER_MARK in site.callee.markers:
            roots = set()
            for a in call.args:
                roots |= _roots(a)
            return self._kill(state, roots)
        return state

    def _stmt_marked_transfer(self, stmt: ast.stmt) -> bool:
        end = getattr(stmt, "end_lineno", stmt.lineno) or stmt.lineno
        for ln in range(stmt.lineno, end + 1):
            c = self.file.comments.get(ln)
            if not c:
                continue
            m = _LINT_RE.search(c)
            if m and TRANSFER_MARK in m.group(1).split():
                return True
        return False

    @staticmethod
    def _none_test(test: ast.AST) -> Tuple[Optional[str], Optional[bool]]:
        """(name, branch-on-which-name-is-dead) for recognizable tests.

        ``x is None`` -> (x, True): the handle is dead on the true branch
        (nothing was allocated).  ``x is not None`` -> (x, False).  Bare
        ``x`` truthiness -> (x, False); ``not x`` -> (x, True).
        """
        if isinstance(test, ast.Compare) and len(test.ops) == 1 \
                and isinstance(test.left, ast.Name) \
                and isinstance(test.comparators[0], ast.Constant) \
                and test.comparators[0].value is None:
            if isinstance(test.ops[0], ast.Is):
                return test.left.id, True
            if isinstance(test.ops[0], ast.IsNot):
                return test.left.id, False
        if isinstance(test, ast.Name):
            return test.id, False
        if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not) \
                and isinstance(test.operand, ast.Name):
            return test.operand.id, True
        return None, None


def _has_acquire(fi: "callgraph_mod.FuncInfo",
                 graph: "callgraph_mod.CallGraph") -> bool:
    dom = None
    for n in ast.walk(fi.node):
        if not isinstance(n, ast.Call) or \
                not isinstance(n.func, ast.Attribute):
            continue
        if n.func.attr in ACQUIRE_METHODS or n.func.attr == "pop":
            if dom is None:
                dom = _Domain(fi, graph)
            if dom._is_pool_method(n, ACQUIRE_METHODS) or \
                    dom._is_entry_pop(n):
                return True
    return False


def check(project: Project, graph=None) -> List[Finding]:
    if graph is None:
        graph = callgraph_mod.build(project)
    findings: List[Finding] = []
    for fi in graph.functions:
        if TRANSFER_MARK in fi.markers:
            continue                 # whole function hands its blocks off
        if not _has_acquire(fi, graph):
            continue
        dom = _Domain(fi, graph)
        sinks = Flow(dom).run(fi.node.body)
        leaks: Dict[Handle, str] = {}
        for (_stmt, s) in sinks.raised:
            for h in s.handles:
                leaks.setdefault(h, "raise")
        for s in sinks.returned:
            for h in s.handles:
                leaks.setdefault(h, "exit")
        for h in sorted(leaks, key=lambda h: (h.line, h.col, h.desc)):
            names = ",".join(sorted(h.names))
            if leaks[h] == "raise":
                msg = (f"resource `{names}` acquired via `{h.desc}` in "
                       f"`{fi.qualname}` may escape on a raising path "
                       f"without release/transfer/`finally` protection")
            else:
                msg = (f"resource `{names}` acquired via `{h.desc}` in "
                       f"`{fi.qualname}` is not released or transferred "
                       f"on every exit path")
            findings.append(Finding(
                rule=RULE_ID, path=fi.path, line=h.line, col=h.col,
                message=msg,
                symbol=f"{fi.qualname}.leak.{names}"))
    return findings
