"""RL003: every public Pallas kernel pairs with a ``*_ref`` oracle.

For each public top-level function in ``src/repro/kernels/*.py``
(excluding ``ref.py`` and ``__init__.py``):

* ``kernels/ref.py`` must define ``<kernel>_ref`` -- the pure-jnp oracle
  the kernel is validated against, and
* at least one file under ``tests/`` must reference both names (the
  parity test that actually exercises the pair).

Extra helpers in ``ref.py`` that don't correspond to a kernel (shared
sub-oracles like ``ssd_ref``) are allowed.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Set

from .core import Finding, Project

RULE_ID = "RL003"


def _public_defs(tree: ast.Module) -> List[ast.FunctionDef]:
    return [n for n in tree.body
            if isinstance(n, ast.FunctionDef) and not n.name.startswith("_")]


def check(project: Project) -> List[Finding]:
    findings: List[Finding] = []
    kernels: Dict[str, tuple] = {}      # name -> (path, lineno), first wins
    ref_names: Set[str] = set()
    kernels_dir_seen = False
    for f in project.files:
        if f.tree is None or "/kernels/" not in f.path:
            continue
        kernels_dir_seen = True
        base = f.path.rsplit("/", 1)[-1]
        if base == "__init__.py":
            continue
        if base == "ref.py":
            ref_names = {n.name for n in _public_defs(f.tree)}
            continue
        for fn in _public_defs(f.tree):
            kernels.setdefault(fn.name, (f.path, fn.lineno))
    if not kernels_dir_seen:
        return findings

    for name, (path, lineno) in sorted(kernels.items()):
        oracle = f"{name}_ref"
        if oracle not in ref_names:
            findings.append(Finding(
                rule=RULE_ID, path=path, line=lineno, col=0,
                message=(f"public kernel `{name}` has no `{oracle}` oracle "
                         f"in kernels/ref.py"),
                symbol=f"kernels.{name}.oracle"))
            continue  # without the oracle, the test check is moot
        pair_re = None
        for test_path, text in project.tests:
            if re.search(rf"\b{re.escape(name)}\b", text) and \
                    re.search(rf"\b{re.escape(oracle)}\b", text):
                pair_re = test_path
                break
        if pair_re is None and project.tests:
            findings.append(Finding(
                rule=RULE_ID, path=path, line=lineno, col=0,
                message=(f"no test references both `{name}` and `{oracle}` "
                         f"(parity test missing)"),
                symbol=f"kernels.{name}.parity-test"))
    return findings
