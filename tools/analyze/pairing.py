"""RL003: every public Pallas kernel pairs with a ``*_ref`` oracle.

For each public top-level function in ``src/repro/kernels/*.py``
(excluding ``ref.py`` and ``__init__.py``):

* ``kernels/ref.py`` must define ``<kernel>_ref`` -- the pure-jnp oracle
  the kernel is validated against;
* the pair must agree on their *non-default positional* parameter names
  and order (the ``ops.py`` wrapper is the canonical signature when the
  kernel is re-wrapped there) -- a drifted oracle signature means the
  parity tests silently compare different argument layouts; and
* at least one file under ``tests/`` must reference both names (the
  parity test that actually exercises the pair).

Extra helpers in ``ref.py`` that don't correspond to a kernel (shared
sub-oracles like ``ssd_ref``) are allowed.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional

from .core import Finding, Project

RULE_ID = "RL003"


def _public_defs(tree: ast.Module) -> List[ast.FunctionDef]:
    return [n for n in tree.body
            if isinstance(n, ast.FunctionDef) and not n.name.startswith("_")]


def _required_positional(fn: ast.FunctionDef) -> List[str]:
    """Positional parameter names without defaults, in order."""
    a = fn.args
    pos = [p.arg for p in a.posonlyargs + a.args]
    n_default = len(a.defaults)
    return pos[:len(pos) - n_default] if n_default else pos


def check(project: Project, graph=None) -> List[Finding]:
    findings: List[Finding] = []
    kernels: Dict[str, tuple] = {}      # name -> (path, lineno), first wins
    sig_defs: Dict[str, ast.FunctionDef] = {}   # canonical signature source
    ops_defs: Dict[str, ast.FunctionDef] = {}
    ref_defs: Dict[str, ast.FunctionDef] = {}
    kernels_dir_seen = False
    for f in project.files:
        if f.tree is None or "/kernels/" not in f.path:
            continue
        kernels_dir_seen = True
        base = f.path.rsplit("/", 1)[-1]
        if base == "__init__.py":
            continue
        if base == "ref.py":
            ref_defs = {n.name: n for n in _public_defs(f.tree)}
            continue
        for fn in _public_defs(f.tree):
            kernels.setdefault(fn.name, (f.path, fn.lineno))
            sig_defs.setdefault(fn.name, fn)
            if base == "ops.py":
                ops_defs[fn.name] = fn
    if not kernels_dir_seen:
        return findings

    for name, (path, lineno) in sorted(kernels.items()):
        oracle = f"{name}_ref"
        ref_fn = ref_defs.get(oracle)
        if ref_fn is None:
            findings.append(Finding(
                rule=RULE_ID, path=path, line=lineno, col=0,
                message=(f"public kernel `{name}` has no `{oracle}` oracle "
                         f"in kernels/ref.py"),
                symbol=f"kernels.{name}.oracle"))
            continue  # without the oracle, the other checks are moot
        # signature parity: the ops.py wrapper is canonical when present
        canon: Optional[ast.FunctionDef] = ops_defs.get(name,
                                                        sig_defs.get(name))
        if canon is not None:
            want = _required_positional(canon)
            got = _required_positional(ref_fn)
            if want != got:
                findings.append(Finding(
                    rule=RULE_ID, path=path, line=lineno, col=0,
                    message=(f"`{oracle}` positional signature "
                             f"({', '.join(got)}) does not match kernel "
                             f"`{name}` ({', '.join(want)}); the parity "
                             f"test compares different argument layouts"),
                    symbol=f"kernels.{name}.signature-parity"))
        pair_re = None
        for test_path, text in project.tests:
            if re.search(rf"\b{re.escape(name)}\b", text) and \
                    re.search(rf"\b{re.escape(oracle)}\b", text):
                pair_re = test_path
                break
        if pair_re is None and project.tests:
            findings.append(Finding(
                rule=RULE_ID, path=path, line=lineno, col=0,
                message=(f"no test references both `{name}` and `{oracle}` "
                         f"(parity test missing)"),
                symbol=f"kernels.{name}.parity-test"))
    return findings
