"""RL006: no device->host syncs on the engine's hot path.

Seeds are functions marked ``# repro-lint: hot-path`` (the engine tick).
The hot set is the forward call-graph closure from the seeds, *not*
descending into jitted callees (device code is RL002's territory).

Inside a hot function:

* ``.item()`` / ``.block_until_ready()`` / ``jax.device_get`` are
  flagged unconditionally -- these APIs only exist for device values;
* ``np.*`` calls (including module-level aliases like ``_to_host =
  np.asarray``), ``.tolist()`` / ``.to_py()``, and
  ``bool()/int()/float()/complex()`` casts are flagged only when an
  argument (or the receiver) is *device-valued*.

Device-ness is a may-analysis fixpoint over the call graph: results of
jit entries and ``jnp.*``/``jax.*`` calls are device; device-ness flows
through assignments (including tuple unpacking), subscripts, arithmetic,
``self`` fields that any method stores a device value into, call
arguments (caller to callee parameter), and return values (``np.*``
results are host, which is what makes a properly fetched array clean
downstream).

The engine's one sanctioned packed sync per tick is expected to carry an
inline ``disable=RL006`` with its justification.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from . import callgraph as callgraph_mod
from .callgraph import CallGraph, FuncInfo, propagate_reachable
from .core import Finding, Project, attr_root, dotted_name

RULE_ID = "RL006"
HOT_MARK = "hot-path"

_ALWAYS_SYNC_METHODS = {"item", "block_until_ready"}
_GATED_SYNC_METHODS = {"tolist", "to_py"}
_CAST_BUILTINS = {"bool", "int", "float", "complex"}
_FIXPOINT_ROUNDS = 10


class _ModuleAliases:
    """numpy / jax import aliases plus module-level np-function aliases."""

    def __init__(self, tree: ast.Module):
        self.np: Set[str] = set()
        self.jax: Set[str] = set()          # device-producing roots
        self.np_funcs: Set[str] = set()     # X = np.asarray  style aliases
        for node in tree.body:
            if isinstance(node, ast.Import):
                for alias in node.names:
                    bound = alias.asname or alias.name.split(".", 1)[0]
                    if alias.name == "numpy" or \
                            alias.name.startswith("numpy."):
                        self.np.add(bound)
                    elif alias.name == "jax" or alias.name.startswith("jax."):
                        self.jax.add(bound)
            elif isinstance(node, ast.ImportFrom):
                if node.module == "numpy":
                    for alias in node.names:
                        self.np_funcs.add(alias.asname or alias.name)
                elif node.module and node.module.startswith("jax"):
                    for alias in node.names:
                        self.jax.add(alias.asname or alias.name)
        for node in tree.body:          # _to_host = np.asarray
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and isinstance(node.value, ast.Attribute) \
                    and attr_root(node.value) in self.np:
                self.np_funcs.add(node.targets[0].id)


class _DeviceModel:
    """Which params / returns / self-fields may hold device values."""

    def __init__(self, graph: CallGraph, fids: Set[str]):
        self.graph = graph
        self.funcs = [f for f in graph.functions if f.fid in fids]
        self.aliases: Dict[str, _ModuleAliases] = {}
        self.dev_params: Dict[str, Set[str]] = {}
        self.returns_dev: Dict[str, bool] = {}
        self.dev_fields: Dict[Tuple[str, str], Set[str]] = {}
        for f in self.funcs:
            if f.path not in self.aliases and f.file.tree is not None:
                self.aliases[f.path] = _ModuleAliases(f.file.tree)
        self._fixpoint()

    def _aliases_of(self, fi: FuncInfo) -> _ModuleAliases:
        return self.aliases.get(fi.path) or _ModuleAliases(ast.Module([], []))

    def _fixpoint(self) -> None:
        for _ in range(_FIXPOINT_ROUNDS):
            before = (sum(len(v) for v in self.dev_params.values()),
                      sum(self.returns_dev.values()),
                      sum(len(v) for v in self.dev_fields.values()))
            for f in self.funcs:
                self._scan_function(f)
            after = (sum(len(v) for v in self.dev_params.values()),
                     sum(self.returns_dev.values()),
                     sum(len(v) for v in self.dev_fields.values()))
            if after == before:
                break

    def _scan_function(self, fi: FuncInfo,
                       report: Optional[List[Tuple[ast.Call, str]]] = None,
                       ) -> None:
        al = self._aliases_of(fi)
        env: Set[str] = set(self.dev_params.get(fi.fid, ()))
        field_key = (fi.path, fi.cls or "")

        def is_dev(expr: ast.AST) -> bool:
            if isinstance(expr, ast.Name):
                return expr.id in env
            if isinstance(expr, ast.Attribute):
                if isinstance(expr.value, ast.Name) and \
                        expr.value.id == "self":
                    return expr.attr in self.dev_fields.get(field_key, ())
                return False
            if isinstance(expr, ast.Subscript):
                return is_dev(expr.value)
            if isinstance(expr, (ast.BinOp,)):
                return is_dev(expr.left) or is_dev(expr.right)
            if isinstance(expr, ast.UnaryOp):
                return is_dev(expr.operand)
            if isinstance(expr, ast.IfExp):
                return is_dev(expr.body) or is_dev(expr.orelse)
            if isinstance(expr, ast.Call):
                return self._call_is_dev(expr, al)
            if isinstance(expr, (ast.Tuple, ast.List)):
                return any(is_dev(e) for e in expr.elts)
            return False

        def bind(target: ast.AST, dev: bool) -> None:
            if isinstance(target, ast.Name):
                if dev:
                    env.add(target.id)
            elif isinstance(target, (ast.Tuple, ast.List, ast.Starred)):
                elts = (target.elts
                        if isinstance(target, (ast.Tuple, ast.List))
                        else [target.value])
                for e in elts:
                    bind(e, dev)
            elif isinstance(target, ast.Attribute) and \
                    isinstance(target.value, ast.Name) and \
                    target.value.id == "self":
                if dev:
                    self.dev_fields.setdefault(field_key, set()).add(
                        target.attr)
            elif isinstance(target, ast.Subscript):
                base = target.value
                if isinstance(base, ast.Attribute) and \
                        isinstance(base.value, ast.Name) and \
                        base.value.id == "self":
                    if dev:
                        self.dev_fields.setdefault(field_key, set()).add(
                            base.attr)

        def visit_call(call: ast.Call) -> None:
            site = self.graph.call_by_node.get(id(call))
            if site is not None and not site.callee.is_jit:
                callee = site.callee
                pos = self._positional_params(callee)
                for i, a in enumerate(call.args):
                    if i < len(pos) and is_dev(a):
                        self.dev_params.setdefault(callee.fid, set()).add(
                            pos[i])
                for kw in call.keywords:
                    if kw.arg and is_dev(kw.value):
                        self.dev_params.setdefault(callee.fid, set()).add(
                            kw.arg)
            if report is not None:
                self._report_call(call, is_dev, al, report)

        def walk_stmts(body: List[ast.stmt]) -> None:
            for stmt in body:
                for sub in ast.walk(stmt):
                    if isinstance(sub, ast.Call):
                        visit_call(sub)
                if isinstance(stmt, (ast.Assign, ast.AnnAssign,
                                     ast.AugAssign)):
                    if stmt.value is None:
                        continue
                    dev = is_dev(stmt.value)
                    targets = (stmt.targets if isinstance(stmt, ast.Assign)
                               else [stmt.target])
                    for t in targets:
                        bind(t, dev)
                elif isinstance(stmt, ast.Return):
                    if stmt.value is not None and is_dev(stmt.value):
                        self.returns_dev[fi.fid] = True
                elif isinstance(stmt, ast.For):
                    bind(stmt.target, is_dev(stmt.iter))
                    walk_stmts(stmt.body)
                    walk_stmts(stmt.orelse)
                elif isinstance(stmt, (ast.If, ast.While)):
                    walk_stmts(stmt.body)
                    walk_stmts(stmt.orelse)
                elif isinstance(stmt, ast.Try):
                    walk_stmts(stmt.body)
                    for h in stmt.handlers:
                        walk_stmts(h.body)
                    walk_stmts(stmt.orelse)
                    walk_stmts(stmt.finalbody)
                elif isinstance(stmt, ast.With):
                    walk_stmts(stmt.body)

        # two passes so loop-carried device-ness stabilizes intra-function
        walk_stmts(fi.node.body)
        walk_stmts(fi.node.body)

    def _call_is_dev(self, call: ast.Call, al: _ModuleAliases) -> bool:
        site = self.graph.call_by_node.get(id(call))
        if site is not None:
            if site.callee.is_jit:
                return True
            return self.returns_dev.get(site.callee.fid, False)
        func = call.func
        root = attr_root(func) if isinstance(func, ast.Attribute) else None
        if root is not None:
            if dotted_name(func) == "jax.device_get":
                return False                    # host by definition
            if root in al.jax:
                return True
            if root in al.np:
                return False
        if isinstance(func, ast.Name):
            if func.id in al.jax:
                return True
            if func.id in al.np_funcs or func.id in _CAST_BUILTINS:
                return False
        return False

    @staticmethod
    def _positional_params(fi: FuncInfo) -> List[str]:
        a = fi.node.args
        names = [p.arg for p in a.posonlyargs + a.args]
        if names and names[0] == "self" and fi.cls is not None:
            names = names[1:]
        return names

    # -- sync detection ------------------------------------------------------
    def _report_call(self, call: ast.Call, is_dev, al: _ModuleAliases,
                     report: List[Tuple[ast.Call, str]]) -> None:
        func = call.func
        if isinstance(func, ast.Attribute):
            if func.attr in _ALWAYS_SYNC_METHODS:
                report.append((call, f".{func.attr}()"))
                return
            if dotted_name(func) == "jax.device_get":
                report.append((call, "jax.device_get"))
                return
            if func.attr in _GATED_SYNC_METHODS and is_dev(func.value):
                report.append((call, f".{func.attr}()"))
                return
            root = attr_root(func)
            if root in al.np and \
                    (any(is_dev(a) for a in call.args)
                     or any(is_dev(kw.value) for kw in call.keywords)):
                report.append((call, dotted_name(func) or f".{func.attr}"))
                return
        elif isinstance(func, ast.Name):
            hot_args = (any(is_dev(a) for a in call.args)
                        or any(is_dev(kw.value) for kw in call.keywords))
            if func.id in al.np_funcs and hot_args:
                report.append((call, f"{func.id}(...)"))
            elif func.id in _CAST_BUILTINS and hot_args:
                report.append((call, f"{func.id}()"))

    def findings_for(self, fi: FuncInfo) -> List[Tuple[ast.Call, str]]:
        report: List[Tuple[ast.Call, str]] = []
        self._scan_function(fi, report=report)
        return report


def check(project: Project, graph=None) -> List[Finding]:
    if graph is None:
        graph = callgraph_mod.build(project)
    seeds = [f for f in graph.functions if HOT_MARK in f.markers]
    if not seeds:
        return []
    hot = propagate_reachable(graph, HOT_MARK)
    seed_names = ", ".join(sorted(f.qualname for f in seeds))
    model = _DeviceModel(graph, hot)
    findings: List[Finding] = []
    seen: Set[str] = set()
    for fi in sorted(graph.functions, key=lambda f: (f.path, f.node.lineno)):
        if fi.fid not in hot or fi.is_jit:
            continue
        for call, what in model.findings_for(fi):
            fnd = Finding(
                rule=RULE_ID, path=fi.path,
                line=call.lineno, col=call.col_offset,
                message=(f"device->host sync `{what}` in `{fi.qualname}`, "
                         f"reachable from hot path `{seed_names}`; the "
                         f"tick budget is one annotated packed sync"),
                symbol=f"{fi.qualname}.hotsync.{what}")
            if fnd.fingerprint not in seen:
                seen.add(fnd.fingerprint)
                findings.append(fnd)
    return findings
