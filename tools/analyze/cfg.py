"""Lightweight per-function control flow for path-sensitive rules.

Not a materialized basic-block graph: a *structured abstract
interpreter* that walks a function body statement by statement, carrying
a set of abstract states, and records every way control can leave the
function in a :class:`Sinks` object:

* ``raised``    -- (statement, state) pairs where an exception can
  escape; the state is the one *before* the statement unless the
  domain's ``transfer`` says otherwise (a release call, for example,
  counts as having released even on its own raise edge);
* ``returned``  -- states at explicit returns and at body fallthrough;
* ``broke`` / ``continued`` -- scoped by the innermost loop.

Structure handled: ``if``/``else``, ``while``/``for`` (iterated to a
fixpoint, bounded), ``try``/``except``/``else``/``finally`` (catch-all
handlers fully consume the body's raise edges; ``finally`` bodies are
replayed on every outflow class), ``with``, ``assert``, early returns.
Nested ``def``/``class`` bodies are opaque.

The domain object supplies the semantics::

    initial() -> state
    key(state) -> hashable                  # dedup / fixpoint detection
    collapse(states) -> [state]             # when the state set overflows
    transfer(stmt, state) -> (state', raise_state_or_None)
    may_raise_expr(expr) -> bool            # for tests / iterables / with
    refine(test, state, branch) -> state | None   # narrowing; None prunes
                                                  # an infeasible branch
    at_return(stmt, state) -> state
"""
from __future__ import annotations

import ast
from typing import List, Optional, Tuple

MAX_STATES = 64
MAX_LOOP_ITERS = 10


class Sinks:
    def __init__(self, raised=None, returned=None, broke=None,
                 continued=None):
        self.raised: List[Tuple[ast.stmt, object]] = \
            raised if raised is not None else []
        self.returned: List[object] = returned if returned is not None else []
        self.broke: List[object] = broke if broke is not None else []
        self.continued: List[object] = \
            continued if continued is not None else []


def _catches_all(handler: ast.ExceptHandler) -> bool:
    t = handler.type
    if t is None:
        return True
    names = []
    if isinstance(t, ast.Tuple):
        names = [e.id for e in t.elts if isinstance(e, ast.Name)]
    elif isinstance(t, ast.Name):
        names = [t.id]
    return any(n in ("Exception", "BaseException") for n in names)


class Flow:
    def __init__(self, domain):
        self.d = domain

    def run(self, body: List[ast.stmt]) -> Sinks:
        sinks = Sinks()
        out = self._body(body, [self.d.initial()], sinks)
        sinks.returned.extend(out)           # implicit return at fallthrough
        return sinks

    # -- helpers -------------------------------------------------------------
    def _dedup(self, states: List[object]) -> List[object]:
        seen, out = set(), []
        for s in states:
            k = self.d.key(s)
            if k not in seen:
                seen.add(k)
                out.append(s)
        if len(out) > MAX_STATES:
            out = self.d.collapse(out)
        return out

    def _keys(self, states: List[object]) -> set:
        return {self.d.key(s) for s in states}

    def _body(self, body: List[ast.stmt], states: List[object],
              sinks: Sinks) -> List[object]:
        for stmt in body:
            if not states:
                break
            states = self._stmt(stmt, states, sinks)
        return states

    # -- dispatch ------------------------------------------------------------
    def _stmt(self, stmt: ast.stmt, states: List[object],
              sinks: Sinks) -> List[object]:
        d = self.d
        if isinstance(stmt, ast.If):
            if d.may_raise_expr(stmt.test):
                for s in states:
                    sinks.raised.append((stmt, s))
            t = [r for r in (d.refine(stmt.test, s, True) for s in states)
                 if r is not None]
            f = [r for r in (d.refine(stmt.test, s, False) for s in states)
                 if r is not None]
            out = (self._body(stmt.body, t, sinks)
                   + self._body(stmt.orelse, f, sinks))
            return self._dedup(out)
        if isinstance(stmt, (ast.While, ast.For)):
            return self._loop(stmt, states, sinks)
        if isinstance(stmt, ast.Try):
            return self._try(stmt, states, sinks)
        if isinstance(stmt, ast.With):
            for item in stmt.items:
                if d.may_raise_expr(item.context_expr):
                    for s in states:
                        sinks.raised.append((stmt, s))
            return self._body(stmt.body, states, sinks)
        if isinstance(stmt, ast.Return):
            if stmt.value is not None and d.may_raise_expr(stmt.value):
                for s in states:
                    sinks.raised.append((stmt, s))
            for s in states:
                sinks.returned.append(d.at_return(stmt, s))
            return []
        if isinstance(stmt, ast.Raise):
            for s in states:
                sinks.raised.append((stmt, s))
            return []
        if isinstance(stmt, ast.Break):
            sinks.broke.extend(states)
            return []
        if isinstance(stmt, ast.Continue):
            sinks.continued.extend(states)
            return []
        if isinstance(stmt, ast.Assert):
            for s in states:                  # a failing assert raises
                sinks.raised.append((stmt, s))
            return [r for r in (d.refine(stmt.test, s, True) for s in states)
                    if r is not None]
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef, ast.Import, ast.ImportFrom,
                             ast.Pass, ast.Global, ast.Nonlocal)):
            return states
        # simple statement: Assign / AugAssign / AnnAssign / Expr / Delete
        out = []
        for s in states:
            ns, raise_state = d.transfer(stmt, s)
            if raise_state is not None:
                sinks.raised.append((stmt, raise_state))
            out.append(ns)
        return self._dedup(out)

    def _loop(self, stmt, states: List[object], sinks: Sinks) -> List[object]:
        d = self.d
        head = stmt.iter if isinstance(stmt, ast.For) else stmt.test
        if d.may_raise_expr(head):
            for s in states:
                sinks.raised.append((stmt, s))
        inner = Sinks(raised=sinks.raised, returned=sinks.returned,
                      broke=[], continued=[])
        entry = self._dedup(list(states))
        for _ in range(MAX_LOOP_ITERS):
            body_out = self._body(stmt.body, list(entry), inner)
            nxt = self._dedup(entry + body_out + inner.continued)
            inner.continued = []
            if self._keys(nxt) == self._keys(entry):
                break
            entry = nxt
        # the loop may run zero times (entry) or be left via break
        out = self._dedup(entry + inner.broke)
        if stmt.orelse:
            out = self._dedup(self._body(stmt.orelse, out, sinks))
        return out

    def _try(self, stmt: ast.Try, states: List[object],
             sinks: Sinks) -> List[object]:
        has_finally = bool(stmt.finalbody)
        if has_finally:
            inner = Sinks()
        else:
            inner = Sinks(raised=[], returned=sinks.returned,
                          broke=sinks.broke, continued=sinks.continued)
        body_out = self._body(stmt.body, states, inner)
        body_raised = inner.raised
        catch_all = any(_catches_all(h) for h in stmt.handlers)

        h_states = self._dedup([s for (_st, s) in body_raised])
        escaped: List[Tuple[ast.stmt, object]] = []
        if stmt.handlers:
            hsinks = Sinks(raised=escaped, returned=inner.returned,
                           broke=inner.broke, continued=inner.continued)
            handler_out: List[object] = []
            for h in stmt.handlers:
                handler_out += self._body(h.body, list(h_states), hsinks)
        else:
            handler_out = []
        if not catch_all:
            escaped += body_raised           # may be uncaught

        orelse_out = (self._body(stmt.orelse, body_out, inner)
                      if stmt.orelse else body_out)
        normal_out = self._dedup(handler_out + orelse_out)

        if not has_finally:
            sinks.raised.extend(escaped)
            return normal_out

        # replay finalbody per outflow class; its own raises go outward
        def replay(sts: List[object]) -> List[object]:
            fsinks = Sinks(raised=sinks.raised, returned=sinks.returned,
                           broke=sinks.broke, continued=sinks.continued)
            return self._body(stmt.finalbody, list(sts), fsinks)

        out = replay(normal_out)
        for (st, s) in escaped:
            for s2 in replay([s]):
                sinks.raised.append((st, s2))
        for s in inner.returned:
            sinks.returned.extend(replay([s]))
        for s in inner.broke:
            sinks.broke.extend(replay([s]))
        for s in inner.continued:
            sinks.continued.extend(replay([s]))
        return self._dedup(out)
