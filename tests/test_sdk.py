"""FAIR SDK: artifact parity (C2), checksum integrity, runtime decoupling,
SDK-vs-core sampler parity with injected uniforms (C3), privacy boundary (C5)."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import generate_trajectories, get_logits, init_delphi
from repro.sdk import (InferenceSession, Runtime, export_model, read_manifest,
                       verify_checksums)


@pytest.fixture(scope="module")
def artifact(tmp_path_factory):
    cfg = get_config("delphi-2m", reduced=True).replace(
        dtype="float32", vocab_size=96, max_seq_len=32)
    params = init_delphi(cfg, jax.random.PRNGKey(11))
    d = str(tmp_path_factory.mktemp("artifact"))
    export_model(params, cfg, d)
    return d, params, cfg


def test_files_and_checksums(artifact):
    d, _, _ = artifact
    assert sorted(os.listdir(d)) == ["decode.bin", "manifest.json",
                                     "model.bin", "params.npz", "prefill.bin"]
    assert verify_checksums(d)


def test_tamper_detection(artifact, tmp_path):
    d, params, cfg = artifact
    d2 = str(tmp_path / "tampered")
    export_model(params, cfg, d2)
    with open(os.path.join(d2, "params.npz"), "r+b") as f:
        f.seek(100)
        f.write(b"\x00\x01\x02")
    assert not verify_checksums(d2)


def test_manifest_fair_fields(artifact):
    d, _, cfg = artifact
    m = read_manifest(d)
    for field in ("name", "identifier", "files", "interchange_format",
                  "signature", "provenance", "license", "sampling",
                  "privacy"):
        assert field in m, field
    assert m["sampling"]["termination"]["max_age_years"] == cfg.max_age
    assert m["sampling"]["termination"]["death_token"] == cfg.death_token


def test_bitwise_logit_parity(artifact):
    """Claim C2: the exported artifact reproduces the jitted in-framework
    logits bit-for-bit (jit-vs-eager fusion differences are out of scope —
    the artifact *is* the jitted graph)."""
    d, params, cfg = artifact
    sess = InferenceSession(d)
    toks = [3, 40, 50]
    ags = [0.0, 20.0, 33.0]
    lg_sdk = sess.get_logits(toks, ags)
    S = cfg.max_seq_len
    t = np.zeros((1, S), np.int32); t[0, :3] = toks
    a = np.zeros((1, S), np.float32); a[0, :3] = ags; a[0, 3:] = ags[-1]
    native = jax.jit(lambda p, tt, aa: get_logits(p, cfg, tt, aa))
    lg = np.asarray(native(params, jnp.asarray(t), jnp.asarray(a)))
    assert (lg_sdk == lg[0, 2]).all()
    # and the eager path agrees to float tolerance
    lg_eager = np.asarray(get_logits(params, cfg, jnp.asarray(t),
                                     jnp.asarray(a)))
    np.testing.assert_allclose(lg_sdk, lg_eager[0, 2], atol=1e-5)


def test_runtime_is_decoupled():
    """The runtime module must not import model code (the ONNX property)."""
    import repro.sdk.runtime as rt
    imports = [l for l in open(rt.__file__).read().splitlines()
               if l.strip().startswith(("import ", "from "))]
    for banned in ("repro.models", "repro.core", "repro.configs",
                   "repro.train", "repro.serve"):
        assert not any(banned in l for l in imports), \
            f"runtime imports {banned}"


def test_sdk_vs_core_trajectory_parity(artifact):
    """Claim C2/C3: host-side SDK generation == in-graph generation when both
    consume the same uniforms."""
    d, params, cfg = artifact
    sess = InferenceSession(d)
    toks = [3, 10, 20]
    ags = [0.0, 15.0, 28.0]
    max_new = 6
    rng = np.random.default_rng(42)
    uniforms = rng.uniform(size=(max_new, cfg.vocab_size)).astype(np.float32)

    sdk_out = sess.generate_trajectory(toks, ags, max_new=max_new,
                                       uniforms=uniforms, max_age=1e9)

    t = jnp.asarray(np.asarray(toks, np.int32)[None])
    a = jnp.asarray(np.asarray(ags, np.float32)[None])
    core_out = generate_trajectories(
        params, cfg, t, a, jax.random.PRNGKey(0), max_new=max_new,
        max_age=1e9, uniforms=jnp.asarray(uniforms)[None])

    n = len(sdk_out["tokens"])
    assert n > 0
    core_toks = core_out["tokens"][0, 3:3 + n].tolist()
    assert sdk_out["tokens"] == core_toks
    # ages: the first waiting times agree to fp tolerance; later steps feed
    # ages back into the model, so fp noise compounds chaotically through
    # exp(-logit) — tokens stay identical, ages agree loosely
    np.testing.assert_allclose(
        sdk_out["ages"][:2], core_out["ages"][0, 3:3 + min(n, 2)], rtol=1e-4)
    np.testing.assert_allclose(
        sdk_out["ages"], core_out["ages"][0, 3:3 + n], rtol=0.08)


def test_make_inputs_rejects_bad_calls(artifact):
    """SDK hardening: clear errors instead of silent misreads/crashes."""
    d, _, _ = artifact
    sess = InferenceSession(d)
    with pytest.raises(ValueError, match="ages"):
        sess.get_logits([3, 10, 20], None)       # ages-manifest, no ages
    with pytest.raises(ValueError, match="empty"):
        sess.get_logits([], [])                  # would silently read index -1
    with pytest.raises(ValueError, match="mismatch"):
        sess.get_logits([3, 10], [0.0])
    with pytest.raises(ValueError, match="longer than"):
        sess.get_logits(list(range(3, 3 + sess.seq_len + 1)),
                        [0.0] * (sess.seq_len + 1))


def test_runtime_offline(artifact, monkeypatch):
    """C5: loading + running the artifact touches no network APIs."""
    import socket
    d, _, _ = artifact

    def no_net(*a, **k):
        raise AssertionError("network access attempted")
    monkeypatch.setattr(socket, "create_connection", no_net)
    rt = Runtime(d)
    sig = rt.input_signature
    S = sig[0]["shape"][1]
    out = rt.run(np.zeros((1, S), np.int32), np.zeros((1, S), np.float32))
    assert out.shape[0] == 1
