"""Dual loss: the competing-risk factorization identity and masking (C3)."""
from hypcompat import hnp, st
import jax.numpy as jnp
import numpy as np
from hypcompat import given, settings

from repro.core import dual_loss, event_ce, joint_nll, time_nll


@settings(max_examples=25, deadline=None)
@given(
    logits=hnp.arrays(np.float32, (3, 5, 11),
                      elements=st.floats(-6, 6, width=32,
                                         allow_subnormal=False)),
    dt=hnp.arrays(np.float32, (3, 5),
                  elements=st.floats(0.0078125, 10, width=32,
                                     allow_subnormal=False)),
    targets=hnp.arrays(np.int64, (3, 5), elements=st.integers(0, 10)),
)
def test_factorization_identity(logits, dt, targets):
    """joint NLL == event CE + time NLL, for any logits/dt/targets — the
    analytic statement that the paper's eq.-1 sampler and the training loss
    describe the same generative process."""
    lhs = joint_nll(jnp.asarray(logits), jnp.asarray(targets), jnp.asarray(dt))
    rhs = (event_ce(jnp.asarray(logits), jnp.asarray(targets))
           + time_nll(jnp.asarray(logits), jnp.asarray(dt)))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-4)


def test_masking():
    logits = jnp.zeros((1, 4, 7))
    targets = jnp.array([[1, 2, 3, 4]])
    dt = jnp.ones((1, 4))
    m_all = dual_loss(logits, targets, dt, jnp.ones((1, 4)))
    m_half = dual_loss(logits, targets, dt,
                       jnp.array([[1.0, 1.0, 0.0, 0.0]]))
    # uniform logits: CE = log(V) regardless of mask scope
    np.testing.assert_allclose(m_all["event_ce"], np.log(7), rtol=1e-5)
    np.testing.assert_allclose(m_half["event_ce"], np.log(7), rtol=1e-5)
    # fully-masked batch must not NaN
    m_none = dual_loss(logits, targets, dt, jnp.zeros((1, 4)))
    assert bool(jnp.isfinite(m_none["loss"]))


def test_time_nll_optimum():
    """Exp-NLL is minimized when the total rate equals 1/dt."""
    dt = jnp.array(2.0)
    rates = jnp.linspace(0.1, 2.0, 200)
    logits = jnp.log(rates)[:, None]          # single-token vocab
    nll = time_nll(logits, dt)
    best = rates[int(jnp.argmin(nll))]
    np.testing.assert_allclose(best, 1 / dt, rtol=0.05)


def test_time_weight():
    logits = jnp.zeros((1, 3, 5))
    targets = jnp.zeros((1, 3), jnp.int32)
    dt = jnp.ones((1, 3))
    mask = jnp.ones((1, 3))
    m0 = dual_loss(logits, targets, dt, mask, time_weight=0.0)
    np.testing.assert_allclose(m0["loss"], m0["event_ce"], rtol=1e-6)
