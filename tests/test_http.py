"""HTTP/SSE front-end + RemoteBackend: the network as a fourth backend.

The acceptance contract of the wire-protocol redesign: trajectories through
``Client(RemoteBackend(url))`` are bit-identical to ``LocalBackend`` under
injected uniforms, SSE streaming yields the same events as non-streaming
generate, and every validation failure surfaces over HTTP as a structured
JSON error with a stable code — both as a raw body and as the same typed
``ApiError`` re-raised client-side."""
import json
import threading
import time
import urllib.error
import urllib.request

import jax
import numpy as np
import pytest

from repro.api import (ApiError, Client, GenerateRequest, RemoteBackend,
                       TrajectoryResult, WIRE_PROTOCOL_VERSION)
from repro.api.client import EngineBackend
from repro.configs import get_config
from repro.core import init_delphi
from repro.serve.server import InferenceServer

TOKS = [3, 10, 20]
AGES = [0.0, 15.0, 28.0]


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("delphi-2m", reduced=True).replace(
        dtype="float32", vocab_size=96, max_seq_len=48, max_age=1e9)
    params = init_delphi(cfg, jax.random.PRNGKey(7))
    backend = EngineBackend.create(params, cfg, slots=4, max_context=64)
    server = InferenceServer(backend, port=0).start()
    yield params, cfg, server
    server.stop()


def _uniforms(max_new, V, seed=42):
    rng = np.random.default_rng(seed)
    return rng.uniform(size=(max_new, V)).astype(np.float32)


def _long_running_uniforms(max_new, cfg, seed=42):
    """Uniforms that can never sample the death token (u -> 0 makes its
    competing waiting time huge), so a long request deterministically runs
    its full max_new instead of flaking out early under the engine RNG."""
    u = _uniforms(max_new, cfg.vocab_size, seed)
    u[:, cfg.death_token] = 1e-12
    return u


def _post_raw(url, path, payload):
    req = urllib.request.Request(
        url + path, data=json.dumps(payload).encode(),
        headers={"Content-Type": "application/json"}, method="POST")
    try:
        with urllib.request.urlopen(req, timeout=60) as resp:
            return resp.status, json.loads(resp.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


# ---------------------------------------------------------------------------
# Discovery endpoints
# ---------------------------------------------------------------------------
def test_manifest_and_healthz(setup):
    _, cfg, server = setup
    with urllib.request.urlopen(server.address + "/v1/manifest") as r:
        m = json.loads(r.read())
    assert m["protocol_version"] == WIRE_PROTOCOL_VERSION
    assert m["backend"] == "engine"
    assert m["model"]["vocab_size"] == cfg.vocab_size
    assert m["model"]["has_ages"] is True
    assert set(m["endpoints"]) == {"generate", "generate_batch", "risk",
                                   "futures", "stream", "cancel",
                                   "manifest", "healthz"}
    with urllib.request.urlopen(server.address + "/v1/healthz") as r:
        h = json.loads(r.read())
    assert h["ok"] and h["engine"]["running"]


def test_background_engine_does_not_retain_completed(setup):
    """A long-running server must not leak finished requests: background
    start() disables the foreground-run() completed list."""
    _, _, server = setup
    remote = Client.connect(server.address)
    before = len(server.backend.engine.completed)
    for _ in range(3):
        remote.generate(tokens=TOKS, ages=AGES, max_new=2)
    assert len(server.backend.engine.completed) == before == 0


# ---------------------------------------------------------------------------
# Acceptance: remote == local, bit-identical under injected uniforms
# ---------------------------------------------------------------------------
def test_remote_bit_identical_to_local(setup):
    params, cfg, server = setup
    max_new = 6
    u = _uniforms(max_new, cfg.vocab_size)
    local = Client.from_params(params, cfg)
    remote = Client.connect(server.address)

    r_loc = local.generate(tokens=TOKS, ages=AGES, max_new=max_new,
                           uniforms=u)
    r_rem = remote.generate(tokens=TOKS, ages=AGES, max_new=max_new,
                            uniforms=u)
    assert len(r_rem.tokens) > 0
    assert r_rem.tokens == r_loc.tokens          # bit-identical events
    assert r_rem.prompt_tokens == TOKS and r_rem.prompt_ages == AGES
    assert r_rem.backend == "remote[engine]"
    np.testing.assert_allclose(r_rem.ages, r_loc.ages, rtol=0.08)


def test_remote_stream_matches_generate(setup):
    _, cfg, server = setup
    max_new = 5
    u = _uniforms(max_new, cfg.vocab_size, seed=9)
    remote = Client.connect(server.address)
    ref = remote.generate(tokens=TOKS, ages=AGES, max_new=max_new,
                          uniforms=u)
    evs = list(remote.stream(tokens=TOKS, ages=AGES, max_new=max_new,
                             uniforms=u))
    assert [e.token for e in evs] == ref.tokens
    assert [e.index for e in evs] == list(range(len(ref.tokens)))
    assert all(e.age is not None for e in evs)


def test_remote_generate_batch_order_and_concurrency(setup):
    """Concurrent remote clients continuously batch onto engine slots and
    every result maps back to its own prompt."""
    _, cfg, server = setup
    remote = Client.connect(server.address)
    reqs = [GenerateRequest(tokens=np.arange(3, 6 + i).tolist(),
                            ages=np.linspace(0, 20 + i, 3 + i).tolist(),
                            max_new=4)
            for i in range(6)]
    outs = remote.generate_batch(reqs)
    assert len(outs) == 6
    for req, out in zip(reqs, outs):
        assert isinstance(out, TrajectoryResult)
        assert out.prompt_tokens == list(req.tokens)
        assert len(out.tokens) == len(out.ages) <= 4

    # hammer the server from parallel threads: distinct prompts per thread
    results, errors = {}, []

    def worker(i):
        try:
            r = remote.generate(tokens=[3, 10 + i, 20 + i],
                                ages=AGES, max_new=3)
            results[i] = r
        except Exception as e:              # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not errors
    assert len(results) == 8
    for i, r in results.items():
        assert r.prompt_tokens == [3, 10 + i, 20 + i]


def test_remote_risk_matches_local(setup):
    params, cfg, server = setup
    local = Client.from_params(params, cfg)
    remote = Client.connect(server.address)
    rl = local.risk(TOKS, AGES, horizon=5.0, top=8)
    rr = remote.risk(TOKS, AGES, horizon=5.0, top=8)
    assert [i.token for i in rr.items] == [i.token for i in rl.items]
    np.testing.assert_allclose([i.risk for i in rr.items],
                               [i.risk for i in rl.items], rtol=1e-5)
    assert rr.backend == "remote[engine]"


# ---------------------------------------------------------------------------
# Error-code mapping (the satellite contract): every _validate failure is a
# stable code over HTTP, raised client-side as the same typed ApiError
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("payload,code", [
    ({"tokens": [], "ages": []}, "empty_trajectory"),
    ({"tokens": list(range(100)), "ages": [0.0] * 100}, "too_long"),
    ({"tokens": [3, 10]}, "ages_required"),
    ({"tokens": [3, 10], "ages": [0.0]}, "ages_length_mismatch"),
])
def test_http_error_codes(setup, payload, code):
    _, _, server = setup
    status, body = _post_raw(server.address, "/v1/generate", payload)
    assert status == 400
    assert body["error"]["code"] == code
    # and through RemoteBackend: same typed exception, same code
    remote = RemoteBackend(server.address)
    with pytest.raises(ApiError) as ei:
        remote.generate(GenerateRequest.from_json(dict(payload)))
    assert ei.value.code == code


def test_http_error_unsupported_override(setup):
    _, _, server = setup
    status, body = _post_raw(server.address, "/v1/generate",
                             {"tokens": TOKS, "ages": AGES, "max_age": 33.0})
    assert status == 400
    assert body["error"]["code"] == "unsupported_override"


def test_http_bad_uniforms_shape_is_structured(setup):
    """Short/misshapen uniforms must 400 with invalid_request instead of
    becoming an IndexError inside the engine loop (which would fail every
    other in-flight request)."""
    _, _, server = setup
    status, body = _post_raw(server.address, "/v1/generate",
                             {"tokens": TOKS, "ages": AGES, "max_new": 6,
                              "uniforms": [[0.5, 0.5]]})
    assert status == 400
    assert body["error"]["code"] == "invalid_request"
    # and the server keeps serving afterwards
    status, _ = _post_raw(server.address, "/v1/generate",
                          {"tokens": TOKS, "ages": AGES, "max_new": 2})
    assert status == 200


def test_http_engine_rejects_per_request_seed(setup):
    _, _, server = setup
    status, body = _post_raw(server.address, "/v1/generate",
                             {"tokens": TOKS, "ages": AGES, "seed": 7})
    assert status == 400
    assert body["error"]["code"] == "unsupported_override"


def test_http_error_invalid_json_and_unknown_endpoint(setup):
    _, _, server = setup
    req = urllib.request.Request(
        server.address + "/v1/generate", data=b"{not json",
        headers={"Content-Type": "application/json"}, method="POST")
    with pytest.raises(urllib.error.HTTPError) as ei:
        urllib.request.urlopen(req, timeout=30)
    assert ei.value.code == 400
    assert json.loads(ei.value.read())["error"]["code"] == "invalid_request"

    status, body = _post_raw(server.address, "/v1/nope", {})
    assert status == 404
    assert body["error"]["code"] == "unknown_endpoint"


def test_http_error_protocol_version(setup):
    """Every POST endpoint enforces the version handshake."""
    _, _, server = setup
    for path, payload in [
            ("/v1/generate", {"tokens": TOKS, "ages": AGES}),
            ("/v1/risk", {"tokens": TOKS, "ages": AGES}),
            ("/v1/stream", {"tokens": TOKS, "ages": AGES}),
            ("/v1/generate_batch", {"requests": []}),
    ]:
        status, body = _post_raw(server.address, path,
                                 {**payload, "protocol_version": "999"})
        assert status == 409, path
        assert body["error"]["code"] == "protocol_version_mismatch", path


def test_http_wrong_typed_fields_are_invalid_request(setup):
    """Coercion failures must be a 400 invalid_request, not a 500."""
    _, _, server = setup
    for path, payload in [
            ("/v1/generate", {"tokens": TOKS, "ages": AGES,
                              "max_new": "many"}),
            ("/v1/generate", {"tokens": ["x"], "ages": [0.0]}),
            ("/v1/risk", {"tokens": TOKS, "ages": AGES, "horizon": "x"}),
    ]:
        status, body = _post_raw(server.address, path, payload)
        assert status == 400, (path, payload)
        assert body["error"]["code"] == "invalid_request", (path, payload)


def test_engine_stop_unblocks_inflight_waiters(setup):
    """engine.stop() with requests in flight must fail them immediately —
    a background-mode waiter must never sit out request_timeout."""
    params, cfg, _ = setup
    backend = EngineBackend.create(params, cfg, slots=4, max_context=64)
    backend.request_timeout = 60.0
    backend.engine.start()
    outcome = {}

    def run():
        try:
            outcome["out"] = backend.generate_batch(
                [GenerateRequest(tokens=TOKS, ages=AGES, max_new=60)
                 for _ in range(8)])
        except Exception as e:              # noqa: BLE001
            outcome["err"] = e

    t = threading.Thread(target=run)
    t.start()
    time.sleep(0.2)
    backend.engine.stop()
    t.join(timeout=15)
    assert not t.is_alive()                 # unblocked promptly
    assert outcome                          # finished or structured error
    if "err" in outcome:
        assert "stopped" in str(outcome["err"])


def test_stream_validation_error_is_json_not_sse(setup):
    """Validation failures on /v1/stream must surface as plain JSON errors
    (proper status), not as an SSE body."""
    _, _, server = setup
    status, body = _post_raw(server.address, "/v1/stream",
                             {"tokens": [], "ages": []})
    assert status == 400
    assert body["error"]["code"] == "empty_trajectory"


def test_remote_stream_validates_eagerly(setup):
    """stream() raises at the call on the remote backend too — the POST
    fires (and the server's validation answer lands) before any next()."""
    _, _, server = setup
    remote = Client.connect(server.address)
    with pytest.raises(ApiError) as ei:
        remote.stream(tokens=[], ages=[])
    assert ei.value.code == "empty_trajectory"


def test_remote_rejects_rng_before_the_wire(setup):
    _, _, server = setup
    remote = Client.connect(server.address)
    with pytest.raises(ApiError) as ei:
        remote.generate(tokens=TOKS, ages=AGES,
                        rng=np.random.default_rng(0))
    assert ei.value.code == "rng_not_serializable"


# ---------------------------------------------------------------------------
# Serving a host-loop backend (artifact over the wire)
# ---------------------------------------------------------------------------
def test_serve_artifact_backend(setup, tmp_path):
    """The front-end is backend-agnostic: an exported FAIR artifact served
    over HTTP answers bit-identically to the engine-backed server."""
    params, cfg, server = setup
    from repro.sdk import export_model
    d = str(tmp_path / "art")
    export_model(params, cfg, d)
    art_server = InferenceServer(
        Client.from_artifact(d).backend, port=0).start()
    try:
        u = _uniforms(5, cfg.vocab_size, seed=3)
        via_engine = Client.connect(server.address).generate(
            tokens=TOKS, ages=AGES, max_new=5, uniforms=u)
        via_art = Client.connect(art_server.address).generate(
            tokens=TOKS, ages=AGES, max_new=5, uniforms=u)
        assert via_art.tokens == via_engine.tokens
        assert via_art.backend == "remote[artifact]"
        # FAIR manifest rides along on /v1/manifest
        m = RemoteBackend(art_server.address).server_manifest
        assert "artifact" in m and "provenance" in m["artifact"]
        evs = list(Client.connect(art_server.address).stream(
            tokens=TOKS, ages=AGES, max_new=5, uniforms=u))
        assert [e.token for e in evs] == via_art.tokens
    finally:
        art_server.stop()


# ---------------------------------------------------------------------------
# HTTP/1.1 keep-alive connection reuse
# ---------------------------------------------------------------------------
def test_keep_alive_reuses_one_connection(setup):
    """Sequential JSON calls ride ONE persistent connection (the req/s
    lever `benchmarks/run.py http` measures); SSE gets its own socket."""
    _, cfg, server = setup
    remote = RemoteBackend(server.address)
    assert remote.connections_opened == 1       # the manifest handshake
    for _ in range(3):
        remote.generate(GenerateRequest(tokens=TOKS, ages=AGES, max_new=2))
    remote.healthz()
    assert remote.connections_opened == 1
    list(remote.stream(GenerateRequest(tokens=TOKS, ages=AGES, max_new=2)))
    assert remote.connections_opened == 2       # SSE is close-delimited
    remote.generate(GenerateRequest(tokens=TOKS, ages=AGES, max_new=2))
    assert remote.connections_opened == 2       # back on the pooled socket
    remote.close()


def test_keep_alive_off_dials_per_call(setup):
    _, _, server = setup
    remote = RemoteBackend(server.address, keep_alive=False)
    n0 = remote.connections_opened
    remote.healthz()
    remote.healthz()
    assert remote.connections_opened == n0 + 2


def test_keep_alive_survives_stale_socket(setup):
    """A pooled socket the server has since dropped retries once on a
    fresh connection instead of failing the call."""
    _, _, server = setup
    remote = RemoteBackend(server.address)
    remote.healthz()
    remote._conn.close()                        # simulate idle drop
    assert remote.healthz()["ok"]


# ---------------------------------------------------------------------------
# Cancellation over the wire
# ---------------------------------------------------------------------------
def test_cancel_unknown_id(setup):
    _, _, server = setup
    remote = Client.connect(server.address)
    assert remote.cancel("no-such-request") is False
    status, body = _post_raw(server.address, "/v1/cancel", {})
    assert status == 400 and body["error"]["code"] == "invalid_request"


def test_cancel_uses_dedicated_connection(setup):
    """/v1/cancel must not queue behind the pooled connection — it usually
    targets the very call holding that connection."""
    _, _, server = setup
    remote = RemoteBackend(server.address)
    remote.healthz()
    n0 = remote.connections_opened
    remote.cancel("whatever")
    assert remote.connections_opened == n0 + 1


def test_unknown_endpoint_with_body_keeps_connection_in_sync(setup):
    """A 404'd POST whose body was never parsed must drain it: with
    keep-alive the leftover bytes would otherwise be read as the next
    request line, failing the following valid call on the connection."""
    _, _, server = setup
    remote = RemoteBackend(server.address)
    with pytest.raises(ApiError) as ei:
        remote._request("POST", "/v1/generte",        # typo'd endpoint
                        {"tokens": [1, 2, 3], "junk": "x" * 256})
    assert ei.value.code == "unknown_endpoint"
    # same pooled connection must still serve a valid request
    assert remote.healthz()["ok"]
    assert remote.connections_opened == 1


def test_duplicate_request_id_is_rejected(setup):
    """A second in-flight request reusing a request_id would clobber the
    cancel registry — refused as a structured 400."""
    from repro.api.errors import InvalidRequestError
    params, cfg, _ = setup
    backend = EngineBackend.create(params, cfg, slots=1, max_context=512,
                                   cache="paged", block_size=16)
    server = InferenceServer(backend, port=0).start()
    try:
        remote = Client.connect(server.address)
        remote.generate(tokens=TOKS, ages=AGES, max_new=2)   # warm
        results = []

        def blocker():
            try:
                results.append(remote.generate(
                    GenerateRequest(tokens=TOKS, ages=AGES, max_new=480,
                                    uniforms=_long_running_uniforms(480, cfg),
                                    request_id="dup")))
            except ApiError as e:       # cancelled at teardown
                results.append(e)
        t = threading.Thread(target=blocker)
        t.start()
        time.sleep(0.3)
        with pytest.raises(InvalidRequestError) as ei:
            Client.connect(server.address).generate(
                GenerateRequest(tokens=TOKS, ages=AGES, max_new=2,
                                request_id="dup"))
        assert ei.value.code == "invalid_request"
        backend.cancel("dup")
        t.join(30)
    finally:
        server.stop()


def test_sse_streams_per_event_not_buffered(setup):
    """Frames must hit the wire as events occur: the first SSE frame has
    to arrive while the request is still in flight (a starred-tuple drain
    in _do_stream once buffered the whole trajectory until completion,
    which also made mid-stream cancellation unobservable)."""
    params, cfg, _ = setup
    backend = EngineBackend.create(params, cfg, slots=1, max_context=512,
                                   cache="paged", block_size=16)
    server = InferenceServer(backend, port=0).start()
    try:
        remote = Client.connect(server.address)
        remote.generate(tokens=TOKS, ages=AGES, max_new=2,
                        uniforms=_long_running_uniforms(2, cfg))  # warm
        it = remote.stream(GenerateRequest(
            tokens=TOKS, ages=AGES, max_new=400,
            uniforms=_long_running_uniforms(400, cfg)))
        next(it)
        eng = backend.engine
        assert any(r is not None for r in eng.slot_req), \
            "first frame only arrived after the request completed"
        n = 1 + sum(1 for _ in it)
        assert n == 400
    finally:
        server.stop()


def test_cancel_inflight_stream_emits_cancelled_frame(setup):
    """Cancel propagates to slot eviction mid-decode; the victim's SSE
    stream terminates with a `cancelled` frame raised client-side as
    RequestCancelledError, and the engine leaks nothing.  (stream()
    returns only once the server commits the SSE body — i.e. after the
    victim's first event — so by the time cancel fires the victim is
    decoding in a slot, with ~479 events still to go.)"""
    from repro.api import RequestCancelledError
    params, cfg, _ = setup
    backend = EngineBackend.create(params, cfg, slots=1, max_context=512,
                                   cache="paged", block_size=16)
    server = InferenceServer(backend, port=0).start()
    try:
        remote = Client.connect(server.address)
        remote.generate(tokens=TOKS, ages=AGES, max_new=2,
                        uniforms=_long_running_uniforms(2, cfg))  # warm
        # throttle the tick: the reduced config decodes hundreds of events
        # per second, so an unthrottled victim could finish before the
        # cancel round-trip lands — 20ms/tick gives it a ~10s runway
        orig_step = backend.engine.step
        backend.engine.step = lambda: (time.sleep(0.02), orig_step())[1]
        it = remote.stream(GenerateRequest(
            tokens=TOKS, ages=AGES, max_new=480,
            uniforms=_long_running_uniforms(480, cfg),
            request_id="cancel-me"))
        got = [next(it)]             # first event: the victim is in-slot
        assert remote.cancel("cancel-me") is True
        with pytest.raises(RequestCancelledError) as ei:
            for ev in it:
                got.append(ev)
        assert ei.value.code == "request_cancelled"
        assert ei.value.http_status == 409
        assert len(got) < 480        # cut short, not drained
        h = remote.backend.healthz()
        assert h["engine"]["memory"]["blocks_used"] == 0
        assert h["engine"]["memory"]["cache"] == "paged"
    finally:
        server.stop()
