"""Mamba2 SSD: chunked == sequential recurrence; chunk-size invariance;
decode step == one more step of the recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.ref import ssd_ref
from repro.models.ssm import (empty_ssm_cache, init_ssm, ssd_chunked,
                              ssm_decode_step, ssm_forward)


def _ssd_inputs(key, B=2, S=64, H=4, P=8, N=16):
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.random.uniform(ks[1], (B, S, H), minval=0.01, maxval=0.2)
    A = -jax.random.uniform(ks[2], (H,), minval=0.5, maxval=4.0)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(jax.random.fold_in(key, 9), (B, S, N))
    return x, dt, A, Bm, Cm


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_chunked_equals_sequential(key, chunk):
    x, dt, A, Bm, Cm = _ssd_inputs(key)
    y, h = ssd_chunked(x * dt[..., None] / dt[..., None], dt, A, Bm, Cm, chunk)
    y_ref, h_ref = ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(y, y_ref, atol=1e-4)
    np.testing.assert_allclose(h, h_ref, atol=1e-4)


def test_chunk_size_invariance(key):
    x, dt, A, Bm, Cm = _ssd_inputs(key)
    y8, h8 = ssd_chunked(x, dt, A, Bm, Cm, 8)
    y32, h32 = ssd_chunked(x, dt, A, Bm, Cm, 32)
    np.testing.assert_allclose(y8, y32, atol=1e-4)
    np.testing.assert_allclose(h8, h32, atol=1e-4)


def test_initial_state_continuation(key):
    """Running [first half] then [second half | state] == full run."""
    x, dt, A, Bm, Cm = _ssd_inputs(key, S=64)
    y_full, h_full = ssd_chunked(x, dt, A, Bm, Cm, 16)
    y1, h1 = ssd_chunked(x[:, :32], dt[:, :32], A, Bm[:, :32], Cm[:, :32], 16)
    y2, h2 = ssd_chunked(x[:, 32:], dt[:, 32:], A, Bm[:, 32:], Cm[:, 32:], 16,
                         h0=h1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full, atol=1e-4)
    np.testing.assert_allclose(h2, h_full, atol=1e-4)


def test_block_prefill_then_decode(key):
    """Full-layer parity: prefill state + decode step == dense forward."""
    cfg = get_config("mamba2-780m", reduced=True).replace(dtype="float32")
    p = init_ssm(key, cfg)
    B, S = 2, 21
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S + 1, cfg.d_model))
    y_full = ssm_forward(p, x, cfg)
    y_pre, cache = ssm_forward(p, x[:, :S], cfg, return_state=True)
    y_dec, _ = ssm_decode_step(p, x[:, S:], cache, cfg)
    np.testing.assert_allclose(y_pre, y_full[:, :S], atol=1e-4)
    np.testing.assert_allclose(y_dec, y_full[:, S:], atol=1e-4)


def test_decay_bounds(key):
    """States stay bounded for long sequences (stability invariant)."""
    x, dt, A, Bm, Cm = _ssd_inputs(key, S=256)
    _, h = ssd_chunked(x, dt, A, Bm, Cm, 32)
    assert bool(jnp.isfinite(h).all())
    assert float(jnp.max(jnp.abs(h))) < 1e4
