"""Core layers: norms, RoPE relative property, age encoding."""
import jax
import jax.numpy as jnp
import numpy as np
from hypcompat import given, settings, st

from repro.configs import get_config
from repro.models.layers import (age_encoding, apply_norm, apply_rope,
                                 init_norm)


def test_rmsnorm_unit_rms(key):
    cfg = get_config("tinyllama-1.1b", reduced=True)
    p = init_norm(cfg, 64)
    x = jax.random.normal(key, (3, 5, 64)) * 7 + 2
    y = apply_norm(p, x, cfg)
    rms = jnp.sqrt(jnp.mean(jnp.square(y), -1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


def test_layernorm_standardizes(key):
    cfg = get_config("delphi-2m", reduced=True)   # layernorm family
    p = init_norm(cfg, 64)
    x = jax.random.normal(key, (3, 64)) * 7 + 2
    y = apply_norm(p, x, cfg)
    np.testing.assert_allclose(jnp.mean(y, -1), 0.0, atol=1e-4)
    np.testing.assert_allclose(jnp.std(y, -1), 1.0, rtol=1e-2)


def test_rope_preserves_norm(key):
    x = jax.random.normal(key, (2, 6, 4, 32))
    pos = jnp.arange(6, dtype=jnp.int32)
    y = apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(shift=st.integers(0, 10_000))
def test_rope_relative_property(shift):
    """q·k after RoPE depends only on the position difference — the property
    that makes ring-buffer caches at absolute offsets exact."""
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 1, 1, 64))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, 64))
    def dot_at(p0, p1):
        qr = apply_rope(q, jnp.array([p0], jnp.int32), 1e4)
        kr = apply_rope(k, jnp.array([p1], jnp.int32), 1e4)
        return float(jnp.sum(qr * kr))
    base = dot_at(7, 3)
    shifted = dot_at(7 + shift, 3 + shift)
    np.testing.assert_allclose(base, shifted, rtol=1e-3, atol=1e-4)


def test_age_encoding_shape_and_distinct():
    ages = jnp.array([[0.0, 10.0, 50.0, 50.001, 84.0]])
    enc = age_encoding(ages, 120)
    assert enc.shape == (1, 5, 120)
    # distinct ages produce distinct encodings; close ages close encodings
    d_far = float(jnp.linalg.norm(enc[0, 1] - enc[0, 2]))
    d_near = float(jnp.linalg.norm(enc[0, 2] - enc[0, 3]))
    assert d_far > d_near
    assert bool(jnp.isfinite(enc).all())


def test_age_encoding_bounded():
    enc = age_encoding(jnp.linspace(0, 100, 50)[None], 64)
    assert float(jnp.max(jnp.abs(enc))) <= 1.0 + 1e-6
