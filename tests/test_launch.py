"""Launch-layer pure logic: HLO collective parsing, depth-extrapolation
algebra, roofline math, input-spec construction (no 512-device mesh here)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_shape
from repro.launch.dryrun import _diff, _lin, _shape_bytes, collective_bytes
from repro.launch.roofline import (HBM_BW, ICI_BW, PEAK_FLOPS, active_params,
                                   analyse, model_flops, terms)


def test_shape_bytes():
    assert _shape_bytes("f32[128,1024]") == 128 * 1024 * 4
    assert _shape_bytes("bf16[2,3]") == 12
    assert _shape_bytes("pred[7]") == 7
    assert _shape_bytes("(f32[4], s32[2])") == 16 + 8
    assert _shape_bytes("f32[]") == 4


def test_collective_parsing():
    hlo = """
  %all-reduce = f32[128,64] all-reduce(%x), replica_groups=[2,4]<=[8]
  %ag = bf16[256] all-gather(%y), dimensions={0}
  %rs.1 = (f32[16], f32[16]) reduce-scatter(%a, %b), to_apply=%sum
  %cp = u32[4] collective-permute(%z), source_target_pairs={{0,1}}
  %a2a-start = f32[32,32] all-to-all-start(%w)
  %not-a-collective = f32[9] add(%p, %q)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 128 * 64 * 4
    assert out["all-gather"] == 256 * 2
    assert out["reduce-scatter"] == 2 * 16 * 4
    assert out["collective-permute"] == 16
    assert out["all-to-all"] == 32 * 32 * 4
    assert sum(out.values()) == 128 * 64 * 4 + 512 + 128 + 16 + 4096


def test_extrapolation_algebra():
    a = {"flops": 10.0, "bytes": 100.0, "collectives": {"all-reduce": 5}}
    b = {"flops": 16.0, "bytes": 130.0, "collectives": {"all-reduce": 8,
                                                        "all-gather": 2}}
    d = _diff(b, a)
    assert d == {"flops": 6.0, "bytes": 30.0,
                 "collectives": {"all-reduce": 3, "all-gather": 2}}
    # a + (L-1)*d for L=4
    out = _lin(a, d, 3)
    assert out["flops"] == 28.0 and out["bytes"] == 190.0
    assert out["collectives"] == {"all-reduce": 14, "all-gather": 6}


def _fake_rec(**kw):
    rec = {"arch": "tinyllama-1.1b", "shape": "train_4k", "mesh": "16x16",
           "n_chips": 256, "mode": "train", "seq_len": 4096,
           "global_batch": 256, "flops_per_device": 1e15,
           "bytes_per_device": 1e13, "collective_total": 1e10,
           "memory": {"peak_estimate_bytes": 2 ** 34}}
    rec.update(kw)
    return rec


def test_roofline_terms():
    t = terms(_fake_rec())
    np.testing.assert_allclose(t["compute_s"], 1e15 / PEAK_FLOPS)
    np.testing.assert_allclose(t["memory_s"], 1e13 / HBM_BW)
    np.testing.assert_allclose(t["collective_s"], 1e10 / ICI_BW)
    assert t["dominant"] == "memory"
    t2 = terms(_fake_rec(collective_total=1e13))
    assert t2["dominant"] == "collective"


def test_model_flops_conventions():
    cfg = get_config("tinyllama-1.1b")
    n = active_params(cfg)
    assert 0.9e9 < n < 1.4e9          # ~1.1B
    rec = _fake_rec()
    assert model_flops(cfg, rec) == pytest.approx(6 * n * 4096 * 256)
    rec_d = _fake_rec(mode="decode", global_batch=128)
    assert model_flops(cfg, rec_d) == pytest.approx(2 * n * 128)


def test_active_params_moe_counts_topk_only():
    moe = get_config("olmoe-1b-7b")
    n_active = active_params(moe)
    # olmoe: ~1B active of ~7B total
    assert 0.7e9 < n_active < 1.8e9
    q = get_config("qwen2.5-32b")
    assert 28e9 < active_params(q) < 36e9


def test_analyse_suggestion():
    a = analyse(_fake_rec())
    assert a["dominant"] == "memory"
    assert "useful_ratio" in a and 0 < a["useful_ratio"] < 1
    assert isinstance(a["suggestion"], str)


def test_input_specs_host_mesh():
    """Spec construction is mesh-size agnostic (host 1x1 mesh)."""
    from repro.launch.mesh import make_host_mesh
    from repro.launch.specs import input_specs
    mesh = make_host_mesh()
    cfg = get_config("tinyllama-1.1b", reduced=True)
    for shape_name in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
        shape = get_shape(shape_name)
        args, shardings = input_specs(cfg, shape, mesh)
        assert len(args) == len(shardings)
        # every leaf is a ShapeDtypeStruct (no allocation)
        for leaf in jax.tree_util.tree_leaves(args):
            assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_long500k_policy():
    from repro.launch.specs import long_context_cfg
    qwen = get_config("qwen2.5-32b")
    assert long_context_cfg(qwen, get_shape("long_500k")).sliding_window == 8192
    assert long_context_cfg(qwen, get_shape("decode_32k")).sliding_window is None
    mamba = get_config("mamba2-780m")
    assert long_context_cfg(mamba, get_shape("long_500k")).sliding_window is None
    danube = get_config("h2o-danube-1.8b")
    assert long_context_cfg(danube, get_shape("long_500k")).sliding_window == 4096
