"""Privacy audit harness: attack statistics, canary determinism, and an
end-to-end audit over a real backend."""
import json

import jax
import numpy as np
import pytest

from repro.api.client import LocalBackend
from repro.api.schemas import (FuturesResult, RiskItem, RiskReport,
                               TrajectoryResult)
from repro.configs import get_config
from repro.core import init_delphi
from repro.data import vocab as V
from repro.data.synthetic import SimulatorConfig, hazard_params
from repro.privacy import (Canary, PrivacyAuditReport, bootstrap_auc_ci,
                           extraction_probe, extraction_rate,
                           inject_canaries, make_canaries, membership_score,
                           rare_code_pool, roc_auc, run_audit,
                           split_canaries)


# ---------------------------------------------------------------------------
# Attack statistics
# ---------------------------------------------------------------------------
def test_roc_auc_units():
    assert roc_auc([2.0, 3.0], [0.0, 1.0]) == 1.0
    assert roc_auc([0.0, 1.0], [2.0, 3.0]) == 0.0
    assert roc_auc([1.0], [1.0]) == 0.5                 # tie -> 0.5
    assert roc_auc([], [1.0]) == 0.5                    # degenerate
    assert roc_auc([1.0], []) == 0.5
    # mixed: pairs (2>1)=1, (2>3)=0, (0>1)=0, (0>3)=0 -> 0.25
    assert roc_auc([2.0, 0.0], [1.0, 3.0]) == 0.25


def test_bootstrap_ci_brackets_and_deterministic():
    pos = [3.0, 4.0, 5.0, 2.5]
    neg = [0.0, 1.0, 2.0, 0.5]
    lo, hi = bootstrap_auc_ci(pos, neg, n_boot=100, seed=7)
    assert 0.0 <= lo <= hi <= 1.0
    assert (lo, hi) == bootstrap_auc_ci(pos, neg, n_boot=100, seed=7)
    assert bootstrap_auc_ci([], [1.0]) == (0.5, 0.5)


# ---------------------------------------------------------------------------
# Canaries
# ---------------------------------------------------------------------------
def test_canaries_deterministic_and_well_formed():
    cfg = SimulatorConfig(seed=0)
    c1 = make_canaries(6, cfg, seed=3, secret_len=4, prefix_events=8)
    c2 = make_canaries(6, cfg, seed=3, secret_len=4, prefix_events=8)
    assert len(c1) == 6
    pool = set(int(V.DISEASE0 + c) for c in rare_code_pool(cfg))
    a, _, _, _ = hazard_params(cfg)
    for x, y in zip(c1, c2):
        np.testing.assert_array_equal(x.tokens, y.tokens)
        np.testing.assert_array_equal(x.ages, y.ages)
        assert x.member == (x.index % 2 == 0)
        assert len(x.secret_tokens) == 4
        assert set(x.secret_tokens) <= pool
        assert np.all(np.diff(x.ages) >= 0)             # monotone ages
        assert V.DEATH not in list(x.prefix_tokens)[1:]  # secret has a future
        assert x.rarity == pytest.approx(
            -float(sum(a[t - V.DISEASE0] for t in x.secret_tokens)))
        assert x.rarity > 0                              # rare => -log h > 0
    # a different audit seed gives different canaries
    c3 = make_canaries(6, cfg, seed=4, secret_len=4, prefix_events=8)
    assert not np.array_equal(c1[0].tokens, c3[0].tokens)


def test_rare_pool_is_rarest_by_base_hazard():
    cfg = SimulatorConfig(seed=0)
    a, _, _, _ = hazard_params(cfg)
    pool = rare_code_pool(cfg)
    assert len(pool) == max(8, int(len(a) * 0.05))
    assert a[pool].max() <= np.delete(a, pool).min()


def test_inject_and_split():
    cfg = SimulatorConfig(seed=0)
    canaries = make_canaries(6, cfg, seed=1)
    members, nonmembers = split_canaries(canaries)
    assert len(members) == 3 and len(nonmembers) == 3
    train = [(np.asarray([3, 20], np.int32),
              np.asarray([0.0, 1.0], np.float32))]
    out = inject_canaries(train, canaries, repeats=2)
    assert len(out) == 1 + 3 * 2
    np.testing.assert_array_equal(out[1][0], members[0].tokens)
    out[1][0][0] = -1                                   # copies, not views
    assert members[0].tokens[0] != -1


# ---------------------------------------------------------------------------
# Probes against a rigged backend
# ---------------------------------------------------------------------------
class _MemorizingBackend:
    """Assigns high next-event probability to a member's secret tokens
    and regurgitates them under sampling; uniform on everything else."""
    name = "memorizing"

    def __init__(self, members, vocab_size=V.VOCAB_SIZE):
        self.vocab_size = vocab_size
        self._known = {tuple(int(t) for t in c.tokens): c for c in members}

    def _lookup(self, tokens):
        for full, c in self._known.items():
            k = len(tokens)
            if k < len(full) and full[:k] == tuple(tokens):
                return full[k]
        return None

    def risk(self, tokens, ages, *, horizon, top):
        nxt = self._lookup([int(t) for t in tokens])
        if nxt is None:                                 # uniform model
            p = 1.0 / self.vocab_size
            items = [RiskItem(token=t, risk=p) for t in range(top)]
        else:
            items = [RiskItem(token=nxt, risk=0.9)]
        return RiskReport(horizon=horizon, items=items)

    def sample_futures(self, req):
        nxt = self._lookup(list(req.tokens))
        toks, ages = list(req.tokens), list(req.ages)
        out_t = []
        while nxt is not None and len(out_t) < req.max_new:
            out_t.append(nxt)
            toks.append(nxt)
            nxt = self._lookup(toks)
        traj = TrajectoryResult(
            tokens=out_t or [V.NO_EVENT],
            ages=[float(ages[-1]) + i + 1.0
                  for i in range(len(out_t) or 1)],
            prompt_tokens=[int(t) for t in req.tokens],
            prompt_ages=[float(a) for a in req.ages], backend=self.name)
        return FuturesResult(
            risk=RiskReport(horizon=req.horizon, items=[]),
            trajectories=[traj] * req.n_futures,
            n_futures=req.n_futures, backend=self.name)


def test_probes_separate_members_from_heldout():
    cfg = SimulatorConfig(seed=0)
    canaries = make_canaries(8, cfg, seed=2)
    members, nonmembers = split_canaries(canaries)
    b = _MemorizingBackend(members)
    for m in members:
        assert membership_score(b, m) > membership_score(
            b, nonmembers[0]) + 1.0
        assert extraction_probe(b, m, n_futures=2, max_new=8, match=2)
    rate_m, flags = extraction_rate(b, members, n_futures=2, max_new=8)
    rate_n, _ = extraction_rate(b, nonmembers, n_futures=2, max_new=8)
    assert rate_m == 1.0 and all(flags) and rate_n == 0.0
    report = run_audit(b, members, nonmembers, n_futures=2, max_new=8,
                       n_boot=50)
    assert report.mi_auc == 1.0
    assert report.extraction_gap == 1.0
    assert report.mi_auc_ci[0] <= report.mi_auc <= report.mi_auc_ci[1] \
        or report.mi_auc_ci == (1.0, 1.0)


def test_report_json_roundtrip():
    r = PrivacyAuditReport(backend="x", n_members=2, n_nonmembers=2,
                           mi_auc=0.75, mi_auc_ci=(0.5, 1.0),
                           member_scores=[-1.0, -2.0],
                           nonmember_scores=[-3.0, -4.0],
                           member_extraction_rate=0.5,
                           nonmember_extraction_rate=0.0,
                           config={"seed": 1})
    r2 = PrivacyAuditReport.from_json(json.loads(json.dumps(r.to_json())))
    assert r2 == r
    assert r.to_json()["extraction_gap"] == 0.5


# ---------------------------------------------------------------------------
# End-to-end over a real (untrained) model
# ---------------------------------------------------------------------------
def test_run_audit_local_backend_smoke():
    """An untrained model should sit near chance: the audit machinery
    must run through the full public surface and return sane numbers."""
    cfg = get_config("delphi-2m", reduced=True).replace(
        dtype="float32", vocab_size=1289)
    params = init_delphi(cfg, jax.random.PRNGKey(2))
    backend = LocalBackend(params, cfg)
    canaries = make_canaries(4, SimulatorConfig(seed=0), seed=0,
                             secret_len=3, prefix_events=4)
    members, nonmembers = split_canaries(canaries)
    report = run_audit(backend, members, nonmembers, n_futures=2,
                       max_new=4, n_boot=25)
    assert report.n_members == 2 and report.n_nonmembers == 2
    assert 0.0 <= report.mi_auc <= 1.0
    assert all(s < 0 for s in report.member_scores + report.nonmember_scores)
    assert 0.0 <= report.member_extraction_rate <= 1.0
    d = report.to_json()
    assert d["backend"] == backend.name and d["config"]["n_boot"] == 25
