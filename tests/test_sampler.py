"""Time-to-event sampler (paper eq. 1): distributional correctness,
determinism, termination semantics (C3, C4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, hnp, settings, st

from repro.configs import get_config
from repro.core import (generate_trajectories, init_delphi,
                        sample_next_event, sample_waiting_times)


def test_argmin_is_softmax_categorical(key):
    """P(argmin_i t_i = j) = softmax(logits)_j — the competing-exponential
    property the paper's sampler relies on."""
    logits = jnp.array([1.2, 0.0, -1.0, 2.0])
    n = 40_000
    u = jax.random.uniform(key, (n, 4))
    evt, _ = sample_next_event(jnp.broadcast_to(logits, (n, 4)), u)
    freq = np.bincount(np.asarray(evt), minlength=4) / n
    np.testing.assert_allclose(freq, jax.nn.softmax(logits), atol=0.01)


def test_tmin_is_exponential_total_rate(key):
    """t_min ~ Exp(sum_i e^{logit_i}): check the mean."""
    logits = jnp.array([0.5, 0.5, -0.5])
    lam = float(jnp.sum(jnp.exp(logits)))
    n = 40_000
    u = jax.random.uniform(key, (n, 3))
    _, tmin = sample_next_event(jnp.broadcast_to(logits, (n, 3)), u)
    np.testing.assert_allclose(float(jnp.mean(tmin)), 1 / lam, rtol=0.05)


@settings(max_examples=30, deadline=None)
@given(
    logits=hnp.arrays(np.float32, (9,),
                      elements=st.floats(-5, 5, width=32,
                                         allow_subnormal=False)),
    seed=st.integers(0, 2**20),
)
def test_deterministic_given_uniforms(logits, seed):
    u = np.random.default_rng(seed).uniform(size=9).astype(np.float32)
    e1, t1 = sample_next_event(jnp.asarray(logits)[None], jnp.asarray(u)[None])
    e2, t2 = sample_next_event(jnp.asarray(logits)[None], jnp.asarray(u)[None])
    assert int(e1[0]) == int(e2[0]) and float(t1[0]) == float(t2[0])
    # the winner's candidate time equals t_min
    t = sample_waiting_times(jnp.asarray(logits), jnp.asarray(u))
    assert float(t1[0]) == float(t[int(e1[0])])


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**20))
def test_monotonicity_in_logit(seed):
    """Raising logit_j (with u fixed) can only shrink t_j — so it can only
    make j more likely to win."""
    rng = np.random.default_rng(seed)
    logits = rng.normal(size=7).astype(np.float32)
    u = rng.uniform(size=7).astype(np.float32)
    t0 = sample_waiting_times(jnp.asarray(logits), jnp.asarray(u))
    logits2 = logits.copy()
    logits2[3] += 1.0
    t1 = sample_waiting_times(jnp.asarray(logits2), jnp.asarray(u))
    assert float(t1[3]) <= float(t0[3])
    mask = np.arange(7) != 3
    np.testing.assert_allclose(np.asarray(t0)[mask], np.asarray(t1)[mask])


@pytest.fixture(scope="module")
def delphi():
    cfg = get_config("delphi-2m", reduced=True).replace(
        dtype="float32", vocab_size=64, death_token=1)
    params = init_delphi(cfg, jax.random.PRNGKey(7))
    return params, cfg


def test_generation_termination_max_age(delphi, key):
    params, cfg = delphi
    B, S = 3, 8
    tokens = jax.random.randint(key, (B, S), 3, cfg.vocab_size)
    ages = jnp.cumsum(jax.random.uniform(key, (B, S), maxval=10.0), axis=1)
    out = generate_trajectories(params, cfg, tokens, ages, key, max_new=32,
                                max_age=cfg.max_age)
    # ages never exceed max_age and are non-decreasing
    assert float(jnp.max(out["ages"])) <= cfg.max_age + 1e-3
    diffs = jnp.diff(out["ages"], axis=1)
    assert float(jnp.min(diffs)) >= -1e-5


def test_generation_stops_at_death(delphi, key):
    params, cfg = delphi
    B, S = 2, 4
    tokens = jax.random.randint(key, (B, S), 3, cfg.vocab_size)
    ages = jnp.cumsum(jax.random.uniform(key, (B, S), maxval=2.0), axis=1)
    # rig uniforms so the death token always wins step 0: t = -e^-l ln(u),
    # so u -> 1 makes t -> 0 (death wins) and u -> 0 makes t huge (others)
    V = cfg.vocab_size
    u = jnp.full((B, 16, V), 1e-30)
    u = u.at[:, :, cfg.death_token].set(1.0 - 1e-9)
    out = generate_trajectories(params, cfg, tokens, ages, key, max_new=16,
                                uniforms=u)
    assert out["n_generated"].tolist() == [1, 1]
    assert out["tokens"][:, S].tolist() == [cfg.death_token] * B
    assert not bool(out["alive_mask"][:, 1:].any())
