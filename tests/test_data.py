"""Synthetic simulator + pipeline invariants (hypothesis where meaningful)."""
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.data import (SimulatorConfig, batches, dataset_stats,
                        generate_dataset, pack_trajectories)
from repro.data import vocab as V
from repro.data.synthetic import _hazard_params, simulate_patient


@pytest.fixture(scope="module")
def small_ds():
    return generate_dataset(SimulatorConfig(n_train=80, n_val=20, seed=3))


def test_deterministic(small_ds):
    tr2, _ = generate_dataset(SimulatorConfig(n_train=80, n_val=20, seed=3))
    t0, a0 = small_ds[0][0]
    t1, a1 = tr2[0]
    np.testing.assert_array_equal(t0, t1)
    np.testing.assert_array_equal(a0, a1)


def test_trajectory_invariants(small_ds):
    train, _ = small_ds
    for tok, age in train:
        assert tok[0] in (V.SEX_FEMALE, V.SEX_MALE)
        assert age[0] == 0.0
        assert np.all(np.diff(age) >= 0)                  # ages non-decreasing
        assert np.all(age <= 85.0 + 1e-5)
        if V.DEATH in tok:
            assert tok[-1] == V.DEATH                     # death is terminal
        dis = tok[tok >= V.DISEASE0]
        assert len(np.unique(dis)) == len(dis)            # first-occurrence
        assert np.all(tok < V.VOCAB_SIZE) and np.all(tok >= 0)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_patient_invariants_property(seed):
    rng = np.random.default_rng(seed)
    cfg = SimulatorConfig()
    a, b, partners, boosts = _hazard_params(rng, cfg)
    tok, age = simulate_patient(rng, a, b, partners, boosts, cfg)
    assert len(tok) == len(age)
    assert np.all(np.diff(age) >= 0)
    assert (tok == V.DEATH).sum() <= 1


def test_pack_shapes_and_mask(small_ds):
    train, _ = small_ds
    S = 64
    p = pack_trajectories(train, S)
    n = len(train)
    for k in ("tokens", "ages", "targets", "target_dt", "loss_mask"):
        assert p[k].shape == (n, S)
    # mask excludes PAD and NO_EVENT targets
    masked = p["targets"][p["loss_mask"] > 0]
    assert not np.isin(masked, [V.PAD, V.NO_EVENT]).any()
    # dt strictly positive where supervised
    assert np.all(p["target_dt"][p["loss_mask"] > 0] > 0)
    # targets are the shifted tokens where supervised
    i, j = np.nonzero(p["loss_mask"])
    np.testing.assert_array_equal(p["targets"][i, j], p["tokens"][i, j + 1])


def test_batches_iterator(small_ds):
    train, _ = small_ds
    p = pack_trajectories(train, 32)
    it = batches(p, 16, seed=0, epochs=1)
    seen = 0
    for b in it:
        assert b["tokens"].shape == (16, 32)
        seen += 1
    assert seen == len(train) // 16


def test_stats(small_ds):
    train, _ = small_ds
    s = dataset_stats(train)
    assert 0.3 < s["death_frac"] <= 1.0
    assert 40 < s["mean_last_age"] < 85
    assert s["mean_diseases"] > 3


def test_vocab_names():
    assert V.code_name(V.DEATH) == "Death"
    assert V.code_name(V.DISEASE0).startswith("A")
    assert V.code_name(V.VOCAB_SIZE - 1).startswith("Z")
    assert len(V.all_names()) == V.VOCAB_SIZE == 1289


# ---------------------------------------------------------------------------
# O(1) per-patient access (cohort workloads)
# ---------------------------------------------------------------------------
def test_patient_o1_determinism():
    from repro.data.synthetic import cohort, patient
    cfg = SimulatorConfig(seed=5)
    t1, a1 = patient(17, cfg)
    t2, a2 = patient(17, cfg)
    np.testing.assert_array_equal(t1, t2)
    np.testing.assert_array_equal(a1, a2)
    # order-independent: regenerating out of order matches a fresh draw
    c = cohort([3, 17], cfg)
    np.testing.assert_array_equal(c[1][0], t1)
    # distinct indices and distinct seeds give distinct streams
    assert not np.array_equal(patient(18, cfg)[0], t1) or \
        not np.array_equal(patient(18, cfg)[1], a1)
    assert not np.array_equal(patient(17, SimulatorConfig(seed=6))[0], t1) \
        or not np.array_equal(patient(17, SimulatorConfig(seed=6))[1], a1)


def test_patient_invariants():
    from repro.data.synthetic import patient
    cfg = SimulatorConfig(seed=0)
    for i in range(20):
        tok, age = patient(i, cfg)
        assert tok[0] in (V.SEX_FEMALE, V.SEX_MALE)
        assert age[0] == 0.0
        assert np.all(np.diff(age) >= 0)
        assert np.all((tok >= 1) & (tok < V.VOCAB_SIZE))
        assert tok.dtype == np.int32 and age.dtype == np.float32


def test_hazard_params_match_seeded_rng():
    from repro.data.synthetic import hazard_params
    cfg = SimulatorConfig(seed=11)
    a, b, partners, boosts = hazard_params(cfg)
    a2, b2, p2, bo2 = _hazard_params(np.random.default_rng(cfg.seed), cfg)
    np.testing.assert_array_equal(a, a2)
    np.testing.assert_array_equal(b, b2)
    np.testing.assert_array_equal(partners, p2)
    np.testing.assert_array_equal(boosts, bo2)
    # cached: same object back on the second call
    assert hazard_params(cfg)[0] is a


def test_generate_dataset_unchanged_by_patient_api():
    """patient(i) is a NEW stream family; the sequential split must stay
    bit-stable (checked against frozen digests of seed=3)."""
    import hashlib
    from repro.data.synthetic import patient
    tr, _ = generate_dataset(SimulatorConfig(n_train=4, n_val=1, seed=3))
    h = hashlib.sha256()
    for tok, age in tr:
        h.update(tok.tobytes())
        h.update(age.tobytes())
    assert h.hexdigest() == ("fed998c557d346a1eb192edfdf188d75"
                             "db504a3744ab13269391481369e95791")
    # and patient(0) deliberately differs from sequential patient 0
    assert not np.array_equal(patient(0, SimulatorConfig(seed=3))[0], tr[0][0])


def test_patient_cross_process_determinism():
    """SimulatorConfig(seed=0) patients are identical across interpreter
    processes (no hash-seed / import-order dependence)."""
    import subprocess
    import sys
    prog = (
        "import hashlib, numpy as np\n"
        "from repro.data.synthetic import SimulatorConfig, patient\n"
        "h = hashlib.sha256()\n"
        "for i in range(8):\n"
        "    tok, age = patient(i, SimulatorConfig(seed=0))\n"
        "    h.update(tok.tobytes()); h.update(age.tobytes())\n"
        "print(h.hexdigest())\n")
    digests = {
        subprocess.run([sys.executable, "-c", prog], check=True,
                       capture_output=True, text=True).stdout.strip()
        for _ in range(2)}
    assert len(digests) == 1 and all(len(d) == 64 for d in digests)
