"""Risk estimation + calibration harness (the App's displayed output)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import init_delphi
from repro.core.risk import (analytic_next_event_risk, disease_chapter_map,
                             monte_carlo_risk, next_event_risk)


@pytest.fixture(scope="module")
def delphi():
    cfg = get_config("delphi-2m", reduced=True).replace(
        dtype="float32", vocab_size=1289)
    params = init_delphi(cfg, jax.random.PRNGKey(2))
    return params, cfg


def test_analytic_risk_properties(key):
    logits = jax.random.normal(key, (3, 50))
    r = analytic_next_event_risk(logits, horizon=5.0)
    assert r.shape == (3, 50)
    assert float(jnp.min(r)) >= 0
    total = jnp.sum(r, axis=-1)
    assert float(jnp.max(total)) <= 1.0 + 1e-5
    # monotone in horizon
    r2 = analytic_next_event_risk(logits, horizon=10.0)
    assert bool((r2 >= r - 1e-7).all())
    # infinite horizon -> softmax
    r_inf = analytic_next_event_risk(logits, horizon=1e9)
    np.testing.assert_allclose(r_inf, jax.nn.softmax(logits, -1), atol=1e-5)


def test_next_event_risk_shape(delphi, key):
    params, cfg = delphi
    tokens = jax.random.randint(key, (2, 8), 3, cfg.vocab_size)
    ages = jnp.cumsum(jax.random.uniform(key, (2, 8), maxval=5.0), axis=1)
    r = next_event_risk(params, cfg, tokens, ages, horizon=5.0)
    assert r.shape == (2, cfg.vocab_size)
    assert bool(jnp.isfinite(r).all())


def test_monte_carlo_risk(delphi, key):
    params, cfg = delphi
    tokens = jax.random.randint(key, (6,), 3, cfg.vocab_size)
    ages = jnp.cumsum(jax.random.uniform(key, (6,), maxval=8.0))
    ch = disease_chapter_map(cfg.vocab_size)
    r = monte_carlo_risk(params, cfg, tokens, ages, jax.random.PRNGKey(1),
                         horizon=10.0, n_samples=16, max_new=12,
                         chapter_of=ch)
    assert r["code_risk"].shape == (cfg.vocab_size,)
    assert 0.0 <= float(r["death_risk"]) <= 1.0
    assert r["chapter_risk"].shape[0] == 27
    assert float(jnp.max(r["chapter_risk"])) <= 1.0 + 1e-6


def test_sdk_estimate_risk(delphi, tmp_path):
    params, cfg = delphi
    from repro.sdk import InferenceSession, export_model
    d = str(tmp_path / "art")
    export_model(params, cfg.replace(max_seq_len=32), d)
    sess = InferenceSession(d)
    out = sess.estimateRisk([3, 40, 50], [0.0, 20.0, 33.0], horizon=5.0,
                            top=5)
    assert len(out) == 5
    risks = [o["risk"] for o in out]
    assert risks == sorted(risks, reverse=True)
    assert all(0 <= r <= 1 for r in risks)


def test_calibration_harness(delphi):
    params, cfg = delphi
    from repro.core.calibration import calibration_report, cohort_stats
    from repro.data import SimulatorConfig, generate_dataset
    held, _ = generate_dataset(SimulatorConfig(n_train=40, n_val=1, seed=9))
    rep = calibration_report(params, cfg, held, n_batches=1)
    assert 0.0 <= rep["chapter_l1"] <= 2.0
    assert rep["data"]["events_per_year"] > 0
