"""Risk estimation + calibration harness (the App's displayed output)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import init_delphi
from repro.core.risk import (analytic_next_event_risk, disease_chapter_map,
                             monte_carlo_risk, next_event_risk)


@pytest.fixture(scope="module")
def delphi():
    cfg = get_config("delphi-2m", reduced=True).replace(
        dtype="float32", vocab_size=1289)
    params = init_delphi(cfg, jax.random.PRNGKey(2))
    return params, cfg


def test_analytic_risk_properties(key):
    logits = jax.random.normal(key, (3, 50))
    r = analytic_next_event_risk(logits, horizon=5.0)
    assert r.shape == (3, 50)
    assert float(jnp.min(r)) >= 0
    total = jnp.sum(r, axis=-1)
    assert float(jnp.max(total)) <= 1.0 + 1e-5
    # monotone in horizon
    r2 = analytic_next_event_risk(logits, horizon=10.0)
    assert bool((r2 >= r - 1e-7).all())
    # infinite horizon -> softmax
    r_inf = analytic_next_event_risk(logits, horizon=1e9)
    np.testing.assert_allclose(r_inf, jax.nn.softmax(logits, -1), atol=1e-5)


def test_next_event_risk_shape(delphi, key):
    params, cfg = delphi
    tokens = jax.random.randint(key, (2, 8), 3, cfg.vocab_size)
    ages = jnp.cumsum(jax.random.uniform(key, (2, 8), maxval=5.0), axis=1)
    r = next_event_risk(params, cfg, tokens, ages, horizon=5.0)
    assert r.shape == (2, cfg.vocab_size)
    assert bool(jnp.isfinite(r).all())


def test_monte_carlo_risk(delphi, key):
    params, cfg = delphi
    tokens = jax.random.randint(key, (6,), 3, cfg.vocab_size)
    ages = jnp.cumsum(jax.random.uniform(key, (6,), maxval=8.0))
    ch = disease_chapter_map(cfg.vocab_size)
    r = monte_carlo_risk(params, cfg, tokens, ages, jax.random.PRNGKey(1),
                         horizon=10.0, n_samples=16, max_new=12,
                         chapter_of=ch)
    assert r["code_risk"].shape == (cfg.vocab_size,)
    assert 0.0 <= float(r["death_risk"]) <= 1.0
    assert r["chapter_risk"].shape[0] == 27
    assert float(jnp.max(r["chapter_risk"])) <= 1.0 + 1e-6


def test_sdk_estimate_risk(delphi, tmp_path):
    params, cfg = delphi
    from repro.sdk import InferenceSession, export_model
    d = str(tmp_path / "art")
    export_model(params, cfg.replace(max_seq_len=32), d)
    sess = InferenceSession(d)
    out = sess.estimateRisk([3, 40, 50], [0.0, 20.0, 33.0], horizon=5.0,
                            top=5)
    assert len(out) == 5
    risks = [o["risk"] for o in out]
    assert risks == sorted(risks, reverse=True)
    assert all(0 <= r <= 1 for r in risks)


def test_calibration_harness(delphi):
    params, cfg = delphi
    from repro.core.calibration import calibration_report, cohort_stats
    from repro.data import SimulatorConfig, generate_dataset
    held, _ = generate_dataset(SimulatorConfig(n_train=40, n_val=1, seed=9))
    rep = calibration_report(params, cfg, held, n_batches=1)
    assert 0.0 <= rep["chapter_l1"] <= 2.0
    assert rep["data"]["events_per_year"] > 0


# ---------------------------------------------------------------------------
# Host futures aggregation (cohort path) — edge cases
# ---------------------------------------------------------------------------
def test_futures_risk_items_edges():
    from repro.core.risk import futures_risk_items
    # no trajectories at all -> all-zero risks, still top-k shaped
    items = futures_risk_items([], 50.0, 5.0, vocab_size=10, top=3)
    assert len(items) == 3 and all(r == 0.0 for _, r in items)
    # empty futures and all-censored futures contribute nothing
    items = futures_risk_items(
        [([], []), ([7, 8], [99.0, 100.0])], 50.0, 5.0, vocab_size=10)
    assert all(r == 0.0 for _, r in items)
    # numpy-array ages and fp32 boundary: age exactly at cutoff counts
    toks = np.asarray([4, 5], np.int32)
    ags = np.asarray([55.0, 55.0000001], np.float32)     # == cutoff in fp32
    items = dict(futures_risk_items([(toks, ags)], 50.0, 5.0,
                                    vocab_size=10, top=10))
    assert items[4] == 1.0
    cutoff = np.float32(np.float32(50.0) + np.float32(5.0))
    assert items[5] == (1.0 if np.float32(ags[1]) <= cutoff else 0.0)
    # ages=None counts every token; out-of-vocab tokens are dropped
    items = dict(futures_risk_items([([2, 3, 42], None)], 0.0, 1.0,
                                    vocab_size=10, top=10))
    assert items[2] == 1.0 and items[3] == 1.0 and 42 not in items


def test_futures_chapter_risk_hand_example():
    from repro.core.risk import disease_chapter_map_np, futures_chapter_risk
    V_ = 1289
    chap = disease_chapter_map_np(V_)
    c20, c700 = int(chap[20]), int(chap[700])
    assert c20 != 0 and c700 != 0 and c20 != c700
    futs = [([20, 700], [51.0, 52.0]),      # both chapters
            ([20, 21], [51.0, 52.0]),       # same chapter twice -> counts 1
            ([700], [99.0]),                # censored (past cutoff)
            ([1], [51.0])]                  # DEATH -> chapter 0 bucket
    r = futures_chapter_risk(futs, 50.0, 5.0, V_)
    assert r.shape == (27,)
    assert r[c20] == 0.5 and r[c700] == 0.25 and r[0] == 0.25
    assert futures_chapter_risk([], 50.0, 5.0, V_).sum() == 0.0


def test_disease_chapter_map_edges():
    from repro.core.risk import disease_chapter_map, disease_chapter_map_np
    from repro.data import vocab as V
    m = disease_chapter_map_np(1289)
    assert m.dtype == np.int32 and m.shape == (1289,)
    assert np.all(m[:V.DISEASE0] == 0)                  # specials/lifestyle
    assert m[V.DISEASE0] == 1 and m.max() == 26
    np.testing.assert_array_equal(np.asarray(disease_chapter_map(1289)), m)
    # truncated vocab (reduced configs) stays consistent
    m96 = disease_chapter_map_np(96)
    np.testing.assert_array_equal(m96, m[:96])


def test_pack_futures_trajectories_shapes():
    from repro.core.risk import pack_futures_trajectories
    toks = np.asarray([3, 20, 30], np.int32)
    ags = np.asarray([0.0, 10.0, 20.0], np.float32)
    futs = [([40, 50], [21.0, 22.0]), ([], [])]
    p = pack_futures_trajectories(toks, ags, futs, max_new=4)
    assert p["tokens"].shape == (2, 7) and p["ages"].shape == (2, 7)
    np.testing.assert_array_equal(np.asarray(p["tokens"][0]),
                                  [3, 20, 30, 40, 50, 0, 0])
    np.testing.assert_array_equal(np.asarray(p["alive_mask"]),
                                  [[True, True, False, False]] +
                                  [[False] * 4])
    # padded ages clamp to the last real age (empty future -> history end)
    assert float(p["ages"][0, -1]) == 22.0
    assert float(p["ages"][1, -1]) == 20.0
    np.testing.assert_array_equal(np.asarray(p["n_generated"]), [2, 0])
