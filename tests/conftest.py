import jax
import pytest

# Tests run on the single host CPU device (the 512-device override lives ONLY
# in dryrun.py).  fp32 everywhere for tight tolerances.
jax.config.update("jax_enable_x64", False)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


def f32(cfg):
    return cfg.replace(dtype="float32")
