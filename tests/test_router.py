"""Multi-replica router: prefix-affinity scheduling, supervision, failover.

The acceptance contract of the serving tier's horizontal layer: a
``RouterServer`` over N in-process replicas is a drop-in for a single
``InferenceServer`` (bit-identical results under injected uniforms, same
wire errors), shared histories route to the replica that already holds
their prefix blocks, a replica crashing mid-stream surfaces the structured
``replica_unavailable`` error on the pinned stream while fresh calls retry
on survivors, and the survivor's pool keeps its zero-leak invariant.
"""
import json
import time
import urllib.error
import urllib.request

import jax
import numpy as np
import pytest

from repro.api import (Client, GenerateRequest, RemoteBackend,
                       ReplicaUnavailableError, WIRE_PROTOCOL_VERSION)
from repro.api.client import EngineBackend
from repro.api.errors import RequestCancelledError
from repro.configs import get_config
from repro.core import init_delphi
from repro.serve.prefix import prompt_digests
from repro.serve.router import (PrefixAffinityScheduler, ReplicaSupervisor,
                                RouterServer)
from repro.serve.server import InferenceServer

TOKS = [3, 10, 20]
AGES = [0.0, 15.0, 28.0]


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("delphi-2m", reduced=True).replace(
        dtype="float32", vocab_size=96, max_seq_len=48, max_age=1e9)
    params = init_delphi(cfg, jax.random.PRNGKey(7))
    return params, cfg


def _make_backend_factory(params, cfg):
    def make_backend(i):
        return EngineBackend.create(params, cfg, slots=4, max_context=64,
                                    cache="paged", prefix_cache=True)
    return make_backend


@pytest.fixture(scope="module")
def router2(setup):
    """Two in-process replicas behind one router (non-destructive tests)."""
    params, cfg = setup
    sup = ReplicaSupervisor.in_process(
        _make_backend_factory(params, cfg), 2, probe_interval=0.1)
    router = RouterServer(sup, port=0).start()
    yield router
    router.stop()


@pytest.fixture(scope="module")
def direct(setup):
    """Single direct engine server: the bit-parity reference."""
    params, cfg = setup
    backend = EngineBackend.create(params, cfg, slots=4, max_context=64,
                                   cache="paged", prefix_cache=True)
    server = InferenceServer(backend, port=0).start()
    yield server
    server.stop()


def _uniforms(max_new, V, seed=42):
    rng = np.random.default_rng(seed)
    return rng.uniform(size=(max_new, V)).astype(np.float32)


def _long_running_uniforms(max_new, cfg, seed=42):
    u = _uniforms(max_new, cfg.vocab_size, seed)
    u[:, cfg.death_token] = 1e-12
    return u


def _post_raw(url, path, payload):
    req = urllib.request.Request(
        url + path, data=json.dumps(payload).encode(),
        headers={"Content-Type": "application/json"}, method="POST")
    try:
        with urllib.request.urlopen(req, timeout=60) as resp:
            return resp.status, json.loads(resp.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


# ---------------------------------------------------------------------------
# prompt_digests: the shared router/replica vocabulary
# ---------------------------------------------------------------------------
def test_prompt_digests_chain_extends():
    toks = list(range(3, 40))
    ages = [float(i) for i in range(len(toks))]
    chain_short, key_short = prompt_digests(toks[:32], ages[:32], 16)
    chain_long, key_long = prompt_digests(toks, ages, 16)
    # a longer prompt's chain extends the shorter one's chain exactly
    assert chain_long[:len(chain_short)] == chain_short
    assert len(chain_short) == 2 and len(chain_long) == 2
    assert key_short != key_long            # whole-prompt keys fold length


# ---------------------------------------------------------------------------
# Scheduler unit tests (no HTTP)
# ---------------------------------------------------------------------------
class _FakeReplica:
    def __init__(self, name, free=None, inflight=0):
        self.name = name
        self._free = free
        self.inflight = inflight

    def free_blocks(self):
        return self._free


def test_scheduler_affinity_and_fallback():
    sched = PrefixAffinityScheduler(block_size=4)
    a, b = _FakeReplica("a", free=10), _FakeReplica("b", free=20)
    toks = list(range(3, 15))
    ages = [float(i) for i in range(len(toks))]
    r1, aff1 = sched.route(toks, ages, [a, b])
    assert not aff1 and r1 is b             # fallback: most free blocks
    # same prefix again: affinity holds it on b even though loads changed
    b.inflight = 5
    r2, aff2 = sched.route(toks, ages, [a, b])
    assert aff2 and r2 is b
    # an EXTENSION of the prefix still lands on b (chain walk)
    r3, aff3 = sched.route(toks + [77, 78, 79, 80], ages + [12., 13., 14., 15.],
                           [a, b])
    assert aff3 and r3 is b
    # a disjoint history falls back again
    r4, aff4 = sched.route([50, 51, 52, 53, 54], [0., 1., 2., 3., 4.], [a, b])
    assert not aff4
    st = sched.stats()
    assert st["affinity_routed"] == 2 and st["fallback_routed"] == 2
    assert st["tracked_digests"] > 0


def test_scheduler_forget_and_candidate_filter():
    sched = PrefixAffinityScheduler(block_size=4)
    a, b = _FakeReplica("a", free=10), _FakeReplica("b", free=5)
    toks, ages = list(range(3, 11)), [float(i) for i in range(8)]
    r1, _ = sched.route(toks, ages, [a, b])
    assert r1 is a
    # owner not in the candidate set (dead / draining): falls back
    r2, aff2 = sched.route(toks, ages, [b])
    assert r2 is b and not aff2
    # forget a dead replica's digests entirely
    dropped = sched.forget("b")
    assert dropped > 0
    r3, aff3 = sched.route(toks, ages, [a, b])
    assert not aff3                         # b's claim was forgotten
    with pytest.raises(ReplicaUnavailableError):
        sched.route(toks, ages, [])


def test_scheduler_least_loaded_tiebreak():
    sched = PrefixAffinityScheduler(block_size=4)
    a = _FakeReplica("a", free=None, inflight=3)
    b = _FakeReplica("b", free=None, inflight=1)
    r, aff = sched.route([3, 4, 5], [0., 1., 2.], [a, b])
    assert r is b and not aff               # unknown pools: fewest in-flight


# ---------------------------------------------------------------------------
# Supervisor: probing + health state machine
# ---------------------------------------------------------------------------
def test_supervisor_marks_unhealthy_after_consecutive_failures():
    # adopt a port nothing listens on: every probe fails
    sup = ReplicaSupervisor.adopt(["http://127.0.0.1:9"],
                                  probe_timeout=0.2)
    lost = []
    sup.on_unhealthy = lost.append
    r = sup.replicas[0]
    assert r.healthy                        # optimistic until proven dead
    for i in range(r.max_failures - 1):
        sup.probe_once()
        assert r.healthy and not lost
    sup.probe_once()                        # crosses the threshold
    assert not r.healthy and lost == ["r0"]
    sup.probe_once()                        # edge fires once, not per probe
    assert lost == ["r0"]
    assert sup.healthy() == []


def test_supervisor_probe_restores_health(router2):
    # probe an in-process replica through a second supervisor adopting it
    url = router2.supervisor.replicas[0].url
    sup = ReplicaSupervisor.adopt([url], probe_timeout=2.0)
    r = sup.replicas[0]
    r.probe_failed(), r.probe_failed(), r.probe_failed()
    assert not r.healthy
    sup.probe_once()                        # server answers: restored
    assert r.healthy
    snap = r.snapshot()
    assert snap["consecutive_failures"] == 0
    assert snap["healthz"]["ok"] is True


# ---------------------------------------------------------------------------
# Router wire surface: parity with a direct server
# ---------------------------------------------------------------------------
def test_router_manifest(router2, setup):
    _, cfg = setup
    with urllib.request.urlopen(router2.address + "/v1/manifest") as r:
        m = json.loads(r.read())
    assert m["protocol_version"] == WIRE_PROTOCOL_VERSION
    assert m["backend"] == "router[engine]"
    assert m["model"]["vocab_size"] == cfg.vocab_size
    assert set(m["router"]["replicas"]) == {"r0", "r1"}


def test_router_generate_bit_parity(router2, direct, setup):
    _, cfg = setup
    u = _uniforms(8, cfg.vocab_size)
    via_router = Client.connect(router2.address).generate(
        tokens=TOKS, ages=AGES, max_new=8, uniforms=u)
    via_direct = Client.connect(direct.address).generate(
        tokens=TOKS, ages=AGES, max_new=8, uniforms=u)
    assert via_router.tokens == via_direct.tokens
    assert via_router.ages == via_direct.ages
    assert via_router.backend.startswith("remote[router[r")
    assert via_router.request_id is not None    # router-assigned id echoes


def test_router_stream_parity(router2, direct, setup):
    _, cfg = setup
    u = _uniforms(8, cfg.vocab_size)
    req = GenerateRequest(tokens=TOKS, ages=AGES, max_new=8, uniforms=u)
    ev_router = list(Client.connect(router2.address).backend.stream(req))
    ev_direct = list(Client.connect(direct.address).backend.stream(req))
    assert [(e.token, e.age) for e in ev_router] == \
           [(e.token, e.age) for e in ev_direct]


def test_router_futures_and_risk(router2, direct, setup):
    from repro.api import FuturesRequest
    _, cfg = setup
    remote_r = Client.connect(router2.address)
    remote_d = Client.connect(direct.address)
    u = np.stack([_uniforms(6, cfg.vocab_size, seed=100 + i)
                  for i in range(3)])
    req = FuturesRequest(tokens=TOKS, ages=AGES, n_futures=3, max_new=6,
                         uniforms=u, horizon=5.0, top=5)
    fr = remote_r.backend.sample_futures(req)
    fd = remote_d.backend.sample_futures(req)
    assert [t.tokens for t in fr.trajectories] == \
           [t.tokens for t in fd.trajectories]
    assert [(i.token, i.risk) for i in fr.risk.items] == \
           [(i.token, i.risk) for i in fd.risk.items]
    assert fr.backend.startswith("remote[router[r")
    rep_r = remote_r.risk(TOKS, AGES, horizon=5.0, top=5)
    rep_d = remote_d.risk(TOKS, AGES, horizon=5.0, top=5)
    assert [(i.token, i.risk) for i in rep_r.items] == \
           [(i.token, i.risk) for i in rep_d.items]
    assert rep_r.backend.startswith("remote[router[r")


def test_router_validation_error_passthrough(router2):
    # replica-side validation failures keep their stable codes and statuses
    status, body = _post_raw(router2.address, "/v1/generate",
                             {"protocol_version": WIRE_PROTOCOL_VERSION,
                              "tokens": [], "max_new": 4})
    assert status == 400
    assert body["error"]["code"] == "empty_trajectory"


def test_router_affinity_counters_and_healthz(router2, setup):
    _, cfg = setup
    remote = Client.connect(router2.address)
    u = _uniforms(2, cfg.vocab_size)
    shared_toks = [5] * 20
    shared_ages = [float(i) for i in range(20)]
    before = remote.backend.healthz()["router"]["scheduler"]
    for i in range(4):
        remote.generate(tokens=shared_toks + [10 + i],
                        ages=shared_ages + [21.0],
                        max_new=2, uniforms=u)
    h = remote.backend.healthz()
    sched = h["router"]["scheduler"]
    # first routed the prefix somewhere; the repeats must follow it
    assert sched["affinity_routed"] >= before["affinity_routed"] + 3
    assert h["ok"] and h["backend"] == "router"
    reps = h["router"]["replicas"]
    assert set(reps) == {"r0", "r1"}
    for snap in reps.values():
        assert snap["healthy"] and snap["healthz"]["ok"]
        assert "blocks_free" in snap["healthz"]["engine"]["memory"]
    # the probe rollup carries each replica's prefix hit-rate delta
    time.sleep(0.3)                         # let a probe land post-traffic
    h2 = remote.backend.healthz()
    deltas = [s["prefix"] for s in h2["router"]["replicas"].values()]
    assert all(d is not None and "hit_rate" in d and "hits_delta" in d
               for d in deltas)


def test_router_pinned_cancel(router2, setup):
    _, cfg = setup
    u = _long_running_uniforms(40, cfg)
    remote = Client.connect(router2.address)
    it = remote.backend.stream(GenerateRequest(
        tokens=TOKS, ages=AGES, max_new=40, uniforms=u,
        request_id="pin-cancel-1"))
    next(it)                                # stream committed and pinned
    pinned = router2.pinned_replica("pin-cancel-1")
    assert pinned in ("r0", "r1")
    status, body = _post_raw(router2.address, "/v1/cancel",
                             {"protocol_version": WIRE_PROTOCOL_VERSION,
                              "request_id": "pin-cancel-1"})
    assert status == 200
    assert body["cancelled"] is True
    assert body["replica"] == pinned        # routed by pin, not broadcast
    with pytest.raises(RequestCancelledError):
        list(it)
    # terminal frame unwinds the pin
    deadline = time.time() + 5.0
    while router2.pinned_replica("pin-cancel-1") and time.time() < deadline:
        time.sleep(0.02)
    assert router2.pinned_replica("pin-cancel-1") is None


def test_cancel_unknown_id_fans_out(router2):
    status, body = _post_raw(router2.address, "/v1/cancel",
                             {"protocol_version": WIRE_PROTOCOL_VERSION,
                              "request_id": "never-seen"})
    assert status == 200
    assert body["cancelled"] is False and body["replica"] is None


def test_remote_backend_timeout_split(router2):
    rb = RemoteBackend(router2.address, connect_timeout=0.5,
                       read_timeout=77.0)
    assert rb.connect_timeout == 0.5 and rb.read_timeout == 77.0
    rb.close()
    rb2 = RemoteBackend(router2.address, timeout=33.0)
    assert rb2.connect_timeout == 33.0 and rb2.read_timeout == 33.0
    rb2.close()


# ---------------------------------------------------------------------------
# Failover: a replica dies mid-stream (destructive — own router)
# ---------------------------------------------------------------------------
def test_failover_mid_stream_kill(setup):
    params, cfg = setup
    sup = ReplicaSupervisor.in_process(
        _make_backend_factory(params, cfg), 2, probe_interval=0.1)
    router = RouterServer(sup, port=0).start()
    try:
        remote = Client.connect(router.address)
        u = _long_running_uniforms(40, cfg)
        it = remote.backend.stream(GenerateRequest(
            tokens=TOKS, ages=AGES, max_new=40, uniforms=u,
            request_id="doomed-stream"))
        next(it)                            # committed: pinned to a replica
        victim = router.pinned_replica("doomed-stream")
        assert victim is not None
        sup.replica(victim).kill()
        # the PINNED stream surfaces the structured replica_unavailable —
        # never a silent replay of already-emitted events on the survivor
        with pytest.raises(ReplicaUnavailableError):
            list(it)
        # fresh idempotent calls retry onto the survivor
        survivor = [r.name for r in sup.replicas if r.name != victim][0]
        out = remote.generate(tokens=TOKS, ages=AGES, max_new=4,
                              uniforms=u[:4])
        assert f"router[{survivor}:" in out.backend
        h = remote.backend.healthz()
        assert h["ok"]
        assert h["router"]["replicas"][victim]["healthy"] is False
        assert h["router"]["replicas"][survivor]["healthy"] is True
        # zero-leak invariant on the survivor's pool: stop ticking, drop
        # the prefix index, and every block must return to the allocator
        eng = sup.replica(survivor).server.backend.engine
        eng.stop()
        eng.drop_prefix_cache()
        st = eng.pool_stats()
        assert st["blocks_used"] == 0 and st["shared_blocks"] == 0
    finally:
        router.stop()


def test_all_replicas_down_is_structured_503(setup):
    params, cfg = setup
    sup = ReplicaSupervisor.in_process(
        _make_backend_factory(params, cfg), 2, probe_interval=0.1)
    router = RouterServer(sup, port=0).start()
    try:
        remote = Client.connect(router.address)
        for r in list(sup.replicas):
            r.kill()
        status, body = _post_raw(router.address, "/v1/generate",
                                 {"protocol_version": WIRE_PROTOCOL_VERSION,
                                  "tokens": TOKS, "ages": AGES,
                                  "max_new": 2, "seed": 0})
        assert status == 503
        assert body["error"]["code"] == "replica_unavailable"
        with pytest.raises(ReplicaUnavailableError):
            remote.generate(tokens=TOKS, ages=AGES, max_new=2, seed=0)
        h = remote.backend.healthz()
        assert h["ok"] is False
    finally:
        router.stop()


def test_drain_then_stop(setup):
    params, cfg = setup
    sup = ReplicaSupervisor.in_process(
        _make_backend_factory(params, cfg), 2, probe_interval=0.1)
    router = RouterServer(sup, port=0).start()
    try:
        remote = Client.connect(router.address)
        u = _uniforms(2, cfg.vocab_size)
        remote.generate(tokens=TOKS, ages=AGES, max_new=2, uniforms=u)
        drained = router.drain_replica("r0", timeout=10.0)
        assert drained
        assert not sup.replica("r0").accepting
        # every subsequent request lands on r1
        for _ in range(3):
            out = remote.generate(tokens=TOKS, ages=AGES, max_new=2,
                                  uniforms=u)
            assert "router[r1:" in out.backend
        assert router.scheduler.stats()["tracked_digests"] >= 0
    finally:
        router.stop()
