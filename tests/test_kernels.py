"""Pallas kernel sweeps vs the ref.py oracles (interpret mode on CPU).

Each kernel is swept over shapes and dtypes per the mandate.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.kernels import (flash_attention, paged_decode_attention, ssd_intra,
                           suffix_prefill_attention, tte_sample)
from repro.kernels import ref

# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
FLASH_CASES = [
    # (B, Hq, Hkv, S, hd, window, dtype)
    (1, 1, 1, 128, 64, None, jnp.float32),
    (2, 4, 2, 256, 64, None, jnp.float32),
    (2, 4, 1, 256, 32, None, jnp.float32),      # strong GQA
    (1, 2, 2, 384, 128, 100, jnp.float32),      # sliding window
    (1, 2, 2, 200, 64, None, jnp.float32),      # ragged -> padding path
    (2, 2, 2, 256, 64, None, jnp.bfloat16),     # bf16 in/out
    (1, 8, 2, 128, 16, 40, jnp.float32),
]


@pytest.mark.parametrize("B,Hq,Hkv,S,hd,window,dtype", FLASH_CASES)
def test_flash_vs_ref(key, B, Hq, Hkv, S, hd, window, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Hq, S, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, hd)).astype(dtype)
    out = flash_attention(q, k, v, causal=True, window=window, bq=128, bk=128)
    r = ref.flash_attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                          v.astype(jnp.float32), causal=True, window=window)
    atol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out.astype(jnp.float32), r, atol=atol)


def test_flash_bidirectional(key):
    B, H, S, hd = 1, 2, 256, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, H, S, hd))
    v = jax.random.normal(ks[2], (B, H, S, hd))
    out = flash_attention(q, k, v, causal=False)
    r = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, r, atol=2e-5)


# ---------------------------------------------------------------------------
# paged decode attention (block-table gather + softmax in one pass)
# ---------------------------------------------------------------------------
PAGED_CASES = [
    # (B, Hkv, G, hd, bs, nbs, window, dtype)
    (1, 1, 1, 32, 4, 2, None, jnp.float32),
    (2, 2, 2, 16, 4, 4, None, jnp.float32),
    (3, 1, 4, 64, 8, 2, None, jnp.float32),     # strong GQA
    (2, 2, 1, 16, 4, 4, 6, jnp.float32),        # sliding window
    (2, 2, 2, 32, 8, 4, None, jnp.bfloat16),    # bf16 pool
]


def _paged_inputs(key, B, Hkv, G, hd, bs, nbs, dtype, *, wrap=False):
    """A consistent pool: slot b holds n_tok sequential tokens, blockwise."""
    rng = np.random.default_rng(
        int(jax.random.randint(key, (), 0, 2**31 - 1)))
    NB = 1 + B * nbs
    W = nbs * bs
    k_pool = jnp.asarray(rng.normal(size=(NB, Hkv, bs, hd))).astype(dtype)
    v_pool = jnp.asarray(rng.normal(size=(NB, Hkv, bs, hd))).astype(dtype)
    q = jnp.asarray(rng.normal(size=(B, Hkv * G, hd))).astype(dtype)
    table = np.full((B, nbs), -1, np.int32)
    pos = np.full((NB, bs), -1, np.int32)
    step = np.zeros((B,), np.int32)
    nxt = 1
    for b in range(B):
        n_tok = int(rng.integers(1, W))
        step[b] = n_tok + (W if wrap else 0)
        nalloc = -(-n_tok // bs)
        for jb in range(nalloc):
            table[b, jb] = nxt
            for o in range(bs):
                p = jb * bs + o
                if p < n_tok:
                    pos[nxt, o] = p + (W if wrap else 0)
            nxt += 1
    return q, k_pool, v_pool, jnp.asarray(table), jnp.asarray(pos), \
        jnp.asarray(step)


@pytest.mark.parametrize("B,Hkv,G,hd,bs,nbs,window,dtype", PAGED_CASES)
def test_paged_decode_vs_ref(key, B, Hkv, G, hd, bs, nbs, window, dtype):
    q, k_pool, v_pool, table, pos, step = _paged_inputs(
        key, B, Hkv, G, hd, bs, nbs, dtype)
    out = paged_decode_attention(q, k_pool, v_pool, table, pos, step,
                                 window=window)
    r = ref.paged_decode_attention_ref(
        q.reshape(B, Hkv, G, hd).astype(jnp.float32),
        k_pool.astype(jnp.float32), v_pool.astype(jnp.float32),
        table, pos, step, window=window)
    atol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out.reshape(B, Hkv, G, hd), np.float32), r, atol=atol)


def test_paged_decode_wrapped_ring_eviction(key):
    """step >= W with stale pre-wrap positions still in the pool: the
    kernel's `p > step - W` eviction mask must agree with the oracle (the
    one clause plain causal masking doesn't cover), and evicted entries
    must not contribute at all."""
    B, Hkv, G, hd, bs, nbs = 2, 2, 2, 16, 4, 4
    W = nbs * bs
    q, k_pool, v_pool, table, pos, step = _paged_inputs(
        key, B, Hkv, G, hd, bs, nbs, jnp.float32, wrap=True)
    # plant stale entries: every other valid position falls back a full
    # ring width, landing at or below step - W (evicted)
    pos_np = np.asarray(pos).copy()
    valid = pos_np >= 0
    stale = valid & (np.arange(pos_np.shape[1])[None, :] % 2 == 0)
    pos_np[stale] -= W
    pos = jnp.asarray(pos_np)
    assert (pos_np[stale] <= int(step.max()) - W).all()
    out = paged_decode_attention(q, k_pool, v_pool, table, pos, step)
    r = ref.paged_decode_attention_ref(
        q.reshape(B, Hkv, G, hd), k_pool, v_pool, table, pos, step)
    np.testing.assert_allclose(np.asarray(out.reshape(B, Hkv, G, hd)), r,
                               atol=2e-5)
    # pushing evicted entries further into the past changes nothing
    pos2 = jnp.asarray(np.where(stale, pos_np - 5 * W, pos_np))
    out2 = paged_decode_attention(q, k_pool, v_pool, table, pos2, step)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


def test_paged_decode_skips_unallocated_blocks(key):
    """Unallocated table entries are index-clamped to the trash block; its
    contents must not leak into the output (pl.when skip)."""
    B, Hkv, G, hd, bs, nbs = 1, 1, 1, 16, 4, 4
    q, k_pool, v_pool, table, pos, step = _paged_inputs(
        key, B, Hkv, G, hd, bs, nbs, jnp.float32)
    out = paged_decode_attention(q, k_pool, v_pool, table, pos, step)
    # poison the trash block: output must be unchanged
    k2 = k_pool.at[0].set(1e9)
    v2 = v_pool.at[0].set(1e9)
    out2 = paged_decode_attention(q, k2, v2, table, pos, step)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


# ---------------------------------------------------------------------------
# suffix prefill attention (chunked prefill over cached context)
# ---------------------------------------------------------------------------
SUFFIX_CASES = [
    # (B, Sc, C, Hkv, G, hd, window, dtype)
    (1, 16, 0, 1, 1, 32, None, jnp.float32),     # chunk at the prompt head
    (2, 16, 32, 2, 2, 32, None, jnp.float32),    # GQA mid-prompt chunk
    (1, 8, 24, 1, 4, 64, None, jnp.float32),     # strong GQA
    (2, 16, 16, 2, 1, 16, 12, jnp.float32),      # sliding window
    (1, 16, 32, 2, 2, 32, None, jnp.bfloat16),   # bf16 cache
]


@pytest.mark.parametrize("B,Sc,C,Hkv,G,hd,window,dtype", SUFFIX_CASES)
def test_suffix_prefill_vs_ref(key, B, Sc, C, Hkv, G, hd, window, dtype):
    """suffix_prefill_attention vs ref.suffix_prefill_attention_ref over
    right-padded contexts (trash slots = pos -1) and padded chunk tails."""
    ks = jax.random.split(key, 5)
    Hq = Hkv * G
    q = jax.random.normal(ks[0], (B, Sc, Hq, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, Sc, Hkv, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, Sc, Hkv, hd)).astype(dtype)
    ctx_k = jax.random.normal(ks[3], (B, C, Hkv, hd)).astype(dtype)
    ctx_v = jax.random.normal(ks[4], (B, C, Hkv, hd)).astype(dtype)
    n_ctx = max(C - 3, 0)
    n_q = Sc - 2
    ctx_pos = np.full((B, C), -1, np.int32)
    ctx_pos[:, :n_ctx] = np.arange(n_ctx)
    q_pos = np.full((B, Sc), -1, np.int32)
    q_pos[:, :n_q] = n_ctx + np.arange(n_q)
    out = suffix_prefill_attention(q, k, v, ctx_k, ctx_v,
                                   jnp.asarray(q_pos), jnp.asarray(ctx_pos),
                                   window=window, q_per_kv=G)
    r = ref.suffix_prefill_attention_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        ctx_k.astype(jnp.float32), ctx_v.astype(jnp.float32),
        jnp.asarray(q_pos), jnp.asarray(ctx_pos), window=window, q_per_kv=G)
    atol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out[:, :n_q], np.float32),
                               np.asarray(r[:, :n_q]), atol=atol)


def test_suffix_prefill_composes_with_flash(key):
    """A mid-prompt suffix chunk attending over its prefix-as-context must
    equal the same rows of ONE full flash_attention pass over the whole
    prompt — the invariant that makes chunked prefill a pure scheduling
    change."""
    B, S, C, H, hd = 1, 48, 32, 2, 32
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    full = flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                           v.transpose(0, 2, 1, 3), causal=True)
    pos = jnp.arange(S)[None]
    out = suffix_prefill_attention(q[:, C:], k[:, C:], v[:, C:],
                                   k[:, :C], v[:, :C],
                                   pos[:, C:], pos[:, :C])
    np.testing.assert_allclose(
        np.asarray(out),
        np.asarray(full.transpose(0, 2, 1, 3)[:, C:]), atol=2e-5)


# ---------------------------------------------------------------------------
# SSD intra-chunk
# ---------------------------------------------------------------------------
SSD_CASES = [
    # (BH, C, Q, P, N, dtype)
    (1, 1, 16, 8, 8, jnp.float32),
    (4, 3, 32, 16, 32, jnp.float32),
    (2, 2, 128, 64, 128, jnp.float32),   # production tile (mamba2-780m)
    (2, 2, 64, 32, 64, jnp.bfloat16),
]


@pytest.mark.parametrize("BH,C,Q,P,N,dtype", SSD_CASES)
def test_ssd_intra_vs_ref(key, BH, C, Q, P, N, dtype):
    ks = jax.random.split(key, 4)
    xdt = jax.random.normal(ks[0], (BH, C, Q, P)).astype(dtype)
    Bm = jax.random.normal(ks[1], (BH, C, Q, N)).astype(dtype)
    Cm = jax.random.normal(ks[2], (BH, C, Q, N)).astype(dtype)
    cum = -jnp.cumsum(jax.random.uniform(ks[3], (BH, C, Q), maxval=0.2), -1)
    y, st_ = ssd_intra(xdt, Bm, Cm, cum)
    atol = 1e-4 if dtype == jnp.float32 else 0.15
    for b in range(BH):
        for c in range(C):
            yr, sr = ref.ssd_intra_ref(xdt[b, c].astype(jnp.float32),
                                       Bm[b, c].astype(jnp.float32),
                                       Cm[b, c].astype(jnp.float32),
                                       cum[b, c])
            np.testing.assert_allclose(y[b, c], yr, atol=atol)
            np.testing.assert_allclose(st_[b, c], sr, atol=atol)


# ---------------------------------------------------------------------------
# time-to-event sampler
# ---------------------------------------------------------------------------
TTE_CASES = [
    (1, 64), (3, 1289), (2, 2048), (1, 50304),
    (2, 100),   # heavy padding path
]


@pytest.mark.parametrize("B,V", TTE_CASES)
def test_tte_vs_ref(key, B, V):
    ks = jax.random.split(key, 2)
    logits = jax.random.normal(ks[0], (B, V)) * 3
    u = jax.random.uniform(ks[1], (B, V))
    e1, t1 = tte_sample(logits, u)
    e2, t2 = ref.tte_sample_ref(logits, u)
    assert e1.tolist() == e2.tolist()
    np.testing.assert_allclose(t1, t2, rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), V=st.integers(5, 700))
def test_tte_property_sweep(seed, V):
    k = jax.random.PRNGKey(seed)
    logits = jax.random.normal(k, (1, V)) * 2
    u = jax.random.uniform(jax.random.fold_in(k, 1), (1, V))
    e1, t1 = tte_sample(logits, u)
    e2, t2 = ref.tte_sample_ref(logits, u)
    assert int(e1[0]) == int(e2[0])
    np.testing.assert_allclose(t1, t2, rtol=1e-6)


def test_tte_matches_core_sampler(key):
    """Kernel == the in-graph sampler used by serving (one mechanism,
    three consumers: kernel, core, SDK)."""
    from repro.core import sample_next_event
    logits = jax.random.normal(key, (4, 999)) * 2
    u = jax.random.uniform(jax.random.fold_in(key, 1), (4, 999))
    e_k, t_k = tte_sample(logits, u)
    e_c, t_c = sample_next_event(logits, u)
    assert e_k.tolist() == e_c.tolist()
    np.testing.assert_allclose(t_k, t_c, rtol=1e-5)
