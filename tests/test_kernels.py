"""Pallas kernel sweeps vs the ref.py oracles (interpret mode on CPU).

Each kernel is swept over shapes and dtypes per the mandate.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.kernels import flash_attention, ssd_intra, tte_sample
from repro.kernels import ref

# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
FLASH_CASES = [
    # (B, Hq, Hkv, S, hd, window, dtype)
    (1, 1, 1, 128, 64, None, jnp.float32),
    (2, 4, 2, 256, 64, None, jnp.float32),
    (2, 4, 1, 256, 32, None, jnp.float32),      # strong GQA
    (1, 2, 2, 384, 128, 100, jnp.float32),      # sliding window
    (1, 2, 2, 200, 64, None, jnp.float32),      # ragged -> padding path
    (2, 2, 2, 256, 64, None, jnp.bfloat16),     # bf16 in/out
    (1, 8, 2, 128, 16, 40, jnp.float32),
]


@pytest.mark.parametrize("B,Hq,Hkv,S,hd,window,dtype", FLASH_CASES)
def test_flash_vs_ref(key, B, Hq, Hkv, S, hd, window, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Hq, S, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, hd)).astype(dtype)
    out = flash_attention(q, k, v, causal=True, window=window, bq=128, bk=128)
    r = ref.attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                          v.astype(jnp.float32), causal=True, window=window)
    atol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out.astype(jnp.float32), r, atol=atol)


def test_flash_bidirectional(key):
    B, H, S, hd = 1, 2, 256, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, H, S, hd))
    v = jax.random.normal(ks[2], (B, H, S, hd))
    out = flash_attention(q, k, v, causal=False)
    r = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, r, atol=2e-5)


# ---------------------------------------------------------------------------
# SSD intra-chunk
# ---------------------------------------------------------------------------
SSD_CASES = [
    # (BH, C, Q, P, N, dtype)
    (1, 1, 16, 8, 8, jnp.float32),
    (4, 3, 32, 16, 32, jnp.float32),
    (2, 2, 128, 64, 128, jnp.float32),   # production tile (mamba2-780m)
    (2, 2, 64, 32, 64, jnp.bfloat16),
]


@pytest.mark.parametrize("BH,C,Q,P,N,dtype", SSD_CASES)
def test_ssd_intra_vs_ref(key, BH, C, Q, P, N, dtype):
    ks = jax.random.split(key, 4)
    xdt = jax.random.normal(ks[0], (BH, C, Q, P)).astype(dtype)
    Bm = jax.random.normal(ks[1], (BH, C, Q, N)).astype(dtype)
    Cm = jax.random.normal(ks[2], (BH, C, Q, N)).astype(dtype)
    cum = -jnp.cumsum(jax.random.uniform(ks[3], (BH, C, Q), maxval=0.2), -1)
    y, st_ = ssd_intra(xdt, Bm, Cm, cum)
    atol = 1e-4 if dtype == jnp.float32 else 0.15
    for b in range(BH):
        for c in range(C):
            yr, sr = ref.ssd_intra_ref(xdt[b, c].astype(jnp.float32),
                                       Bm[b, c].astype(jnp.float32),
                                       Cm[b, c].astype(jnp.float32),
                                       cum[b, c])
            np.testing.assert_allclose(y[b, c], yr, atol=atol)
            np.testing.assert_allclose(st_[b, c], sr, atol=atol)


# ---------------------------------------------------------------------------
# time-to-event sampler
# ---------------------------------------------------------------------------
TTE_CASES = [
    (1, 64), (3, 1289), (2, 2048), (1, 50304),
    (2, 100),   # heavy padding path
]


@pytest.mark.parametrize("B,V", TTE_CASES)
def test_tte_vs_ref(key, B, V):
    ks = jax.random.split(key, 2)
    logits = jax.random.normal(ks[0], (B, V)) * 3
    u = jax.random.uniform(ks[1], (B, V))
    e1, t1 = tte_sample(logits, u)
    e2, t2 = ref.tte_sample_ref(logits, u)
    assert e1.tolist() == e2.tolist()
    np.testing.assert_allclose(t1, t2, rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), V=st.integers(5, 700))
def test_tte_property_sweep(seed, V):
    k = jax.random.PRNGKey(seed)
    logits = jax.random.normal(k, (1, V)) * 2
    u = jax.random.uniform(jax.random.fold_in(k, 1), (1, V))
    e1, t1 = tte_sample(logits, u)
    e2, t2 = ref.tte_sample_ref(logits, u)
    assert int(e1[0]) == int(e2[0])
    np.testing.assert_allclose(t1, t2, rtol=1e-6)


def test_tte_matches_core_sampler(key):
    """Kernel == the in-graph sampler used by serving (one mechanism,
    three consumers: kernel, core, SDK)."""
    from repro.core import sample_next_event
    logits = jax.random.normal(key, (4, 999)) * 2
    u = jax.random.uniform(jax.random.fold_in(key, 1), (4, 999))
    e_k, t_k = tte_sample(logits, u)
    e_c, t_c = sample_next_event(logits, u)
    assert e_k.tolist() == e_c.tolist()
    np.testing.assert_allclose(t_k, t_c, rtol=1e-5)
