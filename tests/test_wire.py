"""Wire protocol v1: canonical JSON forms + the structured error taxonomy.

Every schema must round-trip bit-exactly through its ``to_json``/``from_json``
pair (numpy uniforms via base64 raw bytes), requests must reject live host
PRNG state at the serialization boundary, and every error carries a stable
machine-readable code while staying a ``ValueError`` for the legacy SDK
contract."""
import json

import numpy as np
import pytest

from repro.api import (AgesLengthMismatchError, AgesRequiredError, ApiError,
                       EmptyTrajectoryError, GenerateRequest,
                       ProtocolVersionError, RiskItem, RiskReport,
                       RngNotSerializableError, TooLongError,
                       TrajectoryEvent, TrajectoryResult,
                       WIRE_PROTOCOL_VERSION, error_from_code,
                       error_from_json)
from repro.api.errors import (InvalidRequestError, ReplicaUnavailableError,
                              RequestCancelledError, RequestTimeoutError,
                              UnknownEndpointError, UnsupportedOverrideError)

from hypcompat import given, settings, st


# ---------------------------------------------------------------------------
# GenerateRequest
# ---------------------------------------------------------------------------
def test_generate_request_roundtrip_full():
    u = np.random.default_rng(0).uniform(size=(5, 17)).astype(np.float32)
    req = GenerateRequest(tokens=[3, 10, 20], ages=[0.0, 15.5, 28.25],
                          max_new=5, max_age=80.0, death_token=1,
                          uniforms=u, seed=9)
    d = json.loads(json.dumps(req.to_json()))       # through real JSON text
    assert d["protocol_version"] == WIRE_PROTOCOL_VERSION
    back = GenerateRequest.from_json(d)
    assert back.tokens == [3, 10, 20]
    assert back.ages == [0.0, 15.5, 28.25]
    assert (back.max_new, back.max_age, back.death_token, back.seed) == \
        (5, 80.0, 1, 9)
    assert back.uniforms.dtype == np.float32
    assert (back.uniforms == u).all()               # bit-exact via base64


def test_generate_request_roundtrip_minimal():
    d = GenerateRequest(tokens=[7]).to_json()
    assert "ages" not in d and "uniforms" not in d and "max_age" not in d
    assert "request_id" not in d          # additive field, omitted unset
    back = GenerateRequest.from_json(json.loads(json.dumps(d)))
    assert back.tokens == [7] and back.ages is None
    assert back.uniforms is None and back.rng is None
    assert back.request_id is None


def test_generate_request_request_id_roundtrip():
    d = GenerateRequest(tokens=[7], request_id="cancel-me").to_json()
    assert d["request_id"] == "cancel-me"
    assert GenerateRequest.from_json(
        json.loads(json.dumps(d))).request_id == "cancel-me"


def test_generate_request_uniforms_accept_nested_lists():
    """Hand-written clients (the paper's JS SDK shape) may send plain
    nested lists instead of the base64 object."""
    back = GenerateRequest.from_json(
        {"tokens": [3], "uniforms": [[0.25, 0.5], [0.75, 1.0]]})
    assert back.uniforms.shape == (2, 2)
    np.testing.assert_array_equal(
        back.uniforms, np.asarray([[0.25, 0.5], [0.75, 1.0]], np.float32))


def test_generate_request_rejects_rng():
    req = GenerateRequest(tokens=[3], rng=np.random.default_rng(0))
    with pytest.raises(RngNotSerializableError) as ei:
        req.to_json()
    assert ei.value.code == "rng_not_serializable"
    assert isinstance(ei.value, ValueError)         # legacy contract


def test_generate_request_protocol_version_mismatch():
    with pytest.raises(ProtocolVersionError) as ei:
        GenerateRequest.from_json({"protocol_version": "999", "tokens": [3]})
    assert ei.value.code == "protocol_version_mismatch"
    # absent version is tolerated (hand-written minimal clients)
    assert GenerateRequest.from_json({"tokens": [3]}).tokens == [3]


def test_generate_request_missing_tokens():
    with pytest.raises(InvalidRequestError) as ei:
        GenerateRequest.from_json({"max_new": 4})
    assert ei.value.code == "invalid_request"
    with pytest.raises(InvalidRequestError):
        GenerateRequest.from_json([1, 2, 3])


def test_generate_request_bad_uniforms_object():
    with pytest.raises(InvalidRequestError):
        GenerateRequest.from_json({"tokens": [3], "uniforms": "zzz"})
    with pytest.raises(InvalidRequestError):
        GenerateRequest.from_json(
            {"tokens": [3], "uniforms": {"b64": "!!!not-base64",
                                         "shape": [1], "dtype": "float32"}})


# ---------------------------------------------------------------------------
# Results / events / risk
# ---------------------------------------------------------------------------
def test_trajectory_result_roundtrip():
    res = TrajectoryResult(tokens=[5, 81], ages=[30.25, 31.5],
                           prompt_tokens=[3, 10], prompt_ages=[0.0, 15.0],
                           backend="engine")
    back = TrajectoryResult.from_json(json.loads(json.dumps(res.to_json())))
    assert back == res
    assert back.full_tokens == [3, 10, 5, 81]


def test_trajectory_event_roundtrip():
    ev = TrajectoryEvent(index=2, token=81, age=31.5)
    assert TrajectoryEvent.from_json(ev.to_json()) == ev
    lm = TrajectoryEvent(index=0, token=4)          # generic LM: no age
    d = lm.to_json()
    assert "age" not in d
    assert TrajectoryEvent.from_json(d) == lm


def test_risk_report_roundtrip():
    rep = RiskReport(horizon=5.0,
                     items=[RiskItem(token=7, risk=0.25),
                            RiskItem(token=2, risk=0.125)],
                     backend="local")
    back = RiskReport.from_json(json.loads(json.dumps(rep.to_json())))
    assert back == rep
    assert back.as_dicts() == rep.as_dicts()


# ---------------------------------------------------------------------------
# Property tests (skip without hypothesis — tests/hypcompat.py)
# ---------------------------------------------------------------------------
@settings(max_examples=50, deadline=None)
@given(tokens=st.lists(st.integers(0, 2**31 - 1), min_size=1, max_size=64),
       seed=st.integers(0, 2**31 - 1),
       max_new=st.integers(1, 512))
def test_prop_request_tokens_roundtrip(tokens, seed, max_new):
    req = GenerateRequest(tokens=tokens, max_new=max_new, seed=seed)
    back = GenerateRequest.from_json(json.loads(json.dumps(req.to_json())))
    assert back.tokens == tokens
    assert back.seed == seed and back.max_new == max_new


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 8), v=st.integers(1, 33), seed=st.integers(0, 999))
def test_prop_uniforms_bit_exact(n, v, seed):
    u = np.random.default_rng(seed).uniform(size=(n, v)).astype(np.float32)
    req = GenerateRequest(tokens=[1], uniforms=u)
    back = GenerateRequest.from_json(json.loads(json.dumps(req.to_json())))
    assert back.uniforms.shape == (n, v)
    assert (back.uniforms == u).all()


@settings(max_examples=50, deadline=None)
@given(ages=st.lists(st.floats(0.0, 120.0, allow_nan=False), min_size=1,
                     max_size=32))
def test_prop_ages_roundtrip_exact(ages):
    """Python floats survive JSON text exactly (shortest-repr round trip) —
    the property that makes cross-process trajectories bit-comparable."""
    req = GenerateRequest(tokens=[1] * len(ages), ages=ages)
    back = GenerateRequest.from_json(json.loads(json.dumps(req.to_json())))
    assert back.ages == ages


@settings(max_examples=50, deadline=None)
@given(tokens=st.lists(st.integers(0, 10**6), max_size=32),
       ages=st.lists(st.floats(0, 200, allow_nan=False), max_size=32))
def test_prop_result_roundtrip(tokens, ages):
    res = TrajectoryResult(tokens=tokens, ages=ages, prompt_tokens=[1],
                           prompt_ages=[0.5], backend="x")
    back = TrajectoryResult.from_json(json.loads(json.dumps(res.to_json())))
    assert back == res


# ---------------------------------------------------------------------------
# Error taxonomy
# ---------------------------------------------------------------------------
def test_error_codes_stable():
    """The machine-readable contract: codes and HTTP statuses are API."""
    expect = {
        EmptyTrajectoryError: ("empty_trajectory", 400),
        TooLongError: ("too_long", 400),
        AgesRequiredError: ("ages_required", 400),
        AgesLengthMismatchError: ("ages_length_mismatch", 400),
        RngNotSerializableError: ("rng_not_serializable", 400),
        UnsupportedOverrideError: ("unsupported_override", 400),
        InvalidRequestError: ("invalid_request", 400),
        ProtocolVersionError: ("protocol_version_mismatch", 409),
        UnknownEndpointError: ("unknown_endpoint", 404),
        RequestTimeoutError: ("timeout", 504),
        RequestCancelledError: ("request_cancelled", 409),
        ReplicaUnavailableError: ("replica_unavailable", 503),
    }
    for cls, (code, status) in expect.items():
        e = cls("boom")
        assert (e.code, e.http_status) == (code, status), cls
        assert isinstance(e, ValueError)
        assert ApiError.registry[code] is cls


def test_error_json_roundtrip():
    e = AgesLengthMismatchError("ages/tokens length mismatch: 2 vs 3")
    body = json.loads(json.dumps(e.to_json()))
    back = error_from_json(body)
    assert type(back) is AgesLengthMismatchError
    assert back.code == e.code and back.message == e.message


def test_error_unknown_code_degrades():
    e = error_from_code("code_from_the_future", "newer server")
    assert type(e) is ApiError and e.code == "code_from_the_future"
    assert error_from_json({"nonsense": 1}).code == "internal"


def test_backend_validate_raises_taxonomy():
    """InferenceBackend._validate speaks the taxonomy (and therefore so does
    every backend, local or remote)."""
    from repro.api import InferenceBackend

    b = InferenceBackend()
    b.seq_len, b.vocab_size, b.has_ages = 8, 4, True
    with pytest.raises(EmptyTrajectoryError, match="empty"):
        b._validate([], [])
    with pytest.raises(TooLongError, match="longer than"):
        b._validate(list(range(9)), [0.0] * 9)
    with pytest.raises(AgesRequiredError, match="ages"):
        b._validate([1], None)
    with pytest.raises(AgesLengthMismatchError, match="mismatch"):
        b._validate([1, 2], [0.0])


def test_backend_registry_has_four_backends():
    from repro.api import Client
    assert {"artifact", "engine", "local",
            "remote"} <= set(Client.backends())
