"""Serving engine: slot continuous batching, termination, cache insertion."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.serve import BatchedEngine, Request


@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_config("delphi-2m", reduced=True).replace(
        dtype="float32", vocab_size=64)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return params, cfg


def _reqs(n, max_new=6):
    out = []
    for i in range(n):
        S = 4 + (i % 3)
        out.append(Request(tokens=np.arange(3, 3 + S, dtype=np.int32),
                           ages=np.linspace(0, 30 + i, S).astype(np.float32),
                           max_new=max_new))
    return out


def test_more_requests_than_slots(engine_setup):
    params, cfg = engine_setup
    eng = BatchedEngine(params, cfg, slots=3, max_context=64)
    for r in _reqs(7):
        eng.submit(r)
    done = eng.run()
    assert len(done) == 7
    for r in done:
        # 0 emitted events is legal: SDK-parity semantics censor an event
        # whose waiting time crosses max_age BEFORE emitting it
        assert r.done and len(r.out_tokens) <= 6
        assert len(r.out_ages) == len(r.out_tokens)
        assert all(a <= cfg.max_age + 1e-6 for a in r.out_ages)
        assert all(b >= a - 1e-6 for a, b in zip(r.out_ages, r.out_ages[1:]))


def test_max_new_respected(engine_setup):
    params, cfg = engine_setup
    eng = BatchedEngine(params, cfg, slots=2, max_context=64)
    for r in _reqs(2, max_new=3):
        eng.submit(r)
    done = eng.run()
    assert all(len(r.out_tokens) <= 3 for r in done)


def test_lm_mode():
    cfg = get_config("tinyllama-1.1b", reduced=True).replace(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(1))
    eng = BatchedEngine(params, cfg, slots=2, max_context=48)
    eng.submit(Request(tokens=np.arange(1, 9, dtype=np.int32), max_new=5))
    done = eng.run()
    assert len(done) == 1 and len(done[0].out_tokens) == 5
    assert all(0 <= t < cfg.vocab_size for t in done[0].out_tokens)
