"""Prefix-sharing subsystem: refcounted block pool, COW, prefix index,
engine fork / sample_futures (bit-parity vs the vectorized oracle), the
futures wire endpoint, and the zero-leak invariant extended to refcounts."""
import threading
import time

import jax
import numpy as np
import pytest

from repro.api import FuturesRequest, RequestCancelledError
from repro.api.client import EngineBackend, LocalBackend
from repro.configs import get_config
from repro.core import (engine_oracle_trajectories, futures_risk_items,
                        init_delphi, monte_carlo_risk)
from repro.serve import (BatchedEngine, BlockAllocator, PrefixIndex, Request,
                         SharedBlockPool, chunked_reference_trajectory,
                         ring_reference_futures)

W, BS, K = 64, 16, 4          # shared geometry -> shared jit cache


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("delphi-2m", reduced=True).replace(
        dtype="float32", vocab_size=96, max_seq_len=48, max_age=1e9)
    params = init_delphi(cfg, jax.random.PRNGKey(7))
    return params, cfg


TOKS = np.asarray([3, 10, 20, 30, 41], np.int32)
AGES = np.linspace(0.0, 30.0, 5).astype(np.float32)


def _uniforms(n, max_new, V, seed=42):
    rng = np.random.default_rng(seed)
    return rng.uniform(size=(n, max_new, V)).astype(np.float32)


def _trajs(kids):
    return [(list(k.out_tokens), [np.float32(a) for a in k.out_ages])
            for k in kids]


# ---------------------------------------------------------------------------
# SharedBlockPool
# ---------------------------------------------------------------------------
def test_shared_pool_refcounts():
    pool = SharedBlockPool(BlockAllocator(8))        # capacity 7
    ids = pool.alloc(3)
    assert pool.used == 3 and all(pool.refcount(i) == 1 for i in ids)
    pool.share(ids)
    assert pool.shared_blocks == 3 and pool.peak_shared == 3
    pool.release(ids)                                # drop one of two refs
    assert pool.used == 3, "a still-referenced block must not free"
    assert pool.shared_blocks == 0
    pool.release(ids)
    assert pool.used == 0 and pool.total_refs == 0
    with pytest.raises(ValueError):
        pool.release(ids)                            # refcount underflow
    with pytest.raises(ValueError):
        pool.share([99])                             # share of unallocated
    assert pool.alloc(8) is None                     # never partial


def test_shared_pool_available_counts_shared_once():
    pool = SharedBlockPool(BlockAllocator(8))
    ids = pool.alloc(4)
    pool.share(ids)                                  # 2 owners, 4 blocks
    assert pool.used == 4                            # counted ONCE
    assert pool.available() == 3                     # free only — no index


# ---------------------------------------------------------------------------
# PrefixIndex
# ---------------------------------------------------------------------------
def test_prefix_index_chain_and_eviction():
    pool = SharedBlockPool(BlockAllocator(12))
    idx = PrefixIndex(pool, block_size=4, max_entries=8)
    toks = np.arange(10)
    ages = np.linspace(0, 9, 10).astype(np.float32)
    blocks = pool.alloc(3)                           # 2 full + tail
    idx.register(toks, ages, blocks, S=10, age0=9.0, logits=np.zeros(5))
    assert idx.entries == 1 and idx.cached_blocks == 3
    # chain matches full blocks only, in order, longest-prefix
    assert idx.match_prefix(toks, ages) == blocks[:2]
    assert idx.match_prefix(toks[:8], ages[:8]) == blocks[:2]
    assert idx.match_prefix(toks[:4], ages[:4]) == blocks[:1]
    other = toks.copy()
    other[1] = 77
    assert idx.match_prefix(other, ages) == []
    # exact-prompt complete lookup; age perturbation breaks it
    assert idx.lookup(toks, ages) is not None
    assert idx.lookup(toks, ages + 1.0) is None
    assert idx.lookup(toks[:9], ages[:9]) is None
    # eviction releases the index refs; owner drops at 0 -> blocks free
    pool.release([blocks[0]])        # simulate: request released its refs
    pool.release([blocks[1]])
    pool.release([blocks[2]])
    assert pool.used == 3            # index still holds all three
    assert idx.evictable() == 3
    freed = idx.evict(2)
    assert freed == 3 and idx.entries == 0 and pool.used == 0
    assert idx.match_prefix(toks, ages) == []


def test_prefix_index_lru_cap():
    pool = SharedBlockPool(BlockAllocator(32))
    idx = PrefixIndex(pool, block_size=4, max_entries=2)
    for s in range(3):
        toks = np.arange(8) + 10 * s
        b = pool.alloc(2)
        idx.register(toks, None, b, S=8, age0=0.0)
        pool.release(b)              # only the index holds them
    assert idx.entries == 2          # LRU-capped
    assert idx.evictions == 1
    assert pool.used == 4


# ---------------------------------------------------------------------------
# Fork parity: engine (ring + paged + prefix-cached) == vectorized oracle
# ---------------------------------------------------------------------------
def test_fork_bit_identical_to_oracle(setup):
    """sample_futures through hold/fork/COW must reproduce the scheduler-
    free oracle bit for bit (tokens AND fp32 ages) — on the ring engine
    (row-copy fork), the paged engine (refcounted block sharing), and the
    prefix-cached paged engine twice (2nd run admits by reference)."""
    params, cfg = setup
    n, max_new = 4, 6
    u = _uniforms(n, max_new, cfg.vocab_size)
    ora = [(list(t), [np.float32(a) for a in a_])
           for t, a_ in ring_reference_futures(
               params, cfg, TOKS, AGES, n=n, max_new=max_new, uniforms=u,
               slots=K, max_context=W)]
    ring = BatchedEngine(params, cfg, slots=K, max_context=W)
    assert _trajs(ring.sample_futures(TOKS, AGES, n=n, max_new=max_new,
                                      uniforms=u)) == ora
    paged = BatchedEngine(params, cfg, slots=K, max_context=W,
                          cache="paged", block_size=BS)
    assert _trajs(paged.sample_futures(TOKS, AGES, n=n, max_new=max_new,
                                       uniforms=u)) == ora
    assert paged.allocator.used == 0 and not paged.pool._refs
    pfx = BatchedEngine(params, cfg, slots=K, max_context=W, cache="paged",
                        block_size=BS, prefix_cache=True)
    assert _trajs(pfx.sample_futures(TOKS, AGES, n=n, max_new=max_new,
                                     uniforms=u)) == ora
    assert _trajs(pfx.sample_futures(TOKS, AGES, n=n, max_new=max_new,
                                     uniforms=u)) == ora
    assert pfx.pool_stats()["prefix_cache"]["hits"] >= 1
    pfx.drop_prefix_cache()
    assert pfx.allocator.used == 0 and not pfx.pool._refs


def test_backend_futures_match_monte_carlo_oracle(setup):
    """EngineBackend.sample_futures == monte_carlo_risk configured with the
    engine-parity trajectory source, bit for bit — trajectories AND the
    aggregated risk values (acceptance criterion)."""
    params, cfg = setup
    n, max_new, horizon = 4, 6, 100.0
    u = _uniforms(n, max_new, cfg.vocab_size, seed=3)
    req = FuturesRequest(tokens=TOKS.tolist(), ages=AGES.tolist(),
                         n_futures=n, max_new=max_new, uniforms=u,
                         horizon=horizon, top=8)
    tr = engine_oracle_trajectories(params, cfg, TOKS, AGES, n_samples=n,
                                    max_new=max_new, uniforms=u, slots=K,
                                    max_context=W)
    mc = monte_carlo_risk(params, cfg, TOKS, AGES, horizon=horizon,
                          trajectories=tr)
    code_risk = np.asarray(mc["code_risk"])
    S = len(TOKS)
    n_gen = np.asarray(tr["n_generated"])
    ora = [(np.asarray(tr["tokens"][j])[S:S + n_gen[j]].tolist(),
            [np.float32(x)
             for x in np.asarray(tr["ages"][j])[S:S + n_gen[j]]])
           for j in range(n)]
    for kind, kw in (("ring", {}), ("paged", {"block_size": BS,
                                              "prefix_cache": True})):
        b = EngineBackend.create(params, cfg, slots=K, max_context=W,
                                 cache=kind, **kw)
        out = b.sample_futures(req)
        assert [(t.tokens, [np.float32(a) for a in t.ages])
                for t in out.trajectories] == ora
        for item in out.risk.items:
            assert item.risk == pytest.approx(code_risk[item.token],
                                              abs=0.0)
        if kind == "paged":
            assert out.sharing["forks"] == 1
            assert out.sharing["cow_copies"] >= 1


def test_local_backend_futures_vectorized(setup):
    """LocalBackend fans N futures through ONE jitted call; its risk report
    aggregates through the same host-side path as the engine's."""
    params, cfg = setup
    n, max_new = 3, 5
    u = _uniforms(n, max_new, cfg.vocab_size, seed=9)
    req = FuturesRequest(tokens=TOKS.tolist(), ages=AGES.tolist(),
                         n_futures=n, max_new=max_new, uniforms=u,
                         horizon=50.0)
    out = LocalBackend(params, cfg, seq_len=48).sample_futures(req)
    assert len(out.trajectories) == n and out.backend == "local"
    items = futures_risk_items(
        [(t.tokens, t.ages) for t in out.trajectories],
        float(AGES[-1]), 50.0, cfg.vocab_size, top=10)
    assert [(i.token, i.risk) for i in out.risk.items] == items


def test_monte_carlo_risk_vectorized_uniforms(setup):
    """The vectorized monte_carlo_risk draws every sample through one
    generate_trajectories_jit call; injected uniforms make it exact."""
    params, cfg = setup
    u = _uniforms(4, 5, cfg.vocab_size, seed=11)
    r1 = monte_carlo_risk(params, cfg, TOKS, AGES, n_samples=4, max_new=5,
                          horizon=100.0, uniforms=u)
    r2 = monte_carlo_risk(params, cfg, TOKS, AGES, n_samples=4, max_new=5,
                          horizon=100.0, uniforms=u)
    assert np.array_equal(np.asarray(r1["code_risk"]),
                          np.asarray(r2["code_risk"]))
    assert float(np.max(r1["code_risk"])) > 0.0


# ---------------------------------------------------------------------------
# Scheduler edge cases
# ---------------------------------------------------------------------------
def test_cancel_one_of_n_forks_midstream(setup):
    """Cancelling one forked future mid-decode frees only ITS references;
    the siblings finish and every refcount drains."""
    params, cfg = setup
    eng = BatchedEngine(params, cfg, slots=K, max_context=512, cache="paged",
                        block_size=BS, prefix_cache=True).start()
    try:
        parent = Request(tokens=TOKS, ages=AGES, max_new=400, hold=True,
                         request_id="mc")
        eng.submit(parent)
        kids = eng.fork("mc", 3)
        time.sleep(0.2)                  # let the forks decode a while
        assert eng.cancel("mc/fork-1")
        deadline = time.monotonic() + 120
        while not all(k.done for k in kids) and time.monotonic() < deadline:
            time.sleep(0.02)
        assert all(k.done for k in kids)
    finally:
        eng.stop()
    assert isinstance(kids[1].error, RequestCancelledError)
    assert kids[0].error is None and kids[2].error is None
    assert len(kids[0].out_tokens) > 0
    eng.drop_prefix_cache()
    assert eng.allocator.used == 0 and not eng.pool._refs


def test_preempt_lands_on_fork_and_reacquires_prefix(setup):
    """Pool exhaustion preempts the youngest — a forked future — whose
    recompute resume must RE-ACQUIRE the shared prefix blocks through the
    index instead of duplicating them."""
    params, cfg = setup
    # block-aligned prompt: the whole prefix is shareable full blocks, so
    # the index entry stays pinned (refcount > 1) while any fork lives and
    # pool pressure must preempt a fork rather than evict the entry
    S = 16                               # exactly 2 full blocks at BS=8
    toks = (np.arange(3, 3 + S) % 90).astype(np.int32)
    ages = np.linspace(0.0, 30.0, S).astype(np.float32)
    # capacity 6: prefix 2 + three forks' growth blocks exhaust it mid-run.
    # Suppress the death token (u -> 1e-12 makes its waiting time huge) so
    # every future runs all 12 events and the crunch is deterministic.
    u = _uniforms(3, 12, cfg.vocab_size, seed=7)
    u[:, :, cfg.death_token] = 1e-12
    eng = BatchedEngine(params, cfg, slots=4, max_context=32, cache="paged",
                        block_size=8, blocks=7, prefix_cache=True)
    kids = eng.sample_futures(toks, ages, n=3, max_new=12, uniforms=u)
    assert all(k.done and k.error is None for k in kids)
    assert [len(k.out_tokens) for k in kids] == [12, 12, 12]
    assert eng.preemptions > 0
    st = eng.pool_stats()["prefix_cache"]
    assert st["partial_hits"] > 0, \
        "resumed fork must re-acquire its prefix by reference"
    eng.drop_prefix_cache()
    assert eng.allocator.used == 0 and not eng.pool._refs


def test_over_width_prompt_bypasses_prefix_index(setup):
    """S > max_context histories wrap the ring: they must neither register
    in nor borrow from the prefix index, and forking them still matches
    the ring engine."""
    params, cfg = setup
    S, Wn = 40, 32
    toks = (np.arange(3, 3 + S) % 90).astype(np.int32)
    ages = np.linspace(0.0, 30.0, S).astype(np.float32)
    u = _uniforms(2, 4, cfg.vocab_size, seed=17)
    eng = BatchedEngine(params, cfg, slots=2, max_context=Wn, cache="paged",
                        block_size=8, prefix_cache=True)
    kids = eng.sample_futures(toks, ages, n=2, max_new=4, uniforms=u)
    assert eng.prefix.entries == 0       # bypassed, not registered
    assert eng.prefix.hits == 0
    ring = BatchedEngine(params, cfg, slots=2, max_context=Wn)
    rkids = ring.sample_futures(toks, ages, n=2, max_new=4, uniforms=u)
    assert _trajs(kids) == _trajs(rkids)
    assert eng.allocator.used == 0 and not eng.pool._refs


def test_shared_admission_budget_counts_block_once(setup):
    """N futures co-reside in a pool far smaller than N unshared copies
    would need: the admission budget charges a shared block once."""
    params, cfg = setup
    S = 17                               # 3 blocks at BS=8 (2 full + tail)
    toks = (np.arange(3, 3 + S) % 90).astype(np.int32)
    ages = np.linspace(0.0, 30.0, S).astype(np.float32)
    # capacity 6 < 3 unshared copies (9 blocks); shared: 3 + 3 tails = 6
    eng = BatchedEngine(params, cfg, slots=4, max_context=32, cache="paged",
                        block_size=8, blocks=7, prefix_cache=True)
    kids = eng.sample_futures(toks, ages, n=3, max_new=3)
    assert all(k.done and k.error is None for k in kids)
    assert eng.peak_active == 3          # all three futures co-resident
    assert eng.preemptions == 0
    assert eng.allocator.peak_used <= 6
    assert eng.pool.peak_shared >= 2


def test_pinned_hits_budget_is_honest(setup):
    """Prefix hits must not double as eviction headroom: requests whose
    hits are the pool's cached blocks admit on free blocks alone (waiting
    their turn under pressure) instead of crashing admission or
    livelocking — and the shared entry survives to serve every one."""
    params, cfg = setup
    S1, S2 = 16, 24
    toks1 = (np.arange(3, 3 + S1) % 90).astype(np.int32)
    ages1 = np.linspace(0.0, 30.0, S1).astype(np.float32)
    toks2 = np.concatenate([toks1, np.arange(50, 58) % 90]).astype(np.int32)
    ages2 = np.concatenate([ages1,
                            np.linspace(31, 40, 8)]).astype(np.float32)
    eng = BatchedEngine(params, cfg, slots=4, max_context=32, cache="paged",
                        block_size=8, blocks=5, prefix_cache=True)
    r1 = Request(tokens=toks1, ages=ages1, max_new=2)
    eng.submit(r1)
    eng.run()
    assert eng.prefix.entries == 1       # 2 cached full blocks, free = 2
    # each S=24 request: 2-block hit + 1 fresh + 1 aligned-growth = 2 fresh
    # against 2 free blocks -> they admit one at a time, sharing the SAME
    # pinned entry, which must never be evicted out from under them
    rs = [Request(tokens=toks2.copy(), ages=ages2.copy(), max_new=4)
          for _ in range(3)]
    for r in rs:
        eng.submit(r)
    done = eng.run(max_ticks=2000)
    assert len(done) >= 4
    assert all(r.done and r.error is None for r in rs)
    assert all(len(r.out_tokens) == 4 for r in rs)
    st = eng.pool_stats()["prefix_cache"]
    # every admission (including preempt-resumes) shared the prefix, and
    # the pinned entry was never evicted out from under a live sharer
    assert st["partial_hits"] >= 3
    assert st["evictions"] == 0
    eng.drop_prefix_cache()
    assert eng.allocator.used == 0 and not eng.pool._refs


def test_hold_survives_ticks_with_other_traffic(setup):
    """A parent parked across several ticks of unrelated decode traffic
    must fork the SAME bits as an immediate fork — the held slot's parked
    writes must never corrupt the shared prefix."""
    params, cfg = setup
    n, max_new = 2, 5
    u = _uniforms(n, max_new, cfg.vocab_size, seed=29)
    for kind, kw in (("ring", {}), ("paged", {"block_size": BS})):
        ref_eng = BatchedEngine(params, cfg, slots=K, max_context=W,
                                cache=kind, **kw)
        ref = _trajs(ref_eng.sample_futures(TOKS, AGES, n=n,
                                            max_new=max_new, uniforms=u))
        eng = BatchedEngine(params, cfg, slots=K, max_context=W,
                            cache=kind, **kw)
        parent = Request(tokens=TOKS, ages=AGES, max_new=max_new, hold=True)
        eng.submit(parent)
        other = Request(tokens=TOKS[:3], ages=AGES[:3], max_new=8,
                        uniforms=_uniforms(1, 8, cfg.vocab_size, 31)[0])
        eng.submit(other)
        for _ in range(4):               # parent parked while other decodes
            eng.step()
        kids = eng.fork(parent.request_id, n, uniforms=u, max_new=max_new)
        eng.run()
        assert _trajs(kids) == ref, f"held-parent fork diverged ({kind})"


def test_fork_validation_and_ring_refuses_prefix(setup):
    params, cfg = setup
    from repro.api.errors import InvalidRequestError
    with pytest.raises(ValueError, match="prefix_cache requires"):
        BatchedEngine(params, cfg, cache="ring", prefix_cache=True)
    eng = BatchedEngine(params, cfg, slots=2, max_context=W, cache="paged",
                        block_size=BS)
    with pytest.raises(InvalidRequestError, match="unknown or finished"):
        eng.fork("nope", 2)
    r = Request(tokens=TOKS, ages=AGES, max_new=4)
    eng.submit(r)
    with pytest.raises(InvalidRequestError, match="hold=True parent"):
        eng.fork(r.request_id, 2)
    eng.run()
    assert eng.allocator.used == 0


def test_cancelled_parent_fails_children(setup):
    params, cfg = setup
    eng = BatchedEngine(params, cfg, slots=2, max_context=W, cache="paged",
                        block_size=BS)
    parent = Request(tokens=TOKS, ages=AGES, max_new=4, hold=True,
                     request_id="doomed")
    eng.submit(parent)
    kids = eng.fork("doomed", 2)
    assert eng.cancel("doomed")
    eng.run(max_ticks=200)
    assert parent.done and isinstance(parent.error, RequestCancelledError)
    assert all(k.done and isinstance(k.error, RequestCancelledError)
               for k in kids)
    assert eng.allocator.used == 0 and not eng.pool._refs


def test_pool_stats_sharing_fields(setup):
    params, cfg = setup
    eng = BatchedEngine(params, cfg, slots=2, max_context=W, cache="paged",
                        block_size=BS, prefix_cache=True)
    st = eng.pool_stats()
    for key in ("shared_blocks", "shared_blocks_peak", "cow_copies",
                "forks", "prefix_cache"):
        assert key in st
    assert st["prefix_cache"]["entries"] == 0
    ring = BatchedEngine(params, cfg, slots=2, max_context=W)
    assert "shared_blocks" not in ring.pool_stats()
    assert ring.pool_stats()["forks"] == 0


# ---------------------------------------------------------------------------
# Resource-leak regressions (bugs found by repro-lint RL005): a raising
# path between acquire and transfer must never strand block references
# ---------------------------------------------------------------------------
def test_register_failure_takes_no_refs(monkeypatch):
    """PrefixIndex.register must build its entry BEFORE sharing the blocks:
    a failure mid-registration may not leave unowned index refs behind."""
    import repro.serve.prefix as prefix_mod
    pool = SharedBlockPool(BlockAllocator(8))
    idx = PrefixIndex(pool, block_size=4, max_entries=8)
    blocks = pool.alloc(2)

    def boom(*a, **k):
        raise RuntimeError("entry construction failed")
    monkeypatch.setattr(prefix_mod, "_Entry", boom)
    toks = np.arange(8)
    ages = np.linspace(0.0, 7.0, 8).astype(np.float32)
    with pytest.raises(RuntimeError, match="entry construction failed"):
        idx.register(toks, ages, blocks, S=8, age0=7.0)
    assert idx.entries == 0
    assert pool.total_refs == len(blocks)    # only the caller's own refs
    pool.release(blocks)
    assert pool.used == 0 and not pool._refs


def test_admission_alloc_crash_releases_shared_hits(setup, monkeypatch):
    """Prefix hits are shared BEFORE the suffix alloc; if the alloc raises,
    the admission cleanup must drop those shares (they are parked on the
    slot immediately), and the engine must recover and serve the retry."""
    params, cfg = setup
    S1 = 16                              # exactly 2 full blocks at BS=8
    toks1 = (np.arange(3, 3 + S1) % 90).astype(np.int32)
    ages1 = np.linspace(0.0, 30.0, S1).astype(np.float32)
    toks2 = np.concatenate([toks1, np.arange(50, 58) % 90]).astype(np.int32)
    ages2 = np.concatenate([ages1,
                            np.linspace(31, 40, 8)]).astype(np.float32)
    eng = BatchedEngine(params, cfg, slots=4, max_context=32, cache="paged",
                        block_size=8, blocks=7, prefix_cache=True)
    r1 = Request(tokens=toks1, ages=ages1, max_new=2)
    eng.submit(r1)
    eng.run()
    assert eng.prefix.entries == 1       # 2 cached blocks to hit on

    real_alloc = eng.pool.alloc
    armed = {"on": True}

    def flaky_alloc(n):
        if armed["on"]:
            armed["on"] = False
            raise RuntimeError("injected alloc failure")
        return real_alloc(n)
    monkeypatch.setattr(eng.pool, "alloc", flaky_alloc)
    r2 = Request(tokens=toks2, ages=ages2, max_new=2)
    eng.submit(r2)
    with pytest.raises(RuntimeError, match="injected alloc failure"):
        eng.run()
    # the crashed admission's shares are gone: only the index holds refs
    assert eng.pool.used == 2 and eng.pool.total_refs == 2
    # the request went back on the queue and the next run serves it
    done = eng.run()
    assert r2 in done and r2.error is None and len(r2.out_tokens) == 2
    assert eng.pool_stats()["prefix_cache"]["partial_hits"] >= 1
    eng.drop_prefix_cache()
    assert eng.allocator.used == 0 and not eng.pool._refs


def test_cow_failure_mid_fork_leaks_no_blocks(setup, monkeypatch):
    """A COW copy that crashes after its destination block was allocated
    must release that block on the way out; the loop thread fails the
    in-flight forks and the pool drains to zero."""
    import repro.serve.engine as engine_mod
    params, cfg = setup
    real = engine_mod._cow_block_jit
    fired = {"on": False}

    def flaky(*a, **k):
        if not fired["on"]:
            fired["on"] = True
            raise RuntimeError("injected COW failure")
        return real(*a, **k)
    monkeypatch.setattr(engine_mod, "_cow_block_jit", flaky)
    eng = BatchedEngine(params, cfg, slots=K, max_context=W, cache="paged",
                        block_size=BS).start()
    try:
        parent = Request(tokens=TOKS, ages=AGES, max_new=5, hold=True,
                         request_id="cow")
        eng.submit(parent)
        kids = eng.fork("cow", 2, uniforms=_uniforms(2, 5, cfg.vocab_size))
        deadline = time.monotonic() + 120
        while not all(k.done for k in kids) and time.monotonic() < deadline:
            time.sleep(0.02)
        assert all(k.done for k in kids)
    finally:
        eng.stop()
    assert fired["on"], "fork decode must have attempted a COW"
    assert any(isinstance(k.error, RuntimeError)
               and "injected COW failure" in str(k.error) for k in kids)
    assert eng.allocator.used == 0 and not eng.pool._refs


# ---------------------------------------------------------------------------
# Chunked prefill (prefill/decode interleaving) + partial-prefix suffix
# ---------------------------------------------------------------------------
LONG_TOKS = (np.arange(3, 24) % 90).astype(np.int32)     # S=21: full + tail
LONG_AGES = np.linspace(0.0, 30.0, 21).astype(np.float32)


def _one(eng, toks, ages, max_new, u):
    r = Request(tokens=toks, ages=ages, max_new=max_new, uniforms=u)
    eng.submit(r)
    eng.run()
    assert r.done and r.error is None
    return list(r.out_tokens), [np.float32(a) for a in r.out_ages]


def test_chunked_prefill_bit_identical_to_monolithic(setup):
    """Chunked prefill is a scheduling change, not a numeric one: a budget
    covering any prompt (= monolithic cadence) and a one-block budget both
    reproduce the unchunked engine bit for bit (tokens AND fp32 ages) under
    injected uniforms — and all three match the straight-line chunked
    oracle (acceptance: bit-parity invariant)."""
    params, cfg = setup
    max_new = 6
    u = _uniforms(1, max_new, cfg.vocab_size, seed=23)[0]
    u[:, cfg.death_token] = 1e-12        # run all max_new events

    def run(**kw):
        eng = BatchedEngine(params, cfg, slots=K, max_context=W,
                            cache="paged", block_size=BS, **kw)
        out = _one(eng, LONG_TOKS, LONG_AGES, max_new, u)
        assert eng.allocator.used == 0 and not eng.pool._refs
        return out, eng

    base, _ = run()
    inf, _ = run(prefill_chunk_tokens=W)
    chunked, eng16 = run(prefill_chunk_tokens=BS)
    assert inf == base, "unbounded chunk budget diverged from monolithic"
    assert chunked == base, "one-block chunk budget diverged from monolithic"
    st = eng16.pool_stats()
    assert st["prefill_chunk_tokens"] == BS
    assert st["chunked_prefills"] == 1 and st["prefill_chunks"] == 2
    assert st["suffix_tokens_saved"] == 0 and st["prefill_in_progress"] == 0
    ot, oa = chunked_reference_trajectory(
        params, cfg, LONG_TOKS, LONG_AGES, max_new=max_new, uniforms=u,
        chunk_tokens=BS, slots=K, max_context=W, block_size=BS)
    assert base == (ot, [np.float32(a) for a in oa]), \
        "engine diverged from the chunked oracle"


def test_partial_prefix_hit_prefills_only_suffix(setup):
    """A partial index hit acquires the matched blocks by reference and
    chunk-prefills ONLY the unmatched suffix: suffix_tokens_saved counts
    the skipped prefix, one extra chunk covers the 5-token tail, and the
    trajectory matches the matched-boundary oracle bit for bit."""
    params, cfg = setup
    max_new = 4
    eng = BatchedEngine(params, cfg, slots=K, max_context=W, cache="paged",
                        block_size=BS, prefix_cache=True,
                        prefill_chunk_tokens=BS)
    ua = _uniforms(1, max_new, cfg.vocab_size, seed=5)[0]
    ua[:, cfg.death_token] = 1e-12
    # registrant: block-aligned prompt -> one full shareable block
    _one(eng, LONG_TOKS[:BS], LONG_AGES[:BS], max_new, ua)
    assert eng.prefix.entries >= 1
    chunks0 = eng.pool_stats()["prefill_chunks"]
    ub = _uniforms(1, max_new, cfg.vocab_size, seed=6)[0]
    ub[:, cfg.death_token] = 1e-12
    got = _one(eng, LONG_TOKS, LONG_AGES, max_new, ub)
    st = eng.pool_stats()
    assert st["suffix_tokens_saved"] == BS
    assert st["prefix_cache"]["partial_hits"] == 1
    assert st["prefill_chunks"] == chunks0 + 1      # suffix = one chunk
    ot, oa = chunked_reference_trajectory(
        params, cfg, LONG_TOKS, LONG_AGES, max_new=max_new, uniforms=ub,
        chunk_tokens=BS, slots=K, max_context=W, block_size=BS,
        matched_tokens=BS)
    assert got == (ot, [np.float32(a) for a in oa]), \
        "suffix prefill diverged from the matched-boundary oracle"
    eng.drop_prefix_cache()
    assert eng.allocator.used == 0 and not eng.pool._refs


def test_preempted_chunked_resume_reacquires_prefix(setup):
    """The chunked twin of test_preempt_lands_on_fork_and_reacquires_prefix:
    pool exhaustion preempts a forked future, and its recompute-resume goes
    back through chunked admission — re-acquiring the shared prefix by
    reference and re-prefilling ONLY the unmatched suffix (counted by
    suffix_tokens_saved)."""
    params, cfg = setup
    S = 16                               # exactly 2 full blocks at BS=8
    toks = (np.arange(3, 3 + S) % 90).astype(np.int32)
    ages = np.linspace(0.0, 30.0, S).astype(np.float32)
    u = _uniforms(3, 12, cfg.vocab_size, seed=7)
    u[:, :, cfg.death_token] = 1e-12
    eng = BatchedEngine(params, cfg, slots=4, max_context=32, cache="paged",
                        block_size=8, blocks=7, prefix_cache=True,
                        prefill_chunk_tokens=8)
    kids = eng.sample_futures(toks, ages, n=3, max_new=12, uniforms=u)
    assert all(k.done and k.error is None for k in kids)
    assert [len(k.out_tokens) for k in kids] == [12, 12, 12]
    assert eng.preemptions > 0
    st = eng.pool_stats()
    assert st["prefix_cache"]["partial_hits"] > 0, \
        "resumed fork must re-acquire its prefix by reference"
    assert st["suffix_tokens_saved"] > 0, \
        "resume must skip the matched prefix and prefill only the suffix"
    # bit-parity with the unchunked engine through the same preemption dance
    ref_eng = BatchedEngine(params, cfg, slots=4, max_context=32,
                            cache="paged", block_size=8, blocks=7,
                            prefix_cache=True)
    assert _trajs(kids) == _trajs(ref_eng.sample_futures(
        toks, ages, n=3, max_new=12, uniforms=u))
    eng.drop_prefix_cache()
    assert eng.allocator.used == 0 and not eng.pool._refs


def test_cancel_mid_prefill_releases_partial_blocks(setup):
    """Cancelling a slot whose prompt is still chunking must release its
    partially-written blocks AND its shared prefix refs — the zero-leak
    invariant extended to prefill-in-progress state."""
    params, cfg = setup
    bs = 8
    eng = BatchedEngine(params, cfg, slots=4, max_context=32, cache="paged",
                        block_size=bs, blocks=8, prefix_cache=True,
                        prefill_chunk_tokens=bs)
    toks_a = (np.arange(3, 3 + bs) % 90).astype(np.int32)
    ages_a = np.linspace(0.0, 10.0, bs).astype(np.float32)
    ua = _uniforms(1, 2, cfg.vocab_size, seed=31)[0]
    ua[:, cfg.death_token] = 1e-12
    _one(eng, toks_a, ages_a, 2, ua)     # registers one shareable block
    assert eng.prefix.entries == 1
    toks_b = np.concatenate([toks_a,
                             np.arange(60, 76) % 90]).astype(np.int32)
    ages_b = np.concatenate([ages_a,
                             np.linspace(11.0, 30.0, 16)]).astype(np.float32)
    rb = Request(tokens=toks_b, ages=ages_b, max_new=4, request_id="mid")
    eng.submit(rb)
    eng.step()                           # admit + first suffix chunk only
    st = eng.pool_stats()
    assert st["prefill_in_progress"] == 1
    assert st["suffix_tokens_saved"] == bs
    assert eng.cancel("mid")
    eng.run(max_ticks=50)
    assert rb.done and isinstance(rb.error, RequestCancelledError)
    assert eng.pool_stats()["prefill_in_progress"] == 0
    eng.drop_prefix_cache()
    assert eng.allocator.used == 0 and not eng.pool._refs


def test_fork_from_chunk_prefilled_parent(setup):
    """hold=True parents park their bootstrap logits at the end of chunked
    prefill exactly as monolithic admission does: sample_futures through a
    chunked engine == the unchunked fork run, bit for bit."""
    params, cfg = setup
    n, max_new = 3, 5
    u = _uniforms(n, max_new, cfg.vocab_size, seed=13)
    ora = _trajs(BatchedEngine(
        params, cfg, slots=K, max_context=W, cache="paged",
        block_size=BS).sample_futures(TOKS, AGES, n=n, max_new=max_new,
                                      uniforms=u))
    eng = BatchedEngine(params, cfg, slots=K, max_context=W, cache="paged",
                        block_size=BS, prefill_chunk_tokens=BS)
    assert _trajs(eng.sample_futures(TOKS, AGES, n=n, max_new=max_new,
                                     uniforms=u)) == ora
    assert eng.pool_stats()["chunked_prefills"] == 1
    assert eng.allocator.used == 0 and not eng.pool._refs


def test_chunked_knob_validation(setup):
    params, cfg = setup
    with pytest.raises(ValueError, match="requires the paged KV cache"):
        BatchedEngine(params, cfg, cache="ring", prefill_chunk_tokens=16)
    with pytest.raises(ValueError, match="positive multiple"):
        BatchedEngine(params, cfg, cache="paged", block_size=BS,
                      prefill_chunk_tokens=BS + 1)
    with pytest.raises(ValueError, match="positive multiple"):
        BatchedEngine(params, cfg, cache="paged", block_size=BS,
                      prefill_chunk_tokens=0)


def test_healthz_exposes_chunked_prefill(setup):
    from repro.api.remote import RemoteBackend
    from repro.serve.server import InferenceServer
    params, cfg = setup
    server = InferenceServer(
        EngineBackend.create(params, cfg, slots=2, max_context=W,
                             cache="paged", block_size=BS, prefix_cache=True,
                             prefill_chunk_tokens=2 * BS), port=0).start()
    try:
        rb = RemoteBackend(server.address)
        mem = rb.healthz()["engine"]["memory"]
        assert mem["prefill_chunk_tokens"] == 2 * BS
        for key in ("chunked_prefills", "prefill_chunks",
                    "prefill_in_progress", "suffix_tokens_saved"):
            assert mem[key] == 0
    finally:
        server.stop()


# ---------------------------------------------------------------------------
# Wire: schemas + /v1/futures + RemoteBackend
# ---------------------------------------------------------------------------
def test_futures_wire_roundtrip():
    from repro.api import FuturesResult, RiskItem, RiskReport
    from repro.api.schemas import TrajectoryResult
    u = np.random.default_rng(0).uniform(size=(2, 3, 4)).astype(np.float32)
    req = FuturesRequest(tokens=[1, 2], ages=[0.0, 1.5], n_futures=2,
                         max_new=3, horizon=2.5, top=4, uniforms=u,
                         request_id="abc")
    back = FuturesRequest.from_json(req.to_json())
    assert back.tokens == [1, 2] and back.n_futures == 2
    assert back.horizon == 2.5 and back.request_id == "abc"
    assert np.array_equal(back.uniforms, u)          # bit-exact b64 bytes
    res = FuturesResult(
        risk=RiskReport(horizon=2.5, items=[RiskItem(token=7, risk=0.5)],
                        backend="engine"),
        trajectories=[TrajectoryResult(tokens=[7], ages=[1.0],
                                       prompt_tokens=[1, 2],
                                       prompt_ages=[0.0, 1.5],
                                       backend="engine")],
        n_futures=2, backend="engine", sharing={"forks": 1})
    rb = FuturesResult.from_json(res.to_json())
    assert rb.risk.items[0].token == 7 and rb.n_futures == 2
    assert rb.trajectories[0].tokens == [7] and rb.sharing == {"forks": 1}


def test_futures_validation_errors(setup):
    from repro.api.errors import (AgesRequiredError, EmptyTrajectoryError,
                                  InvalidRequestError)
    params, cfg = setup
    b = EngineBackend.create(params, cfg, slots=2, max_context=W,
                             cache="paged", block_size=BS)
    with pytest.raises(EmptyTrajectoryError):
        b.sample_futures(FuturesRequest(tokens=[]))
    with pytest.raises(AgesRequiredError):
        b.sample_futures(FuturesRequest(tokens=[1, 2]))
    with pytest.raises(InvalidRequestError, match="n_futures"):
        b.sample_futures(FuturesRequest(tokens=[1], ages=[0.0],
                                        n_futures=0))
    with pytest.raises(InvalidRequestError, match="futures uniforms"):
        b.sample_futures(FuturesRequest(
            tokens=[1], ages=[0.0], n_futures=2, max_new=4,
            uniforms=np.zeros((2, 4, 7), np.float32)))


def test_remote_futures_bit_identical(setup):
    """POST /v1/futures through RemoteBackend == in-process EngineBackend,
    trajectories and risks, under injected uniforms (acceptance: remote
    parity for both ring and paged servers)."""
    from repro.api import Client
    from repro.serve.server import InferenceServer
    params, cfg = setup
    n, max_new = 3, 5
    u = _uniforms(n, max_new, cfg.vocab_size, seed=41)
    req = FuturesRequest(tokens=TOKS.tolist(), ages=AGES.tolist(),
                         n_futures=n, max_new=max_new, uniforms=u,
                         horizon=100.0, top=6)
    for kind, kw in (("ring", {}), ("paged", {"block_size": BS,
                                              "prefix_cache": True})):
        local = EngineBackend.create(params, cfg, slots=K, max_context=W,
                                     cache=kind, **kw)
        ref = local.sample_futures(req)
        server = InferenceServer(
            EngineBackend.create(params, cfg, slots=K, max_context=W,
                                 cache=kind, **kw), port=0).start()
        try:
            out = Client.connect(server.address).sample_futures(req)
        finally:
            server.stop()
        assert out.backend == "remote[engine]"
        assert [(t.tokens, [np.float32(a) for a in t.ages])
                for t in out.trajectories] == \
               [(t.tokens, [np.float32(a) for a in t.ages])
                for t in ref.trajectories], f"remote diverged ({kind})"
        assert [(i.token, i.risk) for i in out.risk.items] == \
               [(i.token, i.risk) for i in ref.risk.items]
        if kind == "paged":
            assert out.sharing.get("forks") == 1


def test_healthz_exposes_sharing(setup):
    from repro.api.remote import RemoteBackend
    from repro.serve.server import InferenceServer
    params, cfg = setup
    server = InferenceServer(
        EngineBackend.create(params, cfg, slots=2, max_context=W,
                             cache="paged", block_size=BS,
                             prefix_cache=True), port=0).start()
    try:
        rb = RemoteBackend(server.address)
        mem = rb.healthz()["engine"]["memory"]
        assert "shared_blocks" in mem and "cow_copies" in mem
        assert mem["prefix_cache"]["entries"] == 0
    finally:
        server.stop()


def test_background_sample_futures_concurrent(setup):
    """Handler-thread orchestration: concurrent sample_futures against one
    background-ticking engine all complete, share, and drain."""
    params, cfg = setup
    eng = BatchedEngine(params, cfg, slots=K, max_context=W, cache="paged",
                        block_size=BS, prefix_cache=True).start()
    results = {}
    try:
        def worker(i):
            kids = eng.sample_futures(TOKS, AGES, n=2, max_new=4,
                                      request_id=f"bg-{i}",
                                      wait_timeout=120.0)
            results[i] = kids
        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(3)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=180)
        assert not any(t.is_alive() for t in threads)
    finally:
        eng.stop()
    assert sorted(results) == [0, 1, 2]
    for kids in results.values():
        assert all(k.done and k.error is None for k in kids)
        assert all(len(k.out_tokens) >= 1 for k in kids)
    eng.drop_prefix_cache()
    assert eng.allocator.used == 0 and not eng.pool._refs
