"""Cohort scenario engine: counterfactual edits, bounded-concurrency
sweeps (bit-parity vs the per-patient foreground oracle), scheduler
retry/deadline isolation, and result schemas."""
import json
import threading

import jax
import numpy as np
import pytest

from repro.api.client import EngineBackend, LocalBackend
from repro.api.schemas import (FuturesResult, RiskItem, RiskReport,
                               TrajectoryResult)
from repro.cohort import (CounterfactualEdit, ScenarioEngine, apply_edit,
                          assert_sweep_parity, sweep_uniforms)
from repro.cohort.engine import _merge_sharing
from repro.configs import get_config
from repro.core import init_delphi

W, BS, K = 64, 16, 4          # test_prefix geometry -> shared jit cache


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("delphi-2m", reduced=True).replace(
        dtype="float32", vocab_size=96, max_seq_len=48, max_age=1e9)
    params = init_delphi(cfg, jax.random.PRNGKey(7))
    return params, cfg


def _patients(n, S=5):
    """Fixed-length synthetic histories (fixed shapes -> one compile)."""
    out = []
    for i in range(n):
        rng = np.random.default_rng(100 + i)
        toks = np.concatenate([[3], rng.integers(13, 90, S - 1)])
        ages = np.concatenate([[0.0],
                               np.sort(rng.uniform(1.0, 40.0, S - 1))])
        out.append((toks.astype(np.int32), ages.astype(np.float32)))
    return out


# ---------------------------------------------------------------------------
# Counterfactual edits
# ---------------------------------------------------------------------------
def test_apply_edit_insert_keeps_ages_sorted():
    toks = [3, 20, 30, 40]
    ages = [0.0, 10.0, 20.0, 30.0]
    t2, a2, shared = apply_edit(toks, ages,
                                CounterfactualEdit("insert", 77, age=15.0))
    assert t2.tolist() == [3, 20, 77, 30, 40]
    assert a2.tolist() == [0.0, 10.0, 15.0, 20.0, 30.0]
    assert shared == 2                      # events before the edit point
    assert np.all(np.diff(a2) >= 0)
    # insert past the end shares the whole history
    t3, a3, s3 = apply_edit(toks, ages,
                            CounterfactualEdit("insert", 77, age=99.0))
    assert t3.tolist() == [3, 20, 30, 40, 77] and s3 == 4


def test_apply_edit_remove_and_substitute():
    toks = [3, 20, 30, 40]
    ages = [0.0, 10.0, 20.0, 30.0]
    t2, a2, shared = apply_edit(toks, ages,
                                CounterfactualEdit("remove", 30))
    assert t2.tolist() == [3, 20, 40] and a2.tolist() == [0.0, 10.0, 30.0]
    assert shared == 2
    t3, a3, s3 = apply_edit(
        toks, ages, CounterfactualEdit("substitute", 20, new_code=55))
    assert t3.tolist() == [3, 55, 30, 40]
    assert a3.tolist() == ages and s3 == 1


def test_apply_edit_errors():
    toks, ages = [3, 20], [0.0, 10.0]
    with pytest.raises(ValueError, match="no occurrence"):
        apply_edit(toks, ages, CounterfactualEdit("remove", 99))
    with pytest.raises(ValueError, match="need an age"):
        apply_edit(toks, ages, CounterfactualEdit("insert", 5))
    with pytest.raises(ValueError, match="new_code"):
        apply_edit(toks, ages, CounterfactualEdit("substitute", 20))
    with pytest.raises(ValueError, match="one of"):
        apply_edit(toks, ages, CounterfactualEdit("mutate", 20))
    with pytest.raises(ValueError, match="empty history"):
        apply_edit([20], [5.0], CounterfactualEdit("remove", 20))


def test_edit_json_roundtrip():
    for e in (CounterfactualEdit("insert", 77, age=15.0),
              CounterfactualEdit("remove", 30),
              CounterfactualEdit("substitute", 20, new_code=55)):
        assert CounterfactualEdit.from_json(
            json.loads(json.dumps(e.to_json()))) == e


def test_sweep_uniforms_deterministic():
    u1 = sweep_uniforms(3, 17, 4, 6, 96)
    u2 = sweep_uniforms(3, 17, 4, 6, 96)
    assert u1.shape == (4, 6, 96) and u1.dtype == np.float32
    np.testing.assert_array_equal(u1, u2)
    assert not np.array_equal(u1, sweep_uniforms(3, 18, 4, 6, 96))


def test_merge_sharing_takes_cumulative_max():
    merged = _merge_sharing([
        {"forks": 2, "prefix_cache": {"hits": 1, "misses": 3}},
        {"forks": 5, "cow_copies": 1,
         "prefix_cache": {"hits": 4, "misses": 2}},
        {"forks": 3, "prefix_cache": {"hits": 2, "misses": 9}},
    ])
    assert merged["forks"] == 5 and merged["cow_copies"] == 1
    assert merged["prefix_cache"] == {"hits": 4, "misses": 9}


# ---------------------------------------------------------------------------
# Sweeps
# ---------------------------------------------------------------------------
def test_sweep_engine_bit_parity_with_oracle(setup):
    """The acceptance gate in miniature: a concurrent sweep through the
    paged + prefix-cached engine is bit-identical to the per-patient
    foreground monte_carlo_risk oracle under injected uniforms."""
    params, cfg = setup
    pats = _patients(5)
    be = EngineBackend.create(params, cfg, slots=K, max_context=W,
                              cache="paged", block_size=BS, blocks=64,
                              prefix_cache=True)
    se = ScenarioEngine(be, max_in_flight=3, seed=11)
    res = se.sweep(pats, n_futures=3, max_new=6, horizon=20.0)
    assert res.n_failed == 0 and res.n_patients == 5
    assert res.events_total > 0
    stats = assert_sweep_parity(res, params, cfg, pats, seed=11,
                                n_futures=3, max_new=6, horizon=20.0,
                                slots=K, max_context=W)
    assert stats["patients_checked"] == 5
    assert stats["events_checked"] == res.events_total


def test_sweep_determinism_across_concurrency(setup):
    """max_in_flight must be unobservable: per-patient injected uniforms
    make 1-worker and 3-worker sweeps produce identical results."""
    params, cfg = setup
    pats = _patients(4)

    def run(workers):
        be = EngineBackend.create(params, cfg, slots=K, max_context=W,
                                  cache="paged", block_size=BS, blocks=64,
                                  prefix_cache=True)
        se = ScenarioEngine(be, max_in_flight=workers, seed=5)
        return se.sweep(pats, n_futures=3, max_new=6, horizon=20.0)

    r1, r3 = run(1), run(3)
    assert r1.n_failed == r3.n_failed == 0
    for p1, p3 in zip(r1.results, r3.results):
        assert [(t.tokens, t.ages) for t in p1.result.trajectories] == \
               [(t.tokens, t.ages) for t in p3.result.trajectories]
        np.testing.assert_array_equal(p1.chapter_risk, p3.chapter_risk)
    np.testing.assert_array_equal(r1.chapter_mean, r3.chapter_mean)
    np.testing.assert_array_equal(r1.chapter_hist, r3.chapter_hist)


def test_sweep_local_backend_and_json(setup):
    params, cfg = setup
    pats = _patients(3)
    se = ScenarioEngine(LocalBackend(params, cfg), max_in_flight=2, seed=2)
    res = se.sweep(pats, n_futures=2, max_new=5, horizon=20.0, hist_bins=4)
    assert res.n_failed == 0
    assert res.chapter_hist.shape == (res.chapter_mean.shape[0], 4)
    assert res.chapter_hist.sum(axis=1).max() <= res.n_ok
    d = json.loads(json.dumps(res.to_json()))
    assert d["n_patients"] == 3 and len(d["patients"]) == 3
    assert d["events_total"] == res.events_total
    assert 0.0 <= d["prefix_hit_rate"] <= 1.0


# ---------------------------------------------------------------------------
# Scheduler: retry, deadline, failure isolation
# ---------------------------------------------------------------------------
class _FlakyBackend:
    """Fails the first ``fail_n`` attempts per patient, then succeeds."""
    name = "flaky"
    vocab_size = 96

    def __init__(self, fail_n=1, hang_index=None):
        self.fail_n = fail_n
        self.hang_index = hang_index
        self.attempts = {}
        self._lk = threading.Lock()

    def sample_futures(self, req):
        idx = int(req.request_id.split("-")[1])
        with self._lk:
            k = self.attempts[idx] = self.attempts.get(idx, 0) + 1
        if idx == self.hang_index or k <= self.fail_n:
            raise RuntimeError(f"flaky failure #{k}")
        traj = TrajectoryResult(tokens=[15], ages=[1.0],
                                prompt_tokens=list(req.tokens),
                                prompt_ages=list(req.ages),
                                backend=self.name)
        return FuturesResult(
            risk=RiskReport(horizon=req.horizon,
                            items=[RiskItem(token=15, risk=1.0)]),
            trajectories=[traj] * req.n_futures,
            n_futures=req.n_futures, backend=self.name)


def test_sweep_retries_transient_failures():
    b = _FlakyBackend(fail_n=1)
    se = ScenarioEngine(b, max_in_flight=2, seed=0, retries=2)
    res = se.sweep(_patients(4), n_futures=2, max_new=4)
    assert res.n_failed == 0
    assert all(p.retries == 1 for p in res.results)
    assert all(b.attempts[i] == 2 for i in range(4))


def test_sweep_isolates_exhausted_patients():
    """A patient that keeps failing lands as a structured failure; the
    rest of the cohort still completes and aggregates."""
    b = _FlakyBackend(fail_n=0, hang_index=1)
    se = ScenarioEngine(b, max_in_flight=2, seed=0, retries=1)
    res = se.sweep(_patients(4), n_futures=2, max_new=4)
    assert res.n_failed == 1 and res.n_ok == 3
    bad = res.results[1]
    assert not bad.ok and "RuntimeError" in bad.error
    assert b.attempts[1] == 2               # retries + 1 attempts
    assert res.events_total == 3 * 2        # failed patient contributes 0
    d = res.to_json()
    assert d["patients"][1]["ok"] is False and "error" in d["patients"][1]


def test_sweep_deadline_caps_retries():
    b = _FlakyBackend(fail_n=10**9)         # never succeeds
    se = ScenarioEngine(b, max_in_flight=1, seed=0, retries=50,
                        patient_deadline=0.0)
    res = se.sweep(_patients(2), n_futures=2, max_new=4)
    assert res.n_failed == 2
    for p in res.results:
        assert "deadline" in p.error and "0" in p.error
    assert all(n <= 2 for n in b.attempts.values())


# ---------------------------------------------------------------------------
# Counterfactuals through the engine
# ---------------------------------------------------------------------------
def test_counterfactual_paired_reports(setup):
    """Paired CRN reports: identical uniforms across arms, chapter deltas
    bounded, edited arm re-forks from the shared prefix (the engine's
    prefix index sees the reuse)."""
    params, cfg = setup
    S = 20                                  # > block, so edits share blocks
    rng = np.random.default_rng(0)
    toks = np.concatenate([[3], rng.integers(13, 90, S - 1)]).astype(np.int32)
    ages = np.concatenate([[0.0], np.sort(
        rng.uniform(1.0, 40.0, S - 1))]).astype(np.float32)
    be = EngineBackend.create(params, cfg, slots=K, max_context=W,
                              cache="paged", block_size=4, blocks=128,
                              prefix_cache=True)
    se = ScenarioEngine(be, seed=3)
    edits = [CounterfactualEdit("insert", 44, age=float(ages[-2])),
             CounterfactualEdit("substitute", int(toks[-1]), new_code=50)]
    reps = se.counterfactual(toks, ages, edits, n_futures=3, max_new=5,
                             horizon=30.0)
    assert len(reps) == 2
    for r in reps:
        assert r.shared_prefix_len >= S - 2
        assert np.all(np.abs(r.chapter_delta) <= 1.0)
        assert len(r.baseline.trajectories) == 3
        d = json.loads(json.dumps(r.to_json()))
        assert d["shared_prefix_len"] == r.shared_prefix_len
        assert len(d["chapter_delta"]) == len(r.baseline_chapter)
    pc = be.engine.pool_stats()["prefix_cache"]
    # every edited arm's prefill found the baseline's blocks in the index
    assert pc["hits"] + pc["partial_hits"] >= len(edits)
