"""Paged KV-cache engine: ring-parity (generate/stream/batch), free-block
admission, preemption on pool exhaustion, cancellation, timeouts, and the
zero-leaked-blocks invariant."""
import threading
import time

import jax
import numpy as np
import pytest

from repro.api.errors import RequestCancelledError, RequestTimeoutError
from repro.configs import get_config
from repro.core import init_delphi
from repro.serve import BatchedEngine, BlockAllocator, Request


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("delphi-2m", reduced=True).replace(
        dtype="float32", vocab_size=96, max_seq_len=48, max_age=1e9)
    params = init_delphi(cfg, jax.random.PRNGKey(7))
    return params, cfg


def _uniforms(max_new, V, seed=42):
    rng = np.random.default_rng(seed)
    return rng.uniform(size=(max_new, V)).astype(np.float32)


def _req(s, max_new=8, uniforms=None, request_id=None):
    S = 3 + (s % 4)
    return Request(tokens=(np.arange(3, 3 + S, dtype=np.int32) + s) % 90,
                   ages=np.linspace(0.0, 30.0, S).astype(np.float32),
                   max_new=max_new, uniforms=uniforms, request_id=request_id)


# ---------------------------------------------------------------------------
# Allocator
# ---------------------------------------------------------------------------
def test_allocator_free_list():
    a = BlockAllocator(6)               # capacity 5, block 0 reserved
    assert (a.capacity, a.free, a.used) == (5, 5, 0)
    ids = a.alloc(3)
    assert len(ids) == 3 and 0 not in ids
    assert a.alloc(3) is None           # never partial
    assert a.used == 3 and a.peak_used == 3
    a.release(ids)
    assert a.used == 0 and a.free == 5
    with pytest.raises(ValueError):
        a.release([0])                  # trash block is not allocatable
    with pytest.raises(RuntimeError):
        a.release(ids + [1, 2])         # over-free detected


def test_engine_rejects_bad_paged_config(setup):
    params, cfg = setup
    with pytest.raises(ValueError, match="multiple"):
        BatchedEngine(params, cfg, max_context=50, cache="paged",
                      block_size=16)
    with pytest.raises(ValueError, match="one full slot"):
        BatchedEngine(params, cfg, max_context=64, cache="paged",
                      block_size=16, blocks=3)
    with pytest.raises(ValueError, match="'ring' or 'paged'"):
        BatchedEngine(params, cfg, cache="dense")


# ---------------------------------------------------------------------------
# Ring parity (the tentpole invariant)
# ---------------------------------------------------------------------------
def _run(params, cfg, kind, reqs, **kw):
    eng = BatchedEngine(params, cfg, slots=2, max_context=64, cache=kind,
                        **kw)
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == len(reqs)
    return eng, [(r.out_tokens, r.out_ages) for r in done]


def test_paged_bit_identical_to_ring_generate(setup):
    """Same slots, same injected uniforms: the paged engine's trajectories
    (tokens AND fp32 ages) equal the ring engine's bit for bit — the paged
    read path reconstructs the exact ring view."""
    params, cfg = setup
    u = _uniforms(8, cfg.vocab_size)
    ring_reqs = [_req(s, uniforms=u) for s in range(5)]
    paged_reqs = [_req(s, uniforms=u) for s in range(5)]
    _, ring = _run(params, cfg, "ring", ring_reqs)
    eng, paged = _run(params, cfg, "paged", paged_reqs, block_size=16)
    assert ring == paged                # exact: tokens and ages
    assert eng.allocator.used == 0


def test_paged_bit_identical_over_width_prompt(setup):
    """S > max_context: the wrapped ring pack flows through the block copy
    identically (solo exact-shape admission in both engines)."""
    params, cfg = setup
    S, W = 33, 16
    toks = (np.arange(3, 3 + S) % 90).astype(np.int32)
    ages = np.linspace(0.0, 30.0, S).astype(np.float32)
    u = _uniforms(4, cfg.vocab_size, seed=13)

    def mk():
        return Request(tokens=toks, ages=ages, max_new=4, uniforms=u)
    r_ring = BatchedEngine(params, cfg, slots=1, max_context=W)
    r_ring.submit(mk())
    ring_done = r_ring.run()
    r_paged = BatchedEngine(params, cfg, slots=1, max_context=W,
                            cache="paged", block_size=8)
    r_paged.submit(mk())
    paged_done = r_paged.run()
    assert ring_done[0].out_tokens == paged_done[0].out_tokens
    assert ring_done[0].out_ages == paged_done[0].out_ages
    assert r_paged.allocator.used == 0


def test_paged_stream_and_batch_parity(setup):
    """EngineBackend generate/stream/batch over the paged engine == the
    ring engine, event for event, under injected uniforms."""
    from repro.api import GenerateRequest
    from repro.api.client import EngineBackend
    params, cfg = setup
    u = _uniforms(6, cfg.vocab_size, seed=5)
    toks, ages = [3, 10, 20], [0.0, 15.0, 28.0]

    def backend(kind):
        return EngineBackend.create(params, cfg, slots=2, max_context=64,
                                    cache=kind, block_size=16)
    ring_b, paged_b = backend("ring"), backend("paged")
    req = GenerateRequest(tokens=toks, ages=ages, max_new=6, uniforms=u)
    g_r = ring_b.generate(req)
    g_p = paged_b.generate(req)
    assert g_r.tokens == g_p.tokens and g_r.ages == g_p.ages
    ev_r = [e.token for e in ring_b.stream(req)]
    ev_p = [e.token for e in paged_b.stream(req)]
    assert ev_r == ev_p == g_r.tokens
    batch = [GenerateRequest(tokens=toks, ages=ages, max_new=6, uniforms=u)
             for _ in range(3)]
    b_r = ring_b.generate_batch(batch)
    b_p = paged_b.generate_batch(batch)
    assert [r.tokens for r in b_r] == [r.tokens for r in b_p]
    assert paged_b.engine.allocator.used == 0


# ---------------------------------------------------------------------------
# Scheduler: free-block admission, growth, preemption
# ---------------------------------------------------------------------------
def test_admission_budgeted_by_free_blocks(setup):
    """With a pool below slots x context the scheduler admits what fits and
    queues the rest; peak concurrency still exceeds what a dense ring of
    the same bytes could hold once requests are short."""
    params, cfg = setup
    # capacity 5 blocks of 8 tokens; 4 slots x 32 ctx would need 16
    eng = BatchedEngine(params, cfg, slots=4, max_context=32, cache="paged",
                        block_size=8, blocks=6)
    for s in range(6):
        eng.submit(_req(s, max_new=4))
    done = eng.run(max_ticks=2000)
    assert len(done) == 6
    assert eng.allocator.used == 0
    assert eng.allocator.peak_used <= 5
    assert eng.peak_active >= 2         # several short requests co-resident


def test_preemption_on_pool_exhaustion(setup):
    """Decode growth past the pool preempts the youngest request (requeued,
    recompute-resumed) instead of deadlocking; every request completes and
    no block leaks."""
    params, cfg = setup
    eng = BatchedEngine(params, cfg, slots=4, max_context=32, cache="paged",
                        block_size=8, blocks=6)
    for s in range(8):
        eng.submit(_req(s, max_new=10))
    done = eng.run(max_ticks=4000)
    assert len(done) == 8
    for r in done:
        assert r.error is None
        assert (len(r.out_tokens) == 10
                or r.out_tokens[-1] == cfg.death_token)
        assert len(r.out_ages) == len(r.out_tokens)
        assert all(b >= a - 1e-6
                   for a, b in zip(r.out_ages, r.out_ages[1:]))
    assert eng.preemptions > 0
    assert eng.allocator.used == 0


def test_preempted_injected_request_resumes_uniform_rows(setup):
    """A preempted uniforms-injected request consumes row i for event i
    across the preemption boundary (resume re-prefills, then continues
    from the next unconsumed row)."""
    params, cfg = setup
    u = _uniforms(10, cfg.vocab_size, seed=11)
    reqs = [_req(s, max_new=10, uniforms=u) for s in range(4)]
    eng = BatchedEngine(params, cfg, slots=4, max_context=32, cache="paged",
                        block_size=8, blocks=6)
    for r in reqs:
        eng.submit(r)
    done = eng.run(max_ticks=4000)
    assert len(done) == 4 and eng.preemptions > 0
    # sanity: every trajectory emitted events and respects max_new
    for r in done:
        assert 1 <= len(r.out_tokens) <= 10
    assert eng.allocator.used == 0


# ---------------------------------------------------------------------------
# Cancellation + timeout free blocks
# ---------------------------------------------------------------------------
def test_cancel_pending_and_inflight(setup):
    params, cfg = setup
    eng = BatchedEngine(params, cfg, slots=2, max_context=32, cache="paged",
                        block_size=8)
    rs = [_req(s, max_new=28, request_id=f"r{s}") for s in range(4)]
    for r in rs:
        eng.submit(r)
    eng.step()                          # admit r0/r1; r2/r3 pending
    assert eng.cancel("r0")             # in flight
    assert eng.cancel("r3")             # pending
    assert not eng.cancel("unknown-id")
    eng.run(max_ticks=2000)
    assert isinstance(rs[0].error, RequestCancelledError)
    assert isinstance(rs[3].error, RequestCancelledError)
    assert rs[1].error is None and rs[2].error is None
    assert rs[0] not in eng.completed and rs[3] not in eng.completed
    assert eng.allocator.used == 0
    assert not eng.cancel("r0")         # already finished


def test_cancel_from_background_thread(setup):
    params, cfg = setup
    eng = BatchedEngine(params, cfg, slots=1, max_context=512, cache="paged",
                        block_size=16).start()
    try:
        blocker = _req(0, max_new=480)
        target = _req(1, max_new=480, request_id="victim")
        evt = threading.Event()
        target.on_done = lambda _r: evt.set()
        eng.submit(blocker)
        eng.submit(target)              # queued behind the single slot
        assert eng.cancel("victim")
        assert evt.wait(30)
        assert isinstance(target.error, RequestCancelledError)
    finally:
        eng.stop()
    assert eng.allocator.used == 0


def test_request_timeout_frees_blocks(setup):
    params, cfg = setup
    eng = BatchedEngine(params, cfg, slots=2, max_context=32, cache="paged",
                        block_size=8, request_timeout=0.0)
    r = _req(0, max_new=20)
    eng.submit(r)
    time.sleep(0.01)
    eng.run(max_ticks=100)
    assert r.done and isinstance(r.error, RequestTimeoutError)
    assert eng.allocator.used == 0


def test_ring_engine_cancel_also_supported(setup):
    params, cfg = setup
    eng = BatchedEngine(params, cfg, slots=2, max_context=32)
    r = _req(1, max_new=28)
    eng.submit(r)
    eng.step()
    assert eng.cancel(r.request_id)
    eng.run(max_ticks=500)
    assert r.done and isinstance(r.error, RequestCancelledError)


def test_paged_keeps_one_host_sync_per_tick(setup, monkeypatch):
    """The paged scheduler's host-side bookkeeping (tables, allocator,
    slot positions) must not add device->host transfers: still exactly ONE
    packed sync per tick plus one per admission batch."""
    from repro.serve import engine as engine_mod
    params, cfg = setup
    calls = []
    orig = engine_mod._to_host

    def counting(x):
        calls.append(x.shape)
        return orig(x)
    monkeypatch.setattr(engine_mod, "_to_host", counting)
    eng = BatchedEngine(params, cfg, slots=2, max_context=64, cache="paged",
                        block_size=16)
    for s in range(5):
        eng.submit(_req(s, max_new=4))
    done = eng.run()
    assert len(done) == 5
    assert len(calls) == eng.host_syncs == eng.ticks + eng.admit_batches
    assert all(s[0] == 4 for s in calls)


def test_admission_crash_releases_blocks_and_fails_waiters(setup, monkeypatch):
    """A device error mid-admission (after blocks were allocated, before
    the cohort landed in slots) must return the blocks to the pool and
    surface the failure to the cohort's waiters instead of stranding
    them."""
    from repro.serve import engine as engine_mod
    params, cfg = setup
    eng = BatchedEngine(params, cfg, slots=2, max_context=32, cache="paged",
                        block_size=8)

    def boom(*a, **k):
        raise RuntimeError("injected insert failure")
    monkeypatch.setattr(engine_mod, "_insert_blocks_jit", boom)
    rs = [_req(s, max_new=4) for s in range(2)]
    for r in rs:
        eng.submit(r)
    with pytest.raises(RuntimeError, match="injected"):
        eng.step()                       # foreground: the error propagates
    # blocks allocated for the crashed cohort are back in the pool and the
    # requests are back on the queue (a background loop would now fail them
    # via _fail_inflight)
    assert eng.allocator.used == 0
    assert len(eng.pending) == 2
    eng._fail_inflight(RuntimeError("injected insert failure"))
    assert all(r.done and r.error is not None for r in rs)
    assert eng.allocator.used == 0


def test_duplicate_request_id_rejected(setup):
    from repro.api.errors import InvalidRequestError
    params, cfg = setup
    eng = BatchedEngine(params, cfg, slots=2, max_context=32, cache="paged",
                        block_size=8)
    eng.submit(_req(0, request_id="dup"))
    with pytest.raises(InvalidRequestError, match="already in flight"):
        eng.submit(_req(1, request_id="dup"))
    eng.run(max_ticks=500)
    eng.submit(_req(2, request_id="dup"))   # id free again after completion
    eng.run(max_ticks=500)
    assert eng.allocator.used == 0


def test_pool_stats_shape(setup):
    params, cfg = setup
    eng = BatchedEngine(params, cfg, slots=2, max_context=32, cache="paged",
                        block_size=8)
    st = eng.pool_stats()
    assert st["cache"] == "paged" and st["blocks"] == 9
    assert st["cache_bytes"] == eng.cache_bytes > 0
    ring = BatchedEngine(params, cfg, slots=2, max_context=32)
    assert ring.pool_stats()["cache"] == "ring"
    # dense-equivalent default pool: paged k/v bytes == ring k/v bytes
    dflt = BatchedEngine(params, cfg, slots=2, max_context=32, cache="paged",
                        block_size=8)
    assert dflt.allocator.capacity == 2 * (32 // 8)
