"""MoE: dispatch-implementation equivalence, routing invariants, sharding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.moe import apply_moe, init_moe, route


@pytest.fixture(scope="module")
def moe_setup():
    cfg = get_config("olmoe-1b-7b", reduced=True).replace(dtype="float32")
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, cfg.d_model))
    return cfg, params, x


def test_impl_equivalence(moe_setup):
    """All four dispatch implementations agree (the §Perf variants are
    semantics-preserving)."""
    cfg, params, x = moe_setup
    y1, a1 = apply_moe(params, x, cfg, impl="dense_scan")
    for impl in ("ragged", "dense_einsum", "ragged_local"):
        y2, a2 = apply_moe(params, x, cfg, impl=impl)
        np.testing.assert_allclose(y1, y2, atol=1e-5, err_msg=impl)
        np.testing.assert_allclose(a1, a2, rtol=1e-6, err_msg=impl)


def test_router_normalized(moe_setup):
    cfg, params, x = moe_setup
    w, e, aux = route(params, x.reshape(-1, cfg.d_model), cfg)
    np.testing.assert_allclose(jnp.sum(w, -1), 1.0, rtol=1e-5)
    assert int(jnp.min(e)) >= 0 and int(jnp.max(e)) < cfg.n_experts
    # top-k experts are distinct per token
    assert bool((jnp.sort(e, -1)[:, 1:] != jnp.sort(e, -1)[:, :-1]).all())
    assert float(aux) > 0


def test_aux_loss_balanced_lower_bound(moe_setup):
    """Aux loss is minimized (== top_k) under perfectly uniform routing."""
    cfg, params, x = moe_setup
    # uniform router: zero weights
    params2 = dict(params)
    params2["router"] = jnp.zeros_like(params["router"])
    _, _, aux = route(params2, x.reshape(-1, cfg.d_model), cfg)
    np.testing.assert_allclose(float(aux), cfg.top_k, rtol=0.2)


def test_shared_experts_contribute(moe_setup):
    cfg, params, x = moe_setup
    cfg_shared = get_config("qwen2-moe-a2.7b", reduced=True).replace(
        dtype="float32")
    p = init_moe(jax.random.PRNGKey(3), cfg_shared)
    assert "shared" in p
    y, _ = apply_moe(p, x[..., :cfg_shared.d_model], cfg_shared)
    p0 = dict(p)
    p0["shared"] = jax.tree_util.tree_map(jnp.zeros_like, p["shared"])
    y0, _ = apply_moe(p0, x[..., :cfg_shared.d_model], cfg_shared)
    assert float(jnp.max(jnp.abs(y - y0))) > 1e-4


def test_expert_gradients_flow(moe_setup):
    cfg, params, x = moe_setup
    def loss(p):
        y, aux = apply_moe(p, x, cfg)
        return jnp.sum(y ** 2) + 0.01 * aux
    g = jax.grad(loss)(params)
    gnorm = float(sum(jnp.sum(jnp.abs(v))
                      for v in jax.tree_util.tree_leaves(g)))
    assert np.isfinite(gnorm) and gnorm > 0
    # router must receive gradient (load-balance + combine weights)
    assert float(jnp.sum(jnp.abs(g["router"]))) > 0
