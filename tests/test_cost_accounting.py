"""Validation of the §Roofline cost-accounting methodology.

The dry-run extrapolates FLOP/byte/collective counts linearly over depth from
straight-line twins at depth 1 and 2 (EXPERIMENTS.md §Dry-run/Method).  Here
we verify, on the host mesh with reduced configs, that the extrapolation
reproduces a *fully unrolled* depth-L compile to ~1% — the residual being
XLA fusion across layer boundaries (slightly different CSE at different
depths) — for the homogeneous, hybrid (periodic shared-attention), and
encoder-decoder stack laws.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_shape
from repro.configs.base import InputShape
from repro.launch.dryrun import _count_one, _extrapolated_counts
from repro.launch.mesh import make_host_mesh

SMALL = InputShape("small_train", 64, 2, "train")
SMALL_DECODE = InputShape("small_decode", 64, 2, "decode")


def _full_unrolled(cfg, shape, mesh):
    return _count_one(cfg.replace(unroll_layers=True, attn_direct=True),
                      shape, mesh)


@pytest.mark.parametrize("arch,L", [("tinyllama-1.1b", 4), ("mamba2-780m", 4)])
def test_extrapolation_matches_full_unroll_homogeneous(arch, L):
    mesh = make_host_mesh()
    cfg = get_config(arch, reduced=True).replace(n_layers=L, remat=False)
    got = _extrapolated_counts(cfg, SMALL, mesh)
    want = _full_unrolled(cfg, SMALL, mesh)
    np.testing.assert_allclose(got["flops"], want["flops"], rtol=0.05)
    np.testing.assert_allclose(got["bytes"], want["bytes"], rtol=0.05)


def test_extrapolation_matches_full_unroll_hybrid():
    mesh = make_host_mesh()
    # attn_every=2, L=5 -> 3 shared-attn applications, 5 mamba layers
    cfg = get_config("zamba2-1.2b", reduced=True).replace(
        n_layers=5, attn_every=2, remat=False)
    got = _extrapolated_counts(cfg, SMALL, mesh)
    want = _full_unrolled(cfg, SMALL, mesh)
    np.testing.assert_allclose(got["flops"], want["flops"], rtol=0.05)
    np.testing.assert_allclose(got["bytes"], want["bytes"], rtol=0.05)


def test_extrapolation_matches_full_unroll_encdec():
    mesh = make_host_mesh()
    cfg = get_config("seamless-m4t-large-v2", reduced=True).replace(
        n_layers=3, n_encoder_layers=4, remat=False)
    got = _extrapolated_counts(cfg, SMALL, mesh)
    want = _full_unrolled(cfg, SMALL, mesh)
    np.testing.assert_allclose(got["flops"], want["flops"], rtol=0.05)
    np.testing.assert_allclose(got["bytes"], want["bytes"], rtol=0.05)


def test_extrapolation_decode_mode():
    mesh = make_host_mesh()
    cfg = get_config("tinyllama-1.1b", reduced=True).replace(n_layers=3)
    got = _extrapolated_counts(cfg, SMALL_DECODE, mesh)
    want = _full_unrolled(cfg, SMALL_DECODE, mesh)
    np.testing.assert_allclose(got["flops"], want["flops"], rtol=0.05)


def test_unrolled_twin_counts_exceed_scanned():
    """The scanned deployment graph undercounts loops — the reason the twin
    exists.  At L=4 the straight-line FLOPs must be substantially larger."""
    mesh = make_host_mesh()
    cfg = get_config("tinyllama-1.1b", reduced=True).replace(
        n_layers=4, remat=False)
    scanned = _count_one(cfg, SMALL, mesh)
    unrolled = _full_unrolled(cfg, SMALL, mesh)
    assert unrolled["flops"] > 1.5 * scanned["flops"]
