"""Mandated per-architecture smoke tests: a REDUCED variant of each assigned
family runs one forward + one train step + one decode step on CPU with shape
and finiteness assertions, plus decode-vs-dense logit parity (the cache
machinery proof)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, get_config
from repro.models import decode_step, forward, init_params, param_count
from repro.train import OptimizerConfig, init_opt_state, make_train_step


def make_batch(cfg, key, B=2, S=32, train=False):
    ks = jax.random.split(key, 4)
    batch = {"tokens": jax.random.randint(ks[0], (B, S), 3, cfg.vocab_size)}
    if cfg.age_encoding:
        batch["ages"] = jnp.cumsum(
            jax.random.uniform(ks[1], (B, S), maxval=3.0), axis=1)
        if train:
            batch["targets"] = jax.random.randint(ks[2], (B, S), 3,
                                                  cfg.vocab_size)
            batch["target_dt"] = jax.random.uniform(ks[3], (B, S),
                                                    minval=0.01, maxval=2.0)
            batch["loss_mask"] = jnp.ones((B, S), jnp.float32)
    if cfg.frontend == "vision_patches":
        batch["patches"] = jax.random.normal(
            ks[2], (B, cfg.n_frontend_tokens, cfg.d_model))
    if cfg.frontend == "audio_frames":
        batch["frames"] = jax.random.normal(
            ks[2], (B, max(S // cfg.enc_len_ratio, 2), cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch, key):
    cfg = get_config(arch, reduced=True).replace(dtype="float32")
    params = init_params(cfg, key)
    assert param_count(params) > 0
    B, S = 2, 32
    batch = make_batch(cfg, key, B, S)
    out = forward(params, cfg, batch, mode="train")
    S_out = S + (cfg.n_frontend_tokens if cfg.frontend == "vision_patches" else 0)
    assert out["logits"].shape == (B, S_out, cfg.vocab_size)
    assert bool(jnp.isfinite(out["logits"]).all())


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_one_train_step(arch, key):
    cfg = get_config(arch, reduced=True).replace(dtype="float32")
    params = init_params(cfg, key)
    objective = "delphi" if cfg.age_encoding else "lm"
    step = jax.jit(make_train_step(cfg, OptimizerConfig(lr=1e-3,
                                                        total_steps=10),
                                   objective))
    opt = init_opt_state(params)
    batch = make_batch(cfg, key, 2, 32, train=True)
    new_params, opt, m = step(params, opt, batch)
    assert bool(jnp.isfinite(m["loss"]))
    # params actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), params, new_params)
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_parity(arch, key):
    """decode(prefill(x[:-1]), x[-1]) == forward(x)[-1] — validates KV ring,
    SSD state handoff, cross-attention caches, hybrid shared-block caches."""
    cfg = get_config(arch, reduced=True).replace(dtype="float32")
    params = init_params(cfg, key)
    B, S = 2, 33
    batch = make_batch(cfg, key, B, S)
    full = forward(params, cfg, batch, mode="train")["logits"][:, -1]

    pb = {k: (v[:, :S - 1] if k in ("tokens", "ages") else v)
          for k, v in batch.items()}
    pre = forward(params, cfg, pb, mode="prefill", cache_width=64)
    db = {"tokens": batch["tokens"][:, S - 1:S]}
    if cfg.age_encoding:
        db["ages"] = batch["ages"][:, S - 1:S]
    step = S - 1 + (cfg.n_frontend_tokens
                    if cfg.frontend == "vision_patches" else 0)
    d = decode_step(params, cfg, pre["cache"], db, jnp.int32(step))
    np.testing.assert_allclose(d["logits"][:, 0], full, atol=3e-4)


def test_prefill_logits_last_position(key):
    cfg = get_config("tinyllama-1.1b", reduced=True).replace(dtype="float32")
    params = init_params(cfg, key)
    batch = make_batch(cfg, key, 2, 16)
    pre = forward(params, cfg, batch, mode="prefill")
    full = forward(params, cfg, batch, mode="train")
    assert pre["logits"].shape[1] == 1
    np.testing.assert_allclose(pre["logits"][:, 0], full["logits"][:, -1],
                               atol=1e-5)


def test_paper_technique_attaches_to_zoo_backbone(key):
    """DESIGN.md §Arch-applicability: the Delphi event/time head (T1) is a
    head + loss + sampler, attachable to any next-token backbone.  Attach it
    to the tinyllama (RoPE, GQA) backbone: dual-loss train step runs and the
    competing-exponential sampler generates monotone-age trajectories."""
    from repro.core import generate_trajectories
    cfg = get_config("tinyllama-1.1b", reduced=True).replace(
        dtype="float32", dual_head=True)
    params = init_params(cfg, key)
    assert "out_bias" in params["embed"]            # the T1 head bias
    batch = make_batch(cfg.replace(age_encoding=True), key, 2, 16, train=True)
    step = jax.jit(make_train_step(cfg, OptimizerConfig(total_steps=5),
                                   "delphi"))
    _, _, m = step(params, init_opt_state(params), batch)
    assert bool(jnp.isfinite(m["loss"])) and float(m["time_nll"]) > 0
    out = generate_trajectories(params, cfg, batch["tokens"][:, :8],
                                batch["ages"][:, :8], key, max_new=6)
    diffs = jnp.diff(out["ages"], axis=1)
    assert float(jnp.min(diffs)) >= -1e-5


def test_vlm_frontend_prepended(key):
    cfg = get_config("internvl2-26b", reduced=True).replace(dtype="float32")
    params = init_params(cfg, key)
    batch = make_batch(cfg, key, 2, 16)
    out = forward(params, cfg, batch, mode="train")
    assert out["text_offset"] == cfg.n_frontend_tokens
    # patches influence text logits (information flows across the boundary)
    batch2 = dict(batch)
    batch2["patches"] = batch["patches"] + 1.0
    out2 = forward(params, cfg, batch2, mode="train")
    assert float(jnp.max(jnp.abs(out["logits"] - out2["logits"]))) > 1e-3
