"""Config registry: assigned specs are exact; reduced variants obey bounds."""
import pytest

from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, INPUT_SHAPES, get_config

EXPECTED = {
    # arch: (layers, d_model, heads, kv, d_ff, vocab)
    "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
    "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
    "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
    "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
    "mamba2-780m": (48, 1536, 0, 0, 0, 50280),
    "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
    "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
    "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
    "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
    "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
}


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_assigned_spec_exact(arch):
    c = get_config(arch)
    exp = EXPECTED[arch]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == exp
    assert c.citation


def test_assignment_complete():
    assert len(ASSIGNED_ARCHS) == 10
    assert len(INPUT_SHAPES) == 4
    assert {s.name for s in INPUT_SHAPES} == {
        "train_4k", "prefill_32k", "decode_32k", "long_500k"}


def test_arch_details():
    q = get_config("qwen2.5-32b")
    assert q.qkv_bias
    assert get_config("h2o-danube-1.8b").sliding_window == 4096
    moe = get_config("qwen2-moe-a2.7b")
    assert (moe.n_experts, moe.top_k, moe.n_shared_experts) == (60, 4, 4)
    ol = get_config("olmoe-1b-7b")
    assert (ol.n_experts, ol.top_k) == (64, 8)
    m = get_config("mamba2-780m")
    assert m.ssm_state == 128 and m.is_attention_free
    z = get_config("zamba2-1.2b")
    assert z.ssm_state == 64 and z.attn_every == 6
    s = get_config("seamless-m4t-large-v2")
    assert s.n_encoder_layers == 24 and s.frontend == "audio_frames"
    v = get_config("internvl2-26b")
    assert v.frontend == "vision_patches"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_bounds(arch):
    r = get_config(arch, reduced=True)
    full = get_config(arch)
    assert r.n_layers == 2
    assert r.d_model <= 512
    if full.n_experts:
        assert r.n_experts <= 4
    assert r.arch_type == full.arch_type          # same family
    if full.n_heads:
        assert r.n_heads % r.n_kv_heads == 0


def test_sliding_window_variant():
    c = get_config("qwen2.5-32b")
    assert c.sliding_window is None
    cw = c.with_sliding_window(8192)
    assert cw.sliding_window == 8192 and c.sliding_window is None
