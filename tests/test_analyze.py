"""repro-lint (tools/analyze): per-rule fixtures + repo self-run.

Each rule gets a fixture it MUST flag (positive) and a near-identical one
it must NOT flag (negative), plus suppression/baseline semantics and a
self-run over ``src/repro`` asserting the tree is clean modulo the
committed baseline.  Fixtures are parsed, never imported, so they don't
need to be runnable.
"""
import sys
import textwrap
from pathlib import Path

import pytest

REPO_ROOT = Path(__file__).resolve().parents[1]
if str(REPO_ROOT) not in sys.path:       # tests run with PYTHONPATH=src;
    sys.path.insert(0, str(REPO_ROOT))   # `tools` lives at the repo root

from tools.analyze import baseline as baseline_mod  # noqa: E402
from tools.analyze.cli import main as cli_main, run_lint  # noqa: E402
from tools.analyze.wire import FROZEN_WIRE_V1  # noqa: E402


def make_project(tmp_path, files):
    """Write {relpath: source} and lint it (no baseline)."""
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return run_lint(tmp_path)


def rules_of(res):
    return [f.rule for f in res.new]


# ---------------------------------------------------------------------------
# RL001 lock discipline
# ---------------------------------------------------------------------------
RL001_POSITIVE = """
    import threading

    class Eng:
        def __init__(self):
            self._lock = threading.Lock()
            self.pending = []        # guarded-by: _lock
            self.slot_req = []       # guarded-by: engine-thread

        def submit(self, r):
            with self._lock:
                self.pending.append(r)      # locked: ok

        def bad_queue_peek(self):
            return len(self.pending)        # RL001: unlocked read

        def bad_slot_peek(self):
            return self.slot_req            # RL001: wrong thread

        def step(self):  # repro-lint: engine-thread-only
            return self.pending, self.slot_req   # both exempt

        def holds(self):  # repro-lint: holds=_lock
            return self.pending[0]          # caller owns the lock: ok
"""


def test_rl001_flags_unguarded_access(tmp_path):
    res = make_project(tmp_path, {"src/repro/serve/eng.py": RL001_POSITIVE})
    assert rules_of(res) == ["RL001", "RL001"]
    msgs = " ".join(f.message for f in res.new)
    assert "bad_queue_peek" in msgs and "bad_slot_peek" in msgs
    # the disciplined accesses stay silent
    assert "submit" not in msgs and "`Eng.step`" not in msgs


def test_rl001_foreign_access(tmp_path):
    res = make_project(tmp_path, {
        "src/repro/serve/eng.py": RL001_POSITIVE,
        "src/repro/serve/web.py": """
            class Handler:
                def healthz(self, eng):
                    return len(eng.pending)     # RL001: foreign access
        """,
        "src/repro/serve/other.py": """
            class RefEngine:
                def __init__(self):
                    self.pending = []           # its own field, unguarded

                def drain(self):
                    return self.pending         # not a foreign access
        """,
    })
    foreign = [f for f in res.new if "foreign access" in f.message]
    assert len(foreign) == 1
    assert foreign[0].path.endswith("web.py")


def test_rl001_negative_all_locked(tmp_path):
    res = make_project(tmp_path, {"src/repro/serve/eng.py": """
        import threading

        class Eng:
            def __init__(self):
                self._lock = threading.Lock()
                self.pending = []    # guarded-by: _lock

            def submit(self, r):
                with self._lock:
                    self.pending.append(r)
    """})
    assert res.new == []


# ---------------------------------------------------------------------------
# RL002 trace purity
# ---------------------------------------------------------------------------
def test_rl002_flags_host_syncs(tmp_path):
    res = make_project(tmp_path, {"src/repro/core/fn.py": """
        import functools
        import jax
        import numpy as np

        @jax.jit
        def bad(x):
            if x > 0:            # tracer-dependent control flow
                x = x + 1
            y = float(x)         # host cast
            z = np.abs(x)        # numpy on a tracer
            x.item()             # explicit sync
            return x
    """})
    assert rules_of(res) == ["RL002"] * 4
    msgs = " ".join(f.message for f in res.new)
    for needle in ("`if` on a traced value", "host cast `float()`",
                   "`np.abs` call on a traced value", "host sync `.item()`"):
        assert needle in msgs, needle


def test_rl002_static_args_and_helpers_are_clean(tmp_path):
    res = make_project(tmp_path, {"src/repro/core/fn.py": """
        import functools
        import jax
        import jax.numpy as jnp
        import numpy as np

        @functools.partial(jax.jit, static_argnames=("cfg", "n"))
        def good(x, *, cfg, n):
            if cfg.age_encoding:        # static arg attribute: ok
                x = x + 1
            B, S = x.shape              # shape math is trace-static
            pad = (-S) % max(n, 1)
            if pad == 0:                # derived static local: ok
                x = x * 2
            lim = cfg.max_age if n is not None else np.inf   # np attr: ok
            return _helper(x, cfg), lim

        def _helper(x, cfg):
            if cfg.age_encoding:        # static-ness propagates into helpers
                x = x - 1
            return {k: x[k] for k in x}   # pytree-key iteration: ok

        def untraced(x):
            return float(x) if x > 0 else x.item()   # host code: not scanned
    """})
    assert res.new == []


def test_rl002_closure_mutation(tmp_path):
    res = make_project(tmp_path, {"src/repro/core/fn.py": """
        import jax

        @jax.jit
        def leaky(x):
            acc = []
            def body(i, s):
                acc.append(i)       # escapes the trace body
                return s
            return jax.lax.fori_loop(0, 3, body, x)
    """})
    assert rules_of(res) == ["RL002"]
    assert "mutation `.append()`" in res.new[0].message


# ---------------------------------------------------------------------------
# RL003 kernel <-> oracle pairing
# ---------------------------------------------------------------------------
RL003_KERNEL = """
    def fused_scan(x):
        return x

    def _private_helper(x):
        return x
"""


def test_rl003_missing_oracle_and_test(tmp_path):
    res = make_project(tmp_path, {
        "src/repro/kernels/fused.py": RL003_KERNEL,
        "src/repro/kernels/ref.py": "def other_ref(x):\n    return x\n",
        "tests/test_none.py": "def test_nothing():\n    pass\n",
    })
    assert rules_of(res) == ["RL003"]
    assert "no `fused_scan_ref` oracle" in res.new[0].message

    # oracle present but no parity test naming both sides
    res = make_project(tmp_path, {
        "src/repro/kernels/ref.py":
            "def fused_scan_ref(x):\n    return x\n",
    })
    assert rules_of(res) == ["RL003"]
    assert "parity test missing" in res.new[0].message


def test_rl003_paired_is_clean(tmp_path):
    res = make_project(tmp_path, {
        "src/repro/kernels/fused.py": RL003_KERNEL,
        "src/repro/kernels/ref.py":
            "def fused_scan_ref(x):\n    return x\n",
        "tests/test_fused.py": """
            def test_parity():
                assert fused_scan(1) == fused_scan_ref(1)
        """,
    })
    assert res.new == []


# ---------------------------------------------------------------------------
# RL004 wire stability
# ---------------------------------------------------------------------------
def errors_src(table):
    lines = ["class ApiError(ValueError):",
             "    code = 'bad_request'",
             "    http_status = 400",
             ""]
    for i, (code, status) in enumerate(sorted(table.items())):
        lines += [f"class E{i}(ApiError):",
                  f"    code = {code!r}",
                  f"    http_status = {status}",
                  ""]
    return "\n".join(lines)


def test_rl004_frozen_table_round_trip(tmp_path):
    res = make_project(
        tmp_path, {"src/repro/api/errors.py": errors_src(FROZEN_WIRE_V1)})
    assert res.new == []


def test_rl004_status_drift_new_code_and_removal(tmp_path):
    drifted = dict(FROZEN_WIRE_V1)
    drifted["timeout"] = 500                 # drift
    drifted["brand_new"] = 418               # unfrozen addition
    del drifted["internal"]                  # removal
    res = make_project(
        tmp_path, {"src/repro/api/errors.py": errors_src(drifted)})
    msgs = " ".join(f.message for f in res.new)
    assert rules_of(res) == ["RL004"] * 3
    assert "frozen v1 table says 504" in msgs
    assert "new wire code `brand_new`" in msgs
    assert "`internal` has no ApiError subclass" in msgs


def test_rl004_duplicate_code(tmp_path):
    src = errors_src(FROZEN_WIRE_V1) + (
        "class Dup(ApiError):\n"
        "    code = 'timeout'\n"
        "    http_status = 504\n")
    res = make_project(tmp_path, {"src/repro/api/errors.py": src})
    assert rules_of(res) == ["RL004"]
    assert "registered by both" in res.new[0].message


SCHEMAS_SRC = """
    import dataclasses

    def check_protocol(d):
        pass

    @dataclasses.dataclass
    class Req:
        a: int
        b: int = 0

        def to_json(self):
            return {"a": self.a{MAYBE_B}}

        @classmethod
        def from_json(cls, d):
            check_protocol(d)
            return cls(a=d["a"], b=d.get("b", 0))
"""


def test_rl004_schema_field_must_round_trip(tmp_path):
    src = SCHEMAS_SRC.replace("{MAYBE_B}", "")
    res = make_project(tmp_path, {"src/repro/api/schemas.py": src})
    assert rules_of(res) == ["RL004"]
    assert "`Req.b` does not appear in `to_json`" in res.new[0].message

    src = SCHEMAS_SRC.replace("{MAYBE_B}", ", 'b': self.b")
    res = make_project(tmp_path, {"src/repro/api/schemas.py": src})
    assert res.new == []


def test_rl004_handler_protocol_check(tmp_path):
    res = make_project(tmp_path, {
        "src/repro/api/schemas.py":
            SCHEMAS_SRC.replace("{MAYBE_B}", ", 'b': self.b"),
        "src/repro/serve/server.py": """
            class Handler:
                def do_POST(self):
                    path = self.path
                    if path == "/v1/via_schema":
                        req = Req.from_json(self._read())   # checks inside
                    elif path == "/v1/via_helper":
                        self.helper(self._read())
                    elif path == "/v1/naked":
                        self._send(self._read())            # RL004

                def helper(self, d):
                    check_protocol(d)
        """,
    })
    assert rules_of(res) == ["RL004"]
    assert "`/v1/naked`" in res.new[0].message


# ---------------------------------------------------------------------------
# suppression + baseline semantics
# ---------------------------------------------------------------------------
def test_inline_suppression(tmp_path):
    files = {"src/repro/serve/eng.py": """
        import threading

        class Eng:
            def __init__(self):
                self._lock = threading.Lock()
                self.pending = []    # guarded-by: _lock

            def peek(self):
                # post-join snapshot, documented single-threaded
                return len(self.pending)  # repro-lint: disable=RL001 drained
    """}
    res = make_project(tmp_path, files)
    assert res.new == [] and res.suppressed == 1

    # a disable= for a DIFFERENT rule does not silence the finding
    files["src/repro/serve/eng.py"] = files[
        "src/repro/serve/eng.py"].replace("disable=RL001", "disable=RL002")
    res = make_project(tmp_path, files)
    assert rules_of(res) == ["RL001"] and res.suppressed == 0


def test_baseline_grandfathers_but_catches_new(tmp_path):
    src = {"src/repro/serve/eng.py": RL001_POSITIVE}
    res = make_project(tmp_path, src)
    assert len(res.new) == 2

    base = tmp_path / "baseline.json"
    baseline_mod.save(base, res.new)
    res2 = run_lint(tmp_path, baseline_path=base)
    assert res2.new == [] and len(res2.grandfathered) == 2
    assert res2.exit_code == 0

    # introduce a NEW violation: only it fails the run
    (tmp_path / "src/repro/serve/eng.py").write_text(
        textwrap.dedent(RL001_POSITIVE) + textwrap.dedent("""
            def sneak(self):
                return self.pending.pop()
        """).replace("\n", "\n    ").rstrip() + "\n")
    res3 = run_lint(tmp_path, baseline_path=base)
    assert len(res3.grandfathered) == 2
    assert [f.rule for f in res3.new] == ["RL001"]
    assert "sneak" in res3.new[0].message
    assert res3.exit_code == 1

    # fixing everything leaves stale baseline entries, not failures
    (tmp_path / "src/repro/serve/eng.py").write_text("x = 1\n")
    res4 = run_lint(tmp_path, baseline_path=base)
    assert res4.new == [] and len(res4.stale_baseline) == 2


def test_fingerprint_survives_line_churn(tmp_path):
    res = make_project(tmp_path, {"src/repro/serve/eng.py": RL001_POSITIVE})
    fp = {f.fingerprint for f in res.new}
    shifted = "\n\n# a comment\n" + textwrap.dedent(RL001_POSITIVE)
    (tmp_path / "src/repro/serve/eng.py").write_text(shifted)
    res2 = run_lint(tmp_path)
    assert {f.fingerprint for f in res2.new} == fp


# ---------------------------------------------------------------------------
# the repo itself
# ---------------------------------------------------------------------------
def test_self_run_src_repro_is_clean():
    """The committed tree must lint clean modulo the committed baseline —
    the same gate CI runs."""
    res = run_lint(REPO_ROOT,
                   baseline_path=REPO_ROOT / "tools/analyze/baseline.json")
    assert res.new == [], "\n".join(f.format_text() for f in res.new)


def test_cli_exit_codes(tmp_path, capsys):
    assert cli_main(["--list-rules"]) == 0
    assert cli_main(["--root", str(REPO_ROOT)]) == 0
    out = capsys.readouterr()
    assert "RL001" in out.out          # --list-rules table
    # a dirty fixture tree exits 1 and renders GitHub annotations
    p = tmp_path / "src/repro/serve/eng.py"
    p.parent.mkdir(parents=True)
    p.write_text(textwrap.dedent(RL001_POSITIVE))
    assert cli_main(["--root", str(tmp_path), "--format=github"]) == 1
    out = capsys.readouterr()
    assert "::error file=" in out.out
