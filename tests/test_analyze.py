"""repro-lint (tools/analyze): per-rule fixtures + repo self-run.

Each rule gets a fixture it MUST flag (positive) and a near-identical one
it must NOT flag (negative), plus suppression/baseline semantics, a
self-run over ``src/repro`` asserting the tree is clean modulo the
committed baseline, and seeded-mutation checks that re-introduce the
exact bug classes RL005/RL006/RL007 exist to catch and assert each
yields exactly one finding.  Fixtures are parsed, never imported, so
they don't need to be runnable.
"""
import json
import os
import shutil
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO_ROOT = Path(__file__).resolve().parents[1]
if str(REPO_ROOT) not in sys.path:       # tests run with PYTHONPATH=src;
    sys.path.insert(0, str(REPO_ROOT))   # `tools` lives at the repo root

from tools.analyze import baseline as baseline_mod  # noqa: E402
from tools.analyze import callgraph as callgraph_mod  # noqa: E402
from tools.analyze.cli import main as cli_main, run_lint  # noqa: E402
from tools.analyze.core import Project  # noqa: E402
from tools.analyze.wire import FROZEN_WIRE_V1  # noqa: E402


def make_project(tmp_path, files):
    """Write {relpath: source} and lint it (no baseline)."""
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return run_lint(tmp_path)


def rules_of(res):
    return [f.rule for f in res.new]


# ---------------------------------------------------------------------------
# RL001 lock discipline
# ---------------------------------------------------------------------------
RL001_POSITIVE = """
    import threading

    class Eng:
        def __init__(self):
            self._lock = threading.Lock()
            self.pending = []        # guarded-by: _lock
            self.slot_req = []       # guarded-by: engine-thread

        def submit(self, r):
            with self._lock:
                self.pending.append(r)      # locked: ok

        def bad_queue_peek(self):
            return len(self.pending)        # RL001: unlocked read

        def bad_slot_peek(self):
            return self.slot_req            # RL001: wrong thread

        def step(self):  # repro-lint: engine-thread-only
            return self.pending, self.slot_req   # both exempt

        def holds(self):  # repro-lint: holds=_lock
            return self.pending[0]          # caller owns the lock: ok
"""


def test_rl001_flags_unguarded_access(tmp_path):
    res = make_project(tmp_path, {"src/repro/serve/eng.py": RL001_POSITIVE})
    assert rules_of(res) == ["RL001", "RL001"]
    msgs = " ".join(f.message for f in res.new)
    assert "bad_queue_peek" in msgs and "bad_slot_peek" in msgs
    # the disciplined accesses stay silent
    assert "submit" not in msgs and "`Eng.step`" not in msgs


def test_rl001_foreign_access(tmp_path):
    res = make_project(tmp_path, {
        "src/repro/serve/eng.py": RL001_POSITIVE,
        "src/repro/serve/web.py": """
            class Handler:
                def healthz(self, eng):
                    return len(eng.pending)     # RL001: foreign access
        """,
        "src/repro/serve/other.py": """
            class RefEngine:
                def __init__(self):
                    self.pending = []           # its own field, unguarded

                def drain(self):
                    return self.pending         # not a foreign access
        """,
    })
    foreign = [f for f in res.new if "foreign access" in f.message]
    assert len(foreign) == 1
    assert foreign[0].path.endswith("web.py")


def test_rl001_negative_all_locked(tmp_path):
    res = make_project(tmp_path, {"src/repro/serve/eng.py": """
        import threading

        class Eng:
            def __init__(self):
                self._lock = threading.Lock()
                self.pending = []    # guarded-by: _lock

            def submit(self, r):
                with self._lock:
                    self.pending.append(r)
    """})
    assert res.new == []


# ---------------------------------------------------------------------------
# RL002 trace purity
# ---------------------------------------------------------------------------
def test_rl002_flags_host_syncs(tmp_path):
    res = make_project(tmp_path, {"src/repro/core/fn.py": """
        import functools
        import jax
        import numpy as np

        @jax.jit
        def bad(x):
            if x > 0:            # tracer-dependent control flow
                x = x + 1
            y = float(x)         # host cast
            z = np.abs(x)        # numpy on a tracer
            x.item()             # explicit sync
            return x
    """})
    assert rules_of(res) == ["RL002"] * 4
    msgs = " ".join(f.message for f in res.new)
    for needle in ("`if` on a traced value", "host cast `float()`",
                   "`np.abs` call on a traced value", "host sync `.item()`"):
        assert needle in msgs, needle


def test_rl002_static_args_and_helpers_are_clean(tmp_path):
    res = make_project(tmp_path, {"src/repro/core/fn.py": """
        import functools
        import jax
        import jax.numpy as jnp
        import numpy as np

        @functools.partial(jax.jit, static_argnames=("cfg", "n"))
        def good(x, *, cfg, n):
            if cfg.age_encoding:        # static arg attribute: ok
                x = x + 1
            B, S = x.shape              # shape math is trace-static
            pad = (-S) % max(n, 1)
            if pad == 0:                # derived static local: ok
                x = x * 2
            lim = cfg.max_age if n is not None else np.inf   # np attr: ok
            return _helper(x, cfg), lim

        def _helper(x, cfg):
            if cfg.age_encoding:        # static-ness propagates into helpers
                x = x - 1
            return {k: x[k] for k in x}   # pytree-key iteration: ok

        def untraced(x):
            return float(x) if x > 0 else x.item()   # host code: not scanned
    """})
    assert res.new == []


def test_rl002_closure_mutation(tmp_path):
    res = make_project(tmp_path, {"src/repro/core/fn.py": """
        import jax

        @jax.jit
        def leaky(x):
            acc = []
            def body(i, s):
                acc.append(i)       # escapes the trace body
                return s
            return jax.lax.fori_loop(0, 3, body, x)
    """})
    assert rules_of(res) == ["RL002"]
    assert "mutation `.append()`" in res.new[0].message


# ---------------------------------------------------------------------------
# RL003 kernel <-> oracle pairing
# ---------------------------------------------------------------------------
RL003_KERNEL = """
    def fused_scan(x):
        return x

    def _private_helper(x):
        return x
"""


def test_rl003_missing_oracle_and_test(tmp_path):
    res = make_project(tmp_path, {
        "src/repro/kernels/fused.py": RL003_KERNEL,
        "src/repro/kernels/ref.py": "def other_ref(x):\n    return x\n",
        "tests/test_none.py": "def test_nothing():\n    pass\n",
    })
    assert rules_of(res) == ["RL003"]
    assert "no `fused_scan_ref` oracle" in res.new[0].message

    # oracle present but no parity test naming both sides
    res = make_project(tmp_path, {
        "src/repro/kernels/ref.py":
            "def fused_scan_ref(x):\n    return x\n",
    })
    assert rules_of(res) == ["RL003"]
    assert "parity test missing" in res.new[0].message


def test_rl003_paired_is_clean(tmp_path):
    res = make_project(tmp_path, {
        "src/repro/kernels/fused.py": RL003_KERNEL,
        "src/repro/kernels/ref.py":
            "def fused_scan_ref(x):\n    return x\n",
        "tests/test_fused.py": """
            def test_parity():
                assert fused_scan(1) == fused_scan_ref(1)
        """,
    })
    assert res.new == []


def test_rl003_signature_parity(tmp_path):
    files = {
        "src/repro/kernels/fused.py":
            "def fused_scan(q, k, v):\n    return q\n",
        "src/repro/kernels/ref.py":
            "def fused_scan_ref(q, v, k):\n    return q\n",   # k/v swapped
        "tests/test_fused.py": """
            def test_parity():
                assert fused_scan(1, 2, 3) == fused_scan_ref(1, 2, 3)
        """,
    }
    res = make_project(tmp_path, files)
    assert rules_of(res) == ["RL003"]
    assert res.new[0].symbol == "kernels.fused_scan.signature-parity"
    assert "(q, v, k)" in res.new[0].message

    # matching order (trailing defaults don't count) is clean
    files["src/repro/kernels/ref.py"] = \
        "def fused_scan_ref(q, k, v, eps=1e-6):\n    return q\n"
    res = make_project(tmp_path, files)
    assert res.new == []

    # an ops.py wrapper overrides the raw kernel def as the canonical
    # signature source
    files["src/repro/kernels/ops.py"] = \
        "def fused_scan(a, b):\n    return a\n"
    files["src/repro/kernels/ref.py"] = \
        "def fused_scan_ref(a, b):\n    return a\n"
    res = make_project(tmp_path, files)
    assert res.new == []


# ---------------------------------------------------------------------------
# call graph: resolution + marker propagation (backs RL001/RL005/RL006)
# ---------------------------------------------------------------------------
CG_POOL = """
    class Pool:
        def alloc(self, n):
            return list(range(n))

        def release(self, ids):
            pass
"""

CG_ENG = """
    import threading
    from .pool import Pool

    class Eng:
        def __init__(self):
            self._lock = threading.Lock()
            self.pool = Pool()
            self.pending = []     # guarded-by: _lock
            self.slots = []       # guarded-by: engine-thread

        def step(self):  # repro-lint: engine-thread-only
            return self._inner()

        def _inner(self):
            return self.slots       # marker derived from the only caller

        def submit(self):
            with self._lock:
                return self._locked_pop()

        def _locked_pop(self):
            return self.pending.pop()   # holder derived from lock context

        def grab(self):
            ids = self.pool.alloc(1)
            self.pool.release(ids)
            return ids
"""


def _write(tmp_path, files):
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))


def test_callgraph_resolves_self_field_methods(tmp_path):
    _write(tmp_path, {"src/repro/serve/pool.py": CG_POOL,
                      "src/repro/serve/eng.py": CG_ENG})
    g = callgraph_mod.build(Project(tmp_path))
    grab = next(f for f in g.functions if f.qualname == "Eng.grab")
    callees = {(s.callee.cls, s.callee.name) for s in grab.calls}
    # `self.pool.alloc` resolves through the __init__ field type,
    # across the relative import, to the Pool class
    assert ("Pool", "alloc") in callees and ("Pool", "release") in callees


def test_callgraph_marker_and_holder_propagation(tmp_path):
    _write(tmp_path, {"src/repro/serve/pool.py": CG_POOL,
                      "src/repro/serve/eng.py": CG_ENG})
    g = callgraph_mod.build(Project(tmp_path))
    fid = {f.qualname: f.fid for f in g.functions}
    eng_derived = callgraph_mod.propagate_all_callers(g, "engine-thread-only")
    assert fid["Eng._inner"] in eng_derived
    assert fid["Eng.submit"] not in eng_derived      # unmarked entry point
    holders = callgraph_mod.propagate_holds(g)
    assert fid["Eng._locked_pop"] in holders
    assert fid["Eng._inner"] not in holders


def test_rl001_accepts_derived_markers(tmp_path):
    """The fixture's guarded accesses live in UNANNOTATED helpers reached
    only through annotated (or locked) callers: propagation must keep the
    tree clean end to end."""
    res = make_project(tmp_path, {"src/repro/serve/pool.py": CG_POOL,
                                  "src/repro/serve/eng.py": CG_ENG})
    assert res.new == []
    # sever the propagation path: an unmarked second caller taints _inner
    extra = textwrap.dedent(CG_ENG) + (
        "\n"
        "    def poke(self):\n"
        "        return self._inner()\n")
    (tmp_path / "src/repro/serve/eng.py").write_text(extra)
    res = run_lint(tmp_path)
    assert rules_of(res) == ["RL001"]
    assert "Eng._inner" in res.new[0].message


# ---------------------------------------------------------------------------
# RL004 wire stability
# ---------------------------------------------------------------------------
def errors_src(table):
    lines = ["class ApiError(ValueError):",
             "    code = 'bad_request'",
             "    http_status = 400",
             ""]
    for i, (code, status) in enumerate(sorted(table.items())):
        lines += [f"class E{i}(ApiError):",
                  f"    code = {code!r}",
                  f"    http_status = {status}",
                  ""]
    return "\n".join(lines)


def test_rl004_frozen_table_round_trip(tmp_path):
    res = make_project(
        tmp_path, {"src/repro/api/errors.py": errors_src(FROZEN_WIRE_V1)})
    assert res.new == []


def test_rl004_status_drift_new_code_and_removal(tmp_path):
    drifted = dict(FROZEN_WIRE_V1)
    drifted["timeout"] = 500                 # drift
    drifted["brand_new"] = 418               # unfrozen addition
    del drifted["internal"]                  # removal
    res = make_project(
        tmp_path, {"src/repro/api/errors.py": errors_src(drifted)})
    msgs = " ".join(f.message for f in res.new)
    assert rules_of(res) == ["RL004"] * 3
    assert "frozen v1 table says 504" in msgs
    assert "new wire code `brand_new`" in msgs
    assert "`internal` has no ApiError subclass" in msgs


def test_rl004_duplicate_code(tmp_path):
    src = errors_src(FROZEN_WIRE_V1) + (
        "class Dup(ApiError):\n"
        "    code = 'timeout'\n"
        "    http_status = 504\n")
    res = make_project(tmp_path, {"src/repro/api/errors.py": src})
    assert rules_of(res) == ["RL004"]
    assert "registered by both" in res.new[0].message


SCHEMAS_SRC = """
    import dataclasses

    def check_protocol(d):
        pass

    @dataclasses.dataclass
    class Req:
        a: int
        b: int = 0

        def to_json(self):
            return {"a": self.a{MAYBE_B}}

        @classmethod
        def from_json(cls, d):
            check_protocol(d)
            return cls(a=d["a"], b=d.get("b", 0))
"""


def test_rl004_schema_field_must_round_trip(tmp_path):
    src = SCHEMAS_SRC.replace("{MAYBE_B}", "")
    res = make_project(tmp_path, {"src/repro/api/schemas.py": src})
    assert rules_of(res) == ["RL004"]
    assert "`Req.b` does not appear in `to_json`" in res.new[0].message

    src = SCHEMAS_SRC.replace("{MAYBE_B}", ", 'b': self.b")
    res = make_project(tmp_path, {"src/repro/api/schemas.py": src})
    assert res.new == []


def test_rl004_handler_protocol_check(tmp_path):
    res = make_project(tmp_path, {
        "src/repro/api/schemas.py":
            SCHEMAS_SRC.replace("{MAYBE_B}", ", 'b': self.b"),
        "src/repro/serve/server.py": """
            class Handler:
                def do_POST(self):
                    path = self.path
                    if path == "/v1/via_schema":
                        req = Req.from_json(self._read())   # checks inside
                    elif path == "/v1/via_helper":
                        self.helper(self._read())
                    elif path == "/v1/naked":
                        self._send(self._read())            # RL004

                def helper(self, d):
                    check_protocol(d)
        """,
    })
    assert rules_of(res) == ["RL004"]
    assert "`/v1/naked`" in res.new[0].message


# ---------------------------------------------------------------------------
# RL005 resource discipline
# ---------------------------------------------------------------------------
RL005_SRC = """
    class Eng:
        def __init__(self, pool):
            self.pool = pool
            self._slots = {}

        def leaky_admit(self, n, slot):
            blocks = self.pool.alloc(n)
            if blocks is None:
                raise RuntimeError("budget")
            self._prep(blocks)              # may raise: handle still live
            self._slots[slot] = blocks

        def guarded_admit(self, n, slot):
            blocks = self.pool.alloc(n)
            if blocks is None:
                raise RuntimeError("budget")
            try:
                self._prep(blocks)
            except BaseException:
                self.pool.release(blocks)
                raise
            self._slots[slot] = blocks

        def finally_admit(self, n):
            blocks = self.pool.alloc(n)
            if blocks is None:
                return 0
            try:
                self._prep(blocks)
            finally:
                self.pool.release(blocks)
            return 1

        def handoff(self, n):
            blocks = self.pool.alloc(n)
            self._consume(blocks)

        def _consume(self, blocks):  # repro-lint: transfers-ownership
            self._slots[0] = blocks

        def conditional_share(self, blocks, flag):
            if self.paged:
                self.pool.share(blocks)
            try:
                self._prep(blocks)
            finally:
                if self.paged:
                    self.pool.release(blocks)

        def _prep(self, blocks):
            pass
"""


def test_rl005_leak_on_raise_only(tmp_path):
    """One leak-on-raise positive; the finally/handler/marker/path-fact
    variants of the same shape stay silent."""
    res = make_project(tmp_path, {"src/repro/serve/eng.py": RL005_SRC})
    assert rules_of(res) == ["RL005"]
    f = res.new[0]
    assert "Eng.leaky_admit" in f.message and "raising path" in f.message
    assert f.symbol == "Eng.leaky_admit.leak.blocks"


def test_rl005_missing_release_on_exit(tmp_path):
    res = make_project(tmp_path, {"src/repro/serve/eng.py": """
        class Idx:
            def __init__(self, pool):
                self.pool = pool
                self._entries = {}

            def evict(self, key):
                e = self._entries.pop(key)
                self.evictions += 1         # popped entry's refs never drop
                return self.evictions

            def evict_ok(self, key):
                e = self._entries.pop(key)
                self.pool.release(e.blocks)
                self.evictions += 1
                return self.evictions
    """})
    assert rules_of(res) == ["RL005"]
    assert "Idx.evict" in res.new[0].message
    assert "every exit path" in res.new[0].message


# ---------------------------------------------------------------------------
# RL006 hot-path host syncs
# ---------------------------------------------------------------------------
RL006_SRC = """
    import jax
    import numpy as np

    @jax.jit
    def _fwd(x):
        return x

    class Eng:
        def tick(self):  # repro-lint: hot-path
            out = _fwd(1)
            return self._drain(out)

        def _drain(self, out):
            n = out.sum().item()            # sync in a hot transitive callee
            host = np.asarray(out)          # np on a device value
            meta = np.zeros((4,))           # host-only numpy: fine
            return n, host, meta

        def offline_stats(self, out):
            return out.item()               # not hot-reachable: fine
"""


def test_rl006_transitive_hot_path_syncs(tmp_path):
    res = make_project(tmp_path, {"src/repro/serve/eng.py": RL006_SRC})
    assert rules_of(res) == ["RL006", "RL006"]
    whats = sorted(f.symbol.rsplit(".hotsync.", 1)[1] for f in res.new)
    assert whats == [".item()", "np.asarray"]
    msgs = " ".join(f.message for f in res.new)
    assert "Eng._drain" in msgs and "hot path `Eng.tick`" in msgs
    assert "offline_stats" not in msgs


def test_rl006_annotated_packed_sync_allowed(tmp_path):
    src = RL006_SRC.replace(
        "n = out.sum().item()            # sync in a hot transitive callee",
        "n = out.sum().item()  # repro-lint: disable=RL006 the packed sync"
    ).replace(
        "host = np.asarray(out)          # np on a device value",
        "host = np.asarray(n)")
    res = make_project(tmp_path, {"src/repro/serve/eng.py": src})
    assert res.new == [] and res.suppressed == 1


def test_rl006_silent_without_hot_seed(tmp_path):
    src = RL006_SRC.replace("  # repro-lint: hot-path", "")
    res = make_project(tmp_path, {"src/repro/serve/eng.py": src})
    assert res.new == []


# ---------------------------------------------------------------------------
# RL007 Pallas geometry
# ---------------------------------------------------------------------------
RL007_SRC = """
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    def _copy_kernel(x_ref, o_ref, acc):
        o_ref[...] = x_ref[...]

    def _bad_arity(x):
        grid = (4, 2)
        return pl.pallas_call(
            _copy_kernel,
            grid=grid,
            in_specs=[pl.BlockSpec((1, 8), lambda i, j, k: (i, 0))],
            out_specs=[pl.BlockSpec((1, 8), lambda i, j: (i, 0))],
            scratch_shapes=[pltpu.VMEM((8,), jnp.float32)],
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        )(x)

    def _good(x):
        return pl.pallas_call(
            _copy_kernel,
            grid=(4, 2),
            in_specs=[pl.BlockSpec((1, 8), lambda i, j: (i, 0))],
            out_specs=[pl.BlockSpec((1, 8), lambda i, j: (i, 0))],
            scratch_shapes=[pltpu.VMEM((8,), jnp.float32)],
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        )(x)
"""


def test_rl007_index_map_arity(tmp_path):
    res = make_project(tmp_path, {"src/repro/kernels/toy.py": RL007_SRC})
    assert rules_of(res) == ["RL007"]
    f = res.new[0]
    assert "takes 3 args, expected 2" in f.message
    assert f.symbol == "kernels._copy_kernel.index-map-arity.3"


def test_rl007_kernel_signature_and_scratch_dtype(tmp_path):
    src = textwrap.dedent(RL007_SRC).replace(
        "def _copy_kernel(x_ref, o_ref, acc):",
        "def _copy_kernel(x_ref, o_ref):").replace(
        "lambda i, j, k: (i, 0)", "lambda i, j: (i, 0)").replace(
        "pltpu.VMEM((8,), jnp.float32)", "pltpu.VMEM((8,))")
    res = make_project(tmp_path, {"src/repro/kernels/toy.py": src})
    syms = sorted(f.symbol for f in res.new)
    assert syms == ["kernels._copy_kernel.scratch-dtype",
                    "kernels._copy_kernel.signature"]
    msgs = " ".join(f.message for f in res.new)
    assert "takes 2 positional refs, expected 3" in msgs
    assert "explicit dotted dtype" in msgs


RL007_PREFETCH = """
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    def _unguarded_kernel(tbl_ref, x_ref, o_ref):
        o_ref[...] = x_ref[...]

    def _guarded_kernel(tbl_ref, x_ref, o_ref):
        blk = tbl_ref[0]

        @pl.when(blk >= 0)
        def _():
            o_ref[...] = x_ref[...]

    def _paged(x, tbl):
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(4,),
            in_specs=[pl.BlockSpec((1, 8), lambda i, tbl: (tbl[i], 0))],
            out_specs=[pl.BlockSpec((1, 8), lambda i, tbl: (i, 0))],
        )
        return pl.pallas_call(
            _unguarded_kernel, grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype))(tbl, x)
"""


def test_rl007_prefetch_guard(tmp_path):
    res = make_project(tmp_path,
                       {"src/repro/kernels/paged.py": RL007_PREFETCH})
    assert rules_of(res) == ["RL007"]
    assert res.new[0].symbol == "kernels._unguarded_kernel.prefetch-guard"
    assert "no `pl.when` guard" in res.new[0].message

    guarded = RL007_PREFETCH.replace("_unguarded_kernel, grid_spec",
                                     "_guarded_kernel, grid_spec")
    res = make_project(tmp_path,
                       {"src/repro/kernels/paged.py": guarded})
    assert res.new == []


# ---------------------------------------------------------------------------
# suppression + baseline semantics
# ---------------------------------------------------------------------------
def test_inline_suppression(tmp_path):
    files = {"src/repro/serve/eng.py": """
        import threading

        class Eng:
            def __init__(self):
                self._lock = threading.Lock()
                self.pending = []    # guarded-by: _lock

            def peek(self):
                # post-join snapshot, documented single-threaded
                return len(self.pending)  # repro-lint: disable=RL001 drained
    """}
    res = make_project(tmp_path, files)
    assert res.new == [] and res.suppressed == 1

    # a disable= for a DIFFERENT rule does not silence the finding
    files["src/repro/serve/eng.py"] = files[
        "src/repro/serve/eng.py"].replace("disable=RL001", "disable=RL002")
    res = make_project(tmp_path, files)
    assert rules_of(res) == ["RL001"] and res.suppressed == 0


def test_baseline_grandfathers_but_catches_new(tmp_path):
    src = {"src/repro/serve/eng.py": RL001_POSITIVE}
    res = make_project(tmp_path, src)
    assert len(res.new) == 2

    base = tmp_path / "baseline.json"
    baseline_mod.save(base, res.new)
    res2 = run_lint(tmp_path, baseline_path=base)
    assert res2.new == [] and len(res2.grandfathered) == 2
    assert res2.exit_code == 0

    # introduce a NEW violation: only it fails the run
    (tmp_path / "src/repro/serve/eng.py").write_text(
        textwrap.dedent(RL001_POSITIVE) + textwrap.dedent("""
            def sneak(self):
                return self.pending.pop()
        """).replace("\n", "\n    ").rstrip() + "\n")
    res3 = run_lint(tmp_path, baseline_path=base)
    assert len(res3.grandfathered) == 2
    assert [f.rule for f in res3.new] == ["RL001"]
    assert "sneak" in res3.new[0].message
    assert res3.exit_code == 1

    # fixing everything leaves stale baseline entries, not failures
    (tmp_path / "src/repro/serve/eng.py").write_text("x = 1\n")
    res4 = run_lint(tmp_path, baseline_path=base)
    assert res4.new == [] and len(res4.stale_baseline) == 2


def test_fingerprint_survives_line_churn(tmp_path):
    res = make_project(tmp_path, {"src/repro/serve/eng.py": RL001_POSITIVE})
    fp = {f.fingerprint for f in res.new}
    shifted = "\n\n# a comment\n" + textwrap.dedent(RL001_POSITIVE)
    (tmp_path / "src/repro/serve/eng.py").write_text(shifted)
    res2 = run_lint(tmp_path)
    assert {f.fingerprint for f in res2.new} == fp


# ---------------------------------------------------------------------------
# the repo itself
# ---------------------------------------------------------------------------
def test_self_run_src_repro_is_clean():
    """The committed tree must lint clean modulo the committed baseline —
    the same gate CI runs."""
    res = run_lint(REPO_ROOT,
                   baseline_path=REPO_ROOT / "tools/analyze/baseline.json")
    assert res.new == [], "\n".join(f.format_text() for f in res.new)


def test_cli_exit_codes(tmp_path, capsys):
    assert cli_main(["--list-rules"]) == 0
    assert cli_main(["--root", str(REPO_ROOT)]) == 0
    out = capsys.readouterr()
    assert "RL001" in out.out          # --list-rules table
    # a dirty fixture tree exits 1 and renders GitHub annotations
    p = tmp_path / "src/repro/serve/eng.py"
    p.parent.mkdir(parents=True)
    p.write_text(textwrap.dedent(RL001_POSITIVE))
    assert cli_main(["--root", str(tmp_path), "--format=github"]) == 1
    out = capsys.readouterr()
    assert "::error file=" in out.out


def test_fix_baseline_prints_fingerprint_diff(tmp_path, capsys):
    p = tmp_path / "src/repro/serve/eng.py"
    p.parent.mkdir(parents=True)
    p.write_text(textwrap.dedent(RL001_POSITIVE))
    bl = tmp_path / "bl.json"
    common = ["--root", str(tmp_path), "--baseline", str(bl)]
    assert cli_main(common + ["--fix-baseline"]) == 0
    out = capsys.readouterr().out
    added = [l for l in out.splitlines() if l.startswith("+ ")]
    assert len(added) == 2 and all("RL001" in l for l in added)
    # the rewritten baseline greens the tree
    assert cli_main(common) == 0
    capsys.readouterr()
    # fixing the sources: the next --fix-baseline prunes and prints `-` lines
    p.write_text("x = 1\n")
    assert cli_main(common + ["--fix-baseline"]) == 0
    out = capsys.readouterr().out
    removed = [l for l in out.splitlines() if l.startswith("- ")]
    assert len(removed) == 2
    assert json.loads(bl.read_text())["findings"] == {}


def test_analyzer_output_is_byte_deterministic(tmp_path):
    """Same tree in, same bytes out — across interpreter runs with
    different hash seeds (the CI artifact must be diffable)."""
    for rel, src in {"src/repro/serve/eng.py": RL001_POSITIVE,
                     "src/repro/serve/res.py": RL005_SRC,
                     "src/repro/serve/hot.py": RL006_SRC,
                     "src/repro/kernels/toy.py": RL007_SRC}.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    outs = []
    for seed in ("0", "31337"):
        proc = subprocess.run(
            [sys.executable, "-m", "tools.analyze", "--root", str(tmp_path),
             "--no-baseline", "--format=json"],
            cwd=REPO_ROOT, capture_output=True,
            env=dict(os.environ, PYTHONHASHSEED=seed))
        assert proc.returncode == 1, proc.stderr.decode()
        outs.append(proc.stdout)
    assert outs[0] == outs[1]
    assert len(json.loads(outs[0])) >= 4     # all four rule families fired


# ---------------------------------------------------------------------------
# seeded mutations: re-introduce the exact bug classes the new rules catch
# in the REAL tree and assert each yields exactly one finding
# ---------------------------------------------------------------------------
def _mutated_src(tmp_path, rel, old, new):
    shutil.copytree(REPO_ROOT / "src/repro", tmp_path / "src/repro")
    p = tmp_path / "src/repro" / rel
    text = p.read_text()
    assert old in text, f"mutation anchor drifted in {rel}: {old!r}"
    p.write_text(text.replace(old, new, 1))
    return run_lint(tmp_path)


def test_mutation_deleted_release_is_exactly_one_rl005(tmp_path):
    res = _mutated_src(
        tmp_path, "serve/prefix.py",
        "        self.pool.release(e.blocks)\n", "")
    assert [f.rule for f in res.new] == ["RL005"]
    assert "PrefixIndex._evict_entry" in res.new[0].message


def test_mutation_sync_under_tick_is_exactly_one_rl006(tmp_path):
    anchor = "        arr = self._fetch(packed)    # ONE sync per tick\n"
    res = _mutated_src(
        tmp_path, "serve/engine.py",
        anchor, anchor + "        _dbg = arr.sum().item()\n")
    assert [f.rule for f in res.new] == ["RL006"]
    assert ".item()" in res.new[0].message
    assert "BatchedEngine.step" in res.new[0].message


def test_mutation_index_map_arity_is_exactly_one_rl007(tmp_path):
    res = _mutated_src(
        tmp_path, "kernels/paged_attention.py",
        "lambda b, h, i, tbl, stp: (b, h, 0, 0)",
        "lambda b, h, i, tbl: (b, h, 0, 0)")
    assert [f.rule for f in res.new] == ["RL007"]
    assert res.new[0].symbol == "kernels._paged_kernel.index-map-arity.4"
