"""Federated client-side fine-tuning (FedAvg): aggregation math, privacy
knobs, and end-to-end loss descent with per-client data."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import init_delphi
from repro.core.delphi import loss_fn
from repro.data import (SimulatorConfig, batches, generate_dataset,
                        pack_trajectories)
from repro.federated import FedConfig, aggregate, federated_finetune


def test_aggregate_weighted_mean():
    params = {"w": jnp.zeros((4,))}
    deltas = [{"w": jnp.ones((4,))}, {"w": jnp.full((4,), 3.0)}]
    out = aggregate(params, deltas, [1.0, 3.0], FedConfig())
    np.testing.assert_allclose(out["w"], 2.5)   # (1*1 + 3*3)/4


def test_aggregate_clip_and_noise_shapes(key):
    params = {"w": jnp.zeros((8,))}
    deltas = [{"w": jnp.ones((8,))}]
    fed = FedConfig(clip_delta_norm=1.0, dp_noise_mult=0.1)
    out = aggregate(params, deltas, [1.0], fed, rng=key)
    assert out["w"].shape == (8,)
    assert bool(jnp.isfinite(out["w"]).all())


@pytest.mark.slow
def test_federated_descent():
    cfg = get_config("delphi-2m", reduced=True).replace(
        dtype="float32", vocab_size=1289)
    params = init_delphi(cfg, jax.random.PRNGKey(0))
    train, val = generate_dataset(SimulatorConfig(n_train=96, n_val=32,
                                                  seed=4))
    # 4 clients, 24 patients each — data never pooled
    k = 4
    shards = [train[i::k] for i in range(k)]
    client_iters = [batches(pack_trajectories(s, 48), 8, seed=i)
                    for i, s in enumerate(shards)]
    pv = pack_trajectories(val, 48)
    vb = {kk: jnp.asarray(v[:16]) for kk, v in pv.items()}

    @jax.jit
    def val_loss(p):
        return loss_fn(p, cfg, vb)["loss"]

    v0 = float(val_loss(params))
    fed = FedConfig(n_rounds=3, local_steps=5, local_lr=2e-3)
    params, hist = federated_finetune(params, cfg, client_iters, fed,
                                      eval_fn=val_loss, log_fn=lambda s: None)
    # val improves from init (24 patients/client: expect a modest drop before
    # client overfit sets in), client losses descend steadily
    assert min(hist["val"]) < v0 * 0.97, (v0, hist["val"])
    assert hist["client_loss"][-1] < hist["client_loss"][0]
