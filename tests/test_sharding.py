"""Sharding rules: divisibility property, parameter/cache specs, mesh factory."""
import os

import jax
import numpy as np
import pytest
from hypcompat import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch.mesh import data_axes, make_host_mesh
from repro.launch.sharding import (cache_pspec, param_pspec, partition)


@pytest.fixture(scope="module")
def mesh4():
    # tiny 2x2 mesh over 1 CPU device is not constructible; emulate axis
    # sizes with a fake mesh-like object for the pure spec logic
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    return FakeMesh()


@settings(max_examples=60, deadline=None)
@given(dim=st.integers(1, 4096))
def test_partition_divisibility(dim):
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    spec = partition(FakeMesh(), (dim,), ["model"])
    if dim % 16 == 0:
        assert spec == P("model")
    else:
        assert spec == P(None)


def test_param_rules(mesh4):
    # GQA kv=8 on 16-way model axis -> replicated; q heads 40 not divisible
    assert param_pspec(mesh4, "layers/attn/wk", (64, 5120, 8, 128)) \
        == P(None, None, None, None)
    assert param_pspec(mesh4, "layers/attn/wq", (64, 5120, 32, 128)) \
        == P(None, None, "model", None)
    # dense mlp
    assert param_pspec(mesh4, "layers/mlp/w_gate", (22, 2048, 5632)) \
        == P(None, None, "model")
    # MoE: olmoe 64 experts divide; qwen2-moe 60 do not -> fallback to f dim
    assert param_pspec(mesh4, "layers/moe/w_gate", (16, 64, 2048, 1024)) \
        == P(None, "model", None, None)
    assert param_pspec(mesh4, "layers/moe/w_gate", (24, 60, 2048, 1408)) \
        == P(None, None, None, "model")
    # shared experts are dense
    assert param_pspec(mesh4, "layers/moe/shared/w_gate", (24, 2048, 5632)) \
        == P(None, None, "model")
    # embeddings on vocab
    assert param_pspec(mesh4, "embed/embed", (152064, 5120)) \
        == P("model", None)
    # norms replicated
    assert param_pspec(mesh4, "final_norm/scale", (2048,)) == P(None)


def test_cache_rules(mesh4):
    # kv heads divide (32): heads sharded
    assert cache_pspec(mesh4, "self/k", (30, 128, 32, 32768, 128)) \
        == P(None, ("data",), "model", None, None)
    # kv heads don't divide (8): window sharded instead
    assert cache_pspec(mesh4, "self/k", (64, 128, 8, 32768, 128)) \
        == P(None, ("data",), None, "model", None)
    # batch=1 (long_500k): batch replicated
    assert cache_pspec(mesh4, "self/k", (64, 1, 8, 8192, 128)) \
        == P(None, None, None, "model", None)
    # ssm state
    assert cache_pspec(mesh4, "ssm/h", (48, 128, 48, 128, 64)) \
        == P(None, ("data",), "model", None, None)


def test_host_mesh_and_axes():
    mesh = make_host_mesh()
    assert data_axes(mesh) == ("data",)
    assert mesh.shape["model"] == 1


def test_param_shardings_cover_all_archs():
    """Every param leaf of every arch gets a valid spec on a fake 16x16."""
    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}
    from repro.launch.specs import params_spec
    from repro.launch.sharding import _path_str
    for arch in ("qwen2-moe-a2.7b", "mamba2-780m", "zamba2-1.2b",
                 "seamless-m4t-large-v2", "delphi-2m"):
        cfg = get_config(arch)
        spec_tree = params_spec(cfg)
        leaves = jax.tree_util.tree_flatten_with_path(spec_tree)[0]
        for path, leaf in leaves:
            spec = param_pspec(FakeMesh(), _path_str(path), leaf.shape)
            # every sharded dim must divide
            for dim, ax in zip(leaf.shape, spec):
                if ax is not None:
                    axes = (ax,) if isinstance(ax, str) else ax
                    prod = int(np.prod([FakeMesh.shape[a] for a in axes]))
                    assert dim % prod == 0, (arch, _path_str(path), spec)
