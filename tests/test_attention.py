"""Attention: chunked-flash vs naive, ring caches, GQA, sliding window,
and the paged (block pool + block table) twin of the ring cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.models.attention import (LayerCache, PagedCache, PagedLayerView,
                                    cache_from_prefill, cache_write,
                                    cache_write_stacked, chunked_attention,
                                    decode_attention, empty_cache,
                                    empty_paged_cache, paged_gather_layer)


def _mk(key, B, Hq, Hkv, S, hd):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, hd))
    k = jax.random.normal(ks[1], (B, S, Hkv, hd))
    v = jax.random.normal(ks[2], (B, S, Hkv, hd))
    return q, k, v


@pytest.mark.parametrize("S,window,qb,kb", [
    (64, None, 512, 512),       # direct small path
    (700, None, 128, 128),      # chunked path with padding
    (700, 100, 128, 128),       # sliding window chunked
    (256, 32, 512, 512),        # sliding window direct
])
def test_chunked_vs_ref(key, S, window, qb, kb):
    B, Hq, Hkv, hd = 2, 4, 2, 32
    q, k, v = _mk(key, B, Hq, Hkv, S, hd)
    pos = jnp.arange(S, dtype=jnp.int32)
    out = chunked_attention(q, k, v, pos, pos, causal=True, window=window,
                            q_block=qb, kv_block=kb, q_per_kv=2)
    r = ref.flash_attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3), causal=True, window=window)
    np.testing.assert_allclose(out, r.transpose(0, 2, 1, 3), atol=2e-5)


def test_bidirectional(key):
    B, H, S, hd = 2, 2, 256, 16
    q, k, v = _mk(key, B, H, H, S, hd)
    pos = jnp.arange(S, dtype=jnp.int32)
    out = chunked_attention(q, k, v, pos, pos, causal=False, window=None,
                            q_block=128, kv_block=128)
    r = ref.flash_attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3), causal=False)
    np.testing.assert_allclose(out, r.transpose(0, 2, 1, 3), atol=2e-5)


def test_ring_cache_prefill_layout(key):
    B, Hkv, hd, S, W = 1, 2, 8, 10, 4
    k = jnp.arange(B * S * Hkv * hd, dtype=jnp.float32).reshape(B, S, Hkv, hd)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    c = cache_from_prefill(k, k, pos, W)
    # slot j holds the latest token with position % W == j
    assert c.pos[0].tolist() == [8, 9, 6, 7]
    np.testing.assert_array_equal(c.k[0, :, 0], k[0, 8])


def test_ring_cache_write_and_evict(key):
    B, Hkv, hd, W = 1, 1, 4, 3
    c = empty_cache_like(B, Hkv, W, hd)
    for step in range(5):
        kv = jnp.full((B, 1, Hkv, hd), float(step))
        c = cache_write(c, kv, kv, jnp.int32(step))
    assert sorted(c.pos[0].tolist()) == [2, 3, 4]


def empty_cache_like(B, Hkv, W, hd):
    return LayerCache(k=jnp.zeros((B, Hkv, W, hd)),
                      v=jnp.zeros((B, Hkv, W, hd)),
                      pos=jnp.full((B, W), -1, jnp.int32))


def test_swa_ring_equals_full_window(key):
    """Decoding with an SWA ring of width W must equal full attention
    restricted to the last W tokens."""
    B, Hkv, hd, S, W = 2, 2, 16, 29, 8
    ks = jax.random.split(key, 4)
    k_all = jax.random.normal(ks[0], (B, S + 1, Hkv, hd))
    v_all = jax.random.normal(ks[1], (B, S + 1, Hkv, hd))
    q = jax.random.normal(ks[2], (B, 1, Hkv, hd))

    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    ring = cache_from_prefill(k_all[:, :S], v_all[:, :S], pos, W)
    ring = cache_write(ring, k_all[:, S:], v_all[:, S:], jnp.int32(S))
    o_ring = decode_attention(q, ring, jnp.int32(S), window=W, q_per_kv=1)

    # reference: naive attention of q over the last W tokens (all visible to
    # the newest query, so no causal mask on the 1-token query)
    ctx_k = k_all[:, S - W + 1:].transpose(0, 2, 1, 3)
    ctx_v = v_all[:, S - W + 1:].transpose(0, 2, 1, 3)
    r = ref.flash_attention_ref(q.transpose(0, 2, 1, 3), ctx_k, ctx_v, causal=False)
    np.testing.assert_allclose(o_ring[:, 0], r[:, :, 0], atol=2e-5)


def test_unrolled_attention_matches_scanned(key):
    """The straight-line cost-accounting twin is numerically identical."""
    B, Hq, Hkv, S, hd = 2, 4, 2, 300, 32
    q, k, v = _mk(key, B, Hq, Hkv, S, hd)
    pos = jnp.arange(S, dtype=jnp.int32)
    a = chunked_attention(q, k, v, pos, pos, causal=True, window=None,
                          q_block=128, kv_block=128, q_per_kv=2)
    b = chunked_attention(q, k, v, pos, pos, causal=True, window=None,
                          q_block=128, kv_block=128, q_per_kv=2, unroll=True)
    np.testing.assert_allclose(a, b, atol=1e-5)


def test_seq_shard_attention_flag_noop_on_host(key):
    """cfg.seq_shard_attn only adds sharding constraints — outputs equal."""
    from repro.configs import get_config
    from repro.models import forward, init_params
    from repro.launch.mesh import make_host_mesh
    cfg = get_config("tinyllama-1.1b", reduced=True).replace(dtype="float32")
    p = init_params(cfg, key)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    y0 = forward(p, cfg, {"tokens": tokens}, mode="train")["logits"]
    with make_host_mesh():
        y1 = forward(p, cfg.replace(seq_shard_attn=True),
                     {"tokens": tokens}, mode="train")["logits"]
    np.testing.assert_allclose(y0, y1, atol=1e-5)


def test_cache_from_prefill_wrap_equals_sequential_writes(key):
    """Ring-wrap edge (S > W): packing a long prefill must equal writing the
    same tokens one at a time through the ring — slot j holds the LAST token
    with position % W == j, and evicted positions are gone."""
    B, Hkv, hd, S, W = 2, 2, 8, 23, 8
    ks = jax.random.split(key, 2)
    k = jax.random.normal(ks[0], (B, S, Hkv, hd))
    v = jax.random.normal(ks[1], (B, S, Hkv, hd))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    packed = cache_from_prefill(k, v, pos, W)
    seq = empty_cache_like(B, Hkv, W, hd)
    for p in range(S):
        seq = cache_write(seq, k[:, p:p + 1], v[:, p:p + 1], jnp.int32(p))
    np.testing.assert_array_equal(packed.pos, seq.pos)
    np.testing.assert_allclose(packed.k, seq.k, atol=0)
    np.testing.assert_allclose(packed.v, seq.v, atol=0)
    # only the last W positions survive
    assert sorted(np.asarray(packed.pos[0]).tolist()) == list(range(S - W, S))


def test_mask_padded_positions_under_wrap(key):
    """Bucketed prefill pads past the true prompt; when the padded length
    wraps the ring (S_pad > W) the mask must invalidate every slot holding a
    padded position WITHOUT touching surviving real ones."""
    from repro.models.model import mask_padded_positions
    B, Hkv, hd, W = 1, 1, 4, 8
    S_real, S_pad = 10, 23                 # both wrap the 8-wide ring
    k = jnp.arange(B * S_pad * Hkv * hd, dtype=jnp.float32).reshape(
        B, S_pad, Hkv, hd)
    pos = jnp.broadcast_to(jnp.arange(S_pad, dtype=jnp.int32)[None],
                           (B, S_pad))
    c = cache_from_prefill(k, k, pos, W)
    st = jax.tree_util.tree_map(lambda a: a[None], c)   # stack L=1
    masked = mask_padded_positions({"self": st}, np.asarray([S_real - 1]))
    got = np.asarray(masked["self"].pos[0, 0])
    # padded positions 10..22 overwrote the whole ring except slots still
    # holding positions <= 9: after the wrap the ring holds 15..22, so ALL
    # slots must be invalidated
    assert (got == -1).all(), got

    # shorter pad: S_pad=12 keeps positions 4..11; slots holding 4..9 stay
    c2 = cache_from_prefill(k[:, :12], k[:, :12], pos[:, :12], W)
    st2 = jax.tree_util.tree_map(lambda a: a[None], c2)
    m2 = mask_padded_positions({"self": st2}, np.asarray([S_real - 1]))
    got2 = np.asarray(m2["self"].pos[0, 0])
    kept = sorted(p for p in got2.tolist() if p >= 0)
    assert kept == [4, 5, 6, 7, 8, 9], got2


# ---------------------------------------------------------------------------
# Paged cache: pool + block-table twin of the ring
# ---------------------------------------------------------------------------
def _ring_to_paged(ring: LayerCache, bs: int):
    """Pack a ring LayerCache into an equivalent single-layer paged pool."""
    B, Hkv, W, hd = ring.k.shape
    nbs = W // bs
    NB = 1 + B * nbs
    table = np.full((B, nbs), -1, np.int32)
    pool_k = np.zeros((NB, Hkv, bs, hd), np.float32)
    pool_v = np.zeros((NB, Hkv, bs, hd), np.float32)
    pool_pos = np.full((NB, bs), -1, np.int32)
    nxt = 1
    rk, rv, rp = (np.asarray(x) for x in (ring.k, ring.v, ring.pos))
    for b in range(B):
        for jb in range(nbs):
            if (rp[b, jb * bs:(jb + 1) * bs] < 0).all():
                continue
            table[b, jb] = nxt
            pool_k[nxt] = rk[b, :, jb * bs:(jb + 1) * bs]
            pool_v[nxt] = rv[b, :, jb * bs:(jb + 1) * bs]
            pool_pos[nxt] = rp[b, jb * bs:(jb + 1) * bs]
            nxt += 1
    return PagedLayerView(jnp.asarray(pool_k), jnp.asarray(pool_v),
                          jnp.asarray(pool_pos), jnp.asarray(table))


def test_paged_gather_reconstructs_ring_bitwise(key):
    B, Hkv, hd, S, W, bs = 2, 2, 8, 13, 16, 4
    ks = jax.random.split(key, 2)
    k = jax.random.normal(ks[0], (B, S, Hkv, hd))
    v = jax.random.normal(ks[1], (B, S, Hkv, hd))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    ring = cache_from_prefill(k, v, pos, W)
    g = paged_gather_layer(_ring_to_paged(ring, bs))
    np.testing.assert_array_equal(g.pos, ring.pos)
    valid = np.asarray(ring.pos) >= 0
    np.testing.assert_array_equal(
        np.asarray(g.k).transpose(0, 2, 1, 3)[valid],
        np.asarray(ring.k).transpose(0, 2, 1, 3)[valid])


def test_paged_decode_bit_identical_to_ring(key):
    """decode_attention over a PagedLayerView == over the ring it factors —
    bit-identical, including the deferred-write new-token merge (the paged
    engine's parity claim at the layer level)."""
    B, Hkv, hd, S, W, bs = 2, 2, 16, 21, 16, 4     # S > W: wrapped ring
    ks = jax.random.split(key, 5)
    k = jax.random.normal(ks[0], (B, S, Hkv, hd))
    v = jax.random.normal(ks[1], (B, S, Hkv, hd))
    q = jax.random.normal(ks[2], (B, 1, Hkv * 2, hd))
    kn = jax.random.normal(ks[3], (B, 1, Hkv, hd))
    vn = jax.random.normal(ks[4], (B, 1, Hkv, hd))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    ring = cache_from_prefill(k, v, pos, W)
    view = _ring_to_paged(ring, bs)
    step = jnp.full((B,), S, jnp.int32)
    for window in (None, 6):
        o_r = decode_attention(q, ring, step, window=window, q_per_kv=2,
                               k_new=kn, v_new=vn)
        o_p = decode_attention(q, view, step, window=window, q_per_kv=2,
                               k_new=kn, v_new=vn)
        np.testing.assert_array_equal(np.asarray(o_r), np.asarray(o_p))


def test_paged_write_stacked_matches_ring_write(key):
    """cache_write_stacked dispatches on cache kind; the paged write lands
    in the table-mapped block and unallocated slots write to trash."""
    B, Hkv, hd, W, bs, L = 2, 1, 8, 8, 4, 2
    ks = jax.random.split(key, 3)
    k = jax.random.normal(ks[0], (B, 6, Hkv, hd))
    v = jax.random.normal(ks[1], (B, 6, Hkv, hd))
    pos = jnp.broadcast_to(jnp.arange(6, dtype=jnp.int32)[None], (B, 6))
    ring = cache_from_prefill(k, v, pos, W)
    view = _ring_to_paged(ring, bs)
    pc = PagedCache(k=jnp.stack([view.k] * L), v=jnp.stack([view.v] * L),
                    pos=view.pos, table=view.table)
    ring_st = jax.tree_util.tree_map(lambda a: jnp.stack([a] * L), ring)
    kn = jax.random.normal(ks[2], (L, B, 1, Hkv, hd))
    step = jnp.asarray([6, 7], jnp.int32)
    r2 = cache_write_stacked(ring_st, kn, kn, step)
    p2 = cache_write_stacked(pc, kn, kn, step)
    assert isinstance(p2, PagedCache)
    g = paged_gather_layer(PagedLayerView(p2.k[0], p2.v[0], p2.pos, p2.table))
    np.testing.assert_array_equal(g.pos, r2.pos[0])
    valid = np.asarray(r2.pos[0]) >= 0
    np.testing.assert_array_equal(
        np.asarray(g.k).transpose(0, 2, 1, 3)[valid],
        np.asarray(r2.k[0]).transpose(0, 2, 1, 3)[valid])


def test_empty_paged_cache_shapes_and_validation():
    from repro.configs import get_config
    cfg = get_config("delphi-2m", reduced=True)
    pc = empty_paged_cache(cfg, 3, 9, 4, 32, 8, jnp.float32)
    assert pc.k.shape == (3, 9, cfg.n_kv_heads, 8, cfg.head_dim)
    assert pc.table.shape == (4, 4) and (np.asarray(pc.table) == -1).all()
    assert (np.asarray(pc.pos) == -1).all()
    with pytest.raises(ValueError, match="multiple"):
        empty_paged_cache(cfg, 3, 9, 4, 30, 8, jnp.float32)


def test_deferred_write_matches_inline(key):
    B, Hkv, hd, S, W = 2, 2, 16, 12, 16
    ks = jax.random.split(key, 4)
    k_all = jax.random.normal(ks[0], (B, S + 1, Hkv, hd))
    v_all = jax.random.normal(ks[1], (B, S + 1, Hkv, hd))
    q = jax.random.normal(ks[2], (B, 1, Hkv, hd))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    cache = cache_from_prefill(k_all[:, :S], v_all[:, :S], pos, W)

    inline = cache_write(cache, k_all[:, S:], v_all[:, S:], jnp.int32(S))
    o_inline = decode_attention(q, inline, jnp.int32(S), window=None)
    o_defer = decode_attention(q, cache, jnp.int32(S), window=None,
                               k_new=k_all[:, S:], v_new=v_all[:, S:])
    np.testing.assert_allclose(o_inline, o_defer, atol=1e-5)
