"""Attention: chunked-flash vs naive, ring caches, GQA, sliding window."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.models.attention import (LayerCache, cache_from_prefill,
                                    cache_write, chunked_attention,
                                    decode_attention, empty_cache)


def _mk(key, B, Hq, Hkv, S, hd):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, hd))
    k = jax.random.normal(ks[1], (B, S, Hkv, hd))
    v = jax.random.normal(ks[2], (B, S, Hkv, hd))
    return q, k, v


@pytest.mark.parametrize("S,window,qb,kb", [
    (64, None, 512, 512),       # direct small path
    (700, None, 128, 128),      # chunked path with padding
    (700, 100, 128, 128),       # sliding window chunked
    (256, 32, 512, 512),        # sliding window direct
])
def test_chunked_vs_ref(key, S, window, qb, kb):
    B, Hq, Hkv, hd = 2, 4, 2, 32
    q, k, v = _mk(key, B, Hq, Hkv, S, hd)
    pos = jnp.arange(S, dtype=jnp.int32)
    out = chunked_attention(q, k, v, pos, pos, causal=True, window=window,
                            q_block=qb, kv_block=kb, q_per_kv=2)
    r = ref.attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3), causal=True, window=window)
    np.testing.assert_allclose(out, r.transpose(0, 2, 1, 3), atol=2e-5)


def test_bidirectional(key):
    B, H, S, hd = 2, 2, 256, 16
    q, k, v = _mk(key, B, H, H, S, hd)
    pos = jnp.arange(S, dtype=jnp.int32)
    out = chunked_attention(q, k, v, pos, pos, causal=False, window=None,
                            q_block=128, kv_block=128)
    r = ref.attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3), causal=False)
    np.testing.assert_allclose(out, r.transpose(0, 2, 1, 3), atol=2e-5)


def test_ring_cache_prefill_layout(key):
    B, Hkv, hd, S, W = 1, 2, 8, 10, 4
    k = jnp.arange(B * S * Hkv * hd, dtype=jnp.float32).reshape(B, S, Hkv, hd)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    c = cache_from_prefill(k, k, pos, W)
    # slot j holds the latest token with position % W == j
    assert c.pos[0].tolist() == [8, 9, 6, 7]
    np.testing.assert_array_equal(c.k[0, :, 0], k[0, 8])


def test_ring_cache_write_and_evict(key):
    B, Hkv, hd, W = 1, 1, 4, 3
    c = empty_cache_like(B, Hkv, W, hd)
    for step in range(5):
        kv = jnp.full((B, 1, Hkv, hd), float(step))
        c = cache_write(c, kv, kv, jnp.int32(step))
    assert sorted(c.pos[0].tolist()) == [2, 3, 4]


def empty_cache_like(B, Hkv, W, hd):
    return LayerCache(k=jnp.zeros((B, Hkv, W, hd)),
                      v=jnp.zeros((B, Hkv, W, hd)),
                      pos=jnp.full((B, W), -1, jnp.int32))


def test_swa_ring_equals_full_window(key):
    """Decoding with an SWA ring of width W must equal full attention
    restricted to the last W tokens."""
    B, Hkv, hd, S, W = 2, 2, 16, 29, 8
    ks = jax.random.split(key, 4)
    k_all = jax.random.normal(ks[0], (B, S + 1, Hkv, hd))
    v_all = jax.random.normal(ks[1], (B, S + 1, Hkv, hd))
    q = jax.random.normal(ks[2], (B, 1, Hkv, hd))

    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    ring = cache_from_prefill(k_all[:, :S], v_all[:, :S], pos, W)
    ring = cache_write(ring, k_all[:, S:], v_all[:, S:], jnp.int32(S))
    o_ring = decode_attention(q, ring, jnp.int32(S), window=W, q_per_kv=1)

    # reference: naive attention of q over the last W tokens (all visible to
    # the newest query, so no causal mask on the 1-token query)
    ctx_k = k_all[:, S - W + 1:].transpose(0, 2, 1, 3)
    ctx_v = v_all[:, S - W + 1:].transpose(0, 2, 1, 3)
    r = ref.attention_ref(q.transpose(0, 2, 1, 3), ctx_k, ctx_v, causal=False)
    np.testing.assert_allclose(o_ring[:, 0], r[:, :, 0], atol=2e-5)


def test_unrolled_attention_matches_scanned(key):
    """The straight-line cost-accounting twin is numerically identical."""
    B, Hq, Hkv, S, hd = 2, 4, 2, 300, 32
    q, k, v = _mk(key, B, Hq, Hkv, S, hd)
    pos = jnp.arange(S, dtype=jnp.int32)
    a = chunked_attention(q, k, v, pos, pos, causal=True, window=None,
                          q_block=128, kv_block=128, q_per_kv=2)
    b = chunked_attention(q, k, v, pos, pos, causal=True, window=None,
                          q_block=128, kv_block=128, q_per_kv=2, unroll=True)
    np.testing.assert_allclose(a, b, atol=1e-5)


def test_seq_shard_attention_flag_noop_on_host(key):
    """cfg.seq_shard_attn only adds sharding constraints — outputs equal."""
    from repro.configs import get_config
    from repro.models import forward, init_params
    from repro.launch.mesh import make_host_mesh
    cfg = get_config("tinyllama-1.1b", reduced=True).replace(dtype="float32")
    p = init_params(cfg, key)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    y0 = forward(p, cfg, {"tokens": tokens}, mode="train")["logits"]
    with make_host_mesh():
        y1 = forward(p, cfg.replace(seq_shard_attn=True),
                     {"tokens": tokens}, mode="train")["logits"]
    np.testing.assert_allclose(y0, y1, atol=1e-5)


def test_deferred_write_matches_inline(key):
    B, Hkv, hd, S, W = 2, 2, 16, 12, 16
    ks = jax.random.split(key, 4)
    k_all = jax.random.normal(ks[0], (B, S + 1, Hkv, hd))
    v_all = jax.random.normal(ks[1], (B, S + 1, Hkv, hd))
    q = jax.random.normal(ks[2], (B, 1, Hkv, hd))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    cache = cache_from_prefill(k_all[:, :S], v_all[:, :S], pos, W)

    inline = cache_write(cache, k_all[:, S:], v_all[:, S:], jnp.int32(S))
    o_inline = decode_attention(q, inline, jnp.int32(S), window=None)
    o_defer = decode_attention(q, cache, jnp.int32(S), window=None,
                               k_new=k_all[:, S:], v_new=v_all[:, S:])
    np.testing.assert_allclose(o_inline, o_defer, atol=1e-5)
