"""Device-resident serving engine: SDK/core parity (C2/C3), max-age boundary
semantics, one-host-sync-per-tick, and the bucketed-prefill shape policy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import generate_trajectories, init_delphi
from repro.sdk import InferenceSession, export_model
from repro.serve import BatchedEngine, Request
from repro.serve import engine as engine_mod


@pytest.fixture(scope="module")
def setup(tmp_path_factory):
    cfg = get_config("delphi-2m", reduced=True).replace(
        dtype="float32", vocab_size=96, max_seq_len=48)
    params = init_delphi(cfg, jax.random.PRNGKey(7))
    d = str(tmp_path_factory.mktemp("artifact"))
    export_model(params, cfg, d)
    return params, cfg, d


TOKS = [3, 10, 20]
AGES = [0.0, 15.0, 28.0]


def _uniforms(max_new, V, seed=42):
    rng = np.random.default_rng(seed)
    return rng.uniform(size=(max_new, V)).astype(np.float32)


def _run_engine(params, cfg, *, uniforms, max_new=6, max_context=64,
                sampler="jnp"):
    eng = BatchedEngine(params, cfg, slots=1, max_context=max_context,
                        sampler=sampler)
    eng.submit(Request(tokens=np.asarray(TOKS, np.int32),
                       ages=np.asarray(AGES, np.float32),
                       max_new=max_new, uniforms=uniforms))
    done = eng.run()
    assert len(done) == 1 and done[0].done
    return done[0], eng


def test_engine_vs_sdk_parity(setup):
    """Claim C2/C3: engine in-graph generation == SDK host loop under the
    same injected uniforms — bit-exact event sequence.

    Horizon matches test_sdk's core-vs-SDK parity test: tokens are compared
    exactly; ages loosely (jit-vs-eager fusion rounding compounds through
    exp(-logit), same caveat as there)."""
    params, cfg, d = setup
    max_new = 6
    u = _uniforms(max_new, cfg.vocab_size)
    # max_age=1e9 so neither path censors: pure sampling parity first
    sess = InferenceSession(d)
    sdk = sess.generate_trajectory(TOKS, AGES, max_new=max_new,
                                   uniforms=u, max_age=1e9)
    req, _ = _run_engine(params, cfg.replace(max_age=1e9), uniforms=u,
                         max_new=max_new)
    assert req.out_tokens == sdk["tokens"]
    assert len(req.out_ages) == len(sdk["ages"])
    # Early waiting times agree tightly (same uniforms; fp32 engine state vs
    # fp64 SDK host).  Later ages are NOT compared against the SDK: the
    # untrained model's decade-scale waiting times drive the high-frequency
    # age encoding chaotically, so fp32-vs-fp64 age feedback diverges after
    # ~2 events while the event sequence stays identical.  Tight full-horizon
    # age parity is asserted fp32-vs-fp32 in test_engine_vs_core_parity.
    np.testing.assert_allclose(req.out_ages[:2], sdk["ages"][:2], rtol=1e-3)
    assert all(b >= a for a, b in zip(req.out_ages, req.out_ages[1:]))


def test_engine_vs_sdk_max_age_boundary(setup):
    """The max-age termination boundary: an event whose waiting time crosses
    max_age is censored BEFORE being emitted, in both runtimes."""
    params, cfg, d = setup
    max_new = 6
    u = _uniforms(max_new, cfg.vocab_size)
    sess = InferenceSession(d)
    free = sess.generate_trajectory(TOKS, AGES, max_new=max_new,
                                    uniforms=u, max_age=1e9)
    ages = free["ages"]
    assert len(ages) >= 3
    # max_age strictly between event k-1 and event k -> exactly k emitted.
    # k=2: early enough that the ~decade inter-event gaps dwarf any fp
    # age drift between the two runtimes, so both censor at the same event.
    k = 2
    boundary = (ages[k - 1] + ages[k]) / 2
    sdk = sess.generate_trajectory(TOKS, AGES, max_new=max_new,
                                   uniforms=u, max_age=boundary)
    assert len(sdk["tokens"]) == k
    req, _ = _run_engine(params, cfg.replace(max_age=boundary), uniforms=u,
                         max_new=max_new)
    assert req.out_tokens == sdk["tokens"]
    assert len(req.out_tokens) == k
    assert all(a <= boundary for a in req.out_ages)


def test_engine_vs_core_parity(setup):
    """Engine ticks == in-graph batched generator under the same uniforms."""
    params, cfg, _ = setup
    max_new = 6
    u = _uniforms(max_new, cfg.vocab_size, seed=5)
    cfg9 = cfg.replace(max_age=1e9)
    req, _ = _run_engine(params, cfg9, uniforms=u, max_new=max_new,
                         max_context=len(TOKS) + max_new)
    t = jnp.asarray(np.asarray(TOKS, np.int32)[None])
    a = jnp.asarray(np.asarray(AGES, np.float32)[None])
    core = generate_trajectories(params, cfg9, t, a, jax.random.PRNGKey(0),
                                 max_new=max_new, max_age=1e9,
                                 uniforms=jnp.asarray(u)[None])
    n = len(req.out_tokens)
    assert n == int(core["n_generated"][0])
    S = len(TOKS)
    assert req.out_tokens == core["tokens"][0, S:S + n].tolist()
    np.testing.assert_allclose(req.out_ages, core["ages"][0, S:S + n],
                               rtol=0.08)


def test_pallas_sampler_path_matches_jnp(setup):
    """sampler="pallas" routes eq. 1 through the fused kernel (interpret on
    CPU) and must reproduce the jnp reference path bit-exactly."""
    params, cfg, _ = setup
    u = _uniforms(6, cfg.vocab_size, seed=9)
    cfg9 = cfg.replace(max_age=1e9)
    r_jnp, _ = _run_engine(params, cfg9, uniforms=u, max_new=6)
    r_pal, _ = _run_engine(params, cfg9, uniforms=u, max_new=6,
                           sampler="pallas")
    assert r_jnp.out_tokens == r_pal.out_tokens
    np.testing.assert_allclose(r_jnp.out_ages, r_pal.out_ages, rtol=1e-5)


def test_one_host_sync_per_tick(setup, monkeypatch):
    """The device-resident loop transfers exactly ONE packed array per tick
    (plus one per admission batch) — counted at the module's only
    device->host boundary."""
    params, cfg, _ = setup
    calls = []
    orig = engine_mod._to_host

    def counting(x):
        calls.append(x.shape)
        return orig(x)
    monkeypatch.setattr(engine_mod, "_to_host", counting)

    eng = BatchedEngine(params, cfg, slots=2, max_context=64)
    for i in range(5):
        S = 3 + (i % 3)
        eng.submit(Request(tokens=np.arange(3, 3 + S, dtype=np.int32),
                           ages=np.linspace(0, 20 + i, S).astype(np.float32),
                           max_new=4))
    done = eng.run()
    assert len(done) == 5
    assert eng.ticks > 0
    assert len(calls) == eng.host_syncs == eng.ticks + eng.admit_batches
    # every transfer is the packed (4, B) tick/admission result, nothing else
    assert all(s[0] == 4 for s in calls)


def test_bucketed_prefill_shape_policy(setup):
    """Admissions compile a small fixed set of (batch, seq) buckets instead
    of one shape per prompt length."""
    params, cfg, _ = setup
    eng = BatchedEngine(params, cfg, slots=4, max_context=64)
    lengths = list(range(3, 19))          # 16 distinct prompt lengths
    for S in lengths:
        eng.submit(Request(tokens=np.arange(3, 3 + S, dtype=np.int32) % 90,
                           ages=np.linspace(0, 25, S).astype(np.float32),
                           max_new=3))
    done = eng.run()
    assert len(done) == len(lengths)
    assert len(eng.prefill_shapes) < len(set(lengths))
    for nb, sb in eng.prefill_shapes:
        assert sb in (8, 16, 32)          # power-of-two seq buckets
        assert nb in (1, 2, 4)            # power-of-two batch buckets


def test_seq_bucket_never_exceeds_ring_width(setup):
    """A prompt that fits the ring cache must not lose context to bucket
    rounding: 33 tokens in a 48-wide cache would bucket to 64 (> W) and the
    S>W ring pack would silently evict positions 0..15."""
    params, cfg, d = setup
    S = 33
    toks = (np.arange(3, 3 + S) % 90).astype(np.int32)
    ages = np.linspace(0.0, 30.0, S).astype(np.float32)
    max_new = 4
    u = _uniforms(max_new, cfg.vocab_size, seed=13)
    sess = InferenceSession(d)
    sdk = sess.generate_trajectory(list(toks), list(ages), max_new=max_new,
                                   uniforms=u, max_age=1e9)
    eng = BatchedEngine(params, cfg.replace(max_age=1e9), slots=1,
                        max_context=48)
    eng.submit(Request(tokens=toks, ages=ages, max_new=max_new, uniforms=u))
    done = eng.run()
    assert [(nb, sb) for nb, sb in eng.prefill_shapes] == [(1, 48)]
    assert done[0].out_tokens == sdk["tokens"]


def test_mixed_injected_and_rng_requests(setup):
    """Injected-uniform and RNG requests submitted together serialize into
    separate slot cohorts instead of crashing the tick."""
    params, cfg, _ = setup
    eng = BatchedEngine(params, cfg, slots=2, max_context=64)
    u = _uniforms(4, cfg.vocab_size, seed=3)
    eng.submit(Request(tokens=np.asarray(TOKS, np.int32),
                       ages=np.asarray(AGES, np.float32),
                       max_new=4, uniforms=u))
    eng.submit(Request(tokens=np.asarray(TOKS, np.int32),
                       ages=np.asarray(AGES, np.float32), max_new=4))
    done = eng.run()
    assert len(done) == 2
    assert all(r.done for r in done)


def test_lm_mode_device_engine():
    """Generic-LM slot decoding on the device-resident path (rope + gumbel
    categorical), including refill past slot capacity."""
    from repro.models import init_params
    cfg = get_config("tinyllama-1.1b", reduced=True).replace(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(1))
    eng = BatchedEngine(params, cfg, slots=2, max_context=48)
    for i in range(3):
        eng.submit(Request(tokens=np.arange(1, 7 + i, dtype=np.int32),
                           max_new=5))
    done = eng.run()
    assert len(done) == 3
    for r in done:
        assert len(r.out_tokens) == 5
        assert all(0 <= t < cfg.vocab_size for t in r.out_tokens)
