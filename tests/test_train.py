"""Trainer: convergence on the paper objective, optimizer semantics,
checkpoint round-trip."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import init_delphi
from repro.data import (SimulatorConfig, batches, generate_dataset,
                        pack_trajectories)
from repro.train import (OptimizerConfig, cosine_lr, init_opt_state,
                         make_train_step, restore, save)
from repro.train.optimizer import adamw_update, global_norm


def test_delphi_loss_decreases(key):
    cfg = get_config("delphi-2m", reduced=True).replace(
        dtype="float32", vocab_size=1289)
    params = init_delphi(cfg, key)
    train, _ = generate_dataset(SimulatorConfig(n_train=64, n_val=1, seed=5))
    packed = pack_trajectories(train, 48)
    it = batches(packed, 16, seed=0)
    ocfg = OptimizerConfig(lr=2e-3, warmup_steps=3, total_steps=30)
    step = jax.jit(make_train_step(cfg, ocfg, "delphi"))
    opt = init_opt_state(params)
    losses = []
    for i in range(30):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses[::6]
    assert np.isfinite(losses).all()


def test_cosine_schedule():
    o = OptimizerConfig(lr=1.0, min_lr_ratio=0.1, warmup_steps=10,
                        total_steps=110)
    assert float(cosine_lr(o, jnp.int32(0))) == 0.0
    np.testing.assert_allclose(float(cosine_lr(o, jnp.int32(10))), 1.0)
    np.testing.assert_allclose(float(cosine_lr(o, jnp.int32(110))), 0.1,
                               rtol=1e-5)


def test_grad_clip():
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.full((4,), 100.0)}
    opt = init_opt_state(params)
    _, _, m = adamw_update(grads, opt, params,
                           OptimizerConfig(clip_norm=1.0))
    assert float(m["grad_norm"]) == 200.0   # reported pre-clip


def test_weight_decay_mask():
    params = {"w_gate": jnp.ones((2, 2)), "scale": jnp.ones((2,))}
    grads = jax.tree_util.tree_map(jnp.zeros_like, params)
    opt = init_opt_state(params)
    ocfg = OptimizerConfig(lr=0.1, weight_decay=1.0, warmup_steps=0,
                           total_steps=1)
    new, _, _ = adamw_update(grads, opt, params, ocfg)
    assert float(jnp.max(jnp.abs(new["scale"] - 1.0))) < 1e-6   # no decay
    assert float(jnp.max(jnp.abs(new["w_gate"] - 1.0))) > 1e-3  # decayed


def test_checkpoint_roundtrip(tmp_path, key):
    cfg = get_config("tinyllama-1.1b", reduced=True).replace(dtype="float32")
    from repro.models import init_params
    params = init_params(cfg, key)
    save(str(tmp_path / "ck"), params, cfg, extra={"step": 7})
    restored = restore(str(tmp_path / "ck"), params)
    flat1 = jax.tree_util.tree_leaves(params)
    flat2 = jax.tree_util.tree_leaves(restored)
    for a, b in zip(flat1, flat2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert os.path.exists(tmp_path / "ck" / "meta.json")


def test_global_norm():
    t = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    np.testing.assert_allclose(float(global_norm(t)), 5.0)
