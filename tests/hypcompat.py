"""Optional-``hypothesis`` shim for the property-based test modules.

``hypothesis`` is a dev-only dependency (see ``requirements-dev.txt`` /
``pyproject.toml`` extra ``dev``).  When it is installed this module
re-exports the real API unchanged.  When it is absent, property tests are
*skipped* (``pytest.importorskip`` semantics, but per-test instead of
per-module) so the example-based tests in the same files still run and the
suite degrades instead of erroring at collection.
"""


import pytest

try:
    from hypothesis import given, settings
    import hypothesis.extra.numpy as hnp
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Accepts any strategy-construction call chain and returns itself."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _StrategyStub()
    hnp = _StrategyStub()

    def given(*_args, **_kwargs):
        def deco(fn):
            # No functools.wraps: the skipper must expose a ZERO-arg
            # signature, or pytest would treat the hypothesis-provided
            # parameters as missing fixtures.
            def skipper():
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco


__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st", "hnp"]
