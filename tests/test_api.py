"""Unified client API + artifact spec v2.

Extends the parity pattern of ``tests/test_serve_device.py`` to the new
surfaces: v1 artifacts keep loading and bit-match v2 logits; v2
prefill+decode generation bit-matches the legacy ``InferenceSession`` host
loop and the engine under injected uniforms; all three ``repro.api`` backends
produce bit-identical event sequences; checksum verification reports
per-file status."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (ArtifactBackend, Client, EngineBackend,
                       GenerateRequest, RiskReport, TrajectoryResult)
from repro.configs import get_config
from repro.core import init_delphi
from repro.sdk import (ChecksumError, InferenceSession, Runtime, export_model,
                       read_manifest, verify_checksums)

TOKS = [3, 10, 20]
AGES = [0.0, 15.0, 28.0]


@pytest.fixture(scope="module")
def setup(tmp_path_factory):
    cfg = get_config("delphi-2m", reduced=True).replace(
        dtype="float32", vocab_size=96, max_seq_len=48)
    params = init_delphi(cfg, jax.random.PRNGKey(7))
    d2 = str(tmp_path_factory.mktemp("artifact_v2"))
    export_model(params, cfg, d2)
    d1 = str(tmp_path_factory.mktemp("artifact_v1"))
    export_model(params, cfg, d1, spec_version="1")
    return params, cfg, d2, d1


def _uniforms(max_new, V, seed=42):
    rng = np.random.default_rng(seed)
    return rng.uniform(size=(max_new, V)).astype(np.float32)


# ---------------------------------------------------------------------------
# Artifact versioning
# ---------------------------------------------------------------------------
def test_v1_artifact_still_loads_and_matches_v2_logits(setup):
    _, cfg, d2, d1 = setup
    rt1, rt2 = Runtime(d1), Runtime(d2)
    assert rt1.spec_version == "1.0" and not rt1.has_decode_graph
    assert rt2.spec_version == "2.0" and rt2.has_decode_graph
    S = cfg.max_seq_len
    t = np.zeros((1, S), np.int32)
    t[0, :3] = TOKS
    a = np.zeros((1, S), np.float32)
    a[0, :3] = AGES
    a[0, 3:] = AGES[-1]
    assert (rt1.run(t, a) == rt2.run(t, a)).all()


def test_v2_manifest_graphs_section(setup):
    _, cfg, d2, d1 = setup
    m = read_manifest(d2)
    assert m["spec_version"] == "2.0"
    g = m["graphs"]
    for name in ("full", "prefill", "decode_step"):
        assert g[name]["file"] in m["files"], name
    assert g["cache"]["n_leaves"] == len(g["cache"]["leaves"]) > 0
    assert g["cache"]["width"] == cfg.max_seq_len
    # decode graph I/O declares the cache explicitly (in AND out)
    assert any(i.get("name") == "cache" for i in g["decode_step"]["inputs"])
    assert any(o.get("name") == "cache" for o in g["decode_step"]["outputs"])
    assert "graphs" not in read_manifest(d1)


def test_v1_artifact_generates_via_full_graph_fallback(setup):
    _, cfg, d2, d1 = setup
    u = _uniforms(5, cfg.vocab_size)
    c1 = Client.from_artifact(d1)
    assert c1.backend.use_decode_graph is False       # auto fallback
    c2 = Client.from_artifact(d2)
    assert c2.backend.use_decode_graph is True
    r1 = c1.generate(tokens=TOKS, ages=AGES, max_new=5, uniforms=u,
                     max_age=1e9)
    r2 = c2.generate(tokens=TOKS, ages=AGES, max_new=5, uniforms=u,
                     max_age=1e9)
    assert r1.tokens == r2.tokens
    with pytest.raises(ValueError, match="decode graph"):
        ArtifactBackend(d1, use_decode_graph=True)


# ---------------------------------------------------------------------------
# Prefill+decode parity (the tentpole claim)
# ---------------------------------------------------------------------------
def test_v2_decode_matches_session_full_graph(setup):
    """v2 prefill+decode == legacy full-graph-per-token host loop: bit-exact
    event sequence, first waiting time tight, later ages loose (same fp
    caveat as test_serve_device.test_engine_vs_sdk_parity)."""
    _, cfg, d2, _ = setup
    max_new = 6
    u = _uniforms(max_new, cfg.vocab_size)
    sess = InferenceSession(d2)
    sdk = sess.generate_trajectory(TOKS, AGES, max_new=max_new,
                                   uniforms=u, max_age=1e9)
    res = Client.from_artifact(d2).generate(
        tokens=TOKS, ages=AGES, max_new=max_new, uniforms=u, max_age=1e9)
    assert res.tokens == sdk["tokens"]
    assert len(res.ages) == len(sdk["ages"])
    np.testing.assert_allclose(res.ages[:2], sdk["ages"][:2], rtol=1e-3)
    np.testing.assert_allclose(res.ages, sdk["ages"], rtol=0.08)
    assert res.full_tokens == sdk["full_tokens"]


def test_three_backends_bit_identical_tokens(setup):
    """Acceptance: artifact (prefill+decode), engine (in-graph tick), and
    local (in-graph batched) backends emit identical event sequences under
    one injected uniform stream."""
    params, cfg, d2, _ = setup
    max_new = 6
    u = _uniforms(max_new, cfg.vocab_size, seed=5)
    cfg9 = cfg.replace(max_age=1e9)
    req = GenerateRequest(tokens=TOKS, ages=AGES, max_new=max_new, uniforms=u)

    r_art = Client.from_artifact(d2).generate(
        GenerateRequest(tokens=TOKS, ages=AGES, max_new=max_new, uniforms=u,
                        max_age=1e9))
    r_loc = Client.from_params(params, cfg9).generate(req)
    r_eng = Client.serving(params, cfg9, slots=1, max_context=64).generate(req)

    assert r_art.tokens == r_loc.tokens == r_eng.tokens
    assert len(r_art.tokens) > 0
    assert {r_art.backend, r_loc.backend, r_eng.backend} == \
        {"artifact", "local", "engine"}
    np.testing.assert_allclose(r_art.ages, r_loc.ages, rtol=0.08)
    np.testing.assert_allclose(r_art.ages, r_eng.ages, rtol=0.08)


def test_decode_path_max_age_censoring(setup):
    """The max-age boundary on the decode path: the crossing event is
    censored BEFORE being emitted, exactly like the legacy host loop."""
    _, cfg, d2, _ = setup
    max_new = 6
    u = _uniforms(max_new, cfg.vocab_size)
    client = Client.from_artifact(d2)
    free = client.generate(tokens=TOKS, ages=AGES, max_new=max_new,
                           uniforms=u, max_age=1e9)
    assert len(free.ages) >= 3
    k = 2
    boundary = (free.ages[k - 1] + free.ages[k]) / 2
    cut = client.generate(tokens=TOKS, ages=AGES, max_new=max_new,
                          uniforms=u, max_age=boundary)
    assert cut.tokens == free.tokens[:k]
    assert all(a <= boundary for a in cut.ages)


def test_v2_decode_matches_full_graph_generic_lm(tmp_path):
    """Regression: the exported non-delphi decode graph must receive
    (token, step) in the right argument slots — spec-v2 export used to pass
    the token into the age slot for age_encoding=False configs and crash at
    trace time."""
    from repro.models import init_params
    cfg = get_config("tinyllama-1.1b", reduced=True).replace(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(3))
    d = str(tmp_path / "lm_art")
    from repro.sdk import export_model as export
    export(params, cfg, d)
    u = _uniforms(4, cfg.vocab_size, seed=11)
    toks = [1, 5, 9]
    full = Client.from_artifact(d, use_decode_graph=False).generate(
        tokens=toks, max_new=4, uniforms=u)
    dec = Client.from_artifact(d).generate(tokens=toks, max_new=4,
                                           uniforms=u)
    assert len(dec.tokens) == 4
    assert dec.tokens == full.tokens


def test_uniforms_shape_validated(setup):
    """A malformed uniforms array must be a structured error at validation,
    not an IndexError mid-loop (on the engine that would poison every
    in-flight request)."""
    from repro.api.errors import InvalidRequestError
    params, cfg, d2, _ = setup
    bad = _uniforms(2, cfg.vocab_size)                 # rows < max_new
    for client in (Client.from_artifact(d2),
                   Client.from_params(params, cfg),
                   Client.serving(params, cfg, slots=1, max_context=64)):
        with pytest.raises(InvalidRequestError, match="uniforms"):
            client.generate(tokens=TOKS, ages=AGES, max_new=6, uniforms=bad)
    with pytest.raises(InvalidRequestError, match="uniforms"):
        Client.from_artifact(d2).generate(
            tokens=TOKS, ages=AGES, max_new=2,
            uniforms=_uniforms(2, cfg.vocab_size + 1))  # wrong vocab width


def test_stream_validates_eagerly_on_every_backend(setup):
    """stream() raises at the call, not at the consumer's first next() —
    the same timing on all backends."""
    params, cfg, d2, _ = setup
    for client in (Client.from_artifact(d2),
                   Client.from_params(params, cfg),
                   Client.serving(params, cfg, slots=1, max_context=64)):
        with pytest.raises(ValueError, match="empty"):
            client.stream(tokens=[], ages=[])       # no iteration needed


def test_engine_rejects_per_request_seed(setup):
    """The engine draws from its construction-time PRNG stream: a
    per-request seed would be silently ignored, so it raises instead."""
    params, cfg, _, _ = setup
    client = Client.serving(params, cfg, slots=1, max_context=64)
    with pytest.raises(ValueError, match="seed"):
        client.generate(tokens=TOKS, ages=AGES, max_new=3, seed=7)
    # seed with injected uniforms is inert and therefore fine
    u = _uniforms(3, cfg.vocab_size)
    out = client.generate(tokens=TOKS, ages=AGES, max_new=3, uniforms=u,
                          seed=7)
    assert out.backend == "engine"


# ---------------------------------------------------------------------------
# Streaming + batching
# ---------------------------------------------------------------------------
def test_stream_matches_generate(setup):
    params, cfg, d2, _ = setup
    max_new = 5
    u = _uniforms(max_new, cfg.vocab_size, seed=9)
    art = Client.from_artifact(d2)
    ref = art.generate(tokens=TOKS, ages=AGES, max_new=max_new, uniforms=u,
                       max_age=1e9)
    ev_art = list(art.stream(tokens=TOKS, ages=AGES, max_new=max_new,
                             uniforms=u, max_age=1e9))
    assert [e.token for e in ev_art] == ref.tokens
    assert [e.index for e in ev_art] == list(range(len(ref.tokens)))

    eng = Client.serving(params, cfg.replace(max_age=1e9), slots=1,
                         max_context=64)
    ev_eng = list(eng.stream(tokens=TOKS, ages=AGES, max_new=max_new,
                             uniforms=u))
    assert [e.token for e in ev_eng] == ref.tokens

    loc = Client.from_params(params, cfg.replace(max_age=1e9))
    ev_loc = list(loc.stream(tokens=TOKS, ages=AGES, max_new=max_new,
                             uniforms=u))
    assert [e.token for e in ev_loc] == ref.tokens


def test_engine_generate_batch(setup):
    params, cfg, _, _ = setup
    client = Client.serving(params, cfg, slots=4, max_context=64)
    reqs = [GenerateRequest(tokens=np.arange(3, 6 + i).tolist(),
                            ages=np.linspace(0, 20 + i, 3 + i).tolist(),
                            max_new=4)
            for i in range(6)]
    outs = client.generate_batch(reqs)
    assert len(outs) == 6
    assert all(isinstance(o, TrajectoryResult) for o in outs)
    # results are mapped back in submission order
    for req, out in zip(reqs, outs):
        assert out.prompt_tokens == list(req.tokens)
        assert len(out.tokens) == len(out.ages) <= 4


def test_engine_logits_accept_prompts_up_to_max_context(setup):
    """The engine backend's prompt axis is the ring (max_context), which may
    exceed cfg.max_seq_len — risk()/logits() must not overflow the padded
    buffer for prompts in between."""
    params, cfg, _, _ = setup
    assert cfg.max_seq_len == 48
    client = Client.serving(params, cfg, slots=1, max_context=64)
    n = 50                                    # > max_seq_len, <= max_context
    toks = (np.arange(3, 3 + n) % 90).tolist()
    ages = np.linspace(0.0, 40.0, n).tolist()
    lg = client.backend.logits(toks, ages)
    assert lg.shape == (cfg.vocab_size,) and np.isfinite(lg).all()
    rep = client.risk(toks, ages, top=3)
    assert len(rep.items) == 3


def test_local_generate_honors_host_rng(setup):
    """req.rng must not be silently ignored: LocalBackend falls back to the
    host loop, so generate == stream for the same seeded generator."""
    params, cfg, _, _ = setup
    client = Client.from_params(params, cfg.replace(max_age=1e9))
    gen = client.generate(tokens=TOKS, ages=AGES, max_new=4,
                          rng=np.random.default_rng(123))
    streamed = [e.token for e in client.stream(
        tokens=TOKS, ages=AGES, max_new=4, rng=np.random.default_rng(123))]
    assert gen.tokens == streamed
    # and a different generator produces a different draw (not seed-0 output)
    other = client.generate(tokens=TOKS, ages=AGES, max_new=4,
                            rng=np.random.default_rng(7))
    seed0 = client.generate(tokens=TOKS, ages=AGES, max_new=4, seed=0)
    assert gen.tokens != other.tokens or gen.tokens != seed0.tokens


def test_engine_rejects_per_request_termination_overrides(setup):
    params, cfg, _, _ = setup
    client = Client.serving(params, cfg, slots=1, max_context=64)
    with pytest.raises(ValueError, match="max_age"):
        client.generate(tokens=TOKS, ages=AGES, max_age=1e9)
    with pytest.raises(ValueError, match="death_token"):
        client.generate(tokens=TOKS, ages=AGES, death_token=5)


# ---------------------------------------------------------------------------
# Risk reports
# ---------------------------------------------------------------------------
def test_risk_parity_across_backends(setup):
    params, cfg, d2, _ = setup
    art = Client.from_artifact(d2).risk(TOKS, AGES, horizon=5.0, top=8)
    loc = Client.from_params(params, cfg).risk(TOKS, AGES, horizon=5.0, top=8)
    eng = Client.serving(params, cfg, slots=1, max_context=64).risk(
        TOKS, AGES, horizon=5.0, top=8)
    assert isinstance(art, RiskReport) and len(art.items) == 8
    assert [i.token for i in art.items] == [i.token for i in loc.items] \
        == [i.token for i in eng.items]
    np.testing.assert_allclose([i.risk for i in art.items],
                               [i.risk for i in loc.items], rtol=1e-5)
    # legacy schema delegation
    sess = InferenceSession(d2)
    legacy = sess.estimate_risk(TOKS, AGES, horizon=5.0, top=8)
    assert legacy == art.as_dicts()


# ---------------------------------------------------------------------------
# Checksum report (satellite)
# ---------------------------------------------------------------------------
def test_checksum_report_states(setup, tmp_path):
    params, cfg, _, _ = setup
    d = str(tmp_path / "art")
    export_model(params, cfg, d)
    rep = verify_checksums(d)
    assert rep and rep.ok and set(rep.files.values()) == {"ok"}

    with open(os.path.join(d, "params.npz"), "r+b") as f:
        f.seek(100)
        f.write(b"\x00\x01\x02")
    os.remove(os.path.join(d, "prefill.bin"))
    rep = verify_checksums(d)
    assert not rep
    assert rep.files["params.npz"] == "mismatch"
    assert rep.files["prefill.bin"] == "missing"
    assert rep.files["model.bin"] == "ok"
    assert rep.bad_files == {"params.npz": "mismatch",
                             "prefill.bin": "missing"}
    with pytest.raises(ChecksumError, match="params.npz"):
        verify_checksums(d, strict=True)
    with pytest.raises(ChecksumError, match="prefill.bin"):
        verify_checksums(d, strict=True)


# ---------------------------------------------------------------------------
# Export validation (satellite)
# ---------------------------------------------------------------------------
def test_export_validates_seq_len(setup, tmp_path):
    params, cfg, _, _ = setup
    with pytest.raises(ValueError, match="max_seq_len"):
        export_model(params, cfg, str(tmp_path / "bad"),
                     seq_len=cfg.max_seq_len + 1)


def test_export_rejects_custom_logits_fn_for_v2(setup, tmp_path):
    params, cfg, _, _ = setup
    with pytest.raises(ValueError, match="logits_fn"):
        export_model(params, cfg, str(tmp_path / "bad"),
                     logits_fn=lambda p, t, a: t)
    with pytest.raises(ValueError, match="spec_version"):
        export_model(params, cfg, str(tmp_path / "bad"), spec_version="3")


# ---------------------------------------------------------------------------
# Session shim
# ---------------------------------------------------------------------------
def test_session_is_a_client_shim(setup):
    _, _, d2, _ = setup
    sess = InferenceSession(d2)
    assert isinstance(sess.client, Client)
    # the shim pins the paper-faithful full-graph loop
    assert sess.client.backend.use_decode_graph is False
    with pytest.warns(DeprecationWarning, match="deprecated"):
        sess.getLogits(TOKS, AGES)


def test_client_kwargs_or_request_not_both(setup):
    _, _, d2, _ = setup
    client = Client.from_artifact(d2)
    with pytest.raises(TypeError, match="not both"):
        client.generate(GenerateRequest(tokens=TOKS, ages=AGES), max_new=3)
