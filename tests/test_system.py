"""End-to-end behaviour: the paper's full pipeline on one CPU —
simulate -> train (dual loss) -> export (FAIR artifact) -> client-side SDK
generation -> batched serving.  Validates claims C1–C5 jointly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import init_delphi
from repro.data import (SimulatorConfig, batches, generate_dataset,
                        pack_trajectories)
from repro.data import vocab as V
from repro.sdk import InferenceSession, export_model, verify_checksums
from repro.serve import BatchedEngine, Request
from repro.train import OptimizerConfig, init_opt_state, make_train_step


@pytest.fixture(scope="module")
def trained():
    cfg = get_config("delphi-2m", reduced=True).replace(
        dtype="float32", vocab_size=1289, max_seq_len=48)
    params = init_delphi(cfg, jax.random.PRNGKey(0))
    train, _ = generate_dataset(SimulatorConfig(n_train=96, n_val=8, seed=1))
    packed = pack_trajectories(train, 48)
    it = batches(packed, 16, seed=0)
    step = jax.jit(make_train_step(
        cfg, OptimizerConfig(lr=2e-3, warmup_steps=5, total_steps=40),
        "delphi"))
    opt = init_opt_state(params)
    first = last = None
    for i in range(40):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt, m = step(params, opt, b)
        if i == 0:
            first = float(m["loss"])
        last = float(m["loss"])
    return cfg, params, first, last, train


def test_c1_training_converges(trained):
    _, _, first, last, _ = trained
    assert last < first * 0.85


def test_c2_c5_export_and_client_side_inference(trained, tmp_path):
    cfg, params, _, _, train = trained
    d = str(tmp_path / "artifact")
    export_model(params, cfg, d)
    assert verify_checksums(d)
    sess = InferenceSession(d)
    tok, age = train[0]
    half = min(len(tok) // 2, 20)
    out = sess.generate_trajectory(tok[:half].tolist(), age[:half].tolist(),
                                   max_new=16)
    assert 1 <= len(out["tokens"]) <= 16
    # C4 semantics: ages monotone, capped at 85, death terminal
    ages = out["full_ages"]
    assert all(b >= a - 1e-6 for a, b in zip(ages, ages[1:]))
    assert max(ages) <= 85.0
    if V.DEATH in out["tokens"]:
        assert out["tokens"][-1] == V.DEATH


def test_batched_serving_on_trained_model(trained):
    cfg, params, _, _, train = trained
    eng = BatchedEngine(params, cfg, slots=4, max_context=96, seed=3)
    for tok, age in train[:6]:
        h = min(len(tok) // 2, 20)
        eng.submit(Request(tokens=tok[:h], ages=age[:h], max_new=8))
    done = eng.run()
    assert len(done) == 6
    gaps = [b - a for r in done
            for a, b in zip([r.out_ages[0]] + r.out_ages[:-1], r.out_ages)]
    assert np.isfinite(gaps).all()
