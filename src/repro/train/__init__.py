"""Training substrate: optimizer, steps, loop, checkpointing."""
from repro.train.checkpoint import load_config, restore, save
from repro.train.optimizer import (OptimizerConfig, adamw_update, cosine_lr,
                                   global_norm, init_opt_state)
from repro.train.trainer import (lm_loss, make_eval_step, make_loss_fn,
                                 make_train_step, train_loop)

__all__ = ["load_config", "restore", "save", "OptimizerConfig",
           "adamw_update", "cosine_lr", "global_norm", "init_opt_state",
           "lm_loss", "make_eval_step", "make_loss_fn", "make_train_step",
           "train_loop"]
