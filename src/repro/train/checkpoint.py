"""Checkpointing: pytree <-> npz + json treedef (no external deps).

Array leaves are stored in a single ``.npz`` keyed by flattened path; the
config is stored as JSON alongside.  ``save``/``restore`` round-trip exactly
(dtype- and shape-preserving), which the SDK export also relies on for
parameter shipping.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, Tuple

import jax
import numpy as np

from repro.configs.base import ModelConfig


def _flatten(params) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, params, cfg: ModelConfig | None = None,
         extra: Dict[str, Any] | None = None) -> None:
    os.makedirs(path, exist_ok=True)
    np.savez(os.path.join(path, "params.npz"), **_flatten(params))
    meta = {"extra": extra or {}}
    if cfg is not None:
        meta["config"] = dataclasses.asdict(cfg)
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2, default=str)


def restore(path: str, params_template) -> Any:
    """Restore into the structure of ``params_template`` (e.g. from
    ``init_params`` under ``jax.eval_shape``)."""
    data = np.load(os.path.join(path, "params.npz"))
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(params_template)
    out = []
    for path_k, leaf in leaves_p:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path_k)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def load_config(path: str) -> Tuple[ModelConfig, Dict[str, Any]]:
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    cfg = ModelConfig(**{k: v for k, v in meta["config"].items()})
    return cfg, meta.get("extra", {})
