"""Training steps and loop.

Two objectives share one step factory:
* ``objective="delphi"`` — the paper's dual loss over (tokens, ages, targets,
  target_dt, loss_mask) batches.
* ``objective="lm"``     — next-token CE (+ MoE aux) for the assigned
  architecture zoo; this is the function the train_4k dry-run shapes lower.

``make_train_step`` returns a pure function suitable for ``jax.jit`` directly
or for ``jax.jit(..., in_shardings=..)`` by the multi-pod launcher.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import losses as losses_lib
from repro.core.delphi import loss_fn as delphi_loss_fn
from repro.models import forward
from repro.train.optimizer import (OptimizerConfig, adamw_update,
                                   init_opt_state)


def lm_loss(params, cfg: ModelConfig, batch: Dict[str, Any], *,
            moe_impl: str = "dense_scan") -> Dict[str, jax.Array]:
    """Next-token CE over the text stream (frontend tokens excluded)."""
    out = forward(params, cfg, batch, mode="train", moe_impl=moe_impl)
    logits = out["logits"]
    off = out["text_offset"]
    if off:
        logits = logits[:, off:]
    tokens = batch["tokens"]
    ce = losses_lib.event_ce(logits[:, :-1], tokens[:, 1:])
    loss = jnp.mean(ce)
    total = loss + cfg.router_aux_coef * out["aux_loss"]
    return {"loss": total, "event_ce": loss, "aux_loss": out["aux_loss"]}


def make_loss_fn(cfg: ModelConfig, objective: str = "lm", *,
                 moe_impl: str = "dense_scan") -> Callable:
    if objective == "delphi":
        return lambda p, b: delphi_loss_fn(p, cfg, b)
    return lambda p, b: lm_loss(p, cfg, b, moe_impl=moe_impl)


def make_train_step(cfg: ModelConfig, ocfg: OptimizerConfig,
                    objective: str = "lm", *, moe_impl: str = "dense_scan"
                    ) -> Callable:
    loss_fn = make_loss_fn(cfg, objective, moe_impl=moe_impl)

    def train_step(params, opt_state, batch):
        def scalar_loss(p):
            m = loss_fn(p, batch)
            return m["loss"], m
        grads, metrics = jax.grad(scalar_loss, has_aux=True)(params)
        params, opt_state, opt_metrics = adamw_update(
            grads, opt_state, params, ocfg)
        return params, opt_state, {**metrics, **opt_metrics}

    return train_step


def make_eval_step(cfg: ModelConfig, objective: str = "lm") -> Callable:
    loss_fn = make_loss_fn(cfg, objective)
    return lambda params, batch: loss_fn(params, batch)


def train_loop(params, cfg: ModelConfig, ocfg: OptimizerConfig,
               train_iter: Iterator[Dict[str, Any]], *,
               objective: str = "delphi", steps: int = 200,
               eval_iter: Optional[Iterator[Dict[str, Any]]] = None,
               eval_every: int = 50, log_every: int = 10,
               log_fn: Callable[[str], None] = print
               ) -> Tuple[Any, Dict[str, list]]:
    """Single-host training loop (examples / quickstart).  Returns
    (trained params, history)."""
    step_fn = jax.jit(make_train_step(cfg, ocfg, objective))
    eval_fn = jax.jit(make_eval_step(cfg, objective))
    opt_state = init_opt_state(params)
    hist = {"step": [], "loss": [], "event_ce": [], "time_nll": [],
            "val_loss": [], "val_step": []}
    t0 = time.time()
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(train_iter).items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
        if i % log_every == 0 or i == steps - 1:
            hist["step"].append(i)
            hist["loss"].append(float(m["loss"]))
            hist["event_ce"].append(float(m["event_ce"]))
            hist["time_nll"].append(float(m.get("time_nll", jnp.nan)))
            log_fn(f"step {i:4d} loss {m['loss']:.4f} ce {m['event_ce']:.4f}"
                   f" time_nll {float(m.get('time_nll', jnp.nan)):.4f}"
                   f" lr {m['lr']:.2e} gnorm {m['grad_norm']:.2f}"
                   f" ({time.time() - t0:.1f}s)")
        if eval_iter is not None and (i + 1) % eval_every == 0:
            vb = {k: jnp.asarray(v) for k, v in next(eval_iter).items()}
            vm = eval_fn(params, vb)
            hist["val_loss"].append(float(vm["loss"]))
            hist["val_step"].append(i)
            log_fn(f"  eval step {i:4d} val_loss {vm['loss']:.4f}")
    return params, hist
