"""AdamW + cosine schedule + global-norm clipping on raw pytrees.

No optax in this environment; this is the nanoGPT/llama recipe implemented
directly.  Optimizer state is {mu, nu, step}; master params stay in
``cfg.param_dtype`` (fp32) and moments in fp32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    min_lr_ratio: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 1000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def cosine_lr(ocfg: OptimizerConfig, step):
    step = step.astype(jnp.float32)
    warm = ocfg.lr * step / max(ocfg.warmup_steps, 1)
    t = jnp.clip((step - ocfg.warmup_steps)
                 / max(ocfg.total_steps - ocfg.warmup_steps, 1), 0.0, 1.0)
    cos = ocfg.lr * (ocfg.min_lr_ratio
                     + (1 - ocfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < ocfg.warmup_steps, warm, cos)


def init_opt_state(params) -> Dict[str, Any]:
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"mu": zeros,
            "nu": jax.tree_util.tree_map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def _decay_mask(path) -> bool:
    """Weight-decay matrices only (no norms / biases / scalars) — nanoGPT rule."""
    name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
    return not any(s in name for s in ("scale", "bias", "b_fc", "b_proj",
                                       "bq", "bk", "bv", "A_log", "dt_bias", "D"))


def adamw_update(grads, opt_state, params, ocfg: OptimizerConfig
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = opt_state["step"] + 1
    lr = cosine_lr(ocfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, ocfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32) * scale, grads)

    mu = jax.tree_util.tree_map(
        lambda m, g: ocfg.b1 * m + (1 - ocfg.b1) * g, opt_state["mu"], grads)
    nu = jax.tree_util.tree_map(
        lambda v, g: ocfg.b2 * v + (1 - ocfg.b2) * jnp.square(g),
        opt_state["nu"], grads)
    bc1 = 1 - ocfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - ocfg.b2 ** step.astype(jnp.float32)

    def upd(path, p, m, v):
        u = (m / bc1) / (jnp.sqrt(v / bc2) + ocfg.eps)
        if _decay_mask(path):
            u = u + ocfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree_util.tree_map_with_path(upd, params, mu, nu)
    return new_params, {"mu": mu, "nu": nu, "step": step}, \
        {"lr": lr, "grad_norm": gnorm}
