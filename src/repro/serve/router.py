"""Multi-replica serving tier: a prefix-affinity router over N engines.

One ``BatchedEngine`` behind one ``InferenceServer`` tops out at a single
device's paged pool.  This module is the horizontal lever on the ROADMAP's
millions-of-users north star: N independent ``repro-serve`` replicas behind
ONE wire endpoint, with requests routed so that shared patient histories
land on the replica whose copy-on-write block pool already holds their
prefix.  Three parts:

* :class:`ReplicaSupervisor` — owns the replica set.  It can *spawn*
  replicas as ``repro-serve`` subprocesses, boot them *in-process* (each an
  ``InferenceServer`` on an ephemeral port — the test/benchmark mode), or
  *adopt* already-running URLs.  A background prober hits each replica's
  ``/v1/healthz``; ``max_probe_failures`` consecutive failures mark it
  unhealthy (a later success restores it), and ``drain(name)`` stops
  admitting to a replica, waits for its in-flight requests to finish, then
  stops it.

* :class:`PrefixAffinityScheduler` — reuses ``serve/prefix.py``'s chained
  blake2b chunk digests (:func:`repro.serve.prefix.prompt_digests`): the
  router remembers which replica it sent each full-block prefix digest to,
  so a request whose history extends an already-routed prefix goes to the
  replica whose resident ``PrefixIndex`` can admit it by reference.  No
  match falls back to least-loaded (most free pool blocks from the last
  health probe, then fewest in-flight).

* :class:`RouterServer` — the stdlib HTTP front-end (same
  ``ThreadingHTTPServer`` pattern as ``serve/server.py``) proxying every
  ``/v1/*`` endpoint over per-replica :class:`~repro.api.RemoteBackend`
  connection pools.  Idempotent calls (generate / generate_batch / risk,
  and futures whose ``request_id`` the router itself assigned) are retried
  once on a different healthy replica when the first pick fails at the
  transport level; ``stream``/``cancel``/``futures`` for a given
  ``request_id`` are pinned to one replica (so cancellation finds the
  engine that holds the slot); and when no healthy replica remains the
  structured ``replica_unavailable`` error surfaces — including as the
  terminal SSE ``error`` frame of a pinned stream whose replica died
  mid-flight, which is never retried (a replay would duplicate emitted
  events).  ``/v1/healthz`` rolls up per-replica health/pool stats plus the
  scheduler's affinity-vs-fallback counters and each replica's prefix
  hit-rate delta between probes.

Run:  ``repro-serve --config delphi-2m --reduced --replicas 2``
"""
from __future__ import annotations

import http.client
import itertools
import json
import subprocess
import sys
import threading
import time
import uuid
from collections import OrderedDict
from dataclasses import replace as dc_replace
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Tuple
from urllib.parse import urlsplit

from repro.api.errors import (ApiError, InternalServerError,
                              InvalidRequestError, ReplicaUnavailableError)
from repro.api.schemas import (WIRE_PROTOCOL_VERSION, FuturesRequest,
                               FuturesResult, GenerateRequest, RiskReport,
                               TrajectoryResult, check_protocol)
from repro.serve.prefix import prompt_digests

__all__ = ["ReplicaHandle", "ReplicaSupervisor", "PrefixAffinityScheduler",
           "RouterServer", "build_router"]

ROUTER_NAME = "repro-router/0.1"


def _get_json(url: str, path: str, timeout: float) -> dict:
    """One lightweight GET round-trip (no RemoteBackend handshake) — the
    health-probe primitive.  Raises ``OSError`` on any transport or
    non-200 condition so the prober counts it as a single failure."""
    sp = urlsplit(url if "//" in url else "http://" + url)
    conn = http.client.HTTPConnection(sp.hostname or "127.0.0.1",
                                      sp.port or 80, timeout=timeout)
    try:
        conn.request("GET", (sp.path.rstrip("/")) + path)
        resp = conn.getresponse()
        raw = resp.read()
        if resp.status != 200:
            raise OSError(f"HTTP {resp.status} from {url}{path}")
        try:
            return json.loads(raw.decode("utf-8"))
        except (json.JSONDecodeError, UnicodeDecodeError) as e:
            raise OSError(f"undecodable healthz from {url}: {e}") from None
    finally:
        conn.close()


def _free_port(host: str = "127.0.0.1") -> int:
    import socket
    with socket.socket() as s:
        s.bind((host, 0))
        return s.getsockname()[1]


# ---------------------------------------------------------------------------
# Replica handle
# ---------------------------------------------------------------------------
class ReplicaHandle:
    """One serving replica as the router sees it: an address, a pool of
    keep-alive ``RemoteBackend`` connections, and health/load state.

    The connection pool exists because a ``RemoteBackend``'s pooled socket
    serializes callers: one backend per concurrent proxied request keeps
    the router's throughput at the replica's admission width instead of 1.
    Released backends return to the pool (capped at ``max_pool``; excess
    and transport-failed ones close).
    """

    def __init__(self, name: str, url: str, *,
                 server=None, proc: Optional[subprocess.Popen] = None,
                 connect_timeout: float = 5.0, read_timeout: float = 300.0,
                 max_pool: int = 8, max_failures: int = 3):
        self.name = name
        self.url = url.rstrip("/")
        self.server = server            # owned in-process InferenceServer
        self.proc = proc                # owned repro-serve subprocess
        self.connect_timeout = connect_timeout
        self.read_timeout = read_timeout
        self.max_pool = max_pool
        self.max_failures = max_failures
        self._lock = threading.Lock()
        self._pool: List = []                       # guarded-by: _lock
        self._healthy = True                        # guarded-by: _lock
        self._accepting = True                      # guarded-by: _lock
        self._failures = 0                          # guarded-by: _lock
        self._inflight = 0                          # guarded-by: _lock
        self._last_health: Optional[dict] = None    # guarded-by: _lock
        self._prev_prefix: Optional[dict] = None    # guarded-by: _lock
        self._prefix_delta: Optional[dict] = None   # guarded-by: _lock
        self._dialed = 0                            # guarded-by: _lock

    # -- connection pool ------------------------------------------------------
    def acquire(self):
        """A ``RemoteBackend`` for one proxied call — pooled, or freshly
        dialed (handshake included) outside the lock.  Dial failures raise
        ``replica_unavailable`` like any other transport failure."""
        with self._lock:
            if self._pool:
                return self._pool.pop()
            self._dialed += 1
        from repro.api.remote import RemoteBackend
        try:
            return RemoteBackend(self.url,
                                 connect_timeout=self.connect_timeout,
                                 read_timeout=self.read_timeout)
        except ReplicaUnavailableError:
            raise
        except OSError as e:
            raise ReplicaUnavailableError(
                f"cannot dial replica {self.name} at {self.url}: "
                f"{e}") from None

    def release(self, rb) -> None:
        """Return a healthy connection to the pool (or close the excess)."""
        with self._lock:
            if self._healthy and len(self._pool) < self.max_pool:
                self._pool.append(rb)
                return
        rb.close()

    def discard(self, rb) -> None:
        """Close a connection that saw a transport failure."""
        rb.close()

    def _drain_pool(self) -> List:
        with self._lock:
            pool, self._pool = self._pool, []
        return pool

    # -- load accounting ------------------------------------------------------
    def begin_request(self) -> None:
        with self._lock:
            self._inflight += 1

    def end_request(self) -> None:
        with self._lock:
            self._inflight = max(self._inflight - 1, 0)

    @property
    def inflight(self) -> int:
        with self._lock:
            return self._inflight

    # -- health ---------------------------------------------------------------
    @property
    def healthy(self) -> bool:
        with self._lock:
            return self._healthy

    @property
    def accepting(self) -> bool:
        with self._lock:
            return self._healthy and self._accepting

    def set_accepting(self, flag: bool) -> None:
        with self._lock:
            self._accepting = flag

    def probe_ok(self, health: dict) -> None:
        """A healthz probe landed: restore health, compute the prefix
        hit-rate delta vs the previous probe (affinity effectiveness as
        the replica itself observed it)."""
        prefix = None
        eng = health.get("engine") if isinstance(health, dict) else None
        if isinstance(eng, dict):
            mem = eng.get("memory") or {}
            prefix = mem.get("prefix_cache")
        with self._lock:
            self._failures = 0
            self._healthy = True
            self._last_health = health
            if isinstance(prefix, dict):
                prev = self._prev_prefix or {}
                self._prefix_delta = {
                    "hit_rate": prefix.get("hit_rate"),
                    "hits_delta": (prefix.get("hits", 0)
                                   - prev.get("hits", 0)),
                    "partial_hits_delta": (prefix.get("partial_hits", 0)
                                           - prev.get("partial_hits", 0)),
                }
                self._prev_prefix = prefix

    def probe_failed(self) -> bool:
        """Count one probe failure; returns True when this crossing marks
        the replica unhealthy."""
        with self._lock:
            self._failures += 1
            if self._failures >= self.max_failures and self._healthy:
                self._healthy = False
                return True
            return False

    def mark_unhealthy(self) -> bool:
        """A proxied call failed at the transport level — decisive evidence
        (connection refused / dropped mid-response), so the replica goes
        unhealthy immediately; the prober restores it on its next
        successful ``/v1/healthz``.  Returns True on the healthy->unhealthy
        edge."""
        with self._lock:
            self._failures = max(self._failures, self.max_failures)
            was = self._healthy
            self._healthy = False
        return was

    def free_blocks(self) -> Optional[int]:
        """Free pool blocks from the last health probe (the least-loaded
        routing signal); None when unknown (no probe yet / host backend)."""
        with self._lock:
            h = self._last_health
        eng = h.get("engine") if isinstance(h, dict) else None
        if isinstance(eng, dict):
            mem = eng.get("memory") or {}
            if "blocks_free" in mem:
                return int(mem["blocks_free"])
        return None

    def snapshot(self) -> dict:
        """Healthz rollup entry for this replica."""
        with self._lock:
            return {
                "url": self.url,
                "healthy": self._healthy,
                "accepting": self._accepting,
                "inflight": self._inflight,
                "consecutive_failures": self._failures,
                "connections_dialed": self._dialed,
                "pooled_connections": len(self._pool),
                "prefix": self._prefix_delta,
                "healthz": self._last_health,
            }

    # -- lifecycle ------------------------------------------------------------
    def stop(self, *, kill_timeout: float = 10.0) -> None:
        """Tear the replica down: close pooled connections, then stop the
        owned in-process server or terminate the owned subprocess (adopted
        replicas are left running)."""
        for rb in self._drain_pool():
            rb.close()
        if self.server is not None:
            self.server.stop()
            self.server = None
        if self.proc is not None:
            self.proc.terminate()
            try:
                self.proc.wait(timeout=kill_timeout)
            except subprocess.TimeoutExpired:
                self.proc.kill()
                self.proc.wait(timeout=kill_timeout)
            self.proc = None
        with self._lock:
            self._healthy = False
            self._accepting = False

    def kill(self) -> None:
        """Crash simulation (failover tests / the roundtrip storm): an
        in-process replica severs every live connection mid-response
        (``InferenceServer.kill``), a subprocess replica gets SIGKILL —
        either way open streams die without terminal frames, exactly like
        a crashed process.  The router does NOT get its state updated here:
        it must discover the death through its own transport failures and
        probes, which is the code path under test."""
        if self.server is not None:
            server, self.server = self.server, None
            server.kill()
        if self.proc is not None:
            proc, self.proc = self.proc, None
            proc.kill()
            proc.wait(timeout=10.0)
        with self._lock:
            pool, self._pool = self._pool, []
        for rb in pool:
            rb.close()


# ---------------------------------------------------------------------------
# Replica supervisor
# ---------------------------------------------------------------------------
class ReplicaSupervisor:
    """Owns the replica set: spawn/boot/adopt, health-probe, drain-stop.

    ``on_unhealthy(name)`` (set by the router) fires on every
    healthy->unhealthy edge so the scheduler can forget affinities that
    point at a pool that no longer exists.
    """

    def __init__(self, replicas: Sequence[ReplicaHandle], *,
                 probe_interval: float = 2.0, probe_timeout: float = 5.0):
        self.replicas: List[ReplicaHandle] = list(replicas)
        names = [r.name for r in self.replicas]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate replica names: {names}")
        self.probe_interval = probe_interval
        self.probe_timeout = probe_timeout
        self.on_unhealthy: Optional[Callable[[str], None]] = None
        self._stop_evt = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- constructors ---------------------------------------------------------
    @classmethod
    def in_process(cls, make_backend: Callable[[int], object], n: int, *,
                   request_timeout: float = 300.0,
                   connect_timeout: float = 5.0, read_timeout: float = 300.0,
                   **kw) -> "ReplicaSupervisor":
        """Boot ``n`` replicas inside this process, each a fresh backend
        behind its own ``InferenceServer`` on an ephemeral port — the unit
        of the router tests/benchmarks (engines share the module-level jit
        cache, so replica 2..n compile nothing new)."""
        from repro.serve.server import InferenceServer
        handles = []
        try:
            for i in range(n):
                server = InferenceServer(make_backend(i), port=0,
                                         request_timeout=request_timeout
                                         ).start()
                handles.append(ReplicaHandle(
                    f"r{i}", server.address, server=server,
                    connect_timeout=connect_timeout,
                    read_timeout=read_timeout))
        except BaseException:
            for h in handles:
                h.stop()
            raise
        return cls(handles, **kw)

    @classmethod
    def spawn(cls, replica_argv: Callable[[int, int], List[str]], n: int, *,
              host: str = "127.0.0.1", python: Optional[str] = None,
              ready_timeout: float = 120.0, connect_timeout: float = 5.0,
              read_timeout: float = 300.0, **kw) -> "ReplicaSupervisor":
        """Spawn ``n`` ``repro-serve`` subprocesses.  ``replica_argv(i,
        port)`` returns the CLI argv for replica ``i`` bound to ``port``
        (it must include ``--port <port>``); each replica is polled on
        ``/v1/manifest`` until it answers or ``ready_timeout`` passes."""
        py = python or sys.executable
        handles: List[ReplicaHandle] = []
        try:
            for i in range(n):
                port = _free_port(host)
                argv = replica_argv(i, port)
                proc = subprocess.Popen([py, "-m", "repro.serve.server",
                                         *argv])
                handles.append(ReplicaHandle(
                    f"r{i}", f"http://{host}:{port}", proc=proc,
                    connect_timeout=connect_timeout,
                    read_timeout=read_timeout))
            deadline = time.monotonic() + ready_timeout
            for h in handles:
                while True:
                    if h.proc is not None and h.proc.poll() is not None:
                        raise RuntimeError(
                            f"replica {h.name} exited with code "
                            f"{h.proc.returncode} before serving")
                    try:
                        _get_json(h.url, "/v1/manifest", timeout=2.0)
                        break
                    except OSError:
                        if time.monotonic() > deadline:
                            raise RuntimeError(
                                f"replica {h.name} at {h.url} not ready "
                                f"within {ready_timeout}s") from None
                        time.sleep(0.2)
        except BaseException:
            for h in handles:
                h.stop()
            raise
        return cls(handles, **kw)

    @classmethod
    def adopt(cls, urls: Sequence[str], *, connect_timeout: float = 5.0,
              read_timeout: float = 300.0, **kw) -> "ReplicaSupervisor":
        """Front already-running replicas (not owned: never stopped)."""
        handles = [ReplicaHandle(f"r{i}", url,
                                 connect_timeout=connect_timeout,
                                 read_timeout=read_timeout)
                   for i, url in enumerate(urls)]
        return cls(handles, **kw)

    # -- lookup ---------------------------------------------------------------
    def replica(self, name: str) -> ReplicaHandle:
        for r in self.replicas:
            if r.name == name:
                return r
        raise KeyError(f"no replica named {name!r}")

    def healthy(self) -> List[ReplicaHandle]:
        """Replicas currently eligible for new work (healthy + accepting)."""
        return [r for r in self.replicas if r.accepting]

    # -- probing --------------------------------------------------------------
    def probe_once(self) -> None:
        for r in self.replicas:
            try:
                h = _get_json(r.url, "/v1/healthz",
                              timeout=self.probe_timeout)
            except OSError:
                if r.probe_failed() and self.on_unhealthy is not None:
                    self.on_unhealthy(r.name)
            else:
                r.probe_ok(h)

    def _probe_loop(self) -> None:
        while not self._stop_evt.wait(self.probe_interval):
            self.probe_once()

    def start(self) -> "ReplicaSupervisor":
        if self._thread is not None and self._thread.is_alive():
            return self
        self._stop_evt.clear()
        self.probe_once()           # seed load/health before first route
        self._thread = threading.Thread(target=self._probe_loop,
                                        name="repro-router-prober",
                                        daemon=True)
        self._thread.start()
        return self

    # -- drain / teardown -----------------------------------------------------
    def drain(self, name: str, *, timeout: float = 30.0,
              stop: bool = True) -> bool:
        """Stop admitting to ``name``, wait for its in-flight proxied
        requests to finish, then (by default) stop it.  Returns True when
        in-flight hit zero inside ``timeout`` — the replica is stopped
        either way once ``stop`` is set (a stuck request has the engine's
        own request_timeout as backstop)."""
        r = self.replica(name)
        r.set_accepting(False)
        deadline = time.monotonic() + timeout
        drained = False
        while time.monotonic() < deadline:
            if r.inflight == 0:
                drained = True
                break
            time.sleep(0.02)
        if stop:
            r.stop()
            if self.on_unhealthy is not None:
                self.on_unhealthy(name)
        return drained

    def stop(self) -> None:
        self._stop_evt.set()
        t = self._thread
        if t is not None:
            t.join(timeout=10.0)
            self._thread = None
        for r in self.replicas:
            r.stop()


# ---------------------------------------------------------------------------
# Prefix-affinity scheduler
# ---------------------------------------------------------------------------
class PrefixAffinityScheduler:
    """Route shared histories to the replica that already holds their KV.

    The router cannot see a replica's ``PrefixIndex``, but it doesn't need
    to: both sides hash (token, age) history through the same chained
    blake2b chunk digests (:func:`repro.serve.prefix.prompt_digests`), so
    remembering *where each full-block digest was last routed* predicts
    residency — a replica that admitted a prompt has indexed exactly those
    chain digests.  ``route`` walks the new prompt's chain from longest
    prefix to shortest and picks the first still-eligible owner; no owner
    falls back to least-loaded (most free blocks from the last probe, then
    fewest in-flight).  The table is LRU-capped, mirroring the replicas'
    own LRU eviction.
    """

    def __init__(self, block_size: int = 16, max_tracked: int = 8192):
        self.block_size = block_size
        self.max_tracked = max_tracked
        self._lock = threading.Lock()
        self._owner: "OrderedDict[bytes, str]" = \
            OrderedDict()                           # guarded-by: _lock
        self._affinity_routed = 0                   # guarded-by: _lock
        self._fallback_routed = 0                   # guarded-by: _lock

    def route(self, tokens, ages,
              candidates: Sequence[ReplicaHandle]
              ) -> Tuple[ReplicaHandle, bool]:
        """Pick a replica for this history from ``candidates`` (all
        currently eligible).  Returns ``(replica, via_affinity)`` and
        records the prompt's chain as owned by the pick."""
        if not candidates:
            raise ReplicaUnavailableError(
                "no healthy replica available to take the request")
        chain, _key = prompt_digests(tokens, ages, self.block_size)
        by_name = {r.name: r for r in candidates}
        with self._lock:
            pick: Optional[ReplicaHandle] = None
            affinity = False
            for i in range(len(chain) - 1, -1, -1):
                owner = self._owner.get(chain[i])
                if owner is not None and owner in by_name:
                    pick = by_name[owner]
                    affinity = True
                    break
            if pick is None:
                pick = self._least_loaded(candidates)
            if affinity:
                self._affinity_routed += 1
            else:
                self._fallback_routed += 1
            for d in chain:
                self._owner[d] = pick.name
                self._owner.move_to_end(d)
            while len(self._owner) > self.max_tracked:
                self._owner.popitem(last=False)
        return pick, affinity

    @staticmethod
    def _least_loaded(candidates: Sequence[ReplicaHandle]) -> ReplicaHandle:
        """Most free pool blocks wins (fresh admissions land where CoW
        headroom is); unknown-pool replicas compare by in-flight only."""
        def load_key(r: ReplicaHandle):
            free = r.free_blocks()
            return (-(free if free is not None else 0), r.inflight)
        return min(candidates, key=load_key)

    def forget(self, name: str) -> int:
        """Drop every affinity pointing at ``name`` (replica died or was
        drained: its resident blocks are gone)."""
        with self._lock:
            dead = [d for d, n in self._owner.items() if n == name]
            for d in dead:
                del self._owner[d]
            return len(dead)

    def stats(self) -> dict:
        with self._lock:
            n = self._affinity_routed + self._fallback_routed
            return {
                "affinity_routed": self._affinity_routed,
                "fallback_routed": self._fallback_routed,
                "affinity_rate": self._affinity_routed / n if n else 0.0,
                "tracked_digests": len(self._owner),
                "block_size": self.block_size,
            }


# ---------------------------------------------------------------------------
# Router HTTP front-end
# ---------------------------------------------------------------------------
class RouterServer:
    """One wire endpoint over N replicas (drop-in for ``InferenceServer``:
    ``Client.connect(router.address)`` works unchanged).

    >>> sup = ReplicaSupervisor.in_process(make_backend, n=2)
    >>> router = RouterServer(sup, port=0).start()
    >>> Client.connect(router.address).generate(tokens=..., ages=...)
    >>> router.stop()
    """

    def __init__(self, supervisor: ReplicaSupervisor,
                 host: str = "127.0.0.1", port: int = 8478, *,
                 block_size: int = 16, quiet: bool = True):
        from http.server import ThreadingHTTPServer

        from repro.serve.server import _Handler  # shared plumbing
        self.supervisor = supervisor
        self.scheduler = PrefixAffinityScheduler(block_size=block_size)
        supervisor.on_unhealthy = self._replica_lost
        self.quiet = quiet
        self._lock = threading.Lock()
        self._pins: Dict[str, str] = {}             # guarded-by: _lock
        self._rid_seq = itertools.count()
        self._rid_tag = uuid.uuid4().hex[:8]
        handler = type("_BoundRouterHandler", (_RouterHandler, _Handler),
                       {"srv": self})
        self.httpd = ThreadingHTTPServer((host, port), handler)
        self.httpd.daemon_threads = True
        self.httpd.block_on_close = False
        self._thread: Optional[threading.Thread] = None

    # -- lifecycle ------------------------------------------------------------
    @property
    def address(self) -> str:
        host, port = self.httpd.server_address[:2]
        return f"http://{host}:{port}"

    def start(self) -> "RouterServer":
        self.supervisor.start()
        self._thread = threading.Thread(target=self.httpd.serve_forever,
                                        name="repro-router-http",
                                        daemon=True)
        self._thread.start()
        return self

    def serve_forever(self) -> None:
        self.supervisor.start()
        try:
            self.httpd.serve_forever()
        finally:
            self.stop()

    def stop(self) -> None:
        self.httpd.shutdown()
        self.supervisor.stop()
        self.httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None

    def __enter__(self) -> "RouterServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def drain_replica(self, name: str, *, timeout: float = 30.0) -> bool:
        """Drain-then-stop one replica and drop its affinities."""
        return self.supervisor.drain(name, timeout=timeout)

    # -- request ids / pins ---------------------------------------------------
    def _new_request_id(self) -> str:
        return f"rt-{self._rid_tag}-{next(self._rid_seq)}"

    def _pin(self, request_id: str, replica: ReplicaHandle) -> None:
        with self._lock:
            self._pins[request_id] = replica.name

    def _unpin(self, request_id: str) -> None:
        with self._lock:
            self._pins.pop(request_id, None)

    def pinned_replica(self, request_id: str) -> Optional[str]:
        with self._lock:
            return self._pins.get(request_id)

    def _replica_lost(self, name: str) -> None:
        """Healthy->unhealthy edge (probe threshold / transport failure /
        drain): affinities to its pool are stale — forget them so new
        traffic re-routes instead of chasing a dead prefix."""
        self.scheduler.forget(name)

    def _note_transport_failure(self, replica: ReplicaHandle) -> None:
        if replica.mark_unhealthy():
            self._replica_lost(replica.name)

    # -- routing core ---------------------------------------------------------
    def _candidates(self, exclude: frozenset) -> List[ReplicaHandle]:
        return [r for r in self.supervisor.healthy()
                if r.name not in exclude]

    def _proxied(self, tokens, ages, call, *, pin_id: Optional[str] = None,
                 retry: bool = True):
        """Route -> acquire -> call -> release, with one retry on a
        *different* healthy replica when the pick fails at the transport
        level (``replica_unavailable``).  Protocol-level ``ApiError``s are
        the replica ANSWERING (a validation failure would fail everywhere)
        and propagate without retry."""
        tried: set = set()
        last: Optional[ReplicaUnavailableError] = None
        attempts = 2 if retry else 1
        for _ in range(attempts):
            cands = self._candidates(frozenset(tried))
            if not cands:
                break
            replica, _aff = self.scheduler.route(tokens, ages, cands)
            tried.add(replica.name)
            if pin_id is not None:
                self._pin(pin_id, replica)
            replica.begin_request()
            ok = False
            rb = None
            try:
                rb = replica.acquire()
                out = call(rb, replica)
                ok = True
                return out
            except ReplicaUnavailableError as e:
                last = e
                self._note_transport_failure(replica)
                continue
            finally:
                if rb is not None:
                    (replica.release if ok else replica.discard)(rb)
                replica.end_request()
                if pin_id is not None and not ok:
                    self._unpin(pin_id)
        raise ReplicaUnavailableError(
            "no healthy replica could serve the request"
            + (f" (last failure: {last.message})" if last is not None
               else ""))

    def _relabel(self, obj, replica: ReplicaHandle):
        """``remote[engine]`` (the proxy hop's label) becomes
        ``router[r0:engine]`` — which replica answered stays visible."""
        inner = obj.backend or ""
        if inner.startswith("remote[") and inner.endswith("]"):
            inner = inner[len("remote["):-1]
        obj.backend = f"router[{replica.name}:{inner}]"
        return obj

    # -- endpoint logic (handler threads call these) -------------------------
    def manifest(self) -> dict:
        # not routed through the scheduler: a manifest GET happens on every
        # client handshake and must not count as a fallback-routed request
        last: Optional[ReplicaUnavailableError] = None
        for replica in self.supervisor.healthy():
            rb = None
            try:
                rb = replica.acquire()
                m = rb.server_manifest
            except ReplicaUnavailableError as e:
                last = e
                if rb is not None:
                    replica.discard(rb)
                self._note_transport_failure(replica)
                continue
            replica.release(rb)
            out = dict(m)
            out["server"] = ROUTER_NAME
            out["backend"] = f"router[{m.get('backend', '?')}]"
            out["router"] = {
                "replicas": {r.name: r.url
                             for r in self.supervisor.replicas},
            }
            return out
        raise ReplicaUnavailableError(
            "no healthy replica could serve the manifest"
            + (f" (last failure: {last.message})" if last is not None
               else ""))

    def healthz(self) -> dict:
        replicas = {r.name: r.snapshot()
                    for r in self.supervisor.replicas}
        healthy = [n for n, s in replicas.items() if s["healthy"]]
        sched = self.scheduler.stats()
        with self._lock:
            pinned = len(self._pins)
        # fleet-wide chunked-prefill counters summed from the replicas'
        # last probes (each replica's full healthz stays available below)
        prefill = {"chunked_prefills": 0, "prefill_chunks": 0,
                   "prefill_in_progress": 0, "suffix_tokens_saved": 0}
        for s in replicas.values():
            eng = (s.get("healthz") or {}).get("engine")
            mem = eng.get("memory") if isinstance(eng, dict) else None
            if isinstance(mem, dict):
                for k in prefill:
                    prefill[k] += int(mem.get(k) or 0)
        return {
            "ok": bool(healthy),
            "backend": "router",
            "protocol_version": WIRE_PROTOCOL_VERSION,
            "router": {
                "server": ROUTER_NAME,
                "replicas": replicas,
                "healthy_replicas": len(healthy),
                "scheduler": sched,
                "pinned_requests": pinned,
                "prefill": prefill,
            },
        }

    def generate(self, req: GenerateRequest) -> TrajectoryResult:
        rid = req.request_id or self._new_request_id()
        req = dc_replace(req, request_id=rid)

        def call(rb, replica):
            return self._relabel(rb.generate(req), replica)
        try:
            res = self._proxied(req.tokens, req.ages, call, pin_id=rid)
        finally:
            self._unpin(rid)
        res.request_id = rid
        return res

    def generate_batch(self, reqs: List[GenerateRequest]
                       ) -> List[TrajectoryResult]:
        if not reqs:
            return []
        pin_ids = [r.request_id for r in reqs if r.request_id is not None]
        first = reqs[0]

        def call(rb, replica):
            for pid in pin_ids:
                self._pin(pid, replica)
            out = rb.generate_batch(reqs)
            return [self._relabel(r, replica) for r in out]
        try:
            results = self._proxied(first.tokens, first.ages, call)
        finally:
            for pid in pin_ids:
                self._unpin(pid)
        for req, res in zip(reqs, results):
            res.request_id = req.request_id
        return results

    def sample_futures(self, req: FuturesRequest) -> FuturesResult:
        # a client-chosen id is a cancellation handle the client may
        # already be using: it pins the request to ONE replica (no retry);
        # a router-assigned id exists only for pinning and is safe to
        # re-route before any response was produced
        client_pinned = req.request_id is not None
        rid = req.request_id or self._new_request_id()
        req = dc_replace(req, request_id=rid)

        def call(rb, replica):
            out = rb.sample_futures(req)
            self._relabel(out, replica)
            self._relabel(out.risk, replica)
            for t in out.trajectories:
                self._relabel(t, replica)
            return out
        try:
            return self._proxied(req.tokens, req.ages, call, pin_id=rid,
                                 retry=not client_pinned)
        finally:
            self._unpin(rid)

    def risk(self, d: dict) -> RiskReport:
        check_protocol(d)
        tokens = d.get("tokens")
        if tokens is None:
            raise InvalidRequestError("missing required field 'tokens'")
        try:
            tokens = [int(t) for t in tokens]
            ages = ([float(a) for a in d["ages"]]
                    if d.get("ages") is not None else None)
            horizon = float(d.get("horizon", 5.0))
            top = int(d.get("top", 10))
        except (ValueError, TypeError) as e:
            raise InvalidRequestError(
                f"malformed risk request field: {e}") from e

        def call(rb, replica):
            return self._relabel(
                rb.risk(tokens, ages, horizon=horizon, top=top), replica)
        return self._proxied(tokens, ages, call)

    def cancel(self, d: dict) -> dict:
        check_protocol(d)
        rid = d.get("request_id") if isinstance(d, dict) else None
        if not rid:
            raise InvalidRequestError("missing required field 'request_id'")
        rid = str(rid)
        pinned = self.pinned_replica(rid)
        if pinned is not None:
            targets = [self.supervisor.replica(pinned)]
        else:
            # unknown pin (already completed, or a pre-router id): fan the
            # cancel out — an engine that never saw the id answers False
            targets = self.supervisor.healthy()
        cancelled = False
        replica_name = None
        for replica in targets:
            if not replica.healthy:
                continue
            rb = None
            try:
                rb = replica.acquire()
                if rb.cancel(rid):
                    cancelled = True
                    replica_name = replica.name
            except ReplicaUnavailableError:
                self._note_transport_failure(replica)
            finally:
                if rb is not None:
                    replica.release(rb)
        return {"protocol_version": WIRE_PROTOCOL_VERSION,
                "request_id": rid, "cancelled": cancelled,
                "replica": replica_name}

    # -- streaming proxy ------------------------------------------------------
    def stream_frames(self, req: GenerateRequest
                      ) -> Iterator[Tuple[str, str]]:
        """Proxy ``/v1/stream``: yields raw SSE ``(event_name, data_json)``
        frames from the routed replica.  ``event`` frames pass through
        verbatim (bit-identical to the direct server); the terminal
        ``done`` frame is rewritten to carry the router backend label and
        the routed request id.  Once frames are flowing the stream is
        PINNED: a replica dying mid-flight terminates with a structured
        ``replica_unavailable`` error frame, never a silent replay on a
        survivor (events already emitted cannot be un-emitted)."""
        rid = req.request_id or self._new_request_id()
        req = dc_replace(req, request_id=rid)
        tried: set = set()
        last: Optional[ReplicaUnavailableError] = None
        for _ in range(2):
            cands = self._candidates(frozenset(tried))
            if not cands:
                break
            replica, _aff = self.scheduler.route(req.tokens, req.ages, cands)
            tried.add(replica.name)
            self._pin(rid, replica)
            replica.begin_request()
            rb = replica.acquire()
            try:
                # dedicated socket (stream=True): the pooled rb connection
                # is untouched, so the backend returns to the pool as soon
                # as the response handle exists
                resp, conn = rb._request("POST", "/v1/stream",
                                         req.to_json(), stream=True)
            except ReplicaUnavailableError as e:
                # the POST itself never reached the replica: nothing was
                # emitted, so re-routing is still safe
                last = e
                replica.discard(rb)
                replica.end_request()
                self._unpin(rid)
                self._note_transport_failure(replica)
                continue
            except BaseException:
                replica.release(rb)
                replica.end_request()
                self._unpin(rid)
                raise
            replica.release(rb)
            return self._forward_sse(resp, conn, replica, rid)
        self._unpin(rid)
        raise ReplicaUnavailableError(
            "no healthy replica could take the stream"
            + (f" (last failure: {last.message})" if last is not None
               else ""))

    def _forward_sse(self, resp, conn, replica: ReplicaHandle, rid: str
                     ) -> Iterator[Tuple[str, str]]:
        try:
            event: Optional[str] = None
            data_lines: List[str] = []
            saw_terminal = False
            try:
                for raw in resp:
                    line = raw.decode("utf-8").rstrip("\r\n")
                    if line.startswith("event:"):
                        event = line[len("event:"):].strip()
                    elif line.startswith("data:"):
                        data_lines.append(line[len("data:"):].strip())
                    elif line == "" and event is not None:
                        data = "\n".join(data_lines)
                        if event == "done":
                            data = self._rewrite_done(data, replica, rid)
                        yield event, data
                        if event in ("done", "error", "cancelled"):
                            saw_terminal = True
                            return
                        event, data_lines = None, []
            except (http.client.HTTPException, OSError) as e:
                saw_terminal = True
                # mark the replica BEFORE yielding: a consumer that closes
                # the generator at the error frame must not skip it
                self._note_transport_failure(replica)
                yield "error", json.dumps(ReplicaUnavailableError(
                    f"replica {replica.name} went away mid-stream: {e}"
                ).to_json())
                return
            if not saw_terminal:
                # clean close without a terminal frame: the replica died
                # between events (its SSE is close-delimited)
                self._note_transport_failure(replica)
                yield "error", json.dumps(ReplicaUnavailableError(
                    f"replica {replica.name} closed the stream without a "
                    f"terminal frame").to_json())
        finally:
            resp.close()
            conn.close()
            replica.end_request()
            self._unpin(rid)

    def _rewrite_done(self, data: str, replica: ReplicaHandle,
                      rid: str) -> str:
        try:
            body = json.loads(data or "null")
            res = TrajectoryResult.from_json(body)
        except (ApiError, ValueError, TypeError):
            return data                     # forward unparseable verbatim
        self._relabel(res, replica)
        res.request_id = rid
        return json.dumps(res.to_json())


# ---------------------------------------------------------------------------
# Handler: reuse the server's plumbing, override only the SSE proxy
# ---------------------------------------------------------------------------
class _RouterHandler:
    """Mixed in before ``serve.server._Handler``: all JSON endpoints reuse
    the handler verbatim (they call same-named ``srv`` methods); only the
    stream path differs — the router forwards raw SSE frames instead of
    re-assembling ``TrajectoryEvent`` objects."""
    server_version = ROUTER_NAME

    def _sse_raw(self, event: str, data: str) -> None:
        self.wfile.write(f"event: {event}\n".encode("utf-8"))
        self.wfile.write(f"data: {data}\n\n".encode("utf-8"))
        self.wfile.flush()

    def _do_stream(self) -> None:
        req = GenerateRequest.from_json(self._read_json())
        frames = self.srv.stream_frames(req)
        # pull the first frame BEFORE committing to SSE, so routing and
        # replica-side validation failures still map to HTTP statuses
        first: Tuple[Tuple[str, str], ...] = ()
        try:
            frame = next(frames)
            first = (frame,)
        except StopIteration:
            pass
        self.send_response(200)
        self.send_header("Content-Type", "text/event-stream")
        self.send_header("Cache-Control", "no-cache")
        self.send_header("Connection", "close")
        self.end_headers()
        self.close_connection = True        # SSE is close-delimited
        try:
            for name, data in itertools.chain(first, frames):
                self._sse_raw(name, data)
        except (BrokenPipeError, ConnectionResetError):
            # client went away: unwind the proxy generator so it unpins
            # and closes the upstream connection
            frames.close()
        except ApiError as e:               # mid-stream: headers are out —
            self._sse_raw("error", json.dumps(e.to_json()))
        except Exception as e:              # noqa: BLE001
            self._sse_raw("error", json.dumps(InternalServerError(
                f"{type(e).__name__}: {e}").to_json()))


# ---------------------------------------------------------------------------
# CLI glue (`repro-serve --replicas N` routes through here)
# ---------------------------------------------------------------------------
def build_router(args) -> RouterServer:
    """Build the router described by the ``repro-serve`` CLI namespace:
    ``--replicas N`` in-process or subprocess replicas (or ``--replica-urls``
    to adopt running ones), fronted on ``--host``/``--port``."""
    block_size = getattr(args, "block_size", 16) or 16
    if getattr(args, "replica_urls", None):
        urls = [u for u in args.replica_urls.split(",") if u]
        sup = ReplicaSupervisor.adopt(
            urls, read_timeout=args.request_timeout)
    elif args.replica_mode == "subprocess":
        base = _replica_argv_base(args)

        def replica_argv(i: int, port: int) -> List[str]:
            return base + ["--host", args.host, "--port", str(port),
                           "--seed", str(args.seed)]
        sup = ReplicaSupervisor.spawn(replica_argv, args.replicas,
                                      host=args.host,
                                      read_timeout=args.request_timeout)
    else:
        make_backend = _shared_params_backend_factory(args)
        sup = ReplicaSupervisor.in_process(
            make_backend, args.replicas,
            request_timeout=args.request_timeout,
            read_timeout=args.request_timeout)
    return RouterServer(sup, args.host, args.port, block_size=block_size,
                        quiet=not getattr(args, "verbose", False))


def _replica_argv_base(args) -> List[str]:
    """Forward the model/engine knobs of the router's CLI namespace to a
    subprocess replica's argv (everything but host/port/seed)."""
    argv: List[str] = []
    if args.artifact:
        argv += ["--artifact", args.artifact]
    else:
        argv += ["--config", args.config]
        if args.reduced:
            argv.append("--reduced")
        argv += ["--backend", args.backend]
    argv += ["--slots", str(args.slots),
             "--max-context", str(args.max_context),
             "--cache", args.cache,
             "--block-size", str(args.block_size),
             "--request-timeout", str(args.request_timeout)]
    if args.blocks is not None:
        argv += ["--blocks", str(args.blocks)]
    if getattr(args, "prefill_chunk_tokens", None) is not None:
        argv += ["--prefill-chunk-tokens", str(args.prefill_chunk_tokens)]
    if args.prefix_cache is True:
        argv.append("--prefix-cache")
    elif args.prefix_cache is False:
        argv.append("--no-prefix-cache")
    return argv


def _shared_params_backend_factory(args) -> Callable[[int], object]:
    """In-process replicas share ONE parameter tree (and the module-level
    jit cache), so N replicas cost N KV pools — not N models."""
    from repro.serve.server import _build_backend
    if args.artifact:
        def make_backend(i: int):
            from repro.api.client import ArtifactBackend
            return ArtifactBackend(args.artifact)
        return make_backend
    first = _build_backend(args)
    from repro.api.client import EngineBackend, LocalBackend
    if isinstance(first, LocalBackend):
        made = [first]

        def make_backend(i: int):
            if made:
                return made.pop()
            return LocalBackend(first.params, first.cfg)
        return make_backend
    assert isinstance(first, EngineBackend)
    params, cfg = first.params, first.cfg
    engine_kw = dict(
        slots=args.slots, max_context=args.max_context, cache=args.cache,
        blocks=args.blocks, block_size=args.block_size,
        request_timeout=args.request_timeout,
        prefix_cache=first.engine.prefix is not None,
        prefill_chunk_tokens=getattr(args, "prefill_chunk_tokens", None))
    made = [first]

    def make_backend(i: int):
        if made:
            return made.pop()
        return EngineBackend.create(params, cfg, **engine_kw)
    return make_backend
