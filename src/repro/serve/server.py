"""HTTP/SSE front-end: the wire-protocol half of the serving story.

A stdlib-only (``http.server``) threaded server that exposes ANY
``repro.api`` backend over the versioned JSON wire protocol of
``repro.api.schemas`` — the cross-process counterpart of the paper's thin
JS SDK talking to an inference surface.  ``repro.api.RemoteBackend`` is the
matching client half; together they make the network a fourth pluggable
backend (``Client.connect(url)``).

Endpoints (all under ``/v1``; schemas are the canonical ``to_json`` forms):

=====================  ======  ===============================================
``/v1/generate``       POST    GenerateRequest -> TrajectoryResult
``/v1/generate_batch`` POST    {"requests": [...]} -> {"results": [...]}
``/v1/risk``           POST    {tokens, ages?, horizon?, top?} -> RiskReport
``/v1/futures``        POST    FuturesRequest -> FuturesResult (N Monte-
                               Carlo futures of one history, aggregated
                               into a RiskReport; engine backends fan out
                               through prefix-shared ``fork`` slots)
``/v1/stream``         POST    GenerateRequest -> SSE: one ``event:`` frame
                               per TrajectoryEvent, then ``done`` carrying
                               the assembled TrajectoryResult (``error``
                               frame on mid-stream failure)
``/v1/manifest``       GET     protocol version, model/termination metadata,
                               endpoint map (+ the FAIR artifact manifest
                               when serving an ArtifactBackend)
``/v1/healthz``        GET     liveness + engine stats
=====================  ======  ===============================================

Error contract: every failure is a ``repro.api.errors.ApiError`` rendered as
``{"error": {"code", "message"}}`` with the taxonomy's 1:1 HTTP status —
validation failures surface with the same stable codes whether the backend
is local or remote.

Concurrency: ``ThreadingHTTPServer`` gives one handler thread per
connection.  An :class:`~repro.api.client.EngineBackend` gets **async
admission** — the engine ticks on its own background thread
(``BatchedEngine.start()``, idle backoff when no slot is active) and handler
threads merely enqueue requests and park on completion hooks, so concurrent
requests continuously batch onto engine slots.  Host-loop backends
(artifact/local) are serialized by a lock.

Run:  ``repro-serve --artifact DIR``  or
      ``repro-serve --config delphi-2m --reduced``
"""
from __future__ import annotations

import argparse
import contextlib
import itertools
import json
import socket
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Iterator, List, Optional, Tuple
from urllib.parse import urlsplit

from repro.api.errors import (ApiError, InternalServerError,
                              InvalidRequestError, RequestCancelledError,
                              UnknownEndpointError)
from repro.api.schemas import (WIRE_PROTOCOL_VERSION, FuturesRequest,
                               FuturesResult, GenerateRequest,
                               TrajectoryEvent, TrajectoryResult,
                               check_protocol)

SERVER_NAME = "repro-serve/0.1"

_ENDPOINTS = {
    "generate": {"method": "POST", "path": "/v1/generate"},
    "generate_batch": {"method": "POST", "path": "/v1/generate_batch"},
    "risk": {"method": "POST", "path": "/v1/risk"},
    "futures": {"method": "POST", "path": "/v1/futures"},
    "stream": {"method": "POST", "path": "/v1/stream", "content": "sse"},
    "cancel": {"method": "POST", "path": "/v1/cancel"},
    "manifest": {"method": "GET", "path": "/v1/manifest"},
    "healthz": {"method": "GET", "path": "/v1/healthz"},
}


class _TrackingHTTPServer(ThreadingHTTPServer):
    """ThreadingHTTPServer that remembers its accepted sockets so
    :meth:`sever_connections` can cut every live connection (keep-alive and
    mid-SSE included) — ``shutdown()`` only stops NEW accepts, which makes
    a graceful stop but not a crash.  The router's failover tests use this
    to simulate an in-process replica dying mid-stream."""

    def __init__(self, *a, **kw):
        self._conns: set = set()
        self._conns_lock = threading.Lock()
        super().__init__(*a, **kw)

    def process_request(self, request, client_address):
        with self._conns_lock:
            self._conns.add(request)
        super().process_request(request, client_address)

    def shutdown_request(self, request):
        with self._conns_lock:
            self._conns.discard(request)
        super().shutdown_request(request)

    def sever_connections(self) -> int:
        with self._conns_lock:
            conns = list(self._conns)
        for s in conns:
            try:
                s.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass                        # already gone
        return len(conns)


class InferenceServer:
    """Threaded HTTP wrapper around one ``repro.api`` backend.

    >>> server = InferenceServer(ArtifactBackend(d), port=0)   # 0 = ephemeral
    >>> server.start()
    >>> Client.connect(server.address).generate(tokens=..., ages=...)
    >>> server.stop()
    """

    def __init__(self, backend, host: str = "127.0.0.1", port: int = 8478,
                 *, request_timeout: float = 300.0, quiet: bool = True):
        from repro.api.client import EngineBackend
        self.backend = backend
        self.quiet = quiet
        self._is_engine = isinstance(backend, EngineBackend)
        if self._is_engine:
            backend.request_timeout = request_timeout
        # host-loop backends run the model on the handler thread: serialize
        # them (the engine serializes on its own tick thread instead)
        self._serial = threading.Lock()
        handler = type("_BoundHandler", (_Handler,), {"srv": self})
        self.httpd = _TrackingHTTPServer((host, port), handler)
        self.httpd.daemon_threads = True
        # never join handler threads on close: a stalled client (open
        # connection, unread SSE) would park stop() forever
        self.httpd.block_on_close = False
        self._thread: Optional[threading.Thread] = None

    # -- lifecycle -----------------------------------------------------------
    @property
    def address(self) -> str:
        host, port = self.httpd.server_address[:2]
        return f"http://{host}:{port}"

    def start(self) -> "InferenceServer":
        """Serve on a daemon thread (embedding / tests); returns self."""
        if self._is_engine:
            self.backend.engine.start()
        self._thread = threading.Thread(target=self.httpd.serve_forever,
                                        name="repro-serve-http", daemon=True)
        self._thread.start()
        return self

    def serve_forever(self) -> None:
        """Serve on the calling thread (the CLI entry point)."""
        if self._is_engine:
            self.backend.engine.start()
        try:
            self.httpd.serve_forever()
        finally:
            self.stop()

    def stop(self) -> None:
        self.httpd.shutdown()
        # engine first: in-flight waiters parked in handler threads get
        # their immediate failure before the listener is torn down
        if self._is_engine:
            self.backend.engine.stop()
        self.httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None

    def kill(self) -> None:
        """Crash simulation (in-process replica failover tests): sever every
        live connection FIRST — an open SSE response dies without a terminal
        frame, keep-alive sockets reset — then tear down like :meth:`stop`.
        A graceful stop would let handler threads flush structured error
        frames, which a crashed process never does."""
        self.httpd.sever_connections()
        self.stop()

    def __enter__(self) -> "InferenceServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- endpoint logic (handler threads call these) -------------------------
    def _exclusive(self):
        """Model-executing section for host-loop backends; no-op for the
        engine, whose tick thread is the serialization point."""
        if self._is_engine:
            return contextlib.nullcontext()
        return self._serial

    def manifest(self) -> dict:
        b = self.backend
        m = {
            "protocol_version": WIRE_PROTOCOL_VERSION,
            "server": SERVER_NAME,
            "backend": b.name,
            "model": {
                "seq_len": int(b.seq_len),
                "vocab_size": int(b.vocab_size),
                "has_ages": bool(b.has_ages),
                "max_age": float(b.max_age),
                "death_token": int(b.death_token),
            },
            "endpoints": _ENDPOINTS,
        }
        runtime = getattr(b, "runtime", None)       # FAIR provenance pass-
        if runtime is not None:                     # through for artifacts
            m["artifact"] = runtime.manifest
        return m

    def healthz(self) -> dict:
        h = {"ok": True, "backend": self.backend.name,
             "protocol_version": WIRE_PROTOCOL_VERSION}
        if self._is_engine:
            # one locked snapshot from the engine rather than poking its
            # guarded fields from this handler thread (RL001)
            h["engine"] = self.backend.engine.health_stats()
        return h

    def cancel(self, d: dict) -> dict:
        check_protocol(d)
        rid = d.get("request_id") if isinstance(d, dict) else None
        if not rid:
            raise InvalidRequestError("missing required field 'request_id'")
        return {"protocol_version": WIRE_PROTOCOL_VERSION,
                "request_id": str(rid),
                "cancelled": bool(self.backend.cancel(str(rid)))}

    def generate(self, req: GenerateRequest) -> TrajectoryResult:
        with self._exclusive():
            return self.backend.generate(req)

    def generate_batch(self, reqs: List[GenerateRequest]
                       ) -> List[TrajectoryResult]:
        with self._exclusive():
            return self.backend.generate_batch(reqs)

    def sample_futures(self, req: FuturesRequest) -> FuturesResult:
        with self._exclusive():
            return self.backend.sample_futures(req)

    def risk(self, d: dict):
        check_protocol(d)
        tokens = d.get("tokens")
        if tokens is None:
            raise InvalidRequestError("missing required field 'tokens'")
        try:
            tokens = [int(t) for t in tokens]
            ages = ([float(a) for a in d["ages"]]
                    if d.get("ages") is not None else None)
            horizon = float(d.get("horizon", 5.0))
            top = int(d.get("top", 10))
        except (ValueError, TypeError) as e:
            raise InvalidRequestError(
                f"malformed risk request field: {e}") from e
        with self._serial:        # logits run on the handler thread for
            return self.backend.risk(   # every backend, engine included
                tokens, ages, horizon=horizon, top=top)

    def stream(self, req: GenerateRequest) -> Iterator[TrajectoryEvent]:
        it = self.backend.stream(req)
        lock = None if self._is_engine else self._serial
        while True:
            # hold the lock only across the model step that produces the
            # next event, never across the socket write the caller does
            # with it — a stalled SSE consumer must not block the server
            if lock is not None:
                with lock:
                    ev = next(it, None)
            else:
                ev = next(it, None)
            if ev is None:
                return
            yield ev


class _Handler(BaseHTTPRequestHandler):
    """HTTP/1.1 with keep-alive: JSON responses carry ``Content-Length`` so
    one connection serves many sequential requests (``RemoteBackend`` holds
    a persistent connection per backend — the req/s lever
    ``benchmarks/run.py http`` measures).  SSE responses are the exception:
    they are close-delimited (no chunked encoding on the stdlib server), so
    ``/v1/stream`` sends ``Connection: close`` and drops the connection."""
    server_version = SERVER_NAME
    protocol_version = "HTTP/1.1"
    srv: InferenceServer            # bound by InferenceServer.__init__

    # -- plumbing ------------------------------------------------------------
    def log_message(self, fmt, *args):
        if not self.srv.quiet:
            BaseHTTPRequestHandler.log_message(self, fmt, *args)

    def _send_json(self, obj: dict, status: int = 200) -> None:
        self._drain_body()
        body = json.dumps(obj).encode("utf-8")
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _send_api_error(self, err: ApiError) -> None:
        self._send_json(err.to_json(), err.http_status)

    def _read_json(self) -> dict:
        n = int(self.headers.get("Content-Length") or 0)
        raw = self.rfile.read(n) if n else b""
        self._body_read = True
        try:
            return json.loads(raw.decode("utf-8") or "null")
        except (json.JSONDecodeError, UnicodeDecodeError) as e:
            raise InvalidRequestError(f"request body is not valid JSON: {e}")

    def _drain_body(self) -> None:
        """Consume an unread request body before writing a response: with
        keep-alive, leftover body bytes would be parsed as the NEXT request
        line, desyncing the connection for the following (valid) call."""
        if getattr(self, "_body_read", False):
            return
        n = int(self.headers.get("Content-Length") or 0)
        if n:
            self.rfile.read(n)
        self._body_read = True

    def _sse(self, event: str, obj: dict) -> None:
        self.wfile.write(f"event: {event}\n".encode("utf-8"))
        self.wfile.write(f"data: {json.dumps(obj)}\n\n".encode("utf-8"))
        self.wfile.flush()

    # -- routes --------------------------------------------------------------
    def do_GET(self):          # noqa: N802 (stdlib handler naming)
        self._body_read = False        # handler instance spans keep-alive
        path = urlsplit(self.path).path
        try:
            if path == "/v1/healthz":
                self._send_json(self.srv.healthz())
            elif path == "/v1/manifest":
                self._send_json(self.srv.manifest())
            else:
                raise UnknownEndpointError(f"no such endpoint: GET {path}")
        except ApiError as e:
            self._send_api_error(e)
        except (BrokenPipeError, ConnectionResetError):
            pass
        except Exception as e:                      # noqa: BLE001
            self._send_api_error(InternalServerError(
                f"{type(e).__name__}: {e}"))

    def do_POST(self):         # noqa: N802
        self._body_read = False        # handler instance spans keep-alive
        path = urlsplit(self.path).path
        try:
            if path == "/v1/generate":
                req = GenerateRequest.from_json(self._read_json())
                self._send_json(self.srv.generate(req).to_json())
            elif path == "/v1/generate_batch":
                body = self._read_json()
                if not isinstance(body, dict) or "requests" not in body:
                    raise InvalidRequestError(
                        "generate_batch body must be "
                        "{\"requests\": [GenerateRequest, ...]}")
                check_protocol(body)
                reqs = [GenerateRequest.from_json(r)
                        for r in body["requests"]]
                results = self.srv.generate_batch(reqs)
                self._send_json({
                    "protocol_version": WIRE_PROTOCOL_VERSION,
                    "results": [r.to_json() for r in results]})
            elif path == "/v1/risk":
                body = self._read_json()
                if not isinstance(body, dict):
                    raise InvalidRequestError(
                        "risk body must be a JSON object")
                self._send_json(self.srv.risk(body).to_json())
            elif path == "/v1/futures":
                req = FuturesRequest.from_json(self._read_json())
                self._send_json(self.srv.sample_futures(req).to_json())
            elif path == "/v1/cancel":
                body = self._read_json()
                if not isinstance(body, dict):
                    raise InvalidRequestError(
                        "cancel body must be {\"request_id\": ...}")
                self._send_json(self.srv.cancel(body))
            elif path == "/v1/stream":
                self._do_stream()
            else:
                raise UnknownEndpointError(f"no such endpoint: POST {path}")
        except ApiError as e:
            self._send_api_error(e)
        except (BrokenPipeError, ConnectionResetError):
            pass
        except Exception as e:                      # noqa: BLE001
            self._send_api_error(InternalServerError(
                f"{type(e).__name__}: {e}"))

    def _do_stream(self) -> None:
        req = GenerateRequest.from_json(self._read_json())
        it = self.srv.stream(req)
        # pull the first event BEFORE committing to SSE, so validation
        # failures still map to proper HTTP statuses + JSON bodies
        first: Tuple[TrajectoryEvent, ...] = ()
        try:
            ev = next(it)
            first = (ev,)
        except StopIteration:
            pass
        self.send_response(200)
        self.send_header("Content-Type", "text/event-stream")
        self.send_header("Cache-Control", "no-cache")
        self.send_header("Connection", "close")
        self.end_headers()
        self.close_connection = True        # SSE is close-delimited
        events: List[TrajectoryEvent] = []
        try:
            # chain lazily: a starred tuple here would drain the WHOLE
            # generator before the first frame is written, turning SSE into
            # a buffered-at-completion response (and making mid-stream
            # cancellation unobservable)
            for ev in itertools.chain(first, it):
                events.append(ev)
                self._sse("event", ev.to_json())
            result = self.srv.backend._result(req, events)
            self._sse("done", result.to_json())
        except (BrokenPipeError, ConnectionResetError):
            pass                                    # client went away
        except RequestCancelledError as e:          # /v1/cancel mid-stream:
            self._sse("cancelled", e.to_json())     # terminal frame
        except ApiError as e:                       # mid-stream: headers are
            self._sse("error", e.to_json())         # out — error as a frame
        except Exception as e:                      # noqa: BLE001
            self._sse("error", InternalServerError(
                f"{type(e).__name__}: {e}").to_json())


# ---------------------------------------------------------------------------
# CLI: the `repro-serve` console script
# ---------------------------------------------------------------------------
def _build_backend(args):
    if args.artifact:
        from repro.api.client import ArtifactBackend
        return ArtifactBackend(args.artifact)
    if not args.config:
        raise SystemExit("repro-serve: pass --artifact DIR or --config NAME")
    import jax
    from repro.api.client import EngineBackend, LocalBackend
    from repro.configs import get_config
    from repro.models import init_params
    cfg = get_config(args.config, reduced=args.reduced).replace(
        dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    if args.backend == "local":
        return LocalBackend(params, cfg)
    # the prefix cache rides the paged pool: default it on there, refuse a
    # ring engine asked for it explicitly (no shareable blocks to index)
    prefix_cache = (args.cache == "paged" if args.prefix_cache is None
                    else args.prefix_cache)
    if prefix_cache and args.cache != "paged":
        raise SystemExit("repro-serve: --prefix-cache requires --cache "
                         "paged (the ring layout has no shareable blocks)")
    if args.prefill_chunk_tokens is not None and args.cache != "paged":
        raise SystemExit("repro-serve: --prefill-chunk-tokens requires "
                         "--cache paged (chunked prefill writes through the "
                         "block table)")
    backend = EngineBackend.create(
        params, cfg, slots=args.slots, max_context=args.max_context,
        cache=args.cache, blocks=args.blocks, block_size=args.block_size,
        request_timeout=args.request_timeout, prefix_cache=prefix_cache,
        prefill_chunk_tokens=args.prefill_chunk_tokens)
    # echo the effective memory budget: the sizing knobs' consequence
    eng = backend.engine
    mem = eng.pool_stats()
    budget = (f"{mem['blocks']} x {args.block_size}-token blocks "
              f"(pool, {eng.slots} slots admitted by free-block budget)"
              if eng.paged else
              f"{eng.slots} slots x {eng.max_context} dense ring")
    chunk = (f"chunked prefill {args.prefill_chunk_tokens} tok/tick"
             if args.prefill_chunk_tokens else "monolithic prefill")
    print(f"repro-serve: engine KV cache [{args.cache}] = "
          f"{mem['cache_bytes'] / 1e6:.1f} MB — {budget}; "
          f"prefix cache {'on' if prefix_cache else 'off'}; {chunk}; "
          f"request timeout {args.request_timeout:.0f}s")
    return backend


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-serve",
        description="Serve a repro.api backend over the v%s JSON/SSE wire "
                    "protocol" % WIRE_PROTOCOL_VERSION)
    src = ap.add_argument_group("model source (one required)")
    src.add_argument("--artifact", metavar="DIR",
                     help="exported FAIR artifact directory (ArtifactBackend)")
    src.add_argument("--config", metavar="NAME",
                     help="config name, e.g. delphi-2m: fresh params served "
                          "via --backend")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced layer/width preset for --config")
    ap.add_argument("--backend", choices=("engine", "local"),
                    default="engine",
                    help="--config mode: continuous-batching engine "
                         "(default) or in-process local backend")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8478,
                    help="0 picks an ephemeral port")
    ap.add_argument("--slots", type=int, default=8,
                    help="decode batch width (max concurrent requests)")
    ap.add_argument("--max-context", type=int, default=512,
                    help="per-request KV context (ring width / table span)")
    ap.add_argument("--cache", choices=("ring", "paged"), default="ring",
                    help="KV layout: dense per-slot ring, or a shared "
                         "block pool with free-block admission + preemption")
    ap.add_argument("--blocks", type=int, default=None,
                    help="--cache paged: pool size in blocks "
                         "(default: dense-equivalent slots*context/size + 1)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="--cache paged: tokens per block")
    ap.add_argument("--prefix-cache", dest="prefix_cache",
                    action="store_true", default=None,
                    help="index admitted prompts' KV blocks so identical "
                         "history prefixes admit by reference (default on "
                         "with --cache paged)")
    ap.add_argument("--no-prefix-cache", dest="prefix_cache",
                    action="store_false",
                    help="disable the prefix index")
    ap.add_argument("--prefill-chunk-tokens", type=int, default=None,
                    metavar="N",
                    help="--cache paged: prefill prompts in N-token chunks "
                         "interleaved with decode ticks instead of one "
                         "monolithic pass (N must be a multiple of "
                         "--block-size; bit-identical outputs either way)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--request-timeout", type=float, default=300.0,
                    help="seconds before an in-flight request is expired "
                         "and its slot/blocks reclaimed")
    scale = ap.add_argument_group("scaling out (repro.serve.router)")
    scale.add_argument("--replicas", type=int, default=1,
                       help="N > 1 fronts N engine replicas with the "
                            "prefix-affinity router instead of serving one "
                            "backend directly")
    scale.add_argument("--replica-mode", choices=("inprocess", "subprocess"),
                       default="inprocess",
                       help="--replicas placement: engines in this process "
                            "(shared params + jit cache) or one repro-serve "
                            "subprocess per replica")
    scale.add_argument("--replica-urls", metavar="URL[,URL...]", default=None,
                       help="route over already-running repro-serve "
                            "replicas instead of starting any")
    ap.add_argument("--verbose", action="store_true",
                    help="log one line per HTTP request")
    args = ap.parse_args(argv)

    if args.replicas > 1 or args.replica_urls:
        from repro.serve.router import ROUTER_NAME, build_router
        router = build_router(args)
        n = len(router.supervisor.replicas)
        print(f"repro-serve: {ROUTER_NAME} over {n} replicas on "
              f"{router.address} (wire protocol v{WIRE_PROTOCOL_VERSION})")
        for r in router.supervisor.replicas:
            print(f"  replica {r.name}: {r.url}")
        for name, ep in _ENDPOINTS.items():
            print(f"  {ep['method']:4s} {ep['path']}")
        try:
            router.serve_forever()
        except KeyboardInterrupt:
            print("repro-serve: shutting down")
        return 0

    backend = _build_backend(args)
    server = InferenceServer(backend, args.host, args.port,
                             request_timeout=args.request_timeout,
                             quiet=not args.verbose)
    print(f"repro-serve: {backend.name} backend on {server.address} "
          f"(wire protocol v{WIRE_PROTOCOL_VERSION})")
    for name, ep in _ENDPOINTS.items():
        print(f"  {ep['method']:4s} {ep['path']}")
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        print("repro-serve: shutting down")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
