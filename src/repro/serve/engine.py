"""Batched serving engine: prefill + decode with slot-based continuous batching.

The privacy story of the paper means the *client* runs inference; this engine
is the server-side counterpart used for (a) the e2e batched-serving example
mandated for a serving-kind paper, and (b) the decode-path functions whose
lowered forms the decode dry-run shapes measure.

Design: a fixed number of slots (the decode batch).  All slots step together
(one jitted ``decode_step`` per tick — SPMD-friendly); finished slots are
refilled from a pending queue via a jitted cache insertion
(``dynamic_update_index_in_dim`` on the batch axis of the cache pytree).
Delphi-type models sample with the competing-exponential mechanism; generic
LMs sample from the categorical.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.sampler import sample_next_event
from repro.models import decode_step, forward, make_decode_cache


@dataclasses.dataclass
class Request:
    tokens: np.ndarray                  # (S,) prompt
    ages: Optional[np.ndarray] = None   # (S,) for Delphi-style models
    max_new: int = 64
    # filled by the engine:
    out_tokens: Optional[List[int]] = None
    out_ages: Optional[List[float]] = None
    done: bool = False


class BatchedEngine:
    """Slot-based continuous batching over a jitted decode step."""

    def __init__(self, params, cfg: ModelConfig, *, slots: int = 8,
                 max_context: int = 512, temperature: float = 1.0,
                 seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_context = max_context
        self.temperature = temperature
        self.is_delphi = cfg.age_encoding
        self.rng = jax.random.PRNGKey(seed)

        self.cache = make_decode_cache(params, cfg, slots, max_context)
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.slot_step = np.zeros(slots, np.int64)       # abs position per slot
        self.slot_age = np.zeros(slots, np.float64)
        self.slot_last = np.zeros(slots, np.int32)       # last emitted token
        self.pending: List[Request] = []
        self.completed: List[Request] = []
        self._build_jits()

    # -- jitted primitives -------------------------------------------------
    def _build_jits(self):
        cfg = self.cfg

        @jax.jit
        def _prefill(params, tokens, ages):
            batch = {"tokens": tokens}
            if cfg.age_encoding:
                batch["ages"] = ages
            out = forward(params, cfg, batch, mode="prefill",
                          cache_width=self.max_context)
            return out["cache"], out["logits"][:, -1]

        @jax.jit
        def _step(params, cache, tokens, ages, steps):
            # per-slot absolute steps differ: vmap the single-slot decode
            def one(c, t, a, s):
                c = jax.tree_util.tree_map(lambda x: x[:, None], c)
                b = {"tokens": t[None]}
                if cfg.age_encoding:
                    b["ages"] = a[None]
                d = decode_step(params, cfg, c, b, s)
                nc = jax.tree_util.tree_map(lambda x: jnp.squeeze(x, 1),
                                            d["cache"])
                return nc, d["logits"][0, 0]
            caches, logits = jax.vmap(
                one, in_axes=(_batch_axes(cache), 0, 0, 0),
                out_axes=(_batch_axes(cache), 0))(cache, tokens, ages, steps)
            return caches, logits

        @functools.partial(jax.jit, donate_argnums=(0,))
        def _insert(cache, slot_cache, slot):
            return jax.tree_util.tree_map(
                lambda buf, new: _insert_slot(buf, new, slot), cache, slot_cache)

        self._prefill = _prefill
        self._step = _step
        self._insert = _insert

    # -- public API ---------------------------------------------------------
    def submit(self, req: Request):
        req.out_tokens, req.out_ages = [], []
        self.pending.append(req)

    def _admit(self):
        for slot in range(self.slots):
            if self.slot_req[slot] is None and self.pending:
                req = self.pending.pop(0)
                S = len(req.tokens)
                tokens = jnp.asarray(req.tokens, jnp.int32)[None]
                ages = (jnp.asarray(req.ages, jnp.float32)[None]
                        if req.ages is not None else jnp.zeros((1, S), jnp.float32))
                slot_cache, last_logits = self._prefill(self.params, tokens, ages)
                # drop the leading batch dim of 1, insert at `slot`
                slot_cache = _strip_batch_one(slot_cache)
                self.cache = self._insert(self.cache, slot_cache, slot)
                self.slot_req[slot] = req
                self.slot_step[slot] = S
                self.slot_age[slot] = float(req.ages[-1]) if req.ages is not None else 0.0
                # sample the first token from the prefill logits
                self._emit(slot, np.asarray(last_logits[0]))

    def _emit(self, slot: int, logits: np.ndarray):
        req = self.slot_req[slot]
        cfg = self.cfg
        self.rng, k = jax.random.split(self.rng)
        if self.is_delphi:
            u = np.asarray(jax.random.uniform(k, (cfg.vocab_size,)))
            evt, tmin = sample_next_event(jnp.asarray(logits), jnp.asarray(u))
            evt, tmin = int(evt), float(tmin)
            self.slot_age[slot] += tmin
            done = (evt == cfg.death_token or self.slot_age[slot] > cfg.max_age
                    or len(req.out_tokens) + 1 >= req.max_new)
            req.out_tokens.append(evt)
            req.out_ages.append(self.slot_age[slot])
        else:
            lg = logits / max(self.temperature, 1e-6)
            evt = int(jax.random.categorical(k, jnp.asarray(lg)))
            done = len(req.out_tokens) + 1 >= req.max_new
            req.out_tokens.append(evt)
        self.slot_last[slot] = evt
        if done or self.slot_step[slot] + 1 >= self.max_context:
            req.done = True
            self.completed.append(req)
            self.slot_req[slot] = None

    def step(self):
        """One engine tick: admit pending, decode all active slots, sample."""
        self._admit()
        active = [i for i in range(self.slots) if self.slot_req[i] is not None]
        if not active:
            return False
        tokens = jnp.asarray(self.slot_last[:, None], jnp.int32)
        ages = jnp.asarray(self.slot_age[:, None], jnp.float32)
        steps = jnp.asarray(self.slot_step, jnp.int32)
        self.cache, logits = self._step(self.params, self.cache, tokens, ages, steps)
        logits = np.asarray(logits)
        for slot in active:
            self.slot_step[slot] += 1
            self._emit(slot, logits[slot])
        return True

    def run(self, max_ticks: int = 10_000) -> List[Request]:
        ticks = 0
        while (self.pending or any(r is not None for r in self.slot_req)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.completed


# -- tree helpers ------------------------------------------------------------
def _batch_axes(cache):
    """vmap in_axes pytree: batch axis position per cache leaf.

    Cache leaves are stacked (L, B, ...) so the batch axis is 1."""
    return jax.tree_util.tree_map(lambda _: 1, cache)



def _strip_batch_one(cache):
    """(L, 1, ...) -> (L, ...) for insertion along the slot axis."""
    return jax.tree_util.tree_map(lambda a: jnp.squeeze(a, axis=1), cache)


def _insert_slot(buf, new, slot):
    """buf (L, B, ...), new (L, ...) -> write at batch index `slot`."""
    return jax.lax.dynamic_update_index_in_dim(buf, new.astype(buf.dtype),
                                               slot, 1)
