"""Batched serving engine: device-resident continuous batching.

The privacy story of the paper means the *client* runs inference; this engine
is the server-side counterpart used for (a) the e2e batched-serving example
mandated for a serving-kind paper, and (b) the throughput benchmark behind
the paper's claim that eq. 1 trajectory generation is fast enough for
interactive risk prediction.

Design — one jitted ``decode_and_sample`` step per engine tick:

* the batched ``decode_step`` runs across **all slots at once** with per-slot
  absolute positions (vector ``step`` plumbing in ``repro.models``), instead
  of a ``vmap`` of single-slot decodes;
* eq. 1 competing-exponential sampling happens **in-graph** right after the
  logits head — ``sample_next_event`` (jnp reference, default) or the fused
  Pallas kernel ``repro.kernels.tte_sample`` (``sampler="pallas"``).  Generic
  LMs sample the Gumbel-max categorical from the same uniforms;
* per-slot age / step / emitted-count / active state advances as device
  arrays inside the tick (``advance_trajectory_state`` — the same censoring
  semantics as the SDK: an event past ``max_age`` terminates BEFORE being
  emitted); the host sees exactly ONE packed (4, slots) transfer per tick;
* admissions run a **bucketed-padding batched prefill**: prompt lengths are
  right-padded to power-of-two buckets and admission groups to power-of-two
  batch buckets, so a request stream compiles a small fixed set of
  (batch, seq) shapes instead of one jit per prompt length.  Padded cache
  positions are invalidated (``pos = -1``) so decode never attends garbage;
  bootstrap logits are gathered at each prompt's true last token
  (``forward(..., last_index=...)``).

``ReferenceEngine`` below preserves the original host-loop engine (per-slot
vmap decode + host-side Python sampling) as the before/after benchmark
baseline — ``benchmarks/run.py serve`` reports both.
"""
from __future__ import annotations

import dataclasses
import functools
import threading
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as cb
from repro.configs.base import ModelConfig
from repro.core.sampler import advance_trajectory_state, sample_next_event
from repro.kernels import tte_sample
from repro.models import (decode_step, forward, make_decode_cache,
                          mask_padded_positions)

# Module-level so tests can monkeypatch/count device->host transfers: this is
# the ONLY way the engine moves data off-device.
_to_host = np.asarray


@dataclasses.dataclass
class Request:
    tokens: np.ndarray                  # (S,) prompt
    ages: Optional[np.ndarray] = None   # (S,) for Delphi-style models
    max_new: int = 64
    # optional pre-drawn U(0,1) of shape (max_new, V): injected for
    # SDK/engine bit-parity tests (claims C2/C3).  Row i is consumed by the
    # i-th sampled event (row 0 at admission, from the prefill logits).
    uniforms: Optional[np.ndarray] = None
    # streaming hooks (repro.api.EngineBackend.stream): invoked on the host
    # side of the tick sync — on_event(token, age_or_None) per emitted event,
    # on_done(request) once at termination
    on_event: Optional[Callable[[int, Optional[float]], None]] = None
    on_done: Optional[Callable[["Request"], None]] = None
    # filled by the engine:
    out_tokens: Optional[List[int]] = None
    out_ages: Optional[List[float]] = None
    done: bool = False
    # set (before on_done fires) if the engine loop failed this request —
    # waiters must check it rather than trusting out_tokens
    error: Optional[BaseException] = None


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 1).bit_length() if n > 1 else 1


class _Knobs(NamedTuple):
    """Hashable static engine parameters for the shared module-level jits.

    The jitted tick/prefill functions live at module level with
    ``(cfg, knobs)`` as static arguments, so every engine instance with the
    same configuration shares ONE compiled executable per shape — a second
    engine (or a restarted serving process within one interpreter) pays no
    recompilation."""
    slots: int
    max_context: int
    is_delphi: bool
    use_pallas: bool
    inv_temp: float
    max_age: float
    death_token: int
    vocab: int


def _sample_evt(lg, u, kn: _Knobs):
    """(B, V) logits + uniforms -> (event (B,), waiting time (B,))."""
    if kn.is_delphi:
        if kn.use_pallas:
            return tte_sample(lg, u)
        return sample_next_event(lg, u)
    g = -jnp.log(-jnp.log(jnp.clip(u, 1e-12, 1.0 - 1e-12)))
    evt = jnp.argmax(lg * kn.inv_temp + g, axis=-1).astype(jnp.int32)
    return evt, jnp.zeros(evt.shape, jnp.float32)


def _advance(lg, u, age, n_emitted, max_new, next_pos, active, kn: _Knobs):
    evt, tmin = _sample_evt(lg, u, kn)
    return advance_trajectory_state(
        evt, tmin, age, n_emitted, max_new, next_pos, active,
        max_age=kn.max_age if kn.is_delphi else np.inf,
        death_token=kn.death_token if kn.is_delphi else -1,
        max_context=kn.max_context)


def _pack(adv):
    return jnp.stack([adv["evt"].astype(jnp.float32), adv["age"],
                      adv["emit"].astype(jnp.float32),
                      adv["finished"].astype(jnp.float32)])


def _tick_core(params, cache, state, u, cfg: ModelConfig, kn: _Knobs):
    batch = {"tokens": state["last"][:, None]}
    if cfg.age_encoding:
        batch["ages"] = state["age"][:, None]
    d = decode_step(params, cfg, cache, batch, state["step"])
    lg = d["logits"][:, 0].astype(jnp.float32)
    next_step = jnp.where(state["active"], state["step"] + 1, state["step"])
    adv = _advance(lg, u, state["age"], state["n_emitted"], state["max_new"],
                   next_step, state["active"], kn)
    new_state = {
        "last": jnp.where(adv["emit"], adv["evt"], state["last"]),
        "age": adv["age"],
        "step": next_step,
        "n_emitted": adv["n_emitted"],
        "max_new": state["max_new"],
        "active": state["active"] & ~adv["finished"],
    }
    return d["cache"], new_state, _pack(adv)


@functools.partial(jax.jit, static_argnames=("cfg", "kn"),
                   donate_argnums=(1, 2))
def _tick_u_jit(params, cache, state, u, *, cfg, kn):
    return _tick_core(params, cache, state, u, cfg, kn)


@functools.partial(jax.jit, static_argnames=("cfg", "kn"),
                   donate_argnums=(1, 2))
def _tick_rng_jit(params, cache, state, key, *, cfg, kn):
    key, sub = jax.random.split(key)
    u = jax.random.uniform(sub, (kn.slots, kn.vocab))
    cache, state, packed = _tick_core(params, cache, state, u, cfg, kn)
    return cache, state, packed, key


def _prefill_core(params, tokens, ages, last_idx, age0, lengths, max_new, u,
                  cfg: ModelConfig, kn: _Knobs):
    batch: Dict[str, Any] = {"tokens": tokens}
    if cfg.age_encoding:
        batch["ages"] = ages
    out = forward(params, cfg, batch, mode="prefill",
                  cache_width=kn.max_context, last_index=last_idx)
    cache_rows = mask_padded_positions(out["cache"], last_idx)
    lg = out["logits"][:, 0].astype(jnp.float32)
    nb = tokens.shape[0]
    active = jnp.ones((nb,), bool)
    adv = _advance(lg, u, age0, jnp.zeros((nb,), jnp.int32), max_new,
                   lengths, active, kn)
    rows = {
        "last": jnp.where(adv["emit"], adv["evt"], 0),
        "age": adv["age"],
        "step": lengths,
        "n_emitted": adv["n_emitted"],
        "max_new": max_new,
        "active": active & ~adv["finished"],
    }
    return cache_rows, rows, _pack(adv)


@functools.partial(jax.jit, static_argnames=("cfg", "kn"))
def _prefill_u_jit(params, tokens, ages, last_idx, age0, lengths, max_new, u,
                   *, cfg, kn):
    return _prefill_core(params, tokens, ages, last_idx, age0, lengths,
                         max_new, u, cfg, kn)


@functools.partial(jax.jit, static_argnames=("cfg", "kn"))
def _prefill_rng_jit(params, tokens, ages, last_idx, age0, lengths, max_new,
                     key, *, cfg, kn):
    key, sub = jax.random.split(key)
    u = jax.random.uniform(sub, (tokens.shape[0], kn.vocab))
    cache_rows, rows, packed = _prefill_core(
        params, tokens, ages, last_idx, age0, lengths, max_new, u, cfg, kn)
    return cache_rows, rows, packed, key


@functools.partial(jax.jit, donate_argnums=(0,))
def _insert_rows_jit(cache, rows_cache, slot_ids):
    """One scatter writes all admitted prefill rows into the big cache.

    cache leaves are (L, B, ...) with the slot axis at 1; rows_cache leaves
    (L, n, ...) land at batch indices ``slot_ids`` (n,) — a single jitted
    dispatch per admission batch instead of one whole-cache update per slot.
    """
    return jax.tree_util.tree_map(
        lambda buf, new: buf.at[:, slot_ids].set(new.astype(buf.dtype)),
        cache, rows_cache)


@functools.partial(jax.jit, donate_argnums=(0,))
def _commit_jit(state, slot_ids, rows):
    return {k: state[k].at[slot_ids].set(rows[k].astype(state[k].dtype))
            for k in state}


class BatchedEngine:
    """Slot-based continuous batching, fully device-resident between syncs."""

    def __init__(self, params, cfg: ModelConfig, *, slots: int = 8,
                 max_context: int = 512, temperature: float = 1.0,
                 seed: int = 0, sampler: str = "jnp",
                 min_seq_bucket: int = 8):
        if cfg.frontend is not None or cfg.arch_type in (cb.AUDIO, cb.ENC_DEC):
            raise ValueError("engine serves token-only architectures")
        if sampler not in ("jnp", "pallas"):
            raise ValueError(f"sampler must be 'jnp' or 'pallas': {sampler!r}")
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_context = max_context
        self.temperature = temperature
        self.is_delphi = cfg.age_encoding
        self.sampler = sampler
        self.min_seq_bucket = min_seq_bucket
        # right-padding a prefill is only sound when padded positions can be
        # masked out of the state — true for KV-cache attention (pos = -1),
        # false for recurrent SSM/hybrid state; those admit unbucketed.
        self.bucketed = cfg.arch_type in (cb.DENSE, cb.MOE, cb.VLM)

        self._rng = jax.random.PRNGKey(seed)
        self.cache = make_decode_cache(params, cfg, slots, max_context)
        self._state: Dict[str, jax.Array] = {
            "last": jnp.zeros((slots,), jnp.int32),
            "age": jnp.zeros((slots,), jnp.float32),
            "step": jnp.zeros((slots,), jnp.int32),
            "n_emitted": jnp.zeros((slots,), jnp.int32),
            "max_new": jnp.ones((slots,), jnp.int32),
            "active": jnp.zeros((slots,), bool),
        }
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.pending: List[Request] = []
        self.completed: List[Request] = []
        # foreground run() returns completed; background start() defaults
        # this off so a long-lived server doesn't retain every request
        self.retain_completed = True
        # cross-thread submission (the HTTP front-end submits from handler
        # threads while a background thread ticks): `_lock` guards `pending`,
        # `_wake` cuts the idle backoff short on new work.  Slot/device state
        # is touched only by whichever single thread drives step().
        self._lock = threading.Lock()
        self._wake = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._stop_flag = False
        # instrumentation (asserted on by tests, reported by benchmarks)
        self.ticks = 0
        self.host_syncs = 0
        self.admit_batches = 0
        self.prefill_shapes: set = set()
        self._kn = _Knobs(
            slots=slots, max_context=max_context,
            is_delphi=self.is_delphi, use_pallas=sampler == "pallas",
            inv_temp=1.0 / max(temperature, 1e-6),
            max_age=cfg.max_age, death_token=cfg.death_token,
            vocab=cfg.vocab_size)

    # -- device->host boundary (the only one) -------------------------------
    def _fetch(self, x) -> np.ndarray:
        self.host_syncs += 1
        return _to_host(x)

    # -- public API ----------------------------------------------------------
    def submit(self, req: Request):
        """Thread-safe: handler threads enqueue while the loop thread ticks."""
        if len(req.tokens) == 0:
            raise ValueError("empty prompt")
        req.out_tokens, req.out_ages = [], []
        with self._lock:
            self.pending.append(req)
        self._wake.set()

    # -- background run loop (the HTTP front-end's async admission) ----------
    def start(self, *, idle_min: float = 0.001, idle_max: float = 0.05,
              retain_completed: bool = False) -> "BatchedEngine":
        """Tick on a daemon thread until :meth:`stop`.

        When no slot is active the loop backs off exponentially from
        ``idle_min`` to ``idle_max`` seconds between polls; ``submit`` wakes
        it immediately, so admission latency stays ~0 under load and the
        idle engine costs no busy spin.

        ``retain_completed=False`` (the default here, unlike foreground
        ``run()``) stops appending finished requests to ``self.completed``:
        a long-running server would otherwise leak every request's prompt,
        outputs and uniforms forever — callers observe completion through
        the per-request ``on_event``/``on_done`` hooks instead.
        """
        if self.running:
            return self
        self.retain_completed = retain_completed
        self._stop_flag = False
        self._wake.clear()
        self._thread = threading.Thread(
            target=self._loop, args=(idle_min, idle_max),
            name="repro-engine-loop", daemon=True)
        self._thread.start()
        return self

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def stop(self, join: bool = True, timeout: float = 60.0) -> None:
        was_running = self.running
        self._stop_flag = True
        self._wake.set()
        t = self._thread
        if join and t is not None:
            t.join(timeout=timeout)
            if t.is_alive():
                # mid-compile ticks can outlive the timeout: leave _thread
                # set (running stays True) rather than race a zombie loop
                # over slot state — the caller can retry stop()
                raise RuntimeError(
                    f"engine loop still ticking after {timeout}s "
                    f"(jit compile in flight?) — retry stop()")
        self._thread = None
        # waiters parked on background-mode completion hooks must get an
        # immediate error, not a request_timeout-later 504
        if was_running and (self.pending
                            or any(r is not None for r in self.slot_req)):
            self._fail_inflight(
                RuntimeError("engine stopped with the request in flight"))

    def _loop(self, idle_min: float, idle_max: float) -> None:
        idle = idle_min
        while not self._stop_flag:
            try:
                progressed = self.step()
            except Exception as e:          # fail loudly per-request, keep
                self._fail_inflight(e)      # the loop alive for new work
                progressed = False
            if progressed:
                idle = idle_min
            else:
                self._wake.wait(idle)
                self._wake.clear()
                idle = min(idle * 2.0, idle_max)

    def _fail_inflight(self, exc: Exception) -> None:
        """A tick blew up: every in-flight request gets the error (waiters
        unblock via on_done) and slot state resets so serving continues."""
        with self._lock:
            victims = self.pending[:]
            self.pending.clear()
        victims += [r for r in self.slot_req if r is not None]
        self.slot_req = [None] * self.slots
        self._state = {k: jnp.zeros_like(v) for k, v in self._state.items()}
        for req in victims:
            req.error = exc
            req.done = True
            if req.on_done is not None:
                req.on_done(req)

    # -- admission: bucketed batched prefill --------------------------------
    def _seq_bucket(self, n: int) -> int:
        return max(_next_pow2(n), self.min_seq_bucket)

    def _admit(self):
        while True:
            with self._lock:
                sel = self._select_admission()
            if sel is None:
                return
            self._admit_group(*sel)

    def _select_admission(
            self) -> Optional[Tuple[List[Request], List[int], bool]]:
        """Pop the next admission cohort off ``pending`` (lock held by the
        caller; the jitted prefill itself runs outside the lock so
        submitters never block on device work)."""
        if not self.pending:
            return None
        free = [i for i, r in enumerate(self.slot_req) if r is None]
        if not free:
            return None
        injected = self.pending[0].uniforms is not None
        # one tick samples all slots from ONE uniform source: defer
        # requests whose injectedness differs from the active cohort
        # until it drains (they are admitted on a later tick)
        occupied = [r for r in self.slot_req if r is not None]
        if occupied and (occupied[0].uniforms is not None) != injected:
            return None
        group: List[Request] = []
        limit = len(free) if self.bucketed else 1
        if len(self.pending[0].tokens) > self.max_context:
            # over-width prompt: exact-shape solo admission (the ring
            # cache keeps its last max_context tokens); never grouped,
            # or shorter groupmates would be evicted by the S>W pack
            limit = 1
        while (self.pending and len(group) < limit
               and (self.pending[0].uniforms is not None) == injected
               and (not group
                    or len(self.pending[0].tokens) <= self.max_context)):
            group.append(self.pending.pop(0))
        return group, free[:len(group)], injected

    def _admit_group(self, group: List[Request], slot_ids: List[int],
                     injected: bool):
        n = len(group)
        lens = [len(r.tokens) for r in group]
        if max(lens) > self.max_context:
            sb, nb = max(lens), n            # solo over-width admission
        elif self.bucketed:
            # never bucket past the ring width: a pad-rounded S > W would
            # evict valid prompt context via the S>W ring pack
            sb = min(self._seq_bucket(max(lens)), self.max_context)
            nb = min(_next_pow2(n), self.slots)
        else:
            sb, nb = max(lens), n
        self.prefill_shapes.add((nb, sb))

        tokens = np.zeros((nb, sb), np.int32)
        ages = np.zeros((nb, sb), np.float32)
        age0 = np.zeros((nb,), np.float32)
        lengths = np.full((nb,), lens[0], np.int32)
        max_new = np.full((nb,), 1, np.int32)
        for j, r in enumerate(group):
            S = lens[j]
            tokens[j, :S] = r.tokens
            if r.ages is not None:
                ages[j, :S] = r.ages
                ages[j, S:] = r.ages[-1]
                age0[j] = float(r.ages[-1])
            lengths[j] = S
            max_new[j] = r.max_new
        tokens[n:] = tokens[0]       # padded admission rows: clones of row 0,
        ages[n:] = ages[0]           # computed and discarded
        last_idx = lengths - 1

        args = (self.params, jnp.asarray(tokens), jnp.asarray(ages),
                jnp.asarray(last_idx), jnp.asarray(age0), jnp.asarray(lengths),
                jnp.asarray(max_new))
        if injected:
            u = np.full((nb, self.cfg.vocab_size), 0.5, np.float32)
            for j, r in enumerate(group):
                u[j] = r.uniforms[0]
            cache_rows, rows, packed = _prefill_u_jit(
                *args, jnp.asarray(u), cfg=self.cfg, kn=self._kn)
        else:
            cache_rows, rows, packed, self._rng = _prefill_rng_jit(
                *args, self._rng, cfg=self.cfg, kn=self._kn)

        ids = jnp.asarray(np.asarray(slot_ids, np.int32))
        self.cache = _insert_rows_jit(
            self.cache, jax.tree_util.tree_map(lambda a: a[:, :n], cache_rows),
            ids)
        self._state = _commit_jit(
            self._state, ids, jax.tree_util.tree_map(lambda a: a[:n], rows))

        self.admit_batches += 1
        arr = self._fetch(packed)    # ONE sync per admission batch
        for j, (req, slot) in enumerate(zip(group, slot_ids)):
            self.slot_req[slot] = req
            self._apply_host(req, slot, arr[:, j])

    def _apply_host(self, req: Request, slot: int, col: np.ndarray):
        evt, age, emit, finished = col
        if emit >= 0.5:
            req.out_tokens.append(int(evt))
            if self.is_delphi:
                req.out_ages.append(float(age))
            if req.on_event is not None:
                req.on_event(int(evt), float(age) if self.is_delphi else None)
        if finished >= 0.5:
            req.done = True
            if self.retain_completed:
                self.completed.append(req)
            self.slot_req[slot] = None
            if req.on_done is not None:
                req.on_done(req)

    # -- the tick ------------------------------------------------------------
    def step(self) -> bool:
        """One engine tick: admit pending, decode+sample all slots in-graph."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return False
        self.ticks += 1
        injected = [i for i in active if self.slot_req[i].uniforms is not None]
        if injected and len(injected) != len(active):
            raise ValueError("cannot mix uniform-injected and RNG-sampled "
                             "requests in one tick")
        if injected:
            u = np.full((self.slots, self.cfg.vocab_size), 0.5, np.float32)
            for i in active:
                r = self.slot_req[i]
                u[i] = r.uniforms[len(r.out_tokens)]
            self.cache, self._state, packed = _tick_u_jit(
                self.params, self.cache, self._state, jnp.asarray(u),
                cfg=self.cfg, kn=self._kn)
        else:
            self.cache, self._state, packed, self._rng = _tick_rng_jit(
                self.params, self.cache, self._state, self._rng,
                cfg=self.cfg, kn=self._kn)
        arr = self._fetch(packed)    # ONE sync per tick
        for slot in active:
            self._apply_host(self.slot_req[slot], slot, arr[:, slot])
        return True

    def run(self, max_ticks: int = 10_000) -> List[Request]:
        if self.running:
            raise RuntimeError(
                "engine is ticking on its background thread (start() was "
                "called): submit() and wait on the request instead of run()")
        ticks = 0
        while (self.pending or any(r is not None for r in self.slot_req)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.completed


# ===========================================================================
# Reference engine — the original host-loop implementation, kept as the
# before/after baseline for ``benchmarks/run.py serve``.  One vmapped
# single-slot decode per tick, per-slot host-side Python sampling, and a
# host round-trip per slot per tick.  (Retains the pre-parity-fix max-age
# semantics: the event crossing max_age is still emitted.)
# ===========================================================================
class ReferenceEngine:
    """Seed slot engine: vmap-of-single-slot decode + host-side sampling."""

    def __init__(self, params, cfg: ModelConfig, *, slots: int = 8,
                 max_context: int = 512, temperature: float = 1.0,
                 seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_context = max_context
        self.temperature = temperature
        self.is_delphi = cfg.age_encoding
        self.rng = jax.random.PRNGKey(seed)

        self.cache = make_decode_cache(params, cfg, slots, max_context)
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.slot_step = np.zeros(slots, np.int64)       # abs position per slot
        self.slot_age = np.zeros(slots, np.float64)
        self.slot_last = np.zeros(slots, np.int32)       # last emitted token
        self.pending: List[Request] = []
        self.completed: List[Request] = []
        self._build_jits()

    def _build_jits(self):
        cfg = self.cfg

        @jax.jit
        def _prefill(params, tokens, ages):
            batch = {"tokens": tokens}
            if cfg.age_encoding:
                batch["ages"] = ages
            out = forward(params, cfg, batch, mode="prefill",
                          cache_width=self.max_context)
            return out["cache"], out["logits"][:, -1]

        @jax.jit
        def _step(params, cache, tokens, ages, steps):
            # per-slot absolute steps differ: vmap the single-slot decode
            def one(c, t, a, s):
                c = jax.tree_util.tree_map(lambda x: x[:, None], c)
                b = {"tokens": t[None]}
                if cfg.age_encoding:
                    b["ages"] = a[None]
                d = decode_step(params, cfg, c, b, s)
                nc = jax.tree_util.tree_map(lambda x: jnp.squeeze(x, 1),
                                            d["cache"])
                return nc, d["logits"][0, 0]
            caches, logits = jax.vmap(
                one, in_axes=(_batch_axes(cache), 0, 0, 0),
                out_axes=(_batch_axes(cache), 0))(cache, tokens, ages, steps)
            return caches, logits

        @functools.partial(jax.jit, donate_argnums=(0,))
        def _insert(cache, slot_cache, slot):
            return jax.tree_util.tree_map(
                lambda buf, new: _insert_slot(buf, new, slot), cache, slot_cache)

        self._prefill = _prefill
        self._step = _step
        self._insert = _insert

    def submit(self, req: Request):
        req.out_tokens, req.out_ages = [], []
        self.pending.append(req)

    def _admit(self):
        for slot in range(self.slots):
            if self.slot_req[slot] is None and self.pending:
                req = self.pending.pop(0)
                S = len(req.tokens)
                tokens = jnp.asarray(req.tokens, jnp.int32)[None]
                ages = (jnp.asarray(req.ages, jnp.float32)[None]
                        if req.ages is not None else jnp.zeros((1, S), jnp.float32))
                slot_cache, last_logits = self._prefill(self.params, tokens, ages)
                # drop the leading batch dim of 1, insert at `slot`
                slot_cache = _strip_batch_one(slot_cache)
                self.cache = self._insert(self.cache, slot_cache, slot)
                self.slot_req[slot] = req
                self.slot_step[slot] = S
                self.slot_age[slot] = float(req.ages[-1]) if req.ages is not None else 0.0
                # sample the first token from the prefill logits
                self._emit(slot, np.asarray(last_logits[0]))

    def _emit(self, slot: int, logits: np.ndarray):
        req = self.slot_req[slot]
        cfg = self.cfg
        self.rng, k = jax.random.split(self.rng)
        if self.is_delphi:
            u = np.asarray(jax.random.uniform(k, (cfg.vocab_size,)))
            evt, tmin = sample_next_event(jnp.asarray(logits), jnp.asarray(u))
            evt, tmin = int(evt), float(tmin)
            self.slot_age[slot] += tmin
            done = (evt == cfg.death_token or self.slot_age[slot] > cfg.max_age
                    or len(req.out_tokens) + 1 >= req.max_new)
            req.out_tokens.append(evt)
            req.out_ages.append(self.slot_age[slot])
        else:
            lg = logits / max(self.temperature, 1e-6)
            evt = int(jax.random.categorical(k, jnp.asarray(lg)))
            done = len(req.out_tokens) + 1 >= req.max_new
            req.out_tokens.append(evt)
        self.slot_last[slot] = evt
        if done or self.slot_step[slot] + 1 >= self.max_context:
            req.done = True
            self.completed.append(req)
            self.slot_req[slot] = None

    def step(self):
        """One engine tick: admit pending, decode all active slots, sample."""
        self._admit()
        active = [i for i in range(self.slots) if self.slot_req[i] is not None]
        if not active:
            return False
        tokens = jnp.asarray(self.slot_last[:, None], jnp.int32)
        ages = jnp.asarray(self.slot_age[:, None], jnp.float32)
        steps = jnp.asarray(self.slot_step, jnp.int32)
        self.cache, logits = self._step(self.params, self.cache, tokens, ages, steps)
        logits = np.asarray(logits)
        for slot in active:
            self.slot_step[slot] += 1
            self._emit(slot, logits[slot])
        return True

    def run(self, max_ticks: int = 10_000) -> List[Request]:
        ticks = 0
        while (self.pending or any(r is not None for r in self.slot_req)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.completed


# -- tree helpers ------------------------------------------------------------
def _batch_axes(cache):
    """vmap in_axes pytree: batch axis position per cache leaf.

    Cache leaves are stacked (L, B, ...) so the batch axis is 1."""
    return jax.tree_util.tree_map(lambda _: 1, cache)


def _strip_batch_one(cache):
    """(L, 1, ...) -> (L, ...) for insertion along the slot axis."""
    return jax.tree_util.tree_map(lambda a: jnp.squeeze(a, axis=1), cache)


def _insert_slot(buf, new, slot):
    """buf (L, B, ...), new (L, ...) -> write at batch index `slot`."""
    return jax.lax.dynamic_update_index_in_dim(buf, new.astype(buf.dtype),
                                               slot, 1)
