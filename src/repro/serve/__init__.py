"""Serving substrate: batched engine with slot continuous batching, plus the
HTTP/SSE wire front-end (``repro.serve.server``, imported lazily to keep
``import repro.serve`` free of the client API stack)."""
from repro.serve.engine import (BatchedEngine, BlockAllocator,
                                ReferenceEngine, Request)
from repro.serve.prefix import (PrefixIndex, SharedBlockPool,
                                ring_reference_futures)

__all__ = ["BatchedEngine", "BlockAllocator", "ReferenceEngine", "Request",
           "SharedBlockPool", "PrefixIndex", "ring_reference_futures",
           "InferenceServer"]


def __getattr__(name):
    if name == "InferenceServer":
        from repro.serve.server import InferenceServer
        return InferenceServer
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
