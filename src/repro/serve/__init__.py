"""Serving substrate: batched engine with slot continuous batching."""
from repro.serve.engine import BatchedEngine, ReferenceEngine, Request

__all__ = ["BatchedEngine", "ReferenceEngine", "Request"]
