"""Serving substrate: batched engine with slot continuous batching."""
from repro.serve.engine import BatchedEngine, Request

__all__ = ["BatchedEngine", "Request"]
