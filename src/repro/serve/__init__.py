"""Serving substrate: batched engine with slot continuous batching, plus the
HTTP/SSE wire front-end (``repro.serve.server``) and the multi-replica
prefix-affinity router (``repro.serve.router``) — both imported lazily to
keep ``import repro.serve`` free of the client API stack."""
from repro.serve.engine import (BatchedEngine, BlockAllocator,
                                ReferenceEngine, Request)
from repro.serve.prefix import (PrefixIndex, SharedBlockPool,
                                chunked_reference_trajectory, prompt_digests,
                                ring_reference_futures)

__all__ = ["BatchedEngine", "BlockAllocator", "ReferenceEngine", "Request",
           "SharedBlockPool", "PrefixIndex", "prompt_digests",
           "ring_reference_futures", "chunked_reference_trajectory",
           "InferenceServer", "RouterServer", "ReplicaSupervisor",
           "PrefixAffinityScheduler"]

_LAZY = {
    "InferenceServer": "repro.serve.server",
    "RouterServer": "repro.serve.router",
    "ReplicaSupervisor": "repro.serve.router",
    "PrefixAffinityScheduler": "repro.serve.router",
}


def __getattr__(name):
    mod = _LAZY.get(name)
    if mod is not None:
        import importlib
        return getattr(importlib.import_module(mod), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
