"""Copy-on-write prefix sharing for the paged KV cache.

The paper's headline workload — individual morbidity risk — is a
*many-futures-from-one-history* problem: Delphi-style risk estimates sample N
stochastic continuations of a single patient trajectory.  PR 4's paged cache
already reads through per-slot block tables, so N requests whose histories
share a prefix can share the underlying *blocks*; this module supplies the
ownership layer that makes that safe:

* :class:`SharedBlockPool` — per-block **refcounts** layered on the engine's
  ``BlockAllocator``.  ``alloc`` hands out exclusively-owned blocks
  (refcount 1), ``share`` adds references, ``release`` drops one and returns
  the block to the free list only at refcount 0.  The engine copy-on-writes
  a block the first time a slot writes into one it does not exclusively own
  (refcount > 1), so shared prefixes are immutable while referenced.

* :class:`PrefixIndex` — a hash-keyed index over **full blocks** of admitted
  prompts: each ``block_size`` chunk of (token, age) history hashes into a
  chain (chunk ``i``'s digest folds in chunk ``i-1``'s), so a lookup walks
  the new prompt's chunks and returns the longest run of already-resident
  blocks.  Matched blocks are acquired by *reference* at admission instead
  of re-inserted, and a **complete** entry (full blocks + partial tail +
  bootstrap logits, registered by ``hold`` admissions) lets an identical
  prompt admit with **no prefill at all**.  Entries hold their own block
  references and are LRU-evicted — only blocks whose refcount drops to 0
  actually free, so eviction never rips a prefix out from under a live
  request.

* :func:`ring_reference_futures` — the scheduler-free **bit-parity oracle**
  for the engine's ``fork`` primitive: a straight-line dense-ring N-futures
  generator built from the engine's *own* module-level jitted functions
  (solo bucketed prefill → fork-row bootstrap → shared decode tick), so the
  paged/forked/COW engine path must reproduce it bit for bit under injected
  uniforms.  ``core.risk.monte_carlo_risk`` accepts its trajectories as the
  engine-parity sampling backend.

Zero-leak invariant (extends PR 4): after the engine drains *and* the index
is dropped (``BatchedEngine.drop_prefix_cache``), ``allocator.used == 0`` and
no refcounts remain — ``scripts/paged_parity.py`` storms this with
fork/cancel/preempt/timeout traffic.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["SharedBlockPool", "PrefixIndex", "prompt_digests",
           "ring_reference_futures", "chunked_reference_trajectory"]


class SharedBlockPool:
    """Ref-counted block ownership over a ``BlockAllocator``.

    Every block handed out by :meth:`alloc` starts at refcount 1; additional
    owners (forked requests, the prefix index) attach with :meth:`share`.
    :meth:`release` drops ONE reference — the underlying allocator sees the
    free only when the last reference goes, so ``allocator.used`` keeps
    counting each physical block exactly once no matter how many requests
    reference it (the admission-budget and ``pool_stats`` contract).
    """

    def __init__(self, allocator):
        self.allocator = allocator
        self._refs: Dict[int, int] = {}                    # guarded-by: engine-thread
        #: jitted block copies triggered by a write into a shared block
        self.cow_copies = 0
        #: high-water mark of concurrently shared (refcount >= 2) blocks
        self.peak_shared = 0
        #: set by the engine when the prefix index is enabled — alloc()
        #: evicts LRU index entries before giving up on pool pressure
        self.index: Optional["PrefixIndex"] = None

    # -- allocator passthrough (once-counted accounting) ---------------------
    @property
    def capacity(self) -> int:
        return self.allocator.capacity

    @property
    def free(self) -> int:
        return self.allocator.free

    @property
    def used(self) -> int:
        return self.allocator.used

    @property
    def num_blocks(self) -> int:
        return self.allocator.num_blocks

    @property
    def peak_used(self) -> int:
        return self.allocator.peak_used

    def available(self, exclude=None) -> int:
        """Admission budget: free blocks plus blocks an index eviction could
        free right now.  A block shared by a live request counts ZERO times
        (it is neither free nor evictable), and ``exclude`` removes blocks
        the caller is about to PIN by sharing them — they must not be
        double-counted as both lent-by-reference and evictable."""
        n = self.allocator.free
        if self.index is not None:
            n += self.index.evictable(exclude)
        return n

    # -- ownership ------------------------------------------------------------
    def alloc(self, n: int, *, evict: bool = True) -> Optional[List[int]]:  # repro-lint: engine-thread-only
        """n exclusively-owned blocks (refcount 1), or None — after trying
        to make room by LRU-evicting prefix-index entries."""
        if evict and self.index is not None and n > self.allocator.free:
            self.index.evict(n - self.allocator.free)
        ids = self.allocator.alloc(n)
        if ids is not None:
            for i in ids:
                self._refs[i] = 1
        return ids

    def share(self, ids: List[int]) -> None:  # repro-lint: engine-thread-only
        """Attach one more reference to each block (fork / prefix admit /
        index registration)."""
        for i in ids:
            r = self._refs.get(i)
            if r is None:
                raise ValueError(f"share of unallocated block {i}")
            self._refs[i] = r + 1
        self.peak_shared = max(self.peak_shared, self.shared_blocks)

    def release(self, ids: List[int]) -> None:  # repro-lint: engine-thread-only
        """Drop one reference per block; frees into the allocator at 0."""
        for i in ids:
            r = self._refs.get(i)
            if r is None:
                raise ValueError(f"release of unowned block {i}")
            if r == 1:
                del self._refs[i]
                self.allocator.release([i])
            else:
                self._refs[i] = r - 1

    def refcount(self, block_id: int) -> int:  # repro-lint: engine-thread-only
        return self._refs.get(block_id, 0)

    @property
    def shared_blocks(self) -> int:
        """Physical blocks currently referenced by more than one owner."""
        # repro-lint: disable=RL001 GIL-atomic counter scan; the only
        # cross-thread caller is engine.pool_stats, holding the engine lock
        return sum(1 for r in self._refs.values() if r > 1)

    @property
    def total_refs(self) -> int:
        # repro-lint: disable=RL001 GIL-atomic counter scan; the only
        # cross-thread caller is engine.pool_stats, holding the engine lock
        return sum(self._refs.values())


# ---------------------------------------------------------------------------
# Prefix index
# ---------------------------------------------------------------------------
def _chunk_digest(prev: bytes, toks: np.ndarray,
                  ages: Optional[np.ndarray]) -> bytes:
    h = hashlib.blake2b(prev, digest_size=16)
    h.update(np.ascontiguousarray(toks, np.int64).tobytes())
    if ages is not None:
        h.update(np.ascontiguousarray(ages, np.float32).tobytes())
    return h.digest()


def prompt_digests(tokens, ages, block_size: int
                   ) -> Tuple[List[bytes], bytes]:
    """Chained blake2b digests of a prompt's (token, age) history.

    Returns ``(chain, key)``: one digest per FULL ``block_size`` chunk
    (chunk ``i`` folds in chunk ``i-1``'s digest, so digest ``i`` names the
    whole prefix through block ``i``) plus a whole-prompt key that also
    folds in the partial tail and the exact length.

    This is the shared vocabulary between a replica's :class:`PrefixIndex`
    (which blocks are resident) and the multi-replica router's
    prefix-affinity scheduler (``repro.serve.router``): both sides hash the
    same history to the same chain, so the router can route a request to
    the replica whose pool already holds its prefix blocks without ever
    seeing that pool.
    """
    toks = np.asarray(tokens, np.int64)
    ags = None if ages is None else np.asarray(ages, np.float32)
    bs = block_size
    S = len(toks)
    full, prev = [], b"prefix-v1"
    for i in range(S // bs):
        prev = _chunk_digest(prev, toks[i * bs:(i + 1) * bs],
                             None if ags is None
                             else ags[i * bs:(i + 1) * bs])
        full.append(prev)
    key = prev
    if S % bs:
        key = _chunk_digest(prev, toks[-(S % bs):],
                            None if ags is None else ags[-(S % bs):])
    # fold the exact length in so "aligned prompt" vs "same prompt plus
    # an empty tail" cannot collide
    key = hashlib.blake2b(key + S.to_bytes(8, "little"),
                          digest_size=16).digest()
    return full, key


class _Entry:
    __slots__ = ("key", "chain", "blocks", "complete", "S", "age0", "logits",
                 "hits")

    def __init__(self, key, chain, blocks, complete, S, age0, logits):
        self.key = key
        self.chain = chain          # per-full-block chain digests
        self.blocks = blocks        # table-order block ids (full [+ tail])
        self.complete = complete    # tail + bootstrap logits present
        self.S = S
        self.age0 = age0
        self.logits = logits        # (V,) device array (complete entries)
        self.hits = 0


class PrefixIndex:
    """Hash-keyed LRU index over admitted prompts' KV blocks.

    Two lookup grains:

    * :meth:`match_prefix` — longest run of FULL blocks whose (token, age)
      chunk-chain digests are resident: admission shares these by reference
      and prefills only the unmatched suffix (memory saved, compute kept) —
      also how a preempted forked request *re-acquires* its shared prefix on
      recompute resume.
    * :meth:`lookup` — exact whole-prompt match against a **complete** entry
      (registered by ``hold`` admissions: full blocks, partial tail block,
      and the prompt's bootstrap logits): admission by pure reference, no
      prefill at all — the Monte-Carlo N-futures fast path.

    The index owns one reference per block of each entry; eviction releases
    them, and a block frees only when no live request still shares it.
    """

    def __init__(self, pool: SharedBlockPool, block_size: int,
                 max_entries: int = 256):
        self.pool = pool
        self.block_size = block_size
        self.max_entries = max_entries
        self._entries: "OrderedDict[bytes, _Entry]" = \
            OrderedDict()                                  # guarded-by: engine-thread
        self._chain: Dict[bytes, Tuple[int, bytes]] = {}   # guarded-by: engine-thread
        pool.index = self
        self.hits = 0           # complete-entry (no-prefill) admissions
        self.partial_hits = 0   # admissions that shared >= 1 full block
        self.misses = 0
        self.evictions = 0

    # -- hashing --------------------------------------------------------------
    def _digests(self, tokens, ages) -> Tuple[List[bytes], bytes]:
        return prompt_digests(tokens, ages, self.block_size)

    # -- queries (side-effect-free: admission probes them repeatedly; the
    #    engine calls touch() only when an admission actually lands) ---------
    def digests(self, tokens, ages) -> Tuple[List[bytes], bytes]:
        """(per-full-block chain digests, whole-prompt key) — computed once
        per request and memoized by the engine (hashing a long Delphi
        history is O(S) and admission probes run under the engine lock)."""
        return self._digests(tokens, ages)

    def match_run(self, full_digests: List[bytes]) -> List[int]:  # repro-lint: engine-thread-only
        """Longest resident run of full-block ids for a digest chain."""
        out: List[int] = []
        for d in full_digests:
            hit = self._chain.get(d)
            if hit is None:
                break
            out.append(hit[0])
        return out

    def match_prefix(self, tokens, ages) -> List[int]:
        """Longest resident run of full-block ids for this history."""
        return self.match_run(self._digests(tokens, ages)[0])

    def lookup_key(self, key: bytes) -> Optional[_Entry]:  # repro-lint: engine-thread-only
        """Complete entry exactly matching a whole-prompt key."""
        e = self._entries.get(key)
        return e if e is not None and e.complete else None

    def lookup(self, tokens, ages) -> Optional[_Entry]:
        """Exact whole-prompt match against a complete entry."""
        return self.lookup_key(self._digests(tokens, ages)[1])

    def touch(self, entry: _Entry) -> None:  # repro-lint: engine-thread-only
        """An admission actually used this entry: bump MRU + hit count."""
        self._entries.move_to_end(entry.key)
        entry.hits += 1

    # -- registration / eviction ----------------------------------------------
    def aligned_key(self, chain: List[bytes], n_blocks: int) -> bytes:
        """Whole-prompt key of the block-aligned truncation covering the
        first ``n_blocks`` full blocks — derived from an existing chain in
        O(1) instead of re-hashing the history."""
        prev = chain[n_blocks - 1] if n_blocks else b"prefix-v1"
        S = n_blocks * self.block_size
        return hashlib.blake2b(prev + S.to_bytes(8, "little"),
                               digest_size=16).digest()

    def register(self, tokens, ages, blocks: List[int], *, S: int,  # repro-lint: engine-thread-only
                 age0: float, logits=None,
                 digests: Optional[Tuple[List[bytes], bytes]] = None
                 ) -> None:
        """Index an admitted prompt's blocks (the index takes one reference
        per block).  ``logits`` marks the entry complete: ``blocks`` then
        also carries the partial tail block and :meth:`lookup` can admit the
        exact prompt with no prefill.  ``digests`` passes the prompt's
        already-computed (chain, key) — the engine memoizes them per
        request, and re-hashing a long history here would serialize the
        engine thread for nothing."""
        chain, key = (digests if digests is not None
                      else self._digests(tokens, ages))
        if key in self._entries:
            self._entries.move_to_end(key)
            return
        # build the entry BEFORE taking the shares: _Entry / np.float32 can
        # raise, and shares taken first would have no owner to release them
        e = _Entry(key, chain[:len(blocks)], list(blocks),
                   logits is not None, S, np.float32(age0), logits)
        self.pool.share(blocks)
        self._entries[key] = e
        for d, b in zip(e.chain, e.blocks):
            self._chain.setdefault(d, (b, key))
        while len(self._entries) > self.max_entries:
            # trim the cap preferring entries whose eviction frees blocks;
            # pinned entries (live owners) go only when nothing else is
            # left — evicting them strands a preempted fork's re-acquire
            victim = self._freeing_victim() or next(iter(self._entries))
            self._evict_entry(victim)

    def _evict_entry(self, key: bytes) -> int:  # repro-lint: engine-thread-only
        e = self._entries.pop(key)
        for d in e.chain:
            owner = self._chain.get(d)
            if owner is not None and owner[1] == key:
                del self._chain[d]
        before = self.pool.free
        self.pool.release(e.blocks)
        self.evictions += 1
        return self.pool.free - before

    def _evict_one(self) -> int:  # repro-lint: engine-thread-only
        return self._evict_entry(next(iter(self._entries)))    # LRU head

    def _index_block_refs(self) -> Dict[int, int]:  # repro-lint: engine-thread-only
        """block id -> how many index entries hold a reference to it."""
        counts: Dict[int, int] = {}
        for e in self._entries.values():
            for b in e.blocks:
                counts[b] = counts.get(b, 0) + 1
        return counts

    def _freeing_victim(self) -> Optional[bytes]:  # repro-lint: engine-thread-only
        """LRU-most entry whose eviction makes progress toward freeing
        memory: some of its blocks are held ONLY by index entries (a block
        shared between two cached entries frees once both go — picking
        such entries repeatedly reaches the fixpoint).  Entries whose
        every block is still referenced by a live request are *pinned* —
        evicting them frees nothing and would only strand an in-flight
        fork's resume from re-acquiring its prefix."""
        counts = self._index_block_refs()
        for key, e in self._entries.items():                   # LRU order
            if any(self.pool.refcount(b) == counts.get(b, 0)
                   for b in e.blocks):
                return key
        return None

    def evict(self, need_blocks: Optional[int] = None) -> int:  # repro-lint: engine-thread-only
        """Make room: LRU-evict entries until ``need_blocks`` blocks have
        actually freed, skipping pinned entries (see
        :meth:`_freeing_victim`).  Loops to a fixpoint, so blocks shared
        only between cached entries free once their last holder goes.
        ``need_blocks=None`` clears unconditionally (``drop_prefix_cache``
        / the zero-leak drain)."""
        freed = 0
        if need_blocks is None:
            while self._entries:
                freed += self._evict_one()
            return freed
        while freed < need_blocks:
            victim = self._freeing_victim()
            if victim is None:
                break
            freed += self._evict_entry(victim)
        return freed

    def clear(self) -> int:
        return self.evict(None)

    def evictable(self, exclude=None) -> int:
        """Blocks a pressure eviction could free right now: cached blocks
        whose every reference is an index entry (the fixpoint
        :meth:`evict` reaches).  ``exclude`` drops blocks the caller is
        about to pin by sharing them."""
        counts = self._index_block_refs()
        return sum(1 for b, c in counts.items()
                   if self.pool.refcount(b) == c
                   and (exclude is None or b not in exclude))

    # -- stats ---------------------------------------------------------------
    @property
    def entries(self) -> int:
        # repro-lint: disable=RL001 GIL-atomic counter scan; the only
        # cross-thread caller is engine.pool_stats, holding the engine lock
        return len(self._entries)

    @property
    def cached_blocks(self) -> int:
        # repro-lint: disable=RL001 GIL-atomic counter scan; the only
        # cross-thread caller is engine.pool_stats, holding the engine lock
        return len({b for e in self._entries.values() for b in e.blocks})

    def stats(self) -> Dict[str, float]:
        n = self.hits + self.misses
        return {
            "entries": self.entries,
            "cached_blocks": self.cached_blocks,
            "hits": self.hits,
            "partial_hits": self.partial_hits,
            "misses": self.misses,
            "hit_rate": self.hits / n if n else 0.0,
            "evictions": self.evictions,
        }


# ---------------------------------------------------------------------------
# Bit-parity oracle for engine fork
# ---------------------------------------------------------------------------
def ring_reference_futures(params, cfg, tokens, ages=None, *, n: int,
                           max_new: int = 48, uniforms=None,
                           slots: Optional[int] = None,
                           max_context: int = 512, temperature: float = 1.0,
                           sampler: str = "jnp", min_seq_bucket: int = 8
                           ) -> List[Tuple[List[int], List[float]]]:
    """Scheduler-free N-futures generation on a dense ring — the oracle the
    forked/COW/paged engine must match bit for bit.

    Mirrors the engine's ``sample_futures`` data path while bypassing every
    piece of new machinery under test (allocator, refcounts, prefix index,
    fork ops, preemption): ONE solo bucketed prefill of the history (the
    same ``_prefill_u_jit`` executable a ``hold`` admission dispatches),
    a fork-row bootstrap sampling each future's first event from the shared
    prefill logits (``_fork_rows_jit``), then the engine's own decode tick
    (``_tick_u_jit``) until every future terminates.  Because the jitted
    functions are the engine's module-level ones with an identical knob
    tuple, both sides run the *same compiled executables* — divergence is a
    real bug, never fp noise.

    Bit-parity contract: ``uniforms`` (n, max_new, V) must be injected, the
    engine must run with the same ``slots``/``max_context``/``temperature``/
    ``sampler``/``min_seq_bucket``, and all n forks must land in one wave
    (``slots >= n``, no preemption) — recompute resume re-prefills at new
    shapes and is only *semantically*, not bit-wise, aligned.

    Returns ``[(tokens, fp32 ages), ...]`` per future.
    """
    import jax
    import jax.numpy as jnp
    from repro.models import make_decode_cache
    from repro.serve.engine import (_Knobs, _commit_jit, _fork_copy_rows_jit,
                                    _fork_rows_jit, _insert_rows_jit,
                                    _next_pow2, _prefill_u_jit, _tick_u_jit)
    if uniforms is None:
        raise ValueError("ring_reference_futures is the injected-uniforms "
                         "parity oracle: pass uniforms (n, max_new, V)")
    uniforms = np.asarray(uniforms, np.float32)
    if uniforms.shape[:2] != (n, max_new) \
            or uniforms.shape[2] != cfg.vocab_size:
        raise ValueError(f"uniforms must be (n={n}, max_new={max_new}, "
                         f"V={cfg.vocab_size}); got {uniforms.shape}")
    K = n if slots is None else slots
    if K < n:
        raise ValueError(f"slots={K} cannot hold n={n} futures in one wave")
    W = max_context
    V = cfg.vocab_size
    is_delphi = cfg.age_encoding
    kn = _Knobs(slots=K, max_context=W, is_delphi=is_delphi,
                use_pallas=sampler == "pallas",
                inv_temp=1.0 / max(temperature, 1e-6),
                max_age=cfg.max_age, death_token=cfg.death_token, vocab=V)

    toks = np.asarray(tokens, np.int64)
    ags = None if ages is None else np.asarray(ages, np.float64)
    S = len(toks)
    if S > W:
        sb = S                                   # over-width: exact shape
    else:
        sb = min(max(_next_pow2(S), min_seq_bucket), W)
    t = np.zeros((1, sb), np.int32)
    t[0, :S] = toks
    a = np.zeros((1, sb), np.float32)
    age0 = 0.0
    if ags is not None:
        a[0, :S] = ags
        a[0, S:] = ags[-1]
        age0 = float(ags[-1])

    cache = make_decode_cache(params, cfg, K, W)
    state = {
        "last": jnp.zeros((K,), jnp.int32),
        "age": jnp.zeros((K,), jnp.float32),
        "step": jnp.zeros((K,), jnp.int32),
        "n_emitted": jnp.zeros((K,), jnp.int32),
        "max_new": jnp.ones((K,), jnp.int32),
        "active": jnp.zeros((K,), bool),
    }
    # solo hold-style prefill: filler uniforms, sampled row discarded
    filler = np.full((1, V), 0.5, np.float32)
    cache_rows, _rows, _packed, lg = _prefill_u_jit(
        params, jnp.asarray(t), jnp.asarray(a),
        jnp.asarray([S - 1], jnp.int32), jnp.asarray([age0], jnp.float32),
        jnp.asarray([S], jnp.int32), jnp.asarray([max_new], jnp.int32),
        jnp.asarray(filler), cfg=cfg, kn=kn)
    cache = _insert_rows_jit(
        cache, jax.tree_util.tree_map(lambda x: x[:, :1], cache_rows),
        jnp.asarray([0], np.int32))

    # fork the prefilled row into n child slots (0..n-1), masking any
    # position >= S exactly as the engine's fork copy does
    kb = _next_pow2(n)
    dst = np.zeros((kb,), np.int32)              # padded with src (slot 0)
    dst[:n] = np.arange(n)
    cache = _fork_copy_rows_jit(cache, jnp.int32(0), jnp.asarray(dst),
                                jnp.int32(S - 1))
    u0 = np.full((kb, V), 0.5, np.float32)
    u0[:n] = uniforms[:, 0]
    lg_b = jnp.broadcast_to(lg[0][None], (kb, V))
    rows, packed = _fork_rows_jit(
        lg_b, jnp.asarray(u0),
        jnp.full((kb,), age0, jnp.float32), jnp.full((kb,), S, jnp.int32),
        jnp.full((kb,), max_new, jnp.int32), kn=kn)
    state = _commit_jit(state, jnp.asarray(np.arange(n, dtype=np.int32)),
                        jax.tree_util.tree_map(lambda x: x[:n], rows))

    out_t: List[List[int]] = [[] for _ in range(n)]
    out_a: List[List[float]] = [[] for _ in range(n)]
    live = [True] * n

    def apply(j, col):
        evt, age, emit, finished = col
        if emit >= 0.5:
            out_t[j].append(int(evt))
            if is_delphi:
                out_a[j].append(float(age))
        if finished >= 0.5:
            live[j] = False

    arr = np.asarray(packed)
    for j in range(n):
        apply(j, arr[:, j])
    while any(live):
        u = np.full((K, V), 0.5, np.float32)
        for j in range(n):
            if live[j]:
                u[j] = uniforms[j, len(out_t[j])]
        cache, state, packed = _tick_u_jit(params, cache, state,
                                           jnp.asarray(u), cfg=cfg, kn=kn)
        arr = np.asarray(packed)
        for j in range(n):
            if live[j]:
                apply(j, arr[:, j])
    return [(out_t[j], out_a[j]) for j in range(n)]


# ---------------------------------------------------------------------------
# Bit-parity oracle for chunked / suffix prefill
# ---------------------------------------------------------------------------
def chunked_reference_trajectory(params, cfg, tokens, ages=None, *,
                                 max_new: int, uniforms,
                                 chunk_tokens: int, slots: int = 4,
                                 max_context: int = 512,
                                 block_size: int = 16,
                                 matched_tokens: int = 0,
                                 blocks: Optional[int] = None,
                                 temperature: float = 1.0,
                                 sampler: str = "jnp"
                                 ) -> Tuple[List[int], List[float]]:
    """Scheduler-free single-request trajectory on a paged pool via chunked
    suffix prefill — the oracle the interleaved engine path must match bit
    for bit.

    Mirrors ``BatchedEngine(prefill_chunk_tokens=chunk_tokens)`` serving one
    request while bypassing the scheduler under test (admission budgeting,
    the per-tick budget walk, preemption, the prefix index): the prompt's
    suffix is driven through the engine's OWN module-level jits — one
    ``_suffix_chunk_jit`` per ``_chunk_len``-sized chunk, a
    ``_fork_rows_jit`` bootstrap from the final chunk's logits, then
    ``_tick_u_jit`` decode ticks with block growth + position resets in the
    engine's exact flush order.  Chunk geometry comes from the shared
    ``_chunk_arrays`` helper, so both sides compile and run the *same*
    executables per shape.

    ``matched_tokens`` models a partial prefix-index hit: a warm pass
    chunk-prefills ``tokens[:matched_tokens]`` (block-aligned, < S) into its
    own blocks — standing in for the indexed registrant's blocks, which the
    engine-side request acquires by reference — and the request's cursor
    starts at that boundary, prefilling ONLY the unmatched suffix.  The
    engine registrant must have served that aligned prefix with the same
    ``chunk_tokens`` so the lent block bytes agree.

    Bit-parity contract: injected ``uniforms`` (max_new, V) — row 0 is the
    bootstrap event; the engine must run the request solo on a fresh engine
    with the same ``slots``/``max_context``/``block_size``/``temperature``/
    ``sampler``; and ``S + max_new <= max_context`` (no ring wrap: the
    oracle never copy-on-writes).  Returns ``(tokens, fp32 ages)``.
    """
    import jax
    import jax.numpy as jnp
    from repro.models import make_paged_decode_cache
    from repro.serve.engine import (_Knobs, _chunk_arrays, _chunk_len,
                                    _commit_jit, _fork_rows_jit, _next_pow2,
                                    _reset_pos_jit, _suffix_chunk_jit,
                                    _tick_u_jit)
    uniforms = np.asarray(uniforms, np.float32)
    toks = np.asarray(tokens, np.int64)
    ags = None if ages is None else np.asarray(ages)
    S = len(toks)
    bs = block_size
    W = max_context
    V = cfg.vocab_size
    if uniforms.shape != (max_new, V):
        raise ValueError(f"uniforms must be (max_new={max_new}, V={V}); "
                         f"got {uniforms.shape}")
    if S + max_new > W:
        raise ValueError(
            f"S + max_new = {S + max_new} > max_context={W}: the oracle "
            f"forbids ring wrap (a wrapped slot copy-on-writes, which this "
            f"straight line does not model)")
    if matched_tokens % bs or not 0 <= matched_tokens < S:
        raise ValueError(f"matched_tokens={matched_tokens} must be a "
                         f"block-aligned length in [0, S)")
    if chunk_tokens < bs:
        raise ValueError(f"chunk_tokens={chunk_tokens} must be >= "
                         f"block_size={bs}")
    kn = _Knobs(slots=slots, max_context=W, is_delphi=cfg.age_encoding,
                use_pallas=sampler == "pallas",
                inv_temp=1.0 / max(temperature, 1e-6),
                max_age=cfg.max_age, death_token=cfg.death_token, vocab=V)
    nb = -(-S // bs)
    nb_warm = matched_tokens // bs
    if blocks is None:
        blocks = nb_warm + -(-(S + max_new) // bs) + 2
    cache = make_paged_decode_cache(cfg, slots, W, num_blocks=blocks,
                                    block_size=bs)
    nbs = W // bs
    next_id = 1

    def take(k: int) -> List[int]:
        nonlocal next_id
        ids = list(range(next_id, next_id + k))
        next_id += k
        if next_id > blocks:
            raise ValueError(f"oracle pool of {blocks} blocks exhausted")
        return ids

    def run_chunks(row, start: int, end: int):
        nonlocal cache
        lg = None
        cur = start
        while cur < end:
            n = _chunk_len(end, cur, chunk_tokens, bs)
            t_, a_, p_, c_, d_, li_ = _chunk_arrays(toks, ags, cur, n, bs,
                                                    row)
            cache, lg = _suffix_chunk_jit(
                params, cache, jnp.asarray(t_), jnp.asarray(a_),
                jnp.asarray(p_), jnp.asarray(c_), jnp.asarray(d_),
                jnp.asarray(li_), cfg=cfg)
            cur += n
        return lg

    # warm pass: the indexed registrant's aligned prefix, in its own blocks
    warm = take(nb_warm)
    if nb_warm:
        wrow = np.full((nbs,), -1, np.int32)
        wrow[:nb_warm] = warm
        run_chunks(wrow, 0, matched_tokens)

    # request pass: lent blocks + fresh suffix blocks, cursor at the match
    row = np.full((nbs,), -1, np.int32)
    row[:nb] = warm + take(nb - nb_warm)
    lg = run_chunks(row, matched_tokens, S)

    age0 = float(ags[-1]) if ags is not None else 0.0
    state = {
        "last": jnp.zeros((slots,), jnp.int32),
        "age": jnp.zeros((slots,), jnp.float32),
        "step": jnp.zeros((slots,), jnp.int32),
        "n_emitted": jnp.zeros((slots,), jnp.int32),
        "max_new": jnp.ones((slots,), jnp.int32),
        "active": jnp.zeros((slots,), bool),
    }
    lg_b = jnp.broadcast_to(lg[0][None], (1, V))
    rows, packed = _fork_rows_jit(
        lg_b, jnp.asarray(uniforms[0][None]),
        jnp.full((1,), age0, jnp.float32), jnp.full((1,), S, jnp.int32),
        jnp.full((1,), max_new, jnp.int32), kn=kn)
    state = _commit_jit(state, jnp.asarray([0], np.int32), rows)

    out_t: List[int] = []
    out_a: List[float] = []
    live = [True]

    def apply(col):
        evt, age, emit, finished = col
        if emit >= 0.5:
            out_t.append(int(evt))
            if cfg.age_encoding:
                out_a.append(float(age))
        if finished >= 0.5:
            live[0] = False

    apply(np.asarray(packed)[:, 0])
    pos = S
    tab = np.full((slots, nbs), -1, np.int32)
    table_dirty = True
    npad = _next_pow2(max(1, slots))       # the engine's fresh-id padding
    while live[0]:
        jb = (pos % W) // bs
        if row[jb] < 0:                    # decode growth, engine order:
            row[jb] = take(1)[0]           # reset positions, then the table
            ids = np.zeros(npad, np.int32)
            ids[0] = row[jb]
            cache = _reset_pos_jit(cache, jnp.asarray(ids))
            table_dirty = True
        if table_dirty:
            tab[0] = row
            pc = cache["self"]
            cache = {"self": pc._replace(table=jnp.asarray(tab))}
            table_dirty = False
        u = np.full((slots, V), 0.5, np.float32)
        u[0] = uniforms[len(out_t)]
        cache, state, packed = _tick_u_jit(params, cache, state,
                                           jnp.asarray(u), cfg=cfg, kn=kn)
        apply(np.asarray(packed)[:, 0])
        pos += 1
    return out_t, out_a
