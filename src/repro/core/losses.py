"""Delphi-2M dual loss: next-event cross-entropy + exponential time-to-event NLL.

The model emits one logit per vocabulary entry; rates are lambda_i =
exp(logit_i).  Under competing exponential clocks the joint NLL of observing
event j after waiting time dt factorizes exactly:

    NLL(j, dt) = Lambda*dt - logit_j
               = [logsumexp(logits) - logit_j]  +  [Lambda*dt - log(Lambda)]
               =        CE(event)               +     Exp-NLL(time)

with Lambda = sum_i exp(logit_i).  We expose both the factored form (what the
Delphi training script optimizes: ``ce + time_nll``) and the joint form; their
identity is property-tested (tests/test_losses.py), which validates the
paper's claim C3 that the eq.-1 sampler and the training loss describe the
same generative process.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp


def event_ce(logits, targets):
    """Per-position cross-entropy. logits (..., V) fp32, targets (...) int."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return lse - tgt


def time_nll(logits, dt):
    """Exponential waiting-time NLL with total rate Lambda = sum_i e^{logit_i}.

    dt in years.  NLL = Lambda*dt - log(Lambda).
    """
    log_rate = jax.nn.logsumexp(logits, axis=-1)          # log Lambda
    return jnp.exp(log_rate) * dt - log_rate


def joint_nll(logits, targets, dt):
    """Competing-risk joint NLL: Lambda*dt - logit_j (== event_ce + time_nll)."""
    rate = jnp.exp(jax.nn.logsumexp(logits, axis=-1))
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return rate * dt - tgt


def dual_loss(logits, targets, dt, mask, *, time_weight: float = 1.0
              ) -> Dict[str, jax.Array]:
    """Masked mean of the Delphi dual objective.

    logits: (B, S, V) fp32; targets: (B, S) next-event ids; dt: (B, S) years
    until the next event; mask: (B, S) {0,1} — positions whose *target* is a
    real event (padding / no-event targets are excluded, as in the reference
    train.py).
    """
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    ce = jnp.sum(event_ce(logits, targets) * mask) / denom
    tn = jnp.sum(time_nll(logits, dt) * mask) / denom
    return {"loss": ce + time_weight * tn, "event_ce": ce, "time_nll": tn}
