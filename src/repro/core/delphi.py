"""Delphi-2M model facade — the paper's GPT with age encoding + dual head.

The backbone reuses the architecture zoo (``repro.models``); what makes it
Delphi is (a) ``age_encoding=True`` in the config (continuous sinusoidal age
features replace positional encodings), (b) the dual loss (``core.losses``)
over the single logit head, (c) the competing-exponential sampler
(``core.sampler``).  This module provides the task-level API used by the
trainer, the SDK exporter, and the examples.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import losses
from repro.models import forward, init_params


def init_delphi(cfg: ModelConfig, key):
    assert cfg.age_encoding and cfg.dual_head, "not a Delphi config"
    return init_params(cfg, key)


def get_logits(params, cfg: ModelConfig, tokens, ages):
    """The SDK-parity entry point: (B, S) tokens + ages -> (B, S, V) fp32
    logits.  This exact function is what ``sdk.export`` serializes (claim C2)."""
    return forward(params, cfg, {"tokens": tokens, "ages": ages},
                   mode="train")["logits"]


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jax.Array], *,
            time_weight: float = 1.0) -> Dict[str, jax.Array]:
    """Delphi training objective on a packed batch.

    batch: tokens (B, S), ages (B, S), targets (B, S), target_dt (B, S),
    loss_mask (B, S).
    """
    logits = get_logits(params, cfg, batch["tokens"], batch["ages"])
    out = losses.dual_loss(logits, batch["targets"], batch["target_dt"],
                           batch["loss_mask"], time_weight=time_weight)
    return out
