"""Calibration harness: generated trajectories vs held-out data.

The Delphi-2M evaluation compares model-generated disease histories against
real cohort statistics.  This harness computes the comparable summaries on
our synthetic cohort:

  * age-at-death distribution (mean + deciles),
  * events-per-year by age decade (the hazard ramp),
  * ICD-chapter frequency profile (L1 distance model vs data).

Used by ``benchmarks.run calibration`` and ``tests/test_risk.py``.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.sampler import generate_trajectories
from repro.data import vocab as V


def cohort_stats(trajs: Sequence[Tuple[np.ndarray, np.ndarray]]) -> Dict:
    death_age, rates, chapters = [], [], np.zeros(26)
    for tok, age in trajs:
        if V.DEATH in tok:
            death_age.append(age[-1])
        dis = tok >= V.DISEASE0
        if age[-1] > 1:
            rates.append(dis.sum() / age[-1])
        for c in tok[dis]:
            chapters[V.chapter_of(int(c))] += 1
    chapters = chapters / max(chapters.sum(), 1)
    return {"mean_death_age": float(np.mean(death_age)) if death_age else None,
            "death_frac": len(death_age) / max(len(trajs), 1),
            "events_per_year": float(np.mean(rates)) if rates else 0.0,
            "chapter_freq": chapters}


def generate_cohort(params, cfg: ModelConfig, seeds, *, from_age: float = 40.0,
                    max_new: int = 96, batch: int = 32) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Sample synthetic continuations from a minimal prompt (sex token at 0,
    NO_EVENT marker at ``from_age``)."""
    prompts_t = np.tile(np.array([[V.SEX_FEMALE, V.NO_EVENT]], np.int32),
                        (batch, 1))
    prompts_a = np.tile(np.array([[0.0, from_age]], np.float32), (batch, 1))
    out_trajs = []
    for seed in seeds:
        out = generate_trajectories(
            params, cfg, jnp.asarray(prompts_t), jnp.asarray(prompts_a),
            jax.random.PRNGKey(seed), max_new=max_new)
        toks = np.asarray(out["tokens"])[:, 2:]
        ages = np.asarray(out["ages"])[:, 2:]
        ngen = np.asarray(out["n_generated"])
        for b in range(batch):
            n = int(ngen[b])
            if n:
                out_trajs.append((toks[b, :n], ages[b, :n]))
    return out_trajs


def calibration_report(params, cfg: ModelConfig,
                       held_out: Sequence[Tuple[np.ndarray, np.ndarray]], *,
                       n_batches: int = 2) -> Dict:
    data = cohort_stats(held_out)
    model = cohort_stats(generate_cohort(params, cfg, range(n_batches)))
    l1 = float(np.abs(data["chapter_freq"] - model["chapter_freq"]).sum())
    return {"data": data, "model": model, "chapter_l1": l1}
