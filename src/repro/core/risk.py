"""Morbidity-risk estimation — the App's headline output.

The paper's SDK "performs Postprocessing, converting these results back into
human-readable morbidity risk estimates (events and ages in years)".  Two
estimators over the same model:

* ``analytic_next_event_risk`` — closed form from one forward pass: under the
  competing-exponential model the probability that code i is the next event
  within horizon h is

      P(i, t <= h) = (lambda_i / Lambda) * (1 - exp(-Lambda * h))

* ``monte_carlo_risk`` — unrolls the eq.-1 sampler N times and counts
  trajectories in which the code (or its ICD chapter) occurs within the
  horizon: the multi-event risk the App's right panel visualizes.

Both are exported through ``sdk.session.InferenceSession.estimate_risk`` so
the client-side path matches the paper's architecture.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.sampler import generate_trajectories_jit
from repro.models import forward


def analytic_next_event_risk(logits, horizon: float):
    """logits: (..., V) -> P(next event = i and it happens within horizon).

    Returns (..., V) probabilities summing to (1 - e^{-Lambda h}) <= 1.
    """
    log_l = logits.astype(jnp.float32)
    log_rate = jax.nn.logsumexp(log_l, axis=-1, keepdims=True)   # log Lambda
    frac = jax.nn.softmax(log_l, axis=-1)                        # lambda_i/Lambda
    p_any = 1.0 - jnp.exp(-jnp.exp(log_rate) * horizon)
    return frac * p_any


def analytic_next_event_risk_np(logits, horizon: float) -> np.ndarray:
    """Host-side fp64 twin of :func:`analytic_next_event_risk` for one (V,)
    logit vector — the client-side postprocessing path (``repro.api`` /
    ``InferenceSession.estimate_risk``)."""
    lg = np.asarray(logits).astype(np.float64)
    log_rate = np.logaddexp.reduce(lg)
    frac = np.exp(lg - log_rate)
    p_any = 1.0 - np.exp(-np.exp(log_rate) * horizon)
    return frac * p_any


def next_event_risk(params, cfg: ModelConfig, tokens, ages, *,
                    horizon: float = 5.0):
    """One forward pass -> (B, V) within-horizon next-event risks."""
    out = forward(params, cfg, {"tokens": tokens, "ages": ages}, mode="train")
    return analytic_next_event_risk(out["logits"][:, -1], horizon)


def monte_carlo_risk(params, cfg: ModelConfig, tokens, ages, rng=None, *,
                     horizon: float = 5.0, n_samples: int = 64,
                     max_new: int = 48,
                     chapter_of: Optional[jax.Array] = None,
                     uniforms: Optional[jax.Array] = None,
                     trajectories: Optional[Dict[str, jax.Array]] = None
                     ) -> Dict[str, jax.Array]:
    """Sampled multi-event risk for ONE patient — the N-futures oracle.

    tokens/ages: (S,) history.  All N futures are drawn through ONE
    compiled ``generate_trajectories_jit`` call (batched over the sample
    axis, not a host loop).  ``uniforms`` (n_samples, max_new, V) injects
    the sampling uniforms for determinism.  ``trajectories`` swaps the
    sampling backend entirely — pass
    :func:`engine_oracle_trajectories` output to aggregate futures drawn
    through the serving engine's exact compiled decode path, which is the
    bit-parity oracle configuration for ``BatchedEngine.sample_futures``
    (the engine's forked futures must match it bit for bit under injected
    uniforms).

    Returns dict with
      ``code_risk`` (V,)      P(code occurs within horizon)
      ``chapter_risk`` (C,)   P(any code of chapter occurs within horizon)
                              (when ``chapter_of`` (V,) int32 is given)
      ``death_risk`` ()       P(Death within horizon)
    """
    S = tokens.shape[0]
    if trajectories is None:
        t = jnp.broadcast_to(tokens[None], (n_samples, S))
        a = jnp.broadcast_to(ages[None], (n_samples, S))
        if rng is None:
            rng = jax.random.PRNGKey(0)
        u = None if uniforms is None else jnp.asarray(uniforms)
        out = generate_trajectories_jit(params, cfg, t, a, rng,
                                        max_new=max_new, uniforms=u)
    else:
        out = trajectories
        n_samples = out["tokens"].shape[0]
        max_new = out["alive_mask"].shape[1]
    gen_tok = out["tokens"][:, S:]                    # (N, max_new)
    gen_age = out["ages"][:, S:]
    within = out["alive_mask"] & (gen_age <= ages[-1] + horizon)
    onehot = jax.nn.one_hot(gen_tok, cfg.vocab_size, dtype=jnp.float32)
    occurred = jnp.max(onehot * within[..., None], axis=1)       # (N, V)
    code_risk = jnp.mean(occurred, axis=0)
    res = {"code_risk": code_risk,
           "death_risk": code_risk[cfg.death_token]}
    if chapter_of is not None:
        C = int(jnp.max(chapter_of)) + 1
        chap_onehot = jax.nn.one_hot(chapter_of, C, dtype=jnp.float32)
        chap_occ = jnp.clip(occurred @ chap_onehot, 0.0, 1.0)
        res["chapter_risk"] = jnp.mean(chap_occ, axis=0)
    return res


def engine_oracle_trajectories(params, cfg: ModelConfig, tokens, ages, *,
                               n_samples: int, max_new: int, uniforms,
                               slots: Optional[int] = None,
                               max_context: int = 512,
                               **oracle_kw) -> Dict[str, jax.Array]:
    """N futures drawn through the serving engine's exact compiled decode
    path (``repro.serve.prefix.ring_reference_futures``), packed into the
    ``generate_trajectories`` output format so :func:`monte_carlo_risk`
    can aggregate them via ``trajectories=``.

    This is the bit-parity oracle configuration: under the same injected
    ``uniforms`` (n_samples, max_new, V) and matching engine geometry
    (``slots``/``max_context``/...), ``BatchedEngine.sample_futures`` —
    fork, copy-on-write, prefix sharing and all — must reproduce these
    trajectories bit for bit.
    """
    from repro.serve.prefix import ring_reference_futures   # lazy: core
    toks = np.asarray(tokens)                               # stays below
    ags = np.asarray(ages)                                  # serve
    futs = ring_reference_futures(
        params, cfg, toks, ags, n=n_samples, max_new=max_new,
        uniforms=uniforms, slots=slots, max_context=max_context, **oracle_kw)
    return pack_futures_trajectories(toks, ags, futs, max_new=max_new)


def pack_futures_trajectories(tokens, ages,
                              futures: Sequence[Tuple[Sequence[int],
                                                      Sequence[float]]],
                              *, max_new: int) -> Dict[str, jax.Array]:
    """Pack N generated futures (new tokens/ages only, variable length)
    over one shared (S,) history into the ``generate_trajectories`` output
    format, so :func:`monte_carlo_risk` can aggregate them via
    ``trajectories=``.  Shared by the engine bit-parity oracle above and
    the cohort scenario engine's sweep aggregation."""
    toks = np.asarray(tokens)
    ags = np.asarray(ages)
    S = len(toks)
    n_samples = len(futures)
    tok_buf = np.zeros((n_samples, S + max_new), np.int64)
    age_buf = np.zeros((n_samples, S + max_new), np.float32)
    alive = np.zeros((n_samples, max_new), bool)
    tok_buf[:, :S] = toks
    age_buf[:, :S] = ags
    for j, (ts, as_) in enumerate(futures):
        k = len(ts)
        tok_buf[j, S:S + k] = ts
        age_buf[j, S:S + k] = np.asarray(as_, np.float32)
        age_buf[j, S + k:] = (as_[-1] if k else ags[-1])
        alive[j, :k] = True
    return {"tokens": jnp.asarray(tok_buf), "ages": jnp.asarray(age_buf),
            "alive_mask": jnp.asarray(alive),
            "n_generated": jnp.asarray([len(t) for t, _ in futures],
                                       jnp.int32)}


def futures_risk_items(trajectories: Sequence[Tuple[Sequence[int],
                                                    Sequence[float]]],
                       age0: float, horizon: float, vocab_size: int,
                       top: int = 10) -> List[Tuple[int, float]]:
    """Host-side aggregation of N sampled futures into within-horizon
    code risks: P(code) = fraction of futures in which the code occurs at
    an age <= age0 + horizon.  The ONE aggregation every ``sample_futures``
    backend shares (engine, remote server side, local, artifact), so
    reports are identical whenever the trajectories are.

    The cutoff comparison runs in fp32 — the same arithmetic as the
    in-graph ``monte_carlo_risk`` mask, so boundary events land on the
    same side in both.  Futures without ages (generic-LM configs) count
    every generated token.

    Returns ``[(token, risk), ...]`` sorted by risk, highest first, top-k.
    """
    n = max(len(trajectories), 1)
    cutoff = np.float32(np.float32(age0) + np.float32(horizon))
    counts = np.zeros(vocab_size, np.int64)
    for toks, ags in trajectories:
        if ags is not None and len(ags):     # len(), not truthiness: ages
            seen = {int(t) for t, a in zip(toks, ags)   # may be np arrays
                    if np.float32(a) <= cutoff}
        else:
            seen = {int(t) for t in toks}
        for t in seen:
            if 0 <= t < vocab_size:
                counts[t] += 1
    risk = counts / float(n)
    order = np.argsort(-risk, kind="stable")[:top]
    return [(int(i), float(risk[i])) for i in order]


def futures_chapter_risk(trajectories: Sequence[Tuple[Sequence[int],
                                                      Sequence[float]]],
                         age0: float, horizon: float,
                         vocab_size: int) -> np.ndarray:
    """Host-side per-chapter within-horizon risk over N sampled futures:
    P(chapter) = fraction of futures in which ANY code of the chapter
    occurs at an age <= age0 + horizon.  Same fp32 cutoff arithmetic as
    :func:`futures_risk_items` and the same chapter collapse as
    ``monte_carlo_risk(chapter_of=disease_chapter_map(V))``, so cohort
    aggregation matches the in-graph ``chapter_risk`` exactly.

    Returns (C,) float64 with index 0 the non-disease bucket and
    chapters 1.. the ICD chapters (``disease_chapter_map`` convention).
    """
    chap = disease_chapter_map_np(vocab_size)
    C = int(chap.max()) + 1
    n = max(len(trajectories), 1)
    cutoff = np.float32(np.float32(age0) + np.float32(horizon))
    counts = np.zeros(C, np.int64)
    for toks, ags in trajectories:
        if ags is not None and len(ags):
            seen = {int(t) for t, a in zip(toks, ags)
                    if np.float32(a) <= cutoff}
        else:
            seen = {int(t) for t in toks}
        for c in {int(chap[t]) for t in seen if 0 <= t < vocab_size}:
            counts[c] += 1
    return counts / float(n)


def disease_chapter_map_np(vocab_size: int) -> np.ndarray:
    """(V,) chapter index per token (specials/lifestyle -> chapter 0-pad),
    host-side — the cohort aggregation path, which must stay free of
    device values (RL006)."""
    from repro.data import vocab as V
    out = np.zeros(vocab_size, np.int32)
    for c in range(V.DISEASE0, min(vocab_size, V.VOCAB_SIZE)):
        out[c] = V.chapter_of(c) + 1     # 0 reserved for non-disease
    return out


def disease_chapter_map(vocab_size: int):
    """Device twin of :func:`disease_chapter_map_np` for
    ``monte_carlo_risk(chapter_of=...)``."""
    return jnp.asarray(disease_chapter_map_np(vocab_size))
