"""Morbidity-risk estimation — the App's headline output.

The paper's SDK "performs Postprocessing, converting these results back into
human-readable morbidity risk estimates (events and ages in years)".  Two
estimators over the same model:

* ``analytic_next_event_risk`` — closed form from one forward pass: under the
  competing-exponential model the probability that code i is the next event
  within horizon h is

      P(i, t <= h) = (lambda_i / Lambda) * (1 - exp(-Lambda * h))

* ``monte_carlo_risk`` — unrolls the eq.-1 sampler N times and counts
  trajectories in which the code (or its ICD chapter) occurs within the
  horizon: the multi-event risk the App's right panel visualizes.

Both are exported through ``sdk.session.InferenceSession.estimate_risk`` so
the client-side path matches the paper's architecture.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.sampler import generate_trajectories
from repro.models import forward


def analytic_next_event_risk(logits, horizon: float):
    """logits: (..., V) -> P(next event = i and it happens within horizon).

    Returns (..., V) probabilities summing to (1 - e^{-Lambda h}) <= 1.
    """
    log_l = logits.astype(jnp.float32)
    log_rate = jax.nn.logsumexp(log_l, axis=-1, keepdims=True)   # log Lambda
    frac = jax.nn.softmax(log_l, axis=-1)                        # lambda_i/Lambda
    p_any = 1.0 - jnp.exp(-jnp.exp(log_rate) * horizon)
    return frac * p_any


def analytic_next_event_risk_np(logits, horizon: float) -> np.ndarray:
    """Host-side fp64 twin of :func:`analytic_next_event_risk` for one (V,)
    logit vector — the client-side postprocessing path (``repro.api`` /
    ``InferenceSession.estimate_risk``)."""
    lg = np.asarray(logits).astype(np.float64)
    log_rate = np.logaddexp.reduce(lg)
    frac = np.exp(lg - log_rate)
    p_any = 1.0 - np.exp(-np.exp(log_rate) * horizon)
    return frac * p_any


def next_event_risk(params, cfg: ModelConfig, tokens, ages, *,
                    horizon: float = 5.0):
    """One forward pass -> (B, V) within-horizon next-event risks."""
    out = forward(params, cfg, {"tokens": tokens, "ages": ages}, mode="train")
    return analytic_next_event_risk(out["logits"][:, -1], horizon)


def monte_carlo_risk(params, cfg: ModelConfig, tokens, ages, rng, *,
                     horizon: float = 5.0, n_samples: int = 64,
                     max_new: int = 48,
                     chapter_of: Optional[jax.Array] = None
                     ) -> Dict[str, jax.Array]:
    """Sampled multi-event risk for ONE patient.

    tokens/ages: (S,) history.  Returns dict with
      ``code_risk`` (V,)      P(code occurs within horizon)
      ``chapter_risk`` (C,)   P(any code of chapter occurs within horizon)
                              (when ``chapter_of`` (V,) int32 is given)
      ``death_risk`` ()       P(Death within horizon)
    """
    S = tokens.shape[0]
    t = jnp.broadcast_to(tokens[None], (n_samples, S))
    a = jnp.broadcast_to(ages[None], (n_samples, S))
    out = generate_trajectories(params, cfg, t, a, rng, max_new=max_new)
    gen_tok = out["tokens"][:, S:]                    # (N, max_new)
    gen_age = out["ages"][:, S:]
    within = out["alive_mask"] & (gen_age <= ages[-1] + horizon)
    onehot = jax.nn.one_hot(gen_tok, cfg.vocab_size, dtype=jnp.float32)
    occurred = jnp.max(onehot * within[..., None], axis=1)       # (N, V)
    code_risk = jnp.mean(occurred, axis=0)
    res = {"code_risk": code_risk,
           "death_risk": code_risk[cfg.death_token]}
    if chapter_of is not None:
        C = int(jnp.max(chapter_of)) + 1
        chap_onehot = jax.nn.one_hot(chapter_of, C, dtype=jnp.float32)
        chap_occ = jnp.clip(occurred @ chap_onehot, 0.0, 1.0)
        res["chapter_risk"] = jnp.mean(chap_occ, axis=0)
    return res


def disease_chapter_map(vocab_size: int):
    """(V,) chapter index per token (specials/lifestyle -> chapter 0-pad)."""
    from repro.data import vocab as V
    import numpy as np
    out = np.zeros(vocab_size, np.int32)
    for c in range(V.DISEASE0, min(vocab_size, V.VOCAB_SIZE)):
        out[c] = V.chapter_of(c) + 1     # 0 reserved for non-disease
    return jnp.asarray(out)
