"""The paper's contribution: Delphi-2M + dual loss + time-to-event sampling,
plus the risk-estimation and calibration layers the App exposes."""
from repro.core.calibration import calibration_report, cohort_stats
from repro.core.delphi import get_logits, init_delphi, loss_fn
from repro.core.losses import dual_loss, event_ce, joint_nll, time_nll
from repro.core.risk import (analytic_next_event_risk,
                             analytic_next_event_risk_np, disease_chapter_map,
                             engine_oracle_trajectories, futures_risk_items,
                             monte_carlo_risk, next_event_risk)
from repro.core.sampler import (advance_trajectory_state,
                                generate_trajectories,
                                generate_trajectories_jit,
                                sample_next_event, sample_next_event_np,
                                sample_waiting_times)

__all__ = [
    "calibration_report", "cohort_stats",
    "get_logits", "init_delphi", "loss_fn",
    "dual_loss", "event_ce", "joint_nll", "time_nll",
    "analytic_next_event_risk", "analytic_next_event_risk_np",
    "disease_chapter_map", "engine_oracle_trajectories",
    "futures_risk_items", "monte_carlo_risk", "next_event_risk",
    "advance_trajectory_state", "generate_trajectories",
    "generate_trajectories_jit", "sample_next_event", "sample_next_event_np",
    "sample_waiting_times",
]
