"""Competing-exponential time-to-event trajectory generation (paper eq. 1).

For each vocabulary entry the sampler draws a candidate waiting time

    t_i = -exp(-logit_i) * ln(u_i),    u_i ~ U(0,1)

and takes the argmin — the next event — advancing patient age by t_min.
Generation stops at the Death token or when age exceeds ``max_age`` (85 years
by default, both overridable — exactly the knobs the paper's JS SDK exposes).

Two equivalent consumers exist:
* this module — in-graph batched generation (``lax.fori_loop`` over decode
  steps against the KV cache) used by the serving engine and benchmarks;
* ``repro.sdk.session`` — host-side NumPy generation against the exported
  artifact, mirroring the paper's client-side JS SDK.
Determinism contract: both accept pre-drawn uniforms, so SDK-vs-core parity is
bit-exact and testable (claim C2/C3).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import decode_step, forward


def sample_waiting_times(logits, u):
    """t_i = -exp(-logit_i) * ln(u_i).  logits, u: (..., V) fp32."""
    u = jnp.clip(u, 1e-12, 1.0 - 1e-12)
    return -jnp.exp(-logits) * jnp.log(u)


def sample_next_event(logits, u):
    """Returns (event id (...,), waiting time t_min (...,))."""
    t = sample_waiting_times(logits, u)
    idx = jnp.argmin(t, axis=-1)
    tmin = jnp.take_along_axis(t, idx[..., None], axis=-1)[..., 0]
    return idx.astype(jnp.int32), tmin


def sample_next_event_np(logits, u):
    """Host-side NumPy twin of :func:`sample_next_event` (one trajectory).

    The single eq.-1 implementation behind every host-side client loop
    (``repro.api`` backends and the ``InferenceSession`` shim), so SDK-vs-core
    parity rests on ONE pair of functions.  ``u`` keeps its incoming dtype
    (injected fp32 uniforms stay fp32 through the log, matching the in-graph
    sampler's arithmetic); logits are promoted to fp64 like the paper's JS
    client.  Returns (event id, waiting time t_min) as Python scalars.
    """
    lg = np.asarray(logits).astype(np.float64)
    u = np.clip(u, 1e-12, 1 - 1e-12)
    t = -np.exp(-lg) * np.log(u)
    evt = int(np.argmin(t))
    return evt, float(t[evt])


def advance_trajectory_state(evt, tmin, age, n_emitted, max_new, next_pos,
                             active, *, max_age: float, death_token: int,
                             max_context: int):
    """Canonical per-step termination/emit semantics of the paper's sampler.

    The single source of truth shared by the serving engine's in-graph tick
    and (behaviourally) the SDK's host loop: an event whose waiting time
    pushes age past ``max_age`` is *censored* — the trajectory ends BEFORE
    the event is emitted (claim C2/C3 parity; ``InferenceSession.
    generate_trajectory`` breaks before appending).  Death is emitted, then
    terminates.  All inputs/outputs are (B,) arrays; ``next_pos`` is the
    absolute position where each trajectory's next decode write would land.

    Returns dict with ``evt`` (0 where not emitted), ``age``, ``emit``,
    ``finished``, ``n_emitted``.
    """
    new_age = age + tmin
    over = new_age > max_age
    emit = active & ~over
    evt = jnp.where(emit, evt, 0)
    age_out = jnp.where(emit, new_age, age)
    n_out = n_emitted + emit.astype(n_emitted.dtype)
    ctx_full = next_pos + 1 >= max_context
    finished = active & (over | (emit & (evt == death_token))
                         | (n_out >= max_new) | ctx_full)
    return {"evt": evt, "age": age_out, "emit": emit, "finished": finished,
            "n_emitted": n_out}


def generate_trajectories(params, cfg: ModelConfig, tokens, ages, rng, *,
                          max_new: int = 64, max_age: Optional[float] = None,
                          death_token: Optional[int] = None,
                          uniforms: Optional[jax.Array] = None,
                          cache_width: Optional[int] = None
                          ) -> Dict[str, jax.Array]:
    """Batched trajectory generation.

    tokens/ages: (B, S) prompt (the patient's known history).  Returns dict
    with ``tokens``/``ages`` (B, S+max_new) (padded with 0 / last age after
    termination), ``n_generated`` (B,), ``alive_mask`` (B, max_new).

    uniforms: optional (B, max_new, V) pre-drawn U(0,1) — injected for
    SDK-parity tests; otherwise drawn from ``rng`` (threefry) in-graph.
    """
    max_age = cfg.max_age if max_age is None else max_age
    death = cfg.death_token if death_token is None else death_token
    B, S = tokens.shape
    V = cfg.vocab_size
    W = cache_width or (S + max_new)

    pre = forward(params, cfg, {"tokens": tokens, "ages": ages},
                  mode="prefill", cache_width=W)
    cache = pre["cache"]
    logits0 = pre["logits"][:, -1]                     # (B, V)

    tok_buf = jnp.concatenate(
        [tokens, jnp.zeros((B, max_new), tokens.dtype)], axis=1)
    age_buf = jnp.concatenate(
        [ages, jnp.broadcast_to(ages[:, -1:], (B, max_new)).astype(ages.dtype)],
        axis=1)
    alive0 = jnp.ones((B,), bool)
    alive_hist0 = jnp.zeros((B, max_new), bool)

    def body(i, state):
        cache, logits, tok_buf, age_buf, alive, alive_hist, rng, n_gen = state
        rng, kr = jax.random.split(rng)
        if uniforms is not None:
            u = uniforms[:, i]
        else:
            u = jax.random.uniform(kr, (B, V))
        evt, tmin = sample_next_event(logits, u)
        new_age = age_buf[:, S + i - 1] + tmin
        # termination BEFORE emitting: an event past max_age is censored
        over_age = new_age > max_age
        emit = alive & ~over_age
        evt = jnp.where(emit, evt, 0)
        new_age = jnp.where(emit, new_age, age_buf[:, S + i - 1])
        tok_buf = tok_buf.at[:, S + i].set(jnp.where(emit, evt, tok_buf[:, S + i]))
        age_buf = age_buf.at[:, S + i].set(new_age)
        alive_hist = alive_hist.at[:, i].set(emit)
        n_gen = n_gen + emit.astype(jnp.int32)
        alive = emit & (evt != death)
        d = decode_step(params, cfg,
                        cache, {"tokens": evt[:, None], "ages": new_age[:, None]},
                        jnp.int32(S) + i)
        return (d["cache"], d["logits"][:, 0], tok_buf, age_buf, alive,
                alive_hist, rng, n_gen)

    state = (cache, logits0, tok_buf, age_buf, alive0, alive_hist0, rng,
             jnp.zeros((B,), jnp.int32))
    state = jax.lax.fori_loop(0, max_new, body, state)
    _, _, tok_buf, age_buf, _, alive_hist, _, n_gen = state
    return {"tokens": tok_buf, "ages": age_buf, "n_generated": n_gen,
            "alive_mask": alive_hist}


@functools.partial(jax.jit, static_argnames=("cfg", "max_new", "cache_width",
                                             "max_age", "death_token"))
def generate_trajectories_jit(params, cfg: ModelConfig, tokens, ages, rng, *,
                              max_new: int = 64,
                              cache_width: Optional[int] = None,
                              max_age: Optional[float] = None,
                              death_token: Optional[int] = None,
                              uniforms: Optional[jax.Array] = None):
    """Jitted :func:`generate_trajectories`.  ``uniforms`` (B, max_new, V)
    may be injected for deterministic batched generation — the vectorized
    Monte-Carlo risk path draws all N futures through ONE compiled call."""
    return generate_trajectories(params, cfg, tokens, ages, rng,
                                 max_new=max_new, cache_width=cache_width,
                                 max_age=max_age, death_token=death_token,
                                 uniforms=uniforms)
