"""Shared neural-net layers: norms, RoPE, MLPs, embeddings.

Parameters are plain pytrees (nested dicts of ``jnp.ndarray``).  Every layer is
a pair of functions ``init_*(key, cfg, ...) -> params`` and a pure apply
function.  Compute dtype follows ``cfg.dtype``; parameters are kept in
``cfg.param_dtype`` and cast at use (the TPU-standard mixed-precision recipe).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def act_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def param_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def init_norm(cfg: ModelConfig, d: int):
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.ones((d,), param_dtype(cfg))}
    return {"scale": jnp.ones((d,), param_dtype(cfg)),
            "bias": jnp.zeros((d,), param_dtype(cfg))}


def apply_norm(params, x, cfg: ModelConfig, eps: float = 1e-5):
    """RMSNorm / LayerNorm computed in fp32, cast back to the activation dtype."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    if "bias" in params:  # layernorm
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(ms + eps)
        y = y * params["scale"].astype(jnp.float32)
    return y.astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embedding (applied on absolute positions so ring-buffer
# caches stay correct at any context offset).
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freqs = rope_frequencies(head_dim, theta)                     # (half,)
    angles = positions.astype(jnp.float32)[..., None] * freqs     # (..., seq, half)
    cos = jnp.cos(angles)[..., None, :]                           # (..., seq, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Continuous age encoding (Delphi-2M): sinusoidal features of patient age at
# each event, replacing discrete positional encodings.  Ages are in years;
# frequencies span ~days to ~centuries.
# ---------------------------------------------------------------------------
def age_encoding(ages, d_model: int, min_scale: float = 1e-3, max_scale: float = 200.0):
    """ages: (..., seq) float years -> (..., seq, d_model)."""
    half = d_model // 2
    log_inc = jnp.log(max_scale / min_scale) / max(half - 1, 1)
    inv_scales = (1.0 / min_scale) * jnp.exp(-log_inc * jnp.arange(half, dtype=jnp.float32))
    angles = ages.astype(jnp.float32)[..., None] * inv_scales     # (..., seq, half)
    enc = jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], axis=-1)
    if enc.shape[-1] < d_model:  # odd d_model
        enc = jnp.pad(enc, [(0, 0)] * (enc.ndim - 1) + [(0, d_model - enc.shape[-1])])
    return enc


# ---------------------------------------------------------------------------
# MLP (SwiGLU for llama-family, GELU for GPT/nanoGPT/seamless family)
# ---------------------------------------------------------------------------
def init_mlp(key, cfg: ModelConfig, d: int, d_ff: int):
    pdt = param_dtype(cfg)
    s_in = d ** -0.5
    s_ff = d_ff ** -0.5
    if cfg.activation == "swiglu":
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "w_gate": (jax.random.normal(k1, (d, d_ff)) * s_in).astype(pdt),
            "w_up": (jax.random.normal(k2, (d, d_ff)) * s_in).astype(pdt),
            "w_down": (jax.random.normal(k3, (d_ff, d)) * s_ff).astype(pdt),
        }
    k1, k2 = jax.random.split(key)
    return {
        "w_fc": (jax.random.normal(k1, (d, d_ff)) * s_in).astype(pdt),
        "b_fc": jnp.zeros((d_ff,), pdt),
        "w_proj": (jax.random.normal(k2, (d_ff, d)) * s_ff).astype(pdt),
        "b_proj": jnp.zeros((d,), pdt),
    }


def apply_mlp(params, x, cfg: ModelConfig):
    dt = x.dtype
    if "w_gate" in params:
        g = x @ params["w_gate"].astype(dt)
        u = x @ params["w_up"].astype(dt)
        h = jax.nn.silu(g) * u
        return h @ params["w_down"].astype(dt)
    h = x @ params["w_fc"].astype(dt) + params["b_fc"].astype(dt)
    h = jax.nn.gelu(h)
    return h @ params["w_proj"].astype(dt) + params["b_proj"].astype(dt)


# ---------------------------------------------------------------------------
# Embeddings / output head
# ---------------------------------------------------------------------------
def init_embed(key, cfg: ModelConfig):
    pdt = param_dtype(cfg)
    p = {"embed": (jax.random.normal(key, (cfg.vocab_size, cfg.d_model)) * 0.02).astype(pdt)}
    if not cfg.tie_embeddings:
        k2 = jax.random.fold_in(key, 1)
        p["lm_head"] = (jax.random.normal(k2, (cfg.d_model, cfg.vocab_size))
                        * cfg.d_model ** -0.5).astype(pdt)
    if cfg.dual_head:
        # logits are log-hazards (1/years); start rates low so the initial
        # total rate Lambda = sum e^{logit} is O(0.1/yr), not O(vocab)
        p["out_bias"] = jnp.full((cfg.vocab_size,), -8.0, pdt)
    return p


def embed_tokens(params, tokens, cfg: ModelConfig):
    return params["embed"].astype(act_dtype(cfg))[tokens]


def logits_head(params, h, cfg: ModelConfig):
    if cfg.tie_embeddings:
        w = params["embed"].astype(h.dtype).T
    else:
        w = params["lm_head"].astype(h.dtype)
    # logits in fp32 for numerically stable losses / sampling
    logits = (h @ w).astype(jnp.float32)
    if "out_bias" in params:
        logits = logits + params["out_bias"].astype(jnp.float32)
    return logits
