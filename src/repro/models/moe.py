"""Mixture-of-Experts layer: top-k router, shared experts, load-balance loss.

Two dispatch implementations with identical semantics (equivalence is
property-tested):

* ``dense_scan`` (baseline): ``lax.scan`` over experts, each expert computes
  over all tokens and results are combined with the (mostly-zero) router
  weights.  Always compiles, memory-light, but does E/top_k times the active
  FLOPs — the roofline MODEL_FLOPS/HLO_FLOPs ratio exposes this and the §Perf
  hillclimb replaces it.
* ``ragged`` (optimized): tokens are sorted by expert id and run through
  ``lax.ragged_dot`` grouped matmuls — active-FLOPs-only compute.  On TPU this
  maps to the native grouped-matmul; token sort/gather stays shard-local when
  wrapped in shard_map by the launcher.

Routing follows the qwen/olmoe recipe: softmax over router logits, top-k,
renormalized combine weights; auxiliary load-balance loss (Switch-style
``E * sum_e f_e * p_e``) is returned to the caller.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import init_mlp, apply_mlp, param_dtype


def init_moe(key, cfg: ModelConfig):
    pdt = param_dtype(cfg)
    d, E, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    k_r, k_e, k_s = jax.random.split(key, 3)
    s_in, s_ff = d ** -0.5, f ** -0.5
    ke1, ke2, ke3 = jax.random.split(k_e, 3)
    p = {
        "router": (jax.random.normal(k_r, (d, E)) * s_in).astype(pdt),
        "w_gate": (jax.random.normal(ke1, (E, d, f)) * s_in).astype(pdt),
        "w_up": (jax.random.normal(ke2, (E, d, f)) * s_in).astype(pdt),
        "w_down": (jax.random.normal(ke3, (E, f, d)) * s_ff).astype(pdt),
    }
    if cfg.n_shared_experts:
        # shared experts fused into one always-on MLP of combined width
        p["shared"] = init_mlp(k_s, cfg.replace(activation="swiglu"),
                               d, cfg.n_shared_experts * f)
    return p


def route(params, x, cfg: ModelConfig):
    """x: (T, d) -> (weights (T, k), experts (T, k) int32, aux_loss scalar)."""
    logits = (x.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                     # (T, E)
    weights, experts = jax.lax.top_k(probs, cfg.top_k)          # (T, k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    # Switch-style load-balance aux loss
    E = cfg.n_experts
    onehot = jax.nn.one_hot(experts, E, dtype=jnp.float32)      # (T, k, E)
    frac_tokens = jnp.mean(jnp.sum(onehot, axis=1), axis=0)     # f_e
    frac_probs = jnp.mean(probs, axis=0)                        # p_e
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return weights, experts, aux


def _expert_mlp(w_gate, w_up, w_down, x):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def moe_dense_scan(params, x, cfg: ModelConfig):
    """Baseline dispatch: scan over experts, weighted combine."""
    T, d = x.shape
    dt = x.dtype
    weights, experts, aux = route(params, x, cfg)
    # combine weight of expert e for token t: (T, E), mostly zero
    combine = jnp.zeros((T, cfg.n_experts), dt).at[
        jnp.arange(T)[:, None], experts].set(weights.astype(dt))

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def body(acc, wexp):
        wg, wu, wd, ce = wexp
        y = _expert_mlp(wg.astype(dt), wu.astype(dt), wd.astype(dt), x)
        return acc + y * ce[:, None], None

    acc0 = jnp.zeros_like(x)
    if cfg.unroll_layers:   # cost-accounting mode: exact FLOP counts
        acc = acc0
        for e in range(cfg.n_experts):
            acc, _ = body(acc, (params["w_gate"][e], params["w_up"][e],
                                params["w_down"][e], combine.T[e]))
        return acc, aux
    out, _ = jax.lax.scan(
        body, acc0,
        (params["w_gate"], params["w_up"], params["w_down"], combine.T))
    return out, aux


def moe_ragged(params, x, cfg: ModelConfig):
    """Optimized dispatch: sort by expert + grouped (ragged) matmuls.

    Token order within an expert group follows the stable argsort, so the
    scatter-add back is exact.  Designed to sit inside shard_map so the sort
    is shard-local on TPU.
    """
    T, d = x.shape
    dt = x.dtype
    E, k = cfg.n_experts, cfg.top_k
    weights, experts, aux = route(params, x, cfg)

    flat_expert = experts.reshape(-1)                   # (T*k,)
    flat_weight = weights.reshape(-1)                   # (T*k,)
    flat_token = jnp.repeat(jnp.arange(T), k)           # (T*k,)
    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    group_sizes = jnp.bincount(sorted_expert, length=E).astype(jnp.int32)

    xs = x[sorted_token]                                # (T*k, d)
    h = (jax.nn.silu(jax.lax.ragged_dot(xs, params["w_gate"].astype(dt), group_sizes))
         * jax.lax.ragged_dot(xs, params["w_up"].astype(dt), group_sizes))
    ys = jax.lax.ragged_dot(h, params["w_down"].astype(dt), group_sizes)  # (T*k, d)
    ys = ys * flat_weight[order][:, None].astype(dt)
    out = jnp.zeros((T, d), dt).at[sorted_token].add(ys)
    return out, aux


def moe_dense_einsum(params, x, cfg: ModelConfig):
    """Decode-path dispatch: all experts via one einsum, combine contracting
    the (model-sharded) expert dim.

    Expert weights stay sharded on E; outputs are reduced across the model
    axis (an all-reduce of (T, d) — KBs at decode) instead of the weight
    all-gather that slicing a sharded expert stack forces (GBs).  Memory is
    O(T * E * f), so this is for small-T (decode) only.
    """
    T, d = x.shape
    dt = x.dtype
    weights, experts, aux = route(params, x, cfg)
    combine = jnp.zeros((T, cfg.n_experts), dt).at[
        jnp.arange(T)[:, None], experts].set(weights.astype(dt))
    h = (jax.nn.silu(jnp.einsum("td,edf->tef", x, params["w_gate"].astype(dt)))
         * jnp.einsum("td,edf->tef", x, params["w_up"].astype(dt)))
    y = jnp.einsum("tef,efd->ted", h, params["w_down"].astype(dt))
    out = jnp.einsum("ted,te->td", y, combine)
    return out, aux


def _ambient_mesh():
    try:
        from jax._src.mesh import thread_resources
        mesh = thread_resources.env.physical_mesh
        return mesh if mesh.devices.size > 1 else None
    except Exception:  # noqa: BLE001
        return None


def moe_ragged_local(params, x, cfg: ModelConfig):
    """Shard-local ragged dispatch (the §Perf fix for the global-sort blowup).

    shard_map pins the token dim to the data axes, so argsort / gather /
    scatter stay device-local; expert weights remain on the auto "model" axis
    (f-dim or expert-dim sharded) and the grouped matmuls partition over it.
    Falls back to the plain ragged path off-mesh (CPU tests).
    """
    mesh = _ambient_mesh()
    if mesh is None:
        return moe_ragged(params, x, cfg)
    from jax.sharding import PartitionSpec as P
    da = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def body(xs, p):
        y, aux = moe_ragged(p, xs, cfg)
        return y, aux[None]

    y, aux = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(da, None), P()),
        out_specs=(P(da, None), P(da)),
        check_vma=False, axis_names=set(da))(x, params)
    return y, jnp.mean(aux)


def apply_moe(params, x, cfg: ModelConfig, impl: str = "dense_scan"
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y (B, S, d), aux loss scalar)."""
    B, S, d = x.shape
    flat = x.reshape(B * S, d)
    if impl == "ragged":
        y, aux = moe_ragged(params, flat, cfg)
    elif impl == "ragged_local":
        y, aux = moe_ragged_local(params, flat, cfg)
    elif impl == "dense_einsum":
        y, aux = moe_dense_einsum(params, flat, cfg)
    else:
        y, aux = moe_dense_scan(params, flat, cfg)
    if cfg.n_shared_experts:
        y = y + apply_mlp(params["shared"], flat, cfg)
    return y.reshape(B, S, d), aux
