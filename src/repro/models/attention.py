"""Attention: GQA / MHA, sliding-window, cross-attention, and KV caches.

Design notes
------------
* The jnp path implements **online-softmax chunked attention** (the same
  algorithm as the Pallas flash kernel in ``repro.kernels``) so that the
  lowered HLO never materializes an (S, T) score matrix — mandatory for the
  32k prefill dry-runs to fit on-device memory.  The inner KV-block body is
  rematerialized (``jax.checkpoint``) so the backward pass is flash-like too.
* One **unified ring cache** covers full-cache decode and sliding-window
  decode: a cache of width ``W`` with per-slot absolute positions.  Writing
  slot ``step % W`` makes a full cache (``W >= context``) and an SWA ring
  (``W == window``) the same code path.  Keys are stored *post-RoPE* (RoPE is
  applied on absolute positions, so relative offsets remain exact at any
  context depth — this is what makes long_500k ring decoding valid).
* GQA: queries are grouped ``(n_kv_heads, q_per_kv)``; KV is never repeated
  in memory.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, param_dtype

NEG_INF = -1e30


class LayerCache(NamedTuple):
    """Per-layer decode cache (stacked on a leading layer axis by the model)."""
    k: jax.Array          # (B, Hkv, W, hd)  roped keys
    v: jax.Array          # (B, Hkv, W, hd)
    pos: jax.Array        # (B, W) int32 absolute position per slot, -1 = empty


class PagedCache(NamedTuple):
    """Paged decode cache: one shared block pool + per-slot block tables.

    Instead of a dense per-slot ring (``LayerCache`` stacked to
    ``(L, B, Hkv, W, hd)``), K/V live in a pool of fixed-size blocks that a
    host-side allocator hands out on demand, so resident cache memory scales
    with *tokens actually held*, not ``slots x max_context`` worst case —
    the serving lever for Delphi's short-median/long-tail trajectories.

    Leaves:
      k, v  : (L, num_blocks, Hkv, block_size, hd) — the shared pool.
              Block 0 is the **trash block**: writes of slots with no
              allocated destination land there and are never read back.
      pos   : (num_blocks, block_size) int32 absolute positions, -1 = empty.
              Layer-independent (every layer writes the same positions).
      table : (B, blocks_per_slot) int32 pool block ids, -1 = unallocated.

    The logical layout is *exactly* the ring cache factored through one
    indirection: with ``W = blocks_per_slot * block_size``, the token at
    absolute position ``p`` of slot ``b`` lives at
    ``pool[table[b, (p % W) // block_size], p % block_size]`` — the same
    ``p % W`` ring slot the dense cache uses.  ``paged_gather_layer``
    therefore reconstructs a bit-identical ``LayerCache`` view, which is
    what makes the paged engine's trajectories bit-equal to the ring
    engine's under injected uniforms.
    """
    k: jax.Array
    v: jax.Array
    pos: jax.Array
    table: jax.Array


class PagedLayerView(NamedTuple):
    """One layer's slice of a :class:`PagedCache` (the shared ``pos`` /
    ``table`` plus that layer's pool planes) — what the decode layer scan
    hands to :func:`decode_attention`."""
    k: jax.Array          # (num_blocks, Hkv, block_size, hd)
    v: jax.Array
    pos: jax.Array        # (num_blocks, block_size)
    table: jax.Array      # (B, blocks_per_slot)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig, cross: bool = False):
    pdt = param_dtype(cfg)
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": (jax.random.normal(k1, (d, hq, hd)) * s).astype(pdt),
        "wk": (jax.random.normal(k2, (d, hkv, hd)) * s).astype(pdt),
        "wv": (jax.random.normal(k3, (d, hkv, hd)) * s).astype(pdt),
        "wo": (jax.random.normal(k4, (hq, hd, d)) * (hq * hd) ** -0.5).astype(pdt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq, hd), pdt)
        p["bk"] = jnp.zeros((hkv, hd), pdt)
        p["bv"] = jnp.zeros((hkv, hd), pdt)
    return p


def _project_qkv(params, xq, xkv, cfg: ModelConfig):
    dt = xq.dtype
    q = jnp.einsum("bsd,dhk->bshk", xq, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", xkv, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", xkv, params["wv"].astype(dt))
    if "bq" in params:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    return q, k, v


# ---------------------------------------------------------------------------
# Online-softmax chunked attention (jnp flash)
# ---------------------------------------------------------------------------
def _pad_to(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


def chunked_attention(q, k, v, q_pos, k_pos, *, causal: bool,
                      window: Optional[int], q_block: int = 512,
                      kv_block: int = 512, q_per_kv: int = 1,
                      unroll: bool = False):
    """q: (B, Sq, Hq, hd); k, v: (B, Skv, Hkv, hd); *_pos int32 (B, S*) or (S*,).

    Invalid KV slots are marked with k_pos < 0.  Returns (B, Sq, Hq, hd).
    """
    B, Sq, Hq, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = q_per_kv
    assert Hq == Hkv * G
    scale = hd ** -0.5

    if q_pos.ndim == 1:
        q_pos = jnp.broadcast_to(q_pos[None], (B, Sq))
    if k_pos.ndim == 1:
        k_pos = jnp.broadcast_to(k_pos[None], (B, Skv))

    if Sq * Skv <= q_block * kv_block:
        # small problem: one dense masked block (cheaper than scan machinery)
        qg = q.reshape(B, Sq, Hkv, G, hd)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * scale
        valid = k_pos[:, None, None, None, :] >= 0
        if causal:
            rel = q_pos[:, None, None, :, None] - k_pos[:, None, None, None, :]
            valid = valid & (rel >= 0)
            if window is not None:
                valid = valid & (rel < window)
        s = jnp.where(valid, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
        return o.reshape(B, Sq, Hq, hd)

    q, _ = _pad_to(q, 1, q_block)
    q_pos_p, _ = _pad_to(q_pos, 1, q_block)
    k, _ = _pad_to(k, 1, kv_block)
    v, _ = _pad_to(v, 1, kv_block)
    # padded KV slots must be invalid
    k_pos_p = jnp.pad(k_pos, ((0, 0), (0, (-Skv) % kv_block)), constant_values=-1)
    Sq_p, Skv_p = q.shape[1], k.shape[1]
    nq, nk = Sq_p // q_block, Skv_p // kv_block

    # (nq, B, Hkv, G, q_block, hd)
    qb = q.reshape(B, nq, q_block, Hkv, G, hd).transpose(1, 0, 3, 4, 2, 5)
    qpb = q_pos_p.reshape(B, nq, q_block).transpose(1, 0, 2)
    kb = k.reshape(B, nk, kv_block, Hkv, hd).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(B, nk, kv_block, Hkv, hd).transpose(1, 0, 3, 2, 4)
    kpb = k_pos_p.reshape(B, nk, kv_block).transpose(1, 0, 2)

    @functools.partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def kv_step(carry, blk, q_i, qp_i):
        o, m, l = carry
        k_i, v_i, kp_i = blk
        s = jnp.einsum("bhgqd,bhkd->bhgqk", q_i, k_i).astype(jnp.float32) * scale
        valid = kp_i[:, None, None, None, :] >= 0
        if causal:
            rel = qp_i[:, None, None, :, None] - kp_i[:, None, None, None, :]
            valid = valid & (rel >= 0)
            if window is not None:
                valid = valid & (rel < window)
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        o_new = o * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p.astype(v_i.dtype), v_i).astype(jnp.float32)
        return (o_new, m_new, l_new), None

    def q_step(q_i, qp_i):
        o0 = jnp.zeros((B, Hkv, G, q_block, hd), jnp.float32)
        m0 = jnp.full((B, Hkv, G, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_block), jnp.float32)
        if unroll:
            # straight-line twin (dry-run cost accounting: XLA's CPU cost
            # analysis counts loop bodies once, so loops are peeled here)
            c = (o0, m0, l0)
            for ik in range(nk):
                c, _ = kv_step(c, (kb[ik], vb[ik], kpb[ik]), q_i, qp_i)
            o, m, l = c
        else:
            (o, m, l), _ = jax.lax.scan(
                lambda c, b: kv_step(c, b, q_i, qp_i), (o0, m0, l0),
                (kb, vb, kpb))
        return o / jnp.maximum(l, 1e-30)[..., None]

    if unroll:
        out = jnp.stack([q_step(qb[iq], qpb[iq]) for iq in range(nq)])
    else:
        out = jax.lax.map(lambda args: q_step(*args), (qb, qpb))   # (nq, ...)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq_p, Hq, hd)
    return out[:, :Sq].astype(q.dtype)


def paged_gather_layer(view: PagedLayerView) -> LayerCache:
    """Reconstruct the dense ring view of one layer's paged cache.

    Ring slot ``j`` of slot ``b`` is ``pool[table[b, j // bs], j % bs]``;
    unallocated table entries gather (masked) garbage from the trash block
    and carry ``pos = -1``, exactly like an empty ring slot — so the result
    feeds the unchanged :func:`decode_attention` math and the paged decode
    is bit-identical to the ring decode.  (The dense gather is a transient;
    the fused no-materialization read lives in
    ``repro.kernels.paged_decode_attention``.)
    """
    B, nbs = view.table.shape
    bs = view.k.shape[2]
    W = nbs * bs
    j = jnp.arange(W)
    blk = view.table[:, j // bs]                       # (B, W) pool ids
    off = jnp.broadcast_to(j % bs, (B, W))
    safe = jnp.maximum(blk, 0)
    k = view.k[safe, :, off, :].transpose(0, 2, 1, 3)  # (B, Hkv, W, hd)
    v = view.v[safe, :, off, :].transpose(0, 2, 1, 3)
    pos = jnp.where(blk >= 0, view.pos[safe, off], -1).astype(jnp.int32)
    return LayerCache(k=k, v=v, pos=pos)


def paged_write_stacked(caches: PagedCache, k_news, v_news,
                        step) -> PagedCache:
    """One scatter writes every slot's new token into its pool block.

    k_news/v_news: (L, B, 1, Hkv, hd); ``step`` scalar or (B,) per-slot
    absolute positions.  A slot whose destination block is unallocated
    (``table`` entry -1: an idle engine slot) writes to the trash block 0,
    which no table references — the paged twin of the ring engine's
    harmless inactive-row writes.
    """
    bs = caches.k.shape[3]
    B, nbs = caches.table.shape
    W = nbs * bs
    step = jnp.asarray(step)
    if step.ndim == 0:
        step = jnp.broadcast_to(step, (B,))
    step = step.astype(jnp.int32)
    jb = jnp.mod(step, W) // bs                         # (B,) table column
    blk = jnp.take_along_axis(caches.table, jb[:, None], axis=1)[:, 0]
    dst = jnp.where(blk >= 0, blk, 0)
    off = jnp.mod(step, bs)
    k_t = k_news[:, :, 0].transpose(1, 0, 2, 3)         # (B, L, Hkv, hd)
    v_t = v_news[:, :, 0].transpose(1, 0, 2, 3)
    k = caches.k.at[:, dst, :, off, :].set(k_t.astype(caches.k.dtype))
    v = caches.v.at[:, dst, :, off, :].set(v_t.astype(caches.v.dtype))
    pos = caches.pos.at[dst, off].set(step)
    return caches._replace(k=k, v=v, pos=pos)


def decode_attention(q, cache, step, *, window: Optional[int],
                     q_per_kv: int = 1, k_new=None, v_new=None):
    """Single-token attention against a ring cache (or paged view of one).

    q: (B, 1, Hq, hd) roped; cache.k/v: (B, Hkv, W, hd); step: scalar int32
    (absolute position of the query token) or (B,) per-example positions —
    the batched serving engine decodes slots at different depths in one call.
    A :class:`PagedLayerView` cache dispatches through
    :func:`paged_gather_layer` first (bit-identical ring reconstruction).

    When ``k_new``/``v_new`` (B, 1, Hkv, hd) are given, the cache is treated
    as *read-only* and the new token is attended via an appended logit — the
    actual cache write is deferred to one post-scan scatter (keeps XLA from
    round-tripping the full cache through scan temporaries).  Ring semantics
    are preserved by masking positions <= step - W.
    """
    if isinstance(cache, PagedLayerView):
        cache = paged_gather_layer(cache)
    B, _, Hq, hd = q.shape
    Hkv, W = cache.k.shape[1], cache.k.shape[2]
    G = q_per_kv
    scale = hd ** -0.5
    step = jnp.asarray(step)
    if step.ndim == 1:
        step = step.reshape(B, 1, 1, 1)   # broadcast against pos (B,1,1,W)
    qg = q.reshape(B, Hkv, G, hd)
    s = jnp.einsum("bhgd,bhwd->bhgw", qg, cache.k).astype(jnp.float32) * scale
    pos = cache.pos[:, None, None, :]
    valid = (pos >= 0) & (pos <= step)
    if k_new is not None:
        valid = valid & (pos > step - W)          # ring eviction of oldest
    if window is not None:
        valid = valid & (pos > step - window)
    s = jnp.where(valid, s, NEG_INF)
    if k_new is not None:
        # merge the new token by online-softmax combination rather than a
        # concat along W: every W-dim op stays a pure reduction, so GSPMD can
        # keep a window-sharded cache sharded (a concat forces an all-gather
        # of the whole score tensor — EXPERIMENTS.md §Perf H4)
        s_new = jnp.einsum("bhgd,bhd->bhg", qg,
                           k_new[:, 0]).astype(jnp.float32) * scale
        m_c = jnp.max(s, axis=-1)                              # (b,h,g)
        m = jnp.maximum(m_c, s_new)
        p_c = jnp.exp(s - m[..., None])
        l = jnp.sum(p_c, axis=-1) + jnp.exp(s_new - m)
        o = jnp.einsum("bhgw,bhwd->bhgd", p_c.astype(cache.v.dtype), cache.v)
        o = o + (jnp.exp(s_new - m)[..., None].astype(v_new.dtype)
                 * v_new[:, 0][:, :, None, :])
        o = o / l[..., None].astype(o.dtype)
    else:
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgw,bhwd->bhgd", p.astype(cache.v.dtype), cache.v)
    return o.reshape(B, 1, Hq, hd)


# ---------------------------------------------------------------------------
# Cache construction / update
# ---------------------------------------------------------------------------
def empty_cache(cfg: ModelConfig, batch: int, width: int, dtype) -> LayerCache:
    return LayerCache(
        k=jnp.zeros((batch, cfg.n_kv_heads, width, cfg.head_dim), dtype),
        v=jnp.zeros((batch, cfg.n_kv_heads, width, cfg.head_dim), dtype),
        pos=jnp.full((batch, width), -1, jnp.int32),
    )


def empty_paged_cache(cfg: ModelConfig, n_layers: int, num_blocks: int,
                      slots: int, width: int, block_size: int,
                      dtype) -> PagedCache:
    """Zeroed block pool + all-unallocated tables for ``slots`` decode rows.

    ``width`` is the logical ring width each slot's table spans; it must be
    a block multiple so ``p % W`` and ``p % block_size`` agree blockwise.
    """
    if width % block_size != 0:
        raise ValueError(f"paged cache width {width} must be a multiple of "
                         f"block_size {block_size}")
    return PagedCache(
        k=jnp.zeros((n_layers, num_blocks, cfg.n_kv_heads, block_size,
                     cfg.head_dim), dtype),
        v=jnp.zeros((n_layers, num_blocks, cfg.n_kv_heads, block_size,
                     cfg.head_dim), dtype),
        pos=jnp.full((num_blocks, block_size), -1, jnp.int32),
        table=jnp.full((slots, width // block_size), -1, jnp.int32),
    )


def cache_from_prefill(k, v, positions, width: int) -> LayerCache:
    """Pack the (roped) prefill K/V of length S into a ring cache of width W.

    Slot j holds the most recent token with position % W == j.
    k, v: (B, S, Hkv, hd); positions: (B, S) absolute (assumed 0..S-1 order).
    """
    B, S, Hkv, hd = k.shape
    W = width
    j = jnp.arange(W)
    if S <= W:
        tok = jnp.minimum(j, S - 1)
        pos_slot = jnp.where(j < S, j, -1)
    else:
        tok = S - W + ((j - (S - W)) % W)
        pos_slot = tok
    kc = jnp.take(k, tok, axis=1).transpose(0, 2, 1, 3)       # (B, Hkv, W, hd)
    vc = jnp.take(v, tok, axis=1).transpose(0, 2, 1, 3)
    base = positions[:, :1] if S <= W else positions[:, :1]
    pos = jnp.where(pos_slot[None, :] >= 0,
                    pos_slot[None, :] + base, -1).astype(jnp.int32)
    return LayerCache(k=kc, v=vc, pos=pos)


def cache_write(cache: LayerCache, k_new, v_new, step) -> LayerCache:
    """Write one token (B, 1, Hkv, hd) at absolute position ``step``.

    ``step`` may be a scalar (all examples at the same depth) or (B,)
    per-example positions (the serving engine's continuous-batching slots)."""
    step = jnp.asarray(step)
    k_t = k_new.transpose(0, 2, 1, 3)   # (B, Hkv, 1, hd)
    v_t = v_new.transpose(0, 2, 1, 3)
    if step.ndim == 1:
        def one(k, v, p, kt, vt, s):
            # k: (Hkv, W, hd); p: (W,); kt/vt: (Hkv, 1, hd)
            slot = jnp.mod(s, p.shape[0])
            k = jax.lax.dynamic_update_slice_in_dim(
                k, kt.astype(k.dtype), slot, axis=1)
            v = jax.lax.dynamic_update_slice_in_dim(
                v, vt.astype(v.dtype), slot, axis=1)
            p = jax.lax.dynamic_update_slice(
                p, s.astype(jnp.int32).reshape(1), (slot,))
            return k, v, p
        k, v, pos = jax.vmap(one)(cache.k, cache.v, cache.pos, k_t, v_t, step)
        return LayerCache(k=k, v=v, pos=pos)
    W = cache.k.shape[2]
    slot = jnp.mod(step, W)
    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_t.astype(cache.k.dtype), slot, axis=2)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_t.astype(cache.v.dtype), slot, axis=2)
    pos = jax.lax.dynamic_update_slice_in_dim(
        cache.pos, jnp.broadcast_to(jnp.int32(step), (cache.pos.shape[0], 1)), slot, axis=1)
    return LayerCache(k=k, v=v, pos=pos)


def cache_write_stacked(caches, k_news, v_news, step):
    """One scatter for the whole layer stack (the deferred decode write).

    caches: (L, B, Hkv, W, hd) leaves; k_news/v_news: (L, B, 1, Hkv, hd).
    ``step`` scalar, or (B,) per-example positions (per-slot engine decode —
    each example's write lands in its own ring slot).  A :class:`PagedCache`
    dispatches to :func:`paged_write_stacked` (same semantics, one
    indirection through the block table).
    """
    if isinstance(caches, PagedCache):
        return paged_write_stacked(caches, k_news, v_news, step)
    step = jnp.asarray(step)
    k_t = k_news.transpose(0, 1, 3, 2, 4)    # (L, B, Hkv, 1, hd)
    v_t = v_news.transpose(0, 1, 3, 2, 4)
    if step.ndim == 1:
        def one(k, v, p, kt, vt, s):
            # k: (L, Hkv, W, hd); p: (L, W); kt/vt: (L, Hkv, 1, hd)
            slot = jnp.mod(s, p.shape[1])
            k = jax.lax.dynamic_update_slice_in_dim(
                k, kt.astype(k.dtype), slot, axis=2)
            v = jax.lax.dynamic_update_slice_in_dim(
                v, vt.astype(v.dtype), slot, axis=2)
            p = jax.lax.dynamic_update_slice_in_dim(
                p, jnp.broadcast_to(s.astype(jnp.int32), (p.shape[0], 1)),
                slot, axis=1)
            return k, v, p
        k, v, pos = jax.vmap(one, in_axes=(1, 1, 1, 1, 1, 0),
                             out_axes=(1, 1, 1))(
            caches.k, caches.v, caches.pos, k_t, v_t, step)
        return LayerCache(k=k, v=v, pos=pos)
    W = caches.k.shape[3]
    slot = jnp.mod(step, W)
    k = jax.lax.dynamic_update_slice_in_dim(caches.k, k_t.astype(caches.k.dtype),
                                            slot, axis=3)
    v = jax.lax.dynamic_update_slice_in_dim(caches.v, v_t.astype(caches.v.dtype),
                                            slot, axis=3)
    pos = jax.lax.dynamic_update_slice_in_dim(
        caches.pos,
        jnp.broadcast_to(jnp.int32(step), caches.pos.shape[:2] + (1,)),
        slot, axis=2)
    return LayerCache(k=k, v=v, pos=pos)


# ---------------------------------------------------------------------------
# Full attention layer (self or cross), all modes
# ---------------------------------------------------------------------------
def attention(params, x, positions, cfg: ModelConfig, *, mode: str,
              cache: Optional[LayerCache] = None, step=None,
              memory=None, memory_pos=None, cross: bool = False,
              causal: bool = True, window: Optional[int] = None,
              use_rope: bool = True, cache_width: Optional[int] = None,
              defer_write: bool = False, ctx_k=None, ctx_v=None,
              ctx_pos=None):
    """Run one attention layer.

    mode: "dense"   — full-sequence self/cross attention (train / encoder)
          "prefill" — like dense, but also returns a ring cache
          "decode"  — one-token step against ``cache`` at position ``step``
          "suffix"  — chunked-prefill step: the tokens are a prompt *suffix*
                      attending over pre-existing (roped) context K/V
                      ``ctx_k``/``ctx_v`` (B, C, Hkv, hd) at absolute
                      positions ``ctx_pos`` (B, C) plus themselves; returns
                      the raw suffix (k, v) for the caller's cache write
    For cross-attention pass ``memory`` (B, M, d) in dense/prefill modes, or
    ``cross=True`` in decode mode (the cache then holds the projected memory
    K/V, written at prefill).
    """
    dt = x.dtype
    G = cfg.q_per_kv
    win = window if window is not None else cfg.sliding_window

    if mode == "suffix":
        q, k, v = _project_qkv(params, x, x, cfg)
        if use_rope:
            # keys are stored post-RoPE: rotating at absolute positions
            # keeps suffix K byte-compatible with the cached context K
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        from repro.kernels.ops import suffix_prefill_attention
        o = suffix_prefill_attention(q, k, v, ctx_k, ctx_v, positions,
                                     ctx_pos, causal=causal, window=win,
                                     q_per_kv=G)
        out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(dt))
        return out, (k, v)

    if mode == "decode":
        if cross:
            # cross-attention at decode: cache holds projected memory K/V
            q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
            if "bq" in params:
                q = q + params["bq"].astype(dt)
            o = decode_attention(q, cache, jnp.int32(2**30), window=None,
                                 q_per_kv=G)
            out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(dt))
            return out, cache
        q, k, v = _project_qkv(params, x, x, cfg)
        if use_rope:
            st_arr = jnp.asarray(step)
            # (1, 1) shared position, or (B, 1) per-example engine positions
            pos1 = (st_arr.reshape(-1, 1) if st_arr.ndim == 1
                    else jnp.reshape(st_arr, (1, 1)))
            q = apply_rope(q, pos1, cfg.rope_theta)
            k = apply_rope(k, pos1, cfg.rope_theta)
        if defer_write:
            o = decode_attention(q, cache, step, window=win, q_per_kv=G,
                                 k_new=k, v_new=v)
            out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(dt))
            return out, (k, v)
        cache = cache_write(cache, k, v, step)
        o = decode_attention(q, cache, step, window=win, q_per_kv=G)
        out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(dt))
        return out, cache

    if memory is not None:  # dense/prefill cross-attention
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
        if "bq" in params:
            q = q + params["bq"].astype(dt)
        k = jnp.einsum("bsd,dhk->bshk", memory, params["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", memory, params["wv"].astype(dt))
        if "bk" in params:
            k = k + params["bk"].astype(dt)
            v = v + params["bv"].astype(dt)
        mpos = (memory_pos if memory_pos is not None
                else jnp.arange(memory.shape[1], dtype=jnp.int32))
        qb = kb = 512
        if cfg.attn_direct:
            qb = -(-max(-(-x.shape[1] // 4), 512) // 128) * 128
            kb = -(-max(-(-memory.shape[1] // 4), 512) // 128) * 128
        o = chunked_attention(q, k, v, positions, mpos, causal=False,
                              window=None, q_per_kv=G, q_block=qb,
                              kv_block=kb, unroll=cfg.attn_direct)
        out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(dt))
        if mode == "prefill":
            M = memory.shape[1]
            mpos2 = jnp.broadcast_to(mpos[None], (x.shape[0], M)) if mpos.ndim == 1 else mpos
            new_cache = cache_from_prefill(k, v, mpos2, M)
            return out, new_cache
        return out, None

    q, k, v = _project_qkv(params, x, x, cfg)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    S = x.shape[1]
    if cfg.seq_shard_attn:
        # context parallelism: shard queries over the model axis (KV layout
        # is left to GSPMD — explicitly replicating it forced per-layer
        # all-gathers, see EXPERIMENTS.md §Perf iteration 2)
        from jax.sharding import PartitionSpec as P
        q = jax.lax.with_sharding_constraint(q, P(None, "model", None, None))
    # cost-accounting mode uses big straight-line blocks (nq*nk <= 16)
    qb = max(-(-S // 4), 512) if cfg.attn_direct else 512
    qb = -(-qb // 128) * 128
    o = chunked_attention(q, k, v, positions, positions, causal=causal,
                          window=win, q_per_kv=G, q_block=qb, kv_block=qb,
                          unroll=cfg.attn_direct)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(dt))
    if mode == "prefill":
        W = cache_width or (win if win is not None else x.shape[1])
        pos2 = (jnp.broadcast_to(positions[None], x.shape[:2])
                if positions.ndim == 1 else positions)
        new_cache = cache_from_prefill(k, v, pos2, W)
        return out, new_cache
    return out, None
