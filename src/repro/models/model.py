"""Composable model definitions for every assigned architecture family.

Entry points (all pure functions over parameter pytrees):

* ``init_params(cfg, key)`` — parameter pytree.  Layer stacks are vmapped so
  they carry a leading layer axis and are ``lax.scan``-ed; compile time is
  O(1) in depth (mandatory for the 64-layer × 512-device CPU dry-run).
* ``forward(params, cfg, batch, mode)`` — ``mode="train"`` returns
  ``{"logits", "aux_loss"}``; ``mode="prefill"`` additionally returns the
  decode ``cache``.
* ``decode_step(params, cfg, cache, batch, step)`` — one-token serving step
  (the object lowered by decode dry-run shapes).

Batch dict keys by family:
  dense/moe/ssm/hybrid: tokens (B,S) int32 [+ ages (B,S) f32 for Delphi cfgs]
  vlm:   tokens (B,S) + patches (B, n_frontend_tokens, d_model)   [stub]
  audio: tokens (B,S) + frames (B, M, d_model)                    [stub]

Cache pytrees (leading axis = layer / application):
  dense/moe/vlm: {"self": LayerCache[L]}
  ssm:           {"ssm": SSMCache[L]}
  hybrid:        {"ssm": SSMCache[L], "attn": LayerCache[n_apps]}
  audio:         {"self": LayerCache[L], "cross": LayerCache[L]}
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import base as cb
from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.attention import LayerCache
from repro.models.layers import (act_dtype, age_encoding, apply_mlp, apply_norm,
                                 embed_tokens, init_embed, init_mlp, init_norm,
                                 logits_head)
from repro.models.ssm import SSMCache


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def init_transformer_layer(key, cfg: ModelConfig, *, cross: bool = False,
                           moe: bool = False):
    ks = jax.random.split(key, 3)
    p = {
        "attn_norm": init_norm(cfg, cfg.d_model),
        "attn": attn_lib.init_attention(ks[0], cfg),
        "mlp_norm": init_norm(cfg, cfg.d_model),
    }
    if moe:
        p["moe"] = moe_lib.init_moe(ks[1], cfg)
    else:
        p["mlp"] = init_mlp(ks[1], cfg, cfg.d_model, cfg.d_ff)
    if cross:
        p["cross_norm"] = init_norm(cfg, cfg.d_model)
        p["cross_attn"] = attn_lib.init_attention(ks[2], cfg, cross=True)
    return p


def init_mamba_layer(key, cfg: ModelConfig):
    return {"norm": init_norm(cfg, cfg.d_model),
            "ssm": ssm_lib.init_ssm(key, cfg)}


def _stacked(init_fn, key, n):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def n_attn_apps(cfg: ModelConfig) -> int:
    """Hybrid: number of shared-attention applications over the layer stack."""
    return -(-cfg.n_layers // cfg.attn_every)


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"embed": init_embed(ks[0], cfg),
                         "final_norm": init_norm(cfg, cfg.d_model)}
    t = cfg.arch_type
    if t in (cb.DENSE, cb.VLM):
        p["layers"] = _stacked(lambda k: init_transformer_layer(k, cfg),
                               ks[1], cfg.n_layers)
    elif t == cb.MOE:
        p["layers"] = _stacked(lambda k: init_transformer_layer(k, cfg, moe=True),
                               ks[1], cfg.n_layers)
    elif t == cb.SSM:
        p["layers"] = _stacked(lambda k: init_mamba_layer(k, cfg), ks[1], cfg.n_layers)
    elif t == cb.HYBRID:
        p["layers"] = _stacked(lambda k: init_mamba_layer(k, cfg), ks[1], cfg.n_layers)
        p["shared_attn"] = init_transformer_layer(ks[2], cfg)
    elif t in (cb.AUDIO, cb.ENC_DEC):
        p["encoder"] = _stacked(lambda k: init_transformer_layer(k, cfg),
                                ks[1], cfg.n_encoder_layers)
        p["enc_norm"] = init_norm(cfg, cfg.d_model)
        p["layers"] = _stacked(lambda k: init_transformer_layer(k, cfg, cross=True),
                               ks[2], cfg.n_layers)
    else:
        raise ValueError(t)
    return p


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# Single blocks
# ---------------------------------------------------------------------------
def transformer_layer(lp, x, positions, cfg: ModelConfig, *, mode: str,
                      cache: Optional[LayerCache] = None, step=None,
                      cross_cache: Optional[LayerCache] = None,
                      memory=None, causal: bool = True,
                      cache_width: Optional[int] = None,
                      moe_impl: str = "dense_scan",
                      defer_write: bool = False, ctx_k=None, ctx_v=None,
                      ctx_pos=None):
    """Pre-norm transformer block.  Returns (x, cache, cross_cache, aux).

    In decode mode with ``defer_write``, the second return is the (k, v) pair
    of the new token instead of an updated cache (one post-scan scatter).
    In suffix mode the second return is the (k, v) pair of the chunk tokens
    (same deferred-write contract), attending over ``ctx_k``/``ctx_v``/
    ``ctx_pos`` — the already-cached prompt context."""
    use_rope = not cfg.age_encoding
    a, new_cache = attn_lib.attention(
        lp["attn"], apply_norm(lp["attn_norm"], x, cfg), positions, cfg,
        mode=mode, cache=cache, step=step, causal=causal,
        use_rope=use_rope, cache_width=cache_width, defer_write=defer_write,
        ctx_k=ctx_k, ctx_v=ctx_v, ctx_pos=ctx_pos)
    x = x + a
    new_cross = cross_cache
    if "cross_attn" in lp:
        h = apply_norm(lp["cross_norm"], x, cfg)
        if mode == "decode":
            c, new_cross = attn_lib.attention(
                lp["cross_attn"], h, positions, cfg, mode="decode",
                cache=cross_cache, step=step, cross=True)
        else:
            c, new_cross = attn_lib.attention(
                lp["cross_attn"], h, positions, cfg, mode=mode, memory=memory)
        x = x + c
    h = apply_norm(lp["mlp_norm"], x, cfg)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in lp:
        y, aux = moe_lib.apply_moe(lp["moe"], h, cfg, impl=moe_impl)
    else:
        y = apply_mlp(lp["mlp"], h, cfg)
    return x + y, new_cache, new_cross, aux


def mamba_layer(lp, x, cfg: ModelConfig, *, mode: str,
                cache: Optional[SSMCache] = None):
    h = apply_norm(lp["norm"], x, cfg)
    if mode == "decode":
        y, new_cache = ssm_lib.ssm_decode_step(lp["ssm"], h, cache, cfg)
        return x + y, new_cache
    if mode == "prefill":
        y, new_cache = ssm_lib.ssm_forward(lp["ssm"], h, cfg, return_state=True)
        return x + y, new_cache
    return x + ssm_lib.ssm_forward(lp["ssm"], h, cfg), None


def _maybe_remat(fn, cfg: ModelConfig):
    return jax.checkpoint(fn) if cfg.remat else fn


# ---------------------------------------------------------------------------
# Embedding frontends
# ---------------------------------------------------------------------------
def _embed_input(params, cfg: ModelConfig, batch, *, positions=None):
    """Returns (x (B, S', d), positions (S'? or (B,S')), text_offset)."""
    tokens = batch["tokens"]
    x = embed_tokens(params["embed"], tokens, cfg)
    if cfg.age_encoding:
        x = x + age_encoding(batch["ages"], cfg.d_model).astype(x.dtype)
    offset = 0
    if cfg.frontend == "vision_patches":
        patches = batch["patches"].astype(x.dtype)
        x = jnp.concatenate([patches, x], axis=1)
        offset = patches.shape[1]
    pos = jnp.arange(x.shape[1], dtype=jnp.int32)
    return x, pos, offset


# ---------------------------------------------------------------------------
# Stacks
# ---------------------------------------------------------------------------
def _slice_layer(tree, i):
    return jax.tree_util.tree_map(lambda a: a[i], tree)


def _stack_trees(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _transformer_stack_unrolled(layers, x, positions, cfg, *, mode,
                                memory=None, causal=True, caches=None,
                                cross_caches=None, step=None, cache_width=None,
                                moe_impl="dense_scan", has_cross=False):
    """Python-loop twin of _transformer_stack (cfg.unroll_layers cost mode)."""
    if isinstance(caches, attn_lib.PagedCache):
        raise ValueError("paged KV cache requires the scanned stack "
                         "(cfg.unroll_layers is a cost-accounting mode)")
    L = jax.tree_util.tree_leaves(layers)[0].shape[0]
    aux = jnp.zeros((), jnp.float32)
    out_caches, out_cross, kvs = [], [], []
    for i in range(L):
        lp = _slice_layer(layers, i)
        if mode == "decode":
            x, kv, _, _ = transformer_layer(
                lp, x, positions, cfg, mode="decode",
                cache=_slice_layer(caches, i),
                cross_cache=(_slice_layer(cross_caches, i) if has_cross
                             else None),
                step=step, moe_impl=moe_impl, defer_write=True)
            kvs.append(kv)
        else:
            def call(lp_, h_):
                return transformer_layer(
                    lp_, h_, positions, cfg, mode=mode, memory=memory,
                    causal=causal, cache_width=cache_width,
                    moe_impl=moe_impl)
            x, nc, nx, a = _maybe_remat(call, cfg)(lp, x)
            aux = aux + a
            if mode == "prefill":
                out_caches.append(nc)
                out_cross.append(nx)
    if mode == "decode":
        k_news, v_news = _stack_trees(kvs)
        caches = attn_lib.cache_write_stacked(caches, k_news, v_news, step)
        return x, caches, cross_caches, aux
    if mode == "prefill":
        return (x, _stack_trees(out_caches),
                (_stack_trees(out_cross) if has_cross else None), aux)
    return x, None, None, aux


def _transformer_stack(layers, x, positions, cfg, *, mode, memory=None,
                       causal=True, caches=None, cross_caches=None, step=None,
                       cache_width=None, moe_impl="dense_scan", has_cross=False):
    """Scan a stacked transformer.  In decode mode caches are scan xs; in
    prefill they are scan ys; in train they don't exist."""
    if cfg.unroll_layers:
        return _transformer_stack_unrolled(
            layers, x, positions, cfg, mode=mode, memory=memory,
            causal=causal, caches=caches, cross_caches=cross_caches,
            step=step, cache_width=cache_width, moe_impl=moe_impl,
            has_cross=has_cross)
    if mode == "train":
        def body(h, lp):
            h, _, _, aux = transformer_layer(
                lp, h, positions, cfg, mode="train", memory=memory,
                causal=causal, moe_impl=moe_impl)
            return h, aux
        x, auxes = jax.lax.scan(_maybe_remat(body, cfg), x, layers)
        return x, None, None, jnp.sum(auxes)

    if mode == "prefill":
        def body(h, lp):
            h, nc, nx, aux = transformer_layer(
                lp, h, positions, cfg, mode="prefill", memory=memory,
                causal=causal, cache_width=cache_width, moe_impl=moe_impl)
            if not has_cross:
                nx = jnp.zeros((0,))
            return h, (nc, nx, aux)
        x, (caches, cross_caches, auxes) = jax.lax.scan(
            _maybe_remat(body, cfg), x, layers)
        return x, caches, (cross_caches if has_cross else None), jnp.sum(auxes)

    # decode: caches are read-only inside the scan; new-token K/V are
    # collected and written with ONE stacked scatter afterwards (avoids
    # round-tripping the full cache through scan temporaries)
    if isinstance(caches, attn_lib.PagedCache):
        # paged decode: the scan carries each layer's pool planes; the
        # shared block table / positions are closed over (they have no
        # layer axis).  decode_attention dispatches on the PagedLayerView.
        if has_cross:
            raise ValueError("paged KV cache does not support cross-"
                             "attention stacks")
        pc = caches

        def body(h, xs):
            lp, kl, vl = xs
            view = attn_lib.PagedLayerView(kl, vl, pc.pos, pc.table)
            h, kv, _, _ = transformer_layer(
                lp, h, positions, cfg, mode="decode", cache=view, step=step,
                moe_impl=moe_impl, defer_write=True)
            return h, kv
        x, (k_news, v_news) = jax.lax.scan(body, x, (layers, pc.k, pc.v))
        caches = attn_lib.cache_write_stacked(pc, k_news, v_news, step)
        return x, caches, None, jnp.zeros((), jnp.float32)

    if has_cross:
        def body(h, xs):
            lp, c, xc = xs
            h, kv, _, _ = transformer_layer(
                lp, h, positions, cfg, mode="decode", cache=c, cross_cache=xc,
                step=step, moe_impl=moe_impl, defer_write=True)
            return h, kv
        x, (k_news, v_news) = jax.lax.scan(
            body, x, (layers, caches, cross_caches))
        caches = attn_lib.cache_write_stacked(caches, k_news, v_news, step)
        return x, caches, cross_caches, jnp.zeros((), jnp.float32)

    def body(h, xs):
        lp, c = xs
        h, kv, _, _ = transformer_layer(
            lp, h, positions, cfg, mode="decode", cache=c, step=step,
            moe_impl=moe_impl, defer_write=True)
        return h, kv
    x, (k_news, v_news) = jax.lax.scan(body, x, (layers, caches))
    caches = attn_lib.cache_write_stacked(caches, k_news, v_news, step)
    return x, caches, None, jnp.zeros((), jnp.float32)


def _suffix_stack(layers, x, positions, cfg, *, ctx_k, ctx_v, ctx_pos,
                  moe_impl="dense_scan"):
    """Scan a stacked transformer over a prompt *suffix* (chunked prefill).

    ``ctx_k``/``ctx_v`` (L, B, C, Hkv, hd) are the already-cached context
    K/V per layer (scan xs, like the paged decode scan); ``ctx_pos`` (B, C)
    their absolute positions (-1 = invalid, shared across layers).  Returns
    (x, k_news, v_news) where k_news/v_news (L, B, Sc, Hkv, hd) are the
    suffix K/V for the caller's one stacked block write."""
    def body(h, xs):
        lp, ck, cv = xs
        h, kv, _, _ = transformer_layer(
            lp, h, positions, cfg, mode="suffix", ctx_k=ck, ctx_v=cv,
            ctx_pos=ctx_pos, moe_impl=moe_impl)
        return h, kv
    x, (k_news, v_news) = jax.lax.scan(body, x, (layers, ctx_k, ctx_v))
    return x, k_news, v_news


def forward_suffix(params, cfg: ModelConfig, batch: Dict[str, Any], ctx,
                   *, last_index, moe_impl: str = "dense_scan") -> Dict[str, Any]:
    """Chunked-prefill forward over a prompt *suffix*.

    The suffix tokens attend over pre-existing cache context (gathered from
    the paged pool by the caller) plus themselves, by absolute position —
    the incremental half of a prefill whose earlier chunks (or prefix-cache
    hits) already wrote their K/V.

    batch: tokens (B, Sc) int32 [+ ages (B, Sc) for Delphi cfgs], positions
    (B, Sc) int32 absolute positions (-1 = right padding).  ctx: dict with
    "k"/"v" (L, B, C, Hkv, hd) roped context K/V and "pos" (B, C) absolute
    positions (-1 = invalid).  ``last_index``: (B,) index of each example's
    last valid suffix token (the bootstrap logits read there).

    Returns {"logits": (B, 1, V), "k"/"v": (L, B, Sc, Hkv, hd)} — the
    suffix K/V for the caller's paged block write.  Attention-cache
    architectures only (same constraint as :func:`make_paged_decode_cache`).
    """
    t = cfg.arch_type
    if t not in (cb.DENSE, cb.VLM, cb.MOE):
        raise ValueError(f"suffix prefill supports attention-cache "
                         f"architectures (dense/moe/vlm), not {t}")
    tokens = batch["tokens"]
    x = embed_tokens(params["embed"], tokens, cfg)
    if cfg.age_encoding:
        x = x + age_encoding(batch["ages"], cfg.d_model).astype(x.dtype)
    positions = batch["positions"]
    x, k_news, v_news = _suffix_stack(
        params["layers"], x, positions, cfg, ctx_k=ctx["k"], ctx_v=ctx["v"],
        ctx_pos=ctx["pos"], moe_impl=moe_impl)
    idx = jnp.asarray(last_index, jnp.int32)
    x = jnp.take_along_axis(x, idx[:, None, None], axis=1)
    x = apply_norm(params["final_norm"], x, cfg)
    return {"logits": logits_head(params["embed"], x, cfg),
            "k": k_news, "v": v_news}


def _ssm_stack(layers, x, cfg, *, mode, caches=None):
    if cfg.unroll_layers:   # cost-accounting mode (python loop, exact FLOPs)
        L = jax.tree_util.tree_leaves(layers)[0].shape[0]
        outs = []
        for i in range(L):
            lp = _slice_layer(layers, i)
            c = _slice_layer(caches, i) if caches is not None else None
            def call(lp_, h_):
                return mamba_layer(lp_, h_, cfg, mode=mode, cache=c)
            if mode == "train":
                x, _ = _maybe_remat(call, cfg)(lp, x)
            else:
                x, nc = call(lp, x)
                outs.append(nc)
        return x, (_stack_trees(outs) if outs else None)
    if mode == "train":
        def body(h, lp):
            h, _ = mamba_layer(lp, h, cfg, mode="train")
            return h, None
        x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, layers)
        return x, None
    if mode == "prefill":
        def body(h, lp):
            h, nc = mamba_layer(lp, h, cfg, mode="prefill")
            return h, nc
        x, caches = jax.lax.scan(_maybe_remat(body, cfg), x, layers)
        return x, caches
    def body(h, xs):
        lp, c = xs
        h, nc = mamba_layer(lp, h, cfg, mode="decode", cache=c)
        return h, nc
    x, caches = jax.lax.scan(body, x, (layers, caches))
    return x, caches


def _hybrid_stack(params, x, positions, cfg, *, mode, ssm_caches=None,
                  attn_caches=None, step=None, cache_width=None):
    """Zamba2-style: scan Mamba layers; apply the weight-shared attention
    block before every ``cfg.attn_every``-th layer.  Attention caches are
    stacked per *application* and carried through the scan."""
    L = cfg.n_layers
    k = cfg.attn_every
    shared = params["shared_attn"]
    idxs = jnp.arange(L, dtype=jnp.int32)

    if cfg.unroll_layers:   # cost-accounting mode: static periodic structure
        ssm_outs = []
        attn_list = ([None] * n_attn_apps(cfg) if mode != "train" else None)
        for i in range(L):
            if i % k == 0:
                app = i // k
                if mode == "train":
                    x, _, _, _ = transformer_layer(shared, x, positions, cfg,
                                                   mode="train")
                elif mode == "prefill":
                    x, nc, _, _ = transformer_layer(
                        shared, x, positions, cfg, mode="prefill",
                        cache_width=cache_width)
                    attn_list[app] = nc
                else:
                    c = _slice_layer(attn_caches, app)
                    x, nc, _, _ = transformer_layer(
                        shared, x, positions, cfg, mode="decode", cache=c,
                        step=step)
                    attn_list[app] = nc
            lp = _slice_layer(params["layers"], i)
            c = _slice_layer(ssm_caches, i) if ssm_caches is not None else None
            x, nc = mamba_layer(lp, x, cfg, mode=mode, cache=c)
            if mode != "train":
                ssm_outs.append(nc)
        if mode == "train":
            return x, None, None
        return x, _stack_trees(ssm_outs), _stack_trees(attn_list)

    def apply_shared(h, app_idx, ac_all):
        if mode == "train":
            h2, _, _, _ = transformer_layer(shared, h, positions, cfg,
                                            mode="train")
            return h2, ac_all
        if mode == "prefill":
            h2, nc, _, _ = transformer_layer(shared, h, positions, cfg,
                                             mode="prefill",
                                             cache_width=cache_width)
            ac_all = jax.tree_util.tree_map(
                lambda buf, new: jax.lax.dynamic_update_index_in_dim(
                    buf, new.astype(buf.dtype), app_idx, 0), ac_all, nc)
            return h2, ac_all
        c = jax.tree_util.tree_map(
            lambda buf: jax.lax.dynamic_index_in_dim(buf, app_idx, 0,
                                                     keepdims=False), ac_all)
        h2, nc, _, _ = transformer_layer(shared, h, positions, cfg,
                                         mode="decode", cache=c, step=step)
        ac_all = jax.tree_util.tree_map(
            lambda buf, new: jax.lax.dynamic_update_index_in_dim(
                buf, new.astype(buf.dtype), app_idx, 0), ac_all, nc)
        return h2, ac_all

    def body(carry, xs):
        h, ac_all = carry
        if mode == "decode":
            lp, c, i = xs
        else:
            lp, i = xs
            c = None
        h, ac_all = jax.lax.cond(
            i % k == 0,
            lambda hh, aa: apply_shared(hh, i // k, aa),
            lambda hh, aa: (hh, aa),
            h, ac_all)
        h, nc = mamba_layer(lp, h, cfg, mode=mode, cache=c)
        return (h, ac_all), nc

    body = _maybe_remat(body, cfg) if mode != "decode" else body
    if mode == "decode":
        (x, attn_caches), ssm_caches = jax.lax.scan(
            body, (x, attn_caches), (params["layers"], ssm_caches, idxs))
        return x, ssm_caches, attn_caches
    if mode == "prefill":
        (x, attn_caches), ssm_caches = jax.lax.scan(
            body, (x, attn_caches), (params["layers"], idxs))
        return x, ssm_caches, attn_caches
    dummy = _empty_hybrid_attn_cache(cfg, x.shape[0], 1, x.dtype)
    (x, _), _ = jax.lax.scan(body, (x, dummy), (params["layers"], idxs))
    return x, None, None


def _empty_hybrid_attn_cache(cfg: ModelConfig, batch: int, width: int, dtype):
    one = attn_lib.empty_cache(cfg, batch, width, dtype)
    n = n_attn_apps(cfg)
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), one)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------
def forward(params, cfg: ModelConfig, batch: Dict[str, Any], *,
            mode: str = "train", cache_width: Optional[int] = None,
            moe_impl: str = "dense_scan",
            last_index: Optional[Any] = None) -> Dict[str, Any]:
    """mode in {"train", "prefill"}.

    ``last_index`` (prefill only): (B,) int32 index of each example's last
    *valid* token.  Right-padded batched prefill (the serving engine's
    bucketed admission) reads its bootstrap logits there instead of at the
    fixed position -1; padded tail positions never reach the logits head.
    """
    assert mode in ("train", "prefill")
    t = cfg.arch_type
    x, pos, offset = _embed_input(params, cfg, batch)
    B = x.shape[0]
    out: Dict[str, Any] = {"text_offset": offset}
    aux = jnp.zeros((), jnp.float32)

    if t in (cb.DENSE, cb.VLM, cb.MOE):
        x, caches, _, aux = _transformer_stack(
            params["layers"], x, pos, cfg, mode=mode,
            cache_width=cache_width, moe_impl=moe_impl)
        if mode == "prefill":
            out["cache"] = {"self": caches}
    elif t == cb.SSM:
        x, caches = _ssm_stack(params["layers"], x, cfg, mode=mode)
        if mode == "prefill":
            out["cache"] = {"ssm": caches}
    elif t == cb.HYBRID:
        attn_c = None
        if mode == "prefill":
            W = cache_width or (cfg.sliding_window or x.shape[1])
            attn_c = _empty_hybrid_attn_cache(cfg, B, W, act_dtype(cfg))
        x, ssm_c, attn_c = _hybrid_stack(
            params, x, pos, cfg, mode=mode, attn_caches=attn_c,
            cache_width=cache_width)
        if mode == "prefill":
            out["cache"] = {"ssm": ssm_c, "attn": attn_c}
    elif t in (cb.AUDIO, cb.ENC_DEC):
        frames = batch["frames"].astype(act_dtype(cfg))
        fpos = jnp.arange(frames.shape[1], dtype=jnp.int32)
        mem, _, _, _ = _transformer_stack(
            params["encoder"], frames, fpos, cfg, mode="train", causal=False)
        mem = apply_norm(params["enc_norm"], mem, cfg)
        x, caches, cross, _ = _transformer_stack(
            params["layers"], x, pos, cfg, mode=mode, memory=mem,
            cache_width=cache_width, has_cross=True)
        if mode == "prefill":
            out["cache"] = {"self": caches, "cross": cross}
    else:
        raise ValueError(t)

    if mode == "prefill":
        # decode bootstrap only needs the last position; slicing before the
        # head keeps the (B, S, V) fp32 logits out of the live set
        if last_index is not None:
            idx = jnp.asarray(last_index, jnp.int32) + offset
            x = jnp.take_along_axis(x, idx[:, None, None], axis=1)
        else:
            x = x[:, -1:]
    x = apply_norm(params["final_norm"], x, cfg)
    out["logits"] = logits_head(params["embed"], x, cfg)
    out["aux_loss"] = aux
    return out


def decode_step(params, cfg: ModelConfig, cache, batch: Dict[str, Any], step,
                *, moe_impl: str = "dense_scan") -> Dict[str, Any]:
    """One-token decode.  batch["tokens"]: (B, 1); step: scalar int32 absolute
    position of the new token, or (B,) per-example positions — the serving
    engine advances its continuous-batching slots, each at a different depth,
    in one batched call.  Returns {"logits": (B, 1, V), "cache": ...}."""
    t = cfg.arch_type
    tokens = batch["tokens"]
    x = embed_tokens(params["embed"], tokens, cfg)
    if cfg.age_encoding:
        x = x + age_encoding(batch["ages"], cfg.d_model).astype(x.dtype)
    step = jnp.asarray(step, jnp.int32)
    pos = step if step.ndim == 1 else jnp.reshape(step, (1,))

    if t in (cb.DENSE, cb.VLM, cb.MOE):
        x, caches, _, _ = _transformer_stack(
            params["layers"], x, pos, cfg, mode="decode",
            caches=cache["self"], step=step, moe_impl=moe_impl)
        new_cache = {"self": caches}
    elif t == cb.SSM:
        x, caches = _ssm_stack(params["layers"], x, cfg, mode="decode",
                               caches=cache["ssm"])
        new_cache = {"ssm": caches}
    elif t == cb.HYBRID:
        x, ssm_c, attn_c = _hybrid_stack(
            params, x, pos, cfg, mode="decode", ssm_caches=cache["ssm"],
            attn_caches=cache["attn"], step=step)
        new_cache = {"ssm": ssm_c, "attn": attn_c}
    elif t in (cb.AUDIO, cb.ENC_DEC):
        x, caches, cross, _ = _transformer_stack(
            params["layers"], x, pos, cfg, mode="decode", caches=cache["self"],
            cross_caches=cache["cross"], step=step, has_cross=True)
        new_cache = {"self": caches, "cross": cross}
    else:
        raise ValueError(t)

    x = apply_norm(params["final_norm"], x, cfg)
    return {"logits": logits_head(params["embed"], x, cfg), "cache": new_cache}


def mask_padded_positions(cache, last_idx):
    """Invalidate ring-cache positions past each example's true last token.

    Right-padded batched prefill (the serving engine's bucketed admission,
    the exported spec-v2 prefill graph) writes garbage K/V at positions
    ``len..S-1``; setting their ``pos`` to -1 makes ``decode_attention`` mask
    them until real decode writes reclaim the slots one position at a time.
    Non-attention cache components (SSM state) pass through — callers only
    right-pad pure-attention architectures.  ``last_idx``: (B,) int32.
    """
    li = jnp.asarray(last_idx).reshape((1, -1, 1))

    def fix(v):
        if isinstance(v, LayerCache):
            return v._replace(
                pos=jnp.where((v.pos >= 0) & (v.pos <= li), v.pos, -1))
        return v
    return {k: fix(v) for k, v in cache.items()}


def make_paged_decode_cache(cfg: ModelConfig, batch: int, context_len: int,
                            *, num_blocks: int, block_size: int):
    """Paged twin of :func:`make_decode_cache`: a shared block pool sized by
    ``num_blocks`` (block 0 reserved as trash) instead of a dense
    ``batch x context_len`` ring per slot.  Attention-cache architectures
    only — recurrent SSM/hybrid state has nothing to page."""
    t = cfg.arch_type
    if t not in (cb.DENSE, cb.VLM, cb.MOE):
        raise ValueError(f"paged KV cache supports attention-cache "
                         f"architectures (dense/moe/vlm), not {t}")
    return {"self": attn_lib.empty_paged_cache(
        cfg, cfg.n_layers, num_blocks, batch, context_len, block_size,
        act_dtype(cfg))}


def make_decode_cache(params, cfg: ModelConfig, batch: int, context_len: int):
    """Build an empty decode cache shaped as if ``context_len`` tokens had been
    processed (what the decode dry-run shapes lower against)."""
    dtype = act_dtype(cfg)
    W = min(cfg.sliding_window or context_len, context_len)
    L = cfg.n_layers

    def stack(c, n):
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), c)

    t = cfg.arch_type
    if t in (cb.DENSE, cb.VLM, cb.MOE):
        return {"self": stack(attn_lib.empty_cache(cfg, batch, W, dtype), L)}
    if t == cb.SSM:
        return {"ssm": stack(ssm_lib.empty_ssm_cache(cfg, batch, dtype), L)}
    if t == cb.HYBRID:
        return {"ssm": stack(ssm_lib.empty_ssm_cache(cfg, batch, dtype), L),
                "attn": _empty_hybrid_attn_cache(cfg, batch, W, dtype)}
    if t in (cb.AUDIO, cb.ENC_DEC):
        M = cfg.dec_enc_len
        return {"self": stack(attn_lib.empty_cache(cfg, batch, W, dtype), L),
                "cross": stack(attn_lib.empty_cache(cfg, batch, M, dtype), L)}
    raise ValueError(t)
