"""Mamba2 (SSD — state-space duality) block, chunked scan + decode step.

Follows the SSD formulation of arXiv:2405.21060 with n_groups=1:

    h_t = exp(dt_t * A_h) h_{t-1} + dt_t * B_t ⊗ x_t        (per head h)
    y_t = C_t · h_t + D_h x_t

Training/prefill uses the chunked algorithm: an intra-chunk quadratic term
(MXU-friendly, the Pallas kernel target in ``repro.kernels.ssd_scan``) plus an
inter-chunk recurrence over chunk states.  Decode is the O(1) recurrent step
against a constant-size state — this is why SSM/hybrid archs run long_500k
natively (DESIGN.md).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import param_dtype


class SSMCache(NamedTuple):
    """Per-layer decode state (stacked on a leading layer axis by the model)."""
    h: jax.Array       # (B, H, N, P) fp32 SSD state
    conv: jax.Array    # (B, conv_w, conv_ch) rolling conv input buffer


def conv_channels(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_state


def init_ssm(key, cfg: ModelConfig):
    pdt = param_dtype(cfg)
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_n_heads
    ch = conv_channels(cfg)
    d_in_proj = 2 * di + 2 * N + H          # z, xBC, dt
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "in_proj": (jax.random.normal(k1, (d, d_in_proj)) * d ** -0.5).astype(pdt),
        "conv_w": (jax.random.normal(k2, (cfg.ssm_conv, ch)) * cfg.ssm_conv ** -0.5).astype(pdt),
        "conv_b": jnp.zeros((ch,), pdt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(pdt),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 0.01))).astype(pdt),  # softplus^-1
        "D": jnp.ones((H,), pdt),
        "norm_scale": jnp.ones((di,), pdt),
        "out_proj": (jax.random.normal(k4, (di, d)) * di ** -0.5).astype(pdt),
    }


def _causal_depthwise_conv(x, w, b):
    """x: (B, S, C); w: (W, C) depthwise kernel; causal padding."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    # (B, S, C) = sum_k xp[:, s+k, :] * w[k, :]
    out = jnp.zeros_like(x)
    for k in range(W):  # W is 4: unrolled adds beat conv_general on all backends
        out = out + xp[:, k:k + x.shape[1], :] * w[k][None, None, :]
    return out + b[None, None, :]


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, h0=None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x: (B, S, H, P); dt: (B, S, H) (post-softplus); A: (H,) negative;
    Bm, Cm: (B, S, N).  Returns (y (B, S, H, P), h_last (B, H, N, P) fp32).
    S must be a multiple of ``chunk`` (callers pad).
    """
    b, s, H, P = x.shape
    N = Bm.shape[-1]
    Q = chunk
    c = s // Q
    f32 = jnp.float32

    xdt = (x.astype(f32) * dt.astype(f32)[..., None]).reshape(b, c, Q, H, P)
    Bc = Bm.astype(f32).reshape(b, c, Q, N)
    Cc = Cm.astype(f32).reshape(b, c, Q, N)
    dtA = (dt.astype(f32) * A.astype(f32)).reshape(b, c, Q, H)   # negative
    cum = jnp.cumsum(dtA, axis=2)                                 # (b,c,Q,H)

    # --- intra-chunk (quadratic within chunk; Pallas kernel target) --------
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]           # (b,c,i,j,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)
    y_diag = jnp.einsum("bcij,bcijh,bcjhp->bcihp", scores, L, xdt)

    # --- chunk states -------------------------------------------------------
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)               # (b,c,Q,H)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", Bc, decay_to_end, xdt)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                       # (b,c,H)

    # --- inter-chunk recurrence --------------------------------------------
    h_init = (jnp.zeros((b, H, N, P), f32) if h0 is None else h0.astype(f32))

    def step(h, inp):
        d_c, s_c = inp
        h_new = d_c[:, :, None, None] * h + s_c
        return h_new, h                                           # emit state BEFORE chunk

    h_last, h_prev = jax.lax.scan(
        step, h_init,
        (chunk_decay.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)                      # (b,c,H,N,P)

    y_off = jnp.einsum("bcin,bchnp,bcih->bcihp", Cc, h_prev, jnp.exp(cum))
    y = (y_diag + y_off).reshape(b, s, H, P)
    return y.astype(x.dtype), h_last


def _split_proj(zxbcdt, cfg: ModelConfig):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_n_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + di + 2 * N]
    dt_raw = zxbcdt[..., di + di + 2 * N:]
    return z, xBC, dt_raw


def _gated_norm(y, z, scale, eps: float = 1e-5):
    g = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    return (g * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(y.dtype)


def ssm_forward(params, x, cfg: ModelConfig, h0=None, return_state: bool = False):
    """Full-sequence Mamba2 block.  x: (B, S, d_model)."""
    dt_act = x.dtype
    B_, S, _ = x.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_n_heads, cfg.ssm_head_dim

    zxbcdt = x @ params["in_proj"].astype(dt_act)
    z, xBC, dt_raw = _split_proj(zxbcdt, cfg)
    xBC = jax.nn.silu(_causal_depthwise_conv(
        xBC, params["conv_w"].astype(dt_act), params["conv_b"].astype(dt_act)))
    x_ssm, Bm, Cm = xBC[..., :di], xBC[..., di:di + N], xBC[..., di + N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))     # (B,S,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    xh = x_ssm.reshape(B_, S, H, P)
    # pad to a chunk multiple
    Q = cfg.ssm_chunk
    pad = (-S) % Q
    if pad:
        xh_p = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))   # dt=0 -> identity steps
        Bm_p = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm_p = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    else:
        xh_p, dt_p, Bm_p, Cm_p = xh, dt, Bm, Cm

    y, h_last = ssd_chunked(xh_p, dt_p, A, Bm_p, Cm_p, Q, h0=h0)
    y = y[:, :S]
    y = y + params["D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(B_, S, di)
    y = _gated_norm(y, z, params["norm_scale"])
    out = y @ params["out_proj"].astype(dt_act)
    if return_state:
        conv_w = cfg.ssm_conv
        # last conv_w raw (pre-conv) xBC inputs, zero-padded on the left
        x_tail = x[:, max(S - conv_w, 0):, :]
        xBC_raw = x_tail @ params["in_proj"][:, di:di + di + 2 * N].astype(dt_act)
        pad_l = max(conv_w - S, 0)
        tail = xBC_raw
        if pad_l:
            tail = jnp.pad(tail, ((0, 0), (pad_l, 0), (0, 0)))
        return out, SSMCache(h=h_last, conv=tail)
    return out


def ssm_decode_step(params, x, cache: SSMCache, cfg: ModelConfig):
    """One-token recurrent step.  x: (B, 1, d_model)."""
    dt_act = x.dtype
    B_ = x.shape[0]
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_n_heads, cfg.ssm_head_dim

    zxbcdt = x[:, 0] @ params["in_proj"].astype(dt_act)           # (B, ...)
    z, xBC_new, dt_raw = _split_proj(zxbcdt, cfg)
    conv = jnp.concatenate([cache.conv[:, 1:], xBC_new[:, None, :].astype(cache.conv.dtype)], axis=1)
    xBC = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", conv.astype(dt_act), params["conv_w"].astype(dt_act))
        + params["conv_b"].astype(dt_act))
    x_ssm, Bm, Cm = xBC[..., :di], xBC[..., di:di + N], xBC[..., di + N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))     # (B,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    a = jnp.exp(dt * A)                                               # (B,H)

    xh = x_ssm.reshape(B_, H, P).astype(jnp.float32)
    upd = jnp.einsum("bn,bh,bhp->bhnp", Bm.astype(jnp.float32), dt, xh)
    h = a[:, :, None, None] * cache.h + upd
    y = jnp.einsum("bn,bhnp->bhp", Cm.astype(jnp.float32), h)
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(B_, di).astype(dt_act)
    y = _gated_norm(y, z, params["norm_scale"])
    out = (y @ params["out_proj"].astype(dt_act))[:, None, :]
    return out, SSMCache(h=h, conv=conv)


def empty_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> SSMCache:
    return SSMCache(
        h=jnp.zeros((batch, cfg.ssm_n_heads, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm_conv, conv_channels(cfg)), dtype),
    )
