"""Architecture zoo substrate (pure-JAX, pytree parameters)."""
from repro.models.attention import LayerCache, PagedCache, PagedLayerView
from repro.models.model import (decode_step, forward, forward_suffix,
                                init_params, make_decode_cache,
                                make_paged_decode_cache,
                                mask_padded_positions, n_attn_apps,
                                param_count)

__all__ = ["LayerCache", "PagedCache", "PagedLayerView", "decode_step",
           "forward", "forward_suffix", "init_params", "make_decode_cache",
           "make_paged_decode_cache", "mask_padded_positions", "n_attn_apps",
           "param_count"]
