"""Client-side fine-tuning via federated averaging — the paper's future work.

Paper §Discussion: "to address the challenge of future model updates without
compromising the client-side privacy-preserving guarantee, we will explore
integrating client-side fine-tuning ... or more broadly via decentralized
federated learning, allowing the model to learn and improve while sensitive
data remains exclusively on the user's device."

This module implements that loop, JAX-native and mesh-aware in principle but
runnable on one host for the simulation:

  server params --broadcast--> K clients
  each client: E local AdamW steps on ITS OWN trajectories   (data never moves)
  each client: uploads only a parameter DELTA (optionally clipped + noised —
               the standard DP-SGD-at-the-update knob)
  server: sample-weighted average of deltas (FedAvg)

The client-side step reuses the exact training objective of the centralized
path (``core.delphi.loss_fn``), so a federated fine-tune is bit-compatible
with the exported FAIR artifact: clients can load the artifact's params.npz,
fine-tune locally, and ship deltas.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.train.optimizer import (OptimizerConfig, adamw_update,
                                   init_opt_state)
from repro.train.trainer import make_loss_fn


@dataclasses.dataclass(frozen=True)
class FedConfig:
    n_rounds: int = 5
    local_steps: int = 5
    local_lr: float = 5e-4
    clip_delta_norm: Optional[float] = None    # per-client update clipping
    dp_noise_mult: float = 0.0                 # sigma * clip / n_clients noise
    server_lr: float = 1.0                     # 1.0 = plain FedAvg


def _tree_sub(a, b):
    return jax.tree_util.tree_map(lambda x, y: x - y, a, b)


def _tree_add_scaled(a, b, s):
    return jax.tree_util.tree_map(lambda x, y: x + s * y, a, b)


def _tree_norm(t):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(t)))


def make_local_update(cfg: ModelConfig, fed: FedConfig,
                      objective: str = "delphi") -> Callable:
    """Returns jitted fn(params, batches_stacked) -> (delta, final_loss).

    ``batches_stacked``: pytree of arrays with a leading ``local_steps`` axis
    (one batch per local step) — the client's on-device data.
    """
    loss_fn = make_loss_fn(cfg, objective)
    ocfg = OptimizerConfig(lr=fed.local_lr, warmup_steps=0,
                           total_steps=max(fed.local_steps, 1),
                           min_lr_ratio=1.0)

    def local_update(params, batches_stacked):
        def step(carry, batch):
            p, opt = carry
            def scalar(pp):
                m = loss_fn(pp, batch)
                return m["loss"], m
            grads, m = jax.grad(scalar, has_aux=True)(p)
            p, opt, _ = adamw_update(grads, opt, p, ocfg)
            return (p, opt), m["loss"]

        (new_params, _), losses = jax.lax.scan(
            step, (params, init_opt_state(params)), batches_stacked)
        delta = _tree_sub(new_params, params)
        if fed.clip_delta_norm is not None:
            norm = _tree_norm(delta)
            scale = jnp.minimum(1.0, fed.clip_delta_norm
                                / jnp.maximum(norm, 1e-9))
            delta = jax.tree_util.tree_map(lambda d: d * scale, delta)
        return delta, losses[-1]

    return jax.jit(local_update)


def aggregate(params, deltas: Sequence, weights: Sequence[float],
              fed: FedConfig, rng=None):
    """Sample-weighted FedAvg of client deltas (+ optional Gaussian noise)."""
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.sum(w)
    avg = jax.tree_util.tree_map(
        lambda *ds: sum(wi * d.astype(jnp.float32)
                        for wi, d in zip(w, ds)), *deltas)
    if fed.dp_noise_mult > 0.0 and fed.clip_delta_norm is not None:
        assert rng is not None, "DP noise needs an rng"
        sigma = fed.dp_noise_mult * fed.clip_delta_norm / max(len(deltas), 1)
        leaves, treedef = jax.tree_util.tree_flatten(avg)
        keys = jax.random.split(rng, len(leaves))
        leaves = [l + sigma * jax.random.normal(k, l.shape)
                  for l, k in zip(leaves, keys)]
        avg = jax.tree_util.tree_unflatten(treedef, leaves)
    return jax.tree_util.tree_map(
        lambda p, d: (p.astype(jnp.float32)
                      + fed.server_lr * d).astype(p.dtype), params, avg)


def federated_finetune(params, cfg: ModelConfig,
                       client_iters: List[Iterator[Dict]], fed: FedConfig, *,
                       objective: str = "delphi", rng=None,
                       eval_fn: Optional[Callable] = None,
                       log_fn: Callable[[str], None] = print
                       ) -> Tuple[object, Dict[str, list]]:
    """Run ``fed.n_rounds`` of FedAvg over per-client batch iterators.

    Each element of ``client_iters`` yields batches *from that client's own
    patients only* — the privacy unit of the simulation.
    """
    local_update = make_local_update(cfg, fed, objective)
    hist = {"round": [], "client_loss": [], "val": []}
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    for r in range(fed.n_rounds):
        deltas, weights, losses = [], [], []
        for it in client_iters:
            bs = [next(it) for _ in range(fed.local_steps)]
            stacked = jax.tree_util.tree_map(
                lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]), *bs)
            delta, loss = local_update(params, stacked)
            deltas.append(delta)
            weights.append(float(bs[0]["tokens"].shape[0] * fed.local_steps))
            losses.append(float(loss))
        rng, kr = jax.random.split(rng)
        params = aggregate(params, deltas, weights, fed, rng=kr)
        hist["round"].append(r)
        hist["client_loss"].append(sum(losses) / len(losses))
        msg = (f"round {r}: mean client loss "
               f"{hist['client_loss'][-1]:.4f}")
        if eval_fn is not None:
            v = float(eval_fn(params))
            hist["val"].append(v)
            msg += f" | server val {v:.4f}"
        log_fn(msg)
    return params, hist
