"""Federated client-side fine-tuning (the paper's stated future work)."""
from repro.federated.fedavg import (FedConfig, aggregate, federated_finetune,
                                    make_local_update)

__all__ = ["FedConfig", "aggregate", "federated_finetune",
           "make_local_update"]
