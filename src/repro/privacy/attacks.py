"""Membership-inference and prompt-extraction probes — over the wire.

Both attacks speak only the public inference surface
(:class:`repro.api.InferenceBackend`: ``risk`` + ``sample_futures``), so
they audit exactly what a remote adversary with API access can measure —
no logits endpoint, no parameters.  ``RemoteBackend.logits`` raising is
the privacy boundary these probes respect by construction.

* **Membership inference** (loss-threshold attack): per-event
  log-likelihoods of a record under the served model, scored as the mean
  log P(next event = observed | history).  Members (trained-in canaries)
  score higher than held-out twins when the model memorizes; the
  separation is reported as ROC-AUC with a bootstrap CI.  AUC ~ 0.5
  means the model gives no membership signal; 1.0 means perfect
  re-identification.

* **Prompt extraction**: condition on a canary's natural prefix and
  sample N futures; the canary *leaks* when any single future emits at
  least ``match`` of its planted rare secret codes.  Rare codes
  essentially never co-occur by chance, so the member-vs-nonmember
  leakage gap is a direct verbatim-regurgitation measure.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from repro.api.schemas import FuturesRequest
from repro.privacy.canary import Canary

#: Horizon that saturates 1 - exp(-Lambda*h) -> 1, making the analytic
#: within-horizon risk equal softmax(logits) — an exact next-event
#: probability through the public ``risk`` endpoint.
INF_HORIZON = 1e9

_LOG_FLOOR = 1e-12


def event_log_likelihoods(backend, tokens: Sequence[int],
                          ages: Sequence[float], *, start: int = 1
                          ) -> np.ndarray:
    """log P(next = tokens[k] | tokens[:k]) for k in [start, len) via the
    public ``risk`` endpoint at a saturating horizon (top=V returns the
    full distribution).  One wire call per event."""
    out = []
    V = backend.vocab_size
    for k in range(start, len(tokens)):
        report = backend.risk(list(tokens[:k]), list(ages[:k]),
                              horizon=INF_HORIZON, top=V)
        probs = {it.token: it.risk for it in report.items}
        p = probs.get(int(tokens[k]), 0.0)
        out.append(np.log(max(p, _LOG_FLOOR)))
    return np.asarray(out, np.float64)


def membership_score(backend, canary: Canary, *,
                     secret_only: bool = True) -> float:
    """Mean per-event log-likelihood of a canary — the loss-threshold
    membership statistic.  ``secret_only`` scores just the planted
    secret (the rare events carry the memorization signal; the natural
    prefix is population-typical for members and non-members alike)."""
    start = canary.secret_start if secret_only else 1
    lls = event_log_likelihoods(backend, canary.tokens, canary.ages,
                                start=max(start, 1))
    return float(lls.mean()) if len(lls) else float(np.log(_LOG_FLOOR))


def membership_scores(backend, canaries: Sequence[Canary], *,
                      secret_only: bool = True) -> np.ndarray:
    return np.asarray([membership_score(backend, c,
                                        secret_only=secret_only)
                       for c in canaries], np.float64)


def roc_auc(pos: Sequence[float], neg: Sequence[float]) -> float:
    """Mann-Whitney ROC-AUC: P(member score > nonmember score), ties at
    0.5.  Exact over all pairs — no sorting approximations."""
    pos = np.asarray(pos, np.float64)
    neg = np.asarray(neg, np.float64)
    if not len(pos) or not len(neg):
        return 0.5
    diff = pos[:, None] - neg[None, :]
    return float(np.mean((diff > 0) + 0.5 * (diff == 0)))


def bootstrap_auc_ci(pos: Sequence[float], neg: Sequence[float], *,
                     n_boot: int = 200, alpha: float = 0.05,
                     seed: int = 0) -> Tuple[float, float]:
    """Percentile bootstrap CI for :func:`roc_auc` (resampling each
    group independently with replacement)."""
    pos = np.asarray(pos, np.float64)
    neg = np.asarray(neg, np.float64)
    if not len(pos) or not len(neg):
        return (0.5, 0.5)
    rng = np.random.default_rng(seed)
    aucs = np.empty(n_boot)
    for b in range(n_boot):
        aucs[b] = roc_auc(rng.choice(pos, size=len(pos)),
                          rng.choice(neg, size=len(neg)))
    lo, hi = np.quantile(aucs, [alpha / 2, 1.0 - alpha / 2])
    return (float(lo), float(hi))


def extraction_probe(backend, canary: Canary, *, n_futures: int = 8,
                     max_new: int = 16, match: int = 2,
                     seed: int = 0) -> bool:
    """True when the served model regurgitates the canary's secret:
    condition on the natural prefix, sample ``n_futures`` futures, and
    look for any single future containing >= ``match`` distinct secret
    codes.  Deterministic per (seed, canary) — the backend draws its
    uniforms from the request seed."""
    secret = set(canary.secret_tokens)
    req = FuturesRequest(
        tokens=[int(t) for t in canary.prefix_tokens],
        ages=[float(a) for a in canary.prefix_ages],
        n_futures=n_futures, max_new=max_new, top=1,
        seed=seed * 1000003 + canary.index + 1)
    out = backend.sample_futures(req)
    for t in out.trajectories:
        if len(secret & {int(x) for x in t.tokens}) >= match:
            return True
    return False


def extraction_rate(backend, canaries: Sequence[Canary], *,
                    n_futures: int = 8, max_new: int = 16,
                    match: int = 2, seed: int = 0
                    ) -> Tuple[float, List[bool]]:
    """Fraction of canaries whose secret leaks under
    :func:`extraction_probe`, plus the per-canary flags."""
    flags = [extraction_probe(backend, c, n_futures=n_futures,
                              max_new=max_new, match=match, seed=seed)
             for c in canaries]
    return (float(np.mean(flags)) if flags else 0.0, flags)
