"""Rarity-scored synthetic canary records for privacy red-teaming.

A canary is a plausible synthetic patient history (drawn from the same
competing-risk simulator as the training data) with an appended *secret*
— a short run of deliberately rare diagnoses chosen from the lowest
base-log-hazard codes of the simulated disease universe.  Rare codes
almost never co-occur by chance, so any probability mass the served
model puts on a canary's secret is evidence of memorization, not of the
population distribution.

Canaries come in deterministic member / non-member pairs (even index ->
trained-in, odd -> held-out): ``inject_canaries`` plants the members
into a training set, and the audit attacks
(:mod:`repro.privacy.attacks`) score both groups identically so the
member-vs-nonmember separation IS the privacy leak.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from repro.data import vocab as V
from repro.data.synthetic import (SimulatorConfig, hazard_params,
                                  simulate_patient)

#: Disambiguates canary streams from ``data.synthetic.patient`` streams
#: and the cohort sweep's uniform streams under the same user seed.
_CANARY_TAG = 15485863

#: Fraction of the disease universe (rarest by base log-hazard) the
#: secret codes are drawn from.
RARE_FRACTION = 0.05


@dataclasses.dataclass
class Canary:
    """One canary record.  ``tokens[:secret_start]`` is the natural
    prefix; ``tokens[secret_start:]`` is the planted secret."""
    index: int
    tokens: np.ndarray
    ages: np.ndarray
    secret_start: int
    rarity: float
    member: bool

    @property
    def prefix_tokens(self) -> np.ndarray:
        return self.tokens[:self.secret_start]

    @property
    def prefix_ages(self) -> np.ndarray:
        return self.ages[:self.secret_start]

    @property
    def secret_tokens(self) -> List[int]:
        return [int(t) for t in self.tokens[self.secret_start:]]

    def to_json(self) -> dict:
        return {"index": int(self.index),
                "tokens": [int(t) for t in self.tokens],
                "ages": [float(a) for a in self.ages],
                "secret_start": int(self.secret_start),
                "rarity": float(self.rarity),
                "member": bool(self.member)}


def rare_code_pool(cfg: SimulatorConfig,
                   fraction: float = RARE_FRACTION) -> np.ndarray:
    """Disease-code indices (0-based, NOT vocab tokens) of the rarest
    ``fraction`` of the simulated universe by base log-hazard ``a`` —
    the canary secret alphabet."""
    a, _, _, _ = hazard_params(cfg)
    k = max(8, int(len(a) * fraction))
    return np.argsort(a, kind="stable")[:k]


def make_canaries(n: int, cfg: SimulatorConfig = SimulatorConfig(), *,
                  seed: int = 0, secret_len: int = 4,
                  prefix_events: int = 8) -> List[Canary]:
    """``n`` deterministic canaries over ``cfg``'s disease universe.

    Canary ``i`` derives everything from
    ``default_rng([cfg.seed, tag, seed, i])`` — O(1) regeneration, same
    discipline as ``data.synthetic.patient`` — so the audit CLI and the
    training-time ``inject_canaries`` agree on the exact records without
    shipping them.  Even indices are members (train them in), odd are
    held out.  ``rarity`` is the negative summed base log-hazard of the
    secret codes: higher = rarer = stronger memorization signal.
    """
    a, b, partners, boosts = hazard_params(cfg)
    pool = rare_code_pool(cfg)
    out: List[Canary] = []
    for i in range(n):
        rng = np.random.default_rng([cfg.seed, _CANARY_TAG, seed, i])
        toks, ags = simulate_patient(rng, a, b, partners, boosts, cfg)
        while len(toks) < 3:        # deterministic redraw from the same
            toks, ags = simulate_patient(rng, a, b, partners, boosts,
                                         cfg)   # per-canary stream
        k = min(prefix_events, len(toks))
        prefix_t, prefix_a = list(toks[:k]), list(ags[:k])
        if prefix_t[-1] == V.DEATH:             # a secret needs a future
            prefix_t, prefix_a = prefix_t[:-1], prefix_a[:-1]
        codes = rng.choice(pool, size=secret_len, replace=False)
        age = float(prefix_a[-1])
        secret_t, secret_a = [], []
        for c in codes:
            age += float(rng.uniform(0.5, 1.5))
            secret_t.append(int(V.DISEASE0 + int(c)))
            secret_a.append(age)
        out.append(Canary(
            index=i,
            tokens=np.asarray(prefix_t + secret_t, np.int32),
            ages=np.asarray(prefix_a + secret_a, np.float32),
            secret_start=len(prefix_t),
            rarity=float(-np.sum(a[codes])),
            member=(i % 2 == 0)))
    return out


def split_canaries(canaries: Sequence[Canary]
                   ) -> Tuple[List[Canary], List[Canary]]:
    """(members, nonmembers)."""
    return ([c for c in canaries if c.member],
            [c for c in canaries if not c.member])


def inject_canaries(train: List[Tuple[np.ndarray, np.ndarray]],
                    canaries: Sequence[Canary], *, repeats: int = 1
                    ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Training set with every *member* canary planted ``repeats`` times
    (repetition strengthens memorization, as in real duplicated records).
    Non-members are never added — they are the control group."""
    out = list(train)
    for c in canaries:
        if c.member:
            out.extend([(c.tokens.copy(), c.ages.copy())] * repeats)
    return out
