"""Red-team privacy audit: canaries + attacks -> PrivacyAuditReport.

``run_audit`` scores a trained-in vs held-out canary split against any
inference backend and emits a machine-readable report: membership-
inference ROC-AUC (with bootstrap CI) and prompt-extraction leakage
rates.  The ``repro-audit`` console script runs the same audit against a
served checkpoint over the HTTP wire — the threat model a FAIR,
privacy-preserving deployment must answer for — so the federated path
and future DP noise have a measurable privacy axis next to the perf
axis:

    repro-serve --config delphi-2m --reduced --port 8433 &
    repro-audit --url http://127.0.0.1:8433 --canaries 8 --out audit.json

Reading the numbers: ``mi_auc`` ~ 0.5 = the model cannot tell members
from held-out twins (good); -> 1.0 = per-record re-identification from
API access alone.  ``extraction_gap`` = member minus non-member leakage
rate; > 0 means the model regurgitates planted secrets it trained on.
The audit assumes the server was trained with ``inject_canaries`` over
the SAME canary spec (simulator seed / audit seed / counts) — canaries
regenerate deterministically on both sides, nothing is shipped.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import List, Optional, Tuple

from repro.api.schemas import WIRE_PROTOCOL_VERSION
from repro.data.synthetic import SimulatorConfig
from repro.privacy.attacks import (bootstrap_auc_ci, extraction_rate,
                                   membership_scores, roc_auc)
from repro.privacy.canary import Canary, make_canaries, split_canaries


@dataclasses.dataclass
class PrivacyAuditReport:
    """Machine-readable audit outcome (JSON round-trips)."""
    backend: str
    n_members: int
    n_nonmembers: int
    mi_auc: float
    mi_auc_ci: Tuple[float, float]
    member_scores: List[float]
    nonmember_scores: List[float]
    member_extraction_rate: float
    nonmember_extraction_rate: float
    config: dict = dataclasses.field(default_factory=dict)

    @property
    def extraction_gap(self) -> float:
        return self.member_extraction_rate - self.nonmember_extraction_rate

    def to_json(self) -> dict:
        return {
            "protocol_version": WIRE_PROTOCOL_VERSION,
            "backend": self.backend,
            "n_members": int(self.n_members),
            "n_nonmembers": int(self.n_nonmembers),
            "mi_auc": float(self.mi_auc),
            "mi_auc_ci": [float(self.mi_auc_ci[0]),
                          float(self.mi_auc_ci[1])],
            "member_scores": [float(s) for s in self.member_scores],
            "nonmember_scores": [float(s) for s in self.nonmember_scores],
            "member_extraction_rate": float(self.member_extraction_rate),
            "nonmember_extraction_rate":
                float(self.nonmember_extraction_rate),
            "extraction_gap": float(self.extraction_gap),
            "config": self.config,
        }

    @classmethod
    def from_json(cls, d: dict) -> "PrivacyAuditReport":
        return cls(backend=str(d.get("backend", "")),
                   n_members=int(d["n_members"]),
                   n_nonmembers=int(d["n_nonmembers"]),
                   mi_auc=float(d["mi_auc"]),
                   mi_auc_ci=(float(d["mi_auc_ci"][0]),
                              float(d["mi_auc_ci"][1])),
                   member_scores=[float(s) for s in d["member_scores"]],
                   nonmember_scores=[float(s)
                                     for s in d["nonmember_scores"]],
                   member_extraction_rate=float(
                       d["member_extraction_rate"]),
                   nonmember_extraction_rate=float(
                       d["nonmember_extraction_rate"]),
                   config=dict(d.get("config") or {}))


def run_audit(backend, members: List[Canary], nonmembers: List[Canary], *,
              n_futures: int = 8, max_new: int = 16, match: int = 2,
              n_boot: int = 200, seed: int = 0,
              secret_only: bool = True) -> PrivacyAuditReport:
    """Score both canary groups through the backend's public surface and
    aggregate into a :class:`PrivacyAuditReport`."""
    m_scores = membership_scores(backend, members, secret_only=secret_only)
    n_scores = membership_scores(backend, nonmembers,
                                 secret_only=secret_only)
    auc = roc_auc(m_scores, n_scores)
    ci = bootstrap_auc_ci(m_scores, n_scores, n_boot=n_boot, seed=seed)
    m_rate, _ = extraction_rate(backend, members, n_futures=n_futures,
                                max_new=max_new, match=match, seed=seed)
    n_rate, _ = extraction_rate(backend, nonmembers, n_futures=n_futures,
                                max_new=max_new, match=match, seed=seed)
    return PrivacyAuditReport(
        backend=getattr(backend, "name", ""),
        n_members=len(members), n_nonmembers=len(nonmembers),
        mi_auc=auc, mi_auc_ci=ci,
        member_scores=[float(s) for s in m_scores],
        nonmember_scores=[float(s) for s in n_scores],
        member_extraction_rate=m_rate,
        nonmember_extraction_rate=n_rate,
        config={"n_futures": n_futures, "max_new": max_new,
                "match": match, "n_boot": n_boot, "seed": seed,
                "secret_only": secret_only})


def _build_backend(args):
    from repro.api.client import Client
    if args.url:
        return Client.connect(args.url).backend
    if args.artifact:
        return Client.from_artifact(args.artifact).backend
    raise SystemExit("repro-audit: pass --url (served checkpoint) "
                     "or --artifact (exported directory)")


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(
        prog="repro-audit",
        description="Membership-inference + prompt-extraction audit of a "
                    "served checkpoint, over the public inference API.")
    p.add_argument("--url", help="server base URL (http://host:port)")
    p.add_argument("--artifact", help="exported artifact directory")
    p.add_argument("--canaries", type=int, default=8,
                   help="total canaries (even=member, odd=held-out)")
    p.add_argument("--secret-len", type=int, default=4)
    p.add_argument("--prefix-events", type=int, default=8)
    p.add_argument("--sim-seed", type=int, default=0,
                   help="SimulatorConfig seed the canaries derive from "
                        "(must match the training side)")
    p.add_argument("--seed", type=int, default=0,
                   help="audit seed (canary streams + attack draws)")
    p.add_argument("--n-futures", type=int, default=8)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--match", type=int, default=2,
                   help="secret codes one future must emit to count as "
                        "leaked")
    p.add_argument("--n-boot", type=int, default=200)
    p.add_argument("--out", help="write the report JSON here "
                                 "(default: stdout)")
    args = p.parse_args(argv)

    backend = _build_backend(args)
    canaries = make_canaries(args.canaries,
                             SimulatorConfig(seed=args.sim_seed),
                             seed=args.seed, secret_len=args.secret_len,
                             prefix_events=args.prefix_events)
    members, nonmembers = split_canaries(canaries)
    report = run_audit(backend, members, nonmembers,
                       n_futures=args.n_futures, max_new=args.max_new,
                       match=args.match, n_boot=args.n_boot,
                       seed=args.seed)
    payload = json.dumps(report.to_json(), indent=2, sort_keys=True)
    if args.out:
        with open(args.out, "w") as f:
            f.write(payload + "\n")
    else:
        print(payload)
    print(f"repro-audit: MI AUC {report.mi_auc:.3f} "
          f"[{report.mi_auc_ci[0]:.3f}, {report.mi_auc_ci[1]:.3f}] | "
          f"extraction member {report.member_extraction_rate:.2f} vs "
          f"held-out {report.nonmember_extraction_rate:.2f} "
          f"(gap {report.extraction_gap:+.2f})", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
