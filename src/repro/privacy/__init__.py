"""Privacy red-team audit harness: rarity-scored canaries, membership-
inference and prompt-extraction probes, and the ``repro-audit`` CLI.

    from repro.privacy import make_canaries, inject_canaries, run_audit

    canaries = make_canaries(8, sim_cfg, seed=1)
    train = inject_canaries(train, canaries, repeats=4)   # before training
    ...
    members, nonmembers = split_canaries(canaries)
    report = run_audit(backend, members, nonmembers)      # after serving
"""
from repro.privacy.attacks import (bootstrap_auc_ci, event_log_likelihoods,
                                   extraction_probe, extraction_rate,
                                   membership_score, membership_scores,
                                   roc_auc)
from repro.privacy.audit import PrivacyAuditReport, main, run_audit
from repro.privacy.canary import (Canary, inject_canaries, make_canaries,
                                  rare_code_pool, split_canaries)

__all__ = [
    "Canary", "PrivacyAuditReport", "bootstrap_auc_ci",
    "event_log_likelihoods", "extraction_probe", "extraction_rate",
    "inject_canaries", "main", "make_canaries", "membership_score",
    "membership_scores", "rare_code_pool", "roc_auc", "run_audit",
    "split_canaries",
]
