"""Pallas TPU kernels (validated in interpret mode on CPU) + jnp oracles."""
from repro.kernels.ops import (flash_attention, paged_decode_attention,
                               ssd_intra, suffix_prefill_attention,
                               tte_sample)

__all__ = ["flash_attention", "paged_decode_attention", "ssd_intra",
           "suffix_prefill_attention", "tte_sample"]
