"""Jit'd public wrappers around the Pallas kernels (padding, dtype policy).

``interpret=True`` (the default on this CPU container) runs the kernel bodies
through the Pallas interpreter — same code path that compiles for TPU, minus
the Mosaic lowering.  On TPU, call with ``interpret=False`` (or set
``ModelConfig.use_pallas=True`` so the model layers route here).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.paged_attention import paged_decode_attention as _paged
from repro.kernels.ssd_scan import ssd_intra as _ssd_intra
from repro.kernels.tte_sample import tte_sample as _tte


def _pad_axis(x, axis: int, mult: int, value=0.0):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, bq: int = 128,
                    bk: int = 128, interpret: bool = True) -> jax.Array:
    """q: (B, Hq, S, hd); k, v: (B, Hkv, T, hd) -> (B, Hq, S, hd).

    Pads S/T to block multiples; padded KV rows are masked out by the causal
    predicate (they sit at positions > any query) and padded Q rows are
    sliced off.
    """
    S, T = q.shape[2], k.shape[2]
    qp = _pad_axis(q, 2, bq)
    kp = _pad_axis(k, 2, bk)
    vp = _pad_axis(v, 2, bk)
    if not causal and kp.shape[2] != T:
        raise ValueError("non-causal flash requires T % bk == 0 "
                         "(padding would attend to garbage)")
    out = _flash(qp, kp, vp, causal=causal, window=window, bq=bq, bk=bk,
                 interpret=interpret)
    return out[:, :, :S]


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra(xdt, Bm, Cm, cum, *, interpret: bool = True
              ) -> Tuple[jax.Array, jax.Array]:
    """Intra-chunk SSD: see kernels/ssd_scan.py.  Shapes (BH, C, Q, ·)."""
    return _ssd_intra(xdt, Bm, Cm, cum, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode_attention(q, k_pool, v_pool, table, pos, step, *,
                           window: Optional[int] = None,
                           interpret: Optional[bool] = None) -> jax.Array:
    """Fused paged decode: block-table gather + online softmax in one pass.

    q: (B, Hq, hd) one roped query token per slot; k/v_pool:
    (NB, Hkv, bs, hd) shared block pool; table: (B, nbs) pool ids (-1 =
    unallocated); pos: (NB, bs) absolute positions (-1 = empty); step: (B,)
    per-slot query positions.  GQA by the same (Hkv, G) grouping as
    ``decode_attention``.  ``interpret=None`` resolves by backend like
    ``tte_sample``.  Returns (B, Hq, hd).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Hq, hd = q.shape
    Hkv = k_pool.shape[1]
    q4 = q.reshape(B, Hkv, Hq // Hkv, hd)
    out = _paged(q4, k_pool, v_pool, table, pos, step, window=window,
                 interpret=interpret)
    return out.reshape(B, Hq, hd)


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_per_kv"))
def suffix_prefill_attention(q, k, v, ctx_k, ctx_v, q_pos, ctx_pos, *,
                             causal: bool = True,
                             window: Optional[int] = None,
                             q_per_kv: int = 1) -> jax.Array:
    """Suffix prefill: chunk queries attend over cached context K/V plus the
    chunk itself, masked by absolute position.

    q/k/v: (B, Sc, Hq|Hkv, hd) the suffix chunk's projected (roped) heads;
    ctx_k/ctx_v: (B, C, Hkv, hd) pre-existing cache context (earlier chunks
    or prefix-cache hits); q_pos: (B, Sc) and ctx_pos: (B, C) absolute
    positions, -1 = invalid (right padding / trash-block slots).  Causality
    and sliding windows are decided by position difference, so a chunk
    starting mid-prompt composes exactly with the context before it.
    Dispatches through the online-softmax chunked path (the jnp flash twin
    of ``kernels.flash_attention`` — a concat along KV would break its
    index-based causal predicate, positions are the ground truth here).
    Returns (B, Sc, Hq, hd).
    """
    from repro.models.attention import chunked_attention
    kc = jnp.concatenate([ctx_k, k], axis=1)
    vc = jnp.concatenate([ctx_v, v], axis=1)
    kp = jnp.concatenate([ctx_pos, q_pos], axis=1)
    return chunked_attention(q, kc, vc, q_pos, kp, causal=causal,
                             window=window, q_per_kv=q_per_kv)


@functools.partial(jax.jit, static_argnames=("bv", "interpret"))
def tte_sample(logits, u, *, bv: int = 2048,
               interpret: Optional[bool] = None
               ) -> Tuple[jax.Array, jax.Array]:
    """Fused competing-exponential sampler: (B, V) -> (event, t_min).

    Pads the vocab axis with neutral entries (rate ~ e^-100: never wins).
    ``interpret=None`` resolves by backend: Mosaic lowering on TPU, the
    Pallas interpreter elsewhere — so the serving engine's Pallas sampling
    path is portable without call-site branching.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    V = logits.shape[1]
    b = min(bv, max(256, 1 << (V - 1).bit_length()))
    lp = _pad_axis(logits.astype(jnp.float32), 1, b, value=-100.0)
    up = _pad_axis(u.astype(jnp.float32), 1, b, value=0.5)
    return _tte(lp, up, bv=b, interpret=interpret)
