"""Pallas TPU kernel for the paper's time-to-event sampler (eq. 1), fused.

Computes, per batch row,

    t_i  = -exp(-logit_i) * ln(u_i)
    event = argmin_i t_i ,   t_min = min_i t_i

without materializing the (B, V) waiting-time tensor in HBM — at Delphi scale
(V=1,289) this is a convenience; at the zoo's 256,206-token vocabularies the
fusion saves a full 1 MB/row round trip per generation step, which is the
entire serving inner loop.

The vocabulary is tiled over the innermost grid dimension; VMEM scratch holds
the running (min, argmin) pair which is written out on the last tile.
Uniforms are an explicit input (threefry on device or host-provided), keeping
the kernel deterministic and runtime-reproducible — the property the paper's
cross-runtime parity story depends on.

Tie-breaking matches ``jnp.argmin`` exactly (lowest index wins: strict ``<``
across tiles, first-index argmin within a tile), so the serving engine can
swap this kernel in for the jnp reference sampler (``sampler="pallas"``)
without breaking bit-parity against the SDK (claims C2/C3).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BIG = 3.4e38


def _tte_kernel(lg_ref, u_ref, evt_ref, tmin_ref, best_t, best_i, *, bv: int):
    iv = pl.program_id(1)
    nv = pl.num_programs(1)

    @pl.when(iv == 0)
    def _init():
        best_t[...] = jnp.full_like(best_t, BIG)
        best_i[...] = jnp.zeros_like(best_i)

    lg = lg_ref[...].astype(jnp.float32)         # (1, bv)
    u = u_ref[...].astype(jnp.float32)
    u = jnp.clip(u, 1e-12, 1.0 - 1e-12)
    t = -jnp.exp(-lg) * jnp.log(u)               # (1, bv)
    idx = iv * bv + jax.lax.broadcasted_iota(jnp.int32, (1, bv), 1)
    # local (min, argmin) of this tile, 2-D shapes throughout (TPU-friendly)
    loc_t = jnp.min(t, axis=1)[0]
    loc_i = idx[0, jnp.argmin(t, axis=1)[0]]
    better = loc_t < best_t[0, 0]
    best_i[0, 0] = jnp.where(better, loc_i, best_i[0, 0])
    best_t[0, 0] = jnp.where(better, loc_t, best_t[0, 0])

    @pl.when(iv == nv - 1)
    def _finish():
        evt_ref[0] = best_i[0, 0]
        tmin_ref[0] = best_t[0, 0]


def tte_sample(logits, u, *, bv: int = 2048, interpret: bool = False
               ) -> Tuple[jax.Array, jax.Array]:
    """logits, u: (B, V) -> (event (B,) int32, t_min (B,) fp32).

    V must be divisible by bv (ops.py pads with neutral entries).
    """
    B, V = logits.shape
    kern = functools.partial(_tte_kernel, bv=bv)
    evt, tmin = pl.pallas_call(
        kern,
        grid=(B, V // bv),
        in_specs=[
            pl.BlockSpec((1, bv), lambda b, iv: (b, iv)),
            pl.BlockSpec((1, bv), lambda b, iv: (b, iv)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda b, iv: (b,)),
            pl.BlockSpec((1,), lambda b, iv: (b,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B,), jnp.int32),
            jax.ShapeDtypeStruct((B,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(logits, u)
    return evt, tmin
