"""Pallas TPU kernel for paged single-token decode attention.

The serving engine's paged KV cache stores K/V in a shared pool of
fixed-size blocks with per-slot block tables (``repro.models.attention.
PagedCache``).  The jnp read path reconstructs a dense ring view per layer
(a gather that materializes ``(B, Hkv, W, hd)`` transiently); this kernel is
the fused twin: the block table is **scalar-prefetched**, so each grid step
DMAs exactly one pool block straight from its table-indexed HBM location
into VMEM and folds it into an online softmax — gather and attention in one
pass, no dense intermediate.  At pool scale the resident win is the paged
cache itself; this kernel removes the read path's transient so decode
bandwidth is ``tokens held``, not ``slots x max_context``.

Grid: ``(B, Hkv, blocks_per_slot)``; the innermost dimension walks one
slot's table sequentially, carrying fp32 ``(acc, m, l)`` in VMEM scratch
(same online-softmax scheme as ``flash_attention``).  Unallocated table
entries (id -1) are clamped to block 0 in the index map and skipped with
``pl.when`` — no MXU work, no contribution.

Masking matches ``decode_attention`` on the gathered ring view exactly:
``pos >= 0 & pos <= step & pos > step - W`` (+ sliding window), with
``W = blocks_per_slot * block_size`` the logical ring width.  Slots whose
blocks are all invalid return zeros (the engine never decodes an empty
slot).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(tbl_ref, stp_ref, q_ref, k_ref, v_ref, pos_ref, o_ref,
                  acc, m_s, l_s, *, W: int, scale: float,
                  window: Optional[int]):
    b = pl.program_id(0)
    i = pl.program_id(2)
    ni = pl.num_programs(2)

    @pl.when(i == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    blk = tbl_ref[b, i]
    step = stp_ref[b]

    @pl.when(blk >= 0)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)           # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)           # (bs, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        p = pos_ref[...]                              # (1, bs) int32
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        valid = (p >= 0) & (p <= step) & (p > step - W)
        if window is not None:
            valid = jnp.logical_and(valid, p > step - window)
        s = jnp.where(valid, s, NEG_INF)              # (G, bs) via broadcast
        m_new = jnp.maximum(m_s[...], jnp.max(s, axis=-1, keepdims=True))
        pexp = jnp.exp(s - m_new)
        alpha = jnp.exp(m_s[...] - m_new)
        l_s[...] = l_s[...] * alpha + jnp.sum(pexp, axis=-1, keepdims=True)
        acc[...] = acc[...] * alpha + jax.lax.dot_general(
            pexp, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_s[...] = m_new

    @pl.when(i == ni - 1)
    def _finish():
        o_ref[0, 0] = (acc[...] /
                       jnp.maximum(l_s[...], 1e-30)).astype(o_ref.dtype)


def paged_decode_attention(q, k_pool, v_pool, table, pos, step, *,
                           window: Optional[int] = None,
                           interpret: bool = False) -> jax.Array:
    """q: (B, Hkv, G, hd); k/v_pool: (NB, Hkv, bs, hd); table: (B, nbs)
    int32 pool ids (-1 = unallocated); pos: (NB, bs) int32 absolute
    positions (-1 = empty); step: (B,) int32 query positions.
    Returns (B, Hkv, G, hd)."""
    B, Hkv, G, hd = q.shape
    bs = k_pool.shape[2]
    nbs = table.shape[1]
    kern = functools.partial(_paged_kernel, W=nbs * bs, scale=hd ** -0.5,
                             window=window)

    def _blk(b, h, i, tbl, stp):
        return (jnp.maximum(tbl[b, i], 0), h, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, nbs),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, i, tbl, stp: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, hd), _blk),
            pl.BlockSpec((1, 1, bs, hd), _blk),
            pl.BlockSpec((1, bs),
                         lambda b, h, i, tbl, stp: (jnp.maximum(tbl[b, i], 0),
                                                    0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, h, i, tbl, stp: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, hd), q.dtype),
        interpret=interpret,
    )(table.astype(jnp.int32), step.astype(jnp.int32), q, k_pool, v_pool,
      pos.astype(jnp.int32))
