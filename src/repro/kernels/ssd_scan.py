"""Pallas TPU kernel for the Mamba2 SSD intra-chunk compute.

The SSD chunked algorithm splits into (a) a quadratic *intra-chunk* term plus
per-chunk state construction — dense (Q x Q) and (Q x N) matmuls, ideal MXU
work — and (b) a cheap linear *inter-chunk* recurrence.  This kernel computes
(a) per (batch*head, chunk) grid cell with everything resident in VMEM:

  y_diag = (C B^T  o  L) (dt*x)         L_ij = exp(cum_i - cum_j), i >= j
  state  = B^T (exp(cum_Q - cum) * dt*x)

The inter-chunk scan (b) and the off-diagonal contribution stay in plain JAX
(``repro.models.ssm``) — they are O(S/Q) and bandwidth-trivial.

Chunk Q = 128 keeps every operand MXU-aligned; the tile working set is
Q*(P + 2N + Q) fp32 ~ 0.3 MiB for (Q=128, P=64, N=128), far under VMEM.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_intra_kernel(xdt_ref, b_ref, c_ref, cum_ref, y_ref, st_ref, *, q: int):
    xdt = xdt_ref[0, 0].astype(jnp.float32)      # (Q, P)
    Bm = b_ref[0, 0].astype(jnp.float32)         # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)         # (Q, N)
    cum = cum_ref[0, 0].astype(jnp.float32)      # (Q, 1)

    seg = cum - cum.reshape(1, q)                # (Q, Q) cum_i - cum_j
    qi = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    kj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where(qi >= kj, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(scores * L, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    decay = jnp.exp(cum[q - 1, 0] - cum)         # (Q, 1)
    st = jax.lax.dot_general(Bm, decay * xdt, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    st_ref[0, 0] = st.astype(st_ref.dtype)


def ssd_intra(xdt, Bm, Cm, cum, *, interpret: bool = False
              ) -> Tuple[jax.Array, jax.Array]:
    """Intra-chunk SSD.

    xdt: (BH, C, Q, P) dt-scaled inputs; Bm, Cm: (BH, C, Q, N);
    cum: (BH, C, Q) cumulative dt*A.  Returns
    (y_diag (BH, C, Q, P) fp32, states (BH, C, N, P) fp32).
    """
    BH, C, Q, P = xdt.shape
    N = Bm.shape[-1]
    kern = functools.partial(_ssd_intra_kernel, q=Q)
    return pl.pallas_call(
        kern,
        grid=(BH, C),
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, c: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, c: (b, c, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, C, Q, P), jnp.float32),
            jax.ShapeDtypeStruct((BH, C, N, P), jnp.float32),
        ],
        interpret=interpret,
    )(xdt, Bm, Cm, cum[..., None])
