"""Pallas TPU flash attention (causal / sliding-window, GQA).

TPU-native adaptation of the attention hot spot: online-softmax over KV
blocks with fp32 VMEM accumulators.  The grid is (batch, q_heads, q_blocks,
kv_blocks); the TPU grid is executed sequentially over the innermost
dimension, so VMEM scratch carries (acc, m, l) across KV blocks of one query
block.  Causal and sliding-window tiles that are fully masked are skipped
with ``pl.when`` (no MXU work issued).

Block shapes are (BQ, head_dim) / (BK, head_dim) with BQ = BK = 128 by
default — MXU-aligned (128 lanes) and small enough that the working set
(q + k + v + acc tiles, fp32) stays well under a v5e core's ~128 MiB of VMEM
even at head_dim 256.

GQA is expressed in the index maps: the KV block index map divides the query
head by ``q_per_kv``, so KV tiles are fetched once per KV head group.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s, *,
                  bq: int, bk: int, scale: float, causal: bool,
                  window: Optional[int]):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    q_start = iq * bq
    k_start = ik * bk

    # tile-level skip: fully-masked tiles issue no MXU work
    if causal:
        run = k_start <= q_start + bq - 1            # some (i >= j)
        if window is not None:
            run = jnp.logical_and(
                run, k_start + bk - 1 > q_start - window)  # some (i - j < w)
    else:
        run = ik >= 0                                 # always true (traced)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qi = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kj = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            rel = qi - kj
            valid = rel >= 0
            if window is not None:
                valid = jnp.logical_and(valid, rel < window)
            s = jnp.where(valid, s, NEG_INF)
        m_prev = m_s[...]
        l_prev = l_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_s[...] = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc[...] = acc[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_s[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc[...] / jnp.maximum(l_s[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, bq: int = 128,
                    bk: int = 128, interpret: bool = False) -> jax.Array:
    """q: (B, Hq, S, hd); k, v: (B, Hkv, T, hd) -> (B, Hq, S, hd).

    S must be divisible by bq and T by bk (ops.py pads).
    """
    B, Hq, S, hd = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    G = Hq // Hkv
    grid = (B, Hq, S // bq, T // bk)
    kern = functools.partial(
        _flash_kernel, bq=bq, bk=bk, scale=hd ** -0.5, causal=causal,
        window=window)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
