"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

These are deliberately naive (quadratic attention, sequential SSD recurrence,
full-materialization sampling) — small-shape exact references the kernel
sweeps assert against.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None) -> jax.Array:
    """q: (B, Hq, S, hd); k, v: (B, Hkv, T, hd).  GQA by head repetition."""
    B, Hq, S, hd = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    G = Hq // Hkv
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * hd ** -0.5
    if causal:
        rel = jnp.arange(S)[:, None] - jnp.arange(T)[None, :]
        valid = rel >= 0
        if window is not None:
            valid &= rel < window
        s = jnp.where(valid[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)


def ssd_ref(x, dt, A, Bm, Cm) -> Tuple[jax.Array, jax.Array]:
    """Sequential SSD recurrence (exact).

    x: (B, S, H, P); dt: (B, S, H); A: (H,) negative; Bm, Cm: (B, S, N).
    Returns (y (B, S, H, P), final state (B, H, N, P)), all fp32 math.
    """
    B_, S, H, P = x.shape
    N = Bm.shape[-1]
    f32 = jnp.float32
    x, dt, Bm, Cm = (t.astype(f32) for t in (x, dt, Bm, Cm))
    A = A.astype(f32)

    def step(h, inp):
        xt, dtt, bt, ct = inp           # (B,H,P), (B,H), (B,N), (B,N)
        a = jnp.exp(dtt * A[None])      # (B,H)
        h = a[..., None, None] * h + jnp.einsum("bn,bh,bhp->bhnp", bt, dtt, xt)
        y = jnp.einsum("bn,bhnp->bhp", ct, h)
        return h, y

    h0 = jnp.zeros((B_, H, N, P), f32)
    hT, ys = jax.lax.scan(step, h0,
                          (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
                           Bm.transpose(1, 0, 2), Cm.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2, 3), hT


def ssd_intra_ref(xdt, Bm, Cm, cum) -> Tuple[jax.Array, jax.Array]:
    """Oracle for the intra-chunk kernel (one (head, chunk) tile).

    xdt: (Q, P) dt-scaled inputs; Bm, Cm: (Q, N); cum: (Q,) cumulative dt*A.
    Returns (y_diag (Q, P), state (N, P)).
    """
    f32 = jnp.float32
    xdt, Bm, Cm, cum = (t.astype(f32) for t in (xdt, Bm, Cm, cum))
    Q = xdt.shape[0]
    seg = cum[:, None] - cum[None, :]
    L = jnp.where(jnp.tril(jnp.ones((Q, Q), bool)), jnp.exp(seg), 0.0)
    scores = Cm @ Bm.T                       # (Q, Q)
    y = (scores * L) @ xdt                   # (Q, P)
    decay_to_end = jnp.exp(cum[-1] - cum)    # (Q,)
    state = Bm.T @ (decay_to_end[:, None] * xdt)   # (N, P)
    return y, state


def paged_decode_attention_ref(q, k_pool, v_pool, table, pos, step,
                               window: Optional[int] = None) -> jax.Array:
    """Paged single-token decode oracle: dense gather + masked softmax.

    q: (B, Hkv, G, hd); k/v_pool: (NB, Hkv, bs, hd); table: (B, nbs) int32
    pool ids (-1 = unallocated); pos: (NB, bs) int32 absolute positions
    (-1 = empty); step: (B,) query positions.  Each slot attends its valid
    ring window ``(step - W, step]`` where ``W = nbs * bs``; requires at
    least one valid position per slot.  Returns (B, Hkv, G, hd) fp32.
    """
    B, Hkv, G, hd = q.shape
    bs = k_pool.shape[2]
    nbs = table.shape[1]
    W = nbs * bs
    j = jnp.arange(W)
    blk = table[:, j // bs]                            # (B, W)
    off = jnp.broadcast_to(j % bs, (B, W))
    safe = jnp.maximum(blk, 0)
    k = k_pool[safe, :, off, :].astype(jnp.float32)    # (B, W, Hkv, hd)
    v = v_pool[safe, :, off, :].astype(jnp.float32)
    p = jnp.where(blk >= 0, pos[safe, off], -1)
    s = jnp.einsum("bhgd,bwhd->bhgw", q.astype(jnp.float32), k) * hd ** -0.5
    stp = step.reshape(B, 1, 1, 1)
    pv = p[:, None, None, :]
    valid = (pv >= 0) & (pv <= stp) & (pv > stp - W)
    if window is not None:
        valid &= pv > stp - window
    s = jnp.where(valid, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgw,bwhd->bhgd", w, v)


def suffix_prefill_attention_ref(q, k, v, ctx_k, ctx_v, q_pos, ctx_pos,
                                 causal: bool = True,
                                 window: Optional[int] = None,
                                 q_per_kv: int = 1) -> jax.Array:
    """Suffix-prefill oracle: dense masked softmax over context + chunk.

    q/k/v: (B, Sc, Hq|Hkv, hd) suffix chunk heads; ctx_k/ctx_v:
    (B, C, Hkv, hd) cached context; q_pos (B, Sc) / ctx_pos (B, C) absolute
    positions, -1 = invalid.  GQA by head repetition, fp32 softmax.
    Returns (B, Sc, Hq, hd) fp32.
    """
    B, Sq, Hq, hd = q.shape
    G = q_per_kv
    kc = jnp.concatenate([ctx_k, k], axis=1).astype(jnp.float32)
    vc = jnp.concatenate([ctx_v, v], axis=1).astype(jnp.float32)
    kp = jnp.concatenate([ctx_pos, q_pos], axis=1)
    kc = jnp.repeat(kc, G, axis=2)                     # (B, T, Hq, hd)
    vc = jnp.repeat(vc, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kc) * hd ** -0.5
    valid = kp[:, None, None, :] >= 0
    if causal:
        rel = q_pos[:, None, :, None] - kp[:, None, None, :]
        valid = valid & (rel >= 0)
        if window is not None:
            valid = valid & (rel < window)
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vc)


def tte_sample_ref(logits, u) -> Tuple[jax.Array, jax.Array]:
    """Competing-exponential sampler oracle.

    logits, u: (B, V) fp32.  Returns (event (B,) int32, t_min (B,) f32).
    t_i = -exp(-logit_i) * ln(u_i).
    """
    u = jnp.clip(u, 1e-12, 1.0 - 1e-12)
    t = -jnp.exp(-logits.astype(jnp.float32)) * jnp.log(u)
    idx = jnp.argmin(t, axis=-1).astype(jnp.int32)
    tmin = jnp.take_along_axis(t, idx[..., None], axis=-1)[..., 0]
    return idx, tmin
