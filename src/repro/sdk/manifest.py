"""FAIR manifest for exported model artifacts.

The paper's FAIR claim rests on the exported artifact being Findable (stable
identifiers + checksums), Accessible (self-contained directory, no framework
needed), Interoperable (an open interchange format — StableHLO here, ONNX in
the paper), and Reusable (documented signature, provenance, license, and the
sampling semantics needed to *use* the logits).  This module materializes
those fields as ``manifest.json``.

Spec versions
-------------
* **v1** (``1.0``) — one fixed-shape full-sequence graph (``model.bin``).
* **v2** (``2.0``) — additionally ships a ``prefill`` graph and a KV-cached
  ``decode_step`` graph (cache arrays as explicit graph I/O, the way browser
  ONNX deployments ship decode graphs), described under the ``graphs`` key.
``sdk.runtime.Runtime`` dispatches on ``spec_version``; v1 artifacts keep
loading unchanged.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Any, Dict, Optional

from repro.configs.base import ModelConfig

SPEC_V1 = "1.0"
SPEC_V2 = "2.0"
SPEC_VERSION = SPEC_V2
INTERCHANGE = "stablehlo+jax.export"   # the ONNX analogue (DESIGN.md §2)


def sha256_file(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return "sha256:" + h.hexdigest()


def build_manifest(cfg: ModelConfig, artifact_dir: str, *,
                   signature: Dict[str, Any],
                   spec_version: str = SPEC_VERSION,
                   graphs: Optional[Dict[str, Any]] = None,
                   provenance: str = "Duarte et al. 2026; Shmatko et al. 2025 "
                                     "(Delphi-2M); trained on synthetic data",
                   license_id: str = "Apache-2.0") -> Dict[str, Any]:
    files = {}
    for name in sorted(os.listdir(artifact_dir)):
        if name == "manifest.json":
            continue
        files[name] = sha256_file(os.path.join(artifact_dir, name))
    m = {
        "spec_version": spec_version,
        # F — findability
        "name": cfg.name,
        "identifier": f"repro/{cfg.name}@{files.get('model.bin', 'unhashed')[:23]}",
        "description": "Generative disease-history model (event + "
                       "time-to-event logits).",
        # A — accessibility
        "files": files,
        "requires": ["any XLA runtime with StableHLO support (CPU/TPU/GPU)",
                     "numpy (host-side pre/post-processing only)"],
        # I — interoperability
        "interchange_format": INTERCHANGE,
        "signature": signature,
        # R — reusability
        "provenance": provenance,
        "license": license_id,
        "config": dataclasses.asdict(cfg),
        "sampling": {
            "method": "competing-exponential time-to-event (paper eq. 1)",
            "formula": "t_i = -exp(-logit_i) * ln(u_i); next = argmin_i t_i",
            "termination": {"death_token": cfg.death_token,
                            "max_age_years": cfg.max_age},
        },
        "privacy": "inference requires only this artifact; no network calls, "
                   "no server-side state (paper claim C5)",
    }
    if graphs is not None:
        m["graphs"] = graphs
    return m


def write_manifest(manifest: Dict[str, Any], artifact_dir: str) -> str:
    path = os.path.join(artifact_dir, "manifest.json")
    with open(path, "w") as f:
        json.dump(manifest, f, indent=2, default=str)
    return path


def read_manifest(artifact_dir: str) -> Dict[str, Any]:
    with open(os.path.join(artifact_dir, "manifest.json")) as f:
        return json.load(f)


class ChecksumError(ValueError):
    """A manifest-listed file is missing or fails its checksum."""


OK, MISMATCH, MISSING = "ok", "mismatch", "missing"


@dataclasses.dataclass
class ChecksumReport:
    """Per-file integrity verdict for one artifact directory.

    ``files`` maps each manifest-listed file name to "ok" / "mismatch" /
    "missing".  Truthy exactly when every file is "ok", so existing
    ``assert verify_checksums(d)`` call sites keep working.
    """
    artifact_dir: str
    files: Dict[str, str]

    @property
    def ok(self) -> bool:
        return all(v == OK for v in self.files.values())

    @property
    def bad_files(self) -> Dict[str, str]:
        return {k: v for k, v in self.files.items() if v != OK}

    def __bool__(self) -> bool:
        return self.ok

    def __str__(self) -> str:
        if self.ok:
            return f"all {len(self.files)} files verified"
        bad = ", ".join(f"{k}: {v}" for k, v in sorted(self.bad_files.items()))
        return f"integrity failure ({bad})"


def verify_checksums(artifact_dir: str, *, strict: bool = False
                     ) -> ChecksumReport:
    """Verify every manifest-listed file, returning a structured report.

    A missing file is reported as "missing" (not raised), a digest mismatch
    as "mismatch".  With ``strict=True`` any non-ok file raises
    :class:`ChecksumError` naming the offending file(s).
    """
    m = read_manifest(artifact_dir)
    report: Dict[str, str] = {}
    for name, digest in m["files"].items():
        path = os.path.join(artifact_dir, name)
        if not os.path.isfile(path):
            report[name] = MISSING
        elif sha256_file(path) != digest:
            report[name] = MISMATCH
        else:
            report[name] = OK
    rep = ChecksumReport(artifact_dir=artifact_dir, files=report)
    if strict and not rep.ok:
        raise ChecksumError(
            f"artifact {artifact_dir!r} failed verification: {rep}")
    return rep
