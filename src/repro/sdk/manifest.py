"""FAIR manifest for exported model artifacts.

The paper's FAIR claim rests on the exported artifact being Findable (stable
identifiers + checksums), Accessible (self-contained directory, no framework
needed), Interoperable (an open interchange format — StableHLO here, ONNX in
the paper), and Reusable (documented signature, provenance, license, and the
sampling semantics needed to *use* the logits).  This module materializes
those fields as ``manifest.json``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Any, Dict

from repro.configs.base import ModelConfig

SPEC_VERSION = "1.0"
INTERCHANGE = "stablehlo+jax.export"   # the ONNX analogue (DESIGN.md §2)


def sha256_file(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return "sha256:" + h.hexdigest()


def build_manifest(cfg: ModelConfig, artifact_dir: str, *,
                   signature: Dict[str, Any],
                   provenance: str = "Duarte et al. 2026; Shmatko et al. 2025 "
                                     "(Delphi-2M); trained on synthetic data",
                   license_id: str = "Apache-2.0") -> Dict[str, Any]:
    files = {}
    for name in sorted(os.listdir(artifact_dir)):
        if name == "manifest.json":
            continue
        files[name] = sha256_file(os.path.join(artifact_dir, name))
    return {
        "spec_version": SPEC_VERSION,
        # F — findability
        "name": cfg.name,
        "identifier": f"repro/{cfg.name}@{files.get('model.bin', 'unhashed')[:23]}",
        "description": "Generative disease-history model (event + "
                       "time-to-event logits).",
        # A — accessibility
        "files": files,
        "requires": ["any XLA runtime with StableHLO support (CPU/TPU/GPU)",
                     "numpy (host-side pre/post-processing only)"],
        # I — interoperability
        "interchange_format": INTERCHANGE,
        "signature": signature,
        # R — reusability
        "provenance": provenance,
        "license": license_id,
        "config": dataclasses.asdict(cfg),
        "sampling": {
            "method": "competing-exponential time-to-event (paper eq. 1)",
            "formula": "t_i = -exp(-logit_i) * ln(u_i); next = argmin_i t_i",
            "termination": {"death_token": cfg.death_token,
                            "max_age_years": cfg.max_age},
        },
        "privacy": "inference requires only this artifact; no network calls, "
                   "no server-side state (paper claim C5)",
    }


def write_manifest(manifest: Dict[str, Any], artifact_dir: str) -> str:
    path = os.path.join(artifact_dir, "manifest.json")
    with open(path, "w") as f:
        json.dump(manifest, f, indent=2, default=str)
    return path


def read_manifest(artifact_dir: str) -> Dict[str, Any]:
    with open(os.path.join(artifact_dir, "manifest.json")) as f:
        return json.load(f)


def verify_checksums(artifact_dir: str) -> bool:
    m = read_manifest(artifact_dir)
    for name, digest in m["files"].items():
        if sha256_file(os.path.join(artifact_dir, name)) != digest:
            return False
    return True
