"""Model export: the ONNX-conversion step of the paper, JAX-native.

``export_model`` serializes inference graphs via ``jax.export`` into
StableHLO artifacts plus a parameter archive and a FAIR manifest.  The
artifact directory is self-contained:

    model.bin       full-sequence graph  f(params, tokens[, ages]) -> logits
    prefill.bin     (spec v2) prompt -> (last-token logits, KV cache leaves)
    decode.bin      (spec v2) KV-cached one-token step: cache arrays are
                    explicit graph inputs AND outputs, the way browser ONNX
                    deployments ship decode graphs
    params.npz      parameter arrays keyed by flattened pytree path
    manifest.json   FAIR metadata (checksums, signature, provenance, sampling)

The loading side (``sdk.runtime``) imports **no model code** — exactly the
decoupling the paper achieves with ONNX (DESIGN.md §2, claim C2).  The cache
pytree is flattened to a plain list of arrays at the export boundary, so the
serialized signatures contain only standard containers and the runtime never
needs the ``LayerCache`` class.
"""
from __future__ import annotations

import os
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import export as jexport

from repro.configs.base import ModelConfig
from repro.core.delphi import get_logits
from repro.models import decode_step, forward, mask_padded_positions
from repro.sdk.manifest import (SPEC_V1, SPEC_V2, build_manifest,
                                write_manifest)

FULL_GRAPH = "model.bin"
PREFILL_GRAPH = "prefill.bin"
DECODE_GRAPH = "decode.bin"


def _flatten_params(params) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def nest(flat: Dict[str, np.ndarray]) -> Dict:
    """Rebuild the nested-dict pytree from flattened 'a/b/c' keys."""
    root: Dict = {}
    for key, val in flat.items():
        parts = key.split("/")
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = val
    return root


def build_inference_fns(cfg: ModelConfig, seq_len: int) -> Dict[str, Any]:
    """The three inference callables an artifact serializes, plus their specs.

    Shared between ``export_model`` and ``repro.api.LocalBackend`` (which jits
    the same functions in-process), so the artifact decode path and the local
    decode path are the same graph by construction.

    Returns dict with:
      ``full(p, tokens[, ages]) -> logits (1, S, V)``
      ``prefill(p, tokens[, ages], last_index) -> (logits (1, V), [cache...])``
      ``decode(p, [cache...], token[, age], step) -> (logits (1, V), [cache...])``
      ``cache_treedef`` / ``cache_leaves`` (ShapeDtypeStructs) and the
      jax.ShapeDtypeStruct argument lists ``*_args`` for each graph.
    """
    S = seq_len
    delphi = cfg.age_encoding

    if delphi:
        def full_fn(p, tokens, ages):
            return get_logits(p, cfg, tokens, ages)
    else:
        def full_fn(p, tokens):
            return forward(p, cfg, {"tokens": tokens},
                           mode="train")["logits"]

    def _batch(tokens, ages):
        b = {"tokens": tokens}
        if delphi:
            b["ages"] = ages
        return b

    def _prefill(p, tokens, ages, last_index):
        out = forward(p, cfg, _batch(tokens, ages), mode="prefill",
                      cache_width=S, last_index=last_index)
        # right-padded positions hold garbage K/V: invalidate them so the
        # decode graph never attends past the prompt's true last token
        cache = mask_padded_positions(out["cache"], last_index)
        return out["logits"][:, 0], jax.tree_util.tree_leaves(cache)

    if delphi:
        def prefill_fn(p, tokens, ages, last_index):
            return _prefill(p, tokens, ages, last_index)
    else:
        def prefill_fn(p, tokens, last_index):
            return _prefill(p, tokens, None, last_index)

    tok_s = jax.ShapeDtypeStruct((1, S), jnp.int32)
    age_s = jax.ShapeDtypeStruct((1, S), jnp.float32)
    idx_s = jax.ShapeDtypeStruct((1,), jnp.int32)

    def cache_of(p, tokens, ages):
        return forward(p, cfg, _batch(tokens, ages), mode="prefill",
                       cache_width=S)["cache"]

    def cache_shape(p_spec):
        shape = jax.eval_shape(cache_of, p_spec, tok_s,
                               age_s if delphi else None)
        return jax.tree_util.tree_flatten(shape)

    # treedef is shape-independent; leaves need p_spec, resolved lazily
    _treedef_box: list = []

    def _unflatten(leaves):
        return jax.tree_util.tree_unflatten(_treedef_box[0], leaves)

    def _decode(p, cache_leaves, token, age, step):
        cache = _unflatten(list(cache_leaves))
        d = decode_step(p, cfg, cache, _batch(token, age), step)
        return d["logits"][:, 0], jax.tree_util.tree_leaves(d["cache"])

    if delphi:
        def decode_fn(p, cache_leaves, token, age, step):
            return _decode(p, cache_leaves, token, age, step)
    else:
        def decode_fn(p, cache_leaves, token, step):
            return _decode(p, cache_leaves, token, None, step)

    def resolve(p_spec):
        """Bind the cache structure for ``p_spec``; returns arg-spec lists."""
        leaves, treedef = cache_shape(p_spec)
        _treedef_box[:] = [treedef]
        full_args = [p_spec, tok_s] + ([age_s] if delphi else [])
        prefill_args = full_args + [idx_s]
        tok1 = jax.ShapeDtypeStruct((1, 1), jnp.int32)
        age1 = jax.ShapeDtypeStruct((1, 1), jnp.float32)
        step_s = jax.ShapeDtypeStruct((1,), jnp.int32)
        decode_args = ([p_spec, leaves, tok1]
                       + ([age1] if delphi else []) + [step_s])
        return {"full": full_args, "prefill": prefill_args,
                "decode": decode_args, "cache_leaves": leaves}

    return {"full": full_fn, "prefill": prefill_fn, "decode": decode_fn,
            "resolve": resolve, "delphi": delphi, "seq_len": S}


def _graph_signatures(cfg: ModelConfig, S: int, delphi: bool,
                      cache_leaves) -> Dict[str, Any]:
    """The manifest ``graphs`` section: per-graph files + tensor signatures."""
    V = cfg.vocab_size
    tok = {"name": "tokens", "shape": [1, S], "dtype": "int32"}
    age = {"name": "ages", "shape": [1, S], "dtype": "float32"}
    cache_spec = [{"shape": list(l.shape), "dtype": str(l.dtype)}
                  for l in cache_leaves]
    cache_io = {"name": "cache", "leaves": len(cache_leaves)}
    return {
        "full": {
            "file": FULL_GRAPH,
            "inputs": [tok] + ([age] if delphi else []),
            "outputs": [{"name": "logits", "shape": [1, S, V],
                         "dtype": "float32"}],
        },
        "prefill": {
            "file": PREFILL_GRAPH,
            "inputs": ([tok] + ([age] if delphi else [])
                       + [{"name": "last_index", "shape": [1],
                           "dtype": "int32"}]),
            "outputs": [{"name": "logits", "shape": [1, V],
                         "dtype": "float32"}, cache_io],
        },
        "decode_step": {
            "file": DECODE_GRAPH,
            "inputs": ([cache_io,
                        {"name": "token", "shape": [1, 1], "dtype": "int32"}]
                       + ([{"name": "age", "shape": [1, 1],
                            "dtype": "float32"}] if delphi else [])
                       + [{"name": "step", "shape": [1], "dtype": "int32"}]),
            "outputs": [{"name": "logits", "shape": [1, V],
                         "dtype": "float32"}, cache_io],
        },
        "cache": {"n_leaves": len(cache_leaves), "leaves": cache_spec,
                  "width": S},
    }


def export_model(params, cfg: ModelConfig, out_dir: str, *,
                 seq_len: Optional[int] = None,
                 logits_fn: Optional[Callable] = None,
                 spec_version: str = SPEC_V2) -> str:
    """Export inference graph(s) + params + manifest.

    The full graph is ``f(params, tokens[, ages]) -> logits`` with tokens
    (1, seq_len) int32 (the paper's App also exports a fixed-axes
    single-trajectory graph).  Spec v2 (the default) additionally exports the
    prefill and KV-cached decode_step graphs so clients generate in O(1)
    model work per token instead of re-running the O(S·V) full graph.

    ``spec_version="1"``/``"1.0"`` exports a v1 (full-graph-only) artifact;
    a custom ``logits_fn`` implies v1 (there is no way to derive prefill /
    decode graphs from an opaque callable).
    """
    if spec_version in ("1", SPEC_V1):
        spec_version = SPEC_V1
    elif spec_version in ("2", SPEC_V2):
        spec_version = SPEC_V2
    else:
        raise ValueError(f"unknown artifact spec_version {spec_version!r}; "
                         f"supported: {SPEC_V1!r}, {SPEC_V2!r}")
    if logits_fn is not None and spec_version != SPEC_V1:
        raise ValueError(
            "a custom logits_fn exports only the full graph: pass "
            "spec_version='1' (prefill/decode graphs cannot be derived "
            "from an opaque callable)")
    S = seq_len or cfg.max_seq_len
    if S > cfg.max_seq_len:
        raise ValueError(
            f"seq_len={S} exceeds cfg.max_seq_len={cfg.max_seq_len}: the "
            f"exported graph would compute positions the model was never "
            f"configured for — pass seq_len <= {cfg.max_seq_len} or raise "
            f"max_seq_len in the config")
    os.makedirs(out_dir, exist_ok=True)
    delphi = cfg.age_encoding

    fns = build_inference_fns(cfg, S)
    p_spec = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
    specs = fns["resolve"](p_spec)

    def _export_graph(fn, args, fname):
        exported = jexport.export(jax.jit(fn))(*args)
        with open(os.path.join(out_dir, fname), "wb") as f:
            f.write(exported.serialize())

    _export_graph(logits_fn if logits_fn is not None else fns["full"],
                  specs["full"], FULL_GRAPH)
    graphs = None
    if spec_version == SPEC_V2:
        _export_graph(fns["prefill"], specs["prefill"], PREFILL_GRAPH)
        _export_graph(fns["decode"], specs["decode"], DECODE_GRAPH)
        graphs = _graph_signatures(cfg, S, delphi, specs["cache_leaves"])
    np.savez(os.path.join(out_dir, "params.npz"), **_flatten_params(params))

    signature = {
        "inputs": (
            [{"name": "tokens", "shape": [1, S], "dtype": "int32"}]
            + ([{"name": "ages", "shape": [1, S], "dtype": "float32"}]
               if delphi else [])),
        "outputs": [{"name": "logits", "shape": [1, S, cfg.vocab_size],
                     "dtype": "float32"}],
        "params": "params.npz (flattened pytree paths)",
    }
    write_manifest(build_manifest(cfg, out_dir, signature=signature,
                                  spec_version=spec_version, graphs=graphs),
                   out_dir)
    return out_dir
