"""Model export: the ONNX-conversion step of the paper, JAX-native.

``export_model`` serializes the *inference graph* (``get_logits``) via
``jax.export`` into a StableHLO artifact plus a parameter archive and a FAIR
manifest.  The artifact directory is self-contained:

    model.bin       serialized StableHLO module (jax.export wire format)
    params.npz      parameter arrays keyed by flattened pytree path
    manifest.json   FAIR metadata (checksums, signature, provenance, sampling)

The loading side (``sdk.runtime``) imports **no model code** — exactly the
decoupling the paper achieves with ONNX (DESIGN.md §2, claim C2).
"""
from __future__ import annotations

import os
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import export as jexport

from repro.configs.base import ModelConfig
from repro.core.delphi import get_logits
from repro.models import forward
from repro.sdk.manifest import build_manifest, write_manifest


def _flatten_params(params) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def nest(flat: Dict[str, np.ndarray]) -> Dict:
    """Rebuild the nested-dict pytree from flattened 'a/b/c' keys."""
    root: Dict = {}
    for key, val in flat.items():
        parts = key.split("/")
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = val
    return root


def export_model(params, cfg: ModelConfig, out_dir: str, *,
                 seq_len: Optional[int] = None,
                 logits_fn: Callable = None) -> str:
    """Export the fixed-shape inference graph + params + manifest.

    The exported callable is ``f(params, tokens[, ages]) -> logits`` with
    tokens (1, seq_len) int32 (the paper's App also exports a fixed-axes
    single-trajectory graph).
    """
    os.makedirs(out_dir, exist_ok=True)
    S = seq_len or cfg.max_seq_len
    delphi = cfg.age_encoding

    if logits_fn is None:
        if delphi:
            def logits_fn(p, tokens, ages):
                return get_logits(p, cfg, tokens, ages)
        else:
            def logits_fn(p, tokens):
                return forward(p, cfg, {"tokens": tokens},
                               mode="train")["logits"]

    p_spec = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
    args = [p_spec, jax.ShapeDtypeStruct((1, S), jnp.int32)]
    if delphi:
        args.append(jax.ShapeDtypeStruct((1, S), jnp.float32))

    exported = jexport.export(jax.jit(logits_fn))(*args)
    blob = exported.serialize()
    with open(os.path.join(out_dir, "model.bin"), "wb") as f:
        f.write(blob)
    np.savez(os.path.join(out_dir, "params.npz"), **_flatten_params(params))

    signature = {
        "inputs": (
            [{"name": "tokens", "shape": [1, S], "dtype": "int32"}]
            + ([{"name": "ages", "shape": [1, S], "dtype": "float32"}]
               if delphi else [])),
        "outputs": [{"name": "logits", "shape": [1, S, cfg.vocab_size],
                     "dtype": "float32"}],
        "params": "params.npz (flattened pytree paths)",
    }
    write_manifest(build_manifest(cfg, out_dir, signature=signature), out_dir)
    return out_dir
