"""Artifact runtime — the ONNX-Runtime analogue.

Loads an exported artifact directory and executes the inference graph.
Deliberately imports **nothing** from ``repro.models`` / ``repro.core`` /
``repro.configs``: the graph semantics live entirely in the serialized
StableHLO module, the parameters in ``params.npz``, and the metadata in
``manifest.json`` — framework-decoupled exactly as the paper's ONNX artifact
is (Reusability / Interoperability, claims C2 & C5).
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

import jax
import numpy as np
from jax import export as jexport


def _nest(flat: Dict[str, np.ndarray]) -> Dict:
    root: Dict = {}
    for key in sorted(flat):
        parts = key.split("/")
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = flat[key]
    return root


class Runtime:
    """Minimal execution provider: load → run.  No model code, no network."""

    def __init__(self, artifact_dir: str):
        self.dir = artifact_dir
        with open(os.path.join(artifact_dir, "manifest.json")) as f:
            self.manifest = json.load(f)
        with open(os.path.join(artifact_dir, "model.bin"), "rb") as f:
            self._exported = jexport.deserialize(bytearray(f.read()))
        data = np.load(os.path.join(artifact_dir, "params.npz"))
        self._params = _nest({k: data[k] for k in data.files})
        self._call = jax.jit(self._exported.call)

    @property
    def input_signature(self) -> List[dict]:
        return self.manifest["signature"]["inputs"]

    def run(self, *inputs: np.ndarray) -> np.ndarray:
        """Execute the graph: run(tokens[, ages]) -> logits (numpy)."""
        out = self._call(self._params, *[np.asarray(x) for x in inputs])
        return np.asarray(out)
