"""Artifact runtime — the ONNX-Runtime analogue.

Loads an exported artifact directory and executes its inference graph(s).
Deliberately imports **nothing** from ``repro.models`` / ``repro.core`` /
``repro.configs``: the graph semantics live entirely in the serialized
StableHLO modules, the parameters in ``params.npz``, and the metadata in
``manifest.json`` — framework-decoupled exactly as the paper's ONNX artifact
is (Reusability / Interoperability, claims C2 & C5).

Spec dispatch: v1 artifacts carry only the full-sequence graph (``run``);
v2 artifacts additionally expose ``prefill`` and ``decode_step`` whose KV
cache is a plain list of arrays threaded through by the caller — no model
classes cross the boundary.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Sequence, Tuple

import jax
import numpy as np
from jax import export as jexport


def _nest(flat: Dict[str, np.ndarray]) -> Dict:
    root: Dict = {}
    for key in sorted(flat):
        parts = key.split("/")
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = flat[key]
    return root


class Runtime:
    """Minimal execution provider: load → run.  No model code, no network."""

    def __init__(self, artifact_dir: str):
        self.dir = artifact_dir
        with open(os.path.join(artifact_dir, "manifest.json")) as f:
            self.manifest = json.load(f)
        self.spec_version = str(self.manifest.get("spec_version", "1.0"))
        data = np.load(os.path.join(artifact_dir, "params.npz"))
        # one device_put at load: repeated graph calls reuse device arrays
        self._params = jax.tree_util.tree_map(
            jax.device_put, _nest({k: data[k] for k in data.files}))

        self._calls: Dict[str, object] = {}
        self._load_graph("full", "model.bin")
        graphs = self.manifest.get("graphs") or {}
        for name in ("prefill", "decode_step"):
            if name in graphs:
                self._load_graph(name, graphs[name]["file"])

    def _load_graph(self, name: str, fname: str) -> None:
        with open(os.path.join(self.dir, fname), "rb") as f:
            exported = jexport.deserialize(bytearray(f.read()))
        self._calls[name] = jax.jit(exported.call)

    # -- introspection --------------------------------------------------------
    @property
    def input_signature(self) -> List[dict]:
        return self.manifest["signature"]["inputs"]

    @property
    def graphs(self) -> List[str]:
        return sorted(self._calls)

    @property
    def has_decode_graph(self) -> bool:
        return "decode_step" in self._calls

    # -- execution ------------------------------------------------------------
    def run(self, *inputs) -> np.ndarray:
        """Execute the full graph: run(tokens[, ages]) -> logits (numpy)."""
        out = self._calls["full"](self._params,
                                  *[np.asarray(x) for x in inputs])
        return np.asarray(out)

    def prefill(self, *inputs) -> Tuple[np.ndarray, List]:
        """prefill(tokens[, ages], last_index) -> (logits (1, V), cache).

        ``cache`` is an opaque list of device arrays to thread into
        ``decode_step``; only spec-v2 artifacts ship this graph."""
        logits, cache = self._graph("prefill")(
            self._params, *[np.asarray(x) for x in inputs])
        return np.asarray(logits), cache

    def decode_step(self, cache: Sequence, *inputs
                    ) -> Tuple[np.ndarray, List]:
        """decode_step(cache, token[, age], step) -> (logits (1, V), cache)."""
        logits, cache = self._graph("decode_step")(
            self._params, list(cache), *[np.asarray(x) for x in inputs])
        return np.asarray(logits), cache

    def _graph(self, name: str):
        if name not in self._calls:
            raise ValueError(
                f"artifact {self.dir!r} (spec {self.spec_version}) does not "
                f"ship a {name!r} graph — re-export with spec v2 "
                f"(sdk.export_model) to enable KV-cached decoding")
        return self._calls[name]
