"""InferenceSession — the paper's JavaScript SDK, now a thin shim.

DEPRECATED surface: ``InferenceSession`` is kept as a compatibility layer
over the unified client (``repro.api.Client`` with an ``ArtifactBackend``)
and preserves the original call/return conventions exactly:

  loading            -> ``InferenceSession(artifact_dir)``
  tensor creation    -> backend ``_pad_inputs`` (pad to the graph's fixed axes)
  execution          -> ``get_logits`` (alias ``getLogits``, deprecated)
  post-processing    -> ``generate_trajectory`` (alias ``generateTrajectory``)
                        — eq. 1 sampling in *host* NumPy, outside the graph,
                        exactly where the browser SDK samples in JS.

The shim pins the paper-faithful **full-graph-per-token** loop
(``use_decode_graph=False``) so v1 numerics are preserved bit-for-bit; new
code should use ``repro.api.Client`` directly, which on spec-v2 artifacts
generates via the exported prefill + KV-cached decode graphs instead.

Termination defaults match the paper: Death token, max age 85 — both
overridable by the SDK user.  ``uniforms`` can be injected for bit-parity
tests against the in-graph sampler (claims C2/C3).
"""
from __future__ import annotations

import warnings
from typing import Dict, List, Optional, Sequence

import numpy as np


def _deprecated_alias(old: str, new: str, fn):
    def wrapper(self, *args, **kwargs):
        warnings.warn(f"InferenceSession.{old} is deprecated; use "
                      f"repro.api.Client (or .{new}) instead",
                      DeprecationWarning, stacklevel=2)
        return fn(self, *args, **kwargs)
    wrapper.__name__ = old
    wrapper.__doc__ = f"Deprecated camelCase alias of :meth:`{new}`."
    return wrapper


class InferenceSession:
    def __init__(self, artifact_dir: str):
        # local import: repro.api pulls model code; keeping it out of module
        # scope avoids an import cycle through repro.sdk.__init__
        from repro.api.client import ArtifactBackend, Client
        self.client = Client(
            ArtifactBackend(artifact_dir, use_decode_graph=False))
        backend = self.client.backend
        self.runtime = backend.runtime
        self.seq_len = backend.seq_len
        self.vocab_size = backend.vocab_size
        self.has_ages = backend.has_ages
        self.death_token = backend.death_token
        self.max_age = backend.max_age

    # -- execution ------------------------------------------------------------
    def get_logits(self, tokens: Sequence[int],
                   ages: Optional[Sequence[float]] = None) -> np.ndarray:
        """Logits for the *next* event given the trajectory so far: (V,)."""
        return self.client.backend.logits(tokens, ages)

    # -- post-processing (eq. 1 sampling, host-side) ---------------------------
    def generate_trajectory(self, tokens: Sequence[int],
                            ages: Sequence[float], *,
                            max_new: int = 64,
                            max_age: Optional[float] = None,
                            death_token: Optional[int] = None,
                            rng: Optional[np.random.Generator] = None,
                            uniforms: Optional[np.ndarray] = None
                            ) -> Dict[str, List]:
        """Iterative client-side generation (the App's right-hand panel)."""
        from repro.api.schemas import GenerateRequest
        res = self.client.generate(GenerateRequest(
            tokens=tokens, ages=ages, max_new=max_new, max_age=max_age,
            death_token=death_token, uniforms=uniforms, rng=rng))
        return {"tokens": res.tokens, "ages": res.ages,
                "full_tokens": res.full_tokens, "full_ages": res.full_ages}

    # -- morbidity-risk estimates (the App's displayed output) -----------------
    def estimate_risk(self, tokens: Sequence[int], ages: Sequence[float], *,
                      horizon: float = 5.0, top: int = 10) -> List[dict]:
        """Closed-form within-horizon next-event risks, client-side.

        P(next = i, t <= h) = softmax(logits)_i * (1 - e^{-Lambda h}).
        Returns the ``top`` risks as {token, risk} dicts, highest first.
        """
        return self.client.risk(tokens, ages, horizon=horizon,
                                top=top).as_dicts()

    # paper SDK naming — deprecated camelCase aliases
    getLogits = _deprecated_alias("getLogits", "get_logits", get_logits)
    generateTrajectory = _deprecated_alias(
        "generateTrajectory", "generate_trajectory", generate_trajectory)
    estimateRisk = _deprecated_alias("estimateRisk", "estimate_risk",
                                     estimate_risk)
