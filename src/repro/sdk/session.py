"""InferenceSession — the paper's JavaScript SDK, mirrored in NumPy.

The JS SDK's responsibilities (paper §Methods) map one-to-one:

  loading            -> ``InferenceSession(artifact_dir)`` (Runtime inside)
  tensor creation    -> ``_make_inputs`` (pad to the graph's fixed axes)
  execution          -> ``get_logits`` (alias ``getLogits``)
  post-processing    -> ``generate_trajectory`` (alias ``generateTrajectory``)
                        — eq. 1 sampling in *host* NumPy, outside the graph,
                        exactly where the browser SDK samples in JS.

Termination defaults match the paper: Death token, max age 85 — both
overridable by the SDK user.  ``uniforms`` can be injected for bit-parity
tests against the in-graph sampler (claims C2/C3).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.sdk.runtime import Runtime


class InferenceSession:
    def __init__(self, artifact_dir: str):
        self.runtime = Runtime(artifact_dir)
        m = self.runtime.manifest
        self.seq_len = int(m["signature"]["inputs"][0]["shape"][1])
        self.vocab_size = int(m["signature"]["outputs"][0]["shape"][2])
        self.has_ages = any(i["name"] == "ages"
                            for i in m["signature"]["inputs"])
        samp = m.get("sampling", {}).get("termination", {})
        self.death_token = int(samp.get("death_token", 1))
        self.max_age = float(samp.get("max_age_years", 85.0))

    # -- tensor creation ------------------------------------------------------
    def _make_inputs(self, tokens: Sequence[int],
                     ages: Optional[Sequence[float]]):
        S = self.seq_len
        if len(tokens) == 0:
            raise ValueError("empty trajectory: pass at least one event token")
        if len(tokens) > S:
            raise ValueError(f"trajectory longer than graph axis ({S})")
        t = np.zeros((1, S), np.int32)
        t[0, :len(tokens)] = tokens
        if not self.has_ages:
            return (t,)
        if ages is None:
            raise ValueError("this artifact's signature declares an 'ages' "
                             "input: pass ages alongside tokens")
        if len(ages) != len(tokens):
            raise ValueError(f"ages/tokens length mismatch: "
                             f"{len(ages)} vs {len(tokens)}")
        a = np.zeros((1, S), np.float32)
        a[0, :len(ages)] = ages
        if len(ages):
            a[0, len(ages):] = ages[-1]
        return t, a

    # -- execution ------------------------------------------------------------
    def get_logits(self, tokens: Sequence[int],
                   ages: Optional[Sequence[float]] = None) -> np.ndarray:
        """Logits for the *next* event given the trajectory so far: (V,)."""
        inputs = self._make_inputs(tokens, ages)
        logits = self.runtime.run(*inputs)          # (1, S, V)
        return logits[0, len(tokens) - 1]

    getLogits = get_logits                           # paper SDK naming

    # -- post-processing (eq. 1 sampling, host-side) ---------------------------
    def generate_trajectory(self, tokens: Sequence[int],
                            ages: Sequence[float], *,
                            max_new: int = 64,
                            max_age: Optional[float] = None,
                            death_token: Optional[int] = None,
                            rng: Optional[np.random.Generator] = None,
                            uniforms: Optional[np.ndarray] = None
                            ) -> Dict[str, List]:
        """Iterative client-side generation (the App's right-hand panel)."""
        max_age = self.max_age if max_age is None else max_age
        death = self.death_token if death_token is None else death_token
        rng = rng or np.random.default_rng(0)
        toks = list(tokens)
        ags = [float(a) for a in ages]
        new_toks: List[int] = []
        new_ages: List[float] = []
        for i in range(max_new):
            if len(toks) >= self.seq_len:
                break
            logits = self.get_logits(toks, ags).astype(np.float64)
            u = (uniforms[i] if uniforms is not None
                 else rng.uniform(size=self.vocab_size))
            u = np.clip(u, 1e-12, 1 - 1e-12)
            t = -np.exp(-logits) * np.log(u)        # paper eq. 1
            evt = int(np.argmin(t))
            t_min = float(t[evt])
            age = ags[-1] + t_min
            if age > max_age:
                break
            toks.append(evt)
            ags.append(age)
            new_toks.append(evt)
            new_ages.append(age)
            if evt == death:
                break
        return {"tokens": new_toks, "ages": new_ages,
                "full_tokens": toks, "full_ages": ags}

    generateTrajectory = generate_trajectory         # paper SDK naming

    # -- morbidity-risk estimates (the App's displayed output) -----------------
    def estimate_risk(self, tokens: Sequence[int], ages: Sequence[float], *,
                      horizon: float = 5.0, top: int = 10) -> List[dict]:
        """Closed-form within-horizon next-event risks, client-side.

        P(next = i, t <= h) = softmax(logits)_i * (1 - e^{-Lambda h}).
        Returns the ``top`` risks as {token, risk} dicts, highest first.
        """
        logits = self.get_logits(tokens, ages).astype(np.float64)
        log_rate = np.logaddexp.reduce(logits)
        frac = np.exp(logits - log_rate)
        p_any = 1.0 - np.exp(-np.exp(log_rate) * horizon)
        risk = frac * p_any
        order = np.argsort(-risk)[:top]
        return [{"token": int(i), "risk": float(risk[i])} for i in order]

    estimateRisk = estimate_risk
