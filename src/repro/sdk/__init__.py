"""FAIR portability layer: export (ONNX analogue), runtime, SDK session."""
from repro.sdk.export import build_inference_fns, export_model, nest
from repro.sdk.manifest import (SPEC_V1, SPEC_V2, SPEC_VERSION, ChecksumError,
                                ChecksumReport, build_manifest, read_manifest,
                                sha256_file, verify_checksums, write_manifest)
from repro.sdk.runtime import Runtime
from repro.sdk.session import InferenceSession

__all__ = ["build_inference_fns", "export_model", "nest",
           "SPEC_V1", "SPEC_V2", "SPEC_VERSION",
           "ChecksumError", "ChecksumReport",
           "build_manifest", "read_manifest", "sha256_file",
           "verify_checksums", "write_manifest", "Runtime",
           "InferenceSession"]
