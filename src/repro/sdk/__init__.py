"""FAIR portability layer: export (ONNX analogue), runtime, SDK session."""
from repro.sdk.export import export_model, nest
from repro.sdk.manifest import (build_manifest, read_manifest, sha256_file,
                                verify_checksums, write_manifest)
from repro.sdk.runtime import Runtime
from repro.sdk.session import InferenceSession

__all__ = ["export_model", "nest", "build_manifest", "read_manifest",
           "sha256_file", "verify_checksums", "write_manifest", "Runtime",
           "InferenceSession"]
