"""Divisibility-aware sharding rules for every parameter / activation tree.

Strategy (DESIGN.md §4): batch -> ("pod","data"); heads / FFN hidden / MoE
experts / Mamba inner channels / vocab -> "model".  ``partition`` drops any
mesh axis that does not evenly divide its dimension — e.g. 8 KV heads on a
16-way model axis stay replicated — so every (arch x shape x mesh) lowers
without per-arch hand tuning; the roofline then *shows* the cost of any
replication and §Perf attacks it.
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import data_axes

Axis = Union[None, str, Sequence[str]]


def _axis_size(mesh, axis: Axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape.get(axis, 0) or 0
    return int(np.prod([mesh.shape.get(a, 0) or 0 for a in axis]))


def partition(mesh, shape: Sequence[int], axes: Sequence[Axis]) -> P:
    """Build a PartitionSpec keeping only axes that exist and divide."""
    spec = []
    for dim, ax in zip(shape, axes):
        if ax is None:
            spec.append(None)
            continue
        size = _axis_size(mesh, ax)
        if size > 1 and dim % size == 0:
            spec.append(tuple(ax) if not isinstance(ax, (str, type(None))) else ax)
        else:
            spec.append(None)
    return P(*spec)


def _param_axes(key_path: str, shape) -> list:
    """Logical axes for a parameter leaf, by trailing name + rank.

    Layer-stacked leaves carry a leading L dim (never sharded).
    """
    name = key_path.split("/")[-1]
    nd = len(shape)

    def pad(trailing):  # left-pad with None for the optional layer-stack dim
        return [None] * (nd - len(trailing)) + list(trailing)

    if name == "embed":
        return pad(["model", None])                  # (V, d)
    if name == "lm_head":
        return pad([None, "model"])                  # (d, V)
    if name == "out_bias":
        return pad(["model"])                        # (V,)
    if name in ("wq", "wk", "wv"):
        return pad([None, "model", None])            # (d, H, hd)
    if name == "wo":
        return pad(["model", None, None])            # (H, hd, d)
    if name in ("bq", "bk", "bv"):
        return pad(["model", None])                  # (H, hd)
    is_expert = "moe" in key_path.split("/") and "shared" not in key_path
    if name in ("w_gate", "w_up", "w_fc"):
        if is_expert:   # expert-stacked (E, d, f): experts first, else f
            return pad(["model", None, "model_fallback_f"])
        return pad([None, "model"])
    if name in ("w_down", "w_proj"):
        if is_expert:   # (E, f, d)
            return pad(["model", "model_fallback_f", None])
        return pad(["model", None])
    if name in ("b_fc",):
        return pad(["model"])
    if name == "router":
        return pad([None, None])
    if name == "in_proj":
        return pad([None, "model"])                  # (d, d_in_proj)
    if name == "out_proj":
        return pad(["model", None])                  # (di, d)
    if name == "conv_w":
        return pad([None, "model"])                  # (w, ch)
    if name == "conv_b":
        return pad(["model"])
    return [None] * nd                               # norms, scalars, biases


def param_pspec(mesh, key_path: str, shape) -> P:
    axes = _param_axes(key_path, tuple(shape))
    size = _axis_size(mesh, "model")
    # resolve the MoE fallback: experts on "model" if divisible, else move
    # "model" to the per-expert hidden dim
    primary_ok = all(
        dim % size == 0 for dim, ax in zip(shape, axes) if ax == "model"
    ) and size > 1
    resolved = []
    for dim, ax in zip(shape, axes):
        if ax == "model":
            resolved.append("model" if primary_ok else None)
        elif ax == "model_fallback_f":
            use = (not primary_ok) and size > 1 and dim % size == 0
            resolved.append("model" if use else None)
        else:
            resolved.append(ax)
    return P(*resolved)


def _path_str(path) -> str:
    def part(p):
        if hasattr(p, "key"):
            return str(p.key)
        if hasattr(p, "name"):       # GetAttrKey (NamedTuple caches)
            return str(p.name)
        return str(p.idx)
    return "/".join(part(p) for p in path)


def param_shardings(mesh, params_shape):
    """NamedSharding pytree for a params (or opt-state moments) tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, param_pspec(mesh, _path_str(path), leaf.shape)),
        params_shape)


def opt_state_shardings(mesh, opt_shape, params_sharding):
    return {"mu": params_sharding, "nu": params_sharding,
            "step": NamedSharding(mesh, P())}


def batch_pspec(mesh, shape: Sequence[int]) -> P:
    """(B, ...) activations: batch on ("pod","data") when divisible."""
    da = data_axes(mesh)
    return partition(mesh, shape, [da] + [None] * (len(shape) - 1))


def batch_shardings(mesh, batch_shape):
    return jax.tree_util.tree_map(
        lambda leaf: NamedSharding(mesh, batch_pspec(mesh, leaf.shape)),
        batch_shape)


def cache_pspec(mesh, key_path: str, shape) -> P:
    """Decode-cache leaves are (L, B, ...): batch on data, heads/channels on
    model where divisible; KV falls back to sharding the window dim when the
    KV-head count does not divide the model axis (e.g. 8 heads on 16)."""
    name = key_path.split("/")[-1]
    da = data_axes(mesh)
    size = _axis_size(mesh, "model")
    if name in ("k", "v"):      # (L, B, Hkv, W, hd)
        if size > 1 and shape[2] % size == 0:
            return partition(mesh, shape, [None, da, "model", None, None])
        return partition(mesh, shape, [None, da, None, "model", None])
    if name == "pos":           # (L, B, W) — follow the K/V window sharding
        # only shard W if the K/V fell back to window sharding (pos and k
        # share the W axis layout either way; replication is also fine)
        return partition(mesh, shape, [None, da, None])
    if name == "h":             # (L, B, H, N, P)
        return partition(mesh, shape, [None, da, "model", None, None])
    if name == "conv":          # (L, B, w, ch)
        return partition(mesh, shape, [None, da, None, "model"])
    return partition(mesh, shape, [None, da] + [None] * (len(shape) - 2))


def cache_shardings(mesh, cache_shape):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, cache_pspec(mesh, _path_str(path), leaf.shape)),
        cache_shape)
