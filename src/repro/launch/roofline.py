"""Roofline analysis from dry-run artifacts (TPU v5e constants).

Three terms per (arch x shape x mesh), in seconds:

    compute    = FLOPs_per_device / PEAK_FLOPS
    memory     = HLO_bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / ICI_BW

(The dry-run's cost_analysis is the per-device SPMD program, so terms divide
by per-chip peaks — algebraically identical to total/(chips x peak) for a
balanced partition.)

MODEL_FLOPS uses the 6ND (train) / 2ND (inference) convention with N =
active parameters; the MODEL/HLO ratio exposes remat recompute and
dispatch waste (e.g. dense MoE dispatch).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import get_config
from repro.configs.base import ModelConfig

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
ICI_BW = 50e9             # bytes/s/link


def active_params(cfg: ModelConfig) -> int:
    """Approximate active (per-token) parameter count (MoE: top_k routed +
    shared; frontends excluded)."""
    d = cfg.d_model
    if cfg.arch_type == "ssm":
        per_layer = d * (2 * cfg.d_inner + 2 * cfg.ssm_state + cfg.ssm_n_heads) \
            + cfg.d_inner * d
    elif cfg.arch_type == "hybrid":
        mamba = d * (2 * cfg.d_inner + 2 * cfg.ssm_state + cfg.ssm_n_heads) \
            + cfg.d_inner * d
        attn = (d * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.head_dim
                + cfg.n_heads * cfg.head_dim * d + 3 * d * cfg.d_ff)
        n_apps = -(-cfg.n_layers // cfg.attn_every)
        return cfg.n_layers * mamba + n_apps * attn + 2 * cfg.vocab_size * d
    else:
        attn = (d * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.head_dim
                + cfg.n_heads * cfg.head_dim * d)
        if cfg.n_experts:
            mlp = 3 * d * cfg.moe_d_ff * (cfg.top_k + cfg.n_shared_experts)
        elif cfg.activation == "swiglu":
            mlp = 3 * d * cfg.d_ff
        else:
            mlp = 2 * d * cfg.d_ff
        per_layer = attn + mlp
        n_layers = cfg.n_layers + cfg.n_encoder_layers
        return n_layers * per_layer + 2 * cfg.vocab_size * d
    return cfg.n_layers * per_layer + 2 * cfg.vocab_size * d


def model_flops(cfg: ModelConfig, rec: Dict) -> float:
    """6*N*D train / 2*N*D inference, D = tokens processed this step."""
    n_act = active_params(cfg)
    if rec["mode"] == "train":
        tokens = rec["seq_len"] * rec["global_batch"]
        return 6.0 * n_act * tokens
    if rec["mode"] == "prefill":
        tokens = rec["seq_len"] * rec["global_batch"]
        return 2.0 * n_act * tokens
    return 2.0 * n_act * rec["global_batch"]      # decode: 1 token/row


def terms(rec: Dict) -> Dict[str, float]:
    comp = (rec["flops_per_device"] or 0.0) / PEAK_FLOPS
    memb = (rec["bytes_per_device"] or 0.0) / HBM_BW
    coll = rec.get("collective_total", 0.0) / ICI_BW
    dominant = max(("compute", comp), ("memory", memb),
                   ("collective", coll), key=lambda kv: kv[1])[0]
    return {"compute_s": comp, "memory_s": memb, "collective_s": coll,
            "dominant": dominant}


_SUGGEST = {
    "compute": "reduce redundant FLOPs (remat policy, MoE ragged dispatch, "
               "fused kernels) or widen the model axis",
    "memory": "shrink the HLO working set: bf16 residuals, fused/chunked "
              "softmax+CE, flash attention tiles, better layouts",
    "collective": "re-shard to cut all-gathers (2D sharding of embed/logits, "
                  "overlap via async collectives, fewer resharding points)",
}


def analyse(rec: Dict, cfg: Optional[ModelConfig] = None) -> Dict:
    cfg = cfg or get_config(rec["arch"])
    t = terms(rec)
    mf = model_flops(cfg, rec)
    hlo_total = (rec["flops_per_device"] or 0.0) * rec["n_chips"]
    out = dict(rec)
    out.update(t)
    out["model_flops_total"] = mf
    out["useful_ratio"] = mf / hlo_total if hlo_total else None
    out["suggestion"] = _SUGGEST[t["dominant"]]
    return out


def load_records(dirpath: str) -> List[Dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def table(recs: List[Dict]) -> str:
    rows = ["| arch | shape | mesh | compute_s | memory_s | collective_s | "
            "dominant | useful FLOP ratio | peak GiB/dev |",
            "|---|---|---|---|---|---|---|---|---|"]
    for rec in recs:
        a = analyse(rec)
        ur = f"{a['useful_ratio']:.3f}" if a["useful_ratio"] else "-"
        rows.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} "
            f"| {a['compute_s']:.3e} | {a['memory_s']:.3e} "
            f"| {a['collective_s']:.3e} | **{a['dominant']}** | {ur} "
            f"| {a['memory']['peak_estimate_bytes']/2**30:.2f} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    recs = load_records(args.dir)
    if args.markdown:
        print(table(recs))
        return
    for rec in recs:
        a = analyse(rec)
        print(f"{a['arch']:24s} {a['shape']:12s} {a['mesh']:8s} "
              f"comp {a['compute_s']:.3e}s mem {a['memory_s']:.3e}s "
              f"coll {a['collective_s']:.3e}s -> {a['dominant']:10s} "
              f"useful {a['useful_ratio'] if a['useful_ratio'] else 0:.3f}")


if __name__ == "__main__":
    main()
