"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combination.

Usage (one combination, or sweep):
    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
        --shape train_4k [--multi-pod] [--out experiments/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Writes one JSON per combination with cost_analysis, memory_analysis and the
collective-bytes breakdown parsed from the partitioned HLO — the §Roofline
inputs.
"""
# The placeholder-device override MUST precede any jax-touching import, but
# only for the CLI (`python -m repro.launch.dryrun` imports this module as
# __main__ before anything touches jax).  Library importers (tests,
# benchmarks) get NO side effect: mutating process-global XLA_FLAGS at plain
# import time leaked 512 fake devices into every pytest run that merely
# *collected* a module importing the pure helpers below, perturbing fp
# reduction order across the whole suite.
import os
if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=512")

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from typing import Dict, Optional  # noqa: E402

import jax               # noqa: E402

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config, get_shape  # noqa: E402
from repro.configs.base import InputShape, ModelConfig  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import input_specs, long_context_cfg  # noqa: E402
from repro.models import decode_step, forward, param_count  # noqa: E402
from repro.train.optimizer import OptimizerConfig  # noqa: E402
from repro.train.trainer import make_train_step  # noqa: E402

# dtype sizes for HLO shape parsing
_DT = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
       "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
       "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s+(?:\(([^)]*)\)|(\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _cost_dict(compiled) -> Dict:
    """Version-tolerant ``compiled.cost_analysis()``: older JAX returns a
    one-element list of dicts, newer JAX the dict itself."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DT:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DT[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind result bytes (per device) from partitioned HLO."""
    out: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str = m.group(1) or m.group(2)
        kind = m.group(3)
        out[kind] = out.get(kind, 0) + _shape_bytes(shape_str)
    return out


def build_step_fn(cfg: ModelConfig, shape: InputShape,
                  moe_impl: str = "dense_scan"):
    if shape.mode == "train":
        objective = "delphi" if cfg.age_encoding else "lm"
        step = make_train_step(cfg, OptimizerConfig(), objective,
                               moe_impl=moe_impl)
        return lambda params, opt_state, batch: step(params, opt_state, batch)
    if shape.mode == "prefill":
        def prefill_step(params, batch):
            out = forward(params, cfg, batch, mode="prefill",
                          moe_impl=moe_impl)
            return out["logits"][:, -1], out["cache"]
        return prefill_step
    def serve_step(params, cache, batch, step):
        out = decode_step(params, cfg, cache, batch, step, moe_impl=moe_impl)
        return out["logits"], out["cache"]
    return serve_step


def _count_one(cfg: ModelConfig, shape: InputShape, mesh,
               moe_impl: str = "dense_scan") -> Dict:
    """Compile one straight-line twin and return its counters."""
    args, shardings = input_specs(cfg, shape, mesh)
    step = build_step_fn(cfg, shape, moe_impl)
    with mesh:
        c = jax.jit(step, in_shardings=shardings).lower(*args).compile()
    ca = _cost_dict(c)
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "collectives": collective_bytes(c.as_text())}


def _lin(a: Dict, d: Dict, n: int) -> Dict:
    """a + n*d for counter dicts."""
    coll = dict(a["collectives"])
    for k, v in d["collectives"].items():
        coll[k] = coll.get(k, 0) + n * v
    return {"flops": a["flops"] + n * d["flops"],
            "bytes": a["bytes"] + n * d["bytes"],
            "collectives": {k: max(v, 0) for k, v in coll.items()}}


def _diff(b: Dict, a: Dict) -> Dict:
    return {"flops": b["flops"] - a["flops"],
            "bytes": b["bytes"] - a["bytes"],
            "collectives": {k: b["collectives"].get(k, 0)
                            - a["collectives"].get(k, 0)
                            for k in set(b["collectives"])
                            | set(a["collectives"])}}


def _extrapolated_counts(cfg: ModelConfig, shape: InputShape, mesh,
                         moe_impl: str = "dense_scan") -> Dict:
    base = cfg.replace(unroll_layers=True, attn_direct=True)
    L = cfg.n_layers
    if cfg.arch_type in ("audio", "enc_dec"):
        a = _count_one(base.replace(n_layers=1, n_encoder_layers=1),
                       shape, mesh, moe_impl)
        b = _count_one(base.replace(n_layers=2, n_encoder_layers=1),
                       shape, mesh, moe_impl)
        c = _count_one(base.replace(n_layers=1, n_encoder_layers=2),
                       shape, mesh, moe_impl)
        out = _lin(a, _diff(b, a), L - 1)
        out = _lin(out, _diff(c, a), cfg.n_encoder_layers - 1)
    elif cfg.arch_type == "hybrid":
        k = cfg.attn_every
        n_apps = -(-L // k)
        a = _count_one(base.replace(n_layers=1), shape, mesh, moe_impl)
        b = _count_one(base.replace(n_layers=2), shape, mesh, moe_impl)
        c = _count_one(base.replace(n_layers=k + 1), shape, mesh, moe_impl)
        per_mamba = _diff(b, a)
        per_attn = _diff(c, _lin(a, per_mamba, k))
        out = _lin(a, per_mamba, L - 1)
        out = _lin(out, per_attn, n_apps - 1)
    else:
        a = _count_one(base.replace(n_layers=1), shape, mesh, moe_impl)
        b = _count_one(base.replace(n_layers=2), shape, mesh, moe_impl)
        out = _lin(a, _diff(b, a), L - 1)
    return out


VARIANTS = {
    # §Perf hillclimb variants (EXPERIMENTS.md): cfg override, moe dispatch
    "seqshard": (dict(seq_shard_attn=True), "dense_scan"),
    "moe-einsum": ({}, "dense_einsum"),
    "moe-ragged": ({}, "ragged"),
    "moe-ragged-local": ({}, "ragged_local"),
    "no-remat": (dict(remat=False), "dense_scan"),
}


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               cfg_override: Optional[ModelConfig] = None,
               donate: bool = True, variant: Optional[str] = None) -> Dict:
    shape = get_shape(shape_name)
    cfg = cfg_override or get_config(arch)
    cfg = long_context_cfg(cfg, shape)
    if shape.mode == "train" and cfg_override is None:
        # activation checkpointing over the layer scan is the deployment
        # baseline for 4k x 256 training (see EXPERIMENTS.md §Perf)
        cfg = cfg.replace(remat=True)
    moe_impl = "dense_scan"
    if variant:
        over, moe_impl = VARIANTS[variant]
        cfg = cfg.replace(**over)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size

    args, shardings = input_specs(cfg, shape, mesh)
    step_fn = build_step_fn(cfg, shape, moe_impl)
    donate_argnums = ()
    if donate and shape.mode == "train":
        donate_argnums = (0, 1)   # params + opt state donated (memory truth)
    elif donate and shape.mode == "decode":
        donate_argnums = (1,)     # cache donated

    t0 = time.time()
    with mesh:
        jitted = jax.jit(step_fn, in_shardings=shardings,
                         donate_argnums=donate_argnums)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost_loop = _cost_dict(compiled)

    # Exact FLOP/byte/collective counts: XLA's CPU cost analysis counts
    # while-loop bodies ONCE, so the scanned deployment graph undercounts by
    # ~n_layers.  We compile straight-line (unrolled, loop-free attention)
    # twins at depth 1 and 2 and extrapolate linearly — exact, because every
    # layer is an identical subgraph (DESIGN.md / EXPERIMENTS.md §Method).
    cost = _extrapolated_counts(cfg, shape, mesh, moe_impl)
    coll = cost.pop("collectives")
    rec = {
        "arch": arch, "shape": shape_name,
        "variant": variant,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": int(n_chips),
        "mode": shape.mode,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "sliding_window": cfg.sliding_window,
        "n_params": None,   # filled below (cheap eval_shape count)
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops_per_device": cost.get("flops"),
        "bytes_per_device": cost.get("bytes"),
        "flops_per_device_loop_counted": cost_loop.get("flops"),
        "collective_bytes_per_device": coll,
        "collective_total": sum(coll.values()),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_bytes": (mem.argument_size_in_bytes
                                    + mem.output_size_in_bytes
                                    + mem.temp_size_in_bytes
                                    - mem.alias_size_in_bytes),
        },
    }
    import numpy as np
    from repro.launch.specs import params_spec
    rec["n_params"] = int(sum(
        int(np.prod(l.shape))
        for l in jax.tree_util.tree_leaves(params_spec(cfg))))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--variant", default=None, choices=sorted(VARIANTS))
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    combos = []
    if args.all:
        combos = [(a, s.name) for a in ASSIGNED_ARCHS for s in INPUT_SHAPES]
    else:
        combos = [(args.arch, args.shape)]

    failures = []
    for arch, shape in combos:
        tag = f"{arch}_{shape}_{'2x16x16' if args.multi_pod else '16x16'}"
        if args.variant:
            tag += f"_{args.variant}"
        try:
            rec = dryrun_one(arch, shape, multi_pod=args.multi_pod,
                             variant=args.variant)
            path = os.path.join(args.out, tag + ".json")
            with open(path, "w") as f:
                json.dump(rec, f, indent=2)
            print(f"OK   {tag}: lower {rec['lower_s']}s compile "
                  f"{rec['compile_s']}s flops/dev {rec['flops_per_device']:.3e} "
                  f"coll {rec['collective_total']:.3e}B "
                  f"peak {rec['memory']['peak_estimate_bytes']/2**30:.2f}GiB")
        except Exception as e:  # noqa: BLE001
            failures.append((tag, repr(e)))
            print(f"FAIL {tag}: {e}")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures: "
                         + ", ".join(t for t, _ in failures))
    print("all dry-runs passed")


if __name__ == "__main__":
    main()
