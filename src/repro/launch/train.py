"""Training launcher.

On this CPU container it runs the paper-scale Delphi training end-to-end
(synthetic data -> dual loss -> checkpoint).  On a real TPU slice the same
entry point builds the production mesh and shards the identical
``make_train_step`` with the identical sharding rules the dry-run proves out.

    PYTHONPATH=src python -m repro.launch.train --arch delphi-2m --steps 200 \
        [--patients 2048] [--out runs/delphi]
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np

from repro.configs import get_config
from repro.data import (SimulatorConfig, batches, dataset_stats,
                        generate_dataset, pack_trajectories)
from repro.models import init_params, param_count
from repro.train import OptimizerConfig, save, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="delphi-2m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--patients", type=int, default=7144)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale variant of the arch")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    if jax.default_backend() == "cpu":
        cfg = cfg.replace(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    print(f"{cfg.name}: {param_count(params):,} params "
          f"({jax.default_backend()} backend, {len(jax.devices())} devices)")

    if cfg.age_encoding:
        sim = SimulatorConfig(n_train=args.patients, n_val=args.patients,
                              seed=args.seed)
        train, val = generate_dataset(sim)
        print("train data:", dataset_stats(train))
        pt = pack_trajectories(train, args.seq_len)
        pv = pack_trajectories(val, args.seq_len)
        ti = batches(pt, args.batch, seed=args.seed)
        vi = batches(pv, args.batch, seed=args.seed + 1)
        objective = "delphi"
    else:
        rng = np.random.default_rng(args.seed)
        def lm_iter():
            while True:
                yield {"tokens": rng.integers(
                    0, cfg.vocab_size, (args.batch, args.seq_len)).astype(np.int32)}
        ti, vi = lm_iter(), lm_iter()
        objective = "lm"

    ocfg = OptimizerConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                           total_steps=args.steps)
    params, hist = train_loop(params, cfg, ocfg, ti, objective=objective,
                              steps=args.steps, eval_iter=vi,
                              eval_every=max(args.steps // 4, 25))
    if args.out:
        save(args.out, params, cfg, extra={"history": hist})
        with open(os.path.join(args.out, "history.json"), "w") as f:
            json.dump(hist, f)
        print("saved to", args.out)


if __name__ == "__main__":
    main()
