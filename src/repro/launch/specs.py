"""ShapeDtypeStruct stand-ins + shardings for every (arch x input-shape) step.

``input_specs`` returns (args, in_shardings) for the step function that the
dry-run lowers — weak-type-correct, shardable, zero allocation
(``jax.eval_shape`` everywhere).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import base as cb
from repro.configs.base import InputShape, ModelConfig
from repro.launch import sharding as sh
from repro.models import init_params, make_decode_cache
from repro.train.optimizer import init_opt_state


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def params_spec(cfg: ModelConfig, *, serving: bool = False):
    spec = jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.key(0))
    if serving:
        # inference ships bf16 checkpoints (fp32 masters stay in training)
        spec = jax.tree_util.tree_map(
            lambda l: jax.ShapeDtypeStruct(
                l.shape, jnp.bfloat16 if l.dtype == jnp.float32 else l.dtype),
            spec)
    return spec


def model_batch_spec(cfg: ModelConfig, batch: int, seq: int,
                     *, for_train: bool) -> Dict[str, Any]:
    """The model-input dict for one step (tokens + frontend stubs + labels)."""
    b: Dict[str, Any] = {"tokens": sds((batch, seq), jnp.int32)}
    if cfg.age_encoding:
        b["ages"] = sds((batch, seq), jnp.float32)
        if for_train:
            b["targets"] = sds((batch, seq), jnp.int32)
            b["target_dt"] = sds((batch, seq), jnp.float32)
            b["loss_mask"] = sds((batch, seq), jnp.float32)
    if cfg.frontend == "vision_patches":
        b["patches"] = sds((batch, cfg.n_frontend_tokens, cfg.d_model),
                           jnp.dtype(cfg.dtype))
    if cfg.frontend == "audio_frames":
        b["frames"] = sds((batch, max(seq // cfg.enc_len_ratio, 1),
                           cfg.d_model), jnp.dtype(cfg.dtype))
    return b


def long_context_cfg(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """long_500k policy (DESIGN.md): attention archs get the sliding-window
    variant; SSM/hybrid run natively."""
    if shape.name == "long_500k" and cfg.arch_type != cb.SSM \
            and cfg.sliding_window is None:
        return cfg.with_sliding_window(8192)
    return cfg


def input_specs(cfg: ModelConfig, shape: InputShape, mesh
                ) -> Tuple[Tuple, Tuple]:
    """-> (args, in_shardings) for the step function of ``shape.mode``.

    train:   (params, opt_state, batch)
    prefill: (params, batch)
    decode:  (params, cache, batch, step)
    """
    cfg = long_context_cfg(cfg, shape)
    p_spec = params_spec(cfg, serving=shape.mode != "train")
    p_shard = sh.param_shardings(mesh, p_spec)

    if shape.mode == "train":
        o_spec = jax.eval_shape(init_opt_state, p_spec)
        o_shard = {"mu": p_shard, "nu": p_shard,
                   "step": NamedSharding(mesh, P())}
        batch = model_batch_spec(cfg, shape.global_batch, shape.seq_len,
                                 for_train=True)
        b_shard = sh.batch_shardings(mesh, batch)
        return (p_spec, o_spec, batch), (p_shard, o_shard, b_shard)

    if shape.mode == "prefill":
        batch = model_batch_spec(cfg, shape.global_batch, shape.seq_len,
                                 for_train=False)
        b_shard = sh.batch_shardings(mesh, batch)
        return (p_spec, batch), (p_shard, b_shard)

    # decode: one new token against a cache of shape.seq_len context
    cache_spec = jax.eval_shape(
        functools.partial(make_decode_cache, cfg=cfg,
                          batch=shape.global_batch,
                          context_len=shape.seq_len), p_spec)
    c_shard = sh.cache_shardings(mesh, cache_spec)
    batch = model_batch_spec(cfg, shape.global_batch, 1, for_train=False)
    batch.pop("frames", None)    # decode reads the cross cache, not frames
    batch.pop("patches", None)   # patch tokens already live in the KV cache
    b_shard = sh.batch_shardings(mesh, batch)
    step_spec = sds((), jnp.int32)
    return (p_spec, cache_spec, batch, step_spec), \
        (p_shard, c_shard, b_shard, NamedSharding(mesh, P()))
