"""Production mesh factory (TPU v5e target).

Defined as a function — importing this module never touches jax device state,
so tests and benches keep seeing 1 CPU device; only ``dryrun.py`` forces 512
host devices (and only in its own process).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod stacks 2 pods -> 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Single-device mesh for CPU tests/examples (1x1, same axis names)."""
    return jax.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto, jax.sharding.AxisType.Auto))


def data_axes(mesh) -> tuple:
    """Mesh axes that shard the batch dimension."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis_size(mesh) -> int:
    return mesh.shape.get("model", 1)
