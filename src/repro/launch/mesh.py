"""Production mesh factory (TPU v5e target).

Defined as a function — importing this module never touches jax device state,
so tests and benches keep seeing 1 CPU device; only ``dryrun.py`` forces 512
host devices (and only in its own process).

``jax.sharding.AxisType`` (and the matching ``axis_types=`` kwarg of
``jax.make_mesh``) only exists in newer JAX releases; older ones implicitly
build Auto meshes.  ``_make_mesh`` passes the explicit Auto types when the
installed JAX supports them and silently omits them otherwise — the resulting
mesh semantics are identical (Auto is the default everywhere).
"""
from __future__ import annotations

import inspect
from typing import Sequence

import jax

# getattr (not attribute access) — newer JAX raises a deprecation
# AttributeError through module __getattr__ when the symbol is gone.
_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)
_HAS_AXIS_TYPES_KW = "axis_types" in inspect.signature(jax.make_mesh).parameters


def _make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Version-tolerant ``jax.make_mesh`` with all-Auto axis types."""
    if _AXIS_TYPE is not None and _HAS_AXIS_TYPES_KW:
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(_AXIS_TYPE.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod stacks 2 pods -> 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU tests/examples (1x1, same axis names)."""
    return _make_mesh((1, 1), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Mesh axes that shard the batch dimension."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis_size(mesh) -> int:
    return mesh.shape.get("model", 1)
