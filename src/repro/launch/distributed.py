"""Multi-host bootstrap for real TPU slices.

On actual hardware every host runs the same launcher; this helper wires
``jax.distributed`` from the standard environment variables and asserts the
expected pod topology, after which ``make_production_mesh`` sees all 256/512
devices.  On this CPU container it is a no-op (single process) — the dry-run
emulates the device count instead.

    from repro.launch.distributed import ensure_distributed
    ensure_distributed(expect_devices=512)   # 2-pod v5e-256 x 2
"""
from __future__ import annotations

import os
from typing import Optional

import jax


def ensure_distributed(*, expect_devices: Optional[int] = None,
                       coordinator: Optional[str] = None) -> int:
    """Initialize jax.distributed when launched multi-process.

    Reads ``JAX_COORDINATOR_ADDRESS`` / ``JAX_NUM_PROCESSES`` /
    ``JAX_PROCESS_ID`` (or the provided ``coordinator``).  Returns the global
    device count.  Safe to call repeatedly and on single-host setups.
    """
    num_procs = int(os.environ.get("JAX_NUM_PROCESSES", "1"))
    coord = coordinator or os.environ.get("JAX_COORDINATOR_ADDRESS")
    if num_procs > 1 and coord:
        try:
            jax.distributed.initialize(
                coordinator_address=coord,
                num_processes=num_procs,
                process_id=int(os.environ.get("JAX_PROCESS_ID", "0")))
        except RuntimeError:
            pass  # already initialized
    n = len(jax.devices())
    if expect_devices is not None and n != expect_devices:
        raise RuntimeError(
            f"expected {expect_devices} global devices, found {n}; "
            "check the slice topology / process env")
    return n
