"""Distribution + launch: mesh, sharding rules, dry-run, roofline, drivers."""
from repro.launch.mesh import (data_axes, make_host_mesh,
                               make_production_mesh, model_axis_size)

__all__ = ["data_axes", "make_host_mesh", "make_production_mesh",
           "model_axis_size"]
